"""The multi-device serving tier: Router placement, segment-boundary
work stealing, the PooledAnytimeServer facade, and the sharded
admission queue's per-shard EDF invariants.

The acceptance criterion mirrors the single-server suite: every
delivered readout — stolen and re-routed requests included — is
bit-identical to a solo ``jnp-ref`` session advanced the same number of
steps, on all three backends."""
import threading

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

import jax.numpy as jnp

from repro.core import engine
from repro.forest import make_dataset, split_dataset, train_forest
from repro.obs import NULL_TRACER, Tracer
from repro.schedule import AnytimeRuntime, ForestProgram
from repro.serve import (AdmissionQueue, PooledAnytimeServer, Request,
                         Router, ServeMetrics)
from repro.serve.router import _backlog_score

#: generous per-result wait — a stuck driver fails the test, not the run
WAIT_S = 120.0


@pytest.fixture(scope="module")
def pipeline():
    X, y = make_dataset("magic", seed=1)
    (tr, ytr), (orx, yor), (te, yte) = split_dataset(X, y, seed=1)
    rf = train_forest(tr[:800], ytr[:800], 2, n_trees=4, max_depth=5, seed=1)
    fa = rf.as_arrays()
    pp = engine.path_probs_np(fa, orx[:200])
    return fa, pp, yor[:200], te, yte


@pytest.fixture(scope="module")
def runtime(pipeline):
    fa, pp, yor, te, yte = pipeline
    return AnytimeRuntime(
        ForestProgram(fa, y_order=yor, path_probs=pp, X_order=te[:8]))


def _solo(runtime, x_row, order, steps):
    """The jnp-ref oracle: a solo session advanced ``steps`` steps."""
    sess = runtime.session(
        np.asarray(x_row)[None, :], order=order, backend="jnp-ref")
    sess.advance(steps)
    return sess


BACKEND_OPTS = {
    "jnp-ref": {},
    "pallas": {"block_b": 16, "block_m": 8},
    "sharded": {},
}


def _assert_parity(runtime, order, x_row, result):
    """One delivered result vs the solo oracle at the same step count."""
    assert result.error is None
    solo = _solo(runtime, x_row, order, result.steps_completed)
    if result.steps_completed == 0:
        return  # prior readout; no oracle state to compare against
    np.testing.assert_array_equal(result.proba, solo.predict_proba()[0])


# ---------------------------------------------------------------------------
# Router unit behavior (stub pools — placement logic only)
# ---------------------------------------------------------------------------


class _StubScheduler:
    def __init__(self, waiting=0, active=0, free=8):
        self.load_hint = (waiting, active, free)


class _StubPool:
    def __init__(self, name, queued=0, waiting=0, active=0, free=8):
        self.name = name
        self.queue = [None] * queued  # the router only reads len()
        self.scheduler = _StubScheduler(waiting, active, free)


def _router(pools):
    return Router(pools, ServeMetrics(), NULL_TRACER)


def test_place_picks_least_backlogged_pool():
    pools = [_StubPool("p0", queued=2, active=1),
             _StubPool("p1"),
             _StubPool("p2", waiting=2)]
    assert _backlog_score(pools[0]) == 3
    assert _backlog_score(pools[1]) == 0
    assert _backlog_score(pools[2]) == 2
    assert _router(pools).place(Request(x=None, deadline_ms=1.0)) == 1


def test_place_rotates_round_robin_among_ties():
    pools = [_StubPool(f"p{i}") for i in range(3)]
    router = _router(pools)
    req = Request(x=None, deadline_ms=1.0)
    assert [router.place(req) for _ in range(4)] == [0, 1, 2, 0]


def test_place_single_pool_shortcut():
    router = _router([_StubPool("p0", queued=5)])
    assert router.place(Request(x=None, deadline_ms=1.0)) == 0


def test_steal_into_refuses_busy_thief():
    thief = _StubPool("thief", queued=1)
    victim = _StubPool("victim", queued=5)
    assert not _router([thief, victim]).steal_into(thief)


def test_steal_into_requires_a_worthwhile_victim():
    thief = _StubPool("thief")
    # a sibling running its ONLY request is not worth stealing from —
    # migrating it moves latency without adding parallelism
    solo_runner = _StubPool("busy", active=1)
    assert not _router([thief, solo_runner]).steal_into(thief)
    # two in-flight requests make it a victim
    loaded = _StubPool("loaded", active=2)
    router = _router([thief, loaded])
    assert router._pick_victim(thief) is loaded


def test_pick_victim_prefers_most_loaded_sibling():
    thief = _StubPool("thief")
    light = _StubPool("light", queued=1)
    heavy = _StubPool("heavy", queued=3, waiting=2, active=1)
    router = _router([light, thief, heavy])
    assert router._pick_victim(thief) is heavy


# ---------------------------------------------------------------------------
# Sharded admission queue: per-shard EDF invariants (property-based)
# ---------------------------------------------------------------------------


@settings(max_examples=25)
@given(n=st.integers(1, 40), shards=st.integers(1, 4),
       seed=st.integers(0, 10_000))
def test_sharded_queue_pops_globally_edf(n, shards, seed):
    rng = np.random.default_rng(seed)
    q = AdmissionQueue(shards=shards)
    for _ in range(n):
        q.submit(Request(x=None, deadline_ms=float(rng.integers(0, 60))),
                 now=float(rng.integers(0, 5)))
    assert q.submitted == n and len(q) == n
    popped = [q.pop() for _ in range(n)]
    assert q.pop() is None
    keys = [(r.t_deadline, r.request_id) for r in popped]
    assert keys == sorted(keys)  # earliest deadline first, id tiebreak


@settings(max_examples=25)
@given(n=st.integers(1, 40), shards=st.integers(2, 5),
       seed=st.integers(0, 10_000))
def test_sharded_queue_take_all_merges_edf_and_respects_shard_hash(
        n, shards, seed):
    rng = np.random.default_rng(seed)
    q = AdmissionQueue(shards=shards)
    reqs = [q.submit(Request(x=None, deadline_ms=float(rng.integers(0, 60))),
                     now=float(rng.integers(0, 5))) for _ in range(n)]
    # each request hashes onto exactly the shard its id selects
    for req in reqs:
        shard = q._shards[req.request_id % q.n_shards]
        assert any(e[1] == req.request_id for e in shard.heap)
    drained = q.take_all()
    assert len(drained) == n and not q
    keys = [(r.t_deadline, r.request_id) for r in drained]
    assert keys == sorted(keys)
    assert q.take_all() == []


def test_closed_queue_rejects_submits_on_every_shard():
    q = AdmissionQueue(shards=3)
    q.close()
    for i in range(3):  # ids 0..2 cover every shard
        with pytest.raises(RuntimeError, match="closed"):
            q.submit(Request(x=None, deadline_ms=1.0), now=0.0)


# ---------------------------------------------------------------------------
# Steal parity: stolen requests stay bit-identical to the solo oracle
# on all three backends (the tier's acceptance criterion)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["jnp-ref", "pallas", "sharded"])
def test_stolen_requests_match_solo_oracle(backend, runtime, pipeline):
    """Force imbalance (every submit lands on pool 0), then drain: the
    idle pool must steal, and every delivered readout — migrated or not
    — must equal a solo jnp-ref session at the same step count."""
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    srv = PooledAnytimeServer(runtime, pools=2, capacity=2,
                              backend_opts=BACKEND_OPTS[backend])
    tickets = [srv.pools[0].submit_request(
        Request(x=te[i], deadline_ms=60_000.0, backend=backend))
        for i in range(10)]
    srv.drain()
    snap = srv.metrics.snapshot()
    assert snap["steals"] > 0
    assert snap["delivered"] == len(tickets)
    for i, t in enumerate(tickets):
        r = t.result()
        assert r.completed and r.error is None
        assert r.steps_completed == r.total_steps == len(order)
        solo = _solo(runtime, te[i], order, r.steps_completed)
        if backend == "pallas":
            np.testing.assert_allclose(
                r.proba, solo.predict_proba()[0], rtol=1e-5, atol=1e-5)
        else:
            np.testing.assert_array_equal(r.proba, solo.predict_proba()[0])


class _SpyRouter(Router):
    """Router that records every exported StealRecord before injecting."""

    def __init__(self, *args):
        super().__init__(*args)
        self.records = []

    def _migrate(self, victim, thief):
        with victim._cond:
            rec = victim.scheduler.export_request(victim.clock())
        if rec is None:
            return False
        self.records.append(rec)
        with thief._cond:
            thief.scheduler.inject(rec)
        self.metrics.record_steal()
        return True


def test_steals_export_only_segment_boundary_state(runtime, pipeline):
    """Every exported record is a clean segment-boundary prefix: a
    waiting record never stepped (no device state), and an in-flight
    record's carried index row reads out bit-identically to a solo
    jnp-ref session advanced exactly ``pos`` steps — a torn mid-segment
    export could not satisfy that equality."""
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    total = len(order)
    srv = PooledAnytimeServer(runtime, pools=2, capacity=2)
    spy = _SpyRouter(srv.pools, srv.metrics, srv.tracer)
    srv.router = spy  # the cooperative step() reads this attribute
    tickets = [srv.pools[0].submit_request(
        Request(x=te[i], deadline_ms=60_000.0)) for i in range(12)]
    srv.drain()
    assert spy.records, "forced imbalance produced no steals"
    by_id = {t.request_id: i for i, t in enumerate(tickets)}
    for rec in spy.records:
        if rec.kind == "waiting":
            assert rec.pos == 0 and rec.idx_row is None
            continue
        assert rec.kind == "inflight"
        assert 0 < rec.pos <= total
        i = by_id[rec.request.request_id]
        solo = _solo(runtime, te[i], order, rec.pos)
        stolen_readout = np.asarray(engine.predict_from_state(
            runtime.program.device, jnp.asarray(rec.idx_row)[None]))[0]
        np.testing.assert_array_equal(
            stolen_readout, solo.predict_proba()[0])
    # delivered results resumed past their export point and stayed exact
    for i, t in enumerate(tickets):
        _assert_parity(runtime, order, te[i], t.result())


def test_steal_disabled_still_serves_everything(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    srv = PooledAnytimeServer(runtime, pools=2, capacity=2, steal=False)
    tickets = [srv.pools[0].submit_request(
        Request(x=te[i], deadline_ms=60_000.0)) for i in range(8)]
    srv.drain()
    assert srv.metrics.snapshot()["steals"] == 0
    for i, t in enumerate(tickets):
        r = t.result()
        assert r.completed
        _assert_parity(runtime, order, te[i], r)


# ---------------------------------------------------------------------------
# PooledAnytimeServer facade: routing, drive modes, lifecycle
# ---------------------------------------------------------------------------


def test_pooled_routes_and_serves_cooperatively(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    srv = PooledAnytimeServer(runtime, pools=2, capacity=2)
    results = srv.serve(list(te[:9]), deadline_ms=60_000.0)
    snap = srv.metrics.snapshot()
    assert snap["routed"] == 9 and snap["delivered"] == 9
    assert len(results) == 9
    for i, r in enumerate(results):
        assert r.completed
        _assert_parity(runtime, order, te[i], r)


def test_pooled_threaded_drivers_deliver_across_pools(runtime, pipeline):
    """One driver per pool; tickets resolve on the facade even when a
    request is stolen and delivered by a different pool's driver."""
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    with PooledAnytimeServer(runtime, pools=2, capacity=2,
                             queue_shards=2) as srv:
        assert srv.driver_running
        tickets = [srv.submit(te[i], 60_000.0) for i in range(8)]
        results = [t.result(timeout=WAIT_S) for t in tickets]
    assert not srv.driver_running
    assert len({r.request_id for r in results}) == len(results)
    for i, r in enumerate(results):
        assert r.completed and r.error is None
        _assert_parity(runtime, order, te[i], r)


def test_pooled_submit_after_close_raises(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    srv = PooledAnytimeServer(runtime, pools=2, capacity=2)
    with srv:
        pass
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit(te[0], 60_000.0)


def test_pooled_stop_answers_every_admitted_request(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    srv = PooledAnytimeServer(runtime, pools=2, capacity=2)
    tickets = [srv.submit(te[i], 60_000.0) for i in range(6)]
    for _ in range(2):  # partial progress, then shutdown mid-flight
        srv.step()
    srv.stop()
    for i, t in enumerate(tickets):
        r = t.result()
        assert 0 <= r.steps_completed <= r.total_steps
        _assert_parity(runtime, order, te[i], r)


def test_pooled_result_lookup_uses_shared_pending_registry(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    srv = PooledAnytimeServer(runtime, pools=2, capacity=2)
    ticket = srv.submit(te[0], 60_000.0)
    assert srv.result(ticket.request_id) is None  # still pending
    srv.drain()
    assert ticket.result().completed
    assert srv.result(ticket.request_id) is None  # delivered ⇒ untracked
    assert srv.result(10**9) is None              # unknown id


def test_pooled_shares_one_id_stream_and_metrics(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    srv = PooledAnytimeServer(runtime, pools=3, capacity=2)
    tickets = [srv.submit(te[i % te.shape[0]], 60_000.0) for i in range(9)]
    ids = [t.request_id for t in tickets]
    assert len(set(ids)) == len(ids)  # globally unique across pools
    srv.drain()
    snap = srv.metrics.snapshot()
    assert snap["submitted"] == snap["delivered"] == 9


def test_pooled_rejects_zero_pools(runtime):
    with pytest.raises(ValueError, match="pools"):
        PooledAnytimeServer(runtime, pools=0)


def test_pooled_traced_run_emits_route_and_steal_events(runtime, pipeline):
    """serve.route fires for every placement; forcing imbalance under a
    strict tracer validates serve.steal against the span registry."""
    fa, pp, yor, te, yte = pipeline
    tracer = Tracer()
    srv = PooledAnytimeServer(runtime, pools=2, capacity=2, tracer=tracer)
    for i in range(8):
        srv.pools[0].submit_request(
            Request(x=te[i], deadline_ms=60_000.0))
    for i in range(4):
        srv.submit(te[i], 60_000.0)
    srv.drain()
    assert srv.metrics.snapshot()["steals"] > 0
    names = {ev.name for ev in tracer.events()}
    assert "serve.route" in names and "serve.steal" in names


# ---------------------------------------------------------------------------
# Concurrent submitters against the pooled tier (thread-stress target)
# ---------------------------------------------------------------------------


def test_pooled_concurrent_submitters_all_served_exactly_once(
        runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    n_threads, per_thread = 4, 4
    results: dict[int, list] = {}
    errors: list[BaseException] = []

    def submitter(tid: int) -> None:
        try:
            tickets = [srv.submit(
                te[(tid * per_thread + j) % te.shape[0]], 60_000.0)
                for j in range(per_thread)]
            results[tid] = [t.result(timeout=WAIT_S) for t in tickets]
        except BaseException as e:  # noqa: BLE001 - surfaced below
            errors.append(e)

    with PooledAnytimeServer(runtime, pools=2, capacity=3,
                             queue_shards=2) as srv:
        threads = [threading.Thread(target=submitter, args=(tid,))
                   for tid in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(WAIT_S)
        snap = srv.metrics.snapshot()
    assert not errors
    delivered = [r for rs in results.values() for r in rs]
    assert len(delivered) == n_threads * per_thread
    assert all(r.completed and r.error is None for r in delivered)
    assert len({r.request_id for r in delivered}) == len(delivered)
    assert snap["delivered"] == len(delivered)
    for tid, rs in results.items():
        for j, r in enumerate(rs):
            _assert_parity(
                runtime, order,
                te[(tid * per_thread + j) % te.shape[0]], r)
