"""Step-order generators: validity, optimality, and paper-claimed ordering."""
import itertools

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import engine, orders, pruning, qwyc
from repro.forest import make_dataset, split_dataset, train_forest
from repro.schedule import get_order_policy, list_orders


def _order(name, pp, y, seed=0):
    return get_order_policy(name, seed=seed).generate(pp, y)


def _setup(trees=3, depth=3, dataset="magic", seed=0):
    X, y = make_dataset(dataset, seed=seed)
    (tr, ytr), (orx, yor), (te, yte) = split_dataset(X, y, seed=seed)
    rf = train_forest(tr[:800], ytr[:800], int(y.max()) + 1,
                      n_trees=trees, max_depth=depth, seed=seed)
    fa = rf.as_arrays()
    pp = engine.path_probs_np(fa, orx[:400])
    return fa, pp, yor[:400]


def _mean_acc(ev: orders.StateEvaluator, order: np.ndarray) -> float:
    state = np.zeros(ev.T, dtype=np.int64)
    accs = [ev.accuracy(state)]
    for t in order:
        state[t] += 1
        accs.append(ev.accuracy(state))
    return float(np.mean(accs))


@pytest.mark.parametrize("name", list_orders())
def test_every_generator_produces_valid_order(name):
    fa, pp, y = _setup()
    order = _order(name, pp, y)
    assert orders.validate_order(order, fa.n_trees, fa.max_depth)


def test_optimal_matches_bruteforce_on_tiny_forest():
    """Exhaustive check: Dijkstra's optimum == best of ALL distinct orders."""
    fa, pp, y = _setup(trees=2, depth=2)
    ev = orders.StateEvaluator(pp, y)
    opt = orders.optimal_order(ev)
    best = max(
        _mean_acc(ev, np.asarray(o, dtype=np.int32))
        for o in set(itertools.permutations([0, 0, 1, 1]))
    )
    assert _mean_acc(ev, opt) == pytest.approx(best, abs=1e-9)


def test_unoptimal_matches_bruteforce_minimum():
    fa, pp, y = _setup(trees=2, depth=2)
    ev = orders.StateEvaluator(pp, y)
    unopt = orders.unoptimal_order(ev)
    worst = min(
        _mean_acc(ev, np.asarray(o, dtype=np.int32))
        for o in set(itertools.permutations([0, 0, 1, 1]))
    )
    assert _mean_acc(ev, unopt) == pytest.approx(worst, abs=1e-9)


def test_paper_ordering_on_ordering_set():
    """Sec. VI: on S_o, optimal >= squirrels >= unoptimal (by construction)."""
    fa, pp, y = _setup(trees=4, depth=4)
    ev = orders.StateEvaluator(pp, y)
    m = {n: _mean_acc(ev, _order(n, pp, y))
         for n in ("optimal", "backward_squirrel", "forward_squirrel",
                   "random", "unoptimal")}
    assert m["optimal"] >= m["backward_squirrel"] - 1e-9
    assert m["optimal"] >= m["forward_squirrel"] - 1e-9
    assert m["optimal"] >= m["random"] - 1e-9
    assert m["unoptimal"] <= m["random"] + 1e-9
    assert m["backward_squirrel"] >= m["unoptimal"]


def test_optimal_refuses_infeasible_sizes():
    fa, pp, y = _setup(trees=3, depth=3)
    ev = orders.StateEvaluator(pp, y)
    with pytest.raises(ValueError, match="infeasible"):
        orders.optimal_order(ev, state_limit=10)


def test_squirrel_incremental_matches_full_recompute():
    """candidate_accuracies' incremental score updates must equal direct
    state evaluation (the O(d t^2) trick is exact, not approximate)."""
    fa, pp, y = _setup(trees=3, depth=3)
    ev = orders.StateEvaluator(pp, y)
    state = np.array([1, 0, 2], dtype=np.int64)
    S = ev.score_matrix(state)
    accs = ev.candidate_accuracies(S, state, forward=True)
    for t in range(3):
        nxt = state.copy()
        nxt[t] += 1
        if nxt[t] <= ev.depth:
            assert accs[t] == pytest.approx(ev.accuracy(nxt), abs=1e-6)
        else:
            assert accs[t] == -np.inf


def test_prune_sequences_are_permutations():
    fa, pp, y = _setup(trees=5, depth=3)
    for name, fn in pruning.PRUNE_SEQUENCES.items():
        seq = fn(pp, y)
        assert sorted(seq.tolist()) == list(range(5)), name


def test_qwyc_binary_only():
    fa, pp, y = _setup(trees=3, depth=3, dataset="letter")
    with pytest.raises(ValueError, match="binary"):
        qwyc.qwyc_seq(pp, y)


def test_qwyc_sequence_and_thresholds():
    fa, pp, y = _setup(trees=5, depth=3, dataset="magic")
    seq, taus = qwyc.qwyc_seq(pp, y)
    assert sorted(seq.tolist()) == list(range(5))
    assert (np.diff(taus) <= 1e-6).all()  # remaining swing shrinks
    assert taus[-1] == 0.0


@settings(max_examples=8, deadline=None)
@given(trees=st.integers(2, 4), depth=st.integers(1, 3), seed=st.integers(0, 50))
def test_squirrel_validity_under_hypothesis(trees, depth, seed):
    rng = np.random.default_rng(seed)
    B, C = 60, 3
    pp = rng.random((B, trees, depth + 1, C)).astype(np.float32)
    y = rng.integers(0, C, size=B)
    ev = orders.StateEvaluator(pp, y)
    fwd = orders.forward_squirrel(ev)
    bwd = orders.backward_squirrel(ev)
    assert orders.validate_order(fwd, trees, depth)
    assert orders.validate_order(bwd, trees, depth)
    if (depth + 1) ** trees <= 2000:
        opt = orders.optimal_order(ev)
        assert _mean_acc(ev, opt) >= _mean_acc(ev, fwd) - 1e-9
        assert _mean_acc(ev, opt) >= _mean_acc(ev, bwd) - 1e-9
