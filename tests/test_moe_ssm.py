"""MoE dispatch and Mamba2 SSD vs brute-force references."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.params import init_params

KEY = jax.random.PRNGKey(0)


def _moe_cfg(**kw):
    base = get_config("granite_moe_3b_a800m", reduced=True)
    return dataclasses.replace(base, **kw)


def test_moe_matches_dense_reference():
    """With capacity high enough to be dropless, sort-based dispatch must
    equal the brute-force 'run every expert on every token' reference."""
    cfg = _moe_cfg(capacity_factor=16.0)
    p = init_params(M.moe_param_specs(cfg, layer_axis=False), KEY)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    out, aux = M.moe_mlp(cfg, p, x)

    # reference: explicit top-k routing, dense expert compute
    xf = x.reshape(-1, cfg.d_model)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits.astype(jnp.float32), -1)
    gv, ei = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    act = jax.nn.silu
    ref = jnp.zeros_like(xf)
    for e in range(cfg.num_experts):
        h = act(xf @ p["w_gate"][e]) * (xf @ p["w_up"][e])
        ye = h @ p["w_down"][e]
        w_e = jnp.sum(jnp.where(ei == e, gv, 0.0), axis=1)
        ref = ref + ye * w_e[:, None]
    np.testing.assert_allclose(np.asarray(out.reshape(-1, cfg.d_model)),
                               np.asarray(ref), rtol=2e-4, atol=2e-4)
    assert float(aux.load_balance_loss) > 0.0


def test_moe_capacity_drops_route_to_residual():
    """With capacity 0-ish, output must be ~zero (all tokens dropped) —
    the residual carries them."""
    cfg = _moe_cfg(capacity_factor=1e-9)
    p = init_params(M.moe_param_specs(cfg, layer_axis=False), KEY)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    out, _ = M.moe_mlp(cfg, p, x)
    # capacity floor is 8 tokens/expert -> most tokens dropped, none NaN
    assert bool(jnp.isfinite(out).all())


def test_moe_load_balance_loss_uniform_is_one():
    """Perfectly uniform routing gives LB loss ~= 1 (Switch normalization)."""
    cfg = _moe_cfg()
    p = init_params(M.moe_param_specs(cfg, layer_axis=False), KEY)
    p = dict(p)
    p["router"] = jnp.zeros_like(p["router"])  # uniform router
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 64, cfg.d_model))
    _, aux = M.moe_mlp(cfg, p, x)
    assert float(aux.load_balance_loss) == pytest.approx(1.0, rel=0.1)


def _ssm_cfg():
    return get_config("mamba2_130m", reduced=True)


def _ssd_naive(cfg, p, x):
    """Token-by-token recurrence — the slow oracle for ssd_train."""
    st = S.ssm_init_state(cfg, x.shape[0])
    ys = []
    for t in range(x.shape[1]):
        y, st = S.ssd_decode(cfg, p, x[:, t:t + 1], st)
        ys.append(y)
    return jnp.concatenate(ys, axis=1)


def test_ssd_chunked_matches_naive_recurrence():
    cfg = _ssm_cfg()
    p = init_params(S.ssm_param_specs(cfg, layer_axis=False), KEY)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model)) * 0.3
    fast = S.ssd_train(cfg, p, x)
    slow = _ssd_naive(cfg, p, x)
    np.testing.assert_allclose(np.asarray(fast), np.asarray(slow),
                               rtol=2e-3, atol=2e-3)


def test_ssd_prefill_state_continues_correctly():
    """ssd_train(return_state) -> ssd_decode must equal the pure
    recurrence run one step further."""
    cfg = _ssm_cfg()
    p = init_params(S.ssm_param_specs(cfg, layer_axis=False), KEY)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 17, cfg.d_model)) * 0.3
    _, st = S.ssd_train(cfg, p, x[:, :16], return_state=True)
    y_next, _ = S.ssd_decode(cfg, p, x[:, 16:17], st)
    slow = _ssd_naive(cfg, p, x)
    np.testing.assert_allclose(np.asarray(y_next), np.asarray(slow[:, 16:17]),
                               rtol=2e-3, atol=2e-3)


def test_ssd_causality():
    """Changing a future token must not affect past outputs."""
    cfg = _ssm_cfg()
    p = init_params(S.ssm_param_specs(cfg, layer_axis=False), KEY)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 16, cfg.d_model))
    y1 = S.ssd_train(cfg, p, x)
    x2 = x.at[:, 12].set(5.0)
    y2 = S.ssd_train(cfg, p, x2)
    np.testing.assert_allclose(np.asarray(y1[:, :12]), np.asarray(y2[:, :12]),
                               rtol=1e-4, atol=1e-4)
    assert not np.allclose(np.asarray(y1[:, 12:]), np.asarray(y2[:, 12:]))
