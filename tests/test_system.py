"""End-to-end behaviour of the paper's system: train forest -> generate
orders -> anytime inference -> the paper's qualitative claims hold."""
import numpy as np
import pytest

from repro.core import AnytimeForest, engine
from repro.core.metrics import normalized_mean_accuracy
from repro.forest import make_dataset, split_dataset, train_forest
from repro.schedule import get_order_policy, list_orders


def generate_order(name, pp, y, seed=0):
    return get_order_policy(name, seed=seed).generate(pp, y)


@pytest.fixture(scope="module")
def pipeline():
    X, y = make_dataset("magic", seed=0)
    (tr, ytr), (orx, yor), (te, yte) = split_dataset(X, y, seed=0)
    rf = train_forest(tr, ytr, 2, n_trees=5, max_depth=5, seed=0)
    fa = rf.as_arrays()
    pp = engine.path_probs_np(fa, orx)
    return fa, pp, yor, te, yte


def _curve(fa, order, te, yte):
    return AnytimeForest(fa, order).accuracy_curve(te, yte)


def test_accuracy_rises_with_steps(pipeline):
    """Paper Sec. VI-C: accuracy increases (on trend) with steps taken."""
    fa, pp, yor, te, yte = pipeline
    curve = _curve(fa, generate_order("backward_squirrel", pp, yor), te, yte)
    assert curve[-1] > curve[0] + 0.05
    # monotone on trend: late third must beat early third
    k = len(curve) // 3
    assert curve[-k:].mean() > curve[:k].mean()


def test_all_orders_same_endpoints(pipeline):
    """Every order starts from the prior and converges to the full-forest
    accuracy (Fig. 5: 'all step orders start from and converge to the
    same accuracy')."""
    fa, pp, yor, te, yte = pipeline
    curves = [_curve(fa, generate_order(n, pp, yor), te, yte)
              for n in ("depth", "breadth", "backward_squirrel", "unoptimal")]
    for c in curves[1:]:
        assert c[0] == pytest.approx(curves[0][0], abs=1e-6)
        assert c[-1] == pytest.approx(curves[0][-1], abs=1e-6)


def test_squirrel_beats_naive_on_test_set(pipeline):
    """The headline claim, on held-out data: Backward Squirrel's NMA is
    close to Optimal's and clearly better than Unoptimal."""
    fa, pp, yor, te, yte = pipeline
    nma = {n: normalized_mean_accuracy(_curve(fa, generate_order(n, pp, yor), te, yte))
           for n in ("optimal", "backward_squirrel", "random", "unoptimal")}
    assert nma["backward_squirrel"] >= 0.90 * nma["optimal"]
    assert nma["backward_squirrel"] > nma["unoptimal"]
    assert nma["optimal"] > nma["unoptimal"]


def test_full_order_suite_runs(pipeline):
    fa, pp, yor, te, yte = pipeline
    for name in list_orders():
        curve = _curve(fa, generate_order(name, pp, yor), te, yte)
        assert len(curve) == fa.total_steps + 1
        assert np.isfinite(curve).all()


def test_anytime_session_abort_anywhere(pipeline):
    """Serving-style: abort after arbitrary step counts, prediction is
    always available and final prediction matches batch run."""
    fa, pp, yor, te, yte = pipeline
    af = AnytimeForest(fa, generate_order("backward_squirrel", pp, yor))
    sess = af.session(te[:100])
    preds = [sess.predict()]
    for k in (1, 3, 7, 100):
        sess.advance(k)
        preds.append(sess.predict())
    assert sess.remaining == max(0, af.order.shape[0] - 111)
    sess.advance(10_000)
    final_curve = af.accuracy_curve(te[:100], yte[:100])
    final_acc = float((sess.predict() == yte[:100]).mean())
    assert final_acc == pytest.approx(float(final_curve[-1]), abs=1e-6)
