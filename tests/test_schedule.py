"""repro.schedule: policy registry parity with the legacy dispatch,
deadline sessions, RLE-fused execution, and batched order evaluation."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, orders, pruning, qwyc
from repro.core.anytime import AnytimeForest
from repro.forest import make_dataset, split_dataset, train_forest
from repro.schedule import (
    AnytimeRuntime,
    ForestProgram,
    OrderPolicy,
    check_order,
    get_order_policy,
    list_orders,
    register_order,
    rle_chunks,
)
from repro.schedule import policies as policies_mod


@pytest.fixture(scope="module")
def pipeline():
    # magic is binary, so every registered order (incl. qwyc) is legal
    X, y = make_dataset("magic", seed=0)
    (tr, ytr), (orx, yor), (te, yte) = split_dataset(X, y, seed=0)
    rf = train_forest(tr[:800], ytr[:800], 2, n_trees=4, max_depth=3, seed=0)
    fa = rf.as_arrays()
    pp = engine.path_probs_np(fa, orx[:300])
    return fa, pp, yor[:300], te[:200], yte[:200]


# The names the deleted repro.core string dispatch knew, in its
# enumeration order — frozen here as the parity reference.  New policies
# (e.g. bandit_squirrel) register AFTER this prefix.
LEGACY_NAMES = (
    "optimal", "unoptimal", "forward_squirrel", "backward_squirrel",
    "random", "depth", "breadth",
    "prune_depth_IE", "prune_breadth_IE", "prune_depth_EA",
    "prune_breadth_EA", "prune_depth_RE", "prune_breadth_RE",
    "prune_depth_D", "prune_breadth_D",
    "qwyc_depth", "qwyc_breadth",
)


def _legacy_generate_order(name, path_probs, y, seed=0, state_limit=2_000_000):
    """Frozen copy of the pre-registry string dispatch — the parity
    reference the registry must reproduce byte-for-byte."""
    B, T, d1, C = path_probs.shape
    d = d1 - 1
    ev = orders.StateEvaluator(path_probs, y)
    if name == "optimal":
        return orders.optimal_order(ev, state_limit=state_limit)
    if name == "unoptimal":
        return orders.unoptimal_order(ev, state_limit=state_limit)
    if name == "forward_squirrel":
        return orders.forward_squirrel(ev)
    if name == "backward_squirrel":
        return orders.backward_squirrel(ev)
    if name == "random":
        return orders.random_order(T, d, seed=seed)
    if name == "depth":
        return orders.depth_order(T, d)
    if name == "breadth":
        return orders.breadth_order(T, d)
    if name.startswith("prune_"):
        _, variant, metric = name.split("_")
        seq = pruning.PRUNE_SEQUENCES[metric](path_probs, y)
        fn = orders.depth_order if variant == "depth" else orders.breadth_order
        return fn(T, d, seq)
    if name.startswith("qwyc_"):
        variant = name.split("_")[1]
        seq, _ = qwyc.qwyc_seq(path_probs, y)
        fn = orders.depth_order if variant == "depth" else orders.breadth_order
        return fn(T, d, seq)
    raise ValueError(f"unknown order: {name!r}")


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_registry_covers_legacy_names_in_order():
    assert tuple(list_orders())[: len(LEGACY_NAMES)] == LEGACY_NAMES
    assert len(set(list_orders())) == len(list_orders())


@pytest.mark.parametrize("name", LEGACY_NAMES)
def test_registry_parity_with_legacy_dispatch(name, pipeline):
    """Every legacy string must yield a BYTE-IDENTICAL order through the
    registry (the PR's central acceptance criterion)."""
    fa, pp, yor, te, yte = pipeline
    legacy = _legacy_generate_order(name, pp, yor, seed=0)
    via_registry = get_order_policy(name, seed=0).generate(pp, yor)
    assert legacy.dtype == via_registry.dtype
    assert legacy.tobytes() == via_registry.tobytes()


def test_string_shims_are_gone():
    """generate_order/ORDER_NAMES left repro.core after their grace
    period — only the registry surface remains."""
    import repro.core
    import repro.core.anytime as anytime_mod

    for mod in (repro.core, anytime_mod):
        with pytest.raises(AttributeError):
            mod.generate_order
        with pytest.raises(AttributeError):
            mod.ORDER_NAMES


# ---------------------------------------------------------------------------
# bandit_squirrel: the learned (epsilon-greedy) reordering policy
# ---------------------------------------------------------------------------


def test_bandit_squirrel_registered_after_legacy_prefix():
    assert "bandit_squirrel" in list_orders()
    assert list_orders().index("bandit_squirrel") >= len(LEGACY_NAMES)


def test_bandit_squirrel_valid_and_deterministic(pipeline):
    fa, pp, yor, te, yte = pipeline
    a = get_order_policy("bandit_squirrel", seed=3).generate(pp, yor)
    b = get_order_policy("bandit_squirrel", seed=3).generate(pp, yor)
    assert orders.validate_order(a, fa.n_trees, fa.max_depth)
    assert a.tobytes() == b.tobytes()  # seeded => bit-reproducible
    assert a.dtype == np.int32


def test_bandit_squirrel_preserves_per_tree_segment_order(pipeline):
    """Reordering moves whole squirrel segments between trees but never
    reorders one tree's own steps — counts stay exact per tree."""
    fa, pp, yor, te, yte = pipeline
    out = get_order_policy("bandit_squirrel", seed=0, epsilon=0.5).generate(pp, yor)
    counts = np.bincount(out, minlength=fa.n_trees)
    assert (counts == fa.max_depth).all()


def test_bandit_squirrel_epsilon_zero_is_pure_greedy(pipeline):
    fa, pp, yor, te, yte = pipeline
    a = get_order_policy("bandit_squirrel", epsilon=0.0, seed=0).generate(pp, yor)
    b = get_order_policy("bandit_squirrel", epsilon=0.0, seed=99).generate(pp, yor)
    assert a.tobytes() == b.tobytes()  # no exploration => seed-independent


def test_bandit_squirrel_cache_key_carries_config():
    a = get_order_policy("bandit_squirrel", seed=1).cache_key()
    b = get_order_policy("bandit_squirrel", seed=2).cache_key()
    c = get_order_policy("bandit_squirrel", seed=1, epsilon=0.9).cache_key()
    assert len({a, b, c}) == 3


def test_unknown_order_name_raises():
    with pytest.raises(ValueError, match="unknown order"):
        get_order_policy("no_such_order")


def test_duplicate_registration_rejected():
    with pytest.raises(ValueError, match="already registered"):

        @register_order("depth")
        @dataclasses.dataclass
        class Dup(OrderPolicy):
            pass


def test_policy_config_fields_and_override_filtering():
    p = get_order_policy("random", seed=7, state_limit=123)  # state_limit dropped
    assert p.seed == 7 and p.name == "random"
    q = get_order_policy("optimal", state_limit=99)
    assert q.state_limit == 99
    assert p.cache_key() != get_order_policy("random", seed=8).cache_key()


def test_prune_metrics_in_sync_with_pruning_module():
    # policies.py hardcodes the metric keys to stay import-acyclic
    assert tuple(pruning.PRUNE_SEQUENCES) == policies_mod.PRUNE_METRICS


# ---------------------------------------------------------------------------
# check_order / AnytimeForest validation
# ---------------------------------------------------------------------------


def test_check_order_names_offending_unit():
    with pytest.raises(ValueError, match="unit 1 takes 3 steps"):
        check_order(np.array([0, 0, 1, 1, 1, 2], dtype=np.int32), 3, 2)
    with pytest.raises(ValueError, match="length 5"):
        check_order(np.zeros(5, dtype=np.int32), 3, 2)


def test_anytime_forest_rejects_bad_order(pipeline):
    fa, pp, yor, te, yte = pipeline
    bad = np.zeros(fa.n_trees * fa.max_depth, dtype=np.int32)  # all tree 0
    with pytest.raises(ValueError, match="unit 0"):
        AnytimeForest(fa, bad)


# ---------------------------------------------------------------------------
# Runtime: cache, sessions, RLE fusion, deadline loop
# ---------------------------------------------------------------------------


def test_runtime_order_cache_hits(pipeline):
    fa, pp, yor, te, yte = pipeline
    rt = AnytimeRuntime(ForestProgram(fa, y_order=yor, path_probs=pp))
    a = rt.order("backward_squirrel")
    b = rt.order("backward_squirrel")
    assert a is b  # second call served from the content-hash cache
    assert rt.order("random", seed=1) is not rt.order("random", seed=2)


def test_rle_chunks_roundtrip():
    order = np.array([3, 3, 3, 1, 2, 2, 3], dtype=np.int32)
    chunks = rle_chunks(order)
    assert chunks == [(3, 3), (1, 1), (2, 2), (3, 1)]
    rebuilt = np.concatenate([[u] * n for u, n in chunks])
    np.testing.assert_array_equal(rebuilt, order)
    assert rle_chunks(np.array([], dtype=np.int32)) == []


@pytest.mark.parametrize("name", ["depth", "breadth", "backward_squirrel"])
def test_rle_fused_session_matches_unfused_run_order(name, pipeline):
    """Chunk-fused execution must be step-for-step equivalent to the
    unfused reference scan, at every prefix — not just at the end."""
    fa, pp, yor, te, yte = pipeline
    rt = AnytimeRuntime(ForestProgram(fa, y_order=yor, path_probs=pp))
    order = rt.order(name)
    sess = rt.session(te, order=order)
    dev = engine.to_device(fa)
    pos = 0
    for k in (1, 2, 5, 1, 3, 10_000):  # odd chunks straddle RLE runs
        sess.advance(k)
        pos = min(pos + k, len(order))
        if pos == 0:
            continue
        idx_ref, _ = engine.run_order(dev, jnp.asarray(te), jnp.asarray(order[:pos]))
        ref = np.asarray(engine.predict_from_state(dev, idx_ref))
        np.testing.assert_allclose(sess.predict_proba(), ref, rtol=1e-6, atol=1e-6)
    assert sess.remaining == 0


def test_session_advance_until_deadline(pipeline):
    fa, pp, yor, te, yte = pipeline
    rt = AnytimeRuntime(ForestProgram(fa, y_order=yor, path_probs=pp))

    class FakeClock:
        """Each call advances 1 'ms' — deadline math becomes exact."""

        def __init__(self):
            self.t = 0.0

        def __call__(self):
            self.t += 1e-3
            return self.t

    # t0 costs one read; each loop check costs one more, so elapsed time
    # at check k is k fake-ms: checks at 1..4 ms pass, the 5 ms check
    # fails -> exactly 4 chunks of 2 steps execute
    sess = rt.session(te, "backward_squirrel", chunk=2, clock=FakeClock())
    taken = sess.advance_until(deadline_ms=5.0)
    assert taken == 8 and sess.pos == 8

    # an expired deadline takes no steps at all
    sess2 = rt.session(te, "backward_squirrel", chunk=2, clock=FakeClock())
    assert sess2.advance_until(deadline_ms=0.0) == 0
    assert sess2.pos == 0

    # a generous deadline runs to completion and predictions match the
    # one-shot batch execution
    sess3 = rt.session(te, "backward_squirrel", chunk=3, clock=FakeClock())
    taken3 = sess3.advance_until(deadline_ms=1e9)
    assert taken3 == sess3.total_steps and sess3.remaining == 0
    curve = AnytimeForest(fa, rt.order("backward_squirrel")).accuracy_curve(te, yte)
    acc = float((sess3.predict() == yte).mean())
    assert acc == pytest.approx(float(curve[-1]), abs=1e-6)


def test_evaluate_orders_vmapped_matches_serial(pipeline):
    fa, pp, yor, te, yte = pipeline
    rt = AnytimeRuntime(ForestProgram(fa, y_order=yor, path_probs=pp))
    names = ["depth", "breadth", "backward_squirrel"]
    batched = rt.evaluate_orders(te, yte, names)
    assert set(batched) == set(names)
    for n in names:
        serial = AnytimeForest(fa, rt.order(n)).accuracy_curve(te, yte)
        np.testing.assert_allclose(batched[n], serial, rtol=1e-6, atol=1e-6)
        assert len(batched[n]) == fa.n_trees * fa.max_depth + 1


def test_forest_program_requires_ordering_inputs(pipeline):
    fa, pp, yor, te, yte = pipeline
    with pytest.raises(ValueError, match="X_order or path_probs"):
        ForestProgram(fa, y_order=yor)
