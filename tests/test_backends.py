"""Execution-backend subsystem: step-plan compilation, registry, and the
pallas/sharded parity suite against the jnp-ref oracle on odd shapes."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import engine
from repro.forest import make_dataset, split_dataset, train_forest
from repro.schedule import (
    AnytimeRuntime,
    ExecutorCore,
    ForestExecutor,
    ForestProgram,
    ForestStepBackend,
    Session,
    StepPlan,
    default_backend,
    get_backend,
    list_backends,
    pow2_decompose,
    pow2_floor,
)


@pytest.fixture(scope="module")
def pipeline():
    X, y = make_dataset("magic", seed=3)
    (tr, ytr), (orx, yor), (te, yte) = split_dataset(X, y, seed=3)
    # depth 6 -> up to 127 nodes per tree: many M-tiles at block_m=8
    rf = train_forest(tr[:800], ytr[:800], 2, n_trees=4, max_depth=6, seed=3)
    fa = rf.as_arrays()
    pp = engine.path_probs_np(fa, orx[:200])
    return fa, pp, yor[:200], te, yte


def _runtime(pipeline):
    fa, pp, yor, te, yte = pipeline
    return AnytimeRuntime(ForestProgram(fa, y_order=yor, path_probs=pp))


# ---------------------------------------------------------------------------
# StepPlan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,cap,expect", [
    (0, 64, []),
    (1, 64, [1]),
    (13, 64, [8, 4, 1]),
    (64, 64, [64]),
    (100, 64, [64, 32, 4]),
    (100, 16, [16, 16, 16, 16, 16, 16, 4]),
])
def test_pow2_decompose(n, cap, expect):
    assert pow2_decompose(n, cap=cap) == expect
    assert sum(expect) == n
    assert all(p & (p - 1) == 0 and p <= cap for p in expect)


def test_pow2_decompose_rejects_negative():
    with pytest.raises(ValueError, match="negative"):
        pow2_decompose(-1)


@settings(max_examples=50, deadline=None)
@given(n=st.integers(1, 10_000), log_cap=st.integers(0, 10))
def test_pow2_floor_properties(n, log_cap):
    """The SHARED bucketing primitive (StepPlan splitter + SessionBatch
    slot dispatch): a power of two, <= n, <= cap, and maximal — so
    every dispatched length on either path is in {1, 2, ..., cap}."""
    cap = 1 << log_cap
    p = pow2_floor(n, cap)
    assert p & (p - 1) == 0
    assert 1 <= p <= min(n, cap)
    assert p == cap or 2 * p > n  # maximal under the cap


@settings(max_examples=50, deadline=None)
@given(n=st.integers(0, 10_000), log_cap=st.integers(0, 10))
def test_pow2_decompose_consistent_with_floor(n, log_cap):
    cap = 1 << log_cap
    parts = pow2_decompose(n, cap=cap)
    assert sum(parts) == n
    assert parts == sorted(parts, reverse=True)
    assert all(p == pow2_floor(p, cap) for p in parts)
    if parts:
        assert parts[0] == pow2_floor(n, cap)  # greedy head


@pytest.mark.parametrize("n", [0, -3])
def test_pow2_floor_rejects_non_positive(n):
    with pytest.raises(ValueError, match=">= 1"):
        pow2_floor(n)


def test_pow2_floor_rejects_bad_cap():
    with pytest.raises(ValueError, match="power of two"):
        pow2_floor(5, cap=6)


@pytest.mark.parametrize("cap", [0, -4, 6])
def test_pow2_decompose_rejects_bad_cap(cap):
    with pytest.raises(ValueError, match="power of two"):
        pow2_decompose(5, cap=cap)


def test_step_plan_roundtrip_and_bucketing():
    order = np.array([0] * 13 + [1] * 3 + [0] + [2] * 8, dtype=np.int32)
    plan = StepPlan.compile(order)
    # segments reconstruct the order exactly
    rebuilt = np.concatenate(
        [[u] * n for u, n in zip(plan.seg_units, plan.seg_lens)])
    np.testing.assert_array_equal(rebuilt, order)
    # every segment length is a power of two <= cap
    assert all(int(n) & (int(n) - 1) == 0 for n in plan.seg_lens)
    assert plan.trace_lengths == (1, 2, 4, 8)
    assert plan.total_steps == len(order)
    assert plan.seg_starts[-1] == len(order)
    # segment_at maps positions to containing segments
    for pos in range(len(order)):
        s = plan.segment_at(pos)
        assert plan.seg_starts[s] <= pos < plan.seg_starts[s + 1]


def test_step_plan_validates_order_when_shape_given():
    with pytest.raises(ValueError, match="unit 0"):
        StepPlan.compile(np.zeros(6, dtype=np.int32), n_units=3, unit_steps=2)


def test_step_plan_trace_bound_is_logarithmic(pipeline):
    """Distinct plan segment lengths <= log2(max_segment)+1 = 7 <= 8 —
    the acceptance criterion's compile-count bound for ANY order."""
    rt = _runtime(pipeline)
    for name in ("backward_squirrel", "depth", "breadth", "random"):
        plan = StepPlan.compile(rt.order(name))
        assert len(plan.trace_lengths) <= 8, name


# ---------------------------------------------------------------------------
# Registry / selection surface
# ---------------------------------------------------------------------------


def test_backend_registry():
    assert set(list_backends()) >= {"jnp-ref", "pallas", "sharded"}
    assert get_backend("pallas").name == "pallas"
    with pytest.raises(ValueError, match="unknown backend.*jnp-ref"):
        get_backend("mosaic")


def test_default_backend_matches_platform():
    import jax

    expect = "pallas" if jax.default_backend() == "tpu" else "jnp-ref"
    assert default_backend() == expect


def test_runtime_rejects_unknown_backend_eagerly(pipeline):
    fa, pp, yor, te, yte = pipeline
    with pytest.raises(ValueError, match="unknown backend"):
        AnytimeRuntime(
            ForestProgram(fa, y_order=yor, path_probs=pp), backend="nope")


def test_runtime_backend_default_flows_to_sessions(pipeline):
    fa, pp, yor, te, yte = pipeline
    rt = AnytimeRuntime(
        ForestProgram(fa, y_order=yor, path_probs=pp), backend="pallas")
    sess = rt.session(te[:9], "depth")
    assert sess.backend.backend_name == "pallas"
    # per-session override wins
    sess2 = rt.session(te[:9], "depth", backend="jnp-ref")
    assert sess2.backend.backend_name == "jnp-ref"


def test_step_plans_shared_across_sessions(pipeline):
    fa, pp, yor, te, yte = pipeline
    rt = _runtime(pipeline)
    order = rt.order("backward_squirrel")
    a = rt.session(te[:5], order=order)
    b = rt.session(te[:7], order=order)
    assert a.backend.plan is b.backend.plan  # compile-once, content-addressed


# ---------------------------------------------------------------------------
# Parity suite: pallas (interpret) and sharded vs the jnp-ref oracle.
# Odd shapes: batch not a multiple of the tile, trees larger than one
# M-tile, single-sample batch, mid-chunk advance splits.
# ---------------------------------------------------------------------------

PARITY_OPTS = {
    # tiny tiles force batch padding + multi-M-tile streaming on a
    # depth-6 (<=127 node) forest
    "pallas": {"block_b": 16, "block_m": 8},
    "sharded": {},
}


@pytest.mark.parametrize("backend", ["pallas", "sharded"])
@pytest.mark.parametrize("batch", [1, 33])
@pytest.mark.parametrize("name", ["backward_squirrel", "depth"])
def test_backend_parity_with_oracle(backend, batch, name, pipeline):
    """Index-array state must match the jnp-ref oracle BIT-FOR-BIT at
    every mid-chunk split point; read-outs to float tolerance."""
    fa, pp, yor, te, yte = pipeline
    rt = _runtime(pipeline)
    order = rt.order(name)
    X = te[:batch]
    ref = rt.session(X, order=order, backend="jnp-ref")
    sess = rt.session(X, order=order, backend=backend, **PARITY_OPTS[backend])
    for k in (1, 2, 5, 1, 3, 10_000):  # odd chunks straddle plan segments
        ref.advance(k)
        sess.advance(k)
        assert sess.pos == ref.pos
        np.testing.assert_array_equal(
            np.asarray(sess.idx)[:batch], np.asarray(ref.idx))
        np.testing.assert_allclose(
            sess.predict_proba(), ref.predict_proba(), rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(sess.predict(), ref.predict())
    assert sess.remaining == 0


def test_pallas_backend_dispatches_kernel(monkeypatch, pipeline):
    """backend="pallas" must route the hot path through
    repro.kernels.ops.forest_run / prob_accum (the acceptance criterion),
    not the jnp engine scan."""
    from repro.kernels import ops
    from repro.schedule import backends as B

    calls = {"run": 0, "accum": 0}
    real_run, real_accum = ops.forest_run, ops.prob_accum

    def spy_run(*a, **kw):
        calls["run"] += 1
        return real_run(*a, **kw)

    def spy_accum(*a, **kw):
        calls["accum"] += 1
        return real_accum(*a, **kw)

    monkeypatch.setattr(B.kops, "forest_run", spy_run)
    monkeypatch.setattr(B.kops, "prob_accum", spy_accum)
    rt = _runtime(pipeline)
    fa, pp, yor, te, yte = pipeline
    sess = rt.session(te[:9], "depth", backend="pallas",
                      block_b=16, block_m=8)
    sess.advance(3)
    sess.predict()
    assert calls["run"] >= 1 and calls["accum"] >= 1


def test_pallas_fused_run_single_launch_per_segment(monkeypatch, pipeline):
    """The pallas solo path must dispatch the FUSED multi-step kernel
    (one pallas launch per plan segment), never fall back to scanning
    the single-step kernel for an in-budget forest."""
    from repro.kernels import forest_run as FR
    from repro.kernels import ops

    calls = {"fused": 0, "scanned": 0}
    real_fused = FR.forest_run
    monkeypatch.setattr(
        FR, "forest_run",
        lambda *a, **k: (calls.__setitem__("fused", calls["fused"] + 1),
                         real_fused(*a, **k))[1])
    monkeypatch.setattr(
        ops, "forest_run_scanned",
        lambda *a, **k: (_ for _ in ()).throw(
            AssertionError("in-budget forest must not stream")))
    rt = _runtime(pipeline)
    fa, pp, yor, te, yte = pipeline
    sess = rt.session(te[:9], "depth", backend="pallas", block_b=16)
    sess.advance(5)
    assert calls["fused"] >= 1


def test_pallas_run_slots_dispatches_slot_kernel(monkeypatch, pipeline):
    """ExecutorCore.run with vector units on pallas must route through
    the masked-slot kernel (ROADMAP open item 2), not the generic
    per-slot gather."""
    from repro.kernels import ops

    calls = {"slot": 0}
    real = ops.slot_run
    monkeypatch.setattr(
        ops, "slot_run",
        lambda *a, **k: (calls.__setitem__("slot", calls["slot"] + 1),
                         real(*a, **k))[1])
    rt = _runtime(pipeline)
    fa, pp, yor, te, yte = pipeline
    sess = rt.session(te[:8], "depth", backend="pallas", block_b=8, block_m=8)
    core = sess.backend.executor
    units = np.zeros(8, dtype=np.int32)
    mask = np.ones(8, dtype=bool)
    idx2 = core.run_slots(sess.idx, core.X, units, mask, 2)
    assert calls["slot"] == 1
    # and it matches the engine's generic gather bit-for-bit
    exp = engine.slot_run(core.device, core.X, sess.idx,
                          np.zeros(8, np.int32), np.ones(8, bool), 2)
    np.testing.assert_array_equal(np.asarray(idx2), np.asarray(exp))


@pytest.mark.parametrize("backend", ["jnp-ref", "pallas", "sharded"])
def test_slot_path_parity_mixed_live_dead(backend, pipeline):
    """ExecutorCore's masked-slot shape on every backend: mixed
    live/dead lanes with per-slot tree ids must match the jnp-ref
    oracle bit-for-bit, dead rows bit-frozen."""
    fa, pp, yor, te, yte = pipeline
    rt = _runtime(pipeline)
    S = 9
    sess = rt.session(te[:S], "depth", backend=backend,
                      **PARITY_OPTS.get(backend, {}))
    core = sess.backend.executor
    rng = np.random.default_rng(0)
    idx = core.init_state()
    # size the unit/mask vectors to the EXECUTOR's batch — the sharded
    # executor pads the slot axis to the shard count (as SessionBatch's
    # capacity rounding guarantees in production); padded rows are dead
    B = int(core.X.shape[0])
    units = np.zeros(B, dtype=np.int32)
    units[:S] = rng.integers(0, fa.n_trees, size=S)
    mask = np.zeros(B, dtype=bool)
    mask[:S] = rng.random(S) < 0.6
    oracle = rt.session(te[:S], "depth", backend="jnp-ref")
    exp = oracle.backend.executor.init_state()
    for L in (1, 2, 4):
        idx, probs = core.run(idx, units, mask, L, readout=True)
        exp = engine.slot_run(oracle.backend.executor.device,
                              oracle.backend.executor.X, exp,
                              units[:S], mask[:S], L)
        np.testing.assert_array_equal(np.asarray(idx)[:S], np.asarray(exp))
        dead = ~mask[:S]
        np.testing.assert_array_equal(np.asarray(idx)[:S][dead],
                                      np.asarray(exp)[dead])
        exp_probs = engine.predict_from_state(
            oracle.backend.executor.device, exp)
        np.testing.assert_allclose(np.asarray(probs)[:S],
                                   np.asarray(exp_probs),
                                   rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["jnp-ref", "pallas", "sharded"])
def test_executor_core_unified_entry_solo_shape(backend, pipeline):
    """run() with a SCALAR unit is the solo lockstep shape — identical
    to the legacy run_segment shim, with the boundary readout fusable
    into the same dispatch."""
    fa, pp, yor, te, yte = pipeline
    rt = _runtime(pipeline)
    sess = rt.session(te[:7], "depth", backend=backend,
                      **PARITY_OPTS.get(backend, {}))
    core = sess.backend.executor
    assert isinstance(core, ExecutorCore)
    assert ForestExecutor is ExecutorCore  # compat alias
    idx = core.init_state()
    import jax.numpy as jnp

    unit = jnp.asarray(1, jnp.int32)
    via_run, probs = core.run(idx, unit, length=4, readout=True)
    via_shim = core.run_segment(core.init_state(), unit, 4)
    np.testing.assert_array_equal(np.asarray(via_run), np.asarray(via_shim))
    np.testing.assert_allclose(
        np.asarray(probs), np.asarray(core.readout(via_run)),
        rtol=1e-5, atol=1e-5)


def test_trace_count_bounded_under_deadline_pattern(pipeline):
    """Arbitrary odd advance splits never mint new trace lengths: every
    dispatched fused-segment length is a power of two, <= 8 distinct."""
    fa, pp, yor, te, yte = pipeline
    rt = _runtime(pipeline)
    sess = rt.session(te[:17], "backward_squirrel")
    rng = np.random.default_rng(0)
    while sess.remaining:
        sess.advance(int(rng.integers(1, 8)))
    lens = sess.backend.dispatched_lengths
    assert all(p & (p - 1) == 0 for p in lens)
    assert len(lens) <= 8


def test_sharded_backend_pads_and_unpads_odd_batch(pipeline):
    fa, pp, yor, te, yte = pipeline
    rt = _runtime(pipeline)
    sess = rt.session(te[:33], "depth", backend="sharded")
    sess.run_to_completion()
    assert sess.predict_proba().shape == (33, fa.probs.shape[-1])


def test_legacy_executor_subclass_still_works(pipeline):
    """A pre-ExecutorCore executor that overrides run_segment/readout
    (the old protocol) must still serve BOTH session shapes through the
    unified run() entry point — run_segment honored for solo segments,
    the base class's generic gather behind the slot shape."""
    import jax.numpy as jnp

    fa, pp, yor, te, yte = pipeline
    rt = _runtime(pipeline)
    order = rt.order("depth")
    dev = engine.to_device(fa)
    calls = {"seg": 0}

    class LegacyExecutor(ExecutorCore):
        def run_segment(self, idx, unit, length):
            calls["seg"] += 1
            return engine.tree_run(self.device, self.X, idx, unit, length)

        def readout(self, idx):
            return engine.predict_from_state(self.device, idx)

    plan = StepPlan.compile(np.asarray(order, dtype=np.int32))
    core = LegacyExecutor(dev, te[:6], plan)
    idx, probs = core.run(core.init_state(), jnp.asarray(1, jnp.int32),
                          length=4, readout=True)
    assert calls["seg"] == 1 and probs is not None
    ref_exec = get_backend("jnp-ref")(dev, te[:6], plan)
    exp, _ = ref_exec.run(ref_exec.init_state(), jnp.asarray(1, jnp.int32),
                          length=4)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(exp))
    # slot shape falls back to the base generic gather
    units = np.zeros(6, np.int32)
    mask = np.ones(6, bool)
    got, _ = core.run(core.init_state(), units, mask, 2)
    want = engine.slot_run(dev, core.X, core.init_state(),
                           jnp.asarray(units), jnp.asarray(mask), 2)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    # the old in-tree pattern: run_slots override that DELEGATES to
    # super().run_slots() after placement — must not recurse
    class DelegatingExecutor(LegacyExecutor):
        def run_slots(self, idx, X, units, mask, length):
            return super().run_slots(idx, X, jnp.asarray(units),
                                     jnp.asarray(mask), length)

    core2 = DelegatingExecutor(dev, te[:6], plan)
    got2, _ = core2.run(core2.init_state(), units, mask, 2)
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(want))


def test_forest_step_backend_direct_construction(pipeline):
    """The pre-refactor positional signature keeps working."""
    fa, pp, yor, te, yte = pipeline
    rt = _runtime(pipeline)
    order = rt.order("depth")
    dev = engine.to_device(fa)
    b = ForestStepBackend(dev, te[:5], order)
    assert b.backend_name == default_backend()
    assert b.total_steps == len(order)
    b.advance(4)
    assert b.pos == 4 and b.remaining == len(order) - 4


# ---------------------------------------------------------------------------
# Session fixes (satellite): __getattr__ recursion guard, deadline edge.
# ---------------------------------------------------------------------------


def test_session_getattr_raises_before_init():
    """During unpickling __getattr__ runs before __dict__ holds
    ``backend``; it must raise AttributeError, not recurse forever."""
    s = Session.__new__(Session)
    with pytest.raises(AttributeError):
        s.backend
    with pytest.raises(AttributeError):
        s.idx
    assert not hasattr(s, "anything_else")


def test_advance_until_non_positive_deadline(pipeline):
    fa, pp, yor, te, yte = pipeline
    rt = _runtime(pipeline)

    def exploding_clock():
        raise AssertionError("clock must not be read for non-positive deadlines")

    sess = rt.session(te[:5], "depth", clock=exploding_clock)
    assert sess.advance_until(0.0) == 0
    assert sess.advance_until(-3.0) == 0
    assert sess.pos == 0


# ---------------------------------------------------------------------------
# Fresh (root-start) segments: the depth-aware dispatch path
# ---------------------------------------------------------------------------


def test_step_plan_marks_first_segment_fresh():
    order = np.array([0] * 5 + [1] * 3 + [0] * 2, dtype=np.int32)
    plan = StepPlan.compile(order)
    assert plan.seg_fresh is not None
    # exactly one fresh segment per unit, and it is the unit's first
    for u in (0, 1):
        owned = [i for i, su in enumerate(plan.seg_units) if su == u]
        assert [bool(plan.seg_fresh[i]) for i in owned] == (
            [True] + [False] * (len(owned) - 1))
    # freshness follows PLAN order: unit 0's run of 5 splits [4, 1] and
    # only the head piece starts at the root
    np.testing.assert_array_equal(plan.seg_lens[:2], [4, 1])
    assert bool(plan.seg_fresh[0]) and not bool(plan.seg_fresh[1])


def test_pallas_fresh_segment_dispatches_depth_kernel(monkeypatch, pipeline):
    """The FIRST plan segment of each unit (walkers at the root) must
    route through the depth-aware gather-eliminated kernel; later
    segments through the full-width fused run."""
    from repro.schedule import backends as B

    calls = {"depth": 0}
    real = B.kops.forest_run_depth
    monkeypatch.setattr(
        B.kops, "forest_run_depth",
        lambda *a, **k: (calls.__setitem__("depth", calls["depth"] + 1),
                         real(*a, **k))[1])
    rt = _runtime(pipeline)
    fa, pp, yor, te, yte = pipeline
    ref = rt.session(te[:9], "depth", backend="jnp-ref")
    sess = rt.session(te[:9], "depth", backend="pallas",
                      block_b=16, block_m=8)
    assert sess.backend.executor.layout is not None
    ref.advance(10_000)
    sess.advance(10_000)
    # one fresh dispatch per unit's opening segment (traced once per
    # pow2 length; counted at trace time)
    assert calls["depth"] >= 1
    np.testing.assert_array_equal(
        np.asarray(sess.idx)[:9], np.asarray(ref.idx))
    np.testing.assert_allclose(
        sess.predict_proba(), ref.predict_proba(), rtol=1e-5, atol=1e-5)


def test_pallas_depth_levels_zero_disables_variant(monkeypatch, pipeline):
    from repro.schedule import backends as B

    monkeypatch.setattr(
        B.kops, "forest_run_depth",
        lambda *a, **k: (_ for _ in ()).throw(
            AssertionError("depth_levels=0 must not build/dispatch the "
                           "depth variant")))
    rt = _runtime(pipeline)
    fa, pp, yor, te, yte = pipeline
    ref = rt.session(te[:7], "depth", backend="jnp-ref")
    sess = rt.session(te[:7], "depth", backend="pallas", depth_levels=0,
                      block_b=16, block_m=8)
    assert sess.backend.executor.layout is None
    ref.advance(20)
    sess.advance(20)
    np.testing.assert_array_equal(
        np.asarray(sess.idx)[:7], np.asarray(ref.idx))


def test_executor_run_fresh_flag_is_correctness_neutral(pipeline):
    """fresh=True on a genuinely root-start column must be bit-identical
    to the plain fused dispatch (it only changes the kernel used)."""
    fa, pp, yor, te, yte = pipeline
    rt = _runtime(pipeline)
    sess = rt.session(te[:9], "depth", backend="pallas",
                      block_b=16, block_m=8)
    core = sess.backend.executor
    idx0 = core.init_state()
    plain, _ = core.run(idx0, 1, length=4)
    fresh, _ = core.run(idx0, 1, length=4, fresh=True)
    np.testing.assert_array_equal(np.asarray(plain), np.asarray(fresh))
