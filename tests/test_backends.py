"""Execution-backend subsystem: step-plan compilation, registry, and the
pallas/sharded parity suite against the jnp-ref oracle on odd shapes."""
import numpy as np
import pytest

from repro.core import engine
from repro.forest import make_dataset, split_dataset, train_forest
from repro.schedule import (
    AnytimeRuntime,
    ForestProgram,
    ForestStepBackend,
    Session,
    StepPlan,
    default_backend,
    get_backend,
    list_backends,
    pow2_decompose,
)


@pytest.fixture(scope="module")
def pipeline():
    X, y = make_dataset("magic", seed=3)
    (tr, ytr), (orx, yor), (te, yte) = split_dataset(X, y, seed=3)
    # depth 6 -> up to 127 nodes per tree: many M-tiles at block_m=8
    rf = train_forest(tr[:800], ytr[:800], 2, n_trees=4, max_depth=6, seed=3)
    fa = rf.as_arrays()
    pp = engine.path_probs_np(fa, orx[:200])
    return fa, pp, yor[:200], te, yte


def _runtime(pipeline):
    fa, pp, yor, te, yte = pipeline
    return AnytimeRuntime(ForestProgram(fa, y_order=yor, path_probs=pp))


# ---------------------------------------------------------------------------
# StepPlan
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n,cap,expect", [
    (0, 64, []),
    (1, 64, [1]),
    (13, 64, [8, 4, 1]),
    (64, 64, [64]),
    (100, 64, [64, 32, 4]),
    (100, 16, [16, 16, 16, 16, 16, 16, 4]),
])
def test_pow2_decompose(n, cap, expect):
    assert pow2_decompose(n, cap=cap) == expect
    assert sum(expect) == n
    assert all(p & (p - 1) == 0 and p <= cap for p in expect)


def test_pow2_decompose_rejects_negative():
    with pytest.raises(ValueError, match="negative"):
        pow2_decompose(-1)


@pytest.mark.parametrize("cap", [0, -4, 6])
def test_pow2_decompose_rejects_bad_cap(cap):
    with pytest.raises(ValueError, match="power of two"):
        pow2_decompose(5, cap=cap)


def test_step_plan_roundtrip_and_bucketing():
    order = np.array([0] * 13 + [1] * 3 + [0] + [2] * 8, dtype=np.int32)
    plan = StepPlan.compile(order)
    # segments reconstruct the order exactly
    rebuilt = np.concatenate(
        [[u] * n for u, n in zip(plan.seg_units, plan.seg_lens)])
    np.testing.assert_array_equal(rebuilt, order)
    # every segment length is a power of two <= cap
    assert all(int(l) & (int(l) - 1) == 0 for l in plan.seg_lens)
    assert plan.trace_lengths == (1, 2, 4, 8)
    assert plan.total_steps == len(order)
    assert plan.seg_starts[-1] == len(order)
    # segment_at maps positions to containing segments
    for pos in range(len(order)):
        s = plan.segment_at(pos)
        assert plan.seg_starts[s] <= pos < plan.seg_starts[s + 1]


def test_step_plan_validates_order_when_shape_given():
    with pytest.raises(ValueError, match="unit 0"):
        StepPlan.compile(np.zeros(6, dtype=np.int32), n_units=3, unit_steps=2)


def test_step_plan_trace_bound_is_logarithmic(pipeline):
    """Distinct plan segment lengths <= log2(max_segment)+1 = 7 <= 8 —
    the acceptance criterion's compile-count bound for ANY order."""
    rt = _runtime(pipeline)
    for name in ("backward_squirrel", "depth", "breadth", "random"):
        plan = StepPlan.compile(rt.order(name))
        assert len(plan.trace_lengths) <= 8, name


# ---------------------------------------------------------------------------
# Registry / selection surface
# ---------------------------------------------------------------------------


def test_backend_registry():
    assert set(list_backends()) >= {"jnp-ref", "pallas", "sharded"}
    assert get_backend("pallas").name == "pallas"
    with pytest.raises(ValueError, match="unknown backend.*jnp-ref"):
        get_backend("mosaic")


def test_default_backend_matches_platform():
    import jax

    expect = "pallas" if jax.default_backend() == "tpu" else "jnp-ref"
    assert default_backend() == expect


def test_runtime_rejects_unknown_backend_eagerly(pipeline):
    fa, pp, yor, te, yte = pipeline
    with pytest.raises(ValueError, match="unknown backend"):
        AnytimeRuntime(
            ForestProgram(fa, y_order=yor, path_probs=pp), backend="nope")


def test_runtime_backend_default_flows_to_sessions(pipeline):
    fa, pp, yor, te, yte = pipeline
    rt = AnytimeRuntime(
        ForestProgram(fa, y_order=yor, path_probs=pp), backend="pallas")
    sess = rt.session(te[:9], "depth")
    assert sess.backend.backend_name == "pallas"
    # per-session override wins
    sess2 = rt.session(te[:9], "depth", backend="jnp-ref")
    assert sess2.backend.backend_name == "jnp-ref"


def test_step_plans_shared_across_sessions(pipeline):
    fa, pp, yor, te, yte = pipeline
    rt = _runtime(pipeline)
    order = rt.order("backward_squirrel")
    a = rt.session(te[:5], order=order)
    b = rt.session(te[:7], order=order)
    assert a.backend.plan is b.backend.plan  # compile-once, content-addressed


# ---------------------------------------------------------------------------
# Parity suite: pallas (interpret) and sharded vs the jnp-ref oracle.
# Odd shapes: batch not a multiple of the tile, trees larger than one
# M-tile, single-sample batch, mid-chunk advance splits.
# ---------------------------------------------------------------------------

PARITY_OPTS = {
    # tiny tiles force batch padding + multi-M-tile streaming on a
    # depth-6 (<=127 node) forest
    "pallas": {"block_b": 16, "block_m": 8},
    "sharded": {},
}


@pytest.mark.parametrize("backend", ["pallas", "sharded"])
@pytest.mark.parametrize("batch", [1, 33])
@pytest.mark.parametrize("name", ["backward_squirrel", "depth"])
def test_backend_parity_with_oracle(backend, batch, name, pipeline):
    """Index-array state must match the jnp-ref oracle BIT-FOR-BIT at
    every mid-chunk split point; read-outs to float tolerance."""
    fa, pp, yor, te, yte = pipeline
    rt = _runtime(pipeline)
    order = rt.order(name)
    X = te[:batch]
    ref = rt.session(X, order=order, backend="jnp-ref")
    sess = rt.session(X, order=order, backend=backend, **PARITY_OPTS[backend])
    for k in (1, 2, 5, 1, 3, 10_000):  # odd chunks straddle plan segments
        ref.advance(k)
        sess.advance(k)
        assert sess.pos == ref.pos
        np.testing.assert_array_equal(
            np.asarray(sess.idx)[:batch], np.asarray(ref.idx))
        np.testing.assert_allclose(
            sess.predict_proba(), ref.predict_proba(), rtol=1e-5, atol=1e-5)
        np.testing.assert_array_equal(sess.predict(), ref.predict())
    assert sess.remaining == 0


def test_pallas_backend_dispatches_kernel(monkeypatch, pipeline):
    """backend="pallas" must route the hot path through
    repro.kernels.ops.forest_run / prob_accum (the acceptance criterion),
    not the jnp engine scan."""
    from repro.kernels import ops
    from repro.schedule import backends as B

    calls = {"run": 0, "accum": 0}
    real_run, real_accum = ops.forest_run, ops.prob_accum

    def spy_run(*a, **kw):
        calls["run"] += 1
        return real_run(*a, **kw)

    def spy_accum(*a, **kw):
        calls["accum"] += 1
        return real_accum(*a, **kw)

    monkeypatch.setattr(B.kops, "forest_run", spy_run)
    monkeypatch.setattr(B.kops, "prob_accum", spy_accum)
    rt = _runtime(pipeline)
    fa, pp, yor, te, yte = pipeline
    sess = rt.session(te[:9], "depth", backend="pallas",
                      block_b=16, block_m=8)
    sess.advance(3)
    sess.predict()
    assert calls["run"] >= 1 and calls["accum"] >= 1


def test_trace_count_bounded_under_deadline_pattern(pipeline):
    """Arbitrary odd advance splits never mint new trace lengths: every
    dispatched fused-segment length is a power of two, <= 8 distinct."""
    fa, pp, yor, te, yte = pipeline
    rt = _runtime(pipeline)
    sess = rt.session(te[:17], "backward_squirrel")
    rng = np.random.default_rng(0)
    while sess.remaining:
        sess.advance(int(rng.integers(1, 8)))
    lens = sess.backend.dispatched_lengths
    assert all(p & (p - 1) == 0 for p in lens)
    assert len(lens) <= 8


def test_sharded_backend_pads_and_unpads_odd_batch(pipeline):
    fa, pp, yor, te, yte = pipeline
    rt = _runtime(pipeline)
    sess = rt.session(te[:33], "depth", backend="sharded")
    sess.run_to_completion()
    assert sess.predict_proba().shape == (33, fa.probs.shape[-1])


def test_forest_step_backend_direct_construction(pipeline):
    """The pre-refactor positional signature keeps working."""
    fa, pp, yor, te, yte = pipeline
    rt = _runtime(pipeline)
    order = rt.order("depth")
    dev = engine.to_device(fa)
    b = ForestStepBackend(dev, te[:5], order)
    assert b.backend_name == default_backend()
    assert b.total_steps == len(order)
    b.advance(4)
    assert b.pos == 4 and b.remaining == len(order) - 4


# ---------------------------------------------------------------------------
# Session fixes (satellite): __getattr__ recursion guard, deadline edge.
# ---------------------------------------------------------------------------


def test_session_getattr_raises_before_init():
    """During unpickling __getattr__ runs before __dict__ holds
    ``backend``; it must raise AttributeError, not recurse forever."""
    s = Session.__new__(Session)
    with pytest.raises(AttributeError):
        s.backend
    with pytest.raises(AttributeError):
        s.idx
    assert not hasattr(s, "anything_else")


def test_advance_until_non_positive_deadline(pipeline):
    fa, pp, yor, te, yte = pipeline
    rt = _runtime(pipeline)

    def exploding_clock():
        raise AssertionError("clock must not be read for non-positive deadlines")

    sess = rt.session(te[:5], "depth", clock=exploding_clock)
    assert sess.advance_until(0.0) == 0
    assert sess.advance_until(-3.0) == 0
    assert sess.pos == 0
