"""Threaded serving: the background ServeDriver, thread-safe submit,
future-style Ticket semantics, clean shutdown, and the bit-parity
guarantee under the thread.

This is the suite the CI ``thread-stress`` job loops N times with
``PYTHONFAULTHANDLER=1`` to shake out races the single-shot tier-1 run
misses — keep every test here deterministic under repetition (generous
deadlines, explicit timeouts, no sleeps-as-synchronization for
correctness-critical assertions)."""
import threading
import time

import numpy as np
import pytest

from repro.core import engine
from repro.forest import make_dataset, split_dataset, train_forest
from repro.schedule import AnytimeRuntime, ForestProgram
from repro.serve import AnytimeServer, DriverDead, as_completed

#: generous per-result wait — a stuck driver fails the test, not the run
WAIT_S = 120.0


@pytest.fixture(scope="module")
def pipeline():
    X, y = make_dataset("magic", seed=1)
    (tr, ytr), (orx, yor), (te, yte) = split_dataset(X, y, seed=1)
    rf = train_forest(tr[:800], ytr[:800], 2, n_trees=4, max_depth=5, seed=1)
    fa = rf.as_arrays()
    pp = engine.path_probs_np(fa, orx[:200])
    return fa, pp, yor[:200], te, yte


@pytest.fixture(scope="module")
def runtime(pipeline):
    fa, pp, yor, te, yte = pipeline
    return AnytimeRuntime(
        ForestProgram(fa, y_order=yor, path_probs=pp, X_order=te[:8]))


def _solo(runtime, x_row, order, steps):
    """The jnp-ref oracle: a solo session advanced ``steps`` steps."""
    sess = runtime.session(
        np.asarray(x_row)[None, :], order=order, backend="jnp-ref")
    sess.advance(steps)
    return sess


# ---------------------------------------------------------------------------
# Parity under the thread (the tentpole acceptance criterion)
# ---------------------------------------------------------------------------


BACKEND_OPTS = {
    "jnp-ref": {},
    "pallas": {"block_b": 16, "block_m": 8},
    "sharded": {},
}


@pytest.mark.parametrize("backend", ["jnp-ref", "pallas", "sharded"])
def test_threaded_parity_matches_solo_oracle(backend, runtime, pipeline):
    """With the background driver owning the loop, every served
    prediction is bit-identical to a solo jnp-ref session advanced the
    same number of steps (pallas readouts to kernel tolerance)."""
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    with AnytimeServer(runtime, capacity=3,
                       backend_opts=BACKEND_OPTS[backend]) as server:
        assert server.driver_running
        tickets = [server.submit(te[i], 60_000.0, backend=backend)
                   for i in range(7)]
        results = [t.result(timeout=WAIT_S) for t in tickets]
    for i, r in enumerate(results):
        assert r.completed and r.deadline_hit and r.error is None
        assert r.steps_completed == r.total_steps == len(order)
        solo = _solo(runtime, te[i], order, r.steps_completed)
        np.testing.assert_array_equal(r.prediction, solo.predict()[0])
        if backend == "pallas":
            np.testing.assert_allclose(
                r.proba, solo.predict_proba()[0], rtol=1e-5, atol=1e-5)
        else:
            np.testing.assert_array_equal(r.proba, solo.predict_proba()[0])


def test_threaded_degrade_never_returns_torn_readout(runtime, pipeline):
    """Degrade admission under the driver thread: budgets shrink, but
    every delivered readout is still an exact prefix boundary."""
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    with AnytimeServer(runtime, capacity=2, admission="degrade",
                       admission_k=1.0) as server:
        tickets = [server.submit(te[i % te.shape[0]], 60_000.0)
                   for i in range(10)]
        results = [t.result(timeout=WAIT_S) for t in tickets]
    assert all(r.deadline_hit for r in results)
    assert any(r.degraded for r in results)
    for i, r in enumerate(results):
        assert r.steps_completed <= r.budget_steps
        solo = _solo(runtime, te[i % te.shape[0]], order, r.steps_completed)
        np.testing.assert_array_equal(r.proba, solo.predict_proba()[0])


# ---------------------------------------------------------------------------
# Lifecycle: start/stop/close, submit-after-close, mid-drain stop
# ---------------------------------------------------------------------------


def test_context_manager_owns_driver_lifecycle(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=2)
    assert not server.driver_running
    with server as srv:
        assert srv is server and server.driver_running
        assert srv.submit(te[0], 60_000.0).result(timeout=WAIT_S).completed
    assert not server.driver_running


def test_submit_after_close_raises(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=2)
    with server:
        pass
    with pytest.raises(RuntimeError, match="closed"):
        server.submit(te[0], 60_000.0)
    # close is idempotent; start after close refuses too
    server.close()
    with pytest.raises(RuntimeError, match="closed"):
        server.start()


def test_start_is_idempotent(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=2)
    try:
        server.start()
        driver = server._driver
        server.start()
        assert server._driver is driver  # no second thread spawned
    finally:
        server.close()


def test_stop_mid_flight_answers_every_admitted_request(runtime, pipeline):
    """Clean shutdown: stop() drains in-flight slots to their last
    segment-boundary readout and answers queued requests with the prior
    — no admitted ticket is left pending, and nothing is torn."""
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    server = AnytimeServer(runtime, capacity=2).start()
    tickets = [server.submit(te[i % te.shape[0]], 60_000.0)
               for i in range(8)]
    time.sleep(0.05)  # let the driver get some requests genuinely in flight
    done_before_stop = {t.request_id for t in tickets if t.done}
    flushed = server.stop()
    # every admitted ticket answered; the flush delivered each remaining
    # request exactly once and never re-delivered one the driver already
    # had (together these pin flushed == tickets undelivered at stop —
    # the in-between window belongs to the driver, so only subset and
    # disjointness are deterministic)
    assert all(t.done for t in tickets)
    flushed_ids = [r.request_id for r in flushed]
    assert len(flushed_ids) == len(set(flushed_ids))
    assert set(flushed_ids) <= {t.request_id for t in tickets}
    assert set(flushed_ids).isdisjoint(done_before_stop)
    for i, t in enumerate(tickets):
        r = t.result()
        assert r.error is None
        assert 0 <= r.steps_completed <= r.total_steps
        solo = _solo(runtime, te[i % te.shape[0]], order, r.steps_completed)
        np.testing.assert_array_equal(r.proba, solo.predict_proba()[0])


def test_stop_without_driver_flushes_cooperative_server(runtime, pipeline):
    """stop() is also the cooperative shutdown: mid-drain, it answers
    every admitted request at its last boundary."""
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    server = AnytimeServer(runtime, capacity=2)
    tickets = [server.submit(te[i], 60_000.0) for i in range(5)]
    for _ in range(4):  # a partial drain, then shutdown mid-flight
        server.step()
    server.stop()
    for i, t in enumerate(tickets):
        r = t.result()
        assert 0 <= r.steps_completed <= r.total_steps
        solo = _solo(runtime, te[i], order, r.steps_completed)
        np.testing.assert_array_equal(r.proba, solo.predict_proba()[0])


def test_drain_blocks_until_idle_in_threaded_mode(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    with AnytimeServer(runtime, capacity=2) as server:
        tickets = [server.submit(te[i], 60_000.0) for i in range(5)]
        out = server.drain()
        assert out == []            # results live on the tickets
        assert not server.busy
        assert all(t.done for t in tickets)


def test_threaded_drain_returns_after_deadline_expiry():
    """Deadlock regression: when the LAST deliveries happen at deadline
    expiry, the busy -> idle transition lands in a later, delivery-less
    iteration (the lane's in-flight boundary draining) — a threaded
    drain() parked on the condition must still be woken."""
    rt = AnytimeRuntime(_SlowProgram())
    with AnytimeServer(rt, capacity=4, chunk=1) as server:
        # deadlines fire mid-flight: 12 slow steps (~0.24 s) vs 60 ms
        tickets = [server.submit(float(i), deadline_ms=60.0)
                   for i in range(4)]
        server.drain()              # must return, not hang
        assert all(t.done for t in tickets)
        assert all(t.result().steps_completed < 12 for t in tickets)


# ---------------------------------------------------------------------------
# Future semantics: callbacks, as_completed, result(timeout=)
# ---------------------------------------------------------------------------


def test_callbacks_fire_exactly_once_including_already_done(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    calls: list[tuple[str, object]] = []
    fired = threading.Event()
    with AnytimeServer(runtime, capacity=2) as server:
        ticket = server.submit(te[0], 60_000.0)
        ticket.add_done_callback(lambda t: (calls.append(("live", t)),
                                            fired.set()))
        assert fired.wait(WAIT_S)
        ticket.result(timeout=WAIT_S)
        # already-done ticket: callback fires immediately, exactly once
        ticket.add_done_callback(lambda t: calls.append(("late", t)))
    assert [tag for tag, _ in calls] == ["live", "late"]
    assert all(t is ticket for _, t in calls)


def test_raising_callback_does_not_kill_the_driver(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    with AnytimeServer(runtime, capacity=2) as server:
        bad = server.submit(te[0], 60_000.0)
        bad.add_done_callback(lambda t: 1 / 0)
        assert bad.result(timeout=WAIT_S).completed
        # the driver survived the raising callback and serves on
        assert server.submit(te[1], 60_000.0).result(timeout=WAIT_S).completed


def test_as_completed_yields_every_ticket(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    with AnytimeServer(runtime, capacity=3) as server:
        tickets = [server.submit(te[i], 60_000.0) for i in range(6)]
        seen = list(as_completed(tickets, timeout=WAIT_S))
    assert set(seen) == set(tickets)
    assert all(t.done for t in seen)


def test_as_completed_drives_cooperative_servers(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=2)   # never started
    tickets = [server.submit(te[i], 60_000.0) for i in range(4)]
    seen = list(as_completed(tickets, timeout=WAIT_S))
    assert set(seen) == set(tickets)


# ---------------------------------------------------------------------------
# Slow/raising programs: timeouts and driver-death propagation (session
# lanes — the generic program path — driven by the same thread)
# ---------------------------------------------------------------------------


class _SlowSession:
    """Fake step backend: each advance sleeps, state == steps taken."""

    sleep_s = 0.02

    def __init__(self, order, inputs):
        self.order = np.asarray(order)
        self.inputs = inputs
        self.pos = 0

    @property
    def total_steps(self):
        return len(self.order)

    @property
    def remaining(self):
        return self.total_steps - self.pos

    def advance(self, k):
        k = min(k, self.remaining)
        time.sleep(self.sleep_s)
        self.pos += k
        return k

    def predict_proba(self):
        return np.asarray([[float(self.pos), float(self.inputs)]])

    def predict(self):
        return self.predict_proba().argmax(axis=1)


class _SlowProgram:
    """Minimal AnytimeProgram without make_slot_batch -> session lane."""

    n_units = 4
    unit_steps = 3
    session_cls = _SlowSession

    def quality_table(self):
        rng = np.random.default_rng(0)
        return (rng.random((8, self.n_units, 4, 2)).astype(np.float32),
                rng.integers(0, 2, 8))

    def make_session(self, order, inputs):
        return self.session_cls(order, inputs)


class _BombSession(_SlowSession):
    def advance(self, k):
        raise RuntimeError("boom: device fell over")


class _BombProgram(_SlowProgram):
    session_cls = _BombSession


def test_result_timeout_raises_then_succeeds():
    rt = AnytimeRuntime(_SlowProgram())
    with AnytimeServer(rt, capacity=1, chunk=1) as server:
        ticket = server.submit(5.0, 60_000.0)
        with pytest.raises(TimeoutError):
            ticket.result(timeout=0.01)
        r = ticket.result(timeout=WAIT_S)
        assert r.completed and r.steps_completed == 12


def test_driver_death_propagates_to_waiters_and_submitters():
    rt = AnytimeRuntime(_BombProgram())
    server = AnytimeServer(rt, capacity=1, chunk=1).start()
    ticket = server.submit(5.0, 60_000.0)
    with pytest.raises(DriverDead) as excinfo:
        ticket.result(timeout=WAIT_S)
    assert "boom" in repr(excinfo.value.__cause__)
    with pytest.raises(DriverDead):
        server.submit(6.0, 60_000.0)
    # shutdown still answers the stranded ticket (last known boundary)
    flushed = server.stop()
    assert any(r.request_id == ticket.request_id for r in flushed)


# ---------------------------------------------------------------------------
# Thread-safety: concurrent submitters against one driver
# ---------------------------------------------------------------------------


def test_concurrent_submitters_all_served_exactly_once(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    n_threads, per_thread = 4, 5
    results: dict[int, list] = {}
    errors: list[BaseException] = []

    def submitter(tid: int) -> None:
        try:
            tickets = [
                runtime_server.submit(
                    te[(tid * per_thread + j) % te.shape[0]], 60_000.0)
                for j in range(per_thread)
            ]
            results[tid] = [t.result(timeout=WAIT_S) for t in tickets]
        except BaseException as e:  # noqa: BLE001 - surfaced below
            errors.append(e)

    with AnytimeServer(runtime, capacity=4) as runtime_server:
        threads = [threading.Thread(target=submitter, args=(tid,))
                   for tid in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(WAIT_S)
        snap = runtime_server.metrics.snapshot()
    assert not errors
    delivered = [r for rs in results.values() for r in rs]
    assert len(delivered) == n_threads * per_thread
    assert all(r.completed and r.error is None for r in delivered)
    # every request got a distinct id and was delivered exactly once
    assert len({r.request_id for r in delivered}) == len(delivered)
    assert snap["delivered"] == len(delivered)
    for tid, rs in results.items():
        for j, r in enumerate(rs):
            solo = _solo(runtime,
                         te[(tid * per_thread + j) % te.shape[0]],
                         order, r.steps_completed)
            np.testing.assert_array_equal(r.proba, solo.predict_proba()[0])


def test_eight_submitters_on_sharded_queue_fast_path(runtime, pipeline):
    """The lock-free submit fast path under real contention: 8 threads
    hammer a 4-shard queue while the driver drains it.  Every ticket
    must resolve exactly once with an exact-prefix readout, and the
    shard counters must reconcile with the delivered population — the
    regression test for the stamp → register → push ordering (a ticket
    registered AFTER its request became poppable could be delivered
    before its callback target exists)."""
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    n_threads, per_thread = 8, 6
    barrier = threading.Barrier(n_threads)
    results: dict[int, list] = {}
    errors: list[BaseException] = []

    def submitter(tid: int) -> None:
        try:
            barrier.wait(WAIT_S)  # maximize submit-path overlap
            tickets = [server.submit(
                te[(tid * per_thread + j) % te.shape[0]], 60_000.0)
                for j in range(per_thread)]
            results[tid] = [t.result(timeout=WAIT_S) for t in tickets]
        except BaseException as e:  # noqa: BLE001 - surfaced below
            errors.append(e)

    with AnytimeServer(runtime, capacity=4, queue_shards=4) as server:
        assert server.queue.n_shards == 4
        threads = [threading.Thread(target=submitter, args=(tid,))
                   for tid in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(WAIT_S)
        snap = server.metrics.snapshot()
    assert not errors
    delivered = [r for rs in results.values() for r in rs]
    assert len(delivered) == n_threads * per_thread
    assert all(r.completed and r.error is None for r in delivered)
    assert len({r.request_id for r in delivered}) == len(delivered)
    assert snap["submitted"] == snap["delivered"] == len(delivered)
    assert server.queue.submitted == len(delivered)
    for tid, rs in results.items():
        for j, r in enumerate(rs):
            solo = _solo(runtime,
                         te[(tid * per_thread + j) % te.shape[0]],
                         order, r.steps_completed)
            np.testing.assert_array_equal(r.proba, solo.predict_proba()[0])
