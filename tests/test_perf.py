"""tools.perf (analytical counters, inefficiency report, CLI gates) and
the tuning subsystem's dispatch contract: a tuning record may change
WHICH impl runs, never WHAT it computes."""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref, tuning
from tools.perf import counters as perfc
from tools.perf import report as perfr
from tools.perf.autotune import WIN_MARGIN, _pick
from tools.perf.cli import main as perf_main


# ---------------------------------------------------------------------------
# counters
# ---------------------------------------------------------------------------


def test_counter_impl_names_match_dispatch_registries():
    """The pure-stdlib counter model must describe exactly the impls the
    jax-side registries dispatch (plus the non-registry depth variant)."""
    assert set(perfc.SOLO_IMPLS) == set(tuning.SOLO_IMPLS) | {"depth"}
    assert set(perfc.SLOT_IMPLS) == set(tuning.SLOT_IMPLS)
    assert perfc.DEFAULT_VMEM_BUDGET == ops.VMEM_TABLE_BUDGET_BYTES
    assert perfc.NFIELDS == ops.NFIELDS


def test_solo_counters_shape():
    fused = perfc.solo_counters("fused", M=127, length=32)
    scan = perfc.solo_counters("scan", M=127, length=32)
    depth = perfc.solo_counters("depth", M=127, length=32)
    assert fused["launches"] == 1 and scan["launches"] == 32
    assert fused["gather_rows_per_step"] == 128
    # depth: same single launch, strictly narrower average gather
    assert depth["launches"] == 1
    assert depth["gather_bytes_per_step"] < fused["gather_bytes_per_step"]
    # short runs never unroll past full width
    wide = perfc.solo_counters("depth", M=7, length=2)
    assert wide["gather_rows_per_step"] <= 8
    with pytest.raises(ValueError):
        perfc.solo_counters("nope", M=8, length=1)


def test_slot_counters_ordering():
    kw = dict(T=8, M=127, length=8)
    gather = perfc.slot_counters("gather", **kw)
    flat = perfc.slot_counters("flat", **kw)
    bucket = perfc.slot_counters("bucket", **kw)
    cached = perfc.slot_counters("cached", **kw)
    assert gather["launches"] == 0 and gather["resident_bytes"] == 0
    # bucket's one-hot is T-fold narrower than flat's, and it streams
    # one tile instead of pinning the forest
    assert bucket["gather_rows_per_step"] * 8 == flat["gather_rows_per_step"]
    assert bucket["resident_bytes"] * 8 == flat["resident_bytes"]
    # cached counts conservatively: >= flat residency (tables + top)
    assert cached["resident_bytes"] > flat["resident_bytes"]
    with pytest.raises(ValueError):
        perfc.slot_counters("nope", **kw)


def test_depth_step_widths_levels_cap():
    w = perfc.depth_step_widths(8, 1024, levels=3)
    assert len(w) == 8
    assert w[3:] == [1024] * 5
    assert all(a <= b for a, b in zip(w[:3], w[1:4]))


# ---------------------------------------------------------------------------
# report + CLI
# ---------------------------------------------------------------------------


def test_build_report_and_render(tmp_path):
    rec = {"solo": {"default": {"impl": "fused"}},
           "slot": {"default": {"impl": "gather"}}}
    (tmp_path / "faketpu.json").write_text(json.dumps(rec))
    rep = perfr.build_report(tmp_path)
    assert rep["tuning_platforms"] == ["faketpu"]
    for row in rep["solo"]:
        assert set(row["impls"]) == set(perfc.SOLO_IMPLS)
        assert row["selected"] == {"faketpu": "fused"}
    table = perfr.render_table(rep)
    assert "depth" in table and "bucket" in table


def test_check_report_passes_on_fresh_recompute(tmp_path):
    rep = perfr.build_report(tmp_path)
    path = tmp_path / "kernels.json"
    perfr.write_report(rep, path)
    assert perfr.check_report(rep, path) == []


def test_check_report_flags_divergence_and_bad_selection(tmp_path):
    rep = perfr.build_report(tmp_path)
    path = tmp_path / "kernels.json"
    perfr.write_report(rep, path)
    stale = json.loads(path.read_text())
    stale["solo"][0]["impls"]["depth"]["gather_bytes_per_step"] = 10**9
    path.write_text(json.dumps(stale))
    errs = perfr.check_report(rep, path)
    assert any("diverges" in e for e in errs)
    # a record selecting an unknown impl is caught even though the
    # runtime would degrade it to the default
    (tmp_path / "weird.json").write_text(json.dumps(
        {"solo": {"default": {"impl": "warp"}}}))
    rep2 = perfr.build_report(tmp_path)
    errs2 = perfr.check_report(rep2, committed_path=None)
    assert any("unknown impl" in e for e in errs2)


def test_cli_check_exit_codes(tmp_path, capsys):
    # the report must live OUTSIDE the tuning dir (as in the repo):
    # tuning/*.json are all treated as platform records
    report = tmp_path / "reports" / "kernels.json"
    tdir = tmp_path / "tuning"
    tdir.mkdir()
    args = ["--tuning-dir", str(tdir), "--report", str(report)]
    # no committed report yet: --check fails, --write then --check passes
    assert perf_main([*args, "--check"]) == 1
    assert perf_main([*args, "--write"]) == 0
    assert perf_main([*args, "--check"]) == 0
    out = json.loads(report.read_text())
    assert out["schema"] == 1
    capsys.readouterr()
    assert perf_main([*args, "--json"]) == 0
    assert json.loads(capsys.readouterr().out)["schema"] == 1


def test_committed_report_matches_recompute():
    """The repo's own reports/perf/kernels.json must stay regenerated —
    the same invariant CI's `python -m tools.perf --check` enforces."""
    assert perfr.check_report(perfr.build_report()) == []


# ---------------------------------------------------------------------------
# autotune selection rule
# ---------------------------------------------------------------------------


def test_pick_requires_win_margin():
    timings = {"scan": [({}, 100.0)],
               "fused": [({"block_b": 128}, 95.0), ({"block_b": 256}, 90.0)]}
    # 100/90 = 1.11x < WIN_MARGIN: the fallback keeps the shape
    assert _pick(timings, "scan")[0] == "scan"
    timings["fused"][1] = ({"block_b": 256}, 100.0 / (WIN_MARGIN + 0.05))
    name, params, _ = _pick(timings, "scan")
    assert name == "fused" and params == {"block_b": 256}


# ---------------------------------------------------------------------------
# tuning-driven selection never changes numerics
# ---------------------------------------------------------------------------


def _write_record(tmp_path, solo_impl, slot_impl, **slot_params):
    rec = {
        "solo": {"default": {"impl": solo_impl}},
        "slot": {"default": {"impl": slot_impl, **slot_params}},
    }
    (tmp_path / "cpu.json").write_text(json.dumps(rec))


@pytest.fixture
def tuning_dir(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_TUNING_DIR", str(tmp_path))
    tuning.clear_cache()
    yield tmp_path
    tuning.clear_cache()


def test_tuning_selection_never_changes_numerics(tuning_dir):
    """Every (solo_impl, slot_impl) a tuning record could select yields
    bit-identical states — selection is a pure performance decision."""
    rng = np.random.default_rng(3)
    B, T, M, F = 21, 3, 31, 5
    idx_col = jnp.asarray(rng.integers(0, M, size=B), jnp.int32)
    X = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    tree = (
        jnp.asarray(rng.integers(0, F, size=M), jnp.int32),
        jnp.asarray(rng.normal(size=M), jnp.float32),
        jnp.asarray(rng.integers(0, M, size=M), jnp.int32),
        jnp.asarray(rng.integers(0, M, size=M), jnp.int32),
        jnp.asarray(rng.random(M) < 0.3),
    )
    forest = tuple(jnp.stack([t] * T) for t in tree)
    idx = jnp.asarray(rng.integers(0, M, size=(B, T)), jnp.int32)
    units = jnp.asarray(rng.integers(0, T, size=B), jnp.int32)
    mask = jnp.asarray(rng.random(B) < 0.6)
    solo_exp = ref.forest_run_ref(idx_col, X, *tree, length=5)
    slot_exp = ref.slot_run_ref(idx, X, *forest, units, mask, length=3)
    for solo_impl in tuning.SOLO_IMPLS:
        for slot_impl in tuning.SLOT_IMPLS:
            _write_record(tuning_dir, solo_impl, slot_impl)
            tuning.clear_cache()
            got = ops.forest_run(idx_col, X, *tree, length=5)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(solo_exp),
                err_msg=f"solo impl {solo_impl} diverged via tuning")
            got = ops.slot_run(idx, X, *forest, units, mask, length=3)
            np.testing.assert_array_equal(
                np.asarray(got), np.asarray(slot_exp),
                err_msg=f"slot impl {slot_impl} diverged via tuning")


def test_tuning_selected_params_flow_and_caller_kw_wins(tuning_dir):
    _write_record(tuning_dir, "fused", "cached", block_s=8, top_rows=16)
    tuning.clear_cache()
    name, params = tuning.select("slot", "T3_M128_L3")
    assert name == "cached"
    assert params == {"block_s": 8, "top_rows": 16}
    rng = np.random.default_rng(9)
    B, T, M, F = 9, 3, 20, 4
    forest = (
        jnp.asarray(rng.integers(0, F, size=(T, M)), jnp.int32),
        jnp.asarray(rng.normal(size=(T, M)), jnp.float32),
        jnp.asarray(rng.integers(0, M, size=(T, M)), jnp.int32),
        jnp.asarray(rng.integers(0, M, size=(T, M)), jnp.int32),
        jnp.asarray(rng.random((T, M)) < 0.3),
    )
    idx = jnp.asarray(rng.integers(0, M, size=(B, T)), jnp.int32)
    X = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    units = jnp.asarray(rng.integers(0, T, size=B), jnp.int32)
    mask = jnp.ones(B, bool)
    # tuned params apply, and an explicit caller kwarg overrides them
    got = ops.slot_run(idx, X, *forest, units, mask, length=3)
    got2 = ops.slot_run(idx, X, *forest, units, mask, length=3, top_rows=64)
    exp = ref.slot_run_ref(idx, X, *forest, units, mask, length=3)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(exp))
    np.testing.assert_array_equal(np.asarray(got2), np.asarray(exp))


def test_malformed_or_missing_record_degrades_to_defaults(tuning_dir):
    (tuning_dir / "cpu.json").write_text("{not json")
    tuning.clear_cache()
    assert tuning.select("solo", "M128_L4") == ("fused", {})
    assert tuning.select("slot", "T3_M128_L4") == ("gather", {})
    # a record naming an unregistered impl degrades too
    (tuning_dir / "cpu.json").write_text(json.dumps(
        {"slot": {"default": {"impl": "warp"}}}))
    tuning.clear_cache()
    assert tuning.select("slot", "T3_M128_L4")[0] == "gather"


def test_register_duplicate_impl_raises():
    with pytest.raises(ValueError):
        tuning.register_solo_impl("fused")(lambda: None)
    with pytest.raises(ValueError):
        ops.forest_run(jnp.zeros(1, jnp.int32), jnp.zeros((1, 1)),
                       jnp.zeros(1, jnp.int32), jnp.zeros(1),
                       jnp.zeros(1, jnp.int32), jnp.zeros(1, jnp.int32),
                       jnp.zeros(1, bool), length=1, impl="warp")
