"""Sharding / dry-run machinery at CI scale.

The production 16x16 and 2x16x16 meshes are exercised by
``python -m repro.launch.dryrun`` (see EXPERIMENTS.md §Dry-run); here we
prove the same code path works end-to-end on a subprocess with 8
emulated host devices, plus unit-level checks of the rules and the HLO
collective parser.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.launch.roofline import parse_collective_bytes, RooflineTerms

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_dryrun(args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    return subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900)


@pytest.mark.slow
@pytest.mark.parametrize("arch,shape", [
    ("olmo-1b", "train_4k"),
    ("mamba2-130m", "long_500k"),
    ("granite-moe-3b-a800m", "prefill_32k"),
])
def test_dryrun_subprocess_small_mesh(arch, shape, tmp_path):
    r = _run_dryrun(["--arch", arch, "--shape", shape, "--mesh", "2,4",
                     "--out", str(tmp_path)])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK " in r.stdout
    files = [f for f in os.listdir(tmp_path) if f.endswith(".json")]
    assert files
    data = json.load(open(os.path.join(tmp_path, files[0])))
    rec = data[0]
    assert rec["status"] == "ok"
    assert rec["roofline"]["flops"] > 0
    assert rec["roofline"]["dominant"] in ("compute", "memory", "collective")


@pytest.mark.slow
def test_dryrun_multipod_axes_small():
    r = _run_dryrun(["--arch", "olmo-1b", "--shape", "train_4k",
                     "--mesh", "2,2,2"])
    assert r.returncode == 0, r.stdout + r.stderr
    assert "OK " in r.stdout


def test_collective_parser():
    hlo = """
  %ar = bf16[16,128] all-reduce(bf16[16,128] %x), replica_groups={}
  %ag = f32[4,256] all-gather(f32[4,64] %y), dimensions={1}
  %rs = f32[2,64] reduce-scatter(f32[2,256] %z), dimensions={1}
  %a2a = (s32[8], s32[8]) all-to-all(s32[8] %a, s32[8] %b)
  %cp.1 = bf16[32] collective-permute-start(bf16[32] %c)
  %cp.2 = bf16[32] collective-permute-done(bf16[32] %cpd)
  %normal = f32[8,8] dot(f32[8,8] %p, f32[8,8] %q)
"""
    got = parse_collective_bytes(hlo)
    assert got["all-reduce"] == 16 * 128 * 2
    assert got["all-gather"] == 4 * 256 * 4
    assert got["reduce-scatter"] == 2 * 64 * 4
    assert got["all-to-all"] == 8 * 4 * 2
    assert got["collective-permute"] == 32 * 2  # start counted, done skipped


def test_roofline_terms_math():
    t = RooflineTerms(flops=197e12 * 256, bytes_accessed=819e9 * 256,
                      collective_bytes=50e9 * 256, collective_by_op={},
                      chips=256, model_flops=197e12 * 128)
    assert t.t_compute == pytest.approx(1.0)
    assert t.t_memory == pytest.approx(1.0)
    assert t.t_collective == pytest.approx(1.0)
    assert t.useful_flops_ratio == pytest.approx(0.5)


def test_param_sharding_rules():
    from repro.configs.registry import get_config
    from repro.launch import mesh as mesh_lib
    from repro.models import model as MD
    from repro.models.params import shardings_for
    from jax.sharding import PartitionSpec as P

    mesh = mesh_lib.make_mesh((1, 1), ("data", "model"))
    cfg = get_config("qwen3-14b")
    sh = shardings_for(MD.build_param_specs(cfg), mesh, "fsdp_tp",
                       shard_kv_heads=False)
    # embed table: vocab x d_model -> ("model", "data") under fsdp_tp
    assert sh["embed"].spec == P("model", "data")
    # attention wq [D, H, dh]: fsdp over embed_in=data, heads over model
    assert sh["layers"]["attn"]["wq"].spec[1] == "data"
    assert sh["layers"]["attn"]["wq"].spec[2] == "model"
    # kv replicated when shard_kv_heads=False
    assert sh["layers"]["attn"]["wk"].spec[2] is None


def test_supports_shape_matrix():
    from repro.configs.registry import get_config, transformer_arch_ids
    from repro.models.model import supports_shape
    runs_500k = {a for a in transformer_arch_ids()
                 if supports_shape(get_config(a), "long_500k")[0]}
    assert runs_500k == {"gemma2_2b", "gemma2_27b", "mamba2_130m", "zamba2_1p2b"}
    for a in transformer_arch_ids():
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert supports_shape(get_config(a), s)[0], (a, s)
