"""Anytime execution engine: jnp engine vs numpy reference semantics."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import engine, orders
from repro.forest import train_forest


def _forest(n=400, f=8, c=4, trees=5, depth=4, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, f)).astype(np.float32)
    w = rng.normal(size=(f, c))
    y = np.argmax(X @ w, axis=1).astype(np.int64)
    rf = train_forest(X, y, c, n_trees=trees, max_depth=depth, seed=seed)
    return rf, X, y


def test_full_execution_matches_standard_forest():
    """After ALL steps, the anytime prediction == classic leaf-sum forest."""
    rf, X, y = _forest()
    fa = rf.as_arrays()
    dev = engine.to_device(fa)
    order = orders.depth_order(fa.n_trees, fa.max_depth)
    idx, _ = engine.run_order(dev, jnp.asarray(X), jnp.asarray(order))
    anytime_probs = np.asarray(engine.predict_from_state(dev, idx))
    classic = rf.predict_proba(X) * rf.n_trees
    assert np.allclose(anytime_probs, classic, atol=1e-4)


def test_order_permutation_invariance_of_final_state():
    """ANY valid order reaches the same final state (the design-space
    freedom the paper exploits)."""
    rf, X, y = _forest(trees=4, depth=3)
    fa = rf.as_arrays()
    dev = engine.to_device(fa)
    finals = []
    for seed in range(3):
        order = orders.random_order(fa.n_trees, fa.max_depth, seed=seed)
        idx, _ = engine.run_order(dev, jnp.asarray(X), jnp.asarray(order))
        finals.append(np.asarray(idx))
    assert (finals[0] == finals[1]).all() and (finals[1] == finals[2]).all()


def test_leaf_self_loop():
    """Stepping a tree already at a leaf is a no-op."""
    rf, X, y = _forest(trees=2, depth=2)
    fa = rf.as_arrays()
    dev = engine.to_device(fa)
    X_d = jnp.asarray(X)
    idx = engine.init_state(dev, X.shape[0])
    for _ in range(fa.max_depth + 3):  # overshoot
        idx = engine.tree_step(dev, X_d, idx, 0)
    idx2 = engine.tree_step(dev, X_d, idx, 0)
    assert (np.asarray(idx) == np.asarray(idx2)).all()


def test_paths_consistent_with_stepping():
    rf, X, y = _forest(trees=3, depth=3)
    fa = rf.as_arrays()
    dev = engine.to_device(fa)
    X_d = jnp.asarray(X)
    paths = np.asarray(engine.compute_paths(dev, X_d, fa.max_depth))
    idx = engine.init_state(dev, X.shape[0])
    for d in range(fa.max_depth + 1):
        assert (np.asarray(idx) == paths[:, :, d]).all()
        if d < fa.max_depth:
            for t in range(fa.n_trees):
                idx = engine.tree_step(dev, X_d, idx, t)


def test_accuracy_curve_matches_state_evaluator():
    """run_order's curve must equal StateEvaluator accuracies along the
    same trajectory (engine vs order-generator consistency)."""
    rf, X, y = _forest(trees=3, depth=3)
    fa = rf.as_arrays()
    dev = engine.to_device(fa)
    pp = engine.path_probs_np(fa, X)
    ev = orders.StateEvaluator(pp, y)
    order = orders.random_order(fa.n_trees, fa.max_depth, seed=7)
    _, curve = engine.run_order(dev, jnp.asarray(X), jnp.asarray(order), jnp.asarray(y))
    curve = np.asarray(curve)
    state = np.zeros(fa.n_trees, dtype=np.int64)
    assert curve[0] == pytest.approx(ev.accuracy(state), abs=1e-6)
    for k, t in enumerate(order):
        state[t] += 1
        assert curve[k + 1] == pytest.approx(ev.accuracy(state), abs=1e-6), k


def test_session_prefix_equals_run_order():
    from repro.core import AnytimeForest
    rf, X, y = _forest(trees=4, depth=3)
    fa = rf.as_arrays()
    order = orders.random_order(fa.n_trees, fa.max_depth, seed=1)
    af = AnytimeForest(fa, order)
    sess = af.session(X)
    sess.advance(5)
    # manual: run first 5 steps
    dev = engine.to_device(fa)
    idx = engine.init_state(dev, X.shape[0])
    for t in order[:5]:
        idx = engine.tree_step(dev, jnp.asarray(X), idx, int(t))
    assert (np.asarray(sess.idx) == np.asarray(idx)).all()
    # abort-time prediction is well-formed
    pred = sess.predict()
    assert pred.shape == (X.shape[0],)
