"""Tests for the repo-specific static-analysis suite (``tools.analyze``).

Each checker gets known-good / known-bad in-memory fixtures (paths pick
the layer: ``repro/serve/`` enables the lock rules, ``repro/kernels/``
the Pallas rules), plus subprocess tests asserting the CLI exits 0 on
the current tree and 1 on a seeded violation.

Pure stdlib on purpose — these tests must pass in the CI lint job where
JAX is not installed.
"""
from __future__ import annotations

import json
import subprocess
import sys
import textwrap
from pathlib import Path

from tools.analyze import SourceFile, analyze_sources

REPO = Path(__file__).resolve().parents[1]


def run_on(path: str, text: str):
    return analyze_sources([SourceFile(path, textwrap.dedent(text))])


def rules(findings, checker=None):
    return [f.rule for f in findings if checker is None or f.checker == checker]


# ---------------------------------------------------------------------------
# locks: lock-discipline race detector
# ---------------------------------------------------------------------------

LOCKS_GOOD = """
    import threading

    class Server:
        def __init__(self):
            self._lock = threading.RLock()
            self._cond = threading.Condition(self._lock)
            self._pending = {}  # guarded-by: _lock
            self.name = "s"  # unguarded: immutable after __init__

        def size(self):
            with self._lock:
                return len(self._pending)

        def wake(self):
            with self._cond:
                self._pending.clear()

        def via_alias(self):
            srv = self
            with srv._lock:
                return srv  # alias resolution exercises local_paths

        def peek(self):  # holds: _lock
            return self._pending.get(0)
"""


def test_locks_clean_class_has_no_findings():
    findings = run_on("src/repro/serve/fx_good.py", LOCKS_GOOD)
    assert findings == []


def test_locks_flags_guarded_access_outside_lock():
    bad = LOCKS_GOOD + """
        def racy(self):
            return self._pending.get(1)
    """
    findings = run_on("src/repro/serve/fx_bad.py", bad)
    assert rules(findings) == ["unguarded-access"]
    assert findings[0].symbol.startswith("Server._pending")


def test_locks_condition_alias_counts_as_holding_the_lock():
    # `wake` in the good fixture accesses _pending under `with self._cond`
    # where _cond wraps _lock; absence of findings above already proves
    # the alias — here prove a *non*-alias condition does NOT count.
    text = LOCKS_GOOD.replace(
        "threading.Condition(self._lock)", "threading.Condition()"
    )
    findings = run_on("src/repro/serve/fx_alias.py", text)
    assert rules(findings) == ["unguarded-access"]  # the access in wake()


def test_locks_requires_annotation_in_serve_layer_only():
    text = """
        class Thing:
            def __init__(self):
                self._count = 0
    """
    serve = run_on("src/repro/serve/fx_unannotated.py", text)
    assert rules(serve) == ["unannotated-field"]
    elsewhere = run_on("src/repro/core/fx_unannotated.py", text)
    assert elsewhere == []


def test_locks_annotation_may_sit_on_any_line_of_the_statement():
    text = """
        import threading

        class S:
            def __init__(self):
                self._lock = threading.Lock()
                self._hist = dict(
                    a=1,
                )  # guarded-by: _lock

            def read(self):
                with self._lock:
                    return self._hist
    """
    assert run_on("src/repro/serve/fx_multiline.py", text) == []


# ---------------------------------------------------------------------------
# traces: jit trace-budget checker
# ---------------------------------------------------------------------------

TRACES_HEADER = """
    from functools import partial

    import jax

    @partial(jax.jit, static_argnums=(1,))
    def run_fused(x, length):
        return x
"""


def test_traces_flags_unbucketed_length():
    text = TRACES_HEADER + """
    def bad(x, items):
        n = len(items)
        return run_fused(x, n)
    """
    findings = run_on("src/repro/schedule/fx_traces.py", text)
    assert rules(findings) == ["unbucketed-length"]
    assert "run_fused" in findings[0].message


def test_traces_accepts_bucketed_and_forwarded_lengths():
    text = TRACES_HEADER + """
    def good(x, n, length):
        L = pow2_floor(n)
        run_fused(x, L)
        run_fused(x, 8)
        run_fused(x, pow2_floor(n))
        run_fused(x, length)  # forwarding: caller checked at its site
        for p in pow2_decompose(n):
            run_fused(x, p)
    """
    assert run_on("src/repro/schedule/fx_traces_ok.py", text) == []


def test_traces_follows_instance_alias_of_jitted_fn():
    text = TRACES_HEADER + """
    class Exec:
        def __init__(self):
            self._fused_jit = run_fused

        def go(self, x, items):
            return self._fused_jit(x, length=len(items))
    """
    findings = run_on("src/repro/schedule/fx_traces_alias.py", text)
    assert rules(findings) == ["unbucketed-length"]


def test_traces_flags_jit_inside_loop():
    text = """
        import jax

        def retrace(xs):
            outs = []
            for x in xs:
                f = jax.jit(lambda v: v + 1)
                outs.append(f(x))
            return outs

        def fine(xs):
            f = jax.jit(lambda v: v + 1)
            return [f(x) for x in xs]
    """
    findings = run_on("src/repro/schedule/fx_loop.py", text)
    assert rules(findings) == ["jit-in-loop"]


# ---------------------------------------------------------------------------
# vmem: Pallas kernel hygiene
# ---------------------------------------------------------------------------

KERNEL_HEADER = """
    from jax.experimental import pallas as pl

    def _copy_kernel(x_ref, o_ref):
        o_ref[...] = x_ref[...]
"""


def test_vmem_flags_oversized_resident_blockspec():
    text = KERNEL_HEADER + """
    def big(x):
        return pl.pallas_call(
            _copy_kernel,
            grid=(4,),
            in_specs=[pl.BlockSpec((2048, 2048), lambda b: (0, 0))],
            out_specs=pl.BlockSpec((128, 128), lambda b: (b, 0)),
        )(x)
    """
    findings = run_on("src/repro/kernels/fx_big.py", text)
    assert rules(findings) == ["oversized-resident"]  # 16 MiB > 4 MiB budget


def test_vmem_streamed_blockspec_is_not_resident():
    text = KERNEL_HEADER + """
    def streamed(x):
        return pl.pallas_call(
            _copy_kernel,
            grid=(4,),
            in_specs=[pl.BlockSpec((2048, 2048), lambda b: (b, 0))],
            out_specs=pl.BlockSpec((128, 128), lambda b: (b, 0)),
        )(x)
    """
    assert run_on("src/repro/kernels/fx_streamed.py", text) == []


def test_vmem_symbolic_resident_needs_guarded_callers():
    body = KERNEL_HEADER + """
    def entry(x, M):
        return pl.pallas_call(
            _copy_kernel,
            grid=(4,),
            in_specs=[pl.BlockSpec((M, 8), lambda b: (0, 0))],
            out_specs=pl.BlockSpec((8, 8), lambda b: (b, 0)),
        )(x)

    def caller(x, M):
        {guard}return entry(x, M)
    """
    unguarded = run_on(
        "src/repro/kernels/fx_sym.py", body.format(guard="")
    )
    assert rules(unguarded) == ["missing-budget-guard"]
    assert "caller" in unguarded[0].message

    guard = "if not _tables_fit(M):\n            return x\n        "
    guarded = run_on("src/repro/kernels/fx_sym.py", body.format(guard=guard))
    assert guarded == []


def test_vmem_flags_tracer_control_flow_in_kernel_body():
    text = """
        from jax.experimental import pallas as pl

        def _branchy_kernel(x_ref, o_ref):
            v = x_ref[0]
            if v > 0:
                o_ref[0] = v

        def use(x):
            return pl.pallas_call(
                _branchy_kernel,
                out_specs=pl.BlockSpec((8,), lambda b: (b,)),
            )(x)
    """
    findings = run_on("src/repro/kernels/fx_branch.py", text)
    assert rules(findings) == ["tracer-control-flow"]
    assert "_branchy_kernel" in findings[0].message


def test_vmem_static_params_in_kernel_body_are_fine():
    text = """
        from jax.experimental import pallas as pl

        def _static_kernel(x_ref, o_ref, *, length):
            for _ in range(length):  # static python param: unrolls at trace
                o_ref[...] = x_ref[...]

        def use(x):
            return pl.pallas_call(
                _static_kernel,
                out_specs=pl.BlockSpec((8,), lambda b: (b,)),
            )(x)
    """
    findings = run_on("src/repro/kernels/fx_static.py", text)
    assert "tracer-control-flow" not in rules(findings)


# ---------------------------------------------------------------------------
# registry: registration coherence
# ---------------------------------------------------------------------------


def test_registry_flags_duplicate_names_including_loop_families():
    text = """
        __all__ = ["P"]

        class P:
            \"\"\"doc.\"\"\"

        NAMES = ("a", "b")
        for _n in NAMES:
            register_order(f"fam_{_n}")(P)
        register_order("fam_a")(P)
    """
    findings = run_on("src/repro/schedule/fx_reg_dup.py", text)
    assert rules(findings) == ["duplicate-name"]
    assert "fam_a" in findings[0].message


def test_registry_flags_missing_docstring_and_export():
    text = """
        __all__ = []

        @register_backend("x")
        class C:
            pass
    """
    findings = run_on("src/repro/schedule/fx_reg_doc.py", text)
    assert sorted(rules(findings)) == ["missing-docstring", "missing-export"]


def test_registry_flags_module_without_all():
    text = """
        @register_order("y")
        class D:
            \"\"\"doc.\"\"\"
    """
    findings = run_on("src/repro/schedule/fx_reg_all.py", text)
    assert rules(findings) == ["missing-all"]


def test_registry_clean_module_passes():
    text = """
        __all__ = ["E"]

        @register_order("z")
        class E:
            \"\"\"doc.\"\"\"
    """
    assert run_on("src/repro/schedule/fx_reg_ok.py", text) == []


def test_registry_covers_kernel_impl_registrations():
    """The tuning impl registries are first-class registration sites:
    duplicate impl names collide, undocumented impls are flagged, and
    underscore-private adapters are exempt from the export checks (they
    are reached through the registry, never imported)."""
    text = """
        @tuning.register_solo_impl("warp")
        def _warp(idx):
            \"\"\"doc.\"\"\"

        @tuning.register_solo_impl("warp")
        def _warp2(idx):
            pass

        @tuning.register_slot_impl("warp")
        def _slot_warp(idx):
            \"\"\"doc (same name, different registry kind: no clash).\"\"\"
    """
    findings = run_on("src/repro/kernels/fx_impl_reg.py", text)
    assert sorted(rules(findings)) == ["duplicate-name", "missing-docstring"]
    # no missing-all/missing-export: every target is private


def test_registry_covers_admission_registrations():
    """Admission policies register like every other named family: the
    checker sees @register_admission sites, so duplicate policy names,
    undocumented policies, and unexported public policies are flagged."""
    text = """
        __all__ = ["Good"]

        @register_admission("fx-adm")
        class Good:
            \"\"\"doc.\"\"\"

        @register_admission("fx-adm")
        class Clash:
            pass
    """
    findings = run_on("src/repro/serve/fx_adm_reg.py", text)
    assert sorted(rules(findings)) == [
        "duplicate-name", "missing-docstring", "missing-export"]


# ---------------------------------------------------------------------------
# obs: tracing-call hygiene
# ---------------------------------------------------------------------------

OBS_NAMES_FIXTURE = """
    SPAN_NAMES = {
        "serve.dispatch": "one fused-segment dispatch",
        "serve.submit": "request entered the queue",
    }
"""


def test_obs_span_must_be_a_with_item():
    text = """
        def ok(tracer):
            with tracer.span("serve.dispatch") as sp:
                return sp

        def bad(tracer):
            sp = tracer.span("serve.dispatch")
            return sp
    """
    findings = run_on("src/repro/serve/fx_obs_span.py", text)
    assert rules(findings, "obs") == ["span-without-with"]


def test_obs_flags_tracing_inside_kernel_bodies():
    text = """
        from repro.obs import annotate as _obs_annotate

        def _traced_kernel(x_ref, o_ref, tracer):
            tracer.instant("serve.dispatch")
            _obs_annotate(impl="slot")
            o_ref[...] = x_ref[...]

        def dispatch_layer(tracer):
            # the same calls OUTSIDE a kernel body are the intended
            # instrumentation points
            tracer.instant("serve.dispatch")
            _obs_annotate(impl="slot")
    """
    findings = run_on("src/repro/kernels/fx_obs_kernel.py", text)
    assert rules(findings, "obs") == ["trace-in-kernel", "trace-in-kernel"]
    assert all("_traced_kernel" in f.message for f in findings
               if f.checker == "obs")
    # outside the kernels layer the same function is not a kernel body
    assert rules(
        run_on("src/repro/serve/fx_obs_kernel.py", text), "obs") == []


def test_obs_span_names_checked_against_registry_when_present():
    use = """
        def f(tracer):
            tracer.instant("serve.unknown")
            tracer.instant("serve.submit")
            with tracer.span("serve.dispatch"):
                pass
    """
    findings = analyze_sources([
        SourceFile("src/repro/obs/names.py",
                   textwrap.dedent(OBS_NAMES_FIXTURE)),
        SourceFile("src/repro/serve/fx_obs_names.py", textwrap.dedent(use)),
    ])
    assert rules(findings, "obs") == ["unknown-span-name"]
    assert "serve.unknown" in findings[-1].message
    # without the registry in the file set, the rule stays silent
    assert rules(run_on("src/repro/serve/fx_obs_names.py", use), "obs") == []


def test_obs_ignores_non_tracer_receivers():
    text = """
        def f(doc, tracer):
            doc.span("whatever")           # not a tracer receiver
            events = tracer.events()       # not a recording call
            return doc.span, events
    """
    assert rules(run_on("src/repro/serve/fx_obs_recv.py", text), "obs") == []


# ---------------------------------------------------------------------------
# CLI / end-to-end
# ---------------------------------------------------------------------------

BAD_TREE_FILE = textwrap.dedent(
    """
    import threading

    class S:
        def __init__(self):
            self._lock = threading.Lock()
            self._q = []  # guarded-by: _lock

        def racy(self):
            return len(self._q)
    """
)


def _analyze(*args, cwd=REPO):
    return subprocess.run(
        [sys.executable, "-m", "tools.analyze", *args],
        cwd=cwd,
        capture_output=True,
        text=True,
    )


def test_cli_exits_zero_on_current_tree():
    proc = _analyze("--baseline", "analyze-baseline.json")
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "0 finding(s)" in proc.stdout


def test_cli_exits_nonzero_on_seeded_violation(tmp_path):
    bad = tmp_path / "repro" / "serve" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text(BAD_TREE_FILE)
    proc = _analyze("--root", str(tmp_path), "--json")
    assert proc.returncode == 1, proc.stdout + proc.stderr
    payload = json.loads(proc.stdout)
    assert [f["rule"] for f in payload["findings"]] == ["unguarded-access"]


def test_cli_baseline_suppresses_and_reports_stale(tmp_path):
    bad = tmp_path / "repro" / "serve" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text(BAD_TREE_FILE)
    proc = _analyze("--root", str(tmp_path), "--json")
    key = json.loads(proc.stdout)["findings"][0]["key"]

    baseline = tmp_path / "baseline.json"
    baseline.write_text(
        json.dumps(
            {"findings": [
                {"key": key, "justification": "test fixture"},
                {"key": "locks:gone:x:y", "justification": "stale"},
            ]}
        )
    )
    proc = _analyze("--root", str(tmp_path), "--baseline", str(baseline))
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "1 baseline-suppressed" in proc.stdout
    assert "stale" in proc.stderr


def test_analyzer_imports_without_jax():
    code = (
        "import sys\n"
        "import tools.analyze\n"
        "from tools.analyze import cli, core, locks, obs, registry, traces, vmem\n"
        "from tools.obs import cli as obs_cli, report, schema\n"
        "assert 'jax' not in sys.modules, 'analyzer must not import jax'\n"
        "assert 'numpy' not in sys.modules, 'analyzer must stay stdlib-only'\n"
    )
    proc = subprocess.run(
        [sys.executable, "-c", code], cwd=REPO, capture_output=True, text=True
    )
    assert proc.returncode == 0, proc.stderr
