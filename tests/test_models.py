"""Per-architecture smoke tests (task deliverable f).

Each assigned arch: instantiate the REDUCED variant (<=2 layers,
d_model<=512, <=4 experts), run one forward + one train step on CPU,
assert output shapes and no NaNs; decode-capable archs also run one
serve (prefill + decode) step.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config, transformer_arch_ids
from repro.configs.shapes import InputShape
from repro.models import model as MD
from repro.models import transformer as T
from repro.training import optimizer as opt_lib
from repro.training.train import train_step_fn

ARCHS = transformer_arch_ids()
KEY = jax.random.PRNGKey(0)
SMOKE = InputShape("smoke", 32, 2, "train")


@pytest.fixture(scope="module")
def built():
    out = {}
    for arch in ARCHS:
        cfg = get_config(arch, reduced=True)
        out[arch] = (cfg, MD.init(cfg, KEY))
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(built, arch):
    cfg, params = built[arch]
    batch = MD.make_batch(cfg, SMOKE, KEY)
    logits, aux = T.forward(cfg, params, batch)
    S_expect = batch["tokens"].shape[1] + (cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (SMOKE.global_batch, S_expect, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_updates_and_finite(built, arch):
    cfg, params = built[arch]
    batch = MD.make_batch(cfg, SMOKE, KEY)
    ocfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    step = train_step_fn(cfg, ocfg)
    opt = opt_lib.init_state(params)
    new_params, new_opt, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(new_opt.step) == 1
    # at least one leaf changed
    changed = any(
        not np.allclose(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(new_params)))
    assert changed


@pytest.mark.parametrize("arch", ARCHS)
def test_serve_step_shapes(built, arch):
    cfg, params = built[arch]
    shp = InputShape("smoke_prefill", 16, 2, "prefill")
    batch = MD.make_batch(cfg, shp, KEY)
    logits, _, cache = T.forward(cfg, params, batch, return_cache=True,
                                 cache_len=24)
    assert logits.shape[-1] == cfg.vocab_size
    tok = jnp.zeros((2, 1), jnp.int32)
    dl, cache2 = T.decode_step(cfg, params, cache, tok)
    assert dl.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(dl).all())
    assert int(cache2.pos) == int(cache.pos) + 1


def test_reduced_configs_within_limits():
    for arch in ARCHS:
        cfg = get_config(arch, reduced=True)
        assert cfg.num_layers <= 5, arch
        assert cfg.d_model <= 512, arch
        if cfg.num_experts:
            assert cfg.num_experts <= 4, arch


def test_full_configs_match_assignment():
    """Spot-check the exact assigned hyperparameters."""
    c = get_config("gemma2-2b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.d_ff, c.vocab_size) == (26, 2304, 8, 4, 9216, 256000)
    c = get_config("qwen3-moe-235b-a22b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
            c.num_experts, c.top_k) == (94, 4096, 64, 4, 128, 8)
    assert c.qk_norm
    c = get_config("mamba2-130m")
    assert (c.num_layers, c.d_model, c.ssm_state) == (24, 768, 128)
    c = get_config("zamba2-1.2b")
    assert (c.num_layers, c.d_model, c.ssm_state) == (38, 2048, 64)
    c = get_config("whisper-medium")
    assert (c.num_layers, c.encoder_layers, c.d_model, c.vocab_size) == (24, 24, 1024, 51865)
    c = get_config("internvl2-26b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads) == (48, 6144, 48, 8)
    c = get_config("gemma2-27b")
    assert (c.num_layers, c.d_model, c.d_ff) == (46, 4608, 36864)
    c = get_config("olmo-1b")
    assert c.norm_type == "layernorm_nonparam"


def test_param_count_sane():
    """Analytic 6ND param counts should be near the nameplate sizes."""
    approx = {
        "gemma2-2b": 2.6e9, "gemma2-27b": 27e9, "qwen3-14b": 14e9,
        "mamba2-130m": 0.13e9, "olmo-1b": 1.2e9, "zamba2-1.2b": 1.2e9,
        "qwen3-moe-235b-a22b": 235e9,
    }
    from repro.models.model import exact_param_count
    for name, target in approx.items():
        cfg = get_config(name)
        n_exact = exact_param_count(cfg)
        assert 0.4 * target < n_exact < 2.1 * target, (name, n_exact, target)
        # analytic estimate tracks the exact count
        assert 0.7 * n_exact < cfg.param_count() < 1.3 * n_exact, name
