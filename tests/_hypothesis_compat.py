"""Import ``given``/``settings``/``st`` from hypothesis when available,
else fall back to a deterministic sampler so the tier-1 suite collects
and runs without the dependency installed.

The fallback covers exactly what the suite uses: ``@settings(...)``
stacked on ``@given(**kwargs)`` with ``st.integers(lo, hi)`` strategies.
Each wrapped test runs ``max_examples`` times on values drawn from a
PRNG seeded from the test name (stable across runs and processes).
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Integers:
        def __init__(self, min_value, max_value):
            self.lo, self.hi = int(min_value), int(max_value)

        def sample(self, rng) -> int:
            return int(rng.integers(self.lo, self.hi + 1))

    class st:  # noqa: N801 — mirrors `strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

    class settings:  # noqa: N801
        def __init__(self, max_examples=10, **_ignored):
            self.max_examples = max_examples

        def __call__(self, fn):
            fn._compat_max_examples = self.max_examples
            return fn

    def given(**strategies):
        def deco(fn):
            # No functools.wraps: it would set __wrapped__ and pytest
            # would then mistake the drawn parameters for fixtures.
            def wrapper():
                n = getattr(wrapper, "_compat_max_examples", 10)
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(n):
                    drawn = {k: s.sample(rng) for k, s in strategies.items()}
                    fn(**drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper

        return deco
