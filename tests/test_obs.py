"""Cross-layer tracing (`repro.obs`): tracer semantics, deadline-budget
attribution, Chrome-trace export + schema round-trip, traced-server
completeness on all three backends, tracer thread-safety under the
driver, and the ServeMetrics percentile edge cases.

The thread-safety cases here ride the CI ``thread-stress`` loop next to
``test_serve_driver.py`` — keep them deterministic under repetition
(generous deadlines, explicit timeouts)."""
import json
import subprocess
import sys
import threading
from pathlib import Path

import numpy as np
import pytest

from repro.core import engine
from repro.forest import make_dataset, split_dataset, train_forest
from repro.obs import (
    ATTRIBUTION_FIELDS,
    NULL_TRACER,
    SPAN_NAMES,
    Tracer,
    annotate,
    current_span,
    export_chrome_trace,
    segment_histograms,
    tracing_active,
    write_chrome_trace,
)
from repro.obs.attribution import summarize
from repro.schedule import AnytimeRuntime, ForestProgram
from repro.serve import AnytimeServer
from repro.serve.metrics import ServeMetrics

WAIT_S = 120.0


class ManualClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt


@pytest.fixture(scope="module")
def pipeline():
    X, y = make_dataset("magic", seed=1)
    (tr, ytr), (orx, yor), (te, yte) = split_dataset(X, y, seed=1)
    rf = train_forest(tr[:800], ytr[:800], 2, n_trees=4, max_depth=5, seed=1)
    fa = rf.as_arrays()
    pp = engine.path_probs_np(fa, orx[:200])
    return fa, pp, yor[:200], te, yte


@pytest.fixture(scope="module")
def runtime(pipeline):
    fa, pp, yor, te, yte = pipeline
    return AnytimeRuntime(
        ForestProgram(fa, y_order=yor, path_probs=pp, X_order=te[:8]))


# ---------------------------------------------------------------------------
# Tracer semantics
# ---------------------------------------------------------------------------


def test_span_records_interval_args_and_upward_annotation():
    clock = ManualClock()
    tr = Tracer(clock=clock)
    with tr.span("serve.dispatch", track="lane0", stepped=True) as sp:
        assert current_span() is sp
        clock.advance(0.25)
        annotate(impl="slot_v2", compile=False)  # a lower layer reporting up
    assert current_span() is None
    (ev,) = tr.events()
    assert ev.name == "serve.dispatch" and ev.ph == "X"
    assert ev.t0 == 0.0 and ev.t1 == 0.25 and ev.dur_s == 0.25
    assert ev.track == "lane0"
    assert ev.args == {"stepped": True, "impl": "slot_v2", "compile": False}
    tr.disable()


def test_annotate_targets_innermost_nested_span():
    tr = Tracer()
    with tr.span("serve.step") as outer:
        with tr.span("serve.dispatch") as inner:
            annotate(backend="pallas")
        annotate(seq=7)
    assert inner.args == {"backend": "pallas"}
    assert outer.args == {"seq": 7}
    tr.disable()


def test_span_survives_exception_and_still_records():
    tr = Tracer()
    with pytest.raises(RuntimeError):
        with tr.span("serve.harvest"):
            raise RuntimeError("boom")
    (ev,) = tr.events()
    assert ev.t1 is not None
    assert current_span() is None  # stack unwound cleanly
    tr.disable()


def test_strict_mode_rejects_unregistered_names():
    tr = Tracer()
    with pytest.raises(ValueError, match="unregistered"):
        tr.span("serve.bogus")
    with pytest.raises(ValueError, match="unregistered"):
        tr.instant("serve.bogus")
    tr.disable()
    assert Tracer(strict=False, enabled=False) is not None  # opt-out exists


def test_counter_and_instant_shapes():
    tr = Tracer(margins=True)
    tr.instant("serve.submit", request_id=3)
    tr.counter("serve.margin", 0.75, track="lane", request_id=3, steps=4)
    inst, ctr = tr.events()
    assert inst.ph == "i" and inst.args["request_id"] == 3
    assert ctr.ph == "C" and ctr.cat == "quality"
    assert ctr.args["value"] == 0.75 and ctr.args["steps"] == 4
    tr.disable()


def test_ring_bound_evicts_oldest_and_counts_drops():
    tr = Tracer(capacity=4)
    for i in range(10):
        tr.instant("serve.submit", i=i)
    events = tr.events()
    assert len(events) == 4
    assert [e.args["i"] for e in events] == [6, 7, 8, 9]
    assert tr.dropped == 6
    tr.disable()


def test_disabled_tracer_and_global_flag():
    base = tracing_active()
    tr = Tracer(enabled=False)
    with tr.span("serve.step") as sp:
        assert sp is None          # the reusable null context
    tr.instant("serve.submit")
    assert tr.events() == [] and tracing_active() == base
    tr.enable()
    assert tracing_active()
    tr.disable()
    assert tracing_active() == base


def test_null_tracer_is_hard_noop_and_unenablable():
    with NULL_TRACER.span("anything-goes"):   # no strict check, no record
        pass
    NULL_TRACER.instant("whatever")
    assert NULL_TRACER.events() == []
    assert not NULL_TRACER.enabled
    with pytest.raises(RuntimeError):
        NULL_TRACER.enable()


# ---------------------------------------------------------------------------
# Deadline-budget attribution accounting
# ---------------------------------------------------------------------------


def test_attribution_lifecycle_components_sum():
    clock = ManualClock()
    tr = Tracer(clock=clock)
    tr.request_submitted(1, clock(), "forest")
    tr.request_admission(1, "edf", backlog=2, budget=20)
    clock.advance(0.010)                       # 10 ms queued
    tr.request_slot(1, clock(), "forest:backward_squirrel:jnp-ref", "jnp-ref")
    tr.account([1], "compile", 0.050)
    tr.account([1], "dispatch", 0.030)
    tr.account([1], "harvest", 0.005)
    clock.advance(0.100)                       # 100 ms in flight
    attr = tr.request_delivered(1, clock(), steps=20, total_steps=20,
                                deadline_hit=True)
    assert attr.queue_ms == pytest.approx(10.0)
    assert attr.compile_ms == pytest.approx(50.0)
    assert attr.dispatch_ms == pytest.approx(30.0)
    assert attr.harvest_ms == pytest.approx(5.0)
    assert attr.slack_ms == pytest.approx(15.0)   # 100 - 85 accounted
    assert attr.latency_ms == pytest.approx(110.0)
    assert attr.check()
    assert sum(attr.components().values()) == pytest.approx(attr.latency_ms)
    assert attr.decision == "edf" and attr.backlog == 2
    assert list(tr.attributions) == [attr]
    tr.disable()


def test_attribution_never_admitted_is_pure_queue_wait():
    clock = ManualClock()
    tr = Tracer(clock=clock)
    tr.request_submitted(5, clock(), "forest")
    clock.advance(0.200)
    attr = tr.request_delivered(5, clock(), steps=0, total_steps=20,
                                deadline_hit=False)
    assert attr.t_admit is None and attr.lane is None
    assert attr.queue_ms == pytest.approx(200.0)
    assert attr.slack_ms == 0.0 and attr.dispatch_ms == 0.0
    assert attr.check()
    tr.disable()


def test_attribution_slack_never_negative():
    clock = ManualClock()
    tr = Tracer(clock=clock)
    tr.request_submitted(2, clock(), "forest")
    tr.request_slot(2, clock(), "lane", "jnp-ref")
    # over-account relative to the in-flight window (clock never moved)
    tr.account([2], "dispatch", 1.0)
    attr = tr.request_delivered(2, clock(), steps=1, total_steps=2,
                                deadline_hit=True)
    assert attr.slack_ms == 0.0
    tr.disable()


def test_summarize_well_defined_at_zero_and_one():
    empty = summarize([])
    assert empty["count"] == 0 and empty["sum_check_fail"] == 0
    assert empty["mean_latency_ms"] == 0.0
    for f in ATTRIBUTION_FIELDS:
        assert empty[f"mean_{f}"] == 0.0

    clock = ManualClock()
    tr = Tracer(clock=clock)
    tr.request_submitted(1, clock(), "p")
    clock.advance(0.05)
    tr.request_delivered(1, clock(), 0, 10, False)
    one = summarize(tr.attributions)
    assert one["count"] == 1
    assert one["mean_queue_ms"] == pytest.approx(50.0)
    assert one["sum_check_fail"] == 0
    tr.disable()


# ---------------------------------------------------------------------------
# Export + schema round-trip
# ---------------------------------------------------------------------------


def _tiny_traced_run():
    clock = ManualClock()
    tr = Tracer(clock=clock, margins=True)
    tr.request_submitted(1, clock(), "forest")
    tr.request_admission(1, "edf", 0, None)
    tr.instant("serve.submit", request_id=1)
    clock.advance(0.001)
    tr.request_slot(1, clock(), "laneA", "jnp-ref")
    with tr.span("serve.dispatch", track="laneA") as sp:
        annotate(backend="jnp-ref", impl="jnp-ref", length=4, compile=True)
        clock.advance(0.004)
    tr.account([1], "compile", sp.dur_s)
    with tr.span("serve.dispatch", track="laneA") as sp:
        annotate(backend="jnp-ref", impl="jnp-ref", length=4, compile=False)
        clock.advance(0.002)
    tr.account([1], "dispatch", sp.dur_s)
    tr.counter("serve.margin", 0.5, track="laneA", request_id=1, steps=4)
    attr = tr.request_delivered(1, clock(), 4, 4, True)
    tr.instant("serve.deliver", request_id=1, **attr.components())
    return tr


def test_export_chrome_trace_structure():
    tr = _tiny_traced_run()
    doc = export_chrome_trace(tr, meta={"test": True})
    tr.disable()
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    meta_names = [e["args"]["name"] for e in evs if e["ph"] == "M"]
    assert "repro.serve" in meta_names and "laneA" in meta_names
    xs = [e for e in evs if e["ph"] == "X"]
    assert len(xs) == 2 and all(e["dur"] > 0 and e["ts"] >= 0 for e in xs)
    lane_tids = {e["tid"] for e in evs
                 if e["ph"] == "M" and e["args"]["name"] == "laneA"}
    assert {e["tid"] for e in xs} == lane_tids  # tracked events share a tid
    assert all(e["s"] == "t" for e in evs if e["ph"] == "i")
    other = doc["otherData"]
    assert other["attribution_fields"] == list(ATTRIBUTION_FIELDS)
    assert len(other["attributions"]) == 1 and other["dropped"] == 0
    assert other["meta"] == {"test": True}
    hist = other["segment_histograms"]["jnp-ref/jnp-ref/L4"]
    assert hist["count"] == 1 and hist["compile_count"] == 1
    assert hist["mean_ms"] == pytest.approx(2.0)
    assert hist["compile_mean_ms"] == pytest.approx(4.0)


def test_exported_trace_validates_against_committed_schema(tmp_path):
    from tools.obs import report as obs_report
    from tools.obs import schema as obs_schema

    tr = _tiny_traced_run()
    path = tmp_path / "trace.json"
    doc = write_chrome_trace(tr, path)
    tr.disable()
    schema = obs_report.load_schema()
    assert obs_schema.validate(doc, schema) == []
    reloaded = json.loads(path.read_text())
    assert obs_report.check(reloaded, schema) == []     # full CI gate
    # tools.obs recomputes the histograms from raw events and must agree
    fresh = obs_report.segment_histograms(reloaded["traceEvents"])
    assert fresh == doc["otherData"]["segment_histograms"]


def test_schema_validator_subset_semantics():
    from tools.obs.schema import SchemaError, validate

    schema = {
        "type": "object",
        "required": ["a"],
        "additionalProperties": False,
        "properties": {
            "a": {"type": "integer", "minimum": 0},
            "b": {"type": ["string", "null"]},
            "c": {"type": "array", "items": {"enum": ["x", "y"]},
                  "minItems": 1},
        },
    }
    assert validate({"a": 1, "b": None, "c": ["x"]}, schema) == []
    assert validate({"a": True}, schema)          # bool is NOT an integer
    assert validate({}, schema)                   # missing required
    assert validate({"a": 0, "z": 1}, schema)     # additionalProperties
    assert validate({"a": 0, "c": []}, schema)    # minItems
    assert validate({"a": 0, "c": ["z"]}, schema)  # enum
    with pytest.raises(SchemaError):
        validate({}, {"patternProperties": {}})   # unsupported keyword
    ref_schema = {
        "definitions": {"pos": {"type": "number", "minimum": 0}},
        "type": "object",
        "properties": {"v": {"$ref": "#/definitions/pos"}},
    }
    assert validate({"v": 2.5}, ref_schema) == []
    assert validate({"v": -1}, ref_schema)


def test_tools_obs_mirror_of_attribution_fields():
    from tools.obs import report as obs_report

    assert tuple(obs_report.ATTRIBUTION_FIELDS) == tuple(ATTRIBUTION_FIELDS)


def test_committed_sample_passes_the_gate():
    from tools.obs import report as obs_report

    doc = obs_report.load_trace(obs_report.SAMPLE_PATH)
    schema = obs_report.load_schema()
    assert obs_report.check(doc, schema) == []
    assert obs_report.render_report(doc)  # renders without raising


# ---------------------------------------------------------------------------
# Traced server end to end: every delivered ticket attributes, on all
# three backends (the tentpole acceptance criterion)
# ---------------------------------------------------------------------------


BACKEND_OPTS = {
    "jnp-ref": {},
    "pallas": {"block_b": 16, "block_m": 8},
    "sharded": {},
}


@pytest.mark.parametrize("backend", ["jnp-ref", "pallas", "sharded"])
def test_traced_server_complete_attribution(backend, runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    tracer = Tracer(margins=True)
    with AnytimeServer(runtime, capacity=3, tracer=tracer,
                       backend_opts=BACKEND_OPTS[backend]) as server:
        tickets = [server.submit(te[i], 60_000.0, backend=backend)
                   for i in range(7)]
        results = [t.result(timeout=WAIT_S) for t in tickets]
    tracer.disable()
    assert all(r.deadline_hit for r in results)

    by_id = {a.request_id: a for a in tracer.attributions}
    assert len(by_id) == len(tickets)           # exactly one per ticket
    for t, r in zip(tickets, results):
        a = by_id[t.request_id]
        assert a.check(), a.format()
        assert a.steps == r.steps_completed
        assert a.backend == backend and a.lane and a.decision == "edf"
        assert a.t_admit is not None and a.compile_ms >= 0.0

    events = tracer.events()
    deliver_ids = [e.args["request_id"] for e in events
                   if e.name == "serve.deliver"]
    assert sorted(deliver_ids) == sorted(by_id)  # one deliver instant each
    dispatches = [e for e in events if e.name == "serve.dispatch"]
    assert dispatches
    for d in dispatches:
        assert d.args.get("backend") == backend
        assert "impl" in d.args and "length" in d.args
        assert d.t1 is not None
    # the calibration table has cells for this backend, and at least one
    # jit compile was tabulated separately from steady state
    hist = segment_histograms(events)
    assert hist and all(k.startswith(backend + "/") for k in hist)
    assert sum(row["compile_count"] for row in hist.values()) >= 1
    # margin telemetry: the online confidence curve, per request
    margin_ids = {e.args["request_id"] for e in events
                  if e.name == "serve.margin"}
    assert margin_ids and margin_ids <= set(by_id)
    # the metrics surface carries the same accounting
    snap = server.metrics.snapshot()
    assert snap["attribution"]["count"] == len(tickets)
    assert snap["attribution"]["sum_check_fail"] == 0
    assert snap["attribution"]["complete"] == len(tickets)


def test_tracer_thread_safety_concurrent_submitters(runtime, pipeline):
    """Multiple submitter threads + the driver thread share one ring:
    no torn spans, every delivered ticket attributes exactly once."""
    fa, pp, yor, te, yte = pipeline
    tracer = Tracer(margins=True)
    n_threads, per_thread = 4, 6
    all_tickets = []
    tick_lock = threading.Lock()
    with AnytimeServer(runtime, capacity=3, tracer=tracer) as server:
        def submitter(k):
            mine = [server.submit(te[(k * per_thread + j) % te.shape[0]],
                                  60_000.0)
                    for j in range(per_thread)]
            with tick_lock:
                all_tickets.extend(mine)

        threads = [threading.Thread(target=submitter, args=(k,))
                   for k in range(n_threads)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        results = [t.result(timeout=WAIT_S) for t in all_tickets]
    tracer.disable()
    assert len(results) == n_threads * per_thread

    events = tracer.events()
    for ev in events:                       # no torn/incomplete events
        assert ev.ph in ("X", "i", "C")
        assert ev.t1 is not None and ev.t1 >= ev.t0
    deliver_ids = sorted(e.args["request_id"] for e in events
                         if e.name == "serve.deliver")
    assert deliver_ids == sorted(t.request_id for t in all_tickets)
    by_id = {a.request_id: a for a in tracer.attributions}
    assert sorted(by_id) == deliver_ids
    assert all(a.check() for a in by_id.values())


def test_tracer_survives_stop_midflight(runtime, pipeline):
    """stop() drains in-flight slots; every admitted ticket still gets
    answered AND attributed, and the ring holds only complete events."""
    fa, pp, yor, te, yte = pipeline
    tracer = Tracer()
    server = AnytimeServer(runtime, capacity=2, tracer=tracer)
    server.start()
    tickets = [server.submit(te[i], 60_000.0) for i in range(6)]
    server.stop()                      # mid-flight: no drain() first
    tracer.disable()
    assert all(t.done for t in tickets)
    by_id = {a.request_id: a for a in tracer.attributions}
    assert sorted(by_id) == sorted(t.request_id for t in tickets)
    assert all(a.check() for a in by_id.values())
    assert all(e.t1 is not None for e in tracer.events())


# ---------------------------------------------------------------------------
# ServeMetrics percentile edge cases (satellite regression tests)
# ---------------------------------------------------------------------------


class _FakeResult:
    def __init__(self, steps=5, total=10, budget=None, degraded=False,
                 hit=True, completed=False):
        self.steps_completed = steps
        self.total_steps = total
        self.budget_steps = budget
        self.degraded = degraded
        self.deadline_hit = hit
        self.completed = completed


def test_metrics_empty_snapshot_is_well_defined():
    snap = ServeMetrics().snapshot()
    assert snap["delivered"] == 0 and snap["deadline_hit_rate"] == 0.0
    for key in ("steps_at_deadline", "budget_at_deadline"):
        assert snap[key] == {"p50": 0.0, "p99": 0.0, "mean": 0.0}
        for v in snap[key].values():
            assert np.isfinite(v)
    assert snap["requests_per_sec"] == 0.0 and snap["slot_occupancy"] == 0.0
    assert snap["attribution"]["count"] == 0
    assert snap["attribution"]["sum_check_fail"] == 0


def test_metrics_single_delivery_snapshot():
    m = ServeMetrics()
    m.record_submit(now=1.0)
    m.record_delivery(_FakeResult(steps=7, total=10), now=1.0)  # zero wall
    snap = m.snapshot()
    assert snap["delivered"] == 1 and snap["deadline_hit_rate"] == 1.0
    st = snap["steps_at_deadline"]
    assert st["p50"] == st["p99"] == st["mean"] == 7.0
    # budget defaults to total_steps when the request wasn't degraded
    assert snap["budget_at_deadline"]["p50"] == 10.0
    assert snap["requests_per_sec"] == 0.0      # zero wall: defined, not inf


def test_metrics_reset_clears_every_population():
    m = ServeMetrics()
    m.record_submit(now=0.0)
    m.record_dispatch(3, 4)
    m.record_delivery(
        _FakeResult(steps=3, total=10, budget=5, degraded=True), now=2.0)
    clock = ManualClock()
    tr = Tracer(clock=clock)
    tr.request_submitted(9, clock(), "p")
    clock.advance(0.01)
    m.record_attribution(tr.request_delivered(9, clock(), 3, 10, True))
    tr.disable()

    snap = m.snapshot()
    assert snap["degraded_requests"] == 1
    assert snap["budget_at_deadline"]["p50"] == 5.0
    assert snap["attribution"]["count"] == 1

    m.reset()
    snap = m.snapshot()
    assert snap["submitted"] == snap["delivered"] == snap["dispatches"] == 0
    assert snap["degraded_requests"] == 0
    assert snap["steps_at_deadline"] == {"p50": 0.0, "p99": 0.0, "mean": 0.0}
    assert snap["budget_at_deadline"] == {"p50": 0.0, "p99": 0.0, "mean": 0.0}
    assert snap["attribution"]["count"] == 0
    assert snap["wall_s"] == 0.0 and snap["requests_per_sec"] == 0.0


def test_untraced_server_snapshot_has_empty_attribution(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=2)
    assert server.tracer is NULL_TRACER
    server.serve(list(te[:2]), deadline_ms=60_000.0)
    snap = server.metrics.snapshot()
    assert snap["attribution"]["count"] == 0
    assert snap["delivered"] == 2


def _wcet_traced_run():
    """Three steady + one compile dispatch and three harvests: enough to
    fold a certifiable one-cell WCET table."""
    clock = ManualClock()
    tr = Tracer(clock=clock, margins=True)
    for i in range(4):
        with tr.span("serve.dispatch", track="laneA"):
            annotate(backend="jnp-ref", impl="jnp-ref", length=4,
                     compile=(i == 0))
            clock.advance(0.002 + 0.0003 * i)
        if i:
            with tr.span("serve.harvest", track="laneA"):
                clock.advance(0.0005 + 0.0001 * i)
    return tr


def test_wcet_live_table_cross_validates_against_cli_fold(tmp_path):
    """`worst_case_table` (live events) and `tools.obs.wcet.fold`
    (exported JSON) are two codepaths over the same run — same cells,
    same counts; float fields agree to the µs round-trip."""
    from repro.obs.export import worst_case_table
    from tools.obs import wcet

    tr = _wcet_traced_run()
    live = worst_case_table(tr.events(), platform="cpu", margin=2.5)
    doc = write_chrome_trace(tr, tmp_path / "trace.json")
    tr.disable()
    folded = wcet.fold([json.loads(json.dumps(doc))],
                       platform="cpu", margin=2.5)
    assert wcet.wcet_failures(live) == []
    assert wcet.wcet_failures(folded) == []
    assert live["cells"].keys() == folded["cells"].keys() == {
        "jnp-ref/jnp-ref/L4"}
    lc, fc = live["cells"]["jnp-ref/jnp-ref/L4"], \
        folded["cells"]["jnp-ref/jnp-ref/L4"]
    assert lc["count"] == fc["count"] == 3  # the compile sample is out
    for field in ("mean_ms", "p95_ms", "max_ms", "wcet_ms"):
        assert fc[field] == pytest.approx(lc[field])
    assert live["harvest"]["count"] == folded["harvest"]["count"] == 3
    for field in ("mean_ms", "max_ms", "wcet_ms"):
        assert folded["harvest"][field] == pytest.approx(
            live["harvest"][field])


def test_calibrate_cli_roundtrip_and_check_gate(tmp_path):
    """`python -m tools.obs calibrate` writes a table `--check` accepts;
    a corrupted table fails the structural gate."""
    from tools.obs import wcet

    tr = _wcet_traced_run()
    trace_path = tmp_path / "trace.json"
    write_chrome_trace(tr, trace_path)
    tr.disable()
    out = tmp_path / "wcet_cpu.json"
    repo = Path(__file__).resolve().parents[1]
    proc = subprocess.run(
        [sys.executable, "-m", "tools.obs", "calibrate",
         "--trace", str(trace_path), "--platform", "cpu",
         "--margin", "2.0", "--out", str(out)],
        cwd=repo, capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    table = json.loads(out.read_text())
    assert wcet.wcet_failures(table) == []
    assert table["sources"] == [str(trace_path)]  # provenance ride-along
    # the served CostModel accepts the CLI's output directly
    from repro.serve import CostModel

    cm = CostModel(table)
    assert cm.segment_wcet_ms("jnp-ref", 4) == pytest.approx(
        2.0 * table["cells"]["jnp-ref/jnp-ref/L4"]["max_ms"])
    # corruption is caught: a zero-sample harvest cannot price the lag
    table["harvest"] = {"count": 0, "mean_ms": 0.0, "max_ms": 0.0,
                        "wcet_ms": 0.0}
    failures = wcet.wcet_failures(table)
    assert failures and any("lag" in f for f in failures)
    # calibrating with no platform is a usage error, not a crash
    proc = subprocess.run(
        [sys.executable, "-m", "tools.obs", "calibrate",
         "--trace", str(trace_path)],
        cwd=repo, capture_output=True, text=True)
    assert proc.returncode == 2


def test_span_names_registry_is_closed_and_categorized():
    from repro.obs.names import CATEGORIES

    assert set(SPAN_NAMES) >= {
        "serve.submit", "serve.admission", "serve.slot_admit",
        "serve.deliver", "serve.step", "serve.dispatch", "serve.harvest",
        "serve.flush", "serve.margin"}
    assert set(CATEGORIES) == {"serve", "kernel", "quality"}
