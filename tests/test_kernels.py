"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import engine
from repro.forest import train_forest
from repro.kernels import ops, ref


def _rand_tree_tables(rng, M, F):
    feature = jnp.asarray(rng.integers(0, F, size=M), jnp.int32)
    threshold = jnp.asarray(rng.normal(size=M), jnp.float32)
    left = jnp.asarray(rng.integers(0, M, size=M), jnp.int32)
    right = jnp.asarray(rng.integers(0, M, size=M), jnp.int32)
    is_leaf = jnp.asarray(rng.random(M) < 0.3)
    return feature, threshold, left, right, is_leaf


@pytest.mark.parametrize("B,F,M", [(16, 4, 8), (100, 14, 31), (257, 8, 1000),
                                   (64, 128, 513)])
@pytest.mark.parametrize("block_b,block_m", [(32, 16), (256, 512)])
def test_forest_step_matches_ref(B, F, M, block_b, block_m):
    rng = np.random.default_rng(B * M)
    idx = jnp.asarray(rng.integers(0, M, size=B), jnp.int32)
    X = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    tables = _rand_tree_tables(rng, M, F)
    out = ops.forest_step(idx, X, *tables, block_b=block_b, block_m=block_m)
    exp = ref.forest_step_ref(idx, X, *tables)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("B,T,M,C", [(16, 2, 8, 2), (100, 5, 31, 7),
                                     (64, 3, 200, 26), (33, 10, 17, 11)])
@pytest.mark.parametrize("block_b,block_m", [(32, 16), (256, 512)])
def test_prob_accum_matches_ref(B, T, M, C, block_b, block_m):
    rng = np.random.default_rng(B + T + M + C)
    idx = jnp.asarray(rng.integers(0, M, size=(B, T)), jnp.int32)
    probs = jnp.asarray(rng.random((T, M, C)), jnp.float32)
    out = ops.prob_accum(idx, probs, block_b=block_b, block_m=block_m)
    exp = ref.prob_accum_ref(idx, probs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prob_accum_dtypes(dtype):
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, 16, size=(32, 3)), jnp.int32)
    probs = jnp.asarray(rng.random((3, 16, 5)), dtype)
    out = ops.prob_accum(idx, probs, block_b=16, block_m=8)
    exp = ref.prob_accum_ref(idx, probs.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-2, atol=2e-2)


def test_kernel_step_equals_engine_on_real_forest():
    """End-to-end: kernel stepping reproduces the engine on a trained forest."""
    rng = np.random.default_rng(0)
    X = rng.normal(size=(200, 6)).astype(np.float32)
    y = (X[:, 0] + X[:, 1] > 0).astype(np.int64)
    rf = train_forest(X, y, 2, n_trees=3, max_depth=4, seed=0)
    fa = rf.as_arrays()
    dev = engine.to_device(fa)
    X_d = jnp.asarray(X)
    idx_engine = engine.init_state(dev, X.shape[0])
    idx_kernel = np.zeros((X.shape[0], fa.n_trees), dtype=np.int32)
    for t in [0, 1, 2, 0, 1, 2, 2, 1, 0, 0, 1, 2]:
        idx_engine = engine.tree_step(dev, X_d, idx_engine, t)
        new_col = ops.forest_step(
            jnp.asarray(idx_kernel[:, t]), X_d,
            dev.feature[t], dev.threshold[t], dev.left[t], dev.right[t],
            dev.is_leaf[t], block_b=64, block_m=16)
        idx_kernel[:, t] = np.asarray(new_col)
    np.testing.assert_array_equal(idx_kernel, np.asarray(idx_engine))
    # read-out parity
    probs_kernel = ops.prob_accum(jnp.asarray(idx_kernel), dev.probs,
                                  block_b=64, block_m=16)
    probs_engine = engine.predict_from_state(dev, idx_engine)
    np.testing.assert_allclose(np.asarray(probs_kernel),
                               np.asarray(probs_engine), rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 70), M=st.integers(2, 90), T=st.integers(1, 6),
       C=st.integers(2, 12), seed=st.integers(0, 1000))
def test_prob_accum_hypothesis(B, M, T, C, seed):
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, M, size=(B, T)), jnp.int32)
    probs = jnp.asarray(rng.random((T, M, C)), jnp.float32)
    out = ops.prob_accum(idx, probs, block_b=32, block_m=32)
    exp = ref.prob_accum_ref(idx, probs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)
