"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import engine
from repro.forest import train_forest
from repro.kernels import ops, ref


def _rand_tree_tables(rng, M, F):
    feature = jnp.asarray(rng.integers(0, F, size=M), jnp.int32)
    threshold = jnp.asarray(rng.normal(size=M), jnp.float32)
    left = jnp.asarray(rng.integers(0, M, size=M), jnp.int32)
    right = jnp.asarray(rng.integers(0, M, size=M), jnp.int32)
    is_leaf = jnp.asarray(rng.random(M) < 0.3)
    return feature, threshold, left, right, is_leaf


@pytest.mark.parametrize("B,F,M", [(16, 4, 8), (100, 14, 31), (257, 8, 1000),
                                   (64, 128, 513)])
@pytest.mark.parametrize("block_b,block_m", [(32, 16), (256, 512)])
def test_forest_step_matches_ref(B, F, M, block_b, block_m):
    rng = np.random.default_rng(B * M)
    idx = jnp.asarray(rng.integers(0, M, size=B), jnp.int32)
    X = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    tables = _rand_tree_tables(rng, M, F)
    out = ops.forest_step(idx, X, *tables, block_b=block_b, block_m=block_m)
    exp = ref.forest_step_ref(idx, X, *tables)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("B,T,M,C", [(16, 2, 8, 2), (100, 5, 31, 7),
                                     (64, 3, 200, 26), (33, 10, 17, 11)])
@pytest.mark.parametrize("block_b,block_m", [(32, 16), (256, 512)])
def test_prob_accum_matches_ref(B, T, M, C, block_b, block_m):
    rng = np.random.default_rng(B + T + M + C)
    idx = jnp.asarray(rng.integers(0, M, size=(B, T)), jnp.int32)
    probs = jnp.asarray(rng.random((T, M, C)), jnp.float32)
    out = ops.prob_accum(idx, probs, block_b=block_b, block_m=block_m)
    exp = ref.prob_accum_ref(idx, probs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_prob_accum_dtypes(dtype):
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, 16, size=(32, 3)), jnp.int32)
    probs = jnp.asarray(rng.random((3, 16, 5)), dtype)
    out = ops.prob_accum(idx, probs, block_b=16, block_m=8)
    exp = ref.prob_accum_ref(idx, probs.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-2, atol=2e-2)


def test_kernel_step_equals_engine_on_real_forest():
    """End-to-end: kernel stepping reproduces the engine on a trained forest."""
    rng = np.random.default_rng(0)
    X = rng.normal(size=(200, 6)).astype(np.float32)
    y = (X[:, 0] + X[:, 1] > 0).astype(np.int64)
    rf = train_forest(X, y, 2, n_trees=3, max_depth=4, seed=0)
    fa = rf.as_arrays()
    dev = engine.to_device(fa)
    X_d = jnp.asarray(X)
    idx_engine = engine.init_state(dev, X.shape[0])
    idx_kernel = np.zeros((X.shape[0], fa.n_trees), dtype=np.int32)
    for t in [0, 1, 2, 0, 1, 2, 2, 1, 0, 0, 1, 2]:
        idx_engine = engine.tree_step(dev, X_d, idx_engine, t)
        new_col = ops.forest_step(
            jnp.asarray(idx_kernel[:, t]), X_d,
            dev.feature[t], dev.threshold[t], dev.left[t], dev.right[t],
            dev.is_leaf[t], block_b=64, block_m=16)
        idx_kernel[:, t] = np.asarray(new_col)
    np.testing.assert_array_equal(idx_kernel, np.asarray(idx_engine))
    # read-out parity
    probs_kernel = ops.prob_accum(jnp.asarray(idx_kernel), dev.probs,
                                  block_b=64, block_m=16)
    probs_engine = engine.predict_from_state(dev, idx_engine)
    np.testing.assert_allclose(np.asarray(probs_kernel),
                               np.asarray(probs_engine), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Fused multi-step kernel (PR 4): one launch per plan segment, node
# tables resident in VMEM — must be bit-identical to the scanned
# single-step path and the jnp oracle across odd batches, B=1, and
# trees wider than one lane tile.
# ---------------------------------------------------------------------------


def _rand_forest_tables(rng, T, M, F):
    feature = jnp.asarray(rng.integers(0, F, size=(T, M)), jnp.int32)
    threshold = jnp.asarray(rng.normal(size=(T, M)), jnp.float32)
    left = jnp.asarray(rng.integers(0, M, size=(T, M)), jnp.int32)
    right = jnp.asarray(rng.integers(0, M, size=(T, M)), jnp.int32)
    is_leaf = jnp.asarray(rng.random((T, M)) < 0.3)
    return feature, threshold, left, right, is_leaf


@pytest.mark.parametrize("B,F,M", [(1, 4, 8), (33, 14, 31), (257, 8, 513)])
@pytest.mark.parametrize("length", [1, 2, 8])
def test_fused_forest_run_matches_ref(B, F, M, length):
    rng = np.random.default_rng(B * M + length)
    idx = jnp.asarray(rng.integers(0, M, size=B), jnp.int32)
    X = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    tables = _rand_tree_tables(rng, M, F)
    out = ops.forest_run(idx, X, *tables, length=length, block_b=32)
    exp = ref.forest_run_ref(idx, X, *tables, length=length)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
    scanned = ops.forest_run_scanned(idx, X, *tables, length=length,
                                     block_b=32, block_m=16)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(scanned))


def test_fused_forest_run_readout_matches_refs():
    """The fused run+readout launch == scan + prob_accum_ref (state
    bit-exact, readout to the documented kernel tolerance)."""
    rng = np.random.default_rng(7)
    B, F, M, T, C, length = 33, 6, 31, 4, 3, 4
    idx = jnp.asarray(rng.integers(0, M, size=(B, T)), jnp.int32)
    X = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    feature, thr, left, right, leaf = _rand_forest_tables(rng, T, M, F)
    probs = jnp.asarray(rng.random((T, M, C)), jnp.float32)
    for unit in (0, 2, T - 1):
        new_idx, ro = ops.forest_run_readout(
            idx, X, feature[unit], thr[unit], left[unit], right[unit],
            leaf[unit], probs, unit, length=length, block_b=16)
        col = ref.forest_run_ref(
            idx[:, unit], X, feature[unit], thr[unit], left[unit],
            right[unit], leaf[unit], length=length)
        exp_idx = idx.at[:, unit].set(col)
        np.testing.assert_array_equal(np.asarray(new_idx), np.asarray(exp_idx))
        np.testing.assert_allclose(
            np.asarray(ro), np.asarray(ref.prob_accum_ref(exp_idx, probs)),
            rtol=1e-5, atol=1e-5)
        idx = new_idx  # chain segments, as the executor does


def test_fused_run_oversized_tree_falls_back_to_scan(monkeypatch):
    """Tables over the VMEM budget must stream through the single-step
    scan, not be forced resident — same results either way."""
    monkeypatch.setattr(ops, "VMEM_TABLE_BUDGET_BYTES", 1024)
    rng = np.random.default_rng(3)
    B, F, M = 9, 5, 200  # Mp=256 -> 256*8*4 = 8KiB > 1KiB budget
    idx = jnp.asarray(rng.integers(0, M, size=B), jnp.int32)
    X = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    tables = _rand_tree_tables(rng, M, F)
    out = ops.forest_run(idx, X, *tables, length=3, block_b=8, block_m=64)
    exp = ref.forest_run_ref(idx, X, *tables, length=3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


# ---------------------------------------------------------------------------
# Masked-slot kernel (PR 4): per-slot tree ids + live mask on the
# flattened whole-forest tables — the serving hot path.  Mixed
# live/dead lanes must leave dead rows bit-frozen.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S", [1, 13, 33])
@pytest.mark.parametrize("length", [1, 4])
def test_slot_kernel_parity_mixed_live_dead(S, length):
    rng = np.random.default_rng(S * 10 + length)
    T, M, F = 5, 31, 6
    idx = jnp.asarray(rng.integers(0, M, size=(S, T)), jnp.int32)
    X = jnp.asarray(rng.normal(size=(S, F)), jnp.float32)
    tables = _rand_forest_tables(rng, T, M, F)
    units = jnp.asarray(rng.integers(0, T, size=S), jnp.int32)
    mask = jnp.asarray(rng.random(S) < 0.6)
    # impl pinned: the committed cpu tuning record selects the gather
    # fallback, and this test must exercise the flat kernel itself
    out = ops.slot_run(idx, X, *tables, units, mask, length=length,
                       block_b=8, impl="flat")
    exp = ref.slot_run_ref(idx, X, *tables, units, mask, length=length)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
    # dead rows are bit-frozen
    dead = ~np.asarray(mask)
    np.testing.assert_array_equal(np.asarray(out)[dead],
                                  np.asarray(idx)[dead])


def test_slot_kernel_all_dead_is_identity():
    rng = np.random.default_rng(0)
    S, T, M, F = 7, 3, 15, 4
    idx = jnp.asarray(rng.integers(0, M, size=(S, T)), jnp.int32)
    X = jnp.asarray(rng.normal(size=(S, F)), jnp.float32)
    tables = _rand_forest_tables(rng, T, M, F)
    units = jnp.zeros(S, jnp.int32)
    mask = jnp.zeros(S, bool)
    out = ops.slot_run(idx, X, *tables, units, mask, length=4, impl="flat")
    np.testing.assert_array_equal(np.asarray(out), np.asarray(idx))


def test_slot_kernel_fused_readout_matches_refs():
    rng = np.random.default_rng(11)
    S, T, M, F, C = 17, 4, 31, 6, 3
    idx = jnp.asarray(rng.integers(0, M, size=(S, T)), jnp.int32)
    X = jnp.asarray(rng.normal(size=(S, F)), jnp.float32)
    tables = _rand_forest_tables(rng, T, M, F)
    probs = jnp.asarray(rng.random((T, M, C)), jnp.float32)
    units = jnp.asarray(rng.integers(0, T, size=S), jnp.int32)
    mask = jnp.asarray(rng.random(S) < 0.7)
    new_idx, ro = ops.slot_run_readout(
        idx, X, *tables, probs, units, mask, length=2, block_b=8,
        impl="flat")
    exp = ref.slot_run_ref(idx, X, *tables, units, mask, length=2)
    np.testing.assert_array_equal(np.asarray(new_idx), np.asarray(exp))
    np.testing.assert_allclose(
        np.asarray(ro), np.asarray(ref.prob_accum_ref(exp, probs)),
        rtol=1e-5, atol=1e-5)


def test_fused_readout_oversized_falls_back_to_two_dispatches(monkeypatch):
    """forest_run_readout over the VMEM budget must still return the
    same (state, readout) pair through the scan + prob_accum fallback."""
    monkeypatch.setattr(ops, "VMEM_TABLE_BUDGET_BYTES", 1024)
    rng = np.random.default_rng(9)
    B, F, M, T, C = 9, 5, 200, 3, 4
    idx = jnp.asarray(rng.integers(0, M, size=(B, T)), jnp.int32)
    X = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    feature, thr, left, right, leaf = _rand_forest_tables(rng, T, M, F)
    probs = jnp.asarray(rng.random((T, M, C)), jnp.float32)
    unit = 1
    new_idx, ro = ops.forest_run_readout(
        idx, X, feature[unit], thr[unit], left[unit], right[unit],
        leaf[unit], probs, unit, length=3, block_b=8, block_m=64)
    col = ref.forest_run_ref(idx[:, unit], X, feature[unit], thr[unit],
                             left[unit], right[unit], leaf[unit], length=3)
    exp_idx = idx.at[:, unit].set(col)
    np.testing.assert_array_equal(np.asarray(new_idx), np.asarray(exp_idx))
    np.testing.assert_allclose(
        np.asarray(ro), np.asarray(ref.prob_accum_ref(exp_idx, probs)),
        rtol=1e-5, atol=1e-5)


def test_slot_readout_oversized_falls_back_to_gather(monkeypatch):
    monkeypatch.setattr(ops, "VMEM_TABLE_BUDGET_BYTES", 1024)
    rng = np.random.default_rng(13)
    S, T, M, F, C = 9, 3, 200, 5, 4
    idx = jnp.asarray(rng.integers(0, M, size=(S, T)), jnp.int32)
    X = jnp.asarray(rng.normal(size=(S, F)), jnp.float32)
    tables = _rand_forest_tables(rng, T, M, F)
    probs = jnp.asarray(rng.random((T, M, C)), jnp.float32)
    units = jnp.asarray(rng.integers(0, T, size=S), jnp.int32)
    mask = jnp.asarray(rng.random(S) < 0.5)
    new_idx, ro = ops.slot_run_readout(
        idx, X, *tables, probs, units, mask, length=3, block_b=8,
        block_m=64, impl="flat")
    exp = ref.slot_run_ref(idx, X, *tables, units, mask, length=3)
    np.testing.assert_array_equal(np.asarray(new_idx), np.asarray(exp))
    np.testing.assert_allclose(
        np.asarray(ro), np.asarray(ref.prob_accum_ref(exp, probs)),
        rtol=1e-5, atol=1e-5)


def test_kernel_wrappers_reject_unknown_options():
    rng = np.random.default_rng(0)
    idx = jnp.asarray(rng.integers(0, 8, size=4), jnp.int32)
    X = jnp.asarray(rng.normal(size=(4, 3)), jnp.float32)
    tables = _rand_tree_tables(rng, 8, 3)
    with pytest.raises(TypeError, match="blok_b"):
        ops.forest_run(idx, X, *tables, length=2, blok_b=8)
    # slot-only tuning kwargs are rejected on the solo path, not
    # silently swallowed
    with pytest.raises(TypeError, match="block_s"):
        ops.forest_run(idx, X, *tables, length=2, block_s=8)


def test_slot_kernel_oversized_forest_falls_back_to_gather(monkeypatch):
    monkeypatch.setattr(ops, "VMEM_TABLE_BUDGET_BYTES", 1024)
    rng = np.random.default_rng(5)
    S, T, M, F = 9, 4, 200, 5
    idx = jnp.asarray(rng.integers(0, M, size=(S, T)), jnp.int32)
    X = jnp.asarray(rng.normal(size=(S, F)), jnp.float32)
    tables = _rand_forest_tables(rng, T, M, F)
    units = jnp.asarray(rng.integers(0, T, size=S), jnp.int32)
    mask = jnp.asarray(rng.random(S) < 0.5)
    out = ops.slot_run(idx, X, *tables, units, mask, length=3, impl="flat")
    exp = ref.slot_run_ref(idx, X, *tables, units, mask, length=3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@settings(max_examples=10, deadline=None)
@given(B=st.integers(1, 70), M=st.integers(2, 90), T=st.integers(1, 6),
       C=st.integers(2, 12), seed=st.integers(0, 1000))
def test_prob_accum_hypothesis(B, M, T, C, seed):
    rng = np.random.default_rng(seed)
    idx = jnp.asarray(rng.integers(0, M, size=(B, T)), jnp.int32)
    probs = jnp.asarray(rng.random((T, M, C)), jnp.float32)
    out = ops.prob_accum(idx, probs, block_b=32, block_m=32)
    exp = ref.prob_accum_ref(idx, probs)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=1e-5, atol=1e-5)
