"""Serving runtime + anytime-depth scheduling (paper technique on
transformers) + checkpoint roundtrip."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as ckpt_lib
from repro.configs.registry import get_config
from repro.data.pipeline import make_batches
from repro.models import model as MD
from repro.serving import engine as SE
from repro.serving.anytime_depth import (
    AnytimeEnsembleSession, EnsembleMember, accuracy_curve,
    generate_depth_order, quality_table)

KEY = jax.random.PRNGKey(0)


def test_generate_greedy_deterministic():
    cfg = get_config("olmo_1b", reduced=True)
    params = MD.init(cfg, KEY)
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 100, (2, 8)), jnp.int32)
    a = SE.generate(cfg, params, toks, 6)
    b = SE.generate(cfg, params, toks, 6)
    assert a.shape == (2, 14)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_generate_matches_forward_argmax():
    """First generated token == argmax of the full forward logits."""
    from repro.models import transformer as T
    cfg = get_config("qwen3_14b", reduced=True)
    params = MD.init(cfg, KEY)
    toks = jnp.asarray(np.random.default_rng(1).integers(0, 100, (2, 12)), jnp.int32)
    out = SE.generate(cfg, params, toks, 1)
    logits, _ = T.forward(cfg, params, {"tokens": toks})
    expect = jnp.argmax(logits[:, -1], axis=-1)
    np.testing.assert_array_equal(np.asarray(out[:, -1]), np.asarray(expect))


def _members(cfg, n=2):
    return [EnsembleMember(cfg, MD.init(cfg, jax.random.PRNGKey(i)))
            for i in range(n)]


def test_quality_table_shape_and_padding():
    cfg = get_config("olmo_1b", reduced=True)
    members = _members(cfg)
    b = next(make_batches(cfg, 16, 4, seed=0))
    batch = {"tokens": jnp.asarray(b["tokens"])}
    labels = np.asarray(b["labels"][:, -1])
    pp, y = quality_table(members, batch, labels)
    assert pp.shape == (4, 2, cfg.num_layers + 1, cfg.vocab_size)
    assert np.isfinite(pp).all()


def test_anytime_depth_session_full_run_matches_forward():
    """After all steps, the session's summed readout equals the sum of the
    members' complete forward readouts (the 'final state' invariant)."""
    from repro.models import transformer as T
    cfg = get_config("olmo_1b", reduced=True)
    members = _members(cfg)
    b = next(make_batches(cfg, 16, 4, seed=0))
    batch = {"tokens": jnp.asarray(b["tokens"])}
    order = np.asarray([0, 1] * cfg.num_layers, dtype=np.int32)
    sess = AnytimeEnsembleSession(members, order, batch)
    sess.advance(sess.total_steps)
    got = sess.predict_logprobs()
    expect = None
    for m in members:
        lg, _ = T.forward(m.cfg, m.params, batch)
        lp = jax.nn.log_softmax(lg[:, -1].astype(jnp.float32), axis=-1)
        expect = lp if expect is None else expect + lp
    np.testing.assert_allclose(got, np.asarray(expect), rtol=1e-4, atol=1e-4)


def test_anytime_depth_order_generation():
    cfg = get_config("olmo_1b", reduced=True)
    members = _members(cfg)
    b = next(make_batches(cfg, 16, 8, seed=0))
    batch = {"tokens": jnp.asarray(b["tokens"])}
    labels = np.asarray(b["labels"][:, -1])
    for name in ("backward_squirrel", "forward_squirrel", "breadth"):
        order = generate_depth_order(members, batch, labels, name, top_v=32)
        counts = np.bincount(order, minlength=2)
        assert (counts == cfg.num_layers).all(), name
    curve = accuracy_curve(members, order, batch, labels)
    assert len(curve) == 2 * cfg.num_layers + 1


def test_anytime_depth_readout_cache_hits():
    """Exit readouts are cached per member keyed on layer depth: a
    member whose depth didn't change between predict() calls must not
    recompute norm+unembed, and cached results stay correct."""
    cfg = get_config("olmo_1b", reduced=True)
    members = _members(cfg)
    b = next(make_batches(cfg, 16, 4, seed=0))
    batch = {"tokens": jnp.asarray(b["tokens"])}
    order = np.asarray([0, 1] * cfg.num_layers, dtype=np.int32)
    sess = AnytimeEnsembleSession(members, order, batch)
    first = sess.predict_logprobs()
    assert sess.readout_computes == 2          # one per member
    again = sess.predict_logprobs()
    assert sess.readout_computes == 2          # pure cache hit
    np.testing.assert_array_equal(first, again)
    sess.advance(1)                            # only member 0 moved
    sess.predict_logprobs()
    assert sess.readout_computes == 3          # member 1 still cached
    # cached path matches a cache-cold session advanced identically
    cold = AnytimeEnsembleSession(members, order, batch)
    cold.advance(1)
    np.testing.assert_allclose(sess.predict_logprobs(),
                               cold.predict_logprobs(), rtol=1e-6, atol=1e-6)
    # steps past a member's final layer are no-ops: depth-keying stays hot
    sess.advance(sess.total_steps)
    n = sess.readout_computes
    sess.predict_logprobs()
    assert sess.readout_computes == n + 2      # both members moved since
    sess.predict_logprobs()
    assert sess.readout_computes == n + 2


def test_ensemble_program_rejects_kernel_backends():
    from repro.serving.anytime_depth import EnsembleProgram

    cfg = get_config("olmo_1b", reduced=True)
    members = _members(cfg)
    b = next(make_batches(cfg, 16, 4, seed=0))
    batch = {"tokens": jnp.asarray(b["tokens"])}
    labels = np.asarray(b["labels"][:, -1])
    prog = EnsembleProgram(members, batch, labels, top_v=16)
    order = np.asarray([0, 1] * cfg.num_layers, dtype=np.int32)
    with pytest.raises(ValueError, match="jnp-ref"):
        prog.make_session(order, batch, backend="pallas")
    sess = prog.make_session(order, batch, backend="jnp-ref")
    assert sess.total_steps == len(order)


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("olmo_1b", reduced=True)
    params = MD.init(cfg, KEY)
    path = os.path.join(tmp_path, "ck", "step_1.npz")
    ckpt_lib.save(path, {"params": params}, metadata={"step": 1})
    like = jax.eval_shape(lambda: {"params": params})
    restored = ckpt_lib.restore(path, like)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ckpt_lib.latest_step(os.path.dirname(path)) == 1
