"""benchmarks.loadgen: arrival-process statistics, request-stream
synthesis, and one end-to-end virtual-time simulation point through the
pooled tier (the frontier sweep's unit of work)."""
import random
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks import loadgen  # noqa: E402
from repro.core import engine  # noqa: E402
from repro.forest import make_dataset, split_dataset, train_forest  # noqa: E402
from repro.schedule import AnytimeRuntime, ForestProgram  # noqa: E402


def test_poisson_arrivals_mean_rate_and_monotonic():
    rng = random.Random(0)
    times = loadgen.poisson_arrivals(100.0, 4000, rng)
    assert len(times) == 4000
    assert all(b > a for a, b in zip(times, times[1:]))
    # empirical rate within 10% of nominal at this sample size
    assert times[-1] == pytest.approx(4000 / 100.0, rel=0.1)


def test_mmpp_matches_mean_rate_but_burstier():
    rng = random.Random(1)
    n, rate = 6000, 100.0
    mmpp = loadgen.mmpp_arrivals(rate, n, rng, burst_factor=4.0,
                                 switch_hz=2.0)
    assert len(mmpp) == n
    assert all(b > a for a, b in zip(mmpp, mmpp[1:]))
    assert mmpp[-1] == pytest.approx(n / rate, rel=0.15)  # same mean load
    # burstiness: MMPP inter-arrival CoV must exceed the Poisson CoV (1)
    gaps = np.diff(mmpp)
    cov = float(gaps.std() / gaps.mean())
    assert cov > 1.1


def test_sample_mix_respects_weights():
    rng = random.Random(2)
    mix = ((0.8, "a"), (0.2, "b"))
    draws = [p for (p,) in loadgen.sample_mix(mix, 2000, rng)]
    frac_a = draws.count("a") / len(draws)
    assert 0.72 < frac_a < 0.88


def test_make_schedule_stamps_deadlines_in_service_units():
    rows = [np.zeros(4), np.ones(4)]
    sched = loadgen.make_schedule(
        rows, rate_rps=50.0, n=64, svc_ms=10.0,
        deadline_mix=((1.0, 2.0, 4.0),), arrival="poisson", seed=3)
    assert len(sched) == 64
    times = [t for t, _ in sched]
    assert all(b > a for a, b in zip(times, times[1:]))
    for _, req in sched:
        assert 20.0 <= req.deadline_ms <= 40.0  # 2-4x the 10 ms svc time
        assert req.policy == "backward_squirrel"
    with pytest.raises(ValueError, match="arrival"):
        loadgen.make_schedule(rows, rate_rps=1.0, n=1, svc_ms=1.0,
                              arrival="weibull")


def test_schedule_is_deterministic_per_seed():
    rows = [np.zeros(4)]
    a = loadgen.make_schedule(rows, rate_rps=20.0, n=32, svc_ms=5.0, seed=7)
    b = loadgen.make_schedule(rows, rate_rps=20.0, n=32, svc_ms=5.0, seed=7)
    assert [t for t, _ in a] == [t for t, _ in b]
    assert [r.deadline_ms for _, r in a] == [r.deadline_ms for _, r in b]


@pytest.fixture(scope="module")
def small_runtime():
    X, y = make_dataset("magic", seed=1)
    (tr, ytr), (orx, yor), (te, yte) = split_dataset(X, y, seed=1)
    rf = train_forest(tr[:800], ytr[:800], 2, n_trees=4, max_depth=5, seed=1)
    fa = rf.as_arrays()
    pp = engine.path_probs_np(fa, orx[:200])
    rt = AnytimeRuntime(
        ForestProgram(fa, y_order=yor[:200], path_probs=pp, X_order=te[:8]))
    return rt, te


def test_sim_point_delivers_every_request(small_runtime):
    """One virtual-time simulation point end-to-end: every scheduled
    request is delivered, the stats are internally consistent, and
    generous deadlines complete the full population."""
    from repro.serve import PooledAnytimeServer

    rt, te = small_runtime
    clock = loadgen.ManualClock()
    srv = PooledAnytimeServer(rt, pools=2, capacity=4, clock=clock)
    loadgen._warm(srv, list(te[:4]), loadgen.POLICY_MIX, None)
    stats = loadgen.run_sim_point(
        srv, clock, list(te[:16]), rate_rps=200.0, n_requests=24,
        svc_ms=1e6, step_cost_s=1e-4, seed=0)
    assert stats["requests"] == 24
    assert stats["hit_rate"] == pytest.approx(1.0)
    assert stats["good_rate"] == pytest.approx(1.0)
    assert stats["throughput_rps"] > 0
    assert stats["latency_p99_ms"] >= stats["latency_p50_ms"] >= 0


def test_storm_sim_certifies_guarantees_under_overload(small_runtime):
    """The adversarial deadline storm in virtual time: every admitted
    guaranteed request completes inside its deadline (zero misses, by
    both countings) while the overloaded best-effort lanes visibly
    degrade.  `gate=True` re-asserts the same inside run_storm — this
    is the CI wiring for the certified-serving contract."""
    rt, te = small_runtime
    out = loadgen.run_storm(rt, list(te[:32]), mode="sim", pools=2,
                            capacity=4, n_requests=48, gate=True,
                            verbose=False, seed=0)
    assert out["guaranteed_admitted"] > 0
    assert out["guaranteed_misses"] == 0
    assert out["metrics_guaranteed_misses"] == 0
    assert out["degraded_requests"] > 0
    assert out["priced_full_wcet_ms"] > 0
    assert out["delivered"] <= out["requests"]
