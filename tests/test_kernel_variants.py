"""Parity sweeps for the mined kernel variants (depth-aware
gather-elimination, tree-bucketized slots, cached subtree tops) and the
depth-layout precompute behind them — all in interpret mode against the
jnp oracles, including the edges the variants' static structure makes
dangerous: odd batches, B=1, mixed live/dead lanes, and run lengths at
or past the tree depth (every walker parked on a leaf before the
unrolled prefix ends)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.kernels import layout as klayout
from repro.kernels import ops, ref


def _heap_forest(rng, T, M, F, shuffle=True):
    """Stacked [T, M] tables of real binary trees (heap topology), each
    under an independent random node relabeling fixing the root."""
    feature = np.zeros((T, M), np.int64)
    threshold = np.zeros((T, M), np.float64)
    left = np.zeros((T, M), np.int64)
    right = np.zeros((T, M), np.int64)
    is_leaf = np.zeros((T, M), bool)
    for t in range(T):
        perm = (np.concatenate([[0], 1 + rng.permutation(M - 1)])
                if shuffle and M > 1 else np.arange(M))
        inv = np.empty(M, np.int64)
        inv[perm] = np.arange(M)
        f = rng.integers(0, F, size=M)
        th = rng.normal(size=M)
        lf = np.zeros(M, bool)
        lt = np.zeros(M, np.int64)
        rt = np.zeros(M, np.int64)
        for i in range(M):
            lo, hi = 2 * i + 1, 2 * i + 2
            if hi < M:
                lt[i], rt[i] = perm[lo], perm[hi]
            else:
                lf[i] = True
                lt[i] = rt[i] = perm[i]
        feature[t] = f[inv]
        threshold[t] = th[inv]
        left[t] = lt[inv]
        right[t] = rt[inv]
        is_leaf[t] = lf[inv]
    return (
        jnp.asarray(feature, jnp.int32),
        jnp.asarray(threshold, jnp.float32),
        jnp.asarray(left, jnp.int32),
        jnp.asarray(right, jnp.int32),
        jnp.asarray(is_leaf),
    )


def _rand_forest_tables(rng, T, M, F):
    return (
        jnp.asarray(rng.integers(0, F, size=(T, M)), jnp.int32),
        jnp.asarray(rng.normal(size=(T, M)), jnp.float32),
        jnp.asarray(rng.integers(0, M, size=(T, M)), jnp.int32),
        jnp.asarray(rng.integers(0, M, size=(T, M)), jnp.int32),
        jnp.asarray(rng.random((T, M)) < 0.3),
    )


# ---------------------------------------------------------------------------
# depth layout precompute
# ---------------------------------------------------------------------------


def test_bfs_depths_heap_tree():
    # unshuffled heap: node i sits at depth floor(log2(i+1))
    rng = np.random.default_rng(0)
    M = 15
    _, _, left, right, leaf = _heap_forest(rng, 1, M, 4, shuffle=False)
    d = klayout.bfs_depths(np.asarray(left[0]), np.asarray(right[0]),
                           np.asarray(leaf[0]))
    exp = np.floor(np.log2(np.arange(M) + 1)).astype(np.int64)
    np.testing.assert_array_equal(d, exp)


def test_bfs_depths_unreachable_get_sentinel():
    # node 3 is orphaned: a 1-level tree over {0,1,2} plus a stray node
    left = np.array([1, 1, 2, 3])
    right = np.array([2, 1, 2, 3])
    leaf = np.array([False, True, True, True])
    d = klayout.bfs_depths(left, right, leaf)
    np.testing.assert_array_equal(d, [0, 1, 1, 4])


def test_depth_layout_orders_nodes_by_depth():
    rng = np.random.default_rng(1)
    tables = _heap_forest(rng, 3, 31, 6)
    lay = klayout.build_depth_layout(*tables)
    for t in range(3):
        d = klayout.bfs_depths(np.asarray(tables[2][t]),
                               np.asarray(tables[3][t]),
                               np.asarray(tables[4][t]))
        ordered = d[np.asarray(lay.old_of_new[t])]
        assert (np.diff(ordered) >= 0).all(), "new ids not depth-sorted"
    # permutations are inverses
    for t in range(3):
        np.testing.assert_array_equal(
            np.asarray(lay.new_of_old[t])[np.asarray(lay.old_of_new[t])],
            np.arange(31))
    # prefix widths grow like the complete-tree bound, never past it
    widths = lay.step_widths(0, 8)
    for j, w in enumerate(widths):
        assert w <= klayout.complete_tree_width(j, lay.Mp)


def test_step_widths_start_step_and_levels():
    rng = np.random.default_rng(2)
    tables = _heap_forest(rng, 1, 127, 5)
    lay = klayout.build_depth_layout(*tables)
    full = lay.step_widths(0, 32)
    assert len(full) >= 1 and all(w < lay.Mp for w in full)
    # levels caps the unroll; start_step shifts into wider prefixes
    assert len(lay.step_widths(0, 32, levels=2)) <= 2
    shifted = lay.step_widths(2, 32)
    assert all(s >= f for s, f in zip(shifted, full[2:]))
    # a walk deeper than the tree has no narrow steps left
    assert lay.step_widths(64, 8) == ()


def test_counter_width_model_matches_layout_bound():
    """The pure-stdlib tools.perf width model IS the kernel-side bound —
    pinned here so the two cannot drift apart."""
    from tools.perf import counters as perfc
    for Mp in (128, 256, 1024):
        for step in (0, 1, 3, 6, 20, 64):
            assert (perfc.complete_tree_width(step, Mp)
                    == klayout.complete_tree_width(step, Mp))


# ---------------------------------------------------------------------------
# depth-aware gather-eliminated run
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("B", [1, 33, 128])
@pytest.mark.parametrize("length", [1, 4, 16])
def test_depth_run_parity_from_root(B, length):
    rng = np.random.default_rng(B * 100 + length)
    T, M, F = 3, 31, 6
    tables = _heap_forest(rng, T, M, F)
    lay = klayout.build_depth_layout(*tables)
    X = jnp.asarray(rng.normal(size=(B, F)), jnp.float32)
    idx0 = jnp.zeros(B, jnp.int32)
    for unit in range(T):
        per_tree = tuple(t[unit] for t in tables)
        exp = ref.forest_run_ref(idx0, X, *per_tree, length=length)
        out = ops.forest_run_depth(idx0, X, lay, unit, length=length)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_depth_run_length_past_tree_depth():
    """K >= tree depth: every walker reaches (and self-loops on) a leaf
    inside the narrow prefix — the unrolled steps and the full-width
    tail must both preserve the parked state bit-exactly."""
    rng = np.random.default_rng(5)
    M = 15  # depth-3 heap: any walk parks within 3 steps
    tables = _heap_forest(rng, 1, M, 4)
    lay = klayout.build_depth_layout(*tables)
    X = jnp.asarray(rng.normal(size=(9, 4)), jnp.float32)
    idx0 = jnp.zeros(9, jnp.int32)
    per_tree = tuple(t[0] for t in tables)
    for length in (3, 8, 32):
        exp = ref.forest_run_ref(idx0, X, *per_tree, length=length)
        out = ops.forest_run_depth(idx0, X, lay, 0, length=length)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_depth_run_mid_walk_start_step():
    """start_step > 0 (resuming a fresh walk split across pow2 pieces):
    widths shift to the deeper bounds and parity must hold given idx
    really is start_step steps from the root."""
    rng = np.random.default_rng(6)
    tables = _heap_forest(rng, 2, 63, 5)
    lay = klayout.build_depth_layout(*tables)
    X = jnp.asarray(rng.normal(size=(17, 5)), jnp.float32)
    idx0 = jnp.zeros(17, jnp.int32)
    per_tree = tuple(t[1] for t in tables)
    mid = ops.forest_run_depth(idx0, X, lay, 1, length=2, start_step=0)
    exp = ref.forest_run_ref(idx0, X, *per_tree, length=6)
    out = ops.forest_run_depth(mid, X, lay, 1, length=4, start_step=2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_depth_run_levels_cap_and_oversized_fallback(monkeypatch):
    rng = np.random.default_rng(7)
    tables = _heap_forest(rng, 1, 31, 4)
    lay = klayout.build_depth_layout(*tables)
    X = jnp.asarray(rng.normal(size=(5, 4)), jnp.float32)
    idx0 = jnp.zeros(5, jnp.int32)
    exp = ref.forest_run_ref(idx0, X, *(t[0] for t in tables), length=6)
    out = ops.forest_run_depth(idx0, X, lay, 0, length=6, levels=1)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
    # over-budget layouts stream through the scan over permuted tables
    monkeypatch.setattr(ops, "VMEM_TABLE_BUDGET_BYTES", 64)
    out = ops.forest_run_depth(idx0, X, lay, 0, length=6)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


# ---------------------------------------------------------------------------
# bucketized and cached slot kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("S", [1, 13, 33])
@pytest.mark.parametrize("length", [1, 4])
@pytest.mark.parametrize("impl", ["bucket", "cached"])
def test_slot_variant_parity_mixed_live_dead(S, length, impl):
    rng = np.random.default_rng(S * 17 + length)
    T, M, F = 5, 31, 6
    idx = jnp.asarray(rng.integers(0, M, size=(S, T)), jnp.int32)
    X = jnp.asarray(rng.normal(size=(S, F)), jnp.float32)
    tables = _rand_forest_tables(rng, T, M, F)
    units = jnp.asarray(rng.integers(0, T, size=S), jnp.int32)
    mask = jnp.asarray(rng.random(S) < 0.6)
    out = ops.slot_run(idx, X, *tables, units, mask, length=length,
                       impl=impl, block_s=8)
    exp = ref.slot_run_ref(idx, X, *tables, units, mask, length=length)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))
    dead = ~np.asarray(mask)
    np.testing.assert_array_equal(np.asarray(out)[dead],
                                  np.asarray(idx)[dead])


@pytest.mark.parametrize("impl", ["bucket", "cached"])
def test_slot_variant_readout_matches_refs(impl):
    rng = np.random.default_rng(23)
    S, T, M, F, C = 17, 4, 31, 6, 3
    idx = jnp.asarray(rng.integers(0, M, size=(S, T)), jnp.int32)
    X = jnp.asarray(rng.normal(size=(S, F)), jnp.float32)
    tables = _rand_forest_tables(rng, T, M, F)
    probs = jnp.asarray(rng.random((T, M, C)), jnp.float32)
    units = jnp.asarray(rng.integers(0, T, size=S), jnp.int32)
    mask = jnp.asarray(rng.random(S) < 0.7)
    new_idx, ro = ops.slot_run_readout(
        idx, X, *tables, probs, units, mask, length=2, impl=impl)
    exp = ref.slot_run_ref(idx, X, *tables, units, mask, length=2)
    np.testing.assert_array_equal(np.asarray(new_idx), np.asarray(exp))
    np.testing.assert_allclose(
        np.asarray(ro), np.asarray(ref.prob_accum_ref(exp, probs)),
        rtol=1e-5, atol=1e-5)


def test_cached_slot_kernel_hits_top_on_depth_ordered_forest():
    """On a depth-ordered forest with shallow walkers the cached impl's
    narrow path actually executes (top_rows covers every live node) —
    parity must hold through the fast path, not just the wide one."""
    rng = np.random.default_rng(29)
    S, T, M, F = 13, 3, 63, 5
    tables = _heap_forest(rng, T, M, F)
    lay = klayout.build_depth_layout(*tables)
    dtables = lay.tables  # depth-ordered: shallow nodes have small ids
    idx = jnp.asarray(rng.integers(0, 7, size=(S, T)), jnp.int32)
    X = jnp.asarray(rng.normal(size=(S, F)), jnp.float32)
    units = jnp.asarray(rng.integers(0, T, size=S), jnp.int32)
    mask = jnp.ones(S, bool)
    out = ops.slot_run(idx, X, *dtables, units, mask, length=2,
                       impl="cached", top_rows=32)
    exp = ref.slot_run_ref(idx, X, *dtables, units, mask, length=2)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_cached_top_rows_at_least_tree_height(monkeypatch):
    # top_rows >= Mp clamps to Mp (the whole tree is "the top")
    rng = np.random.default_rng(31)
    S, T, M, F = 5, 2, 15, 4
    tables = _rand_forest_tables(rng, T, M, F)
    idx = jnp.asarray(rng.integers(0, M, size=(S, T)), jnp.int32)
    X = jnp.asarray(rng.normal(size=(S, F)), jnp.float32)
    units = jnp.asarray(rng.integers(0, T, size=S), jnp.int32)
    mask = jnp.asarray(rng.random(S) < 0.5)
    out = ops.slot_run(idx, X, *tables, units, mask, length=3,
                       impl="cached", top_rows=10_000)
    exp = ref.slot_run_ref(idx, X, *tables, units, mask, length=3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


@pytest.mark.parametrize("impl", ["bucket", "cached"])
def test_slot_variant_oversized_falls_back_to_gather(impl, monkeypatch):
    monkeypatch.setattr(ops, "VMEM_TABLE_BUDGET_BYTES", 64)
    rng = np.random.default_rng(37)
    S, T, M, F = 9, 3, 40, 5
    tables = _rand_forest_tables(rng, T, M, F)
    idx = jnp.asarray(rng.integers(0, M, size=(S, T)), jnp.int32)
    X = jnp.asarray(rng.normal(size=(S, F)), jnp.float32)
    units = jnp.asarray(rng.integers(0, T, size=S), jnp.int32)
    mask = jnp.asarray(rng.random(S) < 0.5)
    out = ops.slot_run(idx, X, *tables, units, mask, length=3, impl=impl)
    exp = ref.slot_run_ref(idx, X, *tables, units, mask, length=3)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(exp))


def test_bucketize_slots_roundtrip_and_coherence():
    rng = np.random.default_rng(41)
    units = jnp.asarray(rng.integers(0, 4, size=23), jnp.int32)
    perm, inv = ops.bucketize_slots(units)
    sorted_units = np.asarray(jnp.take(units, perm))
    assert (np.diff(sorted_units) >= 0).all()
    np.testing.assert_array_equal(
        np.asarray(jnp.take(perm, inv)), np.arange(23))
    # round-trip any slot-indexed payload
    payload = jnp.asarray(rng.normal(size=(23, 3)), jnp.float32)
    back = jnp.take(jnp.take(payload, perm, axis=0), inv, axis=0)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(payload))


@settings(max_examples=10, deadline=None)
@given(S=st.integers(1, 40), T=st.integers(1, 5), seed=st.integers(0, 1000))
def test_bucketized_dispatch_is_permutation_invariant(S, T, seed):
    """The scheduler-side bucket transform (sort, dispatch, unsort) is
    bit-neutral for ANY slot impl — the property the executor relies on
    when the tuning record selects ``bucket``."""
    rng = np.random.default_rng(seed)
    M, F = 15, 4
    tables = _rand_forest_tables(rng, T, M, F)
    idx = jnp.asarray(rng.integers(0, M, size=(S, T)), jnp.int32)
    X = jnp.asarray(rng.normal(size=(S, F)), jnp.float32)
    units = jnp.asarray(rng.integers(0, T, size=S), jnp.int32)
    mask = jnp.asarray(rng.random(S) < 0.6)
    direct = ops.slot_run(idx, X, *tables, units, mask, length=2,
                          impl="bucket")
    perm, inv = ops.bucketize_slots(units)
    routed = ops.slot_run(
        jnp.take(idx, perm, axis=0), jnp.take(X, perm, axis=0), *tables,
        jnp.take(units, perm), jnp.take(mask, perm), length=2, impl="bucket")
    np.testing.assert_array_equal(
        np.asarray(jnp.take(routed, inv, axis=0)), np.asarray(direct))
