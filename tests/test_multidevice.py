"""Multi-device mesh coverage for the ``sharded`` backend (ROADMAP open
item): the parity suites re-run in a subprocess whose XLA host platform
emulates 8 devices, so the slot/batch pspec placement is exercised on a
REAL multi-shard mesh rather than the single-device degenerate case.
A pspec regression (wrong axis, missing pad, bad slot placement) that
single-device runs mask fails here — and fails CI, where the same
command runs as a dedicated job (.github/workflows/ci.yml).
"""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_pytest_on_mesh(*pytest_args: str) -> subprocess.CompletedProcess:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    return subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", "-p", "no:cacheprovider",
         *pytest_args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=900,
    )


@pytest.mark.slow
def test_sharded_backend_parity_under_8_device_mesh():
    """tests/test_backends.py sharded + slot-path parity (bit-exact vs
    the jnp-ref oracle, odd batches, mid-chunk splits, mixed live/dead
    slot lanes) on an 8-way batch mesh."""
    r = _run_pytest_on_mesh("tests/test_backends.py", "-k", "sharded or slot")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "passed" in r.stdout


@pytest.mark.slow
def test_slot_and_fused_kernel_parity_under_8_device_mesh():
    """The fused-run and masked-slot KERNEL parity cases re-run on the
    8-device mesh — interpret-mode pallas_calls must stay bit-exact
    when XLA sees a multi-device host platform."""
    r = _run_pytest_on_mesh("tests/test_kernels.py", "-k", "slot or fused")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "passed" in r.stdout


@pytest.mark.slow
def test_sharded_serving_parity_under_8_device_mesh():
    """The serve-layer parity test with the slot axis actually split 8
    ways (SessionBatch rounds capacity up to the shard count)."""
    r = _run_pytest_on_mesh(
        "tests/test_serve.py", "-k", "sharded or session_batch")
    assert r.returncode == 0, r.stdout + r.stderr
    assert "passed" in r.stdout
