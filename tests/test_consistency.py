"""Prefill + decode must reproduce the full forward pass exactly
(the serving path's core correctness property), for every family."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.registry import get_config, transformer_arch_ids
from repro.configs.shapes import InputShape
from repro.models import model as MD
from repro.models import transformer as T

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


@pytest.mark.parametrize("arch", transformer_arch_ids())
def test_prefill_decode_matches_forward(arch):
    cfg = get_config(arch, reduced=True)
    if cfg.family == "moe":
        # capacity truncation depends on token count; large factor makes
        # the layer effectively dropless for exact comparison
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params = MD.init(cfg, KEY)
    batch = MD.make_batch(cfg, InputShape("x", S + 1, B, "prefill"), KEY)
    toks = batch["tokens"]
    St = toks.shape[1]
    P = cfg.num_patches if cfg.family == "vlm" else 0
    full_logits, _ = T.forward(cfg, params, batch)

    pre = dict(batch)
    pre["tokens"] = toks[:, :St - 1]
    logits_p, _, cache = T.forward(cfg, params, pre, return_cache=True,
                                   cache_len=P + St + 8)
    np.testing.assert_allclose(
        np.asarray(full_logits[:, P + St - 2]), np.asarray(logits_p[:, -1]),
        rtol=1e-4, atol=1e-4)

    logits_d, cache2 = T.decode_step(cfg, params, cache, toks[:, St - 1:St])
    np.testing.assert_allclose(
        np.asarray(full_logits[:, P + St - 1]), np.asarray(logits_d[:, 0]),
        rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("arch", ["gemma2_2b", "mamba2_130m", "zamba2_1p2b"])
def test_multi_token_decode_matches_forward(arch):
    """Decode N tokens sequentially; every step must match the full pass."""
    cfg = get_config(arch, reduced=True)
    params = MD.init(cfg, KEY)
    batch = MD.make_batch(cfg, InputShape("x", S + 4, B, "prefill"), KEY)
    toks = batch["tokens"]
    St = toks.shape[1]
    full_logits, _ = T.forward(cfg, params, batch)
    pre = dict(batch)
    pre["tokens"] = toks[:, :St - 4]
    _, _, cache = T.forward(cfg, params, pre, return_cache=True, cache_len=St + 8)
    for k in range(4):
        pos = St - 4 + k
        logits_d, cache = T.decode_step(cfg, params, cache, toks[:, pos:pos + 1])
        np.testing.assert_allclose(
            np.asarray(full_logits[:, pos]), np.asarray(logits_d[:, 0]),
            rtol=2e-3, atol=2e-3)


def test_sliding_window_ring_buffer_correctness():
    """gemma2 local layers with cache window < sequence: ring-buffered
    decode must equal the full pass (which masks by window)."""
    cfg = get_config("gemma2_2b", reduced=True)  # sliding_window=16
    params = MD.init(cfg, KEY)
    n = 24  # > window
    batch = MD.make_batch(cfg, InputShape("x", n + 1, B, "prefill"), KEY)
    toks = batch["tokens"]
    full_logits, _ = T.forward(cfg, params, batch)
    pre = {"tokens": toks[:, :n]}
    _, _, cache = T.forward(cfg, params, pre, return_cache=True, cache_len=n + 4)
    assert cache.attn["local"].k.shape[2] == cfg.sliding_window  # ring alloc
    logits_d, _ = T.decode_step(cfg, params, cache, toks[:, n:n + 1])
    np.testing.assert_allclose(
        np.asarray(full_logits[:, n]), np.asarray(logits_d[:, 0]),
        rtol=2e-3, atol=2e-3)


def test_scan_unroll_equivalence():
    for arch in ("gemma2_2b", "zamba2_1p2b", "granite_moe_3b_a800m"):
        cfg = get_config(arch, reduced=True)
        cfgu = dataclasses.replace(cfg, scan_layers=False)
        params = MD.init(cfg, KEY)
        batch = MD.make_batch(cfg, InputShape("x", 16, B, "train"), KEY)
        l1, _ = T.forward(cfg, params, batch)
        l2, _ = T.forward(cfgu, params, batch)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=1e-3, atol=1e-3)
