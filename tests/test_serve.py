"""repro.serve: EDF admission queue, slot-batched scheduling, the
double-buffered AnytimeServer loop, deadline edges, and solo-session
parity (the subsystem's acceptance criterion)."""
import numpy as np
import pytest

from repro.core import engine
from repro.forest import make_dataset, split_dataset, train_forest
from repro.schedule import AnytimeRuntime, ForestProgram
from repro.serve import AdmissionQueue, AdmissionRejected, AnytimeServer, Request
from repro.serve.scheduler import ForestLane, SessionLane


class ManualClock:
    """Monotonic clock under test control (seconds)."""

    def __init__(self, t: float = 100.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance_ms(self, ms: float) -> None:
        self.t += ms / 1e3


@pytest.fixture(scope="module")
def pipeline():
    X, y = make_dataset("magic", seed=1)
    (tr, ytr), (orx, yor), (te, yte) = split_dataset(X, y, seed=1)
    rf = train_forest(tr[:800], ytr[:800], 2, n_trees=4, max_depth=5, seed=1)
    fa = rf.as_arrays()
    pp = engine.path_probs_np(fa, orx[:200])
    return fa, pp, yor[:200], te, yte


@pytest.fixture(scope="module")
def runtime(pipeline):
    fa, pp, yor, te, yte = pipeline
    # X_order supplies the program's input width (the quality table
    # itself comes from the precomputed path_probs)
    return AnytimeRuntime(
        ForestProgram(fa, y_order=yor, path_probs=pp, X_order=te[:8]))


def _solo(runtime, x_row, order, steps):
    """The jnp-ref oracle: a solo session advanced ``steps`` steps."""
    sess = runtime.session(np.asarray(x_row)[None, :], order=order, backend="jnp-ref")
    sess.advance(steps)
    return sess


# ---------------------------------------------------------------------------
# AdmissionQueue
# ---------------------------------------------------------------------------


def test_queue_stamps_monotonic_deadlines_and_pops_edf():
    q = AdmissionQueue()
    a = q.submit(Request(x=None, deadline_ms=50.0), now=10.0)
    b = q.submit(Request(x=None, deadline_ms=5.0), now=10.0)
    c = q.submit(Request(x=None, deadline_ms=20.0), now=10.0)
    assert (a.request_id, b.request_id, c.request_id) == (0, 1, 2)
    assert b.t_deadline == pytest.approx(10.005)
    assert [q.pop() for _ in range(3)] == [b, c, a]  # earliest deadline first
    assert q.pop() is None and not q


def test_queue_rejects_negative_deadline():
    with pytest.raises(ValueError, match="deadline_ms"):
        AdmissionQueue().submit(Request(x=None, deadline_ms=-1.0), now=0.0)


# ---------------------------------------------------------------------------
# SessionBatch: the slot-state surface
# ---------------------------------------------------------------------------


def test_session_batch_masks_inactive_slots(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    sb = runtime.program.make_slot_batch(order, 4, te.shape[1], backend="jnp-ref")
    sb.admit(1, te[0])
    idx_before = np.asarray(sb.idx)
    for _ in range(4):
        sb.advance_segment()
    idx_after = np.asarray(sb.idx)
    # only slot 1 moved; empty slots are bit-frozen
    for s in (0, 2, 3):
        np.testing.assert_array_equal(idx_after[s], idx_before[s])
    assert (idx_after[1] != idx_before[1]).any()
    assert sb.pos[1] > 0 and (sb.pos[[0, 2, 3]] == 0).all()


def test_session_batch_lockstep_and_trace_bound(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    sb = runtime.program.make_slot_batch(order, 4, te.shape[1], backend="jnp-ref")
    sb.admit(0, te[0])
    sb.advance_segment()
    sb.advance_segment()
    sb.admit(2, te[1])  # joins mid-flight, out of phase
    while sb.stepping_slots().size:
        L = sb.advance_segment()
        assert L & (L - 1) == 0  # every dispatch a power of two
    assert sb.pos[0] == sb.pos[2] == sb.total_steps
    assert len(sb.dispatched_lengths) <= 8
    with pytest.raises(ValueError, match="occupied"):
        sb.admit(0, te[0])


def test_session_batch_rejects_wrong_width(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    sb = runtime.program.make_slot_batch(
        runtime.order("depth"), 2, te.shape[1], backend="jnp-ref")
    with pytest.raises(ValueError, match="features"):
        sb.admit(0, te[0][:3])


# ---------------------------------------------------------------------------
# Parity: every served prediction == solo jnp-ref at the same step count
# (the subsystem acceptance criterion), across all three backends.
# ---------------------------------------------------------------------------


BACKEND_OPTS = {
    "jnp-ref": {},
    "pallas": {"block_b": 16, "block_m": 8},
    "sharded": {},
}


@pytest.mark.parametrize("backend", ["jnp-ref", "pallas", "sharded"])
def test_served_results_match_solo_oracle(backend, runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    server = AnytimeServer(
        runtime, capacity=3, backend_opts=BACKEND_OPTS[backend])
    results = server.serve(
        [te[i] for i in range(7)], deadline_ms=60_000.0, backend=backend)
    assert len(results) == 7
    for i, r in enumerate(results):
        assert r.completed and r.deadline_hit
        assert r.steps_completed == r.total_steps == len(order)
        solo = _solo(runtime, te[i], order, r.steps_completed)
        np.testing.assert_array_equal(r.prediction, solo.predict()[0])
        if backend == "pallas":
            # prob_accum associates float sums differently; state parity
            # is exact, readout to kernel tolerance (as in test_backends)
            np.testing.assert_allclose(
                r.proba, solo.predict_proba()[0], rtol=1e-5, atol=1e-5)
        else:
            np.testing.assert_array_equal(r.proba, solo.predict_proba()[0])
    assert server.metrics.snapshot()["deadline_hit_rate"] == 1.0


def test_mid_flight_admission_joins_at_segment_boundary(runtime, pipeline):
    """A request admitted after the batch started executes its own full
    prefix (out of phase with resident slots) and stays solo-exact."""
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    clk = ManualClock()
    server = AnytimeServer(runtime, capacity=4, clock=clk)
    early = [server.submit(te[i], 1e9) for i in range(2)]
    for _ in range(4):
        server.step()
    lane = next(iter(server.scheduler.lanes.values()))
    pos_before = lane.batch.pos.copy()
    assert lane.n_active == 2 and 0 < pos_before[:2].min() < lane.batch.total_steps
    late = [server.submit(te[i], 1e9) for i in range(2, 4)]
    server.step()
    # the late requests occupy recycled slots at position < residents'
    assert lane.n_active == 4
    assert lane.batch.pos[2:4].max() < lane.batch.pos[:2].min()
    server.drain()
    for i, t in enumerate(early + late):
        r = t.result()
        assert r.completed
        solo = _solo(runtime, te[i], order, r.steps_completed)
        np.testing.assert_array_equal(r.proba, solo.predict_proba()[0])


# ---------------------------------------------------------------------------
# Deadline edges
# ---------------------------------------------------------------------------


def test_deadline_expiry_mid_flight_returns_previous_boundary(runtime, pipeline):
    """A request whose deadline fires mid-segment gets the last
    host-completed boundary readout — bit-identical to a solo jnp-ref
    session advanced that same number of steps — never a torn state."""
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    clk = ManualClock()
    server = AnytimeServer(runtime, capacity=2, clock=clk)
    ticket = server.submit(te[0], deadline_ms=50.0)
    # let several boundaries harvest while the deadline is far away
    for _ in range(5):
        server.step()
    lane = next(iter(server.scheduler.lanes.values()))
    assert 0 < lane.batch.pos[0] < lane.batch.total_steps  # genuinely mid-flight
    clk.advance_ms(60.0)  # deadline fires between boundaries
    server.drain()
    r = ticket.result()
    assert not r.completed and r.deadline_hit
    assert 0 < r.steps_completed < r.total_steps
    solo = _solo(runtime, te[0], order, r.steps_completed)
    np.testing.assert_array_equal(r.proba, solo.predict_proba()[0])
    np.testing.assert_array_equal(r.prediction, solo.predict()[0])


def test_zero_deadline_returns_prior_immediately(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=2)
    ticket = server.submit(te[0], deadline_ms=0.0)
    server.step()  # one iteration suffices — no execution needed
    assert ticket.done
    r = ticket.result()
    assert r.steps_completed == 0 and not r.completed and not r.deadline_hit
    np.testing.assert_array_equal(r.proba, runtime.program.prior_readout())
    # the prior equals the all-roots readout a 0-step solo session gives
    solo = _solo(runtime, te[0], runtime.order("backward_squirrel"), 0)
    np.testing.assert_array_equal(r.proba, solo.predict_proba()[0])


def test_request_starved_in_full_lane_expires_to_prior(runtime, pipeline):
    """EDF admission: when the lane is full, a queued request whose
    deadline passes before a slot frees gets the prior readout."""
    fa, pp, yor, te, yte = pipeline
    clk = ManualClock()
    server = AnytimeServer(runtime, capacity=1, clock=clk)
    long_t = server.submit(te[0], deadline_ms=1e9)
    server.step()   # te[0] occupies the only slot
    starved = server.submit(te[1], deadline_ms=5.0)
    server.step()   # lane full -> te[1] stays queued
    clk.advance_ms(10.0)
    server.step()   # deadline passed while queued -> prior delivery
    r = starved.result()
    assert r.steps_completed == 0 and not r.deadline_hit
    np.testing.assert_array_equal(r.proba, runtime.program.prior_readout())
    server.drain()
    assert long_t.result().completed


def test_reject_admission_sheds_load_at_submit(runtime, pipeline):
    """admission="reject": once the backlog reaches capacity*k, submit
    raises AdmissionRejected instead of enqueueing a request the EDF
    queue would starve to a prior readout."""
    fa, pp, yor, te, yte = pipeline
    clk = ManualClock()
    server = AnytimeServer(runtime, capacity=1, clock=clk,
                           admission="reject", admission_k=2.0)
    accepted = [server.submit(te[i], deadline_ms=1e9) for i in range(2)]
    with pytest.raises(AdmissionRejected, match="backlog"):
        server.submit(te[2], deadline_ms=1e9)
    # nothing about the rejected request leaked into the server
    assert len(server._pending) == 2
    server.drain()
    for t in accepted:
        assert t.result().completed
    # backlog drained -> admission opens again
    assert server.submit(te[3], deadline_ms=1e9) is not None
    server.drain()


def test_reject_admission_starvation_regression(runtime, pipeline):
    """The starvation regression the knob exists for: oversubscribed
    under EDF, late-generation requests starve to 0-step prior readouts;
    under reject, every ADMITTED request is served with >= 1 step (and
    the shed load is visible at submit, not as silent degradation)."""
    fa, pp, yor, te, yte = pipeline
    n_requests, deadline_ms = 12, 40.0

    def flood(server, clk):
        tickets, rejected = [], 0
        for i in range(n_requests):
            try:
                tickets.append(server.submit(te[i % te.shape[0]], deadline_ms))
            except AdmissionRejected:
                rejected += 1
        for _ in range(3):       # a few boundaries complete...
            server.step()
        clk.advance_ms(deadline_ms + 1.0)  # ...then every deadline fires
        server.drain()
        return [t.result() for t in tickets], rejected

    clk = ManualClock()
    edf_results, edf_rejected = flood(
        AnytimeServer(runtime, capacity=2, clock=clk), clk)
    assert edf_rejected == 0 and len(edf_results) == n_requests
    # EDF accepts everyone and starves the tail to 0-step priors
    assert sum(r.steps_completed == 0 for r in edf_results) > 0

    clk = ManualClock()
    rej_results, rej_rejected = flood(
        AnytimeServer(runtime, capacity=2, clock=clk,
                      admission="reject", admission_k=1.0), clk)
    assert rej_rejected > 0                      # load visibly shed
    assert all(r.steps_completed > 0 for r in rej_results)  # no starvation
    assert all(r.deadline_hit for r in rej_results)


def test_reject_admission_is_per_lane(runtime, pipeline):
    """Flooding one (program, policy, backend) lane must not shed load
    for an idle lane: the backlog bound is per-lane, not server-global."""
    fa, pp, yor, te, yte = pipeline
    clk = ManualClock()
    server = AnytimeServer(runtime, capacity=1, clock=clk,
                           admission="reject", admission_k=1.0)
    server.submit(te[0], 1e9, policy="backward_squirrel")
    with pytest.raises(AdmissionRejected):
        server.submit(te[1], 1e9, policy="backward_squirrel")
    # a DIFFERENT lane (other policy) has zero backlog: still admitted
    other = server.submit(te[2], 1e9, policy="depth")
    server.drain()
    assert other.result().completed


def test_degrade_admission_shrinks_budgets_never_rejects(runtime, pipeline):
    """admission="degrade": overload shrinks per-request step budgets
    instead of rejecting; every delivered readout is still an exact
    prefix boundary — bit-identical to a solo session advanced the same
    number of steps (never torn)."""
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    total = len(order)
    server = AnytimeServer(runtime, capacity=2,
                           admission="degrade", admission_k=1.0)
    tickets = [server.submit(te[i % te.shape[0]], 1e9) for i in range(12)]
    server.drain()
    results = [t.result() for t in tickets]
    assert len(results) == 12                      # nothing rejected
    assert all(r.deadline_hit for r in results)    # nothing starved
    degraded = [r for r in results if r.degraded]
    assert degraded                                # pressure did shrink budgets
    for i, r in enumerate(results):
        assert 0 < r.budget_steps <= total
        assert r.steps_completed == r.budget_steps  # ran exactly to budget
        assert r.completed == (r.steps_completed >= total)
        solo = _solo(runtime, te[i % te.shape[0]], order, r.steps_completed)
        np.testing.assert_array_equal(r.proba, solo.predict_proba()[0])
        np.testing.assert_array_equal(r.prediction, solo.predict()[0])
    snap = server.metrics.snapshot()
    assert snap["degraded_requests"] == len(degraded)
    assert snap["budget_at_deadline"]["p50"] < total


def test_degrade_budgets_restore_when_pressure_clears(runtime, pipeline):
    """Budgets are stamped from the instantaneous backlog: once the
    flood drains, a fresh submission gets the full plan again."""
    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=2,
                           admission="degrade", admission_k=1.0)
    for i in range(10):
        server.submit(te[i % te.shape[0]], 1e9)
    server.drain()
    after = server.submit(te[0], 1e9)
    server.drain()
    r = after.result()
    assert not r.degraded and r.completed
    assert r.steps_completed == r.total_steps == r.budget_steps


def test_degrade_dominates_reject_on_hit_rate_at_equal_load(runtime, pipeline):
    """The frontier the policy exists for: at the same offered load,
    degrade answers every request with >= 1 step (hit) where reject
    sheds most of them at submit (miss from the caller's view)."""
    fa, pp, yor, te, yte = pipeline
    n = 12

    def flood(server):
        tickets, attempts = [], 0
        for i in range(n):
            attempts += 1
            try:
                tickets.append(server.submit(te[i % te.shape[0]], 1e9))
            except AdmissionRejected:
                pass
        server.drain()
        hits = sum(t.result().deadline_hit for t in tickets)
        return hits / attempts

    reject_rate = flood(AnytimeServer(
        runtime, capacity=2, admission="reject", admission_k=1.0))
    degrade_rate = flood(AnytimeServer(
        runtime, capacity=2, admission="degrade", admission_k=1.0))
    assert reject_rate < 1.0
    assert degrade_rate == 1.0
    assert degrade_rate > reject_rate


def test_session_batch_budget_caps_dispatch(runtime, pipeline):
    """A budget-capped slot stops dispatching at EXACTLY its budget (an
    arbitrary step index, not a segment boundary) while an uncapped
    neighbor runs the full plan."""
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    sb = runtime.program.make_slot_batch(order, 2, te.shape[1], backend="jnp-ref")
    budget = sb.total_steps // 2 + 1
    sb.admit(0, te[0], budget=budget)
    sb.admit(1, te[1])
    while sb.stepping_slots().size:
        sb.advance_segment()
    assert sb.pos[0] == budget
    assert sb.pos[1] == sb.total_steps
    # the capped slot's state is the exact budget-step prefix
    solo = _solo(runtime, te[0], order, budget)
    np.testing.assert_array_equal(
        np.asarray(sb.readout())[0], solo.predict_proba()[0])
    sb.retire(0)
    with pytest.raises(ValueError, match="budget"):
        sb.admit(0, te[0], budget=0)


def test_admission_knob_validated_eagerly(runtime):
    with pytest.raises(ValueError, match="admission"):
        AnytimeServer(runtime, admission="drop-tail")
    with pytest.raises(ValueError, match="admission_k"):
        AnytimeServer(runtime, admission="reject", admission_k=0)


def test_slot_recycling_many_requests_small_capacity(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=2)
    results = server.serve([te[i] for i in range(9)], deadline_ms=60_000.0)
    assert len(results) == 9 and all(r.completed for r in results)
    assert len(server.scheduler.lanes) == 1  # one (program, policy, backend) key
    snap = server.metrics.snapshot()
    assert snap["delivered"] == 9
    assert snap["deadline_hit_rate"] == 1.0
    assert snap["steps_at_deadline"]["p99"] == results[0].total_steps
    assert 0 < snap["slot_occupancy"] <= 1.0
    assert snap["requests_per_sec"] > 0


def test_distinct_policies_get_distinct_lanes(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=2)
    t1 = server.submit(te[0], 60_000.0, policy="backward_squirrel")
    t2 = server.submit(te[1], 60_000.0, policy="depth")
    server.drain()
    assert len(server.scheduler.lanes) == 2
    assert t1.result().completed and t2.result().completed


def test_unknown_program_raises_at_submit(runtime):
    server = AnytimeServer(runtime)
    with pytest.raises(ValueError, match="unknown program"):
        server.submit(np.zeros(3), 10.0, program="nope")
    assert not server.busy  # nothing enqueued


def test_malformed_request_fails_alone(runtime, pipeline):
    """One unservable request (wrong feature width) gets an error
    result; its well-formed neighbors are served normally — the loop
    must neither crash nor drop anyone."""
    fa, pp, yor, te, yte = pipeline
    order = runtime.order("backward_squirrel")
    server = AnytimeServer(runtime, capacity=2)
    good_a = server.submit(te[0], 60_000.0)
    bad = server.submit(te[1][:3], 60_000.0)     # wrong width
    good_b = server.submit(te[2], 60_000.0)
    server.drain()
    rb = bad.result()
    assert rb.error is not None and "features" in rb.error
    assert not rb.deadline_hit and rb.steps_completed == 0
    # best-available-answer semantics: even an unservable request gets
    # the program's prior readout alongside its error
    np.testing.assert_array_equal(rb.proba, runtime.program.prior_readout())
    for i, t in ((0, good_a), (2, good_b)):
        r = t.result()
        assert r.completed and r.error is None
        solo = _solo(runtime, te[i], order, r.steps_completed)
        np.testing.assert_array_equal(r.proba, solo.predict_proba()[0])


def test_malformed_first_request_cannot_poison_lane(runtime, pipeline):
    """Lane width comes from the program, not the first request: a
    wrong-width FIRST request errors alone and later correct requests
    are served through the properly-sized lane."""
    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=2)
    bad = server.submit(te[0][:3], 60_000.0)     # wrong width, arrives first
    good = server.submit(te[1], 60_000.0)
    server.drain()
    assert bad.result().error is not None
    r = good.result()
    assert r.completed and r.error is None
    solo = _solo(runtime, te[1], runtime.order("backward_squirrel"),
                 r.steps_completed)
    np.testing.assert_array_equal(r.proba, solo.predict_proba()[0])


def test_results_live_on_tickets_not_in_server(runtime, pipeline):
    """Long-lived servers must not accumulate delivered results: the
    server tracks only pending tickets; delivery moves the result onto
    the ticket (and drain()'s return list), so dropping both frees it."""
    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=2)
    tickets = [server.submit(te[i], 60_000.0) for i in range(4)]
    assert len(server._pending) == 4
    drained = server.drain()
    assert len(server._pending) == 0          # nothing retained server-side
    assert len(drained) == 4
    results = [t.result() for t in tickets]
    assert all(r.completed for r in results)
    assert tickets[0].result() is results[0]  # idempotent


def test_idle_lanes_evicted_beyond_cap(runtime, pipeline):
    """Clients cycling through many policy configs must not grow device
    state without bound: LRU idle lanes drop past max_idle_lanes.
    Configured policy VALUES key lanes (cache_key includes the seed), so
    four seeds of 'random' make four distinct lanes, sequentially idle."""
    from repro.schedule import get_order_policy

    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=1)
    server.scheduler.max_idle_lanes = 2
    for seed in range(4):
        server.submit(te[0], 60_000.0, policy=get_order_policy("random", seed=seed))
        server.drain()
    assert len(server.scheduler.lanes) <= 2


def test_zero_deadline_builds_no_lane(runtime, pipeline):
    """An already-expired request is answered from the prior readout
    without paying order generation or slot-batch construction."""
    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=2)
    t = server.submit(te[0], deadline_ms=0.0, policy="depth")
    server.step()
    assert t.done and len(server.scheduler.lanes) == 0


def test_default_and_explicit_backend_share_a_lane(runtime, pipeline):
    """backend=None canonicalizes to the resolved default: no duplicate
    slot batches / jit traces for the same execution path."""
    from repro.schedule import default_backend

    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=2)
    server.submit(te[0], 60_000.0)                              # unset
    server.submit(te[1], 60_000.0, backend=default_backend())   # explicit
    server.drain()
    assert len(server.scheduler.lanes) == 1


def test_metrics_reset_scopes_snapshot(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=2)
    server.serve([te[0], te[1]], deadline_ms=60_000.0)  # "warmup"
    server.metrics.reset()
    server.serve([te[2]], deadline_ms=60_000.0)
    snap = server.metrics.snapshot()
    assert snap["submitted"] == 1 and snap["delivered"] == 1


# ---------------------------------------------------------------------------
# Program-agnostic serving: a non-forest program goes through the same
# loop via a SessionLane (solo sessions, same EDF + deadline semantics).
# ---------------------------------------------------------------------------


class _CountingSession:
    """Deterministic fake step backend: state == steps taken."""

    def __init__(self, order, inputs):
        self.order = np.asarray(order)
        self.inputs = inputs
        self.pos = 0

    @property
    def total_steps(self):
        return len(self.order)

    @property
    def remaining(self):
        return self.total_steps - self.pos

    def advance(self, k):
        k = min(k, self.remaining)
        self.pos += k
        return k

    def predict_proba(self):
        return np.asarray([[float(self.pos), float(self.inputs)]])

    def predict(self):
        return self.predict_proba().argmax(axis=1)


class _CountingProgram:
    """Minimal AnytimeProgram WITHOUT make_slot_batch -> SessionLane."""

    n_units = 2
    unit_steps = 3

    def quality_table(self):
        rng = np.random.default_rng(0)
        return rng.random((8, 2, 4, 2)).astype(np.float32), rng.integers(0, 2, 8)

    def make_session(self, order, inputs):
        return _CountingSession(order, inputs)


def test_generic_program_serves_through_session_lane():
    rt = AnytimeRuntime(_CountingProgram())
    clk = ManualClock()
    server = AnytimeServer(rt, capacity=4, chunk=2, clock=clk)
    done = server.submit(7.0, deadline_ms=1e9)
    expiring = server.submit(9.0, deadline_ms=25.0)
    server.step()
    lane = next(iter(server.scheduler.lanes.values()))
    assert isinstance(lane, SessionLane)
    server.step()  # both advanced chunk=2 twice -> boundary steps == 4
    clk.advance_ms(30.0)
    server.drain()
    r_done, r_exp = done.result(), expiring.result()
    assert r_done.completed and r_done.steps_completed == 6
    np.testing.assert_array_equal(r_done.proba, [[6.0, 7.0]])
    # the expired request returns the boundary BEFORE its deadline fired
    assert not r_exp.completed and 0 < r_exp.steps_completed < 6
    np.testing.assert_array_equal(
        r_exp.proba, [[float(r_exp.steps_completed), 9.0]])


def test_forest_lane_used_for_forest_programs(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=2)
    server.submit(te[0], 60_000.0)
    server.drain()
    assert isinstance(next(iter(server.scheduler.lanes.values())), ForestLane)


def test_multi_program_server(runtime, pipeline):
    """One server, two programs (forest + generic) — the ISSUE's
    program-agnostic claim, behind one queue and metrics object."""
    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(
        programs={"forest": runtime, "counter": AnytimeRuntime(_CountingProgram())},
        capacity=2,
    )
    tf = server.submit(te[0], 60_000.0, program="forest")
    tc = server.submit(3.0, 60_000.0, program="counter")
    server.drain()
    assert tf.result().completed and tc.result().completed
    assert server.metrics.snapshot()["delivered"] == 2
    assert len(server.scheduler.lanes) == 2


# ---------------------------------------------------------------------------
# Metrics reservoirs: exact below capacity, bounded and uniform beyond
# ---------------------------------------------------------------------------


def test_reservoir_exact_below_capacity():
    from repro.serve import Reservoir

    r = Reservoir(capacity=100, seed=0)
    values = [float(i) for i in range(60)]
    for v in values:
        r.add(v)
    # below capacity the sample IS the population — no sampling at all
    assert r.exact and r.count == 60 and len(r) == 60
    assert r.values() == values


def test_reservoir_bounded_beyond_capacity():
    from repro.serve import Reservoir

    r = Reservoir(capacity=64, seed=0)
    for i in range(1000):
        r.add(float(i))
    assert not r.exact
    assert r.count == 1000 and len(r) == 64  # memory stays O(capacity)
    kept = r.values()
    assert all(0.0 <= v < 1000.0 for v in kept)
    # Algorithm R keeps late arrivals with uniform probability — a
    # broken reservoir that stops replacing would hold only 0..63
    assert max(kept) >= 64.0
    # seeded: identical streams give identical samples
    r2 = Reservoir(capacity=64, seed=0)
    for i in range(1000):
        r2.add(float(i))
    assert r2.values() == kept


def test_reservoir_rejects_nonpositive_capacity():
    from repro.serve import Reservoir

    with pytest.raises(ValueError, match="capacity"):
        Reservoir(capacity=0)


def test_metrics_percentiles_exact_below_reservoir():
    """Below the reservoir bound, snapshot percentiles equal the exact
    percentiles of the full delivery population, the snapshot says so
    (``percentiles_exact``), and its cost is O(reservoir)."""
    from repro.serve import Result, ServeMetrics

    m = ServeMetrics(reservoir=256)
    steps = [int(s) for s in np.random.default_rng(7).integers(0, 48, 100)]
    lat = [float(v) for v in np.random.default_rng(8).uniform(0.1, 9.0, 100)]
    for i, (s, ms) in enumerate(zip(steps, lat)):
        m.record_delivery(Result(
            request_id=i, prediction=0, proba=None, steps_completed=s,
            total_steps=48, completed=False, deadline_hit=s > 0,
            latency_ms=ms), now=float(i))
    snap = m.snapshot()
    assert snap["percentiles_exact"]
    assert snap["steps_at_deadline"]["p50"] == pytest.approx(
        float(np.percentile(steps, 50)))
    assert snap["steps_at_deadline"]["p99"] == pytest.approx(
        float(np.percentile(steps, 99)))
    assert snap["latency_ms"]["p99"] == pytest.approx(
        float(np.percentile(lat, 99)))
    assert snap["latency_ms"]["mean"] == pytest.approx(
        float(np.mean(lat)))


def test_metrics_snapshot_bounded_under_heavy_traffic():
    from repro.serve import Result, ServeMetrics

    m = ServeMetrics(reservoir=128)
    for i in range(5000):
        m.record_delivery(Result(
            request_id=i, prediction=0, proba=None, steps_completed=i % 48,
            total_steps=48, completed=False, deadline_hit=True,
            latency_ms=float(i % 7)), now=float(i))
    snap = m.snapshot()
    assert snap["delivered"] == 5000
    assert not snap["percentiles_exact"]
    assert len(m.steps_at_deadline) == 128  # O(reservoir), not O(traffic)
    assert len(m.latency_ms) == 128
    assert 0.0 <= snap["steps_at_deadline"]["p50"] < 48.0
