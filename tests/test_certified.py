"""Certified serving: the WCET cost model, the admission-policy
registry, deadline certification at submit, the guaranteed priority /
steal rules, predicted-pressure degrade budgets, and the unified QoS
submit surface (with its legacy-kwarg deprecation shim)."""
import dataclasses
import heapq
import json

import numpy as np
import pytest

from repro.core import engine
from repro.forest import make_dataset, split_dataset, train_forest
from repro.obs import NULL_TRACER
from repro.schedule import AnytimeRuntime, ForestProgram
from repro.serve import (
    LAG_ITERATIONS,
    AdmissionPolicy,
    AnytimeServer,
    CertificationFailed,
    CostModel,
    CostModelError,
    PooledAnytimeServer,
    QoS,
    Request,
    get_admission_policy,
    list_admissions,
    register_admission,
    resolve_qos,
)
from repro.serve.admission import _REGISTRY
from repro.serve.cost import WCET_DIR_ENV
from repro.serve.router import Router
from repro.serve.scheduler import _plan_lengths, _waiting_entry


class ManualClock:
    """Monotonic clock under test control (seconds)."""

    def __init__(self, t: float = 100.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance_ms(self, ms: float) -> None:
        self.t += ms / 1e3


@pytest.fixture(scope="module")
def pipeline():
    X, y = make_dataset("magic", seed=1)
    (tr, ytr), (orx, yor), (te, yte) = split_dataset(X, y, seed=1)
    rf = train_forest(tr[:800], ytr[:800], 2, n_trees=4, max_depth=5, seed=1)
    fa = rf.as_arrays()
    pp = engine.path_probs_np(fa, orx[:200])
    return fa, pp, yor[:200], te, yte


@pytest.fixture(scope="module")
def runtime(pipeline):
    fa, pp, yor, te, yte = pipeline
    return AnytimeRuntime(
        ForestProgram(fa, y_order=yor, path_probs=pp, X_order=te[:8]))


def make_table(margin=2.0, platform="cpu", backends=("jnp-ref",),
               lengths=(1, 2, 4, 8, 16, 32, 64), base=8.0, harvest=4.0):
    """Synthetic WCET table: wcet_ms = base * L per cell (non-decreasing
    in length, as the model assumes), covering every pow2 dispatch
    length a small test plan can emit."""
    cells = {}
    for b in backends:
        for length in lengths:
            w = base * length
            cells[f"{b}/{b}/L{length}"] = {
                "count": 3, "mean_ms": w / margin, "p95_ms": w / margin,
                "max_ms": w / margin, "wcet_ms": w,
            }
    return {
        "schema_version": 1, "platform": platform, "margin": margin,
        "cells": cells,
        "harvest": {"count": 3, "mean_ms": harvest / margin,
                    "max_ms": harvest / margin, "wcet_ms": harvest},
    }


# ---------------------------------------------------------------------------
# CostModel: pricing from the calibrated table
# ---------------------------------------------------------------------------


def test_cost_model_maxes_cells_across_impls():
    table = make_table()
    table["cells"]["jnp-ref/tuned/L4"] = {
        "count": 1, "mean_ms": 50.0, "p95_ms": 50.0, "max_ms": 50.0,
        "wcet_ms": 99.0}
    cm = CostModel(table)
    # the tuner may pick any impl at dispatch time: worst across impls
    assert cm.segment_wcet_ms("jnp-ref", 4) == 99.0
    assert cm.backends() == ("jnp-ref",)
    assert cm.lengths("jnp-ref") == (1, 2, 4, 8, 16, 32, 64)


def test_cost_model_monotone_fallback_and_unpriceable():
    cm = CostModel(make_table(lengths=(1, 4)))
    # an uncalibrated length prices at the smallest calibrated length
    # at or above it (dispatch cost non-decreasing in length)
    assert cm.segment_wcet_ms("jnp-ref", 3) == cm.segment_wcet_ms("jnp-ref", 4)
    with pytest.raises(CostModelError, match="unpriceable"):
        cm.segment_wcet_ms("jnp-ref", 8)
    with pytest.raises(CostModelError, match="no calibrated"):
        cm.segment_wcet_ms("pallas", 1)
    with pytest.raises(CostModelError, match="no calibrated"):
        cm.lengths("pallas")


def test_cost_model_pricing_formula():
    cm = CostModel(make_table(base=8.0, harvest=4.0))
    # rate = max over L of (8L + 4)/L, maximized at L=1
    assert cm.step_rate_ms("jnp-ref") == 12.0
    assert cm.step_rate_ms("jnp-ref", lengths=(4,)) == (8 * 4 + 4) / 4
    # one iteration: the worst dispatch (L=64) plus a harvest
    assert cm.iteration_wcet_ms("jnp-ref") == 8 * 64 + 4
    expect = 10 * 12.0 + LAG_ITERATIONS * (8 * 64 + 4)
    assert cm.request_wcet_ms(10, backend="jnp-ref") == expect
    # wait adds linearly; interference charges every step AND lag iter
    assert cm.request_wcet_ms(
        10, backend="jnp-ref", interference_ms=1.0, wait_ms=5.0
    ) == pytest.approx(5.0 + expect + (10 + LAG_ITERATIONS) * 1.0)


def test_cost_model_rejects_broken_tables():
    with pytest.raises(CostModelError, match="margin"):
        CostModel(make_table(margin=0.5))
    bad = make_table()
    bad["harvest"] = {"count": 0, "wcet_ms": 0.0}
    with pytest.raises(CostModelError, match="harvest"):
        CostModel(bad)
    bad = make_table()
    bad["cells"]["garbage-key"] = {"wcet_ms": 1.0}
    with pytest.raises(CostModelError, match="malformed"):
        CostModel(bad)
    bad = make_table()
    bad["cells"]["jnp-ref/jnp-ref/L2"]["wcet_ms"] = 0.0
    with pytest.raises(CostModelError, match="wcet_ms"):
        CostModel(bad)


def test_cost_model_load_uses_env_dir_and_fails_with_hint(
        tmp_path, monkeypatch):
    table = make_table(platform="fpga")
    (tmp_path / "wcet_fpga.json").write_text(json.dumps(table))
    monkeypatch.setenv(WCET_DIR_ENV, str(tmp_path))
    cm = CostModel.load(platform="fpga")
    assert cm.platform == "fpga" and cm.step_rate_ms("jnp-ref") == 12.0
    with pytest.raises(CostModelError, match="tools.obs calibrate"):
        CostModel.load(platform="missing")


# ---------------------------------------------------------------------------
# Admission-policy registry
# ---------------------------------------------------------------------------


def test_registry_lists_policies_in_registration_order():
    assert list_admissions() == ("edf", "reject", "degrade", "certified")


def test_registry_instantiates_stamps_name_and_passes_instances_through():
    pol = get_admission_policy("degrade")
    assert pol.name == "degrade" and not pol.fast_path
    assert get_admission_policy("edf").fast_path
    assert get_admission_policy("certified").certify_all
    assert get_admission_policy(pol) is pol  # instance passthrough


def test_registry_unknown_name_lists_registered():
    with pytest.raises(ValueError, match="unknown admission.*edf"):
        get_admission_policy("nope")


def test_registry_rejects_duplicates_and_unknown_bound_fields():
    with pytest.raises(ValueError, match="already registered"):
        register_admission("edf")(AdmissionPolicy)
    with pytest.raises(TypeError, match="no config field"):

        @register_admission("fx-bad-bound", nope=1)
        @dataclasses.dataclass
        class _Bad(AdmissionPolicy):
            """doc."""

    assert "fx-bad-bound" not in _REGISTRY


def test_server_resolves_admission_at_construction(runtime):
    with pytest.raises(ValueError, match="unknown admission"):
        AnytimeServer(runtime, capacity=2, admission="typo")


# ---------------------------------------------------------------------------
# Certification at submit
# ---------------------------------------------------------------------------


def test_guaranteed_submit_without_cost_model_fails_fast(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=2)
    with pytest.raises(CertificationFailed, match="CostModel.load"):
        server.submit(te[0], QoS(deadline_ms=1e6, guaranteed=True))
    assert server.metrics.snapshot()["certified_rejected"] == 1


def test_certified_wave_completes_and_stamps_certificates(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    clk = ManualClock()
    cm = CostModel(make_table())
    server = AnytimeServer(runtime, capacity=4, clock=clk, cost_model=cm)
    qos = QoS(deadline_ms=1e9, backend="jnp-ref", guaranteed=True)
    tickets = [server.submit(te[i], qos) for i in range(4)]
    for t in tickets:
        assert t.request.wcet_ms is not None and t.request.wcet_ms > 0
    server.drain()
    order = runtime.order("backward_squirrel")
    for i, t in enumerate(tickets):
        r = t.result()
        assert r.guaranteed and r.completed
        solo = runtime.session(np.asarray(te[i])[None, :], order=order,
                               backend="jnp-ref")
        solo.advance(r.steps_completed)
        np.testing.assert_array_equal(r.proba, solo.predict_proba()[0])
    snap = server.metrics.snapshot()
    assert snap["certified_admitted"] == 4
    assert snap["guaranteed_delivered"] == 4
    assert snap["guaranteed_misses"] == 0


def test_infeasible_deadline_rejected_with_priced_bound(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    cm = CostModel(make_table())
    server = AnytimeServer(
        runtime, capacity=2, clock=ManualClock(), cost_model=cm)
    with pytest.raises(CertificationFailed, match="priced worst case") as ei:
        server.submit(te[0], QoS(deadline_ms=0.5, backend="jnp-ref",
                                 guaranteed=True))
    e = ei.value
    assert e.wcet_ms is not None and e.wcet_ms > e.deadline_ms == 0.5
    assert f"{e.wcet_ms:.3f}" in str(e)  # the priced bound, caller-visible
    snap = server.metrics.snapshot()
    assert snap["certified_rejected"] == 1 and snap["certified_admitted"] == 0


def test_certify_formula_prices_wait_interference_and_lag(runtime, pipeline):
    """The stamped certificate is exactly wait + steps*(rate+I) +
    LAG_ITERATIONS*(iter+I) — cross-lane interference from the busy
    sibling lane, zero slot wait on the fresh lane."""
    fa, pp, yor, te, yte = pipeline
    clk = ManualClock()
    cm = CostModel(make_table())
    server = AnytimeServer(runtime, capacity=2, clock=clk, cost_model=cm)
    # make the backward_squirrel lane busy
    server.submit(te[0], QoS(deadline_ms=1e9, backend="jnp-ref",
                             guaranteed=True))
    server.step()
    # certify onto a DIFFERENT (fresh) lane: the depth-order plan
    t2 = server.submit(te[1], QoS(deadline_ms=1e9, policy="depth",
                                  backend="jnp-ref", guaranteed=True))
    lane = server.scheduler.lane_for(t2.request)
    steps = server.scheduler.total_steps(t2.request)
    rate = cm.step_rate_ms("jnp-ref", _plan_lengths(lane.batch.plan))
    interference = cm.iteration_wcet_ms("jnp-ref")  # the busy sibling
    iter_ms = cm.iteration_wcet_ms("jnp-ref")
    expect = (steps * (rate + interference)
              + LAG_ITERATIONS * (iter_ms + interference))
    assert t2.request.wcet_ms == pytest.approx(expect)
    server.drain()


def test_certify_counts_queued_guarantees_ahead(runtime, pipeline):
    """Back-to-back guaranteed submits must see each other: with one
    slot, the second certificate cannot pretend the slot is free."""
    fa, pp, yor, te, yte = pipeline
    cm = CostModel(make_table())
    server = AnytimeServer(
        runtime, capacity=1, clock=ManualClock(), cost_model=cm)
    server.submit(te[0], QoS(deadline_ms=1e9, backend="jnp-ref",
                             guaranteed=True))
    with pytest.raises(CertificationFailed, match="already waiting"):
        server.submit(te[1], QoS(deadline_ms=1e9, backend="jnp-ref",
                                 guaranteed=True))
    server.drain()
    # the slot freed: the same submit certifies now
    t = server.submit(te[1], QoS(deadline_ms=1e9, backend="jnp-ref",
                                 guaranteed=True))
    server.drain()
    assert t.result().completed


def test_certify_prices_occupied_slot_wait(runtime, pipeline):
    """With the only slot mid-flight, the occupant's remaining worst
    case is the floor of the wait: a deadline below wait+E rejects, one
    above admits."""
    fa, pp, yor, te, yte = pipeline
    clk = ManualClock()
    cm = CostModel(make_table())
    server = AnytimeServer(runtime, capacity=1, clock=clk, cost_model=cm)
    t1 = server.submit(te[0], QoS(deadline_ms=1e9, backend="jnp-ref",
                                  guaranteed=True))
    server.step()  # t1 occupies the slot
    lane = server.scheduler.lane_for(t1.request)
    assert lane.requests[0] is t1.request
    steps = server.scheduler.total_steps(t1.request)
    rate = cm.step_rate_ms("jnp-ref", _plan_lengths(lane.batch.plan))
    iter_ms = cm.iteration_wcet_ms("jnp-ref")
    exec_ms = steps * rate + LAG_ITERATIONS * iter_ms
    # wait >= one iteration (retire->readmit boundary): E + iter/2 is
    # provably infeasible, E + occupant's full remainder is provably fine
    with pytest.raises(CertificationFailed):
        server.submit(te[1], QoS(deadline_ms=exec_ms + iter_ms / 2,
                                 backend="jnp-ref", guaranteed=True))
    t2 = server.submit(
        te[1], QoS(deadline_ms=exec_ms + steps * rate + iter_ms + 1.0,
                   backend="jnp-ref", guaranteed=True))
    server.drain()
    assert t1.result().completed and t2.result().completed


def test_certified_admission_upgrades_every_request(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    cm = CostModel(make_table())
    server = AnytimeServer(runtime, capacity=2, clock=ManualClock(),
                           admission="certified", cost_model=cm)
    t = server.submit(te[0], QoS(deadline_ms=1e9, backend="jnp-ref"))
    assert t.request.guaranteed and t.request.wcet_ms is not None
    server.drain()
    r = t.result()
    assert r.guaranteed and r.completed
    assert server.metrics.snapshot()["certified_admitted"] == 1


# ---------------------------------------------------------------------------
# Guaranteed priority + steal rules
# ---------------------------------------------------------------------------


def test_guaranteed_outranks_best_effort_in_waiting_order():
    g = Request(x=None, deadline_ms=100.0, guaranteed=True)
    b = Request(x=None, deadline_ms=1.0)
    g.request_id, g.t_deadline = 1, 10.0   # later deadline...
    b.request_id, b.t_deadline = 0, 1.0
    assert _waiting_entry(g) < _waiting_entry(b)  # ...still outranks
    g2 = Request(x=None, deadline_ms=1.0, guaranteed=True)
    g2.request_id, g2.t_deadline = 2, 1.0
    assert _waiting_entry(g2) < _waiting_entry(g)  # EDF within the class


def _inject_waiting(server, req, request_id, t_deadline):
    req.request_id, req.t_deadline = request_id, t_deadline
    key = server.scheduler._lane_key(req)
    heapq.heappush(
        server.scheduler._waiting.setdefault(key, []), _waiting_entry(req))


def test_export_request_skips_guarantees_for_uncertified_thief(
        runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    clk = ManualClock()
    cm = CostModel(make_table())
    server = AnytimeServer(runtime, capacity=1, clock=clk, cost_model=cm)
    greq = QoS(deadline_ms=1e6, backend="jnp-ref",
               guaranteed=True).request(te[0])
    _inject_waiting(server, greq, 7, clk.t + 100.0)
    # a thief with no cost model may not receive a guarantee
    assert server.scheduler.export_request(clk.t, guaranteed_ok=False) is None
    rec = server.scheduler.export_request(clk.t, guaranteed_ok=True)
    assert rec is not None and rec.request is greq and rec.kind == "waiting"


def test_router_migrates_guarantee_only_onto_certifying_pool(
        runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    clk = ManualClock()
    cm = CostModel(make_table())
    victim = AnytimeServer(runtime, capacity=1, clock=clk, cost_model=cm)
    thief = AnytimeServer(runtime, capacity=1, clock=clk)  # no cost model
    router = Router([victim, thief], victim.metrics, NULL_TRACER)
    greq = QoS(deadline_ms=1e9, backend="jnp-ref",
               guaranteed=True).request(te[0])
    _inject_waiting(victim, greq, 11, clk.t + 1e6)
    # thief cannot price the remaining work: the guarantee stays home
    assert router._migrate(victim, thief) is False
    assert victim.scheduler.n_waiting == 1 and thief.scheduler.n_waiting == 0
    # a certifying thief re-proves the REMAINING deadline and takes it
    thief.cost_model = cm
    assert router._migrate(victim, thief) is True
    assert victim.scheduler.n_waiting == 0 and thief.scheduler.n_waiting == 1


def test_router_gives_guarantee_back_when_recertification_fails(
        runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    clk = ManualClock()
    cm = CostModel(make_table())
    victim = AnytimeServer(runtime, capacity=1, clock=clk, cost_model=cm)
    thief = AnytimeServer(runtime, capacity=1, clock=clk, cost_model=cm)
    router = Router([victim, thief], victim.metrics, NULL_TRACER)
    greq = QoS(deadline_ms=1e9, backend="jnp-ref",
               guaranteed=True).request(te[0])
    # nearly expired: exportable (deadline ahead of now) but the thief
    # cannot re-certify the remaining milliseconds
    _inject_waiting(victim, greq, 12, clk.t + 0.001)
    assert router._migrate(victim, thief) is False
    assert victim.scheduler.n_waiting == 1 and thief.scheduler.n_waiting == 0


def test_pooled_guaranteed_submits_complete_with_zero_misses(
        runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    cm = CostModel(make_table())
    srv = PooledAnytimeServer(runtime, pools=2, capacity=2,
                              clock=ManualClock(), cost_model=cm)
    qos = QoS(deadline_ms=1e9, backend="jnp-ref", guaranteed=True)
    tickets = [srv.submit(te[i], qos) for i in range(4)]
    srv.drain()
    assert all(t.result().completed and t.result().guaranteed
               for t in tickets)
    snap = srv.metrics.snapshot()
    assert snap["guaranteed_delivered"] == 4
    assert snap["guaranteed_misses"] == 0
    assert snap["certified_admitted"] == 4


# ---------------------------------------------------------------------------
# Predicted-pressure degrade budgets
# ---------------------------------------------------------------------------


def test_predicted_budget_prices_backlog_not_depth(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    cm = CostModel(make_table())
    server = AnytimeServer(runtime, capacity=2, clock=ManualClock(),
                           admission="degrade", cost_model=cm)
    req = QoS(deadline_ms=5_000.0, backend="jnp-ref").request(te[0])
    total = server.scheduler.total_steps(req)
    rate = cm.step_rate_ms("jnp-ref")

    def expect(backlog):
        wait = (backlog / 2) * total * rate
        left = 5_000.0 - wait
        return max(1, int(left / rate)) if left > 0 else 1

    for backlog in (2, 8, 50, 10_000):
        got = server.scheduler.predicted_budget(req, cm, backlog)
        assert got == expect(backlog)
    assert server.scheduler.predicted_budget(req, cm, 10_000) == 1
    # unpriceable lane -> None (caller falls back to observed depth)
    bad = QoS(deadline_ms=5_000.0, backend="jnp-ref").request(te[0])
    assert server.scheduler.predicted_budget(
        bad, CostModel(make_table(backends=("pallas",))), 8) is None


def test_degrade_never_touches_guaranteed_requests(runtime, pipeline):
    fa, pp, yor, te, yte = pipeline
    policy = get_admission_policy("degrade")
    req = QoS(deadline_ms=1.0, backend="jnp-ref",
              guaranteed=True).request(te[0])
    policy.on_submit(None, req)  # early-out: never reads the server
    assert req.budget_steps is None


# ---------------------------------------------------------------------------
# QoS + the legacy-kwarg deprecation shim
# ---------------------------------------------------------------------------


def test_qos_validates():
    with pytest.raises(ValueError, match="deadline_ms"):
        QoS(deadline_ms=-1.0)
    with pytest.raises(ValueError, match="budget_steps"):
        QoS(deadline_ms=1.0, budget_steps=0)
    with pytest.raises(ValueError, match="guaranteed"):
        QoS(deadline_ms=1.0, budget_steps=5, guaranteed=True)


def test_resolve_qos_surfaces():
    spec = QoS(deadline_ms=2.0, backend="pallas")
    assert resolve_qos(spec, None, None, None, None, None, None) is spec
    with pytest.raises(TypeError, match="not both"):
        resolve_qos(spec, None, "depth", None, None, None, None)
    with pytest.raises(TypeError, match="twice"):
        resolve_qos(3.0, 4.0, None, None, None, None, None)
    with pytest.raises(TypeError, match="deadline"):
        resolve_qos(None, None, None, None, None, None, None)
    with pytest.raises(TypeError, match="QoS"):
        resolve_qos(object(), None, None, None, None, None, None)
    with pytest.warns(DeprecationWarning, match="QoS"):
        built = resolve_qos(None, 7.0, "depth", "jnp-ref", None, 3, None)
    assert built == QoS(deadline_ms=7.0, policy="depth", backend="jnp-ref",
                        budget_steps=3)
    with pytest.warns(DeprecationWarning):
        bare = resolve_qos(9.0, None, None, None, None, None, None)
    assert bare == QoS(deadline_ms=9.0)


def test_legacy_submit_shim_byte_parity(runtime, pipeline):
    """The deprecated kwarg surface must serve byte-identical results
    to the QoS spec it shims onto."""
    fa, pp, yor, te, yte = pipeline
    server = AnytimeServer(runtime, capacity=2)
    with pytest.warns(DeprecationWarning, match="QoS"):
        t_old = server.submit(te[0], 60_000.0, policy="backward_squirrel",
                              backend="jnp-ref")
    t_new = server.submit(
        te[0], QoS(deadline_ms=60_000.0, backend="jnp-ref"))
    server.drain()
    r_old, r_new = t_old.result(), t_new.result()
    assert r_old.completed and r_new.completed
    assert r_old.steps_completed == r_new.steps_completed
    np.testing.assert_array_equal(r_old.proba, r_new.proba)
    np.testing.assert_array_equal(r_old.prediction, r_new.prediction)
