"""Optimizer, schedule, data pipeline and end-to-end training behaviour."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import get_config
from repro.data.pipeline import DataConfig, SyntheticLM, make_batches
from repro.training import optimizer as opt_lib
from repro.training.train import train_loop


def test_adamw_matches_manual_reference():
    """One AdamW step on a scalar-friendly problem vs hand computation."""
    cfg = opt_lib.AdamWConfig(lr=0.1, b1=0.9, b2=0.99, eps=1e-8,
                              weight_decay=0.0, grad_clip=1e9,
                              warmup_steps=0, total_steps=1, min_lr_ratio=1.0)
    params = {"w": jnp.asarray([2.0])}
    grads = {"w": jnp.asarray([0.5])}
    st = opt_lib.init_state(params)
    new_p, new_st, _ = opt_lib.apply_updates(cfg, params, grads, st)
    m = 0.1 * 0.5
    v = 0.01 * 0.25
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    expect = 2.0 - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    assert float(new_p["w"][0]) == pytest.approx(expect, rel=1e-5)


def test_grad_clipping():
    cfg = opt_lib.AdamWConfig(grad_clip=1.0, warmup_steps=0, weight_decay=0.0)
    params = {"w": jnp.ones(4)}
    grads = {"w": jnp.full(4, 100.0)}
    st = opt_lib.init_state(params)
    _, _, stats = opt_lib.apply_updates(cfg, params, grads, st)
    assert float(stats["grad_norm"]) == pytest.approx(200.0, rel=1e-4)


def test_lr_schedule_shape():
    cfg = opt_lib.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                              min_lr_ratio=0.1)
    lrs = [float(opt_lib.lr_at(cfg, jnp.asarray(s))) for s in range(0, 111, 10)]
    assert lrs[0] == 0.0
    assert lrs[1] == pytest.approx(1.0, rel=1e-5)       # end of warmup
    assert lrs[-1] == pytest.approx(0.1, rel=1e-2)      # cosine floor
    assert all(a >= b - 1e-9 for a, b in zip(lrs[1:], lrs[2:]))  # decay


def test_data_pipeline_determinism_and_sharding():
    c = DataConfig(vocab_size=100, seq_len=16, batch_size=4, seed=3)
    a = SyntheticLM(c).batch(5)
    b = SyntheticLM(c).batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    # different shards differ
    c2 = DataConfig(vocab_size=100, seq_len=16, batch_size=4, seed=3,
                    shard_id=1, num_shards=2)
    d = SyntheticLM(c2).batch(5)
    assert not np.array_equal(a["tokens"], d["tokens"])


def test_data_is_learnable_structure():
    """Markov structure => big models should beat the unigram entropy.
    Here: bigram count check — top successor must dominate."""
    c = DataConfig(vocab_size=50, seq_len=256, batch_size=16, seed=0)
    b = SyntheticLM(c).batch(0)
    toks = b["tokens"]
    # repeated contexts appear (hash table is finite)
    pairs = {}
    for row in toks:
        for i in range(len(row) - 2):
            pairs.setdefault((row[i], row[i + 1]), []).append(row[i + 2])
    multi = [v for v in pairs.values() if len(v) >= 5]
    assert multi, "no repeated contexts"
    conc = np.mean([np.max(np.bincount(v)) / len(v) for v in multi])
    assert conc > 0.3  # successors are predictable far beyond uniform


@pytest.mark.slow
def test_end_to_end_loss_decreases():
    cfg = get_config("olmo_1b", reduced=True)
    res = train_loop(cfg, steps=40, seq_len=64, batch_size=8, log_every=0)
    first = np.mean(res.losses[:5])
    last = np.mean(res.losses[-5:])
    assert last < first, (first, last)


def test_vlm_batch_includes_stub():
    cfg = get_config("internvl2_26b", reduced=True)
    b = next(make_batches(cfg, 32, 2))
    assert "image_embeds" in b
    assert b["image_embeds"].shape == (2, cfg.num_patches, cfg.vision_embed_dim)
    assert b["tokens"].shape == (2, 32 - cfg.num_patches)
