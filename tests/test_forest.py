"""CART / random-forest substrate invariants."""
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.forest import make_dataset, split_dataset, train_forest
from repro.forest.cart import train_tree


def _toy(n=300, f=6, c=3, seed=0):
    rng = np.random.default_rng(seed)
    X = rng.normal(size=(n, f)).astype(np.float32)
    w = rng.normal(size=(f, c))
    y = np.argmax(X @ w + 0.1 * rng.normal(size=(n, c)), axis=1)
    return X, y.astype(np.int64)


def test_tree_probs_are_distributions():
    X, y = _toy()
    t = train_tree(X, y, 3, max_depth=5, rng=np.random.default_rng(0))
    assert np.allclose(t.probs.sum(axis=1), 1.0, atol=1e-5)
    assert (t.probs >= 0).all()


def test_tree_perfectly_fits_separable_data():
    # one feature cleanly separates two classes
    X = np.zeros((100, 3), dtype=np.float32)
    X[:, 1] = np.linspace(-1, 1, 100)
    y = (X[:, 1] > 0.0).astype(np.int64)
    t = train_tree(X, y, 2, max_depth=3, rng=np.random.default_rng(0))
    pred = t.predict_proba(X).argmax(axis=1)
    assert (pred == y).all()


def test_tree_depth_limit_respected():
    X, y = _toy()
    for d in (1, 2, 4):
        t = train_tree(X, y, 3, max_depth=d, rng=np.random.default_rng(0))
        assert t.depth.max() <= d


def test_deeper_prediction_no_worse_on_train():
    """The paper's premise: per-step refinement improves (train-set) fit."""
    X, y = _toy(seed=1)
    t = train_tree(X, y, 3, max_depth=6, rng=np.random.default_rng(0))
    accs = [(t.predict_proba(X, depth_limit=d).argmax(1) == y).mean()
            for d in range(7)]
    assert accs[-1] >= accs[0]
    assert accs[-1] > 0.9


def test_forest_beats_single_tree():
    X, y = _toy(n=600, seed=2)
    (tr, ytr), _, (te, yte) = split_dataset(X, y, seed=0)
    rf1 = train_forest(tr, ytr, 3, n_trees=1, max_depth=4, seed=0)
    rf9 = train_forest(tr, ytr, 3, n_trees=9, max_depth=4, seed=0)
    a1 = (rf1.predict(te) == yte).mean()
    a9 = (rf9.predict(te) == yte).mean()
    assert a9 >= a1 - 0.02  # ensembling should not hurt


def test_forest_arrays_padding_is_inert():
    X, y = _toy()
    rf = train_forest(X, y, 3, n_trees=4, max_depth=4, seed=0)
    fa = rf.as_arrays()
    # padded slots are self-looping leaves
    T, M = fa.feature.shape
    for t, tree in enumerate(rf.trees):
        m = tree.n_nodes
        assert (fa.left[t, m:] == np.arange(m, M)).all()
        assert fa.is_leaf[t, m:].all()


def test_dataset_registry_shapes():
    from repro.forest.data import DATASETS
    for name, spec in DATASETS.items():
        X, y = make_dataset(name, seed=0)
        assert X.shape == (spec.n_samples, spec.n_features)
        assert y.min() >= 0 and y.max() < spec.n_classes
        # every class present
        assert len(np.unique(y)) == spec.n_classes


def test_dataset_learnable():
    X, y = make_dataset("letter", seed=0)
    (tr, ytr), _, (te, yte) = split_dataset(X, y, seed=0)
    rf = train_forest(tr, ytr, 26, n_trees=10, max_depth=10, seed=0)
    acc = (rf.predict(te) == yte).mean()
    assert acc > 3.0 / 26  # far above chance


@settings(max_examples=10, deadline=None)
@given(n_trees=st.integers(1, 5), depth=st.integers(1, 4), seed=st.integers(0, 100))
def test_forest_probs_valid_under_hypothesis(n_trees, depth, seed):
    X, y = _toy(n=120, seed=seed)
    rf = train_forest(X, y, 3, n_trees=n_trees, max_depth=depth, seed=seed)
    fa = rf.as_arrays()
    assert np.allclose(fa.probs.sum(axis=2), 1.0, atol=1e-4)
    assert fa.max_depth == depth
    # children stay in range
    assert (fa.left >= 0).all() and (fa.left < fa.n_nodes).all()
    assert (fa.right >= 0).all() and (fa.right < fa.n_nodes).all()
