"""Synthetic LM data pipeline (offline container: no corpora).

Generates *learnable* token streams so training loss demonstrably
decreases: a mixture of (a) order-k Markov chains with a fixed random
transition structure, (b) repeated motif insertion, over a Zipf-ish
unigram prior.  Deterministic per (seed, step) -> restartable without
checkpointing the pipeline itself; sharded per data-parallel host via
``shard_id / num_shards``.

Also provides frontend stubs: random-but-deterministic patch/frame
embeddings for the VLM/audio architectures (the task's one sanctioned
stub).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    batch_size: int            # per-shard batch
    seed: int = 0
    markov_order: int = 2
    branching: int = 4         # candidate successors per context
    motif_len: int = 16
    motif_rate: float = 0.1
    shard_id: int = 0
    num_shards: int = 1


class SyntheticLM:
    """Deterministic synthetic language-model stream."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        V = cfg.vocab_size
        # Zipf unigram prior
        ranks = np.arange(1, V + 1)
        self.unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # hashed Markov structure: successor table per context hash
        self.n_ctx = 1 << 14
        self.succ = root.integers(0, V, size=(self.n_ctx, cfg.branching))
        self.motifs = root.integers(0, V, size=(32, cfg.motif_len))

    def _ctx_hash(self, ctx: np.ndarray) -> np.ndarray:
        h = np.zeros(ctx.shape[0], dtype=np.uint64)
        for k in range(ctx.shape[1]):
            h = h * np.uint64(1000003) + ctx[:, k].astype(np.uint64)
        return (h % np.uint64(self.n_ctx)).astype(np.int64)

    def batch(self, step: int) -> dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * cfg.num_shards + cfg.shard_id)
        B, S, V = cfg.batch_size, cfg.seq_len, cfg.vocab_size
        seq = np.zeros((B, S + 1), dtype=np.int64)
        k = cfg.markov_order
        seq[:, :k] = rng.choice(V, size=(B, k), p=self.unigram)
        for t in range(k, S + 1):
            h = self._ctx_hash(seq[:, t - k:t])
            pick = rng.integers(0, cfg.branching, size=B)
            nxt = self.succ[h, pick]
            # occasional unigram noise keeps entropy nonzero
            noise = rng.random(B) < 0.1
            nxt = np.where(noise, rng.choice(V, size=B, p=self.unigram), nxt)
            seq[:, t] = nxt
        # motif stamping
        n_motifs = int(B * cfg.motif_rate) + 1
        for _ in range(n_motifs):
            b = rng.integers(0, B)
            pos = rng.integers(0, S + 1 - cfg.motif_len)
            m = rng.integers(0, len(self.motifs))
            seq[b, pos:pos + cfg.motif_len] = self.motifs[m]
        return {
            "tokens": seq[:, :-1].astype(np.int32),
            "labels": seq[:, 1:].astype(np.int32),
            "loss_mask": np.ones((B, S), dtype=np.float32),
        }

    def __iter__(self) -> Iterator[dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def frontend_stub(cfg: ModelConfig, batch_size: int, seed: int = 0) -> dict[str, np.ndarray]:
    """Precomputed embeddings standing in for the ViT / audio-conv
    frontend (task-sanctioned stub)."""
    rng = np.random.default_rng(seed)
    out = {}
    if cfg.family == "vlm":
        out["image_embeds"] = rng.normal(
            0, 1, size=(batch_size, cfg.num_patches, cfg.vision_embed_dim)
        ).astype(np.float32)
    if cfg.family == "encdec":
        out["audio_embeds"] = rng.normal(
            0, 1, size=(batch_size, cfg.encoder_seq, cfg.vision_embed_dim or cfg.d_model)
        ).astype(np.float32)
    return out


def make_batches(cfg: ModelConfig, seq_len: int, batch_size: int,
                 seed: int = 0) -> Iterator[dict[str, np.ndarray]]:
    """Full model-ready batch stream (tokens + frontend stubs)."""
    text_len = seq_len - (cfg.num_patches if cfg.family == "vlm" else 0)
    lm = SyntheticLM(DataConfig(cfg.vocab_size, text_len, batch_size, seed=seed))
    stub = frontend_stub(cfg, batch_size, seed)
    for batch in lm:
        batch.update(stub)
        yield batch
