"""The paper's own model family: anytime random forests.

Default experiment grid mirroring Sec. VI (trees x depth combinations,
dataset list, seeds); consumed by benchmarks/ and examples/.
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class ForestConfig:
    n_trees: int = 7
    max_depth: int = 7
    datasets: tuple = (
        "adult", "covertype", "letter", "magic", "mnist",
        "satlog", "sensorless-drive", "spambase", "wearable-body-postures",
    )
    seeds: tuple = (0, 1, 2, 3, 4)
    # small grid (with Optimal Order) and large grid (without), Sec. VI-C
    small_grid: tuple = tuple((t, d) for t in (4, 5, 6, 7) for d in (4, 5, 6, 7))
    large_grid: tuple = tuple((t, d) for t in (5, 10, 20) for d in (2, 5, 10, 20))


CONFIG = ForestConfig()
REDUCED = ForestConfig(
    n_trees=3, max_depth=3,
    datasets=("magic", "letter"), seeds=(0,),
    small_grid=((3, 3),), large_grid=((5, 4),),
)
