"""Gemma 2 27B [arXiv:2408.00118].

46 layers, d_model 4608, 32 heads (GQA kv=16), head_dim 128, d_ff 36864,
vocab 256000; local/global alternation + softcaps; the 27B variant scales
attention by (d_model/num_heads)^-0.5 = 144^-0.5 instead of head_dim^-0.5.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36_864,
    vocab_size=256_000,
    act="gelu",
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sliding_window=4096,
    local_global=True,
    post_attn_norm=True,
    scale_embeds=True,
    attn_scale_override=(4608 / 32) ** -0.5,
    tie_embeddings=True,
    sharding_profile="fsdp_tp",
    citation="arXiv:2408.00118",
)

REDUCED = ModelConfig(
    name="gemma2-27b-reduced",
    family="dense",
    num_layers=2,
    d_model=256,
    num_heads=8,
    num_kv_heads=4,
    head_dim=32,
    d_ff=512,
    vocab_size=512,
    act="gelu",
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sliding_window=16,
    local_global=True,
    post_attn_norm=True,
    scale_embeds=True,
    attn_scale_override=(256 / 8) ** -0.5,
    tie_embeddings=True,
    citation="arXiv:2408.00118",
)
