"""Zamba2-1.2B [arXiv:2411.15242] — Mamba2 backbone + shared attention.

38 mamba2 layers, d_model 2048, ssm_state 64; one SHARED transformer
block (32 heads, kv=32, d_ff 8192) applied after every 6 mamba2 layers
through per-group linear adapters (6 groups + 2 tail mamba layers).
vocab 32000.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,      # d_inner 4096 -> 64 heads
    ssm_conv_width=4,
    ssm_chunk=256,
    hybrid_period=6,
    tie_embeddings=True,
    sharding_profile="tp",
    citation="arXiv:2411.15242",
)

REDUCED = ModelConfig(
    name="zamba2-1.2b-reduced",
    family="hybrid",
    num_layers=5,         # 2 groups of 2 + 1 tail
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=32,
    ssm_conv_width=4,
    ssm_chunk=32,
    hybrid_period=2,
    tie_embeddings=True,
    citation="arXiv:2411.15242",
)
