"""Qwen3-14B [hf:Qwen/Qwen3-8B family scaled per assignment].

40 layers, d_model 5120, 40 heads (GQA kv=8), head_dim 128, d_ff 17408,
vocab 151936; RMSNorm on q/k per head (qk_norm), untied embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-14b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=17_408,
    vocab_size=151_936,
    rope_theta=1_000_000.0,
    qk_norm=True,
    tie_embeddings=False,
    head_pad_to=48,  # 16-way TP divisibility (SPerf iteration 2)
    sharding_profile="fsdp_tp",
    shard_kv_heads=False,  # 8 kv heads < model axis 16: replicate
    citation="hf:Qwen/Qwen3-8B",
)

REDUCED = ModelConfig(
    name="qwen3-14b-reduced",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    qk_norm=True,
    tie_embeddings=False,
    citation="hf:Qwen/Qwen3-8B",
)
