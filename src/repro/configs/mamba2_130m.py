"""Mamba2-130M [arXiv:2405.21060] — SSD (state-space duality).

24 layers, d_model 768 (attention-free), vocab 50280, ssm_state N=128,
d_inner = 2*768 = 1536, head_dim 64 -> 24 SSD heads.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    head_dim=1,           # unused (attention-free)
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv_width=4,
    ssm_chunk=256,
    tie_embeddings=True,
    sharding_profile="tp",
    citation="arXiv:2405.21060",
)

REDUCED = ModelConfig(
    name="mamba2-130m-reduced",
    family="ssm",
    num_layers=2,
    d_model=128,
    num_heads=0,
    num_kv_heads=0,
    head_dim=1,
    d_ff=0,
    vocab_size=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=32,      # 8 heads
    ssm_conv_width=4,
    ssm_chunk=32,
    tie_embeddings=True,
    citation="arXiv:2405.21060",
)
