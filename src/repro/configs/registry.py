"""Architecture registry: ``--arch <id>`` resolves here.

Each module under src/repro/configs/ defines ``CONFIG`` (full assigned
hyperparameters, citation in ``citation``) and ``REDUCED`` (the smoke-
test variant: <=2 layers, d_model<=512, <=4 experts, runnable on CPU).
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

ARCH_IDS = (
    "gemma2_2b",
    "whisper_medium",
    "internvl2_26b",
    "qwen3_14b",
    "mamba2_130m",
    "olmo_1b",
    "zamba2_1p2b",
    "granite_moe_3b_a800m",
    "qwen3_moe_235b_a22b",
    "gemma2_27b",
    "anytime_rf",  # the paper's own model family (random forests)
)

# canonical external ids (dashes) -> module names
_ALIASES = {
    "gemma2-2b": "gemma2_2b",
    "whisper-medium": "whisper_medium",
    "internvl2-26b": "internvl2_26b",
    "qwen3-14b": "qwen3_14b",
    "mamba2-130m": "mamba2_130m",
    "olmo-1b": "olmo_1b",
    "zamba2-1.2b": "zamba2_1p2b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "gemma2-27b": "gemma2_27b",
    "anytime-rf": "anytime_rf",
}


def normalize(arch: str) -> str:
    return _ALIASES.get(arch, arch.replace("-", "_").replace(".", "p"))


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{normalize(arch)}")
    return mod.REDUCED if reduced else mod.CONFIG


def transformer_arch_ids() -> list[str]:
    return [a for a in ARCH_IDS if a != "anytime_rf"]
