"""Qwen3-MoE 235B-A22B [hf:Qwen/Qwen3-30B-A3B family scaled per assignment].

94 layers, d_model 4096, 64 heads (GQA kv=4), head_dim 128, vocab 151936;
MoE with 128 experts, top-8, per-expert d_ff 1536; qk_norm.  The largest
assigned config (~235B total, ~22B active) — requires the fsdp_tp
sharding profile to fit v5e HBM.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151_936,
    rope_theta=1_000_000.0,
    qk_norm=True,
    num_experts=128,
    top_k=8,
    moe_d_ff=1536,
    capacity_factor=1.25,
    tie_embeddings=False,
    moe_constrain_dispatch=False,  # regresses under fsdp_tp (SPerf it.4)
    sharding_profile="fsdp_tp",
    shard_kv_heads=False,  # 4 kv heads: replicate
    citation="hf:Qwen/Qwen3-30B-A3B",
)

REDUCED = ModelConfig(
    name="qwen3-moe-reduced",
    family="moe",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=64,
    vocab_size=512,
    qk_norm=True,
    num_experts=4,
    top_k=2,
    moe_d_ff=64,
    tie_embeddings=False,
    citation="hf:Qwen/Qwen3-30B-A3B",
)
