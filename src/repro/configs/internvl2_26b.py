"""InternVL2-26B [arXiv:2404.16821] — language backbone (InternLM2-20B-ish)
consuming stub InternViT-6B patch embeddings.

48 layers, d_model 6144, 48 heads (GQA kv=8), head_dim 128, d_ff 16384,
vocab 92553.  The ViT + MLP projector frontend is a STUB per the task
spec: ``input_specs()`` provides patch embeddings [B, 1024, 3200]
(InternViT-6B hidden width); ``vis_proj`` maps them into the LM stream.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab_size=92_553,
    rope_theta=1_000_000.0,
    num_patches=1024,
    vision_embed_dim=3200,
    tie_embeddings=False,
    sharding_profile="fsdp_tp",
    shard_kv_heads=False,  # 8 kv heads < model axis 16: replicate
    citation="arXiv:2404.16821",
)

REDUCED = ModelConfig(
    name="internvl2-26b-reduced",
    family="vlm",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    num_patches=8,
    vision_embed_dim=64,
    tie_embeddings=False,
    citation="arXiv:2404.16821",
)
