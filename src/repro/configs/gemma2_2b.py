"""Gemma 2 2B [arXiv:2408.00118].

26 layers, d_model 2304, 8 heads (GQA kv=4), head_dim 256, d_ff 9216,
vocab 256000; alternating local (sliding window 4096) / global layers,
attention- and final-logit softcaps, GeGLU, extra post-norms.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256_000,
    act="gelu",
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sliding_window=4096,
    local_global=True,
    post_attn_norm=True,
    scale_embeds=True,
    tie_embeddings=True,
    sharding_profile="tp",
    shard_kv_heads=False,  # 4 kv heads < model axis: replicate
    citation="arXiv:2408.00118",
)

REDUCED = ModelConfig(
    name="gemma2-2b-reduced",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    act="gelu",
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    sliding_window=16,
    local_global=True,
    post_attn_norm=True,
    scale_embeds=True,
    tie_embeddings=True,
    citation="arXiv:2408.00118",
)
