"""OLMo-1B [arXiv:2402.00838].

16 layers, d_model 2048, 16 heads (kv=16), d_ff 8192, vocab 50304;
non-parametric LayerNorm (no scale/bias) throughout, tied embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    head_dim=128,
    d_ff=8192,
    vocab_size=50_304,
    norm_type="layernorm_nonparam",
    act="silu",
    tie_embeddings=True,
    sharding_profile="tp",
    citation="arXiv:2402.00838",
)

REDUCED = ModelConfig(
    name="olmo-1b-reduced",
    family="dense",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    norm_type="layernorm_nonparam",
    tie_embeddings=True,
    citation="arXiv:2402.00838",
)
