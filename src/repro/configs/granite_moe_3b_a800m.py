"""Granite-3.0 MoE 3B-A800M [hf:ibm-granite/granite-3.0-1b-a400m-base family].

32 layers, d_model 1536, 24 heads (GQA kv=8), vocab 49155; MoE with 40
experts, top-8, per-expert d_ff 512.  (The assignment lists "MoE 40e
top-8"; the bracketed note says 32 experts — we follow the explicit
config field, 40, and record the discrepancy in DESIGN.md.)
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49_155,
    num_experts=40,
    top_k=8,
    moe_d_ff=512,
    capacity_factor=1.25,
    tie_embeddings=True,
    head_pad_to=32,    # 16-way TP divisibility (§Perf iteration 2)
    expert_pad_to=48,  # expert-parallel divisibility (§Perf iteration 3)
    sharding_profile="tp",
    shard_kv_heads=False,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
)

REDUCED = ModelConfig(
    name="granite-moe-reduced",
    family="moe",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=2,
    head_dim=32,
    d_ff=64,
    vocab_size=512,
    num_experts=4,
    top_k=2,
    moe_d_ff=64,
    tie_embeddings=True,
    citation="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
