"""Whisper medium [arXiv:2212.04356] — TRANSFORMER BACKBONE only.

Encoder-decoder: 24+24 layers, d_model 1024, 16 heads (kv=16), d_ff 4096,
vocab 51865.  The mel-spectrogram + conv frontend is a STUB per the task
spec: ``input_specs()`` provides precomputed frame embeddings
[B, 1500, 1024] which ``audio_proj`` consumes.  Deviation noted in
DESIGN.md: positions use RoPE rather than Whisper's learned absolute
embeddings (backbone-equivalent compute/shapes).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51_865,
    act="gelu",
    norm_type="layernorm",
    encoder_layers=24,
    encoder_seq=1500,
    vision_embed_dim=1024,   # stub frontend output width (frame embeddings)
    tie_embeddings=True,
    sharding_profile="tp",
    citation="arXiv:2212.04356",
)

REDUCED = ModelConfig(
    name="whisper-medium-reduced",
    family="encdec",
    num_layers=2,
    d_model=128,
    num_heads=4,
    num_kv_heads=4,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    act="gelu",
    norm_type="layernorm",
    encoder_layers=2,
    encoder_seq=32,
    vision_embed_dim=128,
    tie_embeddings=True,
    citation="arXiv:2212.04356",
)
