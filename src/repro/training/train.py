"""Distributed training step + loop.

``make_train_step`` builds the jit'd (params, opt, batch) -> (params,
opt, metrics) update with explicit in/out shardings derived from the
model's logical axis rules — the same function object the multi-pod
dry-run lowers with ShapeDtypeStructs and the CPU examples execute with
real arrays on a host mesh.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.launch import mesh as mesh_lib
from repro.models import model as MD
from repro.models.config import ModelConfig
from repro.models.params import shardings_for
from repro.training import optimizer as opt_lib


def batch_shardings(cfg: ModelConfig, mesh: Mesh, batch_like: dict[str, Any]):
    bp = mesh_lib.batch_pspec(mesh)
    return {k: NamedSharding(mesh, bp if np.ndim(v) or True else P())
            for k, v in batch_like.items()}


def _batch_pspec_tree(cfg: ModelConfig, mesh: Mesh, batch_like: dict[str, Any]):
    bp = mesh_lib.batch_pspec(mesh)
    out = {}
    for k, v in batch_like.items():
        nd = len(v.shape)
        out[k] = NamedSharding(mesh, P(*(bp + (None,) * (nd - 1))))
    return out


def loss_fn(cfg: ModelConfig):
    def f(params, batch):
        return MD.lm_loss(cfg, params, batch)
    return f


def train_step_fn(cfg: ModelConfig, ocfg: opt_lib.AdamWConfig):
    """The un-jitted step (used directly by the dry-run)."""
    lfn = loss_fn(cfg)

    def step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(lfn, has_aux=True)(params, batch)
        params, opt_state, stats = opt_lib.apply_updates(ocfg, params, grads, opt_state)
        metrics = dict(metrics)
        metrics.update(stats)
        return params, opt_state, metrics

    return step


def make_train_step(cfg: ModelConfig, mesh: Mesh, ocfg: opt_lib.AdamWConfig,
                    batch_like: dict[str, Any], donate: bool = True):
    """jit'd train step with explicit shardings."""
    specs = MD.build_param_specs(cfg)
    p_sh = shardings_for(specs, mesh, cfg.sharding_profile, cfg.shard_kv_heads)
    opt_sh = opt_lib.AdamWState(
        step=NamedSharding(mesh, P()),
        m=p_sh, v=p_sh,
    )
    b_sh = _batch_pspec_tree(cfg, mesh, batch_like)
    metric_sh = None  # replicated
    step = train_step_fn(cfg, ocfg)
    jitted = jax.jit(
        step,
        in_shardings=(p_sh, opt_sh, b_sh),
        out_shardings=(p_sh, opt_sh, metric_sh),
        donate_argnums=(0, 1) if donate else (),
    )
    return jitted, p_sh, opt_sh, b_sh


@dataclasses.dataclass
class TrainResult:
    losses: list[float]
    steps_per_sec: float


def train_loop(cfg: ModelConfig, *, steps: int, seq_len: int, batch_size: int,
               mesh: Optional[Mesh] = None,
               ocfg: Optional[opt_lib.AdamWConfig] = None,
               seed: int = 0,
               ckpt_dir: Optional[str] = None,
               ckpt_every: int = 0,
               log_every: int = 10,
               param_dtype=jnp.float32) -> TrainResult:
    """End-to-end driver: synthetic data -> jit'd sharded steps -> metrics."""
    from repro.checkpoint import ckpt as ckpt_lib
    from repro.data.pipeline import make_batches

    mesh = mesh or mesh_lib.make_host_mesh(data=len(jax.devices()))
    ocfg = ocfg or opt_lib.AdamWConfig(total_steps=steps)
    batches = make_batches(cfg, seq_len, batch_size, seed=seed)
    first = next(batches)

    with mesh_lib.mesh_context(mesh):
        params = MD.init(cfg, jax.random.PRNGKey(seed))
        if param_dtype != jnp.float32:
            from repro.models.params import cast_tree
            params = cast_tree(params, param_dtype)
        opt_state = opt_lib.init_state(params)
        jitted, p_sh, opt_sh, b_sh = make_train_step(cfg, mesh, ocfg, first)
        params = jax.device_put(params, p_sh)
        opt_state = jax.device_put(opt_state, opt_sh)

        losses = []
        t0 = time.perf_counter()
        batch = first
        for i in range(steps):
            batch_dev = jax.device_put(batch, b_sh)
            params, opt_state, metrics = jitted(params, opt_state, batch_dev)
            batch = next(batches)
            loss = float(metrics["loss"])
            losses.append(loss)
            if log_every and (i % log_every == 0 or i == steps - 1):
                print(f"step {i:5d} loss {loss:.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e}", flush=True)
            if ckpt_dir and ckpt_every and (i + 1) % ckpt_every == 0:
                ckpt_lib.save(f"{ckpt_dir}/step_{i+1}.npz",
                              {"params": params, "opt": opt_state},
                              metadata={"step": i + 1, "cfg": cfg.name})
        dt = time.perf_counter() - t0
        return TrainResult(losses=losses, steps_per_sec=steps / dt)
