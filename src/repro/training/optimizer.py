"""AdamW optimizer + LR schedules (hand-rolled; no optax in-container).

State is a pytree mirroring params (m, v) + a scalar step count, so it
inherits parameter shardings automatically under pjit.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Linear warmup -> cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps) /
                    jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def init_state(params) -> AdamWState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree_util.tree_map(jnp.zeros_like, params))


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(leaf.astype(jnp.float32)))
                        for leaf in leaves))


def apply_updates(cfg: AdamWConfig, params, grads, state: AdamWState):
    """One AdamW step with global-norm clipping. Returns (params, state, stats)."""
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        step_dir = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step_dir).astype(p.dtype), m, v

    flat_p, td = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(state.m)
    flat_v = jax.tree_util.tree_leaves(state.v)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(td, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(td, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(td, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
