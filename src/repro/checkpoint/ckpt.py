"""Checkpointing: flat-keyed npz of the (params, optimizer, step) pytree.

Sharding-aware in the sense that save gathers addressable shards (on a
real multi-host cluster each host writes its own addressable slice file;
on one host this degenerates to a single npz) and restore re-shards via
``jax.device_put`` against the current mesh shardings.
"""
from __future__ import annotations

import json
import os
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save(path: str, tree, metadata: Optional[dict[str, Any]] = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, **flat)
    if metadata is not None:
        with open(path + ".meta.json", "w") as f:
            json.dump(metadata, f, indent=2, default=str)


def restore(path: str, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings to place the restored leaves."""
    if not path.endswith(".npz"):
        path = path + ".npz"
    data = np.load(path)
    paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path_k, leaf in paths:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path_k)
        arr = data[key]
        assert arr.shape == tuple(leaf.shape), (key, arr.shape, leaf.shape)
        leaves.append(arr.astype(leaf.dtype))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree_util.tree_map(jax.device_put, tree, shardings)
    return tree


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for f in os.listdir(ckpt_dir):
        if f.startswith("step_") and f.endswith(".npz"):
            steps.append(int(f[len("step_"):-len(".npz")]))
    return max(steps) if steps else None
