"""Shared neural building blocks for all assigned architectures.

Covers every attention/norm/MLP variant the assigned configs need:
GQA + RoPE, qk-norm (qwen3), attention-logit softcap (gemma2), sliding
windows (gemma2 local layers), non-parametric LN (olmo), SwiGLU / GeGLU
MLPs.  Attention over long sequences uses a chunked online-softmax
("flash-style") formulation so the [Sq, Sk] score matrix is never
materialized — mandatory for the 32k-prefill input shapes.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.params import spec

# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, w: Optional[jax.Array], eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    if w is not None:
        x = x * (1.0 + w.astype(jnp.float32))  # gemma-style (1 + w)
    return x.astype(dt)


def layernorm_nonparam(x: jax.Array, eps: float = 1e-5) -> jax.Array:
    """OLMo's non-parametric LayerNorm: no scale, no bias [arXiv:2402.00838]."""
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps)).astype(dt)


def apply_norm(cfg: ModelConfig, x: jax.Array, w: Optional[jax.Array]) -> jax.Array:
    if cfg.norm_type == "layernorm_nonparam":
        return layernorm_nonparam(x)
    if cfg.norm_type == "layernorm":  # whisper: parametric LN (scale, no bias)
        y = layernorm_nonparam(x)
        return y if w is None else (y * (1.0 + w.astype(y.dtype))).astype(x.dtype)
    return rmsnorm(x, w)


def norm_spec(cfg: ModelConfig, *lead):
    """Param spec for a norm weight (None-shaped for non-parametric)."""
    if cfg.norm_type == "layernorm_nonparam":
        return None
    lead_axes = ("layers",) * len(lead)
    return spec((*lead, cfg.d_model), (*lead_axes, "embed"), init="zeros")


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, ..., head_dim] with positions [..., S] broadcastable.

    NeoX-style half rotation.  positions: [B, S] or [S]."""
    dh = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(dh, theta), dtype=jnp.float32)    # [dh/2]
    ang = positions[..., None].astype(jnp.float32) * freqs           # [..., S, dh/2]
    # broadcast ang over the head axis: x is [B, S, H, dh]; ang [B, S, dh/2]
    ang = ang[..., None, :]                                          # [B, S, 1, dh/2]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., : dh // 2], x[..., dh // 2:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# chunked online-softmax attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _mask(qpos, kpos, causal: bool, window: Optional[int]):
    """[..., Sq, Sk] additive mask from absolute positions."""
    m = jnp.zeros(qpos.shape[:-1] + (qpos.shape[-1], kpos.shape[-1]), jnp.float32)
    q = qpos[..., :, None]
    k = kpos[..., None, :]
    valid = k >= 0  # padding slots carry kpos = -1
    if causal:
        valid &= k <= q
    if window is not None:
        valid &= q - k < window
    return jnp.where(valid, m, NEG_INF)


def _scores(q, k, scale, softcap):
    # q: [B, Sq, KH, G, dh]  k: [B, Sk, KH, dh] -> [B, KH, G, Sq, Sk]
    s = jnp.einsum("bqhgd,bshd->bhgqs", q, k, preferred_element_type=jnp.float32)
    s = s * scale
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    return s


def attention(
    q: jax.Array,                 # [B, Sq, H, dh]
    k: jax.Array,                 # [B, Sk, KH, dh]
    v: jax.Array,                 # [B, Sk, KH, dh]
    q_positions: jax.Array,       # [B, Sq] absolute positions
    k_positions: jax.Array,       # [B, Sk] absolute positions (-1 = invalid)
    *,
    scale: float,
    causal: bool = True,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    k_chunk: int = 1024,
    q_chunk: int = 1024,
) -> jax.Array:
    """Flash-style attention: O(Sq * dh) memory, never materializes the
    full score matrix.  Handles GQA natively (no KV repetition)."""
    B, Sq, H, dh = q.shape
    KH = k.shape[2]
    G = H // KH
    qg = q.reshape(B, Sq, KH, G, dh)

    k_chunk = min(k_chunk, k.shape[1])
    q_chunk = min(q_chunk, Sq)
    # pad seqs to chunk multiples
    Skp = -(-k.shape[1] // k_chunk) * k_chunk
    Sqp = -(-Sq // q_chunk) * q_chunk
    kp = jnp.pad(k, ((0, 0), (0, Skp - k.shape[1]), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, Skp - k.shape[1]), (0, 0), (0, 0)))
    kpos_p = jnp.pad(k_positions, ((0, 0), (0, Skp - k.shape[1])), constant_values=-1)
    qp = jnp.pad(qg, ((0, 0), (0, Sqp - Sq), (0, 0), (0, 0), (0, 0)))
    qpos_p = jnp.pad(q_positions, ((0, 0), (0, Sqp - Sq)), constant_values=-1)

    nq, nk = Sqp // q_chunk, Skp // k_chunk
    kc = kp.reshape(B, nk, k_chunk, KH, dh)
    vc = vp.reshape(B, nk, k_chunk, KH, dh)
    kposc = kpos_p.reshape(B, nk, k_chunk)

    def q_block(args):
        qb, qposb = args                     # [B, qc, KH, G, dh], [B, qc]

        def kv_step(carry, kv):
            m, lsum, acc = carry
            kb, vb, kposb = kv               # [B, kc, KH, dh] ...
            s = _scores(qb, kb, scale, softcap)                     # [B,KH,G,qc,kc]
            s = s + _mask(qposb, kposb, causal, window)[:, None, None]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            lsum = lsum * corr + p.sum(axis=-1)
            pv = jnp.einsum("bhgqs,bshd->bhgqd", p.astype(vb.dtype), vb,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, lsum, acc), None

        m0 = jnp.full((B, KH, G, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KH, G, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KH, G, q_chunk, dh), jnp.float32)
        (m, lsum, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.moveaxis(kposc, 1, 0)),
        )
        out = acc / jnp.maximum(lsum, 1e-30)[..., None]                # [B,KH,G,qc,dh]
        return jnp.transpose(out, (0, 3, 1, 2, 4))                  # [B,qc,KH,G,dh]

    qcs = jnp.moveaxis(qp.reshape(B, nq, q_chunk, KH, G, dh), 1, 0)
    qposcs = jnp.moveaxis(qpos_p.reshape(B, nq, q_chunk), 1, 0)
    outs = jax.lax.map(q_block, (qcs, qposcs))                      # [nq,B,qc,KH,G,dh]
    out = jnp.moveaxis(outs, 0, 1).reshape(B, Sqp, KH, G, dh)[:, :Sq]
    return out.reshape(B, Sq, H, dh).astype(q.dtype)


def decode_attention(
    q: jax.Array,            # [B, 1, H, dh]
    k_cache: jax.Array,      # [B, S, KH, dh] (RoPE already applied at write)
    v_cache: jax.Array,      # [B, S, KH, dh]
    k_positions: jax.Array,  # [B, S] absolute position per slot (-1 invalid)
    cur_pos: jax.Array,      # [B] position of the query token
    *,
    scale: float,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    """Single-token attention against a (possibly ring-buffered) KV cache.

    Memory/compute are linear in S — decode needs no flash machinery."""
    B, _, H, dh = q.shape
    KH = k_cache.shape[2]
    G = H // KH
    qg = q.reshape(B, 1, KH, G, dh)
    s = _scores(qg, k_cache, scale, softcap)                 # [B,KH,G,1,S]
    qpos = cur_pos[:, None]                                   # [B,1]
    s = s + _mask(qpos, k_positions, True, window)[:, None, None]
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqs,bshd->bqhgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, dh).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention block (projections + rope + norms) and MLP
# ---------------------------------------------------------------------------

def head_mask(cfg: ModelConfig) -> Optional[jax.Array]:
    """[H_pad] 0/1 mask over padded query heads (None when unpadded).
    Layout: heads grouped per kv head; within each group the first
    num_heads//num_kv_heads are real."""
    Hp = cfg.padded_heads
    if Hp == cfg.num_heads:
        return None
    KH = max(cfg.num_kv_heads, 1)
    g_real = cfg.num_heads // KH
    g_pad = Hp // KH
    m = (jnp.arange(g_pad) < g_real).astype(jnp.float32)
    return jnp.tile(m, KH)


def attn_param_specs(cfg: ModelConfig, n_layers: Optional[int] = None, layer_axis: bool = True):
    """Spec dict for one attention block; if layer_axis, stacked over layers."""
    D, H, KH, dh = cfg.d_model, cfg.padded_heads, cfg.num_kv_heads, cfg.head_dim
    lead = (n_layers,) if layer_axis else ()
    la = ("layers",) if layer_axis else ()
    p = {
        "wq": spec((*lead, D, H, dh), (*la, "embed_in", "heads", "head_dim")),
        "wk": spec((*lead, D, KH, dh), (*la, "embed_in", "kv_heads", "head_dim")),
        "wv": spec((*lead, D, KH, dh), (*la, "embed_in", "kv_heads", "head_dim")),
        "wo": spec((*lead, H, dh, D), (*la, "heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        p["q_norm"] = spec((*lead, dh), (*la, "head_dim"), init="zeros")
        p["k_norm"] = spec((*lead, dh), (*la, "head_dim"), init="zeros")
    return p


def mlp_param_specs(cfg: ModelConfig, d_ff: Optional[int] = None,
                    n_layers: Optional[int] = None, layer_axis: bool = True):
    D = cfg.d_model
    F = d_ff if d_ff is not None else cfg.d_ff
    lead = (n_layers,) if layer_axis else ()
    la = ("layers",) if layer_axis else ()
    return {
        "w_gate": spec((*lead, D, F), (*la, "embed_in", "ffn")),
        "w_up": spec((*lead, D, F), (*la, "embed_in", "ffn")),
        "w_down": spec((*lead, F, D), (*la, "ffn", "embed")),
    }


def qkv_project(cfg: ModelConfig, p, x: jax.Array, positions: jax.Array):
    """x [B,S,D] -> q [B,S,H,dh], k/v [B,S,KH,dh] with rope + optional qk-norm."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_out(p, o: jax.Array, cfg: Optional[ModelConfig] = None) -> jax.Array:
    if cfg is not None:
        m = head_mask(cfg)
        if m is not None:  # zero padded heads' output AND their gradients
            o = o * m[None, None, :, None].astype(o.dtype)
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


def mlp(cfg: ModelConfig, p, x: jax.Array) -> jax.Array:
    act = jax.nn.gelu if cfg.act == "gelu" else jax.nn.silu
    h = act(jnp.einsum("bsd,df->bsf", x, p["w_gate"])) * jnp.einsum(
        "bsd,df->bsf", x, p["w_up"]
    )
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


def final_logits(cfg: ModelConfig, embed: jax.Array, lm_head: Optional[jax.Array],
                 x: jax.Array) -> jax.Array:
    """Readout; also reused by the anytime early-exit heads (logit lens)."""
    if cfg.tie_embeddings or lm_head is None:
        logits = jnp.einsum("bsd,vd->bsv", x, embed)
    else:
        logits = jnp.einsum("bsd,dv->bsv", x, lm_head)
    if cfg.final_logit_softcap is not None:
        c = cfg.final_logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits
