"""Parameter specification / initialization / sharding machinery.

Every model declares its parameters as a pytree of :class:`ParamSpec`
(shape + logical axis names + initializer).  From the same spec tree we
derive:

  * materialized params        (init, on device)    — training/smoke tests
  * abstract params            (ShapeDtypeStruct)   — dry-run lowering,
                                                      zero allocation
  * NamedShardings per leaf    (logical -> mesh axis rules)

This is the hand-rolled equivalent of flax.linen.partitioning — the
container has no flax, and the explicit version keeps the
logical-to-physical mapping inspectable for the roofline analysis.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]   # logical name per dim (None = replicated)
    init: str = "normal"              # normal | zeros | ones | scaled
    scale: float = 1.0
    dtype: Any = jnp.float32

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def spec(shape, axes, init="scaled", scale=1.0, dtype=jnp.float32) -> ParamSpec:
    return ParamSpec(tuple(shape), tuple(axes), init, scale, dtype)


def _init_leaf(key: jax.Array, s: ParamSpec) -> jax.Array:
    if s.init == "zeros":
        return jnp.zeros(s.shape, s.dtype)
    if s.init == "ones":
        return jnp.ones(s.shape, s.dtype)
    if s.init == "normal":
        return (jax.random.normal(key, s.shape) * s.scale).astype(s.dtype)
    if s.init == "scaled":
        # fan-in scaled (lecun normal on the second-to-last... use last-but-one dim)
        fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
        return (jax.random.normal(key, s.shape) * (s.scale / math.sqrt(fan_in))).astype(s.dtype)
    raise ValueError(s.init)


def init_params(spec_tree, key: jax.Array):
    """Materialize a spec pytree into parameter arrays."""
    leaves, treedef = jax.tree_util.tree_flatten(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    keys = jax.random.split(key, len(leaves))
    vals = [_init_leaf(k, s) for k, s in zip(keys, leaves)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_params(spec_tree, shardings=None):
    """ShapeDtypeStructs (optionally sharded) — the dry-run stand-in."""
    def mk(s: ParamSpec, sh=None):
        return jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh)

    if shardings is None:
        return jax.tree_util.tree_map(
            mk, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)
        )
    return jax.tree_util.tree_map(
        mk, spec_tree, shardings, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def param_count(spec_tree) -> int:
    leaves = jax.tree_util.tree_leaves(
        spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )
    return sum(int(np.prod(s.shape)) for s in leaves)


# ---------------------------------------------------------------------------
# Logical-axis -> mesh-axis rules.
#
# "tp":       Megatron-style tensor parallelism on the "model" mesh axis.
# "fsdp_tp":  additionally shard the embed (d_model) dim of weight
#             matrices over the "data" axis (2D / fully-sharded layout) —
#             required for the >10B assigned configs to fit HBM.
# In the multi-pod mesh the batch axes are ("pod", "data"); parameters
# never shard over "pod" (pure data parallelism between pods).
# ---------------------------------------------------------------------------

TP_RULES: dict[str, Optional[str]] = {
    "vocab": "model",
    "embed": None,
    "embed_in": None,      # input-side d_model dim of weight matrices
    "ffn": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "experts": "model",
    "expert_ffn": None,
    "inner": "model",      # ssm d_inner
    "ssm_heads": "model",
    "state": None,
    "conv": None,
    "layers": None,
    "groups": None,
    "patches": None,
    "vis_embed": None,
}

FSDP_TP_RULES = dict(TP_RULES)
FSDP_TP_RULES.update({
    "embed": "data",
    "embed_in": "data",
    "expert_ffn": "data",  # second shard dim for expert weights
})


def rules_for(profile: str) -> dict[str, Optional[str]]:
    if profile == "tp":
        return TP_RULES
    if profile == "fsdp_tp":
        return FSDP_TP_RULES
    raise ValueError(profile)


def logical_to_pspec(
    axes: tuple[Optional[str], ...],
    rules: dict[str, Optional[str]],
    mesh: Mesh,
    shape: Optional[tuple[int, ...]] = None,
    shard_kv_heads: bool = True,
) -> P:
    """Map logical axes to a PartitionSpec, dropping mappings whose mesh
    axis is absent or whose dimension is too small to usefully shard."""
    out = []
    used: set[str] = set()
    for i, ax in enumerate(axes):
        tgt = rules.get(ax) if ax is not None else None
        if ax == "kv_heads" and not shard_kv_heads:
            tgt = None
        if tgt is not None and tgt not in mesh.axis_names:
            tgt = None
        if tgt is not None and shape is not None:
            # pjit input shardings require exact divisibility; replicate
            # otherwise (e.g. 40 heads or a 51865 vocab on a 16-way axis).
            if shape[i] % mesh.shape[tgt] != 0:
                tgt = None
        if tgt is not None and tgt in used:
            # one mesh axis may appear once per spec: first dim wins
            # (e.g. MoE [experts, d, ffn]: expert-parallel takes "model").
            tgt = None
        if tgt is not None:
            used.add(tgt)
        out.append(tgt)
    return P(*out)


def shardings_for(spec_tree, mesh: Mesh, profile: str, shard_kv_heads: bool = True):
    """NamedSharding pytree matching a spec pytree."""
    rules = rules_for(profile)

    def mk(s: ParamSpec):
        ps = logical_to_pspec(s.axes, rules, mesh, s.shape, shard_kv_heads)
        return NamedSharding(mesh, ps)

    return jax.tree_util.tree_map(
        mk, spec_tree, is_leaf=lambda x: isinstance(x, ParamSpec)
    )


def cast_tree(tree, dtype):
    return jax.tree_util.tree_map(
        lambda a: a.astype(dtype) if jnp.issubdtype(a.dtype, jnp.floating) else a, tree
    )
