"""Mixture-of-Experts layer: top-k router + sort-based capacity dispatch.

TPU adaptation notes (DESIGN.md §Hardware-adaptation): GPU MoE stacks
(megablocks) use CSR-style grouped GEMMs; the TPU-native equivalent is a
dense [E, capacity, d] batched matmul fed by a sort-based dispatch
(argsort over expert assignments), which XLA lowers to all-to-all when
experts are sharded over the "model" mesh axis.  Capacity overflow drops
tokens (standard Switch behaviour); the residual connection carries
dropped tokens through unchanged.

Router aux losses: load-balance loss (Switch) + router z-loss.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.params import spec


class MoEAux(NamedTuple):
    load_balance_loss: jax.Array
    router_z_loss: jax.Array


def moe_param_specs(cfg: ModelConfig, n_layers: Optional[int] = None, layer_axis: bool = True):
    D, E, F = cfg.d_model, cfg.padded_experts, cfg.moe_d_ff
    lead = (n_layers,) if layer_axis else ()
    la = ("layers",) if layer_axis else ()
    return {
        "router": spec((*lead, D, E), (*la, "embed_in", None)),
        "w_gate": spec((*lead, E, D, F), (*la, "experts", "expert_ffn", "ffn")),
        "w_up": spec((*lead, E, D, F), (*la, "experts", "expert_ffn", "ffn")),
        "w_down": spec((*lead, E, F, D), (*la, "experts", "ffn", "expert_ffn")),
    }


def _constrain(x: jax.Array, *dims: Optional[str], enable: bool = True) -> jax.Array:
    """Best-effort sharding constraint against the ambient mesh.

    dims entries: "batch" -> ("pod","data") axes, "expert" -> "model",
    None -> replicated.  No-op outside a mesh context or when the dim
    does not divide the axis (§Perf iteration 3: without these, GSPMD
    replicated the [G, E*cap, D] dispatch buffers and all-reduced ~64GB
    per layer)."""
    from jax.sharding import PartitionSpec as P
    if not enable:
        return x
    try:
        mesh = jax.sharding.get_abstract_mesh()
        names = getattr(mesh, "axis_names", ()) or ()
        if not names:
            return x
        sizes = dict(zip(names, mesh.axis_sizes))
        out = []
        for i, d in enumerate(dims):
            if d == "batch":
                axes = tuple(a for a in ("pod", "data") if a in names)
                n = 1
                for a in axes:
                    n *= sizes[a]
                out.append(axes if axes and x.shape[i] % n == 0 else None)
            elif d == "expert":
                ok = "model" in names and x.shape[i] % sizes["model"] == 0
                out.append("model" if ok else None)
            else:
                out.append(None)
        return jax.lax.with_sharding_constraint(x, P(*out))
    except Exception:
        return x


def _num_groups(cfg: ModelConfig, n_tokens: int) -> int:
    """Dispatch group count.  Groups are the unit of locality: all
    sort/gather/scatter ops carry a leading group axis that stays
    sharded over the data mesh axes, so dispatch never degenerates into
    global collectives (§Perf iteration 1 — the ungrouped global argsort
    cost ~1e14 all-reduce bytes PER LAYER at train_4k scale)."""
    g = cfg.moe_groups
    while g > 1 and (n_tokens % g != 0 or n_tokens // g < 64):
        g //= 2
    return max(1, g)


def moe_mlp(cfg: ModelConfig, p, x: jax.Array) -> tuple[jax.Array, MoEAux]:
    """x: [B, S, D] -> (out [B, S, D], aux losses).

    Grouped sort-based dispatch (t5x/megablocks-style): tokens split
    into G groups aligned with the data-parallel sharding; per group:
    argsort by assigned expert, truncate each expert's queue at
    capacity/G, dense per-expert GEMMs, scatter back with router gates.
    """
    B, S, D = x.shape
    E, K = cfg.padded_experts, cfg.top_k
    N = B * S
    G = _num_groups(cfg, N)
    Ng = N // G
    cap = max(4, int(cfg.capacity_factor * K * Ng / max(cfg.num_experts, 1)))

    en = cfg.moe_constrain_dispatch
    xg = _constrain(x.reshape(G, Ng, D), "batch", None, None, enable=en)
    logits = jnp.einsum("gnd,de->gne", xg, p["router"]).astype(jnp.float32)
    if E > cfg.num_experts:  # padded experts are unroutable
        pad_mask = jnp.where(jnp.arange(E) < cfg.num_experts, 0.0, -1e30)
        logits = logits + pad_mask
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)                        # [G, Ng, K]
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    # aux losses (global statistics) -----------------------------------------
    me = probs.mean(axis=(0, 1))                                           # [E]
    ce = jnp.zeros(E).at[expert_ids.reshape(-1)].add(1.0) / (N * K)
    lb = cfg.num_experts * jnp.sum(me * ce)
    z = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)

    # group-local sort-based dispatch -----------------------------------------
    flat_e = expert_ids.reshape(G, Ng * K)
    flat_g = gate_vals.reshape(G, Ng * K)
    flat_tok = jnp.broadcast_to(
        (jnp.arange(Ng * K, dtype=jnp.int32) // K)[None], (G, Ng * K))
    order = jnp.argsort(flat_e, axis=1, stable=True)                       # [G, NgK]
    e_sorted = jnp.take_along_axis(flat_e, order, axis=1)
    tok_sorted = jnp.take_along_axis(flat_tok, order, axis=1)
    g_sorted = jnp.take_along_axis(flat_g, order, axis=1)
    counts = jnp.zeros((G, E), jnp.int32).at[
        jnp.arange(G)[:, None], flat_e].add(1)
    starts = jnp.concatenate(
        [jnp.zeros((G, 1), jnp.int32), jnp.cumsum(counts, axis=1)[:, :-1]], axis=1)
    pos_in_e = jnp.arange(Ng * K, dtype=jnp.int32)[None] - jnp.take_along_axis(
        starts, e_sorted, axis=1)
    keep = pos_in_e < cap

    # gather tokens into [G, E, cap, D]
    slot = e_sorted * cap + pos_in_e                                       # [G, NgK]
    slot = jnp.where(keep, slot, E * cap)                                  # overflow -> waste
    x_slots = jnp.take_along_axis(xg, tok_sorted[..., None], axis=1)       # [G, NgK, D]
    x_slots = _constrain(x_slots.astype(x.dtype), "batch", None, None, enable=en)
    slot = _constrain(slot, "batch", None, enable=en)
    z0 = _constrain(jnp.zeros((G, E * cap + 1, D), x.dtype), "batch", None, None, enable=en)
    xe = z0.at[jnp.arange(G)[:, None], slot].set(x_slots)
    xe = _constrain(xe, "batch", None, None, enable=en)
    xe = _constrain(xe[:, :-1].reshape(G, E, cap, D),
                    "batch", "expert", None, None, enable=en)

    # per-expert GEMMs (experts sharded over "model": all-to-all happens here)
    act = jax.nn.gelu if cfg.act == "gelu" else jax.nn.silu
    h = act(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) * jnp.einsum(
        "gecd,edf->gecf", xe, p["w_up"]
    )
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])                      # [G, E, cap, D]
    ye = _constrain(ye, "batch", "expert", None, None, enable=en)

    # combine -------------------------------------------------------------------
    yf = _constrain(ye.reshape(G, E * cap, D), "batch", None, None, enable=en)
    contrib = jnp.where(
        keep[..., None],
        jnp.take_along_axis(yf, jnp.clip(slot, 0, E * cap - 1)[..., None], axis=1),
        0.0)
    contrib = _constrain(contrib.astype(x.dtype), "batch", None, None, enable=en)
    z1 = _constrain(jnp.zeros((G, Ng, D), x.dtype), "batch", None, None, enable=en)
    out = z1.at[jnp.arange(G)[:, None], tok_sorted].add(
        contrib * g_sorted[..., None].astype(x.dtype))
    out = _constrain(out, "batch", None, None, enable=en)
    return out.reshape(B, S, D), MoEAux(lb, z)
