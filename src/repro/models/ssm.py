"""Mamba2 (SSD — state-space duality) layer [arXiv:2405.21060].

TPU adaptation (DESIGN.md §Hardware-adaptation): the CUDA reference
implements SSD with fused warp-level scans; on TPU we use the paper's own
*block decomposition* — intra-chunk terms are dense matmuls (MXU) and
only the O(S / chunk) inter-chunk state passing is a sequential
``lax.scan``, which is exactly the structure the SSD paper recommends
for matmul-rich hardware.

Single-group (G=1) B/C variant, scalar-per-head A (the Mamba2 default).

Three entry points:
  ssd_train   — full-sequence chunked scan (training / prefill)
  ssd_decode  — single-token recurrence against carried state
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import rmsnorm
from repro.models.params import spec


class SSMState(NamedTuple):
    conv: jax.Array  # [B, W-1, di + 2N]   rolling conv window
    ssm: jax.Array   # [B, H, dh, N]       recurrent state


def ssm_param_specs(cfg: ModelConfig, n_layers: Optional[int] = None, layer_axis: bool = True):
    D, di, N, H, W = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                      cfg.ssm_heads, cfg.ssm_conv_width)
    lead = (n_layers,) if layer_axis else ()
    la = ("layers",) if layer_axis else ()
    return {
        # projections to [z | x | B | C | dt]
        "in_proj": spec((*lead, D, 2 * di + 2 * N + H), (*la, "embed_in", "inner")),
        "conv_w": spec((*lead, W, di + 2 * N), (*la, "conv", "inner")),
        "conv_b": spec((*lead, di + 2 * N), (*la, "inner"), init="zeros"),
        "A_log": spec((*lead, H), (*la, "ssm_heads"), init="zeros"),
        "D": spec((*lead, H), (*la, "ssm_heads"), init="ones"),
        "dt_bias": spec((*lead, H), (*la, "ssm_heads"), init="zeros"),
        "norm_w": spec((*lead, di), (*la, "inner"), init="zeros"),
        "out_proj": spec((*lead, di, D), (*la, "inner", "embed")),
    }


def _split_proj(cfg: ModelConfig, zxbcdt: jax.Array):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :di]
    xBC = zxbcdt[..., di: 2 * di + 2 * N]
    dt = zxbcdt[..., 2 * di + 2 * N:]
    return z, xBC, dt


def _conv_causal(xBC: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv1d: xBC [B, S, Ch], w [W, Ch]."""
    W = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(xBC)
    for i in range(W):  # W is small (4): unrolled shifts, no gather
        out = out + pad[:, i: i + xBC.shape[1]] * w[i]
    return jax.nn.silu(out + b)


def ssd_train(cfg: ModelConfig, p, x: jax.Array, return_state: bool = False):
    """Full-sequence SSD. x: [B, S, D] -> [B, S, D] (+ final SSMState
    when ``return_state``, enabling prefill-then-decode serving)."""
    B, S, D = x.shape
    di, N, H, dh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    # largest chunk <= cfg.ssm_chunk that divides S (assigned shapes are
    # powers of two, so this is cfg.ssm_chunk in production; odd test
    # lengths degrade gracefully)
    Q = min(cfg.ssm_chunk, S)
    while S % Q != 0:
        Q -= 1
    nC = S // Q

    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    xBC_raw = xBC  # pre-conv activations: the rolling conv window for decode
    xBC = _conv_causal(xBC, p["conv_w"], p["conv_b"])
    xi = xBC[..., :di].reshape(B, S, H, dh)
    Bm = xBC[..., di: di + N]                      # [B, S, N]
    Cm = xBC[..., di + N:]                         # [B, S, N]
    dt = jax.nn.softplus(dt + p["dt_bias"])        # [B, S, H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))   # [H]

    # chunk everything: [B, nC, Q, ...]
    def ck(a, extra=()):
        return a.reshape(B, nC, Q, *extra)

    xi_c = xi.reshape(B, nC, Q, H, dh)
    B_c = ck(Bm, (N,))
    C_c = ck(Cm, (N,))
    dA_c = ck(dt * A, (H,))
    dt_c = ck(dt, (H,))

    cum = jnp.cumsum(dA_c, axis=2)                 # [B, nC, Q, H] inclusive
    seg_end = cum[:, :, -1]                        # [B, nC, H] total decay per chunk

    # intra-chunk: Y[i] = sum_{j<=i} C_i.B_j exp(cum_i - cum_j) dt_j x_j
    decay = jnp.exp(cum[:, :, :, None] - cum[:, :, None, :, :])      # [B,nC,Qi,Qj,H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, None, :, :, None]
    decay = jnp.where(causal, decay, 0.0)
    cb = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)                     # [B,nC,Qi,Qj]
    scores = cb[..., None] * decay                                    # [B,nC,Qi,Qj,H]
    y_intra = jnp.einsum("bcijh,bcjh,bcjhp->bcihp", scores, dt_c, xi_c)

    # chunk-local final states: S_loc = sum_j exp(seg_end - cum_j) dt_j B_j x_j^T
    w = jnp.exp(seg_end[:, :, None] - cum) * dt_c                     # [B,nC,Q,H]
    S_loc = jnp.einsum("bcjh,bcjn,bcjhp->bchpn", w, B_c, xi_c)        # [B,nC,H,dh,N]

    # inter-chunk scan over nC (sequential, nC = S/Q steps)
    def scan_body(carry, inp):
        S_prev = carry                                               # [B,H,dh,N]
        S_l, g = inp                                                 # g: [B,H] chunk decay
        S_new = S_prev * jnp.exp(g)[:, :, None, None] + S_l
        return S_new, S_prev

    S0 = jnp.zeros((B, H, dh, N), jnp.float32)
    S_final, S_prevs = jax.lax.scan(
        scan_body, S0,
        (jnp.moveaxis(S_loc, 1, 0), jnp.moveaxis(seg_end, 1, 0)),
    )
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)                            # [B,nC,H,dh,N]

    # inter-chunk contribution: Y_i += C_i . S_prev * exp(cum_i)
    y_inter = jnp.einsum("bcin,bchpn,bcih->bcihp",
                         C_c, S_prevs, jnp.exp(cum))
    y = (y_intra + y_inter).reshape(B, S, H, dh)
    y = y + xi * p["D"][None, None, :, None]
    y = y.reshape(B, S, di)
    y = rmsnorm(y, p["norm_w"]) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y.astype(x.dtype), p["out_proj"])
    if not return_state:
        return out
    W = cfg.ssm_conv_width
    state = SSMState(conv=xBC_raw[:, S - (W - 1):], ssm=S_final)
    return out, state


def ssm_init_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SSMState:
    di, N, H, dh, W = (cfg.d_inner, cfg.ssm_state, cfg.ssm_heads,
                       cfg.ssm_head_dim, cfg.ssm_conv_width)
    return SSMState(
        conv=jnp.zeros((batch, W - 1, di + 2 * N), dtype),
        ssm=jnp.zeros((batch, H, dh, N), jnp.float32),
    )


def ssd_decode(cfg: ModelConfig, p, x: jax.Array, state: SSMState) -> tuple[jax.Array, SSMState]:
    """One-token recurrence. x: [B, 1, D] -> ([B, 1, D], new state)."""
    B = x.shape[0]
    di, N, H, dh = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    zxbcdt = jnp.einsum("bsd,de->bse", x, p["in_proj"])[:, 0]        # [B, E]
    z, xBC, dt = _split_proj(cfg, zxbcdt)
    # rolling conv
    window = jnp.concatenate([state.conv, xBC[:, None]], axis=1)     # [B, W, Ch]
    xBC = jax.nn.silu(jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"])
    new_conv = window[:, 1:]
    xi = xBC[..., :di].reshape(B, H, dh)
    Bm = xBC[..., di: di + N]
    Cm = xBC[..., di + N:]
    dt = jax.nn.softplus(dt + p["dt_bias"])                          # [B, H]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                             # [B, H]
    dBx = jnp.einsum("bh,bn,bhp->bhpn", dt, Bm, xi)
    S_new = state.ssm * dA[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cm, S_new)                        # [B,H,dh]
    y = y + xi * p["D"][None, :, None]
    y = y.reshape(B, di)
    y = rmsnorm(y, p["norm_w"]) * jax.nn.silu(z)
    out = jnp.einsum("bi,id->bd", y.astype(x.dtype), p["out_proj"])[:, None]
    return out, SSMState(conv=new_conv, ssm=S_new)
