"""Model configuration schema covering all assigned architecture families.

One frozen dataclass drives every family (dense / moe / ssm / hybrid /
encdec / vlm); family-specific fields are zero/None when unused.  Each
``src/repro/configs/<arch>.py`` instantiates one of these with the exact
assigned hyperparameters and cites its source.
"""
from __future__ import annotations

import dataclasses
from typing import Optional


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 -> d_model // num_heads

    # --- attention options -------------------------------------------------
    rope_theta: float = 10_000.0
    qk_norm: bool = False            # qwen3
    attn_logit_softcap: Optional[float] = None   # gemma2: 50.0
    final_logit_softcap: Optional[float] = None  # gemma2: 30.0
    sliding_window: Optional[int] = None         # gemma2 local layers: 4096
    local_global: bool = False       # gemma2: alternate local/global layers
    attn_scale_override: Optional[float] = None  # gemma2-27b uses (d/2H)^-0.5

    # --- norms / mlp --------------------------------------------------------
    norm_type: str = "rmsnorm"       # rmsnorm | layernorm | layernorm_nonparam (olmo)
    act: str = "silu"                # silu | gelu (gemma)
    post_attn_norm: bool = False     # gemma2 extra post-norms
    scale_embeds: bool = False       # gemma2: multiply embeddings by sqrt(D)
    tie_embeddings: bool = True

    # --- MoE ----------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0                # per-expert hidden width
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    moe_groups: int = 32             # dispatch groups (data-local sorting)
    # Pad the expert count (router logits masked to -inf, zero traffic &
    # zero gradients for pads) so the expert axis divides the model mesh
    # axis — §Perf iteration 3 (40 experts on a 16-way axis were fully
    # REPLICATED otherwise).
    expert_pad_to: int = 0           # 0 = off
    # Explicit sharding constraints on the dispatch scatters. Big win for
    # tp-profile MoE (granite: collective 2.5x down); HURTS fsdp_tp MoE
    # (qwen3-moe: conflicts with data-sharded expert_ffn weights) — see
    # EXPERIMENTS.md SPerf iteration 3/4.
    moe_constrain_dispatch: bool = True

    # --- SSM (mamba2) --------------------------------------------------------
    ssm_state: int = 0               # N (state dim per head)
    ssm_expand: int = 2              # d_inner = expand * d_model
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # --- hybrid (zamba2) ------------------------------------------------------
    hybrid_period: int = 0           # shared attention every N ssm layers

    # --- encoder-decoder (whisper) ---------------------------------------------
    encoder_layers: int = 0
    encoder_seq: int = 0             # audio frames after conv frontend (stub)

    # --- vlm (internvl2) ---------------------------------------------------------
    num_patches: int = 0             # image patch embeddings from the stub ViT
    vision_embed_dim: int = 0        # stub frontend output width

    # --- anytime (paper technique carried over to transformers) -----------------
    anytime_exits: bool = False      # per-layer logit-lens early-exit heads

    # --- distribution ---------------------------------------------------------
    sharding_profile: str = "tp"     # tp | fsdp_tp
    shard_kv_heads: bool = True      # False -> replicate KV heads across model axis
    remat: bool = True               # activation checkpointing per block
    scan_layers: bool = True
    # Pad query heads (per kv group, masked to zero contribution) so the
    # head axis divides the model mesh axis — §Perf iteration 2. Real
    # heads keep their original kv-group assignment; padded heads are
    # multiplicatively masked before wo so both their output AND their
    # gradients are exactly zero.
    head_pad_to: int = 0             # 0 = off; else pad num_heads up to this

    citation: str = ""

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads > 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # derived ---------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def padded_heads(self) -> int:
        """Query-head count after TP divisibility padding (== num_heads
        when head_pad_to is 0).  Padding is inserted PER KV GROUP so real
        heads keep their original kv-head assignment."""
        if not self.head_pad_to or self.head_pad_to <= self.num_heads:
            return self.num_heads
        kh = max(self.num_kv_heads, 1)
        g_pad = -(-self.head_pad_to // kh)
        return kh * g_pad

    @property
    def padded_experts(self) -> int:
        if not self.expert_pad_to or self.expert_pad_to <= self.num_experts:
            return self.num_experts
        return self.expert_pad_to

    @property
    def attn_scale(self) -> float:
        if self.attn_scale_override is not None:
            return self.attn_scale_override
        return self.head_dim ** -0.5

    def param_count(self) -> int:
        """Approximate total parameter count (used for roofline 6ND)."""
        D, V, L = self.d_model, self.vocab_size, self.num_layers
        emb = V * D * (1 if self.tie_embeddings else 2)
        n = emb
        hd = self.head_dim
        attn = D * self.num_heads * hd + 2 * D * self.num_kv_heads * hd + self.num_heads * hd * D
        if self.family in ("dense", "vlm"):
            n += L * (attn + 3 * D * self.d_ff)
        elif self.family == "moe":
            n += L * (attn + 3 * D * self.moe_d_ff * self.num_experts + D * self.num_experts)
        elif self.family == "ssm":
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            per = D * (2 * di + 2 * N + H) + di * D + self.ssm_conv_width * (di + 2 * N)
            n += L * per
        elif self.family == "hybrid":
            di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
            per = D * (2 * di + 2 * N + H) + di * D + self.ssm_conv_width * (di + 2 * N)
            n += L * per
            # one shared transformer block + per-group adapters
            n_groups = L // max(self.hybrid_period, 1)
            n += attn + 3 * D * self.d_ff + n_groups * D * D
        elif self.family == "encdec":
            n += self.encoder_layers * (attn + 2 * D * self.d_ff)
            n += L * (2 * attn + 2 * D * self.d_ff)  # self + cross attention
        return n

    def active_param_count(self) -> int:
        """Activated parameters per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        D, L = self.d_model, self.num_layers
        dense = self.param_count() - L * 3 * D * self.moe_d_ff * self.num_experts
        return dense + L * 3 * D * self.moe_d_ff * self.top_k
