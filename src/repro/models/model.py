"""Model facade: config -> params/specs, losses, and shape-only input
specifications for the dry-run.

``input_specs(cfg, shape)`` returns ShapeDtypeStructs (no allocation) for
each execution kind; the frontend carve-outs (audio frames, image
patches) appear here as precomputed embedding inputs.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.shapes import SHAPES, InputShape
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.params import init_params, param_count


def build_param_specs(cfg: ModelConfig):
    return T.param_specs(cfg)


def init(cfg: ModelConfig, key: jax.Array):
    return init_params(build_param_specs(cfg), key)


# ---------------------------------------------------------------------------
# batch construction
# ---------------------------------------------------------------------------

def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text tokens in a sequence (VLM reserves positions for patches)."""
    if cfg.family == "vlm":
        return max(1, seq_len - cfg.num_patches)
    return seq_len


def input_specs(cfg: ModelConfig, shape: InputShape | str, dtype=jnp.bfloat16) -> dict[str, Any]:
    """ShapeDtypeStruct stand-ins for every model input of this shape."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B = shape.global_batch
    S = shape.seq_len
    kind = shape.kind
    i32 = jnp.int32

    def tok(s):
        return jax.ShapeDtypeStruct((B, s), i32)

    if kind in ("train", "prefill"):
        St = _text_len(cfg, S)
        batch: dict[str, Any] = {"tokens": tok(St)}
        if kind == "train":
            batch["labels"] = tok(St)
            batch["loss_mask"] = jax.ShapeDtypeStruct((B, St), dtype)
        if cfg.family == "vlm":
            batch["image_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.num_patches, cfg.vision_embed_dim), dtype)
        if cfg.family == "encdec":
            batch["audio_embeds"] = jax.ShapeDtypeStruct(
                (B, cfg.encoder_seq, cfg.vision_embed_dim or cfg.d_model), dtype)
        return batch
    if kind == "decode":
        return {"tokens": tok(1)}
    raise ValueError(kind)


def make_batch(cfg: ModelConfig, shape: InputShape | str, key: jax.Array,
               dtype=jnp.float32) -> dict[str, Any]:
    """Materialized random batch matching input_specs (smoke tests/examples)."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    specs = input_specs(cfg, shape, dtype=dtype)
    out = {}
    for name, s in specs.items():
        key, k = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab_size, s.dtype)
        else:
            if name == "loss_mask":
                out[name] = jnp.ones(s.shape, s.dtype)
            else:
                out[name] = jax.random.normal(k, s.shape, s.dtype)
    return out


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------

def lm_loss(cfg: ModelConfig, params, batch) -> tuple[jax.Array, dict[str, jax.Array]]:
    """Next-token cross-entropy (+MoE aux).  VLM: image positions excluded."""
    logits, aux = T.forward(cfg, params, batch)
    if cfg.family == "vlm":
        logits = logits[:, cfg.num_patches:]
    labels = batch["labels"]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, logits.dtype)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    metrics = {"ce": loss}
    if cfg.family == "moe":
        loss = loss + cfg.router_aux_loss * aux["moe_lb"] + 1e-3 * aux["moe_z"]
        metrics.update({"moe_lb": aux["moe_lb"], "moe_z": aux["moe_z"]})
    metrics["loss"] = loss
    return loss, metrics


def supports_shape(cfg: ModelConfig, shape: InputShape | str) -> tuple[bool, str]:
    """Applicability matrix for the assigned (arch x shape) grid.

    long_500k needs sub-quadratic attention (task spec): SSM/hybrid always
    qualify; gemma2 qualifies via sliding-window local layers (global
    layers remain linear-per-token in decode; see DESIGN.md); pure
    full-attention archs skip.  Whisper's decoder is bounded by its
    448-token spec but we exercise the assigned decode_32k shape anyway
    (backbone stress shape); long_500k is skipped (full attention).
    """
    if isinstance(shape, str):
        shape = SHAPES[shape]
    if shape.name == "long_500k":
        if cfg.family in ("ssm", "hybrid"):
            return True, ""
        if cfg.local_global and cfg.sliding_window:
            return True, "sliding-window local layers (global layers full-KV decode)"
        return False, "pure full-attention arch: long_500k skipped per task spec"
    if cfg.family == "encdec" and shape.kind == "train" and shape.seq_len > 32_768:
        return False, "decoder context beyond backbone spec"
    return True, ""


def traffic_floor_bytes(cfg: ModelConfig, shape: InputShape | str) -> float:
    """Analytic lower bound on global HBM traffic for one step.

    XLA's 'bytes accessed' on the CPU backend counts every op's operands
    without TPU-grade fusion, so it overestimates; this floor assumes
    perfect fusion: weights streamed once per use, KV/SSM caches read
    once, activations written+read once per layer boundary.  True TPU
    traffic lies in [floor, xla_bound]; EXPERIMENTS.md reports both.
    """
    import numpy as np
    from repro.models import transformer as T

    if isinstance(shape, str):
        shape = SHAPES[shape]
    N = cfg.param_count()
    pb = 2.0 * N                     # bf16 weights, one streaming read
    B, S = shape.global_batch, shape.seq_len
    D, L = cfg.d_model, max(cfg.num_layers, 1)
    act = 2.0 * B * S * D * L * 2    # residual stream in+out per layer (bf16)

    def cache_bytes() -> float:
        like = jax.eval_shape(lambda: T.init_cache(cfg, B, S, dtype=jnp.bfloat16))
        return float(sum(np.prod(leaf.shape) * leaf.dtype.itemsize
                         for leaf in jax.tree_util.tree_leaves(like)))

    if shape.kind == "train":
        # fwd read + bwd read + grad write/read + AdamW m,v fp32 r/w
        return 3 * pb + 2 * 4.0 * N + 2 * 8.0 * N + 2 * act
    if shape.kind == "prefill":
        return pb + cache_bytes() + act
    # decode: weights + full cache read + one-slot write + tiny activations
    return pb + cache_bytes() + 2.0 * B * 1 * D * L * 2


def exact_param_count(cfg: ModelConfig) -> int:
    """Exact parameter count from the spec tree (vs the analytic
    approximation in cfg.param_count)."""
    return param_count(build_param_specs(cfg))


def exact_active_param_count(cfg: ModelConfig) -> int:
    n = exact_param_count(cfg)
    if cfg.family == "moe":
        n -= cfg.num_layers * 3 * cfg.d_model * cfg.moe_d_ff * \
            (cfg.num_experts - cfg.top_k)
    return n


def model_flops(cfg: ModelConfig, shape: InputShape | str) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D for the roofline's
    useful-compute ratio.  D = tokens processed by the step."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    n = exact_active_param_count(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens  # forward only
    tokens = shape.global_batch * 1
    return 2.0 * n * tokens
