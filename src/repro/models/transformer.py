"""Decoder-stack assembly for every assigned architecture family.

Layers are *scanned* (``jax.lax.scan`` over stacked parameter pytrees) so
HLO size and compile time are depth-independent — essential for the 94-
layer qwen3-moe dry-run.  Architectures with heterogeneous layer types
use structured stacks:

  dense/moe/vlm : one homogeneous stack
  gemma2        : paired stacks (local sliding-window layer, global layer)
                  scanned together — which also gives local layers
                  window-sized ring-buffer KV caches in decode
  ssm           : one mamba2 stack
  hybrid        : grouped stacks (N mamba2 layers + one SHARED attention
                  block, zamba2-style) + tail mamba2 layers
  encdec        : encoder stack + decoder stack with cross-attention

Three execution modes share the same parameters:
  train(tokens)           -> logits [B, S, V]
  prefill(tokens)         -> (last-position logits, KV/SSM cache)
  decode(token, cache)    -> (logits [B, 1, V], updated cache)
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S
from repro.models.config import ModelConfig
from repro.models.params import spec

# =============================================================================
# parameter specs
# =============================================================================

def _block_specs(cfg: ModelConfig, n: int, kind: str):
    """Stacked specs for n layers of a given kind."""
    p: dict[str, Any] = {}
    if kind in ("attn_mlp", "attn_moe", "attn_only", "cross"):
        p["attn"] = L.attn_param_specs(cfg, n)
        p["ln_attn"] = L.norm_spec(cfg, n)
        if cfg.post_attn_norm:
            p["ln_attn_post"] = L.norm_spec(cfg, n)
    if kind == "cross":
        p["xattn"] = L.attn_param_specs(cfg, n)
        p["ln_xattn"] = L.norm_spec(cfg, n)
    if kind in ("attn_mlp", "cross"):
        p["mlp"] = L.mlp_param_specs(cfg, n_layers=n)
        p["ln_mlp"] = L.norm_spec(cfg, n)
        if cfg.post_attn_norm:
            p["ln_mlp_post"] = L.norm_spec(cfg, n)
    if kind == "attn_moe":
        p["moe"] = M.moe_param_specs(cfg, n)
        p["ln_mlp"] = L.norm_spec(cfg, n)
    if kind == "mamba":
        p["ssm"] = S.ssm_param_specs(cfg, n)
        p["ln_ssm"] = L.norm_spec(cfg, n)
    return {k: v for k, v in p.items() if v is not None}


def param_specs(cfg: ModelConfig):
    D, V = cfg.d_model, cfg.vocab_size
    p: dict[str, Any] = {
        # N(0, 1/D): unit-variance stream after the sqrt(D) input scale AND
        # unit-variance logits under tied readout.
        "embed": spec((V, D), ("vocab", "embed"), scale=D ** -0.5, init="normal"),
    }
    fln = L.norm_spec(cfg)
    if fln is not None:
        p["final_norm"] = fln
    if not cfg.tie_embeddings:
        p["lm_head"] = spec((D, V), ("embed_in", "vocab"))

    fam = cfg.family
    if fam in ("dense", "vlm"):
        if cfg.local_global:
            n = cfg.num_layers // 2
            p["layers_local"] = _block_specs(cfg, n, "attn_mlp")
            p["layers_global"] = _block_specs(cfg, n, "attn_mlp")
        else:
            p["layers"] = _block_specs(cfg, cfg.num_layers, "attn_mlp")
        if fam == "vlm":
            p["vis_proj"] = spec((cfg.vision_embed_dim, D), ("vis_embed", "embed"))
            p["vis_norm"] = L.norm_spec(cfg) or spec((D,), ("embed",), init="zeros")
    elif fam == "moe":
        p["layers"] = _block_specs(cfg, cfg.num_layers, "attn_moe")
    elif fam == "ssm":
        p["layers"] = _block_specs(cfg, cfg.num_layers, "mamba")
    elif fam == "hybrid":
        per = cfg.hybrid_period
        n_groups = cfg.num_layers // per
        tail = cfg.num_layers - n_groups * per
        p["groups"] = _block_specs(cfg, n_groups * per, "mamba")  # reshaped at use
        if tail:
            p["tail"] = _block_specs(cfg, tail, "mamba")
        # one SHARED transformer block (zamba2) + per-group input adapters
        p["shared_attn"] = L.attn_param_specs(cfg, layer_axis=False)
        p["shared_ln"] = L.norm_spec(cfg)  # may be None (nonparam)
        p["shared_mlp"] = L.mlp_param_specs(cfg, layer_axis=False)
        p["shared_mlp_ln"] = L.norm_spec(cfg)
        p["group_adapters"] = spec((n_groups, D, D), ("groups", "embed_in", "embed"),
                                   scale=0.1)
        p = {k: v for k, v in p.items() if v is not None}
    elif fam == "encdec":
        p["enc_layers"] = _block_specs(cfg, cfg.encoder_layers, "attn_mlp")
        p["enc_final_norm"] = L.norm_spec(cfg) or spec((D,), ("embed",), init="zeros")
        p["layers"] = _block_specs(cfg, cfg.num_layers, "cross")
        p["audio_proj"] = spec((cfg.vision_embed_dim or D, D), ("vis_embed", "embed"))
    else:
        raise ValueError(fam)
    return p


# =============================================================================
# caches
# =============================================================================

class AttnCache(NamedTuple):
    k: jax.Array   # [n, B, Sc, KH, dh]
    v: jax.Array   # [n, B, Sc, KH, dh]


@dataclasses.dataclass
class Cache:
    """Decode-time state for the whole stack.  ``pos`` is the number of
    tokens already absorbed (uniform across the batch)."""
    pos: jax.Array                       # int32 scalar
    attn: dict[str, AttnCache]           # per stack name
    ssm: dict[str, S.SSMState]           # per stack name (stacked over layers)
    cross: Optional[AttnCache] = None    # encdec: precomputed encoder K/V


def _attn_cache_spec(cfg: ModelConfig, n: int, B: int, Sc: int, dtype):
    KH, dh = cfg.num_kv_heads, cfg.head_dim
    z = jnp.zeros((n, B, Sc, KH, dh), dtype)
    return AttnCache(k=z, v=z)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16) -> Cache:
    attn: dict[str, AttnCache] = {}
    ssm: dict[str, S.SSMState] = {}
    fam = cfg.family
    if fam in ("dense", "vlm"):
        if cfg.local_global:
            n = cfg.num_layers // 2
            w = min(cfg.sliding_window or max_seq, max_seq)
            attn["local"] = _attn_cache_spec(cfg, n, batch, w, dtype)
            attn["global"] = _attn_cache_spec(cfg, n, batch, max_seq, dtype)
        else:
            attn["layers"] = _attn_cache_spec(cfg, cfg.num_layers, batch, max_seq, dtype)
    elif fam == "moe":
        attn["layers"] = _attn_cache_spec(cfg, cfg.num_layers, batch, max_seq, dtype)
    elif fam == "ssm":
        st = S.ssm_init_state(cfg, batch)
        ssm["layers"] = S.SSMState(
            conv=jnp.broadcast_to(st.conv, (cfg.num_layers, *st.conv.shape)),
            ssm=jnp.broadcast_to(st.ssm, (cfg.num_layers, *st.ssm.shape)),
        )
    elif fam == "hybrid":
        per = cfg.hybrid_period
        n_groups = cfg.num_layers // per
        tail = cfg.num_layers - n_groups * per
        st = S.ssm_init_state(cfg, batch)
        ssm["groups"] = S.SSMState(
            conv=jnp.broadcast_to(st.conv, (n_groups * per, *st.conv.shape)),
            ssm=jnp.broadcast_to(st.ssm, (n_groups * per, *st.ssm.shape)),
        )
        if tail:
            ssm["tail"] = S.SSMState(
                conv=jnp.broadcast_to(st.conv, (tail, *st.conv.shape)),
                ssm=jnp.broadcast_to(st.ssm, (tail, *st.ssm.shape)),
            )
        attn["shared"] = _attn_cache_spec(cfg, n_groups, batch, max_seq, dtype)
    elif fam == "encdec":
        attn["layers"] = _attn_cache_spec(cfg, cfg.num_layers, batch, max_seq, dtype)
        # cross K/V (overwritten at prefill from the encoder output)
        cross = _attn_cache_spec(cfg, cfg.num_layers, batch, cfg.encoder_seq, dtype)
        return Cache(pos=jnp.zeros((), jnp.int32), attn=attn, ssm=ssm, cross=cross)
    return Cache(pos=jnp.zeros((), jnp.int32), attn=attn, ssm=ssm, cross=None)


jax.tree_util.register_dataclass(Cache, ["pos", "attn", "ssm", "cross"], [])


# =============================================================================
# blocks
# =============================================================================

def _norm(cfg, p, name, x):
    w = p.get(name) if isinstance(p, dict) else None
    return L.apply_norm(cfg, x, w)


def _attn_train(cfg: ModelConfig, p, x, positions, window, causal=True):
    """Full-sequence attention (train / prefill). Returns (out, (k, v))."""
    q, k, v = L.qkv_project(cfg, p, x, positions)
    o = L.attention(
        q, k, v, positions, positions,
        scale=cfg.attn_scale, causal=causal, window=window,
        softcap=cfg.attn_logit_softcap,
    )
    return L.attn_out(p, o, cfg), (k, v)


def _dense_block(cfg: ModelConfig, lp, x, positions, window):
    h = _norm(cfg, lp, "ln_attn", x)
    a, kv = _attn_train(cfg, lp["attn"], h, positions, window)
    if cfg.post_attn_norm:
        a = _norm(cfg, lp, "ln_attn_post", a)
    x = x + a
    h = _norm(cfg, lp, "ln_mlp", x)
    m = L.mlp(cfg, lp["mlp"], h)
    if cfg.post_attn_norm:
        m = _norm(cfg, lp, "ln_mlp_post", m)
    return x + m, kv


def _moe_block(cfg: ModelConfig, lp, x, positions):
    h = _norm(cfg, lp, "ln_attn", x)
    a, kv = _attn_train(cfg, lp["attn"], h, positions, None)
    x = x + a
    h = _norm(cfg, lp, "ln_mlp", x)
    m, aux = M.moe_mlp(cfg, lp["moe"], h)
    return x + m, kv, aux


def _mamba_block(cfg: ModelConfig, lp, x, return_state: bool = False):
    h = _norm(cfg, lp, "ln_ssm", x)
    if return_state:
        y, st = S.ssd_train(cfg, lp["ssm"], h, return_state=True)
        return x + y, st
    return x + S.ssd_train(cfg, lp["ssm"], h)


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat:
        return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)
    return fn


def _scan(cfg: ModelConfig, body, carry, xs):
    """lax.scan over stacked layers, or an unrolled python loop when
    cfg.scan_layers=False.  Unrolling exists for the roofline dry-run:
    XLA's cost_analysis counts a while-loop body ONCE regardless of trip
    count, so accurate FLOP/byte/collective totals need the unrolled HLO
    (compile time is depth-proportional; production uses scan)."""
    if cfg.scan_layers:
        return jax.lax.scan(body, carry, xs)
    length = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(length):
        xi = jax.tree_util.tree_map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys and jax.tree_util.tree_leaves(ys[0]):
        ys = jax.tree_util.tree_map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


# =============================================================================
# full-sequence forward (train / prefill) per family
# =============================================================================

def _embed_inputs(cfg: ModelConfig, params, batch) -> tuple[jax.Array, jax.Array]:
    """Token (+frontend) embedding. Returns (x [B,S,D], positions [B,S])."""
    tokens = batch["tokens"]
    x = params["embed"][tokens]
    if cfg.scale_embeds:  # gemma2 only — other archs use raw embeddings
        x = x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)
    if cfg.family == "vlm":
        vis = jnp.einsum("bpe,ed->bpd", batch["image_embeds"].astype(x.dtype),
                         params["vis_proj"])
        vis = L.apply_norm(cfg, vis, params.get("vis_norm"))
        x = jnp.concatenate([vis, x], axis=1)
    B, Sx = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(Sx, dtype=jnp.int32)[None], (B, Sx))
    return x, positions


def _run_encoder(cfg: ModelConfig, params, audio_embeds) -> jax.Array:
    """Whisper-style encoder over precomputed (stub) frame embeddings."""
    x = jnp.einsum("bse,ed->bsd", audio_embeds, params["audio_proj"])
    B, Sa = x.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(Sa, dtype=jnp.int32)[None], (B, Sa))

    def body(x, lp):
        def blk(x):
            h = _norm(cfg, lp, "ln_attn", x)
            a, _ = _attn_train(cfg, lp["attn"], h, pos, None, causal=False)
            x = x + a
            h = _norm(cfg, lp, "ln_mlp", x)
            return x + L.mlp(cfg, lp["mlp"], h)
        return _maybe_remat(cfg, blk)(x), None

    x, _ = _scan(cfg, body, x, params["enc_layers"])
    return L.apply_norm(cfg, x, params.get("enc_final_norm"))


def forward(cfg: ModelConfig, params, batch, *, return_cache: bool = False,
            cache_len: Optional[int] = None):
    """Full-sequence forward.  train: logits over all positions.
    prefill (return_cache): also builds the decode cache of ``cache_len``."""
    x, positions = _embed_inputs(cfg, params, batch)
    B, Sx, D = x.shape
    aux = {"moe_lb": jnp.zeros(()), "moe_z": jnp.zeros(())}
    collected: dict[str, AttnCache] = {}
    collected_ssm: dict[str, S.SSMState] = {}
    fam = cfg.family
    enc_out = None

    if fam in ("dense", "vlm") and cfg.local_global:
        def body(x, lps):
            lp_l, lp_g = lps
            def blk(x):
                x, kv_l = _dense_block(cfg, lp_l, x, positions, cfg.sliding_window)
                x, kv_g = _dense_block(cfg, lp_g, x, positions, None)
                return x, (kv_l, kv_g)
            return _maybe_remat(cfg, blk)(x)

        x, (kv_l, kv_g) = _scan(
            cfg, body, x, (params["layers_local"], params["layers_global"]))
        if return_cache:
            collected["local"] = AttnCache(*kv_l)
            collected["global"] = AttnCache(*kv_g)
    elif fam in ("dense", "vlm"):
        def body(x, lp):
            def blk(x):
                return _dense_block(cfg, lp, x, positions, cfg.sliding_window)
            return _maybe_remat(cfg, blk)(x)
        x, kv = _scan(cfg, body, x, params["layers"])
        if return_cache:
            collected["layers"] = AttnCache(*kv)
    elif fam == "moe":
        def body(carry, lp):
            x, lb, z = carry
            def blk(x):
                return _moe_block(cfg, lp, x, positions)
            x, kv, a = _maybe_remat(cfg, blk)(x)
            return (x, lb + a.load_balance_loss, z + a.router_z_loss), kv
        (x, lb, z), kv = _scan(cfg, body, (x, aux["moe_lb"], aux["moe_z"]),
                               params["layers"])
        aux = {"moe_lb": lb / cfg.num_layers, "moe_z": z / cfg.num_layers}
        if return_cache:
            collected["layers"] = AttnCache(*kv)
    elif fam == "ssm":
        def body(x, lp):
            if return_cache:
                return _maybe_remat(cfg, lambda x: _mamba_block(cfg, lp, x, True))(x)
            return _maybe_remat(cfg, lambda x: _mamba_block(cfg, lp, x))(x), None
        x, sts = _scan(cfg, body, x, params["layers"])
        if return_cache:
            collected_ssm["layers"] = sts
    elif fam == "hybrid":
        per = cfg.hybrid_period
        n_groups = cfg.num_layers // per
        tail = cfg.num_layers - n_groups * per
        gp = jax.tree_util.tree_map(
            lambda a: a.reshape(n_groups, per, *a.shape[1:]), params["groups"])

        def group_body(x, inp):
            glp, adapter = inp

            def blk(x):
                def inner(x, lp):
                    if return_cache:
                        return _mamba_block(cfg, lp, x, True)
                    return _mamba_block(cfg, lp, x), None
                x, g_sts = jax.lax.scan(inner, x, glp)
                # zamba2 shared transformer block with per-group adapter
                h = L.apply_norm(cfg, x, params.get("shared_ln"))
                h = jnp.einsum("bsd,de->bse", h, adapter)
                a, kv = _attn_train(cfg, params["shared_attn"], h, positions, None)
                x = x + a
                h = L.apply_norm(cfg, x, params.get("shared_mlp_ln"))
                return x + L.mlp(cfg, params["shared_mlp"], h), (kv, g_sts)
            return _maybe_remat(cfg, blk)(x)

        x, (kv, g_sts) = _scan(cfg, group_body, x, (gp, params["group_adapters"]))
        if return_cache:
            collected["shared"] = AttnCache(*kv)
            collected_ssm["groups"] = jax.tree_util.tree_map(
                lambda a: a.reshape(n_groups * per, *a.shape[2:]), g_sts)
        if tail:
            def body(x, lp):
                if return_cache:
                    return _mamba_block(cfg, lp, x, True)
                return _maybe_remat(cfg, lambda x: _mamba_block(cfg, lp, x))(x), None
            x, t_sts = _scan(cfg, body, x, params["tail"])
            if return_cache:
                collected_ssm["tail"] = t_sts
    elif fam == "encdec":
        enc_out = _run_encoder(cfg, params, batch["audio_embeds"])
        enc_pos = jnp.broadcast_to(
            jnp.arange(enc_out.shape[1], dtype=jnp.int32)[None], enc_out.shape[:2])

        def body(x, lp):
            def blk(x):
                h = _norm(cfg, lp, "ln_attn", x)
                a, kv = _attn_train(cfg, lp["attn"], h, positions, None)
                x = x + a
                h = _norm(cfg, lp, "ln_xattn", x)
                # cross attention: q from decoder, K/V from encoder output
                qx = jnp.einsum("bsd,dhk->bshk", h, lp["xattn"]["wq"])
                kx = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wk"])
                vx = jnp.einsum("bsd,dhk->bshk", enc_out, lp["xattn"]["wv"])
                o = L.attention(qx, kx, vx, positions, enc_pos,
                                scale=cfg.attn_scale, causal=False)
                x = x + L.attn_out(lp["xattn"], o, cfg)
                h = _norm(cfg, lp, "ln_mlp", x)
                return x + L.mlp(cfg, lp["mlp"], h), (kv, (kx, vx))
            return _maybe_remat(cfg, blk)(x)
        x, (kv, kv_cross) = _scan(cfg, body, x, params["layers"])
        if return_cache:
            collected["layers"] = AttnCache(*kv)
            collected["__cross__"] = AttnCache(*kv_cross)
    else:
        raise ValueError(fam)

    x = L.apply_norm(cfg, x, params.get("final_norm"))
    logits = L.final_logits(cfg, params["embed"], params.get("lm_head"), x)

    if not return_cache:
        return logits, aux

    # ---- build decode cache from collected full-seq K/V --------------------
    cache = init_cache(cfg, B, cache_len or Sx, dtype=x.dtype)
    pos = jnp.asarray(Sx, jnp.int32)
    cross = collected.pop("__cross__", None)
    for name, kv in collected.items():
        tgt = cache.attn[name]
        Sc = tgt.k.shape[2]
        if Sc >= Sx:
            new = AttnCache(
                k=jax.lax.dynamic_update_slice_in_dim(tgt.k, kv.k.astype(tgt.k.dtype), 0, axis=2),
                v=jax.lax.dynamic_update_slice_in_dim(tgt.v, kv.v.astype(tgt.v.dtype), 0, axis=2),
            )
        else:  # ring buffer (local sliding-window layers): keep last Sc
            slots = (jnp.arange(Sx - Sc, Sx)) % Sc
            new = AttnCache(
                k=tgt.k.at[:, :, slots].set(kv.k[:, :, Sx - Sc:].astype(tgt.k.dtype)),
                v=tgt.v.at[:, :, slots].set(kv.v[:, :, Sx - Sc:].astype(tgt.v.dtype)),
            )
        cache.attn[name] = new
    for name, st in collected_ssm.items():
        cache.ssm[name] = S.SSMState(conv=st.conv.astype(cache.ssm[name].conv.dtype),
                                     ssm=st.ssm)
    cache = dataclasses.replace(cache, pos=pos, cross=cross)
    return logits, aux, cache


# =============================================================================
# anytime early-exit support (the paper's technique on transformers)
# =============================================================================

def exit_logits(cfg: ModelConfig, params, batch) -> jax.Array:
    """Per-layer logit-lens readouts at the final position.

    Returns [L+1, B, V]: entry 0 is the embedding-only readout, entry l
    the readout after layer l (final norm + unembed applied to the
    intermediate residual) — the transformer analogue of the paper's
    inner-node prediction vectors (Sec. III-C).  Supported for the
    homogeneous-stack families (dense/moe without local_global).
    """
    if cfg.family not in ("dense", "moe", "ssm", "vlm") or cfg.local_global:
        raise NotImplementedError("exit_logits: homogeneous stacks only")
    x, positions = _embed_inputs(cfg, params, batch)

    def body(x, lp):
        if cfg.family == "ssm":
            x = _mamba_block(cfg, lp, x)
        elif cfg.family == "moe":
            x, _, _ = _moe_block(cfg, lp, x, positions)
        else:
            x, _ = _dense_block(cfg, lp, x, positions, cfg.sliding_window)
        return x, x[:, -1]

    x_fin, hs = jax.lax.scan(body, x, params["layers"])      # hs: [L, B, D]
    hs = jnp.concatenate([x[None, :, -1], hs], axis=0)        # [L+1, B, D]
    hs = L.apply_norm(cfg, hs, params.get("final_norm"))
    return L.final_logits(cfg, params["embed"], params.get("lm_head"), hs)


# =============================================================================
# decode (one token against the cache)
# =============================================================================

def _cache_positions(pos: jax.Array, Sc: int, ring: bool) -> jax.Array:
    """Absolute position held by each cache slot (-1 = empty).

    Linear cache: slot i holds position i, valid iff i <= pos (the current
    token was just written at slot pos).  Ring cache of width Sc: slot i
    holds the largest p <= pos with p == i (mod Sc)."""
    i = jnp.arange(Sc, dtype=jnp.int32)
    if not ring:
        return jnp.where(i <= pos, i, -1)
    p = pos - ((pos - i) % Sc)
    return jnp.where(p >= 0, p, -1)


def _attn_decode(cfg: ModelConfig, p, x, kc, vc, pos, window):
    """One-token attention against one layer's cache slice.

    x: [B, 1, D]; kc/vc: [B, Sc, KH, dh]. Returns (out, new kc, new vc)."""
    B = x.shape[0]
    Sc = kc.shape[1]
    pos_b = jnp.broadcast_to(pos[None, None], (B, 1))
    q, k, v = L.qkv_project(cfg, p, x, pos_b)
    ring = window is not None and Sc <= window
    slot = (pos % Sc) if ring else pos
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k.astype(kc.dtype), slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v.astype(vc.dtype), slot, axis=1)
    kpos = jnp.broadcast_to(_cache_positions(pos, Sc, ring)[None], (B, Sc))
    o = L.decode_attention(
        q, kc, vc, kpos, jnp.broadcast_to(pos[None], (B,)),
        scale=cfg.attn_scale, window=window, softcap=cfg.attn_logit_softcap,
    )
    return L.attn_out(p, o, cfg), kc, vc


def decode_step(cfg: ModelConfig, params, cache: Cache, tokens: jax.Array):
    """tokens: [B, 1] -> (logits [B, 1, V], updated Cache)."""
    pos = cache.pos
    x = params["embed"][tokens]
    if cfg.scale_embeds:
        x = x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)
    B = x.shape[0]
    fam = cfg.family
    new_attn = dict(cache.attn)
    new_ssm = dict(cache.ssm)

    if fam in ("dense", "vlm") and cfg.local_global:
        lc, gc = cache.attn["local"], cache.attn["global"]

        def body(x, inp):
            lp_l, lp_g, kl, vl, kg, vg = inp
            h = _norm(cfg, lp_l, "ln_attn", x)
            a, kl, vl = _attn_decode(cfg, lp_l["attn"], h, kl, vl, pos,
                                     cfg.sliding_window)
            if cfg.post_attn_norm:
                a = _norm(cfg, lp_l, "ln_attn_post", a)
            x = x + a
            h = _norm(cfg, lp_l, "ln_mlp", x)
            m = L.mlp(cfg, lp_l["mlp"], h)
            if cfg.post_attn_norm:
                m = _norm(cfg, lp_l, "ln_mlp_post", m)
            x = x + m
            h = _norm(cfg, lp_g, "ln_attn", x)
            a, kg, vg = _attn_decode(cfg, lp_g["attn"], h, kg, vg, pos, None)
            if cfg.post_attn_norm:
                a = _norm(cfg, lp_g, "ln_attn_post", a)
            x = x + a
            h = _norm(cfg, lp_g, "ln_mlp", x)
            m = L.mlp(cfg, lp_g["mlp"], h)
            if cfg.post_attn_norm:
                m = _norm(cfg, lp_g, "ln_mlp_post", m)
            return x + m, (kl, vl, kg, vg)

        x, (kl, vl, kg, vg) = _scan(
            cfg, body, x,
            (params["layers_local"], params["layers_global"], lc.k, lc.v, gc.k, gc.v))
        new_attn["local"] = AttnCache(kl, vl)
        new_attn["global"] = AttnCache(kg, vg)
    elif fam in ("dense", "vlm", "moe", "encdec"):
        c = cache.attn["layers"]
        window = cfg.sliding_window

        def body(x, inp):
            lp, kc, vc = inp
            h = _norm(cfg, lp, "ln_attn", x)
            a, kc, vc = _attn_decode(cfg, lp["attn"], h, kc, vc, pos, window)
            x = x + a
            if fam == "moe":
                h = _norm(cfg, lp, "ln_mlp", x)
                m, _ = M.moe_mlp(cfg, lp["moe"], h)
                x = x + m
            elif fam == "encdec":
                hx = _norm(cfg, lp, "ln_xattn", x)
                qx = jnp.einsum("bsd,dhk->bshk", hx, lp["xattn"]["wq"])
                kx, vx = lp["__cross_k"], lp["__cross_v"]
                Sa = kx.shape[1]
                kpos = jnp.broadcast_to(jnp.arange(Sa, dtype=jnp.int32)[None], (B, Sa))
                o = L.decode_attention(qx, kx, vx, kpos,
                                       jnp.full((B,), Sa, jnp.int32),
                                       scale=cfg.attn_scale, window=None,
                                       softcap=cfg.attn_logit_softcap)
                x = x + L.attn_out(lp["xattn"], o, cfg)
                h = _norm(cfg, lp, "ln_mlp", x)
                x = x + L.mlp(cfg, lp["mlp"], h)
            else:
                h = _norm(cfg, lp, "ln_mlp", x)
                m = L.mlp(cfg, lp["mlp"], h)
                if cfg.post_attn_norm:
                    m = _norm(cfg, lp, "ln_mlp_post", m)
                x = x + m
            return x, (kc, vc)

        lp_in = dict(params["layers"])
        if fam == "encdec":
            lp_in["__cross_k"] = cache.cross.k
            lp_in["__cross_v"] = cache.cross.v
        x, (kc, vc) = _scan(cfg, body, x, (lp_in, c.k, c.v))
        new_attn["layers"] = AttnCache(kc, vc)
    elif fam == "ssm":
        st = cache.ssm["layers"]

        def body(x, inp):
            lp, conv, s = inp
            h = _norm(cfg, lp, "ln_ssm", x)
            y, ns = S.ssd_decode(cfg, lp["ssm"], h, S.SSMState(conv, s))
            return x + y, (ns.conv, ns.ssm)

        x, (conv, s) = _scan(cfg, body, x, (params["layers"], st.conv, st.ssm))
        new_ssm["layers"] = S.SSMState(conv, s)
    elif fam == "hybrid":
        per = cfg.hybrid_period
        n_groups = cfg.num_layers // per
        tail = cfg.num_layers - n_groups * per
        st = cache.ssm["groups"]
        sh = cache.attn["shared"]
        gp = jax.tree_util.tree_map(
            lambda a: a.reshape(n_groups, per, *a.shape[1:]), params["groups"])
        gst = jax.tree_util.tree_map(
            lambda a: a.reshape(n_groups, per, *a.shape[1:]), st)

        def group_body(x, inp):
            glp, gconv, gssm, adapter, kc, vc = inp

            def inner(x, lpst):
                lp, conv, s = lpst
                h = _norm(cfg, lp, "ln_ssm", x)
                y, ns = S.ssd_decode(cfg, lp["ssm"], h, S.SSMState(conv, s))
                return x + y, (ns.conv, ns.ssm)

            x, (nconv, nssm) = jax.lax.scan(inner, x, (glp, gconv, gssm))
            h = L.apply_norm(cfg, x, params.get("shared_ln"))
            h = jnp.einsum("bsd,de->bse", h, adapter)
            a, kc, vc = _attn_decode(cfg, params["shared_attn"], h, kc, vc, pos, None)
            x = x + a
            h = L.apply_norm(cfg, x, params.get("shared_mlp_ln"))
            x = x + L.mlp(cfg, params["shared_mlp"], h)
            return x, (nconv, nssm, kc, vc)

        x, (nconv, nssm, kc, vc) = _scan(
            cfg, group_body, x, (gp, gst.conv, gst.ssm, params["group_adapters"], sh.k, sh.v))
        new_ssm["groups"] = S.SSMState(
            conv=nconv.reshape(n_groups * per, *nconv.shape[2:]),
            ssm=nssm.reshape(n_groups * per, *nssm.shape[2:]))
        new_attn["shared"] = AttnCache(kc, vc)
        if tail:
            tst = cache.ssm["tail"]

            def body(x, inp):
                lp, conv, s = inp
                h = _norm(cfg, lp, "ln_ssm", x)
                y, ns = S.ssd_decode(cfg, lp["ssm"], h, S.SSMState(conv, s))
                return x + y, (ns.conv, ns.ssm)

            x, (conv, s) = _scan(cfg, body, x, (params["tail"], tst.conv, tst.ssm))
            new_ssm["tail"] = S.SSMState(conv, s)
    else:
        raise ValueError(fam)

    x = L.apply_norm(cfg, x, params.get("final_norm"))
    logits = L.final_logits(cfg, params["embed"], params.get("lm_head"), x)
    new_cache = Cache(pos=pos + 1, attn=new_attn, ssm=new_ssm, cross=cache.cross)
    return logits, new_cache
