"""Random forests as padded array ensembles.

``ForestArrays`` pads every member tree to a common node count so the
whole forest is a dense ``[T, M, ...]`` tensor stack — the layout the
anytime engine, the Pallas kernels and the order generators all share.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.forest.cart import TreeArrays, train_tree


@dataclasses.dataclass
class ForestArrays:
    """Dense stacked encoding of a forest of ``T`` trees, ``M`` node slots.

    Padding slots are synthetic leaves (self-loop, uniform probs) that are
    unreachable from the root; they exist purely so every tree shares the
    same array shape.
    """

    feature: np.ndarray    # int32   [T, M]
    threshold: np.ndarray  # float32 [T, M]
    left: np.ndarray       # int32   [T, M]
    right: np.ndarray      # int32   [T, M]
    is_leaf: np.ndarray    # bool    [T, M]
    probs: np.ndarray      # float32 [T, M, C]
    max_depth: int         # forest-wide step budget per tree (d)

    @property
    def n_trees(self) -> int:
        return int(self.feature.shape[0])

    @property
    def n_nodes(self) -> int:
        return int(self.feature.shape[1])

    @property
    def n_classes(self) -> int:
        return int(self.probs.shape[2])

    @property
    def total_steps(self) -> int:
        """Total anytime steps in a full execution: d steps per tree."""
        return self.n_trees * self.max_depth

    def reorder(self, tree_order: Sequence[int]) -> "ForestArrays":
        """Forest with trees permuted — used to turn a tree *sequence*
        (e.g. a pruning rank) into Depth/Breadth step orders."""
        o = np.asarray(tree_order)
        return ForestArrays(
            feature=self.feature[o],
            threshold=self.threshold[o],
            left=self.left[o],
            right=self.right[o],
            is_leaf=self.is_leaf[o],
            probs=self.probs[o],
            max_depth=self.max_depth,
        )


@dataclasses.dataclass
class RandomForest:
    trees: list[TreeArrays]
    n_classes: int
    max_depth: int

    @property
    def n_trees(self) -> int:
        return len(self.trees)

    def as_arrays(self) -> ForestArrays:
        T = self.n_trees
        M = max(t.n_nodes for t in self.trees)
        C = self.n_classes
        feature = np.zeros((T, M), dtype=np.int32)
        threshold = np.zeros((T, M), dtype=np.float32)
        left = np.tile(np.arange(M, dtype=np.int32), (T, 1))
        right = left.copy()
        is_leaf = np.ones((T, M), dtype=bool)
        probs = np.full((T, M, C), 1.0 / C, dtype=np.float32)
        for i, t in enumerate(self.trees):
            m = t.n_nodes
            feature[i, :m] = t.feature
            threshold[i, :m] = t.threshold
            left[i, :m] = t.left
            right[i, :m] = t.right
            is_leaf[i, :m] = t.is_leaf
            probs[i, :m] = t.probs
        return ForestArrays(feature, threshold, left, right, is_leaf, probs, self.max_depth)

    def predict_proba(self, X: np.ndarray) -> np.ndarray:
        """Standard (non-anytime) forest prediction: sum of leaf vectors."""
        acc = np.zeros((X.shape[0], self.n_classes), dtype=np.float64)
        for t in self.trees:
            acc += t.predict_proba(X)
        return (acc / self.n_trees).astype(np.float32)

    def predict(self, X: np.ndarray) -> np.ndarray:
        return np.argmax(self.predict_proba(X), axis=1)


def train_forest(
    X: np.ndarray,
    y: np.ndarray,
    n_classes: int,
    n_trees: int,
    max_depth: int,
    seed: int = 0,
    max_features: Optional[str | int] = "sqrt",
    bootstrap: bool = True,
) -> RandomForest:
    """Breiman random forest: bootstrap rows + per-node feature subsets.

    Mirrors the sklearn default configuration the paper trains with
    (``max_features='sqrt'``, bootstrap resampling, Gini splits).
    """
    rng = np.random.default_rng(seed)
    n, n_features = X.shape
    if max_features == "sqrt":
        mf = max(1, int(np.sqrt(n_features)))
    elif max_features is None:
        mf = n_features
    else:
        mf = int(max_features)
    trees = []
    for _ in range(n_trees):
        if bootstrap:
            rows = rng.integers(0, n, size=n)
        else:
            rows = np.arange(n)
        trees.append(
            train_tree(X[rows], y[rows], n_classes, max_depth, rng, max_features=mf)
        )
    return RandomForest(trees=trees, n_classes=n_classes, max_depth=max_depth)
