"""CART decision-tree induction with per-node prediction vectors.

The paper's key enabling observation (Sec. III-C) is that the CART
algorithm already computes, at *every* node, the empirical class
distribution of the training samples that reach it.  Standard
implementations discard these for inner nodes; we retain them so that an
inference aborted at an inner node can still emit a calibrated
probability vector.

Trees are emitted as flat arrays (``TreeArrays``) so the anytime engine
can step through them with pure index arithmetic (no pointers, no
recursion) — the "native tree" realization of Sec. V.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class TreeArrays:
    """Flat array encoding of one decision tree.

    All arrays are indexed by node id; node 0 is the root.  Leaves carry
    ``left == right == own id`` (self loop) so that stepping past a leaf
    is a well-defined no-op — exactly the semantics the anytime step
    order relies on when a schedule advances a tree whose sample already
    sits in a leaf.
    """

    feature: np.ndarray      # int32 [M]   split feature index (leaf: 0)
    threshold: np.ndarray    # float32 [M] split value          (leaf: 0)
    left: np.ndarray         # int32 [M]   left child id  (<= goes left)
    right: np.ndarray        # int32 [M]   right child id
    is_leaf: np.ndarray      # bool  [M]
    probs: np.ndarray        # float32 [M, C] per-node class distribution
    depth: np.ndarray        # int32 [M]   depth of node (root = 0)
    max_depth: int           # maximum depth this tree was grown to

    @property
    def n_nodes(self) -> int:
        return int(self.feature.shape[0])

    @property
    def n_classes(self) -> int:
        return int(self.probs.shape[1])

    def predict_proba(self, X: np.ndarray, depth_limit: Optional[int] = None) -> np.ndarray:
        """Reference traversal (numpy).  ``depth_limit`` stops early and
        returns the inner-node prediction vector — the paper's anytime
        read-out for a single tree."""
        limit = self.max_depth if depth_limit is None else depth_limit
        idx = np.zeros(X.shape[0], dtype=np.int64)
        for _ in range(limit):
            f = self.feature[idx]
            go_left = X[np.arange(X.shape[0]), f] <= self.threshold[idx]
            nxt = np.where(go_left, self.left[idx], self.right[idx])
            idx = np.where(self.is_leaf[idx], idx, nxt)
        return self.probs[idx]


def _gini_gain_best_split(
    X: np.ndarray,
    y: np.ndarray,
    n_classes: int,
    feature_ids: np.ndarray,
    min_samples_leaf: int,
) -> Optional[tuple[int, float]]:
    """Best (feature, threshold) by Gini impurity over candidate features.

    Vectorized over thresholds per feature: sort once, evaluate every
    midpoint between distinct consecutive values.
    """
    n = y.shape[0]
    best = None
    best_score = np.inf  # weighted child impurity; lower is better
    onehot = np.zeros((n, n_classes), dtype=np.float64)
    onehot[np.arange(n), y] = 1.0
    for f in feature_ids:
        xv = X[:, f]
        order = np.argsort(xv, kind="stable")
        xs = xv[order]
        # class counts left of each split position (prefix sums)
        cum = np.cumsum(onehot[order], axis=0)  # [n, C]
        total = cum[-1]
        # candidate split after position i (1-based count i+1 on the left)
        distinct = xs[1:] != xs[:-1]
        pos = np.nonzero(distinct)[0]  # split between pos and pos+1
        if pos.size == 0:
            continue
        nl = (pos + 1).astype(np.float64)
        nr = n - nl
        valid = (nl >= min_samples_leaf) & (nr >= min_samples_leaf)
        if not np.any(valid):
            continue
        pos = pos[valid]
        nl = nl[valid]
        nr = nr[valid]
        cl = cum[pos]          # [k, C]
        cr = total[None] - cl  # [k, C]
        gini_l = 1.0 - np.sum((cl / nl[:, None]) ** 2, axis=1)
        gini_r = 1.0 - np.sum((cr / nr[:, None]) ** 2, axis=1)
        score = (nl * gini_l + nr * gini_r) / n
        k = int(np.argmin(score))
        if score[k] < best_score - 1e-12:
            best_score = float(score[k])
            thr = 0.5 * (xs[pos[k]] + xs[pos[k] + 1])
            best = (int(f), float(thr))
    return best


def train_tree(
    X: np.ndarray,
    y: np.ndarray,
    n_classes: int,
    max_depth: int,
    rng: np.random.Generator,
    max_features: Optional[int] = None,
    min_samples_leaf: int = 1,
    min_samples_split: int = 2,
) -> TreeArrays:
    """Grow one CART tree, retaining inner-node class distributions.

    ``max_features`` < n_features gives the random-forest per-node
    feature subsampling of Breiman [2].
    """
    n, n_features = X.shape
    if max_features is None:
        max_features = n_features
    y = y.astype(np.int64)

    feature, threshold, left, right, is_leaf, probs, depth = [], [], [], [], [], [], []

    def node_probs(idxs: np.ndarray) -> np.ndarray:
        counts = np.bincount(y[idxs], minlength=n_classes).astype(np.float64)
        return (counts / max(counts.sum(), 1.0)).astype(np.float32)

    def add_node(d: int) -> int:
        nid = len(feature)
        feature.append(0)
        threshold.append(0.0)
        left.append(nid)
        right.append(nid)
        is_leaf.append(True)
        probs.append(None)
        depth.append(d)
        return nid

    # Iterative growth (explicit stack) — avoids recursion limits for
    # deep trees and keeps node ids in DFS order.
    root = add_node(0)
    stack = [(root, np.arange(n), 0)]
    while stack:
        nid, idxs, d = stack.pop()
        probs[nid] = node_probs(idxs)
        pure = np.all(y[idxs] == y[idxs[0]])
        if d >= max_depth or idxs.size < min_samples_split or pure:
            continue
        feats = rng.choice(n_features, size=min(max_features, n_features), replace=False)
        split = _gini_gain_best_split(X[idxs], y[idxs], n_classes, feats, min_samples_leaf)
        if split is None:
            continue
        f, thr = split
        go_left = X[idxs, f] <= thr
        li, ri = idxs[go_left], idxs[~go_left]
        if li.size == 0 or ri.size == 0:
            continue
        feature[nid] = f
        threshold[nid] = thr
        is_leaf[nid] = False
        lid = add_node(d + 1)
        rid = add_node(d + 1)
        left[nid] = lid
        right[nid] = rid
        stack.append((lid, li, d + 1))
        stack.append((rid, ri, d + 1))

    return TreeArrays(
        feature=np.asarray(feature, dtype=np.int32),
        threshold=np.asarray(threshold, dtype=np.float32),
        left=np.asarray(left, dtype=np.int32),
        right=np.asarray(right, dtype=np.int32),
        is_leaf=np.asarray(is_leaf, dtype=bool),
        probs=np.stack(probs).astype(np.float32),
        depth=np.asarray(depth, dtype=np.int32),
        max_depth=max_depth,
    )
