"""Decision-tree / random-forest substrate.

Array-encoded ("native") trees per Asadi et al. [1] as referenced by the
paper: the tree topology lives in flat arrays so that a single anytime
*step* is an indexed load + compare + index update, which is what the
anytime engine (repro.core.engine) and the Pallas kernels operate on.
"""
from repro.forest.cart import train_tree, TreeArrays
from repro.forest.forest import RandomForest, ForestArrays, train_forest
from repro.forest.data import make_dataset, DATASETS, split_dataset

__all__ = [
    "train_tree",
    "TreeArrays",
    "RandomForest",
    "ForestArrays",
    "train_forest",
    "make_dataset",
    "split_dataset",
    "DATASETS",
]
