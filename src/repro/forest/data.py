"""Tabular dataset substrate.

The paper evaluates on 9 UCI datasets.  This container is offline, so we
provide *statistical stand-ins*: synthetic classification problems whose
class count, feature count and rough difficulty match each UCI dataset.
The generator is a self-contained reimplementation of the
``make_classification`` recipe (Gaussian class clusters on informative
subspaces + redundant linear mixtures + noise features + label noise) so
no sklearn dependency is needed.

EXPERIMENTS.md documents this substitution: the paper's *claims* being
validated (accuracy monotonicity in steps, order rankings, optimal vs
squirrel gaps) are order-relative properties that transfer to any
tabular task family; absolute accuracies will differ from the paper's.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_classes: int
    n_features: int
    n_samples: int
    n_informative: int
    class_sep: float
    label_noise: float
    binary: bool


# Stand-ins matched to the paper's 9 UCI datasets (class counts are the
# real ones; sample counts are scaled down to keep CI fast).
DATASETS: dict[str, DatasetSpec] = {
    "adult": DatasetSpec("adult", 2, 14, 4000, 8, 1.0, 0.15, True),
    "covertype": DatasetSpec("covertype", 7, 54, 4000, 20, 1.2, 0.05, False),
    "letter": DatasetSpec("letter", 26, 16, 6000, 12, 1.8, 0.02, False),
    "magic": DatasetSpec("magic", 2, 10, 4000, 6, 0.9, 0.12, True),
    "mnist": DatasetSpec("mnist", 10, 64, 5000, 32, 1.5, 0.03, False),
    "satlog": DatasetSpec("satlog", 6, 36, 3000, 18, 1.3, 0.05, False),
    "sensorless-drive": DatasetSpec("sensorless-drive", 11, 48, 5000, 24, 1.5, 0.02, False),
    "spambase": DatasetSpec("spambase", 2, 57, 3000, 20, 1.1, 0.08, True),
    "wearable-body-postures": DatasetSpec("wearable-body-postures", 5, 17, 4000, 10, 1.2, 0.05, False),
}


def make_dataset(spec: DatasetSpec | str, seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Synthesize (X, y) for a dataset spec.

    Each class is a mixture of 2 Gaussian clusters placed on the
    informative subspace; redundant features are random linear mixtures
    of informative ones; remaining features are pure noise.  A fraction
    ``label_noise`` of labels is resampled uniformly.
    """
    if isinstance(spec, str):
        spec = DATASETS[spec]
    rng = np.random.default_rng(seed)
    n, f, c = spec.n_samples, spec.n_features, spec.n_classes
    ninf = min(spec.n_informative, f)
    clusters_per_class = 2
    total_clusters = c * clusters_per_class
    # cluster centers: scaled hypercube corners + jitter
    centers = rng.normal(0.0, 1.0, size=(total_clusters, ninf))
    centers *= spec.class_sep * 2.0 / np.maximum(np.linalg.norm(centers, axis=1, keepdims=True), 1e-9) * np.sqrt(ninf)
    y = rng.integers(0, c, size=n)
    which_cluster = rng.integers(0, clusters_per_class, size=n)
    cluster_id = y * clusters_per_class + which_cluster
    X_inf = centers[cluster_id] + rng.normal(0.0, 1.0, size=(n, ninf))
    # redundant features = linear mixtures of informative
    nred = min(max(0, f - ninf), ninf)
    if nred > 0:
        B = rng.normal(0.0, 1.0, size=(ninf, nred))
        X_red = X_inf @ B / np.sqrt(ninf)
    else:
        X_red = np.zeros((n, 0))
    nnoise = f - ninf - nred
    X_noise = rng.normal(0.0, 1.0, size=(n, nnoise))
    X = np.concatenate([X_inf, X_red, X_noise], axis=1)
    # shuffle feature columns so informativeness is not positional
    perm = rng.permutation(f)
    X = X[:, perm]
    # label noise
    flip = rng.random(n) < spec.label_noise
    y = np.where(flip, rng.integers(0, c, size=n), y)
    return X.astype(np.float32), y.astype(np.int64)


def split_dataset(
    X: np.ndarray, y: np.ndarray, seed: int = 0,
    fractions: tuple[float, float, float] = (0.5, 0.25, 0.25),
) -> tuple[tuple[np.ndarray, np.ndarray], tuple[np.ndarray, np.ndarray], tuple[np.ndarray, np.ndarray]]:
    """The paper's three-way split: train (50%) / ordering (25%) / test (25%).

    The ordering set S_o is the third split used *only* to generate step
    orders (Sec. III-A) — analogous to a validation set.
    """
    assert abs(sum(fractions) - 1.0) < 1e-9
    rng = np.random.default_rng(seed)
    n = X.shape[0]
    perm = rng.permutation(n)
    n_tr = int(n * fractions[0])
    n_or = int(n * fractions[1])
    tr = perm[:n_tr]
    orx = perm[n_tr:n_tr + n_or]
    te = perm[n_tr + n_or:]
    return (X[tr], y[tr]), (X[orx], y[orx]), (X[te], y[te])
