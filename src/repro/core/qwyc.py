"""QWYC ("Quit When You Can", Wang et al. [21]) tree ordering.

QWYC greedily orders an ensemble so that, with per-prefix early-stopping
thresholds, as many samples as possible can be *decided* after as few
trees as possible.  Binary classification only (the paper notes the same
restriction); for non-binary datasets callers fall back to pruning
sequences.

We implement the ordering component: maintain the set of samples still
undecided; at each position greedily append the unused tree that
maximizes the number of samples whose partial margin can no longer flip
sign given worst-case contributions of the remaining trees.
"""
from __future__ import annotations

import numpy as np


def qwyc_seq(path_probs: np.ndarray, y: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """Returns (tree sequence [T], decision thresholds tau [T]).

    Margin of sample b after prefix P: m_b = sum_{t in P} (p_t(b, 1) - 0.5).
    A sample is decided after k trees if |m_b| > tau_k where tau_k bounds
    the maximal total swing of the remaining trees (computed per-prefix
    from the ordering set, as in QWYC's validation-calibrated variant).
    """
    probs = path_probs[:, :, -1, :]           # [B, T, C]
    B, T, C = probs.shape
    if C != 2:
        raise ValueError("QWYC is defined for binary classification only")
    margin_t = probs[:, :, 1] - 0.5           # [B, T] per-tree signed contribution
    max_swing = np.abs(margin_t).max(axis=0)  # [T] worst-case |contribution| per tree

    remaining = list(range(T))
    seq: list[int] = []
    taus: list[float] = []
    cum_margin = np.zeros(B, dtype=np.float64)
    for _ in range(T):
        best_t, best_decided = remaining[0], -1
        for t in remaining:
            cand = cum_margin + margin_t[:, t]
            rem_after = [u for u in remaining if u != t]
            tau = float(max_swing[rem_after].sum()) if rem_after else 0.0
            decided = int(np.sum(np.abs(cand) > tau))
            if decided > best_decided:
                best_decided, best_t = decided, t
        cum_margin += margin_t[:, best_t]
        remaining.remove(best_t)
        seq.append(best_t)
        taus.append(float(max_swing[remaining].sum()) if remaining else 0.0)
    return np.asarray(seq, dtype=np.int32), np.asarray(taus, dtype=np.float32)
