"""Core contribution: anytime random-forest inference with optimized
step orders ("Jump Like A Squirrel", Biebert et al.).

Public API:
  AnytimeForest / AnytimeSession  — inference with any step order
  generate_order / ORDER_NAMES    — every order the paper evaluates
  StateEvaluator                  — state-accuracy machinery
  engine                          — jnp reference execution engine
"""
from repro.core.anytime import (
    AnytimeForest,
    AnytimeSession,
    AnytimeProgram,
    ORDER_NAMES,
    generate_order,
)
from repro.core.orders import StateEvaluator, validate_order
from repro.core import engine, metrics, orders, pruning, qwyc

__all__ = [
    "AnytimeForest",
    "AnytimeSession",
    "AnytimeProgram",
    "ORDER_NAMES",
    "generate_order",
    "StateEvaluator",
    "validate_order",
    "engine",
    "metrics",
    "orders",
    "pruning",
    "qwyc",
]
