"""Core contribution: anytime random-forest inference with optimized
step orders ("Jump Like A Squirrel", Biebert et al.).

The PUBLIC scheduling API is :mod:`repro.schedule`; this package holds
the forest-facing machinery underneath it.  Migration table (the
``generate_order`` / ``ORDER_NAMES`` string shims are DELETED after
their one-release grace period):

    old call (repro.core)                     new call (repro.schedule)
    ----------------------------------------  ------------------------------------------
    generate_order(name, pp, y, seed=s)       get_order_policy(name, seed=s).generate(pp, y)
    ORDER_NAMES                               list_orders()
    AnytimeForest.build(f, name, X, y)        AnytimeRuntime(ForestProgram(f, y_order=y,
                                                  X_order=X)).session(X_test, name)
    AnytimeSession(af, X) / af.session(X)     AnytimeRuntime(...).session(X)  (adds
                                                  advance_until(deadline_ms), RLE fusion)
    [serial loop over run_order per order]    AnytimeRuntime(...).evaluate_orders(X, y)
                                                  (single vmapped batched pass)

Still exported from here:
  AnytimeForest / AnytimeSession  — forest + order convenience wrapper
  AnytimeProgram                  — the schedulable-computation protocol
  StateEvaluator                  — state-accuracy machinery
  engine                          — jnp reference execution engine
"""
# Submodules first: repro.schedule.runtime imports repro.core.engine
# mid-cycle, so engine must be bound before anytime (which pulls in the
# schedule package) executes.
from repro.core import engine, metrics, orders, pruning, qwyc
from repro.core.anytime import AnytimeForest, AnytimeProgram
from repro.core.orders import StateEvaluator, validate_order
from repro.schedule.policies import OrderPolicy, get_order_policy, list_orders

# Runtime-side names resolve lazily: when this package is imported from
# inside repro.schedule.runtime's own import, the runtime module is not
# finished yet.
_LAZY_RUNTIME = ("AnytimeRuntime", "ForestProgram", "Session", "AnytimeSession")


def __getattr__(name: str):
    if name in _LAZY_RUNTIME:
        from repro.schedule import runtime

        val = runtime.Session if name == "AnytimeSession" else getattr(runtime, name)
        globals()[name] = val
        return val
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")

__all__ = [
    "AnytimeForest",
    "AnytimeSession",
    "AnytimeProgram",
    "AnytimeRuntime",
    "ForestProgram",
    "OrderPolicy",
    "get_order_policy",
    "list_orders",
    "StateEvaluator",
    "validate_order",
    "engine",
    "metrics",
    "orders",
    "pruning",
    "qwyc",
]
