"""Anytime random-forest inference engine (JAX).

Implements Sec. V of the paper: the forest state is an *index array*
(current node id per tree per sample); inference is a tight loop over a
precomputed *step order* (array of tree ids), advancing one tree per
step; a prediction is available after ANY prefix of steps by summing the
per-node probability vectors addressed by the index array.

Two execution paths:
  * ``tree_step`` / ``run_order``     — pure jnp (reference, CPU-friendly)
  * ``repro.kernels.ops``             — Pallas TPU kernels for the two hot
    spots (batched step, probability accumulation); validated against
    this module in tests.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.forest.forest import ForestArrays


class DeviceForest(NamedTuple):
    """jnp mirror of :class:`ForestArrays` (see that class for layout)."""

    feature: jax.Array    # int32   [T, M]
    threshold: jax.Array  # float32 [T, M]
    left: jax.Array       # int32   [T, M]
    right: jax.Array      # int32   [T, M]
    is_leaf: jax.Array    # bool    [T, M]
    probs: jax.Array      # float32 [T, M, C]

    @property
    def n_trees(self) -> int:
        return self.feature.shape[0]

    @property
    def n_nodes(self) -> int:
        return self.feature.shape[1]

    @property
    def n_classes(self) -> int:
        return self.probs.shape[2]


def to_device(forest: ForestArrays) -> DeviceForest:
    return DeviceForest(
        feature=jnp.asarray(forest.feature),
        threshold=jnp.asarray(forest.threshold),
        left=jnp.asarray(forest.left),
        right=jnp.asarray(forest.right),
        is_leaf=jnp.asarray(forest.is_leaf),
        probs=jnp.asarray(forest.probs),
    )


def init_state(forest: DeviceForest, batch: int) -> jax.Array:
    """Index array: every tree starts at its root (node 0)."""
    return jnp.zeros((batch, forest.n_trees), dtype=jnp.int32)


def tree_step(forest: DeviceForest, X: jax.Array, idx: jax.Array, tree_id: jax.Array) -> jax.Array:
    """Advance ``tree_id`` by one step for every sample.

    idx: int32 [B, T] index array; X: [B, F]. Stepping a tree whose
    sample already sits in a leaf is a no-op (leaf self-loop).
    """
    node = idx[:, tree_id]                                  # [B]
    f = forest.feature[tree_id, node]                       # [B]
    thr = forest.threshold[tree_id, node]                   # [B]
    fv = jnp.take_along_axis(X, f[:, None].astype(jnp.int32), axis=1)[:, 0]
    go_left = fv <= thr
    nxt = jnp.where(go_left, forest.left[tree_id, node], forest.right[tree_id, node])
    nxt = jnp.where(forest.is_leaf[tree_id, node], node, nxt)
    return idx.at[:, tree_id].set(nxt)


def tree_run(
    forest: DeviceForest, X: jax.Array, idx: jax.Array, tree_id: jax.Array, n: int
) -> jax.Array:
    """n fused steps of ``tree_id`` as one ``lax.scan`` (n static under jit).

    This is the RLE-fusion primitive: a run of n consecutive same-tree
    steps in an order costs one dispatch instead of n.  ``tree_id`` stays
    a traced scalar, so runs of different trees share the compilation.
    """

    def body(i, _):
        return tree_step(forest, X, i, tree_id), None

    return jax.lax.scan(body, idx, None, length=n)[0]


def slot_step(
    forest: DeviceForest,
    X: jax.Array,
    idx: jax.Array,
    units: jax.Array,
    mask: jax.Array,
) -> jax.Array:
    """Advance, for every batch row b, tree ``units[b]`` by one step.

    The slot-batched generalization of :func:`tree_step` used by the
    serving scheduler: each row is a *slot* holding an independent
    request, so one dispatch advances many concurrent requests that sit
    at different positions of the same step order.  Rows where ``mask``
    is False (empty or retired slots) keep their state.  The per-row
    arithmetic is exactly :func:`tree_step`'s, so slot execution stays
    bit-exact with a solo session advanced the same number of steps.
    """
    b = jnp.arange(idx.shape[0])
    node = idx[b, units]                                    # [B]
    f = forest.feature[units, node]                         # [B]
    thr = forest.threshold[units, node]                     # [B]
    fv = X[b, f.astype(jnp.int32)]                          # [B]
    go_left = fv <= thr
    nxt = jnp.where(go_left, forest.left[units, node], forest.right[units, node])
    nxt = jnp.where(forest.is_leaf[units, node], node, nxt)
    nxt = jnp.where(mask, nxt, node)
    return idx.at[b, units].set(nxt)


def slot_run(
    forest: DeviceForest,
    X: jax.Array,
    idx: jax.Array,
    units: jax.Array,
    mask: jax.Array,
    n: int,
) -> jax.Array:
    """n fused masked slot-steps as one ``lax.scan`` (n static under jit).

    The serving analogue of :func:`tree_run`: a plan segment of n
    consecutive steps costs one dispatch for the whole slot batch, with
    every slot stepping its own tree (``units``) or idling (``mask``).
    """

    def body(i, _):
        return slot_step(forest, X, i, units, mask), None

    return jax.lax.scan(body, idx, None, length=n)[0]


def segment_run(
    forest: DeviceForest,
    X: jax.Array,
    idx: jax.Array,
    units: jax.Array,
    mask: Optional[jax.Array],
    n: int,
) -> jax.Array:
    """The unified plan-segment primitive behind ``ExecutorCore``.

    ``units`` scalar (0-d) -> lockstep batch: every sample advances the
    SAME tree for n steps (:func:`tree_run`, the solo-session shape).
    ``units`` vector [B]  -> masked slots: row b advances its OWN tree
    ``units[b]`` unless ``mask[b]`` is False (:func:`slot_run`, the
    serving shape).  The rank check is static under jit, so both shapes
    share one entry point without a runtime branch.
    """
    if jnp.ndim(units) == 0:
        return tree_run(forest, X, idx, units, n)
    if mask is None:
        mask = jnp.ones(idx.shape[0], dtype=bool)
    return slot_run(forest, X, idx, units, mask, n)


def predict_from_state(forest: DeviceForest, idx: jax.Array) -> jax.Array:
    """Anytime read-out: sum per-node probability vectors over trees.

    idx: [B, T] -> probs [B, C] (unnormalized sum, argmax-equivalent)."""
    # gather probs[t, idx[b, t]] for all b, t
    t_ids = jnp.arange(forest.n_trees)[None, :]            # [1, T]
    vecs = forest.probs[t_ids, idx]                         # [B, T, C]
    return vecs.sum(axis=1)


def run_order(
    forest: DeviceForest,
    X: jax.Array,
    order: jax.Array,
    y: Optional[jax.Array] = None,
) -> tuple[jax.Array, Optional[jax.Array]]:
    """Execute a full step order, returning the final index array and —
    if labels are given — the per-step accuracy curve (length steps+1,
    position 0 = prediction from the all-roots state).

    This is the *evaluation* entry point; production serving uses
    :func:`repro.core.anytime.AnytimeForestSession` which can stop after
    any prefix.
    """
    idx0 = init_state(forest, X.shape[0])

    def acc(idx):
        pred = jnp.argmax(predict_from_state(forest, idx), axis=1)
        return jnp.mean((pred == y).astype(jnp.float32))

    def body(idx, tree_id):
        idx = tree_step(forest, X, idx, tree_id)
        out = acc(idx) if y is not None else jnp.zeros(())
        return idx, out

    idx_final, accs = jax.lax.scan(body, idx0, order)
    if y is None:
        return idx_final, None
    curve = jnp.concatenate([acc(idx0)[None], accs])
    return idx_final, curve


# ---------------------------------------------------------------------------
# Path precomputation — the order generators (optimal / squirrel) never
# re-traverse the forest: for the ordering set S_o they precompute, per
# sample and tree, the node visited at every depth, then evaluate any
# state (steps-per-tree vector) with pure gathers.
# ---------------------------------------------------------------------------

def compute_paths(forest: DeviceForest, X: jax.Array, max_depth: int) -> jax.Array:
    """[B, T, d+1] node id on each sample's path per tree, clamped at leaves."""
    B = X.shape[0]
    T = forest.n_trees
    idx = jnp.zeros((B, T), dtype=jnp.int32)

    # advance ALL trees one level (vectorized over T)
    def step_all(idx):
        t_ids = jnp.arange(T)[None, :]
        f = forest.feature[t_ids, idx]                     # [B, T]
        thr = forest.threshold[t_ids, idx]
        fv = jnp.take_along_axis(X, f.astype(jnp.int32), axis=1)  # [B, T]
        go_left = fv <= thr
        nxt = jnp.where(go_left, forest.left[t_ids, idx], forest.right[t_ids, idx])
        return jnp.where(forest.is_leaf[t_ids, idx], idx, nxt)

    def scan_body(idx, _):
        nxt = step_all(idx)
        return nxt, nxt

    _, trail = jax.lax.scan(scan_body, idx, None, length=max_depth)
    # trail: [d, B, T]
    paths = jnp.concatenate([idx[None], trail], axis=0)    # [d+1, B, T]
    return jnp.transpose(paths, (1, 2, 0))                  # [B, T, d+1]


def compute_path_probs(forest: DeviceForest, paths: jax.Array) -> jax.Array:
    """[B, T, d+1, C] probability vector along each path."""
    t_ids = jnp.arange(forest.n_trees)[None, :, None]
    return forest.probs[t_ids, paths]


def path_probs_np(forest: ForestArrays, X: np.ndarray) -> np.ndarray:
    """Numpy convenience used by the (offline) order generators."""
    dev = to_device(forest)
    paths = compute_paths(dev, jnp.asarray(X), forest.max_depth)
    return np.asarray(compute_path_probs(dev, paths))


def state_accuracy_np(path_probs: np.ndarray, y: np.ndarray, state: np.ndarray) -> float:
    """Accuracy of one forest state (steps-per-tree vector) on S_o.

    path_probs: [B, T, d+1, C]; state: int [T]."""
    B, T, _, _ = path_probs.shape
    vecs = path_probs[np.arange(B)[:, None], np.arange(T)[None, :], state[None, :]]  # [B, T, C]
    pred = vecs.sum(axis=1).argmax(axis=1)
    return float(np.mean(pred == y))
