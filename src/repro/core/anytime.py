"""High-level anytime-inference API (forest-facing convenience layer).

The public scheduling surface now lives in :mod:`repro.schedule`
(policy registry + :class:`~repro.schedule.runtime.AnytimeRuntime`);
this module keeps:

* :class:`AnytimeProgram` — the generic protocol every schedulable
  computation implements (forests here, transformer ensembles in
  ``repro.serving.anytime_depth``);

* :class:`AnytimeForest` — a trained forest + a generated step order;
  one-call evaluation (accuracy curve, NMA) and an interruptible
  session, now served through the RLE-fused ``repro.schedule`` runtime.

The ``generate_order`` / ``ORDER_NAMES`` string shims that briefly lived
here are GONE (their one-release grace period is over): enumerate orders
with :func:`repro.schedule.list_orders` and generate them with
``get_order_policy(name, ...).generate(path_probs, y)``.
"""
from __future__ import annotations

import dataclasses
from typing import Protocol

import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core.metrics import mean_accuracy, normalized_mean_accuracy
from repro.forest.forest import ForestArrays
# Only the policies half of repro.schedule is importable here at module
# level: repro.schedule.runtime imports repro.core back, so its pieces
# (Session, ForestStepBackend, check_order) are imported lazily inside
# the methods that need them.
from repro.schedule.policies import get_order_policy


class AnytimeProgram(Protocol):
    """A computation decomposable into schedulable units.

    n_units: number of independent unit chains (trees / ensemble members)
    unit_steps: steps per chain (tree depth / layers per member)
    quality_table: [B, n_units, unit_steps+1, C] per-state contribution
        vectors on a calibration set — exactly the shape
        engine.compute_path_probs produces for forests, and what the
        early-exit logit-lens readouts produce for transformers.
    make_session: an executor over (order, inputs) with ``advance`` /
        ``predict`` — what :class:`repro.schedule.AnytimeRuntime` wraps
        into deadline-aware :class:`~repro.schedule.runtime.Session`s.
    """

    @property
    def n_units(self) -> int: ...

    @property
    def unit_steps(self) -> int: ...

    def quality_table(self) -> tuple[np.ndarray, np.ndarray]:
        """Returns (contribution vectors [B, U, S+1, C], labels [B])."""
        ...

    def make_session(self, order: np.ndarray, inputs): ...


@dataclasses.dataclass
class AnytimeForest:
    """A forest + step order, ready for anytime inference."""

    forest: ForestArrays
    order: np.ndarray
    device: engine.DeviceForest = dataclasses.field(init=False)

    def __post_init__(self):
        from repro.schedule.runtime import check_order

        check_order(self.order, self.forest.n_trees, self.forest.max_depth)
        self.device = engine.to_device(self.forest)

    @classmethod
    def build(
        cls,
        forest: ForestArrays,
        order_name: str,
        X_order: np.ndarray,
        y_order: np.ndarray,
        seed: int = 0,
    ) -> "AnytimeForest":
        pp = engine.path_probs_np(forest, X_order)
        policy = get_order_policy(order_name, seed=seed)
        return cls(forest=forest, order=policy.generate(pp, y_order))

    def accuracy_curve(self, X: np.ndarray, y: np.ndarray) -> np.ndarray:
        """Accuracy after every prefix of the step order on (X, y)."""
        _, curve = engine.run_order(
            self.device, jnp.asarray(X), jnp.asarray(self.order), jnp.asarray(y)
        )
        return np.asarray(curve)

    def evaluate(self, X: np.ndarray, y: np.ndarray) -> dict[str, float]:
        curve = self.accuracy_curve(X, y)
        return {
            "mean_accuracy": mean_accuracy(curve),
            "nma": normalized_mean_accuracy(curve),
            "final_accuracy": float(curve[-1]),
            "initial_accuracy": float(curve[0]),
        }

    def session(self, X: np.ndarray) -> "Session":
        """Interruptible, RLE-fused, deadline-aware inference session."""
        from repro.schedule.runtime import ForestStepBackend, Session

        return Session(ForestStepBackend(self.device, X, self.order))


def __getattr__(name: str):
    # Back-compat alias: sessions are now the runtime-level
    # repro.schedule.runtime.Session (adds advance_until + RLE fusion).
    # Resolved lazily to keep this module importable mid-cycle.
    if name == "AnytimeSession":
        from repro.schedule.runtime import Session

        return Session
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
