"""High-level anytime-inference API.

Two layers:

* :class:`AnytimeForest` — owns a trained forest + a generated step
  order; one-call evaluation (accuracy curve, NMA) and an interruptible
  session for production serving.

* :class:`AnytimeProgram` — the generic abstraction the framework uses
  to apply the paper's scheduling idea beyond forests (e.g. early-exit
  transformer depth scheduling in ``repro.serving.anytime_depth``): any
  computation decomposable into discrete *units* with per-state quality
  estimates can be ordered by the same Optimal/Squirrel machinery.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine, orders, pruning, qwyc
from repro.core.metrics import mean_accuracy, normalized_mean_accuracy
from repro.forest.forest import ForestArrays


class AnytimeProgram(Protocol):
    """A computation decomposable into schedulable units.

    n_units: number of independent unit chains (trees / ensemble members)
    unit_steps: steps per chain (tree depth / layers per member)
    quality_table: [B, n_units, unit_steps+1, C] per-state contribution
        vectors on a calibration set — exactly the shape
        engine.compute_path_probs produces for forests, and what the
        early-exit logit-lens readouts produce for transformers.
    """

    @property
    def n_units(self) -> int: ...

    @property
    def unit_steps(self) -> int: ...

    def quality_table(self) -> tuple[np.ndarray, np.ndarray]:
        """Returns (contribution vectors [B, U, S+1, C], labels [B])."""
        ...


ORDER_NAMES = (
    "optimal", "unoptimal", "forward_squirrel", "backward_squirrel",
    "random",
    "depth", "breadth",
    "prune_depth_IE", "prune_breadth_IE",
    "prune_depth_EA", "prune_breadth_EA",
    "prune_depth_RE", "prune_breadth_RE",
    "prune_depth_D", "prune_breadth_D",
    "qwyc_depth", "qwyc_breadth",
)


def generate_order(
    name: str,
    path_probs: np.ndarray,
    y: np.ndarray,
    seed: int = 0,
    state_limit: int = 2_000_000,
) -> np.ndarray:
    """Dispatch every step-order generator the paper evaluates by name.

    path_probs/y are computed on the ordering set S_o.
    """
    B, T, d1, C = path_probs.shape
    d = d1 - 1
    ev = orders.StateEvaluator(path_probs, y)
    if name == "optimal":
        return orders.optimal_order(ev, state_limit=state_limit)
    if name == "unoptimal":
        return orders.unoptimal_order(ev, state_limit=state_limit)
    if name == "forward_squirrel":
        return orders.forward_squirrel(ev)
    if name == "backward_squirrel":
        return orders.backward_squirrel(ev)
    if name == "random":
        return orders.random_order(T, d, seed=seed)
    if name == "depth":
        return orders.depth_order(T, d)
    if name == "breadth":
        return orders.breadth_order(T, d)
    if name.startswith("prune_"):
        _, variant, metric = name.split("_")
        seq = pruning.PRUNE_SEQUENCES[metric](path_probs, y)
        fn = orders.depth_order if variant == "depth" else orders.breadth_order
        return fn(T, d, seq)
    if name.startswith("qwyc_"):
        variant = name.split("_")[1]
        seq, _ = qwyc.qwyc_seq(path_probs, y)
        fn = orders.depth_order if variant == "depth" else orders.breadth_order
        return fn(T, d, seq)
    raise ValueError(f"unknown order: {name!r}")


@dataclasses.dataclass
class AnytimeForest:
    """A forest + step order, ready for anytime inference."""

    forest: ForestArrays
    order: np.ndarray
    device: engine.DeviceForest = dataclasses.field(init=False)

    def __post_init__(self):
        assert orders.validate_order(self.order, self.forest.n_trees, self.forest.max_depth)
        self.device = engine.to_device(self.forest)

    @classmethod
    def build(
        cls,
        forest: ForestArrays,
        order_name: str,
        X_order: np.ndarray,
        y_order: np.ndarray,
        seed: int = 0,
    ) -> "AnytimeForest":
        pp = engine.path_probs_np(forest, X_order)
        return cls(forest=forest, order=generate_order(order_name, pp, y_order, seed=seed))

    def accuracy_curve(self, X: np.ndarray, y: np.ndarray) -> np.ndarray:
        """Accuracy after every prefix of the step order on (X, y)."""
        _, curve = engine.run_order(
            self.device, jnp.asarray(X), jnp.asarray(self.order), jnp.asarray(y)
        )
        return np.asarray(curve)

    def evaluate(self, X: np.ndarray, y: np.ndarray) -> dict[str, float]:
        curve = self.accuracy_curve(X, y)
        return {
            "mean_accuracy": mean_accuracy(curve),
            "nma": normalized_mean_accuracy(curve),
            "final_accuracy": float(curve[-1]),
            "initial_accuracy": float(curve[0]),
        }

    def session(self, X: np.ndarray) -> "AnytimeSession":
        return AnytimeSession(self, jnp.asarray(X))


class AnytimeSession:
    """Interruptible inference: advance in chunks, read a prediction at
    any point — the deployment-facing realization of Sec. V."""

    def __init__(self, af: AnytimeForest, X: jax.Array):
        self.af = af
        self.X = X
        self.idx = engine.init_state(af.device, X.shape[0])
        self.pos = 0
        self._order_dev = jnp.asarray(af.order)

        def _advance(idx, start, k):
            chunk = jax.lax.dynamic_slice_in_dim(self._order_dev, start, k)

            def body(i, tree_id):
                return engine.tree_step(af.device, self.X, i, tree_id), None

            idx, _ = jax.lax.scan(body, idx, chunk)
            return idx

        # jit with static chunk length: one compile per distinct k, then
        # every deadline-loop step is a cached dispatch (the serving loop
        # calls this thousands of times).
        self._advance = jax.jit(_advance, static_argnums=(2,))

    @property
    def total_steps(self) -> int:
        return int(self.af.order.shape[0])

    @property
    def remaining(self) -> int:
        return self.total_steps - self.pos

    def advance(self, k: int) -> int:
        """Execute up to k more steps; returns steps actually taken."""
        k = min(k, self.remaining)
        if k > 0:
            self.idx = self._advance(self.idx, self.pos, k)
            self.pos += k
        return k

    def predict_proba(self) -> np.ndarray:
        return np.asarray(engine.predict_from_state(self.af.device, self.idx))

    def predict(self) -> np.ndarray:
        return self.predict_proba().argmax(axis=1)
