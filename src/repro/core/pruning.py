"""Tree sequences derived from ensemble-pruning literature (Sec. IV-A).

The paper repurposes pruning *rankings* as execution sequences: all trees
are kept, only the order changes.  Implemented metrics:

  individual_error (IE)  — rank by per-tree error on S_o            [15]
  error_ambiguity  (EA)  — rank by error-ambiguity decomposition     [15]
  reduced_error    (RE)  — greedy: add tree minimizing subset error  [19]
  drep             (D)   — greedy diversity-regularized selection    [16]

Each returns a permutation of tree ids; combine with
orders.depth_order / orders.breadth_order to obtain the paper's
"Prune Depth Order" / "Prune Breadth Order" variants.
"""
from __future__ import annotations

import numpy as np


def _tree_probs(path_probs: np.ndarray) -> np.ndarray:
    """Final-depth (leaf) prediction vector per tree: [B, T, C]."""
    return path_probs[:, :, -1, :]


def _tree_preds(path_probs: np.ndarray) -> np.ndarray:
    return _tree_probs(path_probs).argmax(axis=2)  # [B, T]


def individual_error_seq(path_probs: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Rank trees by their own error on S_o (best first)."""
    preds = _tree_preds(path_probs)
    err = (preds != y[:, None]).mean(axis=0)  # [T]
    return np.argsort(err, kind="stable").astype(np.int32)


def error_ambiguity_seq(path_probs: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Error-ambiguity decomposition ranking (Jiang et al. [15]).

    score_t = err_t - amb_t where amb_t measures disagreement with the
    full-ensemble prediction; low score (accurate AND diverse) first.
    """
    preds = _tree_preds(path_probs)                          # [B, T]
    ens = _tree_probs(path_probs).sum(axis=1).argmax(axis=1)  # [B]
    err = (preds != y[:, None]).mean(axis=0)
    amb = (preds != ens[:, None]).mean(axis=0)
    return np.argsort(err - amb, kind="stable").astype(np.int32)


def reduced_error_seq(path_probs: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Greedy forward selection minimizing running-ensemble error
    (Margineantu & Dietterich [19]); selection order = sequence."""
    probs = _tree_probs(path_probs)                  # [B, T, C]
    B, T, C = probs.shape
    remaining = list(range(T))
    seq: list[int] = []
    acc_probs = np.zeros((B, C), dtype=np.float64)
    while remaining:
        best_t, best_err = -1, np.inf
        for t in remaining:
            cand = acc_probs + probs[:, t]
            err = float(np.mean(cand.argmax(axis=1) != y))
            if err < best_err - 1e-12:
                best_err, best_t = err, t
        seq.append(best_t)
        acc_probs += probs[:, best_t]
        remaining.remove(best_t)
    return np.asarray(seq, dtype=np.int32)


def drep_seq(path_probs: np.ndarray, y: np.ndarray, rho: float = 0.4) -> np.ndarray:
    """DREP (Li et al. [16]): greedily pick, among the rho-fraction of
    remaining trees most *diverse* w.r.t. the current ensemble, the one
    minimizing ensemble error.  First tree = lowest individual error."""
    probs = _tree_probs(path_probs)
    preds = probs.argmax(axis=2)                     # [B, T]
    B, T, C = probs.shape
    err_ind = (preds != y[:, None]).mean(axis=0)
    first = int(np.argmin(err_ind))
    seq = [first]
    remaining = [t for t in range(T) if t != first]
    acc_probs = probs[:, first].astype(np.float64).copy()
    while remaining:
        ens_pred = acc_probs.argmax(axis=1)
        # diversity = disagreement with current ensemble prediction
        div = np.array([(preds[:, t] != ens_pred).mean() for t in remaining])
        k = max(1, int(np.ceil(rho * len(remaining))))
        cand_ids = [remaining[i] for i in np.argsort(-div, kind="stable")[:k]]
        best_t, best_err = cand_ids[0], np.inf
        for t in cand_ids:
            cand = acc_probs + probs[:, t]
            err = float(np.mean(cand.argmax(axis=1) != y))
            if err < best_err - 1e-12:
                best_err, best_t = err, t
        seq.append(best_t)
        acc_probs += probs[:, best_t]
        remaining.remove(best_t)
    return np.asarray(seq, dtype=np.int32)


PRUNE_SEQUENCES = {
    "IE": individual_error_seq,
    "EA": error_ambiguity_seq,
    "RE": reduced_error_seq,
    "D": drep_seq,
}
