"""Step-order generators (Sec. IV of the paper).

A *step order* is an int array of length T*d over tree ids; executing it
advances the named tree one level per step.  Every generator here is an
OFFLINE procedure (run once before inference, on the ordering set S_o)
and returns a plain numpy array.

Naming follows the paper:
  depth_order / breadth_order       — intuitive orders (Sec. IV-A)
  optimal_order                     — Dijkstra over the state graph (IV-B)
  forward_squirrel / backward_squirrel — greedy heuristics (IV-C)
  unoptimal_order, random_order     — naive baselines (Sec. VI)
Tree *sequences* for depth/breadth come from repro.core.pruning /
repro.core.qwyc.
"""
from __future__ import annotations

import heapq
from typing import Optional, Sequence

import numpy as np


def validate_order(order: np.ndarray, n_trees: int, depth: int) -> bool:
    """An order is valid iff each tree takes exactly ``depth`` steps."""
    counts = np.bincount(order, minlength=n_trees)
    return order.shape[0] == n_trees * depth and bool(np.all(counts == depth))


def depth_order(n_trees: int, depth: int, tree_seq: Optional[Sequence[int]] = None) -> np.ndarray:
    """Finish each tree before starting the next (the standard execution)."""
    seq = np.arange(n_trees) if tree_seq is None else np.asarray(tree_seq)
    return np.repeat(seq, depth).astype(np.int32)


def breadth_order(n_trees: int, depth: int, tree_seq: Optional[Sequence[int]] = None) -> np.ndarray:
    """Advance every tree one level before going deeper anywhere."""
    seq = np.arange(n_trees) if tree_seq is None else np.asarray(tree_seq)
    return np.tile(seq, depth).astype(np.int32)


def random_order(n_trees: int, depth: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    order = np.repeat(np.arange(n_trees), depth)
    rng.shuffle(order)
    return order.astype(np.int32)


# ---------------------------------------------------------------------------
# State-graph machinery shared by Optimal / Unoptimal / Squirrel.
#
# A state is the vector s in {0..d}^T of steps taken per tree.  Its
# accuracy on S_o is computable from precomputed per-depth path
# probability vectors (engine.compute_path_probs): gather + sum + argmax.
# ---------------------------------------------------------------------------

class StateEvaluator:
    """Incremental state-accuracy evaluation on S_o.

    Holds path_probs [B, T, d+1, C] and exposes:
      * accuracy(state)            — exact accuracy of a state
      * candidate_accuracies(S, s, direction) — vectorized accuracy of all
        T neighbor states reached by one step forward/backward, given the
        running class-score matrix S = sum_t pp[:, t, s_t].
    The incremental form is what gives the Squirrel orders their
    O(d * T^2) state-evaluation count (footnote 1 of the paper).
    """

    def __init__(self, path_probs: np.ndarray, y: np.ndarray):
        self.pp = np.ascontiguousarray(path_probs, dtype=np.float32)  # [B, T, d+1, C]
        self.y = np.asarray(y)
        self.B, self.T, d1, self.C = self.pp.shape
        self.depth = d1 - 1
        self._cache: dict[tuple, float] = {}

    def score_matrix(self, state: np.ndarray) -> np.ndarray:
        """S[b, c] = sum_t pp[b, t, s_t, c]."""
        vec = self.pp[np.arange(self.B)[:, None], np.arange(self.T)[None, :], state[None, :]]
        return vec.sum(axis=1)

    def accuracy_from_scores(self, S: np.ndarray) -> float:
        return float(np.mean(S.argmax(axis=1) == self.y))

    def accuracy(self, state: np.ndarray) -> float:
        key = tuple(int(v) for v in state)
        hit = self._cache.get(key)
        if hit is not None:
            return hit
        a = self.accuracy_from_scores(self.score_matrix(state))
        self._cache[key] = a
        return a

    def candidate_accuracies(self, S: np.ndarray, state: np.ndarray, forward: bool) -> np.ndarray:
        """Accuracy of every one-step neighbor; invalid moves -> -inf.

        forward=True  : neighbor s_t -> s_t + 1 (Forward Squirrel)
        forward=False : neighbor s_t -> s_t - 1 (Backward Squirrel, i.e.
                        accuracy of the *predecessor* state)."""
        delta = 1 if forward else -1
        tgt = state + delta
        valid = (tgt >= 0) & (tgt <= self.depth)
        tgt_c = np.clip(tgt, 0, self.depth)
        b_ix = np.arange(self.B)[:, None]
        t_ix = np.arange(self.T)[None, :]
        pp_new = self.pp[b_ix, t_ix, tgt_c[None, :]]          # [B, T, C]
        pp_old = self.pp[b_ix, t_ix, state[None, :]]          # [B, T, C]
        cand = S[:, None, :] + (pp_new - pp_old)               # [B, T, C]
        preds = cand.argmax(axis=2)                            # [B, T]
        accs = (preds == self.y[:, None]).mean(axis=0)         # [T]
        return np.where(valid, accs, -np.inf)

    def apply_step(self, S: np.ndarray, state: np.ndarray, tree: int, forward: bool) -> None:
        """In-place: move tree's depth one step and update S."""
        delta = 1 if forward else -1
        b_ix = np.arange(self.B)
        S += self.pp[b_ix, tree, state[tree] + delta] - self.pp[b_ix, tree, state[tree]]
        state[tree] += delta


# ---------------------------------------------------------------------------
# Optimal Order (Sec. IV-B): Dijkstra on the (d+1)^T state DAG.
# ---------------------------------------------------------------------------

def optimal_order(
    evaluator: StateEvaluator,
    maximize: bool = True,
    state_limit: int = 2_000_000,
) -> np.ndarray:
    """Dijkstra over the state graph; edge weight into state v is the
    inaccuracy of v (inverted for the Unoptimal Order).

    The graph is a DAG (levels = total steps taken) but we follow the
    paper and run Dijkstra; worst case O((d+1)^T log (d+1)^T).  Refuses
    to run if the state count exceeds ``state_limit``.
    """
    T, d = evaluator.T, evaluator.depth
    n_states = (d + 1) ** T
    if n_states > state_limit:
        raise ValueError(
            f"Optimal Order infeasible: (d+1)^T = {n_states} states exceeds limit "
            f"{state_limit} — use squirrel orders (the paper's own conclusion)."
        )

    def weight(state_tuple: tuple) -> float:
        a = evaluator.accuracy(np.asarray(state_tuple, dtype=np.int64))
        inacc = 1.0 - a
        return inacc if maximize else a  # Unoptimal: minimize accuracy sum

    start = (0,) * T
    goal = (d,) * T
    dist: dict[tuple, float] = {start: 0.0}
    prev: dict[tuple, tuple] = {}
    heap: list[tuple[float, tuple]] = [(0.0, start)]
    visited: set[tuple] = set()
    while heap:
        du, u = heapq.heappop(heap)
        if u in visited:
            continue
        visited.add(u)
        if u == goal:
            break
        for t in range(T):
            if u[t] >= d:
                continue
            v = u[:t] + (u[t] + 1,) + u[t + 1:]
            nd = du + weight(v)
            if nd < dist.get(v, np.inf) - 1e-15:
                dist[v] = nd
                prev[v] = u
                heapq.heappush(heap, (nd, v))

    # reconstruct the step order from the predecessor chain
    order: list[int] = []
    cur = goal
    while cur != start:
        p = prev[cur]
        stepped = next(i for i in range(T) if cur[i] != p[i])
        order.append(stepped)
        cur = p
    order.reverse()
    return np.asarray(order, dtype=np.int32)


def unoptimal_order(evaluator: StateEvaluator, state_limit: int = 2_000_000) -> np.ndarray:
    """The accuracy-MINIMIZING order — the paper's lower-bound baseline."""
    return optimal_order(evaluator, maximize=False, state_limit=state_limit)


# ---------------------------------------------------------------------------
# Squirrel Orders (Sec. IV-C): greedy DFS through the state graph.
# ---------------------------------------------------------------------------

def forward_squirrel(evaluator: StateEvaluator) -> np.ndarray:
    """Greedy forward: from the initial state, repeatedly take the single
    step whose successor state has maximal accuracy on S_o."""
    T, d = evaluator.T, evaluator.depth
    state = np.zeros(T, dtype=np.int64)
    S = evaluator.score_matrix(state)
    order = np.empty(T * d, dtype=np.int32)
    for k in range(T * d):
        accs = evaluator.candidate_accuracies(S, state, forward=True)
        tree = int(np.argmax(accs))
        evaluator.apply_step(S, state, tree, forward=True)
        order[k] = tree
    return order


def backward_squirrel(evaluator: StateEvaluator) -> np.ndarray:
    """Greedy backward: from the FINAL state, repeatedly undo the step
    whose *predecessor* state has maximal accuracy; the undone steps,
    reversed, form the order.  The paper finds this variant the best
    polynomial heuristic (~94% of Optimal's NMA)."""
    T, d = evaluator.T, evaluator.depth
    state = np.full(T, d, dtype=np.int64)
    S = evaluator.score_matrix(state)
    rev: list[int] = []
    for _ in range(T * d):
        accs = evaluator.candidate_accuracies(S, state, forward=False)
        tree = int(np.argmax(accs))
        evaluator.apply_step(S, state, tree, forward=False)
        rev.append(tree)
    rev.reverse()
    return np.asarray(rev, dtype=np.int32)
