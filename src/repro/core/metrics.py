"""Anytime-quality metrics (Sec. VI of the paper)."""
from __future__ import annotations

import numpy as np


def mean_accuracy(curve: np.ndarray) -> float:
    """Mean accuracy over all states along an execution, including the
    initial (all-roots) state — the quantity every order generator
    maximizes under the paper's uniform-abort-time assumption."""
    return float(np.mean(curve))


def normalized_mean_accuracy(curve: np.ndarray) -> float:
    """NMA: mean accuracy normalized by the final accuracy ("achieving
    the final accuracy at every step" scores 1.0).  The paper normalizes
    the accuracy *sum* by (#steps x final accuracy), which is exactly
    mean/final; higher is better and configurations of different sizes
    become comparable."""
    final = float(curve[-1])
    if final <= 0:
        return 0.0
    return float(np.mean(curve)) / final


def auc_steps(curve: np.ndarray) -> float:
    """Area under the accuracy-vs-steps curve (trapezoid), in steps."""
    return float(np.trapezoid(curve)) if hasattr(np, "trapezoid") else float(np.trapz(curve))
