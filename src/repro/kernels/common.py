"""Shared plumbing for the Pallas kernel package.

Every kernel module used to carry its own copy of the TPU/interpret-mode
detection and the jax-version `CompilerParams` shim; drift between those
copies would silently run one kernel compiled and another interpreted.
This module is the single choke point:

* :func:`on_tpu` / :func:`resolve_interpret` — interpret-mode selection
  (compiled Mosaic on TPU, interpret mode everywhere else, explicit
  override always wins);
* :data:`CompilerParams` — the renamed ``TPUCompilerParams`` →
  ``CompilerParams`` class, whichever this jax version has;
* the **field-matrix layout** shared by the stepping kernels: node
  tables gather through one-hot matmuls against a ``[M, NFIELDS]`` f32
  matrix whose columns are (feature, threshold, left, right, is_leaf),
  padded to :data:`NFIELDS` lanes so the contraction tiles cleanly.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across versions.
CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

# Column layout of the one-hot-gatherable node-field matrix.
F_IDX, THR, LEFT, RIGHT, LEAF = range(5)
NFIELDS = 8  # padded to 8 lanes


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_interpret(interpret=None) -> bool:
    """Interpret-mode selection for every kernel in this package.

    ``None`` auto-selects: compiled Mosaic on a real TPU, interpret mode
    (same kernel body, element-for-element) elsewhere.  An explicit
    True/False always wins — the parity tests force interpret mode, the
    TPU benchmarks force compilation.
    """
    return (not on_tpu()) if interpret is None else bool(interpret)


def pack_fields(feature, threshold, left, right, is_leaf) -> jax.Array:
    """Node tables -> the ``[M, NFIELDS]`` f32 field matrix.

    A one-hot ``[B, M]`` contraction against this matrix gathers all
    five per-node scalars of one node per sample in a single MXU matmul.
    """
    mat = jnp.stack(
        [a.astype(jnp.float32) for a in (feature, threshold, left, right, is_leaf)],
        axis=1,
    )
    pad = jnp.zeros((mat.shape[0], NFIELDS - mat.shape[1]), mat.dtype)
    return jnp.concatenate([mat, pad], axis=1)


def round_up(n: int, multiple: int) -> int:
    return -(-n // multiple) * multiple


def pad_fields(fields: jax.Array) -> jax.Array:
    """Pad a [M, NFIELDS] field matrix to a lane-aligned Mp; padding
    nodes are leaves (self-loop) so a stray visit cannot escape.  The
    ONE place the padding invariant lives — both the solo tables and
    the flattened per-tree slot tables go through it."""
    M = fields.shape[0]
    Mp = round_up(max(M, 1), 128)
    out = jnp.pad(fields.astype(jnp.float32), ((0, Mp - M), (0, 0)))
    if Mp > M:
        out = out.at[M:, LEAF].set(1.0)
    return out


def onehot_step_body(col, x, fields, m_ids, f_cols):
    """One anytime step of one tree for a batch tile — THE step body
    every stepping kernel shares (fused solo, depth-aware, bucketized):

      * node gather    -> one-hot ``[Bb, W]`` x field-matrix ``[W, 8]``
        matmul (MXU), where ``W`` is the gather width (``Mp`` for the
        full table, narrower for a depth-bounded prefix);
      * feature gather -> one-hot masked reduction over ``x`` (VPU);
      * branch select  -> vectorized where; leaves self-loop.

    ``fields`` and ``m_ids`` must agree on ``W`` — callers pick the
    width; the arithmetic is bit-identical at any width that contains
    every live node index.
    """
    onehot = (col[:, None] == m_ids).astype(jnp.float32)      # [Bb, W]
    acc = jax.lax.dot(onehot, fields, preferred_element_type=jnp.float32)
    f_onehot = (f_cols == acc[:, F_IDX][:, None]).astype(jnp.float32)
    fv = jnp.sum(x * f_onehot, axis=1)                        # [Bb]
    nxt = jnp.where(fv <= acc[:, THR], acc[:, LEFT], acc[:, RIGHT])
    new = jnp.where(acc[:, LEAF] > 0.5, col.astype(jnp.float32), nxt)
    return new.astype(jnp.int32)


def accum_boundary_readout(new_idx, probs_ref, *, block_m: int,
                           n_trees: int, n_classes: int) -> jax.Array:
    """The fused ``prob_accum`` body shared by the run-readout kernels:
    accumulate ``sum_t probs[t, new_idx[:, t]]`` over per-tree tiles of
    a flattened ``[T*Mp, C]`` probability ref, in the same tree order
    (0..T-1) as the standalone kernel.  ``new_idx`` is the advanced
    [Bb, T] index block; returns the readout ``[Bb, C]``."""
    t_ids = jax.lax.broadcasted_iota(jnp.int32, new_idx.shape, 1)
    m_ids = jax.lax.broadcasted_iota(jnp.int32, (1, block_m), 1)

    def ro_body(t, acc):
        col_t = jnp.sum(jnp.where(t_ids == t, new_idx, 0), axis=1)
        onehot = (col_t[:, None] == m_ids).astype(jnp.float32)
        ptile = probs_ref[pl.ds(t * block_m, block_m), :]
        return acc + jax.lax.dot(onehot, ptile, preferred_element_type=jnp.float32)

    ro0 = jnp.zeros((new_idx.shape[0], n_classes), jnp.float32)
    return jax.lax.fori_loop(0, n_trees, ro_body, ro0)
