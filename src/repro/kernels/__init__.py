"""Pallas TPU kernels for the anytime-forest execution core.

Layout:

* :mod:`repro.kernels.common`      — shared plumbing: interpret-mode
  selection, compiler-params shim, node-field-matrix layout.
* :mod:`repro.kernels.forest_step` — single-step kernel (PR 2).
* :mod:`repro.kernels.forest_run`  — fused multi-step kernel: one launch
  per plan segment, node tables resident in VMEM, optional fused
  boundary read-out.
* :mod:`repro.kernels.slot_run`    — masked-slot kernel: per-slot tree
  ids + live mask on flattened whole-forest tables (serving hot path).
* :mod:`repro.kernels.prob_accum`  — standalone read-out kernel.
* :mod:`repro.kernels.ref`         — pure-jnp oracles for all of them.
* :mod:`repro.kernels.ops`         — the public wrappers (budget-checked
  fallbacks, interpret-mode defaults); everything above is plumbing.
"""
