"""Pallas TPU kernel: depth-aware fused run — gather elimination for the
shallow levels of a fresh anytime walk.

:mod:`repro.kernels.forest_run` contracts a one-hot ``[Bb, Mp]`` against
the FULL node-field matrix every step.  But a dispatch that starts at
the ROOT (the first segment a tree receives under the paper's step
plans) provably cannot reach deep nodes early: after ``j`` steps every
walker sits at BFS depth ≤ ``j``.  With the tables depth-ordered
(:mod:`repro.kernels.layout`) those nodes occupy a PREFIX of the field
matrix, so step ``j``'s gather narrows from ``Mp`` rows to the layout's
``counts(j)`` rows — the first steps touch a handful of sublanes instead
of the whole table, the register/cache service of shallow levels that
Gossen & Steffen identify as the dominant win for large forests.

Mechanics: the narrow prefix widths are a **static tuple** (computed
host-side from the concrete layout), so the kernel simply unrolls one
``onehot_step_body`` per width over ``fields_ref[pl.ds(0, w), :]`` and
finishes the remaining steps with the usual full-width ``fori_loop``.
Same arithmetic, same field matrix, strictly fewer rows gathered —
bit-parity with :func:`repro.kernels.ops.forest_run` on the same layout
is exact, and the analytical gather-bytes counter in :mod:`tools.perf`
drops accordingly.

Only valid when ``idx`` is in the layout's depth-ordered node space and
every walker has taken at most ``start_step`` steps — the executor
guards this by restricting the variant to *fresh* (offset-0) segments.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (
    NFIELDS,
    CompilerParams,
    onehot_step_body,
    round_up,
)


def _depth_run_kernel(
    idx_ref,     # int32 [Bb, 1]  depth-space index column
    x_ref,       # f32   [Bb, F]
    fields_ref,  # f32   [Mp, NFIELDS]  depth-ordered resident fields
    out_ref,     # int32 [Bb, 1]
    *,
    widths: tuple,
    length: int,
    block_m: int,
):
    x = x_ref[...]
    f_cols = jax.lax.broadcasted_iota(jnp.float32, x.shape, 1)
    col = idx_ref[:, 0]

    # statically unrolled narrow-prefix steps: step j gathers widths[j]
    # rows — every node reachable by then lives in that prefix
    for w in widths:
        m_ids = jax.lax.broadcasted_iota(jnp.int32, (1, w), 1)
        col = onehot_step_body(col, x, fields_ref[pl.ds(0, w), :], m_ids, f_cols)

    tail = length - len(widths)
    if tail > 0:
        fields = fields_ref[...]
        m_ids = jax.lax.broadcasted_iota(jnp.int32, (1, block_m), 1)

        def body(_, c):
            return onehot_step_body(c, x, fields, m_ids, f_cols)

        col = jax.lax.fori_loop(0, tail, body, col)
    out_ref[:, 0] = col


@functools.partial(
    jax.jit, static_argnames=("widths", "length", "block_b", "interpret")
)
def depth_run(
    idx: jax.Array,     # int32 [B]  index column, DEPTH-ORDERED node space
    X: jax.Array,       # f32   [B, F]
    fields: jax.Array,  # f32   [Mp, NFIELDS]  depth-ordered, pad_fields'd
    *,
    widths: tuple,      # static per-step narrow gather widths (may be ())
    length: int,
    block_b: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """``length`` fused steps of one depth-ordered tree in ONE launch,
    the first ``len(widths)`` steps gathering only a table prefix.

    ``widths`` must come from ``DepthLayout.step_widths`` for the same
    start offset — each entry must cover every node reachable by that
    step, or narrow gathers would drop live states.  ``widths=()``
    degrades to exactly the full-width fused kernel.
    """
    B, F = X.shape
    Mp = fields.shape[0]
    if any(w > Mp for w in widths):
        raise ValueError(f"narrow widths {widths} exceed table height {Mp}")
    block_b = min(block_b, max(8, B))
    Bp = round_up(B, block_b)
    idx_p = jnp.pad(idx, (0, Bp - B)).reshape(Bp, 1)
    x_p = jnp.pad(X, ((0, Bp - B), (0, 0)))

    out = pl.pallas_call(
        functools.partial(
            _depth_run_kernel, widths=tuple(widths), length=length, block_m=Mp
        ),
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda b: (b, 0)),
            pl.BlockSpec((block_b, F), lambda b: (b, 0)),
            pl.BlockSpec((Mp, NFIELDS), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, 1), jnp.int32),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(idx_p, x_p, fields)
    return out[:B, 0]
