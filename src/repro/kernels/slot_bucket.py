"""Pallas TPU kernel: tree-bucketized masked-slot run.

:mod:`repro.kernels.slot_run` pays for per-slot tree ids with a one-hot
contraction over the WHOLE flattened forest — ``[Sb, T*Mp]`` per step —
and demands every tree's table be VMEM-resident at once.  This variant
restructures the launch around the tree id instead: the grid grows a
second (innermost, "arbitrary") tree dimension, grid step ``(s, t)``
advances only the slots of tile ``s`` whose unit is ``t``, and the
BlockSpec index map streams exactly ONE tree's ``[Mp, NFIELDS]`` tile
per grid step.  Consequences:

* per-slot one-hot width drops from ``T*Mp`` to ``Mp`` — the gather
  bytes-moved counter (:mod:`tools.perf`) falls by a factor of T;
* no tree table is ever resident longer than its own grid step, so the
  kernel serves forests whose FLAT tables blow the VMEM budget — the
  shapes the flat kernel must refuse;
* the output block is revisited across consecutive ``t`` steps
  (initialized from the input at ``t == 0`` via ``pl.when``), the
  standard Pallas accumulation pattern.

Slots whose unit is not ``t`` pass through untouched at that grid step,
so after the full ``t`` sweep every live slot has advanced its own tree
by ``length`` steps — bit-exact with :func:`repro.core.engine.slot_run`.
The scheduler-side companion (``ops.bucketize_slots``) stably sorts
slots by unit first, giving each ``(s, t)`` tile gather coherence.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (
    NFIELDS,
    CompilerParams,
    onehot_step_body,
    round_up,
)


def _bucket_loop(idx, x, units, live, fields, t, *, length, block_m):
    """Advance columns of tree ``t`` only: slots with ``units == t`` and
    live step ``length`` times against this tree's [Mp, NFIELDS] tile."""
    t_ids = jax.lax.broadcasted_iota(jnp.int32, idx.shape, 1)   # [Sb, T]
    sel = (t_ids == t) & (units == t)[:, None] & live[:, None]
    m_ids = jax.lax.broadcasted_iota(jnp.int32, (1, block_m), 1)
    f_cols = jax.lax.broadcasted_iota(jnp.float32, x.shape, 1)

    def body(_, idx):
        node = jnp.sum(jnp.where(sel, idx, 0), axis=1)          # idx[s, t]
        new = onehot_step_body(node, x, fields, m_ids, f_cols)
        return jnp.where(sel, new[:, None], idx)

    return jax.lax.fori_loop(0, length, body, idx)


def _slot_bucket_kernel(
    idx_ref,     # int32 [Sb, T]
    x_ref,       # f32   [Sb, F]
    units_ref,   # int32 [Sb, 1]
    mask_ref,    # int32 [Sb, 1]
    fields_ref,  # f32   [1, Mp, NFIELDS]  tree t's tile (streamed per step)
    out_ref,     # int32 [Sb, T]  revisited across the t sweep
    *,
    length: int,
    block_m: int,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = idx_ref[...]

    out_ref[...] = _bucket_loop(
        out_ref[...], x_ref[...], units_ref[:, 0], mask_ref[:, 0] > 0,
        fields_ref[0], t, length=length, block_m=block_m,
    )


def _slot_bucket_readout_kernel(
    idx_ref, x_ref, units_ref, mask_ref,
    fields_ref,  # f32 [1, Mp, NFIELDS]  tree t's fields (streamed)
    probs_ref,   # f32 [1, Mp, C]        tree t's probs (streamed)
    out_ref,     # int32 [Sb, T]
    ro_out,      # f32   [Sb, C]  accumulated across the t sweep
    *,
    length: int,
    block_m: int,
):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        out_ref[...] = idx_ref[...]
        ro_out[...] = jnp.zeros_like(ro_out)

    new_idx = _bucket_loop(
        out_ref[...], x_ref[...], units_ref[:, 0], mask_ref[:, 0] > 0,
        fields_ref[0], t, length=length, block_m=block_m,
    )
    out_ref[...] = new_idx

    # tree t's column is final once its own grid step ran, so its
    # readout term accumulates here — t-ascending, the same summation
    # order as accum_boundary_readout (bit-exact readout parity)
    t_ids = jax.lax.broadcasted_iota(jnp.int32, new_idx.shape, 1)
    col_t = jnp.sum(jnp.where(t_ids == t, new_idx, 0), axis=1)
    m_ids = jax.lax.broadcasted_iota(jnp.int32, (1, block_m), 1)
    onehot = (col_t[:, None] == m_ids).astype(jnp.float32)
    ro_out[...] += jax.lax.dot(
        onehot, probs_ref[0], preferred_element_type=jnp.float32
    )


def _pad_slots(idx, X, units, mask, block_s):
    S = X.shape[0]
    Sp = round_up(S, block_s)
    pad = Sp - S
    return (
        jnp.pad(idx, ((0, pad), (0, 0))),
        jnp.pad(X, ((0, pad), (0, 0))),
        jnp.pad(units.astype(jnp.int32), (0, pad)).reshape(Sp, 1),
        jnp.pad(mask.astype(jnp.int32), (0, pad)).reshape(Sp, 1),
        Sp,
    )


@functools.partial(jax.jit, static_argnames=("length", "block_s", "interpret"))
def slot_bucket_run(
    idx: jax.Array,     # int32 [S, T]
    X: jax.Array,       # f32   [S, F]
    fields: jax.Array,  # f32   [T, Mp, NFIELDS]  per-tree padded tiles
    units: jax.Array,   # int32 [S]
    mask: jax.Array,    # bool  [S]
    *,
    length: int,
    block_s: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """``length`` fused masked slot-steps with per-tree table streaming:
    one launch, grid ``(slots, trees)``, tree ``t``'s table in VMEM only
    during its own grid step."""
    S, T = idx.shape
    F = X.shape[1]
    Mp = fields.shape[1]
    block_s = min(block_s, max(8, S))
    idx_p, x_p, units_p, mask_p, Sp = _pad_slots(idx, X, units, mask, block_s)

    out = pl.pallas_call(
        functools.partial(_slot_bucket_kernel, length=length, block_m=Mp),
        grid=(Sp // block_s, T),
        in_specs=[
            pl.BlockSpec((block_s, T), lambda s, t: (s, 0)),
            pl.BlockSpec((block_s, F), lambda s, t: (s, 0)),
            pl.BlockSpec((block_s, 1), lambda s, t: (s, 0)),
            pl.BlockSpec((block_s, 1), lambda s, t: (s, 0)),
            pl.BlockSpec((1, Mp, NFIELDS), lambda s, t: (t, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, T), lambda s, t: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((Sp, T), jnp.int32),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(idx_p, x_p, units_p, mask_p, fields)
    return out[:S]


@functools.partial(jax.jit, static_argnames=("length", "block_s", "interpret"))
def slot_bucket_run_readout(
    idx: jax.Array,
    X: jax.Array,
    fields: jax.Array,  # f32 [T, Mp, NFIELDS]
    probs: jax.Array,   # f32 [T, Mp, C]  per-tree padded probability tiles
    units: jax.Array,
    mask: jax.Array,
    *,
    length: int,
    block_s: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused bucketized run + boundary read-out: the probability tiles
    stream per tree alongside the fields, the readout accumulates across
    the ``t`` sweep — one launch for the serving dispatch+readout pair."""
    S, T = idx.shape
    F = X.shape[1]
    Mp = fields.shape[1]
    C = probs.shape[2]
    block_s = min(block_s, max(8, S))
    idx_p, x_p, units_p, mask_p, Sp = _pad_slots(idx, X, units, mask, block_s)

    new_idx, ro = pl.pallas_call(
        functools.partial(
            _slot_bucket_readout_kernel, length=length, block_m=Mp
        ),
        grid=(Sp // block_s, T),
        in_specs=[
            pl.BlockSpec((block_s, T), lambda s, t: (s, 0)),
            pl.BlockSpec((block_s, F), lambda s, t: (s, 0)),
            pl.BlockSpec((block_s, 1), lambda s, t: (s, 0)),
            pl.BlockSpec((block_s, 1), lambda s, t: (s, 0)),
            pl.BlockSpec((1, Mp, NFIELDS), lambda s, t: (t, 0, 0)),
            pl.BlockSpec((1, Mp, C), lambda s, t: (t, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_s, T), lambda s, t: (s, 0)),
            pl.BlockSpec((block_s, C), lambda s, t: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Sp, T), jnp.int32),
            jax.ShapeDtypeStruct((Sp, C), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
        interpret=interpret,
    )(idx_p, x_p, units_p, mask_p, fields, probs)
    return new_idx[:S], ro[:S]
