"""Pallas TPU kernel: batched anytime forest step.

TPU adaptation of the paper's native-tree step (Sec. V).  The CPU/MCU
algorithm is a pointer chase (load node, compare, jump); a literal port
would serialize on scalar loads.  On TPU we rethink the step as dense
linear algebra so it runs on the MXU/VPU:

  * node gather       -> one-hot [Bb, M] x node-table [M] matmuls (MXU)
  * feature gather    -> one-hot [Bb, F] masked reduction (VPU)
  * branch select     -> vectorized where

The node table is tiled over the M (node) axis so arbitrarily large
trees stream through VMEM; gathered per-node scalars accumulate in a
scratch block (the one-hot has a single nonzero, so partial sums across
M-tiles compose by addition).  Batch is tiled over the grid's parallel
axis.

This single-tree-step kernel is the latency-critical inner loop of an
anytime execution: between two abort checkpoints the engine executes
`order[k]` steps by calling this kernel once per step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.common import CompilerParams as _CompilerParams

# Scratch layout: per-sample gathered node fields, accumulated over M
# tiles (shared with the fused kernels via kernels.common).
from repro.kernels.common import (  # noqa: F401  (re-exported layout)
    F_IDX as _F_IDX,
    THR as _THR,
    LEFT as _LEFT,
    RIGHT as _RIGHT,
    LEAF as _LEAF,
    NFIELDS as _NFIELDS,
)


def _forest_step_kernel(
    idx_ref,        # int32 [Bb, 1]        current node ids
    x_ref,          # f32   [Bb, F]        feature rows
    feature_ref,    # f32   [1, Mb]        node split-feature (as f32)
    threshold_ref,  # f32   [1, Mb]
    left_ref,       # f32   [1, Mb]
    right_ref,      # f32   [1, Mb]
    leaf_ref,       # f32   [1, Mb]
    out_ref,        # int32 [Bb, 1]
    acc_ref,        # f32   [Bb, _NFIELDS] scratch accumulator
    *,
    block_m: int,
    n_m_blocks: int,
):
    m_blk = pl.program_id(1)

    @pl.when(m_blk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    idx = idx_ref[:, 0]                                   # [Bb]
    m_base = m_blk * block_m
    m_ids = m_base + jax.lax.broadcasted_iota(jnp.int32, (1, block_m), 1)
    onehot = (idx[:, None] == m_ids).astype(jnp.float32)  # [Bb, Mb]

    # Gather node fields via one-hot contraction (MXU-friendly).
    fields = jnp.stack(
        [
            feature_ref[0, :],
            threshold_ref[0, :],
            left_ref[0, :],
            right_ref[0, :],
            leaf_ref[0, :],
        ],
        axis=1,
    )  # [Mb, 5]
    pad = jnp.zeros((fields.shape[0], _NFIELDS - fields.shape[1]), fields.dtype)
    fields = jnp.concatenate([fields, pad], axis=1)       # [Mb, 8]
    acc_ref[...] += jax.lax.dot(
        onehot, fields, preferred_element_type=jnp.float32
    )

    @pl.when(m_blk == n_m_blocks - 1)
    def _finish():
        acc = acc_ref[...]
        f_idx = acc[:, _F_IDX]                            # [Bb] f32
        thr = acc[:, _THR]
        x = x_ref[...]                                    # [Bb, F]
        f_cols = jax.lax.broadcasted_iota(jnp.float32, x.shape, 1)
        f_onehot = (f_cols == f_idx[:, None]).astype(jnp.float32)
        fv = jnp.sum(x * f_onehot, axis=1)                # [Bb]
        nxt = jnp.where(fv <= thr, acc[:, _LEFT], acc[:, _RIGHT])
        new = jnp.where(acc[:, _LEAF] > 0.5, idx.astype(jnp.float32), nxt)
        out_ref[:, 0] = new.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_b", "block_m", "interpret"))
def forest_step(
    idx: jax.Array,        # int32 [B]
    X: jax.Array,          # f32   [B, F]
    feature: jax.Array,    # int32 [M]
    threshold: jax.Array,  # f32   [M]
    left: jax.Array,       # int32 [M]
    right: jax.Array,      # int32 [M]
    is_leaf: jax.Array,    # bool  [M]
    *,
    block_b: int = 256,
    block_m: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """One anytime step for one tree over a batch.  See module docstring."""
    B, F = X.shape
    M = feature.shape[0]
    block_b = min(block_b, max(8, B))
    block_m = min(block_m, M)

    # pad batch and nodes to block multiples
    Bp = -(-B // block_b) * block_b
    Mp = -(-M // block_m) * block_m
    idx_p = jnp.pad(idx, (0, Bp - B)).reshape(Bp, 1)
    x_p = jnp.pad(X, ((0, Bp - B), (0, 0)))
    def padm(a, fill=0):
        return jnp.pad(a.astype(jnp.float32), (0, Mp - M), constant_values=fill).reshape(1, Mp)
    feat_p = padm(feature)
    thr_p = padm(threshold)
    left_p = padm(left)
    right_p = padm(right)
    leaf_p = padm(is_leaf.astype(jnp.float32), fill=1.0)  # padding nodes are leaves

    n_b, n_m = Bp // block_b, Mp // block_m
    out = pl.pallas_call(
        functools.partial(_forest_step_kernel, block_m=block_m, n_m_blocks=n_m),
        grid=(n_b, n_m),
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda b, m: (b, 0)),
            pl.BlockSpec((block_b, F), lambda b, m: (b, 0)),
            pl.BlockSpec((1, block_m), lambda b, m: (0, m)),
            pl.BlockSpec((1, block_m), lambda b, m: (0, m)),
            pl.BlockSpec((1, block_m), lambda b, m: (0, m)),
            pl.BlockSpec((1, block_m), lambda b, m: (0, m)),
            pl.BlockSpec((1, block_m), lambda b, m: (0, m)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda b, m: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, 1), jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_b, _NFIELDS), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(idx_p, x_p, feat_p, thr_p, left_p, right_p, leaf_p)
    return out[:B, 0]
