"""Pallas TPU kernel: fused multi-step anytime forest run.

The single-step kernel (:mod:`repro.kernels.forest_step`) pays one
kernel launch per tree-step: a plan segment of length L scanned over it
re-reads the tree's node tables from HBM L times.  This kernel moves the
run loop *inside* the launch: a kernel-internal ``jax.lax.fori_loop``
advances the stepped tree's index column L times while the node-field
matrix stays **resident in VMEM** for the whole segment — the
memory-hierarchy-aware layout the large-forest literature (Gossen &
Steffen) motivates, applied to the paper's per-step anytime execution.

Per step the arithmetic is identical to the single-step kernel (so the
index state stays bit-exact with the jnp oracle):

  * node gather     -> one-hot [Bb, Mp] x field-matrix [Mp, 8] matmul (MXU)
  * feature gather  -> one-hot [Bb, F] masked reduction (VPU)
  * branch select   -> vectorized where

:func:`forest_run_readout` additionally fuses the ``prob_accum``
read-out into the SAME launch: after the run loop it accumulates
``sum_t probs[t, idx[b, t]]`` over the flattened per-tree probability
tiles, so a segment-boundary dispatch that needs its readout (the
serving hot path) costs one launch instead of two.

Residency tradeoff: there is no M-tiling here — the field matrix (and,
for the readout variant, the flattened probability table) must fit in
VMEM.  :mod:`repro.kernels.ops` checks the footprint against a budget
and falls back to the streamed single-step scan for oversized forests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (
    NFIELDS,
    CompilerParams,
    accum_boundary_readout,
    onehot_step_body as _step_body,
    pad_fields,
    round_up,
)


def _forest_run_kernel(
    idx_ref,     # int32 [Bb, 1]   stepped tree's index column
    x_ref,       # f32   [Bb, F]
    fields_ref,  # f32   [Mp, NFIELDS]  resident node-field matrix
    out_ref,     # int32 [Bb, 1]
    *,
    length: int,
    block_m: int,
):
    fields = fields_ref[...]
    x = x_ref[...]
    m_ids = jax.lax.broadcasted_iota(jnp.int32, (1, block_m), 1)
    f_cols = jax.lax.broadcasted_iota(jnp.float32, x.shape, 1)

    def body(_, col):
        return _step_body(col, x, fields, m_ids, f_cols)

    out_ref[:, 0] = jax.lax.fori_loop(0, length, body, idx_ref[:, 0])


def _forest_run_readout_kernel(
    unit_ref,    # int32 [1, 1]    stepped tree id
    idx_ref,     # int32 [Bb, T]   FULL index array
    x_ref,       # f32   [Bb, F]
    fields_ref,  # f32   [Mp, NFIELDS]  stepped tree's resident fields
    probs_ref,   # f32   [T*Mp, C] flattened per-tree probability tiles
    idx_out,     # int32 [Bb, T]
    ro_out,      # f32   [Bb, C]
    *,
    length: int,
    block_m: int,
    n_trees: int,
):
    unit = unit_ref[0, 0]
    idx = idx_ref[...]                                        # [Bb, T]
    x = x_ref[...]
    fields = fields_ref[...]
    t_ids = jax.lax.broadcasted_iota(jnp.int32, idx.shape, 1)
    sel = t_ids == unit                                       # [Bb, T]
    m_ids = jax.lax.broadcasted_iota(jnp.int32, (1, block_m), 1)
    f_cols = jax.lax.broadcasted_iota(jnp.float32, x.shape, 1)

    def body(_, col):
        return _step_body(col, x, fields, m_ids, f_cols)

    col0 = jnp.sum(jnp.where(sel, idx, 0), axis=1)            # idx[:, unit]
    col = jax.lax.fori_loop(0, length, body, col0)
    new_idx = jnp.where(sel, col[:, None], idx)
    idx_out[...] = new_idx
    ro_out[...] = accum_boundary_readout(
        new_idx, probs_ref, block_m=block_m, n_trees=n_trees,
        n_classes=ro_out.shape[1],
    )


def _pad_batch(idx, X, block_b):
    B = X.shape[0]
    Bp = round_up(B, block_b)
    return (
        jnp.pad(idx, ((0, Bp - B),) + ((0, 0),) * (idx.ndim - 1)),
        jnp.pad(X, ((0, Bp - B), (0, 0))),
        Bp,
    )


def flatten_probs(probs: jax.Array, Mp: int) -> jax.Array:
    """[T, M, C] -> [T*Mp, C] with each tree's tile padded to Mp, so
    flat index ``t*Mp + node`` addresses tree t's node row."""
    T, M, C = probs.shape
    padded = jnp.pad(probs.astype(jnp.float32), ((0, 0), (0, Mp - M), (0, 0)))
    return padded.reshape(T * Mp, C)


@functools.partial(jax.jit, static_argnames=("length", "block_b", "interpret"))
def forest_run(
    idx: jax.Array,     # int32 [B]   stepped tree's index column
    X: jax.Array,       # f32   [B, F]
    fields: jax.Array,  # f32   [M, NFIELDS]  (common.pack_fields)
    *,
    length: int,
    block_b: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """``length`` fused steps of one tree in ONE launch (VMEM-resident
    tables).  ``length`` must be static — plan-bucketed powers of two."""
    B, F = X.shape
    block_b = min(block_b, max(8, B))
    idx_p, x_p, Bp = _pad_batch(idx, X, block_b)
    fields_p = pad_fields(fields)
    Mp = fields_p.shape[0]

    out = pl.pallas_call(
        functools.partial(_forest_run_kernel, length=length, block_m=Mp),
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda b: (b, 0)),
            pl.BlockSpec((block_b, F), lambda b: (b, 0)),
            pl.BlockSpec((Mp, NFIELDS), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, 1), jnp.int32),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(idx_p.reshape(Bp, 1), x_p, fields_p)
    return out[:B, 0]


@functools.partial(jax.jit, static_argnames=("length", "block_b", "interpret"))
def forest_run_readout(
    idx: jax.Array,     # int32 [B, T]  FULL index array
    X: jax.Array,       # f32   [B, F]
    fields: jax.Array,  # f32   [M, NFIELDS]  stepped tree's fields
    probs: jax.Array,   # f32   [T, M, C]
    unit,               # int32 scalar: stepped tree id
    *,
    length: int,
    block_b: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused run + boundary read-out: one launch advances ``unit`` by
    ``length`` steps AND returns the full anytime readout ``[B, C]`` of
    the resulting state."""
    B, F = X.shape
    T = idx.shape[1]
    C = probs.shape[2]
    block_b = min(block_b, max(8, B))
    idx_p, x_p, Bp = _pad_batch(idx, X, block_b)
    fields_p = pad_fields(fields)
    Mp = fields_p.shape[0]
    probs_p = flatten_probs(probs, Mp)
    unit_arr = jnp.asarray(unit, jnp.int32).reshape(1, 1)

    new_idx, ro = pl.pallas_call(
        functools.partial(
            _forest_run_readout_kernel, length=length, block_m=Mp, n_trees=T
        ),
        grid=(Bp // block_b,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b: (0, 0)),
            pl.BlockSpec((block_b, T), lambda b: (b, 0)),
            pl.BlockSpec((block_b, F), lambda b: (b, 0)),
            pl.BlockSpec((Mp, NFIELDS), lambda b: (0, 0)),
            pl.BlockSpec((T * Mp, C), lambda b: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, T), lambda b: (b, 0)),
            pl.BlockSpec((block_b, C), lambda b: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bp, T), jnp.int32),
            jax.ShapeDtypeStruct((Bp, C), jnp.float32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(unit_arr, idx_p, x_p, fields_p, probs_p)
    return new_idx[:B], ro[:B]
