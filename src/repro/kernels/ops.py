"""Public jit'd wrappers around the Pallas kernels — the kernel-resident
execution core, with tuning-record-driven implementation selection.

On a real TPU these dispatch compiled Mosaic kernels; on CPU (this
container) they run in interpret mode, which executes the same kernel
body element-for-element — the mode the test suite validates against the
ref.py oracles.  Interpret-mode selection lives in ONE place
(:func:`repro.kernels.common.resolve_interpret`) so it cannot drift
between kernels.

Dispatch: each execution shape has several registered, bit-exact
implementations (:mod:`repro.kernels.tuning`); the public wrappers pick
one per call from the platform's committed ``tuning/<platform>.json``
record — or from the caller's explicit ``impl=`` override (how the
parity tests pin each kernel) — and every kernel-backed impl still
budget-checks its VMEM footprint and degrades to its streamed/generic
sibling rather than failing Mosaic compilation.  Absent a tuning entry
the defaults are conservative: ``fused`` for the solo path, ``gather``
for the slot path (a kernel must MEASURE faster to be selected — no
shape regresses vs the generic gather it replaced).

Entry points, by execution shape:

* :func:`forest_step` — one step of one tree (the PR-2 latency kernel).
* :func:`forest_run` / :func:`forest_run_readout` — L fused steps of one
  tree in ONE launch; impls ``fused`` (VMEM-resident tables,
  :mod:`repro.kernels.forest_run`) and ``scan`` (streamed single-step
  launches).
* :func:`forest_run_depth` — the gather-eliminated variant over a
  precomputed :class:`repro.kernels.layout.DepthLayout`: the first steps
  of a fresh walk contract against a narrow table PREFIX
  (:mod:`repro.kernels.depth_run`).
* :func:`slot_run` / :func:`slot_run_readout` — masked per-slot trees;
  impls ``gather`` (generic jnp), ``flat`` (whole-forest resident,
  :mod:`repro.kernels.slot_run`), ``bucket`` (per-tree streamed grid,
  :mod:`repro.kernels.slot_bucket`), ``cached`` (flat + hot subtree-top
  fast path).  :func:`bucketize_slots` is the scheduler-side companion
  permutation for gather coherence.
* :func:`prob_accum` — the standalone read-out kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref  # noqa: F401  (oracles re-exported below)
from repro.kernels import depth_run as _depth
from repro.kernels import forest_run as _fused
from repro.kernels import slot_bucket as _bucket
from repro.kernels import slot_run as _slots
from repro.kernels import tuning
from repro.kernels.common import (
    NFIELDS,
    on_tpu,
    pack_fields,
    pad_fields,
    resolve_interpret,
    round_up,
)
from repro.kernels.forest_step import forest_step as _forest_step
from repro.kernels.prob_accum import prob_accum as _prob_accum
from repro.obs import annotate as _obs_annotate
from repro.obs import tracing_active as _obs_tracing_active

#: Soft cap on the VMEM-resident table footprint of the fused kernels.
#: Above it the wrappers fall back to the streamed/generic paths — the
#: fused kernels trade M-tiling for residency, so arbitrarily large
#: forests must not be forced through them.  ~4 MiB leaves headroom in a
#: 16 MiB VMEM for the batch tile, one-hot blocks, and double buffering.
VMEM_TABLE_BUDGET_BYTES = 4 * 2**20

#: rows of each tree's depth-ordered tile the ``cached`` slot impl keeps
#: in its compacted hot-top table when the tuning record doesn't say
DEFAULT_TOP_ROWS = 32


def _on_tpu() -> bool:  # retained alias: single source is common.on_tpu
    return on_tpu()


def forest_step(idx, X, feature, threshold, left, right, is_leaf, **kw):
    """Batched anytime step (see kernels.forest_step)."""
    kw["interpret"] = resolve_interpret(kw.pop("interpret", None))
    return _forest_step(idx, X, feature, threshold, left, right, is_leaf, **kw)


def forest_run_scanned(
    idx, X, feature, threshold, left, right, is_leaf, *, length, **kw
):
    """Legacy multi-step path: ``length`` launches of the single-step
    kernel under one ``lax.scan``.  Kept as the streaming fallback for
    forests whose tables exceed the VMEM budget, and as the baseline the
    fused-vs-scan benchmark gate compares against."""
    kw["interpret"] = resolve_interpret(kw.pop("interpret", None))

    def body(col, _):
        col = _forest_step(
            col, X, feature, threshold, left, right, is_leaf, **kw
        )
        return col, None

    return jax.lax.scan(body, idx, None, length=length)[0]


def _tables_fit(M: int, *, field_trees: int = 1, probs_trees: int = 0,
                C: int = 0, onehot_rows: int = 0) -> bool:
    """Does the kernel's VMEM footprint fit the budget?

    Counts what the target kernel actually holds: ``field_trees``
    trees' [Mp, NFIELDS] field matrices, (for the fused-readout
    variants) ``probs_trees`` trees' [Mp, C] probability tiles, and —
    the dominant term for wide trees — the per-step one-hot matmul
    operand ``[onehot_rows, field_trees*Mp]`` the gather materializes.
    All f32.  Exact accounting both ways: the fused paths are not
    disabled for forests that fit, and wide forests whose one-hot
    would blow VMEM on a real TPU fall back to the streamed/generic
    paths instead of failing Mosaic compilation.
    """
    Mp = round_up(max(M, 1), 128)
    resident = (field_trees * Mp * NFIELDS + probs_trees * Mp * C
                + onehot_rows * field_trees * Mp) * 4
    return resident <= VMEM_TABLE_BUDGET_BYTES


def _block_rows(n_rows: int, kw: dict, default: int = 256) -> int:
    """The batch/slot tile height the kernel will actually use (the
    wrappers clamp the block to the padded row count; for the slot
    kernels an explicit block_s wins, mirroring _slot_kw)."""
    rows = kw.get("block_s", kw.get("block_b", default))
    return min(int(rows), max(8, int(n_rows)))


_SOLO_KW = frozenset({"block_b", "block_m", "interpret"})
_SLOT_ALLOWED_KW = _SOLO_KW | {"block_s", "top_rows"}


def _check_kw(kw: dict, allowed: frozenset = _SOLO_KW) -> None:
    """Reject tuning kwargs the target path cannot honor — eagerly and
    identically for every impl behind the shape, never silently
    swallowed (block_s/top_rows are slot-only; the solo wrappers reject
    them)."""
    unknown = set(kw) - allowed
    if unknown:
        raise TypeError(f"unknown kernel option(s): {sorted(unknown)}")


def _fb_kw(kw: dict) -> dict:
    """Kwargs for the scan/prob_accum fallback paths."""
    return {k: v for k, v in kw.items()
            if k in ("block_b", "block_m", "interpret")}


def _slot_kw(kw: dict) -> dict:
    """Kwargs for the slot kernels: callers tune the slot tile via
    either name; an explicit block_s wins over a translated block_b."""
    out = {}
    if "block_b" in kw:
        out["block_s"] = kw["block_b"]
    if "block_s" in kw:
        out["block_s"] = kw["block_s"]
    return out


def _resolve(kind: str, key: str, impl, kw: dict, allowed: frozenset):
    """Pick the implementation for one dispatch: an explicit ``impl=``
    wins (unknown names raise — tests must not silently re-route); else
    the platform tuning record decides, its block parameters merging
    UNDER any caller-supplied kwargs."""
    registry = tuning.SOLO_IMPLS if kind == "solo" else tuning.SLOT_IMPLS
    if impl is not None:
        if impl not in registry:
            raise ValueError(
                f"unknown {kind} impl {impl!r} (registered: {sorted(registry)})"
            )
        name, merged = impl, dict(kw)
    else:
        name, params = tuning.select(kind, key)
        merged = {k: v for k, v in params.items() if k in allowed}
        merged.update(kw)
    if _obs_tracing_active():
        # this Python only runs while jax TRACES the enclosing jitted
        # body — steady-state dispatches replay the cached trace and
        # never reach here — so firing inside an active dispatch span
        # marks that dispatch as the one that minted a jit trace, with
        # the registry's authoritative impl name
        _obs_annotate(impl=name, jit_trace=True)
    return registry[name], merged


# --------------------------------------------------------------------------
# solo path: one stepped tree, index COLUMN [B]
# --------------------------------------------------------------------------

@tuning.register_solo_impl("scan")
def _solo_scan(idx, X, feature, threshold, left, right, is_leaf,
               *, length, probs=None, unit=None, readout=False, **kw):
    """Streamed baseline: ``length`` single-step launches (plus a
    standalone ``prob_accum`` dispatch when a readout is fused in).
    No residency requirement — serves any table size."""
    fb = _fb_kw(kw)
    if not readout:
        return forest_run_scanned(
            idx, X, feature, threshold, left, right, is_leaf,
            length=length, **fb,
        )
    col = jnp.take(idx, unit, axis=1)
    col = forest_run_scanned(
        col, X, feature, threshold, left, right, is_leaf, length=length, **fb
    )
    new_idx = idx.at[:, unit].set(col)
    return new_idx, prob_accum(new_idx, probs, **fb)


@tuning.register_solo_impl("fused")
def _solo_fused(idx, X, feature, threshold, left, right, is_leaf,
                *, length, probs=None, unit=None, readout=False, **kw):
    """VMEM-resident fused kernel: the whole segment in ONE launch
    (:mod:`repro.kernels.forest_run`); degrades to ``scan`` when the
    tables exceed the VMEM budget."""
    M = feature.shape[0]
    probs_trees = probs.shape[0] if readout else 0
    C = probs.shape[2] if readout else 0
    if not _tables_fit(M, probs_trees=probs_trees, C=C,
                       onehot_rows=_block_rows(X.shape[0], kw)):
        return _solo_scan(
            idx, X, feature, threshold, left, right, is_leaf, length=length,
            probs=probs, unit=unit, readout=readout, **_fb_kw(kw),
        )
    interpret = resolve_interpret(kw.pop("interpret", None))
    bb = {k: v for k, v in kw.items() if k == "block_b"}
    fields = pack_fields(feature, threshold, left, right, is_leaf)
    if readout:
        return _fused.forest_run_readout(
            idx, X, fields, probs, unit, length=length, interpret=interpret,
            **bb,
        )
    return _fused.forest_run(
        idx, X, fields, length=length, interpret=interpret, **bb
    )


def forest_run(idx, X, feature, threshold, left, right, is_leaf,
               *, length, impl=None, **kw):
    """RLE-fused run: ``length`` consecutive steps of ONE tree for a
    batch, via the tuned (or explicitly pinned) solo implementation.

    ``idx`` is the stepped tree's index COLUMN (int32 [B]); ``length``
    must be static under jit — the step-plan buckets it to powers of two
    so at most log2(cap)+1 traces ever exist.
    """
    _check_kw(kw)
    Mp = round_up(max(feature.shape[0], 1), 128)
    fn, kw = _resolve("solo", tuning.solo_key(Mp, length), impl, kw, _SOLO_KW)
    return fn(idx, X, feature, threshold, left, right, is_leaf,
              length=length, **kw)


def forest_run_readout(
    idx, X, feature, threshold, left, right, is_leaf, probs, unit,
    *, length, impl=None, **kw,
):
    """Fused run + boundary read-out: advance ``unit``'s column of the
    FULL index array ``idx`` [B, T] by ``length`` steps and return
    ``(new_idx, readout [B, C])`` — one launch on the fused impl, a
    scan + ``prob_accum`` pair on the streamed one.
    """
    _check_kw(kw)
    Mp = round_up(max(feature.shape[0], 1), 128)
    fn, kw = _resolve("solo", tuning.solo_key(Mp, length), impl, kw, _SOLO_KW)
    return fn(idx, X, feature, threshold, left, right, is_leaf,
              length=length, probs=probs, unit=unit, readout=True, **kw)


def forest_run_depth(idx, X, layout, unit, *, length, start_step=0,
                     levels=None, **kw):
    """Depth-aware gather-eliminated run over a precomputed
    :class:`~repro.kernels.layout.DepthLayout`.

    ``idx`` [B] and the result are in the ORIGINAL node space — the
    wrapper converts through the layout's permutations around the
    kernel.  Only sound when every walker of ``unit`` has taken at most
    ``start_step`` steps (both must be host ints; the executor restricts
    the variant to fresh offset-0 segments).  ``levels`` caps how many
    leading steps unroll narrow (None = as many as stay below full
    width).  Falls back to the streamed scan over the permuted tables
    when the VMEM budget is exceeded.
    """
    _check_kw(kw)
    new_of_old = jnp.take(layout.new_of_old, unit, axis=0)
    old_of_new = jnp.take(layout.old_of_new, unit, axis=0)
    dcol = jnp.take(new_of_old, idx)
    if not _tables_fit(layout.M, onehot_rows=_block_rows(X.shape[0], kw)):
        feature, threshold, left, right, is_leaf = (
            jnp.take(t, unit, axis=0) for t in layout.tables
        )
        out = forest_run_scanned(
            dcol, X, feature, threshold, left, right, is_leaf,
            length=length, **_fb_kw(kw),
        )
        return jnp.take(old_of_new, out)
    interpret = resolve_interpret(kw.pop("interpret", None))
    widths = layout.step_widths(start_step, length, levels=levels)
    fields = jnp.take(layout.fields, unit, axis=0)
    out = _depth.depth_run(
        dcol, X, fields, widths=widths, length=length, interpret=interpret,
        **{k: v for k, v in kw.items() if k == "block_b"},
    )
    return jnp.take(old_of_new, out)


# --------------------------------------------------------------------------
# slot path: per-slot tree ids + live mask, index rows [S, T]
# --------------------------------------------------------------------------

def _tree_tables(feature, threshold, left, right, is_leaf):
    """Stacked per-tree tables [T, M] -> padded field tiles
    [T, Mp, NFIELDS], every tree through the shared pad_fields
    invariant."""
    return jax.vmap(
        lambda *tree: pad_fields(pack_fields(*tree))
    )(feature, threshold, left, right, is_leaf)


def _flat_tables(feature, threshold, left, right, is_leaf):
    """Stacked per-tree tables [T, M] -> resident flat fields
    [T*Mp, NFIELDS] (row ``t*Mp + m`` = node m of tree t)."""
    padded = _tree_tables(feature, threshold, left, right, is_leaf)
    T, Mp, _ = padded.shape
    return padded.reshape(T * Mp, NFIELDS), Mp


def bucketize_slots(units):
    """Tree-id bucketization of a slot batch: the stable permutation
    that groups slots by their stepped tree, plus its inverse.

    Dispatching on ``perm``-reordered rows gives every slot tile gather
    coherence (few distinct trees per tile) for the bucketized kernel;
    ``inv`` restores the scheduler's slot order afterwards.  Pure
    in-graph (``argsort`` is stable) — safe under jit with traced units.
    """
    perm = jnp.argsort(units)
    inv = jnp.argsort(perm)
    return perm, inv


@tuning.register_slot_impl("gather")
def _slot_gather(idx, X, feature, threshold, left, right, is_leaf,
                 units, mask, *, length, probs=None, readout=False, **kw):
    """PR-3 generic jnp gather — the conservative baseline every other
    slot impl must beat to be selected.  No residency requirement."""
    new_idx = ref.slot_run_ref(
        idx, X, feature, threshold, left, right, is_leaf, units, mask,
        length=length,
    )
    if not readout:
        return new_idx
    return new_idx, prob_accum(new_idx, probs, **_fb_kw(kw))


@tuning.register_slot_impl("flat")
def _slot_flat(idx, X, feature, threshold, left, right, is_leaf,
               units, mask, *, length, probs=None, readout=False, **kw):
    """PR-4 flat kernel: the WHOLE forest's tables resident as one
    [T*Mp, NFIELDS] matrix, per-slot gathers as one-hot MXU
    contractions; degrades to ``gather`` over the VMEM budget."""
    T, M = feature.shape
    probs_trees = T if readout else 0
    C = probs.shape[2] if readout else 0
    if not _tables_fit(M, field_trees=T, probs_trees=probs_trees, C=C,
                       onehot_rows=_block_rows(X.shape[0], kw)):
        return _slot_gather(
            idx, X, feature, threshold, left, right, is_leaf, units, mask,
            length=length, probs=probs, readout=readout, **_fb_kw(kw),
        )
    interpret = resolve_interpret(kw.pop("interpret", None))
    fields, Mp = _flat_tables(feature, threshold, left, right, is_leaf)
    if readout:
        probs_flat = _fused.flatten_probs(probs, Mp)
        return _slots.slot_run_readout(
            idx, X, fields, probs_flat, units, mask, mp=Mp, length=length,
            interpret=interpret, **_slot_kw(kw),
        )
    return _slots.slot_run(
        idx, X, fields, units, mask, mp=Mp, length=length,
        interpret=interpret, **_slot_kw(kw),
    )


@tuning.register_slot_impl("bucket")
def _slot_bucket(idx, X, feature, threshold, left, right, is_leaf,
                 units, mask, *, length, probs=None, readout=False, **kw):
    """Tree-bucketized kernel: the grid streams ONE tree's [Mp, NFIELDS]
    tile per step (:mod:`repro.kernels.slot_bucket`), dropping the
    per-slot one-hot width by a factor of T and the residency need to a
    single tree — the budget check is per TREE, so it serves forests the
    flat kernel must refuse."""
    T, M = feature.shape
    probs_trees = 1 if readout else 0
    C = probs.shape[2] if readout else 0
    if not _tables_fit(M, field_trees=1, probs_trees=probs_trees, C=C,
                       onehot_rows=_block_rows(X.shape[0], kw)):
        return _slot_gather(
            idx, X, feature, threshold, left, right, is_leaf, units, mask,
            length=length, probs=probs, readout=readout, **_fb_kw(kw),
        )
    interpret = resolve_interpret(kw.pop("interpret", None))
    tiles = _tree_tables(feature, threshold, left, right, is_leaf)
    Mp = tiles.shape[1]
    if readout:
        probs_p = jnp.pad(
            probs.astype(jnp.float32), ((0, 0), (0, Mp - M), (0, 0))
        )
        return _bucket.slot_bucket_run_readout(
            idx, X, tiles, probs_p, units, mask, length=length,
            interpret=interpret, **_slot_kw(kw),
        )
    return _bucket.slot_bucket_run(
        idx, X, tiles, units, mask, length=length, interpret=interpret,
        **_slot_kw(kw),
    )


@tuning.register_slot_impl("cached")
def _slot_cached(idx, X, feature, threshold, left, right, is_leaf,
                 units, mask, *, length, probs=None, readout=False, **kw):
    """Flat kernel + hot subtree-top cache: steps where every live node
    id is below ``top_rows`` contract against a compacted small top
    table instead of the full flat tables (the fast path HITS when the
    tables are depth-ordered — shallow nodes get small ids).  Readout
    rides a second ``prob_accum`` dispatch; degrades to ``gather`` over
    the VMEM budget."""
    T, M = feature.shape
    if not _tables_fit(M, field_trees=T,
                       onehot_rows=_block_rows(X.shape[0], kw)):
        return _slot_gather(
            idx, X, feature, threshold, left, right, is_leaf, units, mask,
            length=length, probs=probs, readout=readout, **_fb_kw(kw),
        )
    interpret = resolve_interpret(kw.pop("interpret", None))
    top_rows = int(kw.pop("top_rows", DEFAULT_TOP_ROWS))
    tiles = _tree_tables(feature, threshold, left, right, is_leaf)
    Mp = tiles.shape[1]
    top_rows = max(8, min(top_rows, Mp))
    fields = tiles.reshape(T * Mp, NFIELDS)
    top = tiles[:, :top_rows, :].reshape(T * top_rows, NFIELDS)
    new_idx = _slots.slot_run_cached(
        idx, X, fields, top, units, mask, mp=Mp, top_rows=top_rows,
        length=length, interpret=interpret, **_slot_kw(kw),
    )
    if not readout:
        return new_idx
    return new_idx, prob_accum(new_idx, probs, interpret=interpret)


def slot_run(
    idx, X, feature, threshold, left, right, is_leaf, units, mask,
    *, length, impl=None, **kw,
):
    """Masked-slot fused run: slot s advances its OWN tree ``units[s]``
    for ``length`` steps (``mask[s]`` False = frozen), via the tuned (or
    explicitly pinned) slot implementation.

    Selection is conservative: with no tuning entry for this platform
    and shape the generic ``gather`` runs — a kernel is only dispatched
    where the committed record says it measured faster.
    """
    _check_kw(kw, _SLOT_ALLOWED_KW)
    T, M = feature.shape
    key = tuning.slot_key(T, round_up(max(M, 1), 128), length)
    fn, kw = _resolve("slot", key, impl, kw, _SLOT_ALLOWED_KW)
    return fn(idx, X, feature, threshold, left, right, is_leaf, units, mask,
              length=length, **kw)


def slot_run_readout(
    idx, X, feature, threshold, left, right, is_leaf, probs, units, mask,
    *, length, impl=None, **kw,
):
    """Fused masked run + boundary read-out for the serving loop:
    returns ``(new_idx [S, T], readout [S, C])`` — one launch on the
    fused impls, a run + ``prob_accum`` pair on the others."""
    _check_kw(kw, _SLOT_ALLOWED_KW)
    T, M = feature.shape
    key = tuning.slot_key(T, round_up(max(M, 1), 128), length)
    fn, kw = _resolve("slot", key, impl, kw, _SLOT_ALLOWED_KW)
    return fn(idx, X, feature, threshold, left, right, is_leaf, units, mask,
              length=length, probs=probs, readout=True, **kw)


def prob_accum(idx, probs, **kw):
    """Anytime prediction read-out (see kernels.prob_accum)."""
    kw["interpret"] = resolve_interpret(kw.pop("interpret", None))
    return _prob_accum(idx, probs, **kw)


# Re-export oracles so callers can opt into the pure-jnp path explicitly.
forest_step_ref = ref.forest_step_ref
forest_run_ref = ref.forest_run_ref
slot_run_ref = ref.slot_run_ref
prob_accum_ref = ref.prob_accum_ref
