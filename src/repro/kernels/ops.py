"""Public jit'd wrappers around the Pallas kernels.

On a real TPU these dispatch to the compiled Mosaic kernels; on CPU (this
container) they run in interpret mode, which executes the same kernel
body element-for-element — the mode the test suite validates against the
ref.py oracles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref  # noqa: F401  (oracles re-exported below)
from repro.kernels.forest_step import forest_step as _forest_step
from repro.kernels.prob_accum import prob_accum as _prob_accum


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def forest_step(idx, X, feature, threshold, left, right, is_leaf, **kw):
    """Batched anytime step (see kernels.forest_step)."""
    interpret = kw.pop("interpret", not _on_tpu())
    return _forest_step(
        idx, X, feature, threshold, left, right, is_leaf,
        interpret=interpret, **kw,
    )


def forest_run(idx, X, feature, threshold, left, right, is_leaf, *, length, **kw):
    """RLE-fused run: ``length`` consecutive steps of ONE tree for a batch,
    scanned over the Pallas step kernel in a single dispatch.

    idx here is the stepped tree's index COLUMN (int32 [B]); ``length``
    must be static under jit — the step-plan buckets it to powers of two
    so at most log2(cap)+1 traces ever exist.
    """
    interpret = kw.pop("interpret", not _on_tpu())

    def body(col, _):
        col = _forest_step(
            col, X, feature, threshold, left, right, is_leaf,
            interpret=interpret, **kw,
        )
        return col, None

    return jax.lax.scan(body, idx, None, length=length)[0]


def prob_accum(idx, probs, **kw):
    """Anytime prediction read-out (see kernels.prob_accum)."""
    interpret = kw.pop("interpret", not _on_tpu())
    return _prob_accum(idx, probs, interpret=interpret, **kw)


# Re-export oracles so callers can opt into the pure-jnp path explicitly.
forest_step_ref = ref.forest_step_ref
prob_accum_ref = ref.prob_accum_ref
