"""Public jit'd wrappers around the Pallas kernels — the kernel-resident
execution core.

On a real TPU these dispatch compiled Mosaic kernels; on CPU (this
container) they run in interpret mode, which executes the same kernel
body element-for-element — the mode the test suite validates against the
ref.py oracles.  Interpret-mode selection lives in ONE place
(:func:`repro.kernels.common.resolve_interpret`) so it cannot drift
between kernels.

Entry points, by execution shape:

* :func:`forest_step` — one step of one tree (the PR-2 latency kernel).
* :func:`forest_run` — L fused steps of one tree in ONE launch, node
  tables resident in VMEM across the whole segment
  (:mod:`repro.kernels.forest_run`); falls back to
  :func:`forest_run_scanned` when the tables exceed the VMEM budget.
* :func:`forest_run_readout` — same launch, plus the full anytime
  read-out of the resulting state (segment-boundary fusion).
* :func:`slot_run` / :func:`slot_run_readout` — the masked-slot
  variants (:mod:`repro.kernels.slot_run`): per-slot tree ids + live
  mask, flattened whole-forest tables resident in VMEM — the serving
  hot path on the MXU; generic-gather fallback over the same budget.
* :func:`prob_accum` — the standalone read-out kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import ref  # noqa: F401  (oracles re-exported below)
from repro.kernels import forest_run as _fused
from repro.kernels import slot_run as _slots
from repro.kernels.common import (
    NFIELDS,
    on_tpu,
    pack_fields,
    pad_fields,
    resolve_interpret,
    round_up,
)
from repro.kernels.forest_step import forest_step as _forest_step
from repro.kernels.prob_accum import prob_accum as _prob_accum

#: Soft cap on the VMEM-resident table footprint of the fused kernels.
#: Above it the wrappers fall back to the streamed/generic paths — the
#: fused kernels trade M-tiling for residency, so arbitrarily large
#: forests must not be forced through them.  ~4 MiB leaves headroom in a
#: 16 MiB VMEM for the batch tile, one-hot blocks, and double buffering.
VMEM_TABLE_BUDGET_BYTES = 4 * 2**20


def _on_tpu() -> bool:  # retained alias: single source is common.on_tpu
    return on_tpu()


def forest_step(idx, X, feature, threshold, left, right, is_leaf, **kw):
    """Batched anytime step (see kernels.forest_step)."""
    kw["interpret"] = resolve_interpret(kw.pop("interpret", None))
    return _forest_step(idx, X, feature, threshold, left, right, is_leaf, **kw)


def forest_run_scanned(
    idx, X, feature, threshold, left, right, is_leaf, *, length, **kw
):
    """Legacy multi-step path: ``length`` launches of the single-step
    kernel under one ``lax.scan``.  Kept as the streaming fallback for
    forests whose tables exceed the VMEM budget, and as the baseline the
    fused-vs-scan benchmark gate compares against."""
    kw["interpret"] = resolve_interpret(kw.pop("interpret", None))

    def body(col, _):
        col = _forest_step(
            col, X, feature, threshold, left, right, is_leaf, **kw
        )
        return col, None

    return jax.lax.scan(body, idx, None, length=length)[0]


def _tables_fit(M: int, *, field_trees: int = 1, probs_trees: int = 0,
                C: int = 0, onehot_rows: int = 0) -> bool:
    """Does the kernel's VMEM footprint fit the budget?

    Counts what the target kernel actually holds: ``field_trees``
    trees' [Mp, NFIELDS] field matrices, (for the fused-readout
    variants) ``probs_trees`` trees' [Mp, C] probability tiles, and —
    the dominant term for wide trees — the per-step one-hot matmul
    operand ``[onehot_rows, field_trees*Mp]`` the gather materializes.
    All f32.  Exact accounting both ways: the fused paths are not
    disabled for forests that fit, and wide forests whose one-hot
    would blow VMEM on a real TPU fall back to the streamed/generic
    paths instead of failing Mosaic compilation.
    """
    Mp = round_up(max(M, 1), 128)
    resident = (field_trees * Mp * NFIELDS + probs_trees * Mp * C
                + onehot_rows * field_trees * Mp) * 4
    return resident <= VMEM_TABLE_BUDGET_BYTES


def _block_rows(n_rows: int, kw: dict, default: int = 256) -> int:
    """The batch/slot tile height the kernel will actually use (the
    wrappers clamp the block to the padded row count; for the slot
    kernels an explicit block_s wins, mirroring _slot_kw)."""
    rows = kw.get("block_s", kw.get("block_b", default))
    return min(int(rows), max(8, int(n_rows)))


_SOLO_KW = frozenset({"block_b", "block_m", "interpret"})
_SLOT_ALLOWED_KW = _SOLO_KW | {"block_s"}


def _check_kw(kw: dict, allowed: frozenset = _SOLO_KW) -> None:
    """Reject tuning kwargs the target path cannot honor — eagerly and
    identically on both sides of the VMEM budget, never silently
    swallowed (block_s is slot-only; the solo wrappers reject it)."""
    unknown = set(kw) - allowed
    if unknown:
        raise TypeError(f"unknown kernel option(s): {sorted(unknown)}")


def _fb_kw(kw: dict) -> dict:
    """Kwargs for the scan/prob_accum fallback paths."""
    return {k: v for k, v in kw.items()
            if k in ("block_b", "block_m", "interpret")}


def _slot_kw(kw: dict) -> dict:
    """Kwargs for the slot kernels: callers tune the slot tile via
    either name; an explicit block_s wins over a translated block_b."""
    out = {}
    if "block_b" in kw:
        out["block_s"] = kw["block_b"]
    if "block_s" in kw:
        out["block_s"] = kw["block_s"]
    return out


def forest_run(idx, X, feature, threshold, left, right, is_leaf, *, length, **kw):
    """RLE-fused run: ``length`` consecutive steps of ONE tree for a
    batch in a single kernel launch with VMEM-resident node tables.

    ``idx`` is the stepped tree's index COLUMN (int32 [B]); ``length``
    must be static under jit — the step-plan buckets it to powers of two
    so at most log2(cap)+1 traces ever exist.  Falls back to the
    streamed single-step scan when the tree exceeds the VMEM budget.
    """
    _check_kw(kw)
    if not _tables_fit(feature.shape[0],
                       onehot_rows=_block_rows(X.shape[0], kw)):
        return forest_run_scanned(
            idx, X, feature, threshold, left, right, is_leaf,
            length=length, **_fb_kw(kw),
        )
    interpret = resolve_interpret(kw.pop("interpret", None))
    fields = pack_fields(feature, threshold, left, right, is_leaf)
    return _fused.forest_run(
        idx, X, fields, length=length, interpret=interpret,
        **{k: v for k, v in kw.items() if k == "block_b"},
    )


def forest_run_readout(
    idx, X, feature, threshold, left, right, is_leaf, probs, unit,
    *, length, **kw,
):
    """Fused run + boundary read-out: advance ``unit``'s column of the
    FULL index array ``idx`` [B, T] by ``length`` steps and return
    ``(new_idx, readout [B, C])`` from ONE launch.  Falls back to
    scan + :func:`prob_accum` (two dispatches) over the VMEM budget.
    """
    _check_kw(kw)
    M = feature.shape[0]
    if not _tables_fit(M, probs_trees=probs.shape[0], C=probs.shape[2],
                       onehot_rows=_block_rows(X.shape[0], kw)):
        fb = _fb_kw(kw)
        col = jnp.take(idx, unit, axis=1)
        col = forest_run_scanned(
            col, X, feature, threshold, left, right, is_leaf,
            length=length, **fb,
        )
        new_idx = idx.at[:, unit].set(col)
        return new_idx, prob_accum(new_idx, probs, **fb)
    interpret = resolve_interpret(kw.pop("interpret", None))
    fields = pack_fields(feature, threshold, left, right, is_leaf)
    return _fused.forest_run_readout(
        idx, X, fields, probs, unit, length=length, interpret=interpret,
        **{k: v for k, v in kw.items() if k == "block_b"},
    )


def _flat_tables(feature, threshold, left, right, is_leaf):
    """Stacked per-tree tables [T, M] -> resident flat fields [T*Mp, NF],
    every tree's tile through the shared pad_fields invariant."""
    T = feature.shape[0]
    padded = jax.vmap(
        lambda *tree: pad_fields(pack_fields(*tree))
    )(feature, threshold, left, right, is_leaf)
    Mp = padded.shape[1]
    return padded.reshape(T * Mp, NFIELDS), Mp


def slot_run(
    idx, X, feature, threshold, left, right, is_leaf, units, mask,
    *, length, **kw,
):
    """Masked-slot fused run: slot s advances its OWN tree ``units[s]``
    for ``length`` steps in one launch (``mask[s]`` False = frozen).

    Tables for the WHOLE forest flatten into one VMEM-resident field
    matrix, so the per-slot (tree, node) double gather is a single
    one-hot MXU contraction.  Generic-gather fallback over the budget.
    """
    _check_kw(kw, _SLOT_ALLOWED_KW)
    T, M = feature.shape
    if not _tables_fit(M, field_trees=T,
                       onehot_rows=_block_rows(X.shape[0], kw)):
        return ref.slot_run_ref(
            idx, X, feature, threshold, left, right, is_leaf, units, mask,
            length=length,
        )
    interpret = resolve_interpret(kw.pop("interpret", None))
    fields, Mp = _flat_tables(feature, threshold, left, right, is_leaf)
    return _slots.slot_run(
        idx, X, fields, units, mask, mp=Mp, length=length,
        interpret=interpret, **_slot_kw(kw),
    )


def slot_run_readout(
    idx, X, feature, threshold, left, right, is_leaf, probs, units, mask,
    *, length, **kw,
):
    """Fused masked run + boundary read-out for the serving loop: ONE
    launch returns ``(new_idx [S, T], readout [S, C])``."""
    _check_kw(kw, _SLOT_ALLOWED_KW)
    T, M = feature.shape
    if not _tables_fit(M, field_trees=T, probs_trees=T, C=probs.shape[2],
                       onehot_rows=_block_rows(X.shape[0], kw)):
        new_idx = ref.slot_run_ref(
            idx, X, feature, threshold, left, right, is_leaf, units, mask,
            length=length,
        )
        return new_idx, prob_accum(new_idx, probs, **_fb_kw(kw))
    interpret = resolve_interpret(kw.pop("interpret", None))
    fields, Mp = _flat_tables(feature, threshold, left, right, is_leaf)
    probs_flat = _fused.flatten_probs(probs, Mp)
    return _slots.slot_run_readout(
        idx, X, fields, probs_flat, units, mask, mp=Mp, length=length,
        interpret=interpret, **_slot_kw(kw),
    )


def prob_accum(idx, probs, **kw):
    """Anytime prediction read-out (see kernels.prob_accum)."""
    kw["interpret"] = resolve_interpret(kw.pop("interpret", None))
    return _prob_accum(idx, probs, **kw)


# Re-export oracles so callers can opt into the pure-jnp path explicitly.
forest_step_ref = ref.forest_step_ref
forest_run_ref = ref.forest_run_ref
slot_run_ref = ref.slot_run_ref
prob_accum_ref = ref.prob_accum_ref
