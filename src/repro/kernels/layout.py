"""Depth-ordered node layouts — the host-side precompute behind the
gather-eliminating kernel variants.

The PR-4 kernels gather node fields through a one-hot matmul against the
FULL ``[Mp, NFIELDS]`` table every step, no matter where the walk
actually is.  But anytime stepping starts every tree at its root, and
after ``s`` steps a walker can only be at a node whose BFS distance from
the root is ≤ ``s`` — for a binary tree that is at most ``2^(s+1) - 1``
nodes.  Gossen & Steffen ("Large Random Forests: Optimisation for Rapid
Evaluation") exploit exactly this: the shallow levels are served from
registers/caches while only deep levels touch the big table.

This module makes that bound usable by a Pallas kernel:

* :func:`bfs_depths` — BFS distance of every node from the root,
  following ``left``/``right`` of non-leaf nodes (unreachable nodes get
  a sentinel depth and sort to the end — they can never be visited, so
  excluding them from any gather is always safe);
* :class:`DepthLayout` — per-forest relabeling ``new = rank by (depth,
  id)`` with both permutations mirrored on device, the permuted packed
  field matrices, and the static per-step prefix *widths* the kernels
  unroll against (``step_widths``).  Because nodes are depth-sorted, all
  nodes reachable within ``s`` steps occupy a PREFIX of the table —
  the step-``s`` gather narrows from ``Mp`` rows to ``counts(s)`` rows.

Widths are host-side Python ints (static under jit), computed from the
concrete tables at executor-build time; ``complete_tree_width`` is the
data-independent upper bound (``2^(s+1) - 1``) the analytical counters
in ``tools/perf`` use — real layouts are never wider.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.common import NFIELDS, pack_fields, pad_fields, round_up

#: sublane granularity the narrow gather widths round up to
WIDTH_LANES = 8


def bfs_depths(left: np.ndarray, right: np.ndarray, is_leaf: np.ndarray) -> np.ndarray:
    """BFS distance from node 0 for one tree's tables ([M] each).

    Leaves self-loop (no out-edges); nodes unreachable from the root get
    depth ``M`` (beyond any real walk, so they sort after every
    reachable node and never widen a prefix).
    """
    M = int(left.shape[0])
    left = np.asarray(left)
    right = np.asarray(right)
    is_leaf = np.asarray(is_leaf).astype(bool)
    dist = np.full(M, M, dtype=np.int64)
    dist[0] = 0
    frontier = [0]
    d = 0
    while frontier:
        nxt = []
        for n in frontier:
            if is_leaf[n]:
                continue
            for c in (int(left[n]), int(right[n])):
                if 0 <= c < M and dist[c] > d + 1:
                    dist[c] = d + 1
                    nxt.append(c)
        frontier = nxt
        d += 1
    return dist


def complete_tree_width(step: int, m_padded: int, lanes: int = WIDTH_LANES) -> int:
    """Data-independent upper bound on the step-``step`` gather width:
    a binary tree reaches at most ``2^(step+1) - 1`` nodes in ``step``
    steps.  Shared with ``tools.perf.counters`` (cross-checked by test)."""
    reachable = (1 << (step + 1)) - 1 if step < 62 else m_padded
    return min(m_padded, round_up(min(reachable, m_padded), lanes))


@dataclasses.dataclass(frozen=True, eq=False)
class DepthLayout:
    """Depth-ordered relabeling of a whole forest's node tables.

    Node ``m`` of tree ``t`` gets new id ``new_of_old[t, m]``; all
    arrays below live in the NEW space.  ``fields`` stacks each tree's
    permuted, padded ``[Mp, NFIELDS]`` field matrix; ``tables`` are the
    permuted raw tables (for the streamed scan fallback).  ``counts`` is
    the host-side per-depth prefix histogram behind :meth:`step_widths`.
    """

    fields: jax.Array          # f32   [T, Mp, NFIELDS] permuted + padded
    tables: tuple              # permuted raw (feature, thr, left, right, leaf), [T, M]
    old_of_new: jax.Array      # int32 [T, M]  new id -> original id
    new_of_old: jax.Array      # int32 [T, M]  original id -> new id
    counts: np.ndarray         # int64 [max_depth+1] forest-max nodes at depth <= d
    M: int
    Mp: int

    @property
    def n_trees(self) -> int:
        return int(self.fields.shape[0])

    def flat_fields(self) -> jax.Array:
        """[T*Mp, NFIELDS] — the slot kernels' flat-table layout, in
        depth order (row ``t*Mp + new_id``)."""
        T, Mp, _ = self.fields.shape
        return self.fields.reshape(T * Mp, NFIELDS)

    def top_fields(self, rows: int) -> jax.Array:
        """[T*rows, NFIELDS] compacted hot subtree tops: the first
        ``rows`` depth-ordered rows of every tree, contiguously — the
        small resident table the cached slot kernel hits when every
        live walker is still shallow."""
        rows = min(int(rows), self.Mp)
        T = self.n_trees
        return self.fields[:, :rows, :].reshape(T * rows, NFIELDS)

    def max_count(self, depth: int) -> int:
        """Forest-wide max #nodes within BFS distance ``depth``."""
        d = min(int(depth), len(self.counts) - 1)
        return int(self.counts[d])

    def step_widths(
        self,
        start_step: int,
        length: int,
        levels: int | None = None,
        lanes: int = WIDTH_LANES,
    ) -> tuple[int, ...]:
        """Static narrow-gather widths for steps ``start_step ..``.

        Entry ``j`` bounds the gather at kernel step ``j`` given that
        the walk has taken ``start_step + j`` steps from the root.  The
        tuple stops at the first full-width step (the kernel's
        ``fori_loop`` tail covers the rest) and is capped at ``levels``
        unrolled steps.  Every width is lane-rounded and ≤ the
        data-independent :func:`complete_tree_width` bound.
        """
        n = length if levels is None else min(int(levels), length)
        widths = []
        for j in range(n):
            w = round_up(max(self.max_count(start_step + j), 1), lanes)
            if w >= self.Mp:
                break
            widths.append(w)
        return tuple(widths)


def build_depth_layout(feature, threshold, left, right, is_leaf) -> DepthLayout:
    """Depth-order a forest's stacked ``[T, M]`` tables (host-side —
    requires CONCRETE arrays, so call it at executor/bench build time,
    never under jit)."""
    feature = np.asarray(feature)
    threshold = np.asarray(threshold)
    left = np.asarray(left)
    right = np.asarray(right)
    is_leaf = np.asarray(is_leaf)
    if feature.ndim == 1:  # single tree -> T=1 forest
        feature, threshold, left, right, is_leaf = (
            a[None] for a in (feature, threshold, left, right, is_leaf)
        )
    T, M = feature.shape
    Mp = round_up(max(M, 1), 128)

    perms, invs, dists = [], [], []
    for t in range(T):
        dist = bfs_depths(left[t], right[t], is_leaf[t])
        perm = np.argsort(dist, kind="stable")          # new -> old
        inv = np.empty(M, dtype=np.int64)
        inv[perm] = np.arange(M)
        perms.append(perm)
        invs.append(inv)
        dists.append(dist)
    perm = np.stack(perms)                              # [T, M]
    inv = np.stack(invs)
    dist = np.stack(dists)

    # permuted raw tables: row new_id holds old node perm[new_id], with
    # child pointers rewritten into the new space
    t_ids = np.arange(T)[:, None]
    p_feature = feature[t_ids, perm]
    p_threshold = threshold[t_ids, perm]
    p_left = np.take_along_axis(inv, left[t_ids, perm], axis=1)
    p_right = np.take_along_axis(inv, right[t_ids, perm], axis=1)
    p_leaf = is_leaf[t_ids, perm]

    fields = jax.vmap(lambda *tree: pad_fields(pack_fields(*tree)))(
        jnp.asarray(p_feature, jnp.int32),
        jnp.asarray(p_threshold, jnp.float32),
        jnp.asarray(p_left, jnp.int32),
        jnp.asarray(p_right, jnp.int32),
        jnp.asarray(p_leaf),
    )

    # forest-max prefix histogram: counts[d] = max_t #nodes(dist_t <= d)
    reach = np.where(dist >= M, M, dist)                # sentinel stays M
    max_d = int(reach[reach < M].max(initial=0))
    counts = np.zeros(max_d + 1, dtype=np.int64)
    for d in range(max_d + 1):
        counts[d] = int((reach <= d).sum(axis=1).max())

    return DepthLayout(
        fields=fields,
        tables=(
            jnp.asarray(p_feature, jnp.int32),
            jnp.asarray(p_threshold, jnp.float32),
            jnp.asarray(p_left, jnp.int32),
            jnp.asarray(p_right, jnp.int32),
            jnp.asarray(p_leaf),
        ),
        old_of_new=jnp.asarray(perm, jnp.int32),
        new_of_old=jnp.asarray(inv, jnp.int32),
        counts=counts,
        M=M,
        Mp=Mp,
    )
