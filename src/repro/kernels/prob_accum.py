"""Pallas TPU kernel: anytime prediction read-out (probability accumulation).

Computes out[b] = sum_t probs[t, idx[b, t]] — the Sec. III-C combined
prediction from an index-array state.  This is BOTH the abort-time
read-out of serving AND the inner loop of order generation (every state
accuracy the Optimal/Squirrel generators evaluate is one such read-out),
so it is the throughput hot spot of the paper's offline phase.

TPU mapping: the per-tree gather probs[t, idx[:, t]] becomes a one-hot
[Bb, M] x probs[t] [M, C] matmul — a pure MXU contraction — accumulated
over the tree axis on the grid's sequential dimension.  M is tiled as
well so wide trees stream through VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import CompilerParams as _CompilerParams


def _prob_accum_kernel(
    idx_ref,    # int32 [Bb, 1]     idx[:, t] column for this grid t
    probs_ref,  # f32   [1, Mb, C]  probs[t] tile
    out_ref,    # f32   [Bb, C]
    *,
    block_m: int,
):
    t = pl.program_id(1)
    m_blk = pl.program_id(2)

    @pl.when(jnp.logical_and(t == 0, m_blk == 0))
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    idx = idx_ref[:, 0]                                    # [Bb]
    m_base = m_blk * block_m
    m_ids = m_base + jax.lax.broadcasted_iota(jnp.int32, (1, block_m), 1)
    onehot = (idx[:, None] == m_ids).astype(jnp.float32)   # [Bb, Mb]
    out_ref[...] += jax.lax.dot(
        onehot, probs_ref[0], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_b", "block_m", "interpret"))
def prob_accum(
    idx: jax.Array,    # int32 [B, T]
    probs: jax.Array,  # f32   [T, M, C]
    *,
    block_b: int = 256,
    block_m: int = 512,
    interpret: bool = False,
) -> jax.Array:
    """Anytime read-out: [B, C] class-score sums over all trees."""
    B, T = idx.shape
    _, M, C = probs.shape
    block_b = min(block_b, max(8, B))
    block_m = min(block_m, M)
    Bp = -(-B // block_b) * block_b
    Mp = -(-M // block_m) * block_m
    idx_p = jnp.pad(idx, ((0, Bp - B), (0, 0)))
    probs_p = jnp.pad(probs.astype(jnp.float32), ((0, 0), (0, Mp - M), (0, 0)))

    n_b, n_m = Bp // block_b, Mp // block_m
    out = pl.pallas_call(
        functools.partial(_prob_accum_kernel, block_m=block_m),
        grid=(n_b, T, n_m),
        in_specs=[
            pl.BlockSpec((block_b, 1), lambda b, t, m: (b, t)),
            pl.BlockSpec((1, block_m, C), lambda b, t, m: (t, m, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, C), lambda b, t, m: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((Bp, C), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(idx_p, probs_p)
    return out[:B]
