"""Pallas TPU kernel: fused masked-slot run (the serving hot path).

The slot-batched scheduler (:mod:`repro.serve`) advances, per dispatch,
every live *slot* by L steps of its OWN tree — per-slot tree ids defeat
the single-tree table gather the solo kernels tile for, which is why
``run_slots`` historically fell back to the generic jnp gather on every
backend (ROADMAP open item 2).  This kernel puts that path on the MXU:

  * the whole forest's node tables flatten to ONE field matrix
    ``[T*Mp, NFIELDS]`` resident in VMEM, where row ``t*Mp + m`` holds
    node m of tree t — a per-slot (tree, node) gather becomes a single
    one-hot ``[Sb, T*Mp]`` matmul, no matter which tree each slot steps;
  * a kernel-internal ``fori_loop`` runs all L steps of the segment in
    one launch, tables resident throughout;
  * the live ``mask`` freezes empty/retired slots bit-exactly (their
    index rows pass through untouched), matching
    :func:`repro.core.engine.slot_run` element-for-element;
  * :func:`slot_run_readout` fuses the ``prob_accum`` boundary read-out
    into the same launch — the double-buffered serving loop's
    dispatch+readout pair becomes one kernel.

Residency tradeoff: no TM-tiling — the flattened tables (and, for the
readout variant, the flattened probabilities) must fit in VMEM;
:mod:`repro.kernels.ops` budget-checks and falls back to the generic
gather for oversized forests.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.common import (
    F_IDX,
    LEAF,
    LEFT,
    NFIELDS,
    RIGHT,
    THR,
    CompilerParams,
    accum_boundary_readout,
    round_up,
)


def _slot_loop(idx, x, units, live, fields, *, length, block_m, n_trees):
    """The fused masked step loop shared by both kernel variants."""
    t_ids = jax.lax.broadcasted_iota(jnp.int32, idx.shape, 1)  # [Sb, T]
    sel = (t_ids == units[:, None]) & live[:, None]
    tm_ids = jax.lax.broadcasted_iota(jnp.int32, (1, n_trees * block_m), 1)
    f_cols = jax.lax.broadcasted_iota(jnp.float32, x.shape, 1)
    base = units * block_m                                     # [Sb]

    def body(_, idx):
        node = jnp.sum(jnp.where(sel, idx, 0), axis=1)         # idx[s, units[s]]
        onehot = ((base + node)[:, None] == tm_ids).astype(jnp.float32)
        acc = jax.lax.dot(onehot, fields, preferred_element_type=jnp.float32)
        f_onehot = (f_cols == acc[:, F_IDX][:, None]).astype(jnp.float32)
        fv = jnp.sum(x * f_onehot, axis=1)
        nxt = jnp.where(fv <= acc[:, THR], acc[:, LEFT], acc[:, RIGHT])
        new = jnp.where(acc[:, LEAF] > 0.5, node.astype(jnp.float32), nxt)
        return jnp.where(sel, new.astype(jnp.int32)[:, None], idx)

    return jax.lax.fori_loop(0, length, body, idx)


def _slot_run_kernel(
    idx_ref,     # int32 [Sb, T]   per-slot index rows
    x_ref,       # f32   [Sb, F]   per-slot input rows
    units_ref,   # int32 [Sb, 1]   per-slot stepped tree id
    mask_ref,    # int32 [Sb, 1]   1 = live, 0 = frozen
    fields_ref,  # f32   [T*Mp, NFIELDS]  resident flattened tables
    out_ref,     # int32 [Sb, T]
    *,
    length: int,
    block_m: int,
    n_trees: int,
):
    out_ref[...] = _slot_loop(
        idx_ref[...], x_ref[...], units_ref[:, 0], mask_ref[:, 0] > 0,
        fields_ref[...], length=length, block_m=block_m, n_trees=n_trees,
    )


def _slot_run_readout_kernel(
    idx_ref, x_ref, units_ref, mask_ref, fields_ref,
    probs_ref,   # f32 [T*Mp, C]  flattened per-tree probability tiles
    out_ref,
    ro_out,      # f32 [Sb, C]
    *,
    length: int,
    block_m: int,
    n_trees: int,
):
    new_idx = _slot_loop(
        idx_ref[...], x_ref[...], units_ref[:, 0], mask_ref[:, 0] > 0,
        fields_ref[...], length=length, block_m=block_m, n_trees=n_trees,
    )
    out_ref[...] = new_idx
    ro_out[...] = accum_boundary_readout(
        new_idx, probs_ref, block_m=block_m, n_trees=n_trees,
        n_classes=ro_out.shape[1],
    )


def _pad_slots(idx, X, units, mask, block_s):
    S = X.shape[0]
    Sp = round_up(S, block_s)
    pad = Sp - S
    return (
        jnp.pad(idx, ((0, pad), (0, 0))),
        jnp.pad(X, ((0, pad), (0, 0))),
        jnp.pad(units.astype(jnp.int32), (0, pad)).reshape(Sp, 1),
        # padded slots are dead: their index rows pass through untouched
        jnp.pad(mask.astype(jnp.int32), (0, pad)).reshape(Sp, 1),
        Sp,
    )


@functools.partial(jax.jit, static_argnames=("mp", "length", "block_s", "interpret"))
def slot_run(
    idx: jax.Array,     # int32 [S, T]
    X: jax.Array,       # f32   [S, F]
    fields: jax.Array,  # f32   [T*Mp, NFIELDS]  (ops flattens/pads)
    units: jax.Array,   # int32 [S]
    mask: jax.Array,    # bool  [S]
    *,
    mp: int,
    length: int,
    block_s: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """``length`` fused masked slot-steps in ONE launch; slot s advances
    tree ``units[s]`` (``mask[s]`` False = frozen).  ``mp`` is the
    padded per-tree row stride of ``fields``."""
    S, T = idx.shape
    F = X.shape[1]
    block_s = min(block_s, max(8, S))
    idx_p, x_p, units_p, mask_p, Sp = _pad_slots(idx, X, units, mask, block_s)
    TM = fields.shape[0]

    out = pl.pallas_call(
        functools.partial(
            _slot_run_kernel, length=length, block_m=mp, n_trees=T
        ),
        grid=(Sp // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, T), lambda s: (s, 0)),
            pl.BlockSpec((block_s, F), lambda s: (s, 0)),
            pl.BlockSpec((block_s, 1), lambda s: (s, 0)),
            pl.BlockSpec((block_s, 1), lambda s: (s, 0)),
            pl.BlockSpec((TM, NFIELDS), lambda s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, T), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((Sp, T), jnp.int32),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(idx_p, x_p, units_p, mask_p, fields)
    return out[:S]


def _slot_cached_loop(
    idx, x, units, live, fields, top, *, length, block_m, top_rows, n_trees
):
    """The flat masked step loop with a hot subtree-top fast path: per
    step, when EVERY live node id in the tile is below ``top_rows``, the
    gather contracts against the compacted ``[T*top_rows, NFIELDS]`` top
    table instead of the full ``[T*Mp, NFIELDS]`` flat table.

    ``top`` must hold rows ``0..top_rows-1`` of every tree's tile of
    ``fields`` (``DepthLayout.top_fields``), which makes the two
    branches bit-identical whenever the narrow one is taken — depth
    ordering is what makes the fast path HIT (shallow nodes get small
    ids), not what makes it correct.
    """
    t_ids = jax.lax.broadcasted_iota(jnp.int32, idx.shape, 1)  # [Sb, T]
    sel = (t_ids == units[:, None]) & live[:, None]
    tm_ids = jax.lax.broadcasted_iota(jnp.int32, (1, n_trees * block_m), 1)
    tr_ids = jax.lax.broadcasted_iota(jnp.int32, (1, n_trees * top_rows), 1)
    f_cols = jax.lax.broadcasted_iota(jnp.float32, x.shape, 1)
    base_full = units * block_m                                # [Sb]
    base_top = units * top_rows

    def body(_, idx):
        node = jnp.sum(jnp.where(sel, idx, 0), axis=1)         # idx[s, units[s]]
        shallow = jnp.max(jnp.where(live, node, 0)) < top_rows

        def narrow(_):
            onehot = ((base_top + node)[:, None] == tr_ids).astype(jnp.float32)
            return jax.lax.dot(onehot, top, preferred_element_type=jnp.float32)

        def wide(_):
            onehot = ((base_full + node)[:, None] == tm_ids).astype(jnp.float32)
            return jax.lax.dot(onehot, fields, preferred_element_type=jnp.float32)

        acc = jax.lax.cond(shallow, narrow, wide, None)
        f_onehot = (f_cols == acc[:, F_IDX][:, None]).astype(jnp.float32)
        fv = jnp.sum(x * f_onehot, axis=1)
        nxt = jnp.where(fv <= acc[:, THR], acc[:, LEFT], acc[:, RIGHT])
        new = jnp.where(acc[:, LEAF] > 0.5, node.astype(jnp.float32), nxt)
        return jnp.where(sel, new.astype(jnp.int32)[:, None], idx)

    return jax.lax.fori_loop(0, length, body, idx)


def _slot_cached_kernel(
    idx_ref, x_ref, units_ref, mask_ref,
    fields_ref,  # f32 [T*Mp, NFIELDS]  full flat tables
    top_ref,     # f32 [T*R, NFIELDS]   compacted depth-ordered tops
    out_ref,
    *,
    length: int,
    block_m: int,
    top_rows: int,
    n_trees: int,
):
    out_ref[...] = _slot_cached_loop(
        idx_ref[...], x_ref[...], units_ref[:, 0], mask_ref[:, 0] > 0,
        fields_ref[...], top_ref[...], length=length, block_m=block_m,
        top_rows=top_rows, n_trees=n_trees,
    )


@functools.partial(
    jax.jit, static_argnames=("mp", "top_rows", "length", "block_s", "interpret")
)
def slot_run_cached(
    idx: jax.Array,     # int32 [S, T]  (depth-ordered node space to hit)
    X: jax.Array,       # f32   [S, F]
    fields: jax.Array,  # f32   [T*Mp, NFIELDS]  flat depth-ordered tables
    top: jax.Array,     # f32   [T*R, NFIELDS]   DepthLayout.top_fields(R)
    units: jax.Array,   # int32 [S]
    mask: jax.Array,    # bool  [S]
    *,
    mp: int,
    top_rows: int,
    length: int,
    block_s: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Masked slot run with hot subtree-top caching: steps where every
    live walker is still shallow contract a T*``top_rows``-wide one-hot
    against the small resident top table instead of the full flat
    tables — the fresh segments of a slot batch never touch the deep
    rows at all."""
    S, T = idx.shape
    F = X.shape[1]
    block_s = min(block_s, max(8, S))
    idx_p, x_p, units_p, mask_p, Sp = _pad_slots(idx, X, units, mask, block_s)
    TM = fields.shape[0]
    TR = top.shape[0]

    out = pl.pallas_call(
        functools.partial(
            _slot_cached_kernel, length=length, block_m=mp,
            top_rows=top_rows, n_trees=T,
        ),
        grid=(Sp // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, T), lambda s: (s, 0)),
            pl.BlockSpec((block_s, F), lambda s: (s, 0)),
            pl.BlockSpec((block_s, 1), lambda s: (s, 0)),
            pl.BlockSpec((block_s, 1), lambda s: (s, 0)),
            pl.BlockSpec((TM, NFIELDS), lambda s: (0, 0)),
            pl.BlockSpec((TR, NFIELDS), lambda s: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_s, T), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((Sp, T), jnp.int32),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(idx_p, x_p, units_p, mask_p, fields, top)
    return out[:S]


@functools.partial(jax.jit, static_argnames=("mp", "length", "block_s", "interpret"))
def slot_run_readout(
    idx: jax.Array,
    X: jax.Array,
    fields: jax.Array,  # f32 [T*Mp, NFIELDS]
    probs: jax.Array,   # f32 [T*Mp, C]  (ops flattens/pads)
    units: jax.Array,
    mask: jax.Array,
    *,
    mp: int,
    length: int,
    block_s: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused masked run + boundary read-out in one launch: the advanced
    index rows AND the full anytime readout ``[S, C]`` of the new state
    (all slots, live or frozen — retirement bookkeeping is host-side)."""
    S, T = idx.shape
    F = X.shape[1]
    C = probs.shape[1]
    block_s = min(block_s, max(8, S))
    idx_p, x_p, units_p, mask_p, Sp = _pad_slots(idx, X, units, mask, block_s)
    TM = fields.shape[0]

    new_idx, ro = pl.pallas_call(
        functools.partial(
            _slot_run_readout_kernel, length=length, block_m=mp, n_trees=T
        ),
        grid=(Sp // block_s,),
        in_specs=[
            pl.BlockSpec((block_s, T), lambda s: (s, 0)),
            pl.BlockSpec((block_s, F), lambda s: (s, 0)),
            pl.BlockSpec((block_s, 1), lambda s: (s, 0)),
            pl.BlockSpec((block_s, 1), lambda s: (s, 0)),
            pl.BlockSpec((TM, NFIELDS), lambda s: (0, 0)),
            pl.BlockSpec((TM, C), lambda s: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_s, T), lambda s: (s, 0)),
            pl.BlockSpec((block_s, C), lambda s: (s, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Sp, T), jnp.int32),
            jax.ShapeDtypeStruct((Sp, C), jnp.float32),
        ],
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(idx_p, x_p, units_p, mask_p, fields, probs)
    return new_idx[:S], ro[:S]
