"""Pure-jnp oracles for the Pallas kernels.

Every kernel in this package is validated (tests/test_kernels.py) against
these references across shape/dtype sweeps in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def forest_step_ref(
    idx: jax.Array,        # int32 [B]   current node of the stepped tree
    X: jax.Array,          # f32   [B, F]
    feature: jax.Array,    # int32 [M]
    threshold: jax.Array,  # f32   [M]
    left: jax.Array,       # int32 [M]
    right: jax.Array,      # int32 [M]
    is_leaf: jax.Array,    # bool/int32 [M]
) -> jax.Array:
    """One anytime step of one tree for a batch of samples."""
    f = feature[idx]                                        # [B]
    thr = threshold[idx]
    fv = jnp.take_along_axis(X, f[:, None].astype(jnp.int32), axis=1)[:, 0]
    nxt = jnp.where(fv <= thr, left[idx], right[idx])
    return jnp.where(is_leaf[idx].astype(bool), idx, nxt).astype(jnp.int32)


def forest_run_ref(
    idx, X, feature, threshold, left, right, is_leaf, *, length: int
) -> jax.Array:
    """``length`` consecutive :func:`forest_step_ref` steps (the oracle
    for the fused multi-step kernel)."""

    def body(col, _):
        return forest_step_ref(
            col, X, feature, threshold, left, right, is_leaf
        ), None

    return jax.lax.scan(body, idx, None, length=length)[0]


def slot_step_ref(
    idx: jax.Array,        # int32 [S, T]  per-slot index rows
    X: jax.Array,          # f32   [S, F]  per-slot input rows
    feature: jax.Array,    # int32 [T, M]  stacked per-tree tables
    threshold: jax.Array,  # f32   [T, M]
    left: jax.Array,       # int32 [T, M]
    right: jax.Array,      # int32 [T, M]
    is_leaf: jax.Array,    # bool  [T, M]
    units: jax.Array,      # int32 [S]     per-slot stepped tree
    mask: jax.Array,       # bool  [S]     False = frozen slot
) -> jax.Array:
    """One masked slot-step: slot s advances tree ``units[s]`` (same
    arithmetic as :func:`repro.core.engine.slot_step`, on raw tables)."""
    s = jnp.arange(idx.shape[0])
    node = idx[s, units]
    f = feature[units, node]
    thr = threshold[units, node]
    fv = X[s, f.astype(jnp.int32)]
    nxt = jnp.where(fv <= thr, left[units, node], right[units, node])
    nxt = jnp.where(is_leaf[units, node].astype(bool), node, nxt)
    nxt = jnp.where(mask, nxt, node)
    return idx.at[s, units].set(nxt.astype(jnp.int32))


def slot_run_ref(
    idx, X, feature, threshold, left, right, is_leaf, units, mask,
    *, length: int,
) -> jax.Array:
    """``length`` fused masked slot-steps (the masked-slot kernel oracle)."""

    def body(i, _):
        return slot_step_ref(
            i, X, feature, threshold, left, right, is_leaf, units, mask
        ), None

    return jax.lax.scan(body, idx, None, length=length)[0]


def prob_accum_ref(idx: jax.Array, probs: jax.Array) -> jax.Array:
    """Anytime prediction read-out.

    idx: int32 [B, T]; probs: f32 [T, M, C] -> [B, C]
    out[b] = sum_t probs[t, idx[b, t]]
    """
    T = probs.shape[0]
    t_ids = jnp.arange(T)[None, :]
    return probs[t_ids, idx].sum(axis=1)


def state_scores_ref(path_probs: jax.Array, state: jax.Array) -> jax.Array:
    """Order-generation read-out: class scores of one forest state.

    path_probs: f32 [B, T, D1, C]; state: int32 [T] -> [B, C]
    out[b] = sum_t path_probs[b, t, state[t]]
    """
    T = path_probs.shape[1]
    t_ids = jnp.arange(T)
    return path_probs[:, t_ids, state].sum(axis=1)
