"""Pluggable execution backends for anytime forest serving.

The paper's anytime value proposition (Sec. V) only pays off if the
per-step overhead is negligible; this module makes the execution layer
a pluggable subsystem so the same :class:`~repro.schedule.runtime.Session`
surface can dispatch to whichever implementation the hardware rewards:

* ``jnp-ref``  — the pure-jnp ``engine.tree_step`` scan.  Kept as the
  bit-exactness oracle every other backend is parity-tested against.
* ``pallas``   — RLE-fused runs dispatched through the MXU-oriented
  Pallas kernels (:func:`repro.kernels.ops.forest_run` for stepping,
  :func:`repro.kernels.ops.prob_accum` for the read-out).  Interpret
  mode on CPU, compiled Mosaic on TPU.
* ``sharded``  — the batch axis placed on a ``launch/mesh.py`` mesh via
  ``batch_pspec``, so ONE runtime serves many concurrent deadline
  streams; the jit partitioner splits every segment scan across the
  mesh's batch shards.

Selection surface: ``AnytimeRuntime(program, backend="pallas")`` or
per-session ``runtime.session(X, policy, backend="sharded")``; with no
explicit choice, :func:`default_backend` picks by ``jax.default_backend()``.

**Step-plans.** Orders are compiled ONCE into a :class:`StepPlan`:
``check_order`` + ``rle_chunks`` lower the order into device arrays of
(unit, run-length) segments whose run lengths are bucketed to powers of
two.  ``advance``/``advance_until`` then execute under a handful of
cached jit traces (one per distinct power-of-two length, ≤
``log2(max_segment)+1`` ≈ 7) instead of one compilation per distinct
run length — mid-chunk splits decompose into the SAME power-of-two
buckets, so arbitrary deadline-driven advance patterns never mint new
traces.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.kernels import ops as kops
from repro.launch import mesh as mesh_lib


def check_order(order: np.ndarray, n_units: int, unit_steps: int) -> np.ndarray:
    """Validate a step order, raising a ValueError that names the first
    offending unit (unlike a bare assert, this survives ``python -O``)."""
    order = np.asarray(order)
    expect = n_units * unit_steps
    if order.shape[0] != expect:
        raise ValueError(
            f"invalid step order: length {order.shape[0]}, expected "
            f"{n_units} units x {unit_steps} steps = {expect}"
        )
    counts = np.bincount(order, minlength=n_units)
    bad = np.flatnonzero(counts != unit_steps)
    if bad.size:
        t = int(bad[0])
        raise ValueError(
            f"invalid step order: unit {t} takes {int(counts[t])} steps, "
            f"expected {unit_steps} (and {bad.size - 1} more offending units)"
        )
    return order


def rle_chunks(order: np.ndarray) -> list[tuple[int, int]]:
    """Run-length encode a step order into (unit_id, run_length) chunks.

    Consecutive equal entries fuse into one chunk, which a backend
    executes as a single fused segment.
    """
    order = np.asarray(order)
    if order.size == 0:
        return []
    change = np.flatnonzero(np.diff(order)) + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [order.size]])
    return [(int(order[s]), int(e - s)) for s, e in zip(starts, ends)]


def pow2_decompose(n: int, cap: int = 64) -> list[int]:
    """Descending powers of two (each ≤ cap) summing to n.

    This is the trace-count bound: every dispatched segment length is a
    member of {1, 2, 4, ..., cap}, so at most log2(cap)+1 distinct jit
    traces exist no matter how an order's runs are split by deadlines.
    """
    if n < 0:
        raise ValueError(f"cannot decompose negative run length {n}")
    if cap < 1 or cap & (cap - 1):
        raise ValueError(f"cap must be a positive power of two, got {cap}")
    out = []
    while n:
        p = min(1 << (n.bit_length() - 1), cap)
        out.append(p)
        n -= p
    return out


# eq=False: plans hold ndarray/jax.Array fields (value __eq__/__hash__
# would be broken) and are shared by identity via ForestProgram's
# content-addressed cache.
@dataclasses.dataclass(frozen=True, eq=False)
class StepPlan:
    """Compile-once lowering of a step order to fused device segments.

    ``seg_units[i]`` advances for ``seg_lens[i]`` consecutive steps;
    lengths are powers of two ≤ ``max_segment``.  ``seg_starts`` is the
    cumulative step position of each segment boundary (host-side, for
    the ``advance`` bookkeeping); ``units_dev`` mirrors the unit ids on
    device so per-segment dispatch never re-uploads scalars.
    """

    order: np.ndarray                       # int32 [total_steps]
    seg_units: np.ndarray                   # int32 [S]
    seg_lens: np.ndarray                    # int32 [S], all powers of two
    seg_starts: np.ndarray                  # int64 [S+1], cumulative
    units_dev: jax.Array = dataclasses.field(repr=False)
    max_segment: int = 64

    @classmethod
    def compile(
        cls,
        order: np.ndarray,
        n_units: Optional[int] = None,
        unit_steps: Optional[int] = None,
        max_segment: int = 64,
    ) -> "StepPlan":
        order = np.asarray(order, dtype=np.int32)
        if n_units is not None and unit_steps is not None:
            check_order(order, n_units, unit_steps)
        units, lens = [], []
        for u, n in rle_chunks(order):
            for p in pow2_decompose(n, cap=max_segment):
                units.append(u)
                lens.append(p)
        seg_units = np.asarray(units, dtype=np.int32)
        seg_lens = np.asarray(lens, dtype=np.int32)
        seg_starts = np.concatenate([[0], np.cumsum(seg_lens, dtype=np.int64)])
        return cls(
            order=order,
            seg_units=seg_units,
            seg_lens=seg_lens,
            seg_starts=seg_starts,
            units_dev=jnp.asarray(seg_units),
            max_segment=max_segment,
        )

    @property
    def total_steps(self) -> int:
        return int(self.order.shape[0])

    @property
    def n_segments(self) -> int:
        return int(self.seg_units.shape[0])

    @property
    def trace_lengths(self) -> tuple[int, ...]:
        """Distinct segment lengths = upper bound on live jit traces."""
        return tuple(sorted(set(int(x) for x in self.seg_lens)))

    def segment_at(self, pos: int) -> int:
        """Index of the segment containing absolute step position pos."""
        return int(np.searchsorted(self.seg_starts, pos, side="right")) - 1


# ---------------------------------------------------------------------------
# Backend registry.
# ---------------------------------------------------------------------------

_BACKENDS: dict[str, type] = {}


def register_backend(name: str) -> Callable[[type], type]:
    """Class decorator registering a :class:`ForestExecutor` under ``name``."""

    def deco(cls: type) -> type:
        if name in _BACKENDS:
            raise ValueError(f"backend {name!r} already registered")
        cls.name = name
        _BACKENDS[name] = cls
        return cls

    return deco


def get_backend(name: str) -> type:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {', '.join(list_backends())}"
        ) from None


def list_backends() -> tuple[str, ...]:
    return tuple(_BACKENDS)


def default_backend() -> str:
    """Auto-selection: kernels where the MXU exists, reference elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "jnp-ref"


# ---------------------------------------------------------------------------
# Executors (the ExecutionBackend protocol).
# ---------------------------------------------------------------------------


class ForestExecutor:
    """Execution strategy behind :class:`ForestStepBackend`.

    Implementations own state placement and the two hot operations:

    * ``run_segment(idx, unit, length)`` — ``length`` fused steps of one
      tree (``length`` is a static power of two from the step-plan, so
      each distinct value is one cached jit trace);
    * ``readout(idx)`` — the anytime prediction read-out ``[B, C]``.
    """

    name = "abstract"

    def __init__(self, device: engine.DeviceForest, X, plan: StepPlan):
        self.device = device
        self.X = jnp.asarray(X)
        self.plan = plan
        self.batch = int(self.X.shape[0])

        @partial(jax.jit, static_argnums=(4,))
        def _run_slots(idx, X, units, mask, length):
            return engine.slot_run(self.device, X, idx, units, mask, length)

        self._run_slots_jit = _run_slots

    def init_state(self) -> jax.Array:
        return engine.init_state(self.device, self.batch)

    def run_segment(self, idx: jax.Array, unit: jax.Array, length: int) -> jax.Array:
        raise NotImplementedError

    def readout(self, idx: jax.Array) -> jax.Array:
        raise NotImplementedError

    # -- masked-slot entry point (the repro.serve scheduler's hot path) --

    def run_slots(
        self, idx: jax.Array, X, units: jax.Array, mask: jax.Array, length: int
    ) -> jax.Array:
        """``length`` fused masked steps where slot b advances its OWN
        tree ``units[b]`` (``mask[b]`` False = idle slot).

        One dispatch serves many concurrent requests sitting at
        different positions of the same step plan; ``length`` is a
        static power of two from the plan, so the trace bound of
        :meth:`run_segment` carries over unchanged.  The generic
        per-slot gather path is shared by every executor (per-slot tree
        ids defeat the single-tree table gather the Pallas kernels are
        tiled for); ``sharded`` re-places the slot axis, see
        :meth:`place_slots`.
        """
        return self._run_slots_jit(idx, jnp.asarray(X), units, mask, length)

    def place_slots(self, *arrays) -> tuple:
        """Placement hook for slot-batch state arrays whose leading dim
        is the slot axis (identity by default; the sharded executor puts
        the slot axis on the mesh).  Always returns a tuple."""
        return arrays


@register_backend("jnp-ref")
class JnpRefExecutor(ForestExecutor):
    """Pure-jnp scan over ``engine.tree_step`` — the parity oracle."""

    def __init__(self, device, X, plan):
        super().__init__(device, X, plan)

        @partial(jax.jit, static_argnums=(2,))
        def _run(idx, unit, length):
            return engine.tree_run(self.device, self.X, idx, unit, length)

        self._run = _run

    def run_segment(self, idx, unit, length):
        return self._run(idx, unit, length)

    def readout(self, idx):
        return engine.predict_from_state(self.device, idx)


@register_backend("pallas")
class PallasExecutor(ForestExecutor):
    """RLE-fused runs through the Pallas kernels.

    Stepping gathers one tree's node tables and scans
    :func:`repro.kernels.ops.forest_step` over the fused segment
    (:func:`~repro.kernels.ops.forest_run`); the read-out is the
    :func:`~repro.kernels.ops.prob_accum` one-hot MXU contraction.
    Interpret mode on CPU — same kernel body, element-for-element.
    """

    def __init__(self, device, X, plan, *, block_b: int = 256,
                 block_m: int = 512, interpret: Optional[bool] = None):
        super().__init__(device, X, plan)
        kw = {"block_b": block_b, "block_m": block_m}
        if interpret is not None:
            kw["interpret"] = interpret
        self._kernel_kw = kw

        @partial(jax.jit, static_argnums=(2,))
        def _run(idx, unit, length):
            feature, threshold, left, right, is_leaf = (
                jnp.take(a, unit, axis=0)
                for a in (self.device.feature, self.device.threshold,
                          self.device.left, self.device.right,
                          self.device.is_leaf)
            )
            col = jnp.take(idx, unit, axis=1)
            col = kops.forest_run(
                col, self.X, feature, threshold, left, right, is_leaf,
                length=length, **kw,
            )
            return idx.at[:, unit].set(col)

        self._run = _run

    def run_segment(self, idx, unit, length):
        return self._run(idx, unit, length)

    def readout(self, idx):
        return kops.prob_accum(idx, self.device.probs, **self._kernel_kw)


@register_backend("sharded")
class ShardedExecutor(JnpRefExecutor):
    """Batch axis on a mesh: one runtime, many concurrent deadline streams.

    The forest tables replicate; inputs and the index-array state shard
    over the mesh's batch axes (``batch_pspec``), so the jit partitioner
    splits every segment scan across shards with zero collectives (the
    anytime step is embarrassingly batch-parallel; only the read-out
    gathers are per-shard too).  Batches that don't divide the shard
    count are padded internally and sliced at read-out.
    """

    def __init__(self, device, X, plan, *, mesh=None):
        self.mesh = mesh if mesh is not None else mesh_lib.make_host_mesh(
            data=len(jax.devices())
        )
        self._shards = mesh_lib.n_batch_shards(self.mesh)
        X = jnp.asarray(X)
        self._true_batch = int(X.shape[0])
        pad = (-self._true_batch) % self._shards
        if pad:
            X = jnp.concatenate([X, jnp.zeros((pad, X.shape[1]), X.dtype)])
        batch_sh = mesh_lib.batch_sharding(self.mesh)
        repl = mesh_lib.replicated_sharding(self.mesh)
        super().__init__(jax.device_put(device, repl), jax.device_put(X, batch_sh), plan)
        self._batch_sharding = batch_sh

    def init_state(self):
        return jax.device_put(super().init_state(), self._batch_sharding)

    def readout(self, idx):
        return super().readout(idx)[: self._true_batch]

    def place_slots(self, *arrays):
        """Slot-batch state (idx [S,T], X [S,F], masks/units [S]) gets
        its leading slot axis placed via ``mesh.batch_pspec`` — the slot
        batch IS the mesh's data-parallel batch, so every masked segment
        dispatch splits across shards with zero collectives."""
        return tuple(jax.device_put(a, self._batch_sharding) for a in arrays)

    def run_slots(self, idx, X, units, mask, length):
        units, mask = self.place_slots(jnp.asarray(units), jnp.asarray(mask))
        return super().run_slots(idx, X, units, mask, length)


# ---------------------------------------------------------------------------
# The step backend every Session wraps.
# ---------------------------------------------------------------------------


class ForestStepBackend:
    """Step-level forest executor over a compiled :class:`StepPlan`.

    A run of r consecutive steps of the same tree executes as fused
    segments of power-of-two length through the selected executor (the
    tree id is a traced scalar, so runs of different trees share each
    trace).  ``advance`` remains exact at single-step granularity — a
    segment splits into smaller power-of-two pieces whenever the
    requested step budget ends inside it, which by construction mints no
    new trace lengths.
    """

    def __init__(
        self,
        device: engine.DeviceForest,
        X,
        order: np.ndarray,
        backend: Optional[str] = None,
        plan: Optional[StepPlan] = None,
        **backend_opts,
    ):
        self.backend_name = backend if backend is not None else default_backend()
        self.plan = plan if plan is not None else StepPlan.compile(order)
        self.order = self.plan.order
        self.executor = get_backend(self.backend_name)(
            device, X, self.plan, **backend_opts
        )
        self.device = self.executor.device
        self.X = self.executor.X
        self.idx = self.executor.init_state()
        self.pos = 0
        #: distinct fused-segment lengths dispatched so far — each is one
        #: cached jit trace; the parity/trace tests assert the bound.
        self.dispatched_lengths: set[int] = set()

    @property
    def total_steps(self) -> int:
        return self.plan.total_steps

    @property
    def remaining(self) -> int:
        return self.total_steps - self.pos

    def advance(self, k: int) -> int:
        """Execute up to k more steps (plan-fused); returns steps taken."""
        k = min(int(k), self.remaining)
        taken = 0
        while taken < k:
            s = self.plan.segment_at(self.pos)
            seg_end = int(self.plan.seg_starts[s + 1])
            step = min(k - taken, seg_end - self.pos)
            unit = self.plan.units_dev[s]
            for p in pow2_decompose(step, cap=self.plan.max_segment):
                self.idx = self.executor.run_segment(self.idx, unit, p)
                self.dispatched_lengths.add(p)
            self.pos += step
            taken += step
        return taken

    def predict_proba(self) -> np.ndarray:
        return np.asarray(self.executor.readout(self.idx))

    def predict(self) -> np.ndarray:
        return self.predict_proba().argmax(axis=1)
