"""Pluggable execution backends for anytime forest serving.

The paper's anytime value proposition (Sec. V) only pays off if the
per-step overhead is negligible; this module makes the execution layer
a pluggable subsystem so the same :class:`~repro.schedule.runtime.Session`
surface can dispatch to whichever implementation the hardware rewards:

* ``jnp-ref``  — the pure-jnp ``engine.segment_run`` scan.  Kept as the
  bit-exactness oracle every other backend is parity-tested against.
* ``pallas``   — kernel-resident execution: one fused Pallas launch per
  plan segment with the node tables resident in VMEM across all steps
  (:func:`repro.kernels.ops.forest_run` for lockstep segments,
  :func:`repro.kernels.ops.slot_run` for masked slot segments), the
  boundary read-out fusable into the same launch.  Interpret mode on
  CPU, compiled Mosaic on TPU.
* ``sharded``  — the batch axis placed on a ``launch/mesh.py`` mesh via
  ``batch_pspec``, so ONE runtime serves many concurrent deadline
  streams; the jit partitioner splits every segment scan across the
  mesh's batch shards.

All three implement :class:`ExecutorCore` — one plan-segment entry
point (:meth:`ExecutorCore.run`) shared by the solo-session shape
(:class:`ForestStepBackend`) and the slot-batch serving shape
(:class:`~repro.schedule.runtime.SessionBatch`).

Selection surface: ``AnytimeRuntime(program, backend="pallas")`` or
per-session ``runtime.session(X, policy, backend="sharded")``; with no
explicit choice, :func:`default_backend` picks by ``jax.default_backend()``.

**Step-plans.** Orders are compiled ONCE into a :class:`StepPlan`:
``check_order`` + ``rle_chunks`` lower the order into device arrays of
(unit, run-length) segments whose run lengths are bucketed to powers of
two.  ``advance``/``advance_until`` then execute under a handful of
cached jit traces (one per distinct power-of-two length, ≤
``log2(max_segment)+1`` ≈ 7) instead of one compilation per distinct
run length — mid-chunk splits decompose into the SAME power-of-two
buckets, so arbitrary deadline-driven advance patterns never mint new
traces.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.kernels import layout as klayout
from repro.kernels import ops as kops
from repro.kernels import tuning as ktuning
from repro.launch import mesh as mesh_lib
from repro.obs import annotate as obs_annotate
from repro.obs import tracing_active as obs_tracing_active

__all__ = [
    "check_order",
    "rle_chunks",
    "pow2_floor",
    "pow2_decompose",
    "StepPlan",
    "register_backend",
    "get_backend",
    "list_backends",
    "default_backend",
    "resolve_device",
    "ExecutorCore",
    "ForestExecutor",
    "JnpRefExecutor",
    "PallasExecutor",
    "ShardedExecutor",
    "ForestStepBackend",
]


def check_order(order: np.ndarray, n_units: int, unit_steps: int) -> np.ndarray:
    """Validate a step order, raising a ValueError that names the first
    offending unit (unlike a bare assert, this survives ``python -O``)."""
    order = np.asarray(order)
    expect = n_units * unit_steps
    if order.shape[0] != expect:
        raise ValueError(
            f"invalid step order: length {order.shape[0]}, expected "
            f"{n_units} units x {unit_steps} steps = {expect}"
        )
    counts = np.bincount(order, minlength=n_units)
    bad = np.flatnonzero(counts != unit_steps)
    if bad.size:
        t = int(bad[0])
        raise ValueError(
            f"invalid step order: unit {t} takes {int(counts[t])} steps, "
            f"expected {unit_steps} (and {bad.size - 1} more offending units)"
        )
    return order


def rle_chunks(order: np.ndarray) -> list[tuple[int, int]]:
    """Run-length encode a step order into (unit_id, run_length) chunks.

    Consecutive equal entries fuse into one chunk, which a backend
    executes as a single fused segment.
    """
    order = np.asarray(order)
    if order.size == 0:
        return []
    change = np.flatnonzero(np.diff(order)) + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [order.size]])
    return [(int(order[s]), int(e - s)) for s, e in zip(starts, ends)]


def pow2_floor(n: int, cap: int = 64) -> int:
    """Largest power of two ≤ min(n, cap) — the shared run-length
    bucketing primitive.

    Both dispatch planners quantize through this ONE function: the
    :class:`StepPlan` compiler / ``advance`` splitter (via
    :func:`pow2_decompose`) and the :class:`~repro.schedule.runtime.
    SessionBatch` masked slot dispatch (directly).  Every dispatched
    segment length therefore comes from {1, 2, 4, ..., cap}, and the
    ≤ log2(cap)+1 jit-trace bound cannot drift between the solo and
    slot paths.
    """
    n = int(n)
    if n < 1:
        raise ValueError(f"run length must be >= 1, got {n}")
    if cap < 1 or cap & (cap - 1):
        raise ValueError(f"cap must be a positive power of two, got {cap}")
    return min(1 << (n.bit_length() - 1), cap)


def pow2_decompose(n: int, cap: int = 64) -> list[int]:
    """Descending powers of two (each ≤ cap) summing to n.

    This is the trace-count bound: every dispatched segment length is a
    member of {1, 2, 4, ..., cap} (:func:`pow2_floor`), so at most
    log2(cap)+1 distinct jit traces exist no matter how an order's runs
    are split by deadlines.
    """
    if n < 0:
        raise ValueError(f"cannot decompose negative run length {n}")
    if cap < 1 or cap & (cap - 1):
        raise ValueError(f"cap must be a positive power of two, got {cap}")
    out = []
    while n:
        p = pow2_floor(n, cap)
        out.append(p)
        n -= p
    return out


# eq=False: plans hold ndarray/jax.Array fields (value __eq__/__hash__
# would be broken) and are shared by identity via ForestProgram's
# content-addressed cache.
@dataclasses.dataclass(frozen=True, eq=False)
class StepPlan:
    """Compile-once lowering of a step order to fused device segments.

    ``seg_units[i]`` advances for ``seg_lens[i]`` consecutive steps;
    lengths are powers of two ≤ ``max_segment``.  ``seg_starts`` is the
    cumulative step position of each segment boundary (host-side, for
    the ``advance`` bookkeeping); ``units_dev`` mirrors the unit ids on
    device so per-segment dispatch never re-uploads scalars.
    ``seg_fresh[i]`` marks the FIRST segment of its unit in the plan —
    every walker of that unit is still at the root when it starts, the
    precondition for the depth-aware gather-eliminated kernel.
    """

    order: np.ndarray                       # int32 [total_steps]
    seg_units: np.ndarray                   # int32 [S]
    seg_lens: np.ndarray                    # int32 [S], all powers of two
    seg_starts: np.ndarray                  # int64 [S+1], cumulative
    units_dev: jax.Array = dataclasses.field(repr=False)
    max_segment: int = 64
    seg_fresh: Optional[np.ndarray] = None  # bool [S], None = all stale

    @classmethod
    def compile(
        cls,
        order: np.ndarray,
        n_units: Optional[int] = None,
        unit_steps: Optional[int] = None,
        max_segment: int = 64,
    ) -> "StepPlan":
        order = np.asarray(order, dtype=np.int32)
        if n_units is not None and unit_steps is not None:
            check_order(order, n_units, unit_steps)
        units, lens = [], []
        for u, n in rle_chunks(order):
            for p in pow2_decompose(n, cap=max_segment):
                units.append(u)
                lens.append(p)
        seg_units = np.asarray(units, dtype=np.int32)
        seg_lens = np.asarray(lens, dtype=np.int32)
        seg_starts = np.concatenate([[0], np.cumsum(seg_lens, dtype=np.int64)])
        seen: set[int] = set()
        fresh = []
        for u in units:
            fresh.append(u not in seen)
            seen.add(u)
        return cls(
            order=order,
            seg_units=seg_units,
            seg_lens=seg_lens,
            seg_starts=seg_starts,
            units_dev=jnp.asarray(seg_units),
            max_segment=max_segment,
            seg_fresh=np.asarray(fresh, dtype=bool),
        )

    @property
    def total_steps(self) -> int:
        return int(self.order.shape[0])

    @property
    def n_segments(self) -> int:
        return int(self.seg_units.shape[0])

    @property
    def trace_lengths(self) -> tuple[int, ...]:
        """Distinct segment lengths = upper bound on live jit traces."""
        return tuple(sorted(set(int(x) for x in self.seg_lens)))

    def segment_at(self, pos: int) -> int:
        """Index of the segment containing absolute step position pos."""
        return int(np.searchsorted(self.seg_starts, pos, side="right")) - 1


# ---------------------------------------------------------------------------
# Backend registry.
# ---------------------------------------------------------------------------

_BACKENDS: dict[str, type] = {}


def register_backend(name: str) -> Callable[[type], type]:
    """Class decorator registering a :class:`ForestExecutor` under ``name``."""

    def deco(cls: type) -> type:
        if name in _BACKENDS:
            raise ValueError(f"backend {name!r} already registered")
        cls.name = name
        _BACKENDS[name] = cls
        return cls

    return deco


def get_backend(name: str) -> type:
    try:
        return _BACKENDS[name]
    except KeyError:
        raise ValueError(
            f"unknown backend {name!r}; available: {', '.join(list_backends())}"
        ) from None


def list_backends() -> tuple[str, ...]:
    return tuple(_BACKENDS)


def default_backend() -> str:
    """Auto-selection: kernels where the MXU exists, reference elsewhere."""
    return "pallas" if jax.default_backend() == "tpu" else "jnp-ref"


def resolve_device(pin_device) -> jax.Device:
    """Normalize a ``pin_device`` backend option to a ``jax.Device``.

    Accepts a device object (passed through) or an integer index into
    ``jax.devices()`` — the index form is what serving configs and CLIs
    carry, since device objects aren't serializable."""
    if isinstance(pin_device, int):
        devices = jax.devices()
        if not 0 <= pin_device < len(devices):
            raise ValueError(
                f"pin_device index {pin_device} out of range; "
                f"{len(devices)} device(s) visible"
            )
        return devices[pin_device]
    return pin_device


# ---------------------------------------------------------------------------
# Executors: the ExecutorCore interface (the ExecutionBackend protocol).
# ---------------------------------------------------------------------------


class ExecutorCore:
    """Unified execution core behind :class:`ForestStepBackend` and
    :class:`~repro.schedule.runtime.SessionBatch`.

    ONE plan-segment entry point — :meth:`run` — serves both session
    shapes on every backend:

    * **solo lockstep batch** (``units`` scalar): every sample advances
      the SAME tree for ``length`` steps — the ``Session`` shape;
    * **masked slot batch** (``units`` vector + live ``mask``): row b
      advances its OWN tree — the ``repro.serve`` shape.

    ``length`` is always a static power of two from the step-plan
    (:func:`pow2_floor`), so each distinct value is one cached jit
    trace on either shape.  ``readout=True`` fuses the anytime boundary
    read-out into the SAME dispatch — the same kernel launch on
    ``pallas``, the same jit computation on ``jnp-ref``/``sharded`` —
    so the serving loop's dispatch+readout pair costs one device round
    trip.  Subclasses implement ``_segment``/``_slots``/``readout``;
    the legacy ``run_segment``/``run_slots`` methods remain as shims
    over :meth:`run`.
    """

    name = "abstract"

    def __init__(self, device: engine.DeviceForest, X, plan: StepPlan,
                 pin_device=None):
        if pin_device is not None:
            pin_device = resolve_device(pin_device)
            # commit the forest tables AND the input batch to the pinned
            # device: a serving tier runs one executor per device, and
            # every downstream dispatch must land there, not on jax's
            # process-default device
            device = jax.device_put(device, pin_device)
            X = jax.device_put(jnp.asarray(X), pin_device)
        self._pin = pin_device
        self.device = device
        self.X = jnp.asarray(X)
        self.plan = plan
        self.batch = int(self.X.shape[0])

        # generic masked-slot path, available to every subclass (and the
        # default behind _slots for legacy executors that only implement
        # run_segment/readout — the pre-ExecutorCore base class shipped
        # a working run_slots, so the base class still must)
        @partial(jax.jit, static_argnums=(4,))
        def _generic_slots(idx, X, units, mask, length):
            return engine.slot_run(self.device, X, idx, units, mask, length)

        self._generic_slots_jit = _generic_slots
        # dispatch shapes already seen by THIS executor — the first run
        # of a (kind, length, readout, fresh) combination is the one
        # that mints its jit trace, which is what trace spans must count
        # as compile_ms rather than steady-state dispatch
        self._traced_shapes: set[tuple] = set()

    def init_state(self) -> jax.Array:
        state = engine.init_state(self.device, self.batch)
        return state if self._pin is None else jax.device_put(state, self._pin)

    # -- the single plan-segment entry point -----------------------------

    def run(
        self,
        idx: jax.Array,
        units,
        mask=None,
        length: int = 1,
        *,
        X=None,
        readout: bool = False,
        fresh: bool = False,
    ) -> tuple[jax.Array, Optional[jax.Array]]:
        """``length`` fused steps of one plan segment; returns
        ``(new_idx, probs)`` where ``probs`` is the fused boundary
        read-out when ``readout`` else None.  ``units`` scalar selects
        the lockstep shape, vector the masked-slot shape (the rank
        check is static, so both shapes share this entry point without
        a runtime branch).

        ``fresh=True`` asserts that the stepped unit's walkers are all
        still at the ROOT (a plan's first segment for that unit, offset
        0) — backends with a depth-aware variant may then eliminate the
        shallow-level table gathers; it is purely a performance hint and
        must never change results."""
        X = self.X if X is None else jnp.asarray(X)
        solo = jnp.ndim(units) == 0
        if obs_tracing_active():
            self._annotate_dispatch(
                "solo" if solo else "slot", length, readout, fresh)
        if solo:
            if fresh and not readout:
                return self._segment_fresh(idx, X, units, length, readout)
            return self._segment(idx, X, units, length, readout)
        if mask is None:
            mask = jnp.ones(idx.shape[0], dtype=bool)
        units, mask = self._place_unit_mask(jnp.asarray(units), jnp.asarray(mask))
        return self._slots(idx, X, units, mask, length, readout)

    def _annotate_dispatch(self, kind: str, length: int, readout: bool,
                           fresh: bool) -> None:
        """Report this dispatch onto the enclosing trace span (eager —
        ``run`` itself is never jitted, only the per-backend hooks it
        calls are): backend, tuned impl, segment length, fresh flag, and
        whether this (kind, length, readout, fresh) shape is the first
        of its kind on this executor — i.e. the dispatch that mints its
        jit trace, which attribution counts as compile not dispatch."""
        shape = (kind, int(length), bool(readout), bool(fresh))
        compiled = shape not in self._traced_shapes
        if compiled:
            self._traced_shapes.add(shape)
        # the depth variant only takes solo fresh segments WITHOUT a
        # fused readout (run()'s routing) — impl naming must match
        eff_fresh = bool(fresh) and kind == "solo" and not readout
        obs_annotate(
            backend=self.name, kind=kind, length=int(length),
            fresh=bool(fresh), compile=compiled,
            impl=self.impl_name(kind, int(length), fresh=eff_fresh),
        )

    def impl_name(self, kind: str, length: int, fresh: bool = False) -> str:
        """Registry name of the implementation a ``kind`` ("solo" |
        "slot") segment of ``length`` steps dispatches to — trace-span
        metadata (the tuning-registry kernel choice on ``pallas``); the
        backend name where there is no per-shape selection."""
        return self.name

    # -- per-backend hooks ----------------------------------------------
    #
    # The base implementations keep PRE-ExecutorCore subclasses working:
    # an external executor registered against the old protocol overrides
    # run_segment (and maybe run_slots) rather than these hooks, so the
    # base hooks route back to those overrides — never to the shims,
    # which would recurse into run().

    def _segment(self, idx, X, unit, length, readout):
        if type(self).run_segment is not ExecutorCore.run_segment:
            self._in_legacy_segment = True
            try:
                idx = self.run_segment(idx, unit, length)
            finally:
                self._in_legacy_segment = False
            return idx, (self.readout(idx) if readout else None)
        raise NotImplementedError

    def _segment_fresh(self, idx, X, unit, length, readout):
        """Hook for root-start solo segments (``fresh=True``); defaults
        to the plain segment path — only backends with a depth-aware
        variant override it."""
        return self._segment(idx, X, unit, length, readout)

    def _slots(self, idx, X, units, mask, length, readout):
        if type(self).run_slots is not ExecutorCore.run_slots:
            # re-entrancy note: if the legacy override delegates to
            # super().run_slots(), the shim below detects the live
            # legacy call and runs the old base behavior (the generic
            # gather) instead of recursing through run() again
            self._in_legacy_slots = True
            try:
                idx = self.run_slots(idx, X, units, mask, length)
            finally:
                self._in_legacy_slots = False
        else:
            idx = self._generic_slots_jit(idx, X, units, mask, length)
        return idx, (self.readout(idx) if readout else None)

    def readout(self, idx: jax.Array) -> jax.Array:
        """Standalone anytime read-out ``[B, C]`` (no step)."""
        raise NotImplementedError

    def _place_unit_mask(self, units, mask):
        """Placement hook for the per-slot unit/mask vectors (identity;
        ``sharded`` puts them on the mesh's batch axis)."""
        return units, mask

    def place_slots(self, *arrays) -> tuple:
        """Placement hook for slot-batch state arrays whose leading dim
        is the slot axis (identity by default, re-committed to the
        pinned device when one was given; the sharded executor puts the
        slot axis on the mesh).  Always returns a tuple."""
        if self._pin is None:
            return arrays
        return tuple(jax.device_put(a, self._pin) for a in arrays)

    # -- legacy shims (pre-ExecutorCore call surface) --------------------

    def run_segment(self, idx: jax.Array, unit, length: int) -> jax.Array:
        if getattr(self, "_in_legacy_segment", False):
            # reached via super().run_segment() from a legacy override:
            # the pre-ExecutorCore base had no solo implementation —
            # keep that contract rather than recursing through run()
            raise NotImplementedError(
                "the base class provides no run_segment implementation"
            )
        return self.run(idx, unit, None, length)[0]

    def run_slots(self, idx, X, units, mask, length) -> jax.Array:
        if getattr(self, "_in_legacy_slots", False):
            # reached via super().run_slots() from a legacy override
            # mid-dispatch: behave like the pre-ExecutorCore base class
            # (generic masked gather), don't recurse through run()
            return self._generic_slots_jit(
                idx, jnp.asarray(X), jnp.asarray(units), jnp.asarray(mask),
                length,
            )
        return self.run(idx, units, mask, length, X=X)[0]


#: Pre-PR-4 name for :class:`ExecutorCore`, kept for external callers.
ForestExecutor = ExecutorCore


@register_backend("jnp-ref")
class JnpRefExecutor(ExecutorCore):
    """Pure-jnp ``engine.segment_run`` scans — the parity oracle.

    Both session shapes route through ONE jitted function (the shape of
    ``units`` picks the engine primitive at trace time); ``readout``
    fuses ``predict_from_state`` into the same XLA computation.
    """

    def __init__(self, device, X, plan, pin_device=None):
        super().__init__(device, X, plan, pin_device=pin_device)

        @partial(jax.jit, static_argnums=(4, 5))
        def _run(idx, X, units, mask, length, readout):
            idx = engine.segment_run(self.device, X, idx, units, mask, length)
            probs = (
                engine.predict_from_state(self.device, idx) if readout else None
            )
            return idx, probs

        self._run = _run

    def _segment(self, idx, X, unit, length, readout):
        return self._run(idx, X, unit, None, length, readout)

    def _slots(self, idx, X, units, mask, length, readout):
        return self._run(idx, X, units, mask, length, readout)

    def readout(self, idx):
        return engine.predict_from_state(self.device, idx)


@register_backend("pallas")
class PallasExecutor(ExecutorCore):
    """Kernel-resident Pallas paths for BOTH session shapes.

    * solo segments dispatch the fused multi-step kernel
      (:func:`repro.kernels.ops.forest_run`): one launch per plan
      segment, the tree's node tables resident in VMEM across all steps;
    * masked slot segments dispatch through the TUNED slot
      implementation (:func:`repro.kernels.ops.slot_run`): the
      platform's committed tuning record picks gather / flat / bucket /
      cached per shape (conservative default: the generic gather), and
      when the bucketized kernel is selected the slot batch is first
      tree-id-bucketized (``ops.bucketize_slots``) for gather coherence;
    * **fresh** solo segments (every walker still at the unit's root —
      the plan's first segment for that unit) dispatch the depth-aware
      gather-eliminated kernel (:func:`repro.kernels.ops.
      forest_run_depth`) over a depth-ordered layout precomputed once at
      construction; ``depth_levels=0`` (or a tuning record saying so)
      disables the variant;
    * ``readout=True`` fuses the ``prob_accum`` boundary read-out into
      the SAME launch (``forest_run_readout`` / ``slot_run_readout``).

    Block defaults come from the tuning record's ``executor`` section
    (explicit constructor arguments win).  Interpret mode on CPU — same
    kernel bodies, element-for-element; oversized forests fall back to
    the streamed/generic paths inside :mod:`repro.kernels.ops` (VMEM
    residency budget).
    """

    def __init__(self, device, X, plan, *, block_b: Optional[int] = None,
                 block_m: Optional[int] = None,
                 interpret: Optional[bool] = None,
                 depth_levels: Optional[int] = None,
                 pin_device=None):
        super().__init__(device, X, plan, pin_device=pin_device)
        tuned = ktuning.executor_params()
        block_b = int(tuned.get("block_b", 256) if block_b is None else block_b)
        block_m = int(tuned.get("block_m", 512) if block_m is None else block_m)
        depth_levels = int(
            tuned.get("depth_levels", 4) if depth_levels is None
            else depth_levels
        )
        kw = {"block_b": block_b, "block_m": block_m}
        if interpret is not None:
            kw["interpret"] = interpret
        self._kernel_kw = kw
        self.depth_levels = depth_levels
        d = self.device
        T = int(d.feature.shape[0])
        Mp = kops.round_up(max(int(d.feature.shape[1]), 1), 128)
        self._tuning_shape = (T, Mp)  # impl_name keys the tuning record

        # depth-ordered layout for the fresh-segment variant: a one-time
        # host-side BFS over the concrete device tables
        self.layout = (
            klayout.build_depth_layout(
                d.feature, d.threshold, d.left, d.right, d.is_leaf
            )
            if depth_levels > 0 else None
        )
        lay, levels = self.layout, depth_levels

        def _tables(unit):
            return tuple(
                jnp.take(a, unit, axis=0)
                for a in (d.feature, d.threshold, d.left, d.right, d.is_leaf)
            )

        @partial(jax.jit, static_argnums=(3, 4))
        def _seg(idx, X, unit, length, readout):
            tables = _tables(unit)
            if readout:
                return kops.forest_run_readout(
                    idx, X, *tables, d.probs, unit, length=length, **kw
                )
            col = kops.forest_run(
                jnp.take(idx, unit, axis=1), X, *tables, length=length, **kw
            )
            return idx.at[:, unit].set(col), None

        @partial(jax.jit, static_argnums=(3,))
        def _seg_fresh(idx, X, unit, length):
            col = kops.forest_run_depth(
                jnp.take(idx, unit, axis=1), X, lay, unit, length=length,
                start_step=0, levels=levels, **kw,
            )
            return idx.at[:, unit].set(col), None

        @partial(jax.jit, static_argnums=(4, 5))
        def _slt(idx, X, units, mask, length, readout):
            tables = (d.feature, d.threshold, d.left, d.right, d.is_leaf)
            # tuned-impl peek at trace time: bucketized dispatch prefers
            # tree-sorted slots (pure in-graph permutation, bit-neutral)
            name, _ = ktuning.select(
                "slot", ktuning.slot_key(T, Mp, length)
            )
            perm = inv = None
            if name == "bucket":
                perm, inv = kops.bucketize_slots(units)
                idx, X = idx[perm], X[perm]
                units, mask = units[perm], mask[perm]
            if readout:
                new_idx, ro = kops.slot_run_readout(
                    idx, X, *tables, d.probs, units, mask, length=length, **kw
                )
                return (new_idx, ro) if inv is None else (new_idx[inv], ro[inv])
            new_idx = kops.slot_run(
                idx, X, *tables, units, mask, length=length, **kw
            )
            return (new_idx if inv is None else new_idx[inv]), None

        self._seg, self._seg_fresh_jit, self._slt = _seg, _seg_fresh, _slt

    def _segment(self, idx, X, unit, length, readout):
        return self._seg(idx, X, unit, length, readout)

    def _segment_fresh(self, idx, X, unit, length, readout):
        if self.layout is None:
            return self._seg(idx, X, unit, length, readout)
        return self._seg_fresh_jit(idx, X, unit, length)

    def _slots(self, idx, X, units, mask, length, readout):
        return self._slt(idx, X, units, mask, length, readout)

    def impl_name(self, kind: str, length: int, fresh: bool = False) -> str:
        """The committed tuning record's kernel choice for this shape —
        what trace spans report as ``impl`` on every dispatch, compiled
        or steady-state (the same ``tuning.select`` the jitted bodies
        consult at trace time)."""
        T, Mp = self._tuning_shape
        if kind == "slot":
            return ktuning.select(
                "slot", ktuning.slot_key(T, Mp, int(length)))[0]
        if fresh and self.layout is not None:
            return "depth"
        return ktuning.select("solo", ktuning.solo_key(Mp, int(length)))[0]

    def readout(self, idx):
        return kops.prob_accum(idx, self.device.probs, **self._kernel_kw)


@register_backend("sharded")
class ShardedExecutor(JnpRefExecutor):
    """Batch axis on a mesh: one runtime, many concurrent deadline streams.

    The forest tables replicate; inputs and the index-array state shard
    over the mesh's batch axes (``batch_pspec``), so the jit partitioner
    splits every segment scan across shards with zero collectives (the
    anytime step is embarrassingly batch-parallel; only the read-out
    gathers are per-shard too).  Batches that don't divide the shard
    count are padded internally and sliced at read-out.
    """

    def __init__(self, device, X, plan, *, mesh=None, pin_device=None):
        if mesh is not None:
            self.mesh = mesh
        elif pin_device is not None:
            # device-pinned executor selection for the serving tier:
            # a per-device pool gets a degenerate one-device mesh, so
            # the SAME backend_opts dict works for every pool and the
            # mesh placement machinery does the committing
            self.mesh = mesh_lib.make_single_device_mesh(
                resolve_device(pin_device))
        else:
            self.mesh = mesh_lib.make_host_mesh(data=len(jax.devices()))
        self._shards = mesh_lib.n_batch_shards(self.mesh)
        X = jnp.asarray(X)
        self._true_batch = int(X.shape[0])
        pad = (-self._true_batch) % self._shards
        if pad:
            X = jnp.concatenate([X, jnp.zeros((pad, X.shape[1]), X.dtype)])
        batch_sh = mesh_lib.batch_sharding(self.mesh)
        repl = mesh_lib.replicated_sharding(self.mesh)
        super().__init__(jax.device_put(device, repl), jax.device_put(X, batch_sh), plan)
        self._batch_sharding = batch_sh

    def init_state(self):
        return jax.device_put(super().init_state(), self._batch_sharding)

    def run(self, idx, units, mask=None, length=1, *, X=None, readout=False,
            fresh=False):
        idx, probs = super().run(
            idx, units, mask, length, X=X, readout=readout, fresh=fresh
        )
        if probs is not None:
            probs = probs[: self._true_batch]
        return idx, probs

    def readout(self, idx):
        return super().readout(idx)[: self._true_batch]

    def place_slots(self, *arrays):
        """Slot-batch state (idx [S,T], X [S,F], masks/units [S]) gets
        its leading slot axis placed via ``mesh.batch_pspec`` — the slot
        batch IS the mesh's data-parallel batch, so every masked segment
        dispatch splits across shards with zero collectives."""
        return tuple(jax.device_put(a, self._batch_sharding) for a in arrays)

    def _place_unit_mask(self, units, mask):
        return self.place_slots(units, mask)


# ---------------------------------------------------------------------------
# The step backend every Session wraps.
# ---------------------------------------------------------------------------


class ForestStepBackend:
    """Step-level forest executor over a compiled :class:`StepPlan`.

    A run of r consecutive steps of the same tree executes as fused
    segments of power-of-two length through the selected executor (the
    tree id is a traced scalar, so runs of different trees share each
    trace).  ``advance`` remains exact at single-step granularity — a
    segment splits into smaller power-of-two pieces whenever the
    requested step budget ends inside it, which by construction mints no
    new trace lengths.
    """

    def __init__(
        self,
        device: engine.DeviceForest,
        X,
        order: np.ndarray,
        backend: Optional[str] = None,
        plan: Optional[StepPlan] = None,
        **backend_opts,
    ):
        self.backend_name = backend if backend is not None else default_backend()
        self.plan = plan if plan is not None else StepPlan.compile(order)
        self.order = self.plan.order
        self.executor = get_backend(self.backend_name)(
            device, X, self.plan, **backend_opts
        )
        self.device = self.executor.device
        self.X = self.executor.X
        self.idx = self.executor.init_state()
        self.pos = 0
        #: distinct fused-segment lengths dispatched so far — each is one
        #: cached jit trace; the parity/trace tests assert the bound.
        self.dispatched_lengths: set[int] = set()

    @property
    def total_steps(self) -> int:
        return self.plan.total_steps

    @property
    def remaining(self) -> int:
        return self.total_steps - self.pos

    def advance(self, k: int) -> int:
        """Execute up to k more steps (plan-fused); returns steps taken."""
        k = min(int(k), self.remaining)
        taken = 0
        while taken < k:
            s = self.plan.segment_at(self.pos)
            seg_end = int(self.plan.seg_starts[s + 1])
            step = min(k - taken, seg_end - self.pos)
            unit = self.plan.units_dev[s]
            # fresh = this dispatch starts the unit's FIRST plan segment
            # at offset 0 (every walker still at the root); only the
            # first power-of-two piece of a split keeps the property
            fresh = bool(
                self.plan.seg_fresh is not None
                and self.plan.seg_fresh[s]
                and self.pos == int(self.plan.seg_starts[s])
            )
            for p in pow2_decompose(step, cap=self.plan.max_segment):
                self.idx, _ = self.executor.run(
                    self.idx, unit, length=p, fresh=fresh
                )
                fresh = False
                self.dispatched_lengths.add(p)
            self.pos += step
            taken += step
        return taken

    def predict_proba(self) -> np.ndarray:
        return np.asarray(self.executor.readout(self.idx))

    def predict(self) -> np.ndarray:
        return self.predict_proba().argmax(axis=1)
