"""Deadline-aware anytime runtime — the serving half of ``repro.schedule``.

:class:`AnytimeRuntime` wraps any :class:`~repro.core.anytime.AnytimeProgram`
(a random forest via :class:`ForestProgram`, a transformer ensemble via
:class:`repro.serving.anytime_depth.EnsembleProgram`, or anything else
decomposable into schedulable units) and owns:

* **order generation** through the :mod:`repro.schedule.policies` registry,
  memoized in a content-hash cache keyed on (quality table, policy config)
  so repeated sessions never re-run Dijkstra/Squirrel;
* **sessions** — interruptible executions with ``advance(k)``,
  ``advance_until(deadline_ms)`` and ``predict()`` after any prefix;
* **RLE-fused execution** — consecutive same-unit steps in an order are
  run-length encoded and each run executes as ONE ``lax.scan`` segment
  instead of per-step dispatches (depth-style orders collapse from
  U*S dispatches to U);
* **batched evaluation** — :func:`evaluate_orders` runs the accuracy
  curves of many orders in a single vmapped pass over the step axis.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from functools import partial
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.forest.forest import ForestArrays
from repro.schedule.policies import OrderPolicy, get_order_policy, list_orders

PolicyLike = Union[str, OrderPolicy]


def _as_policy(policy: PolicyLike, **overrides) -> OrderPolicy:
    if isinstance(policy, OrderPolicy):
        return policy
    return get_order_policy(policy, **overrides)


def check_order(order: np.ndarray, n_units: int, unit_steps: int) -> np.ndarray:
    """Validate a step order, raising a ValueError that names the first
    offending unit (unlike a bare assert, this survives ``python -O``)."""
    order = np.asarray(order)
    expect = n_units * unit_steps
    if order.shape[0] != expect:
        raise ValueError(
            f"invalid step order: length {order.shape[0]}, expected "
            f"{n_units} units x {unit_steps} steps = {expect}"
        )
    counts = np.bincount(order, minlength=n_units)
    bad = np.flatnonzero(counts != unit_steps)
    if bad.size:
        t = int(bad[0])
        raise ValueError(
            f"invalid step order: unit {t} takes {int(counts[t])} steps, "
            f"expected {unit_steps} (and {bad.size - 1} more offending units)"
        )
    return order


def rle_chunks(order: np.ndarray) -> list[tuple[int, int]]:
    """Run-length encode a step order into (unit_id, run_length) chunks.

    Consecutive equal entries fuse into one chunk, which the forest
    backend executes as a single ``lax.scan`` segment.
    """
    order = np.asarray(order)
    if order.size == 0:
        return []
    change = np.flatnonzero(np.diff(order)) + 1
    starts = np.concatenate([[0], change])
    ends = np.concatenate([change, [order.size]])
    return [(int(order[s]), int(e - s)) for s, e in zip(starts, ends)]


# ---------------------------------------------------------------------------
# Forest execution backend (RLE-fused).
# ---------------------------------------------------------------------------


class ForestStepBackend:
    """Step-level forest executor over an RLE-chunked order.

    A run of r consecutive steps of the same tree executes as one jitted
    ``lax.scan`` of length r (compiled once per distinct run length; the
    tree id is a traced scalar, so runs of different trees share the
    compilation).  ``advance`` remains exact at single-step granularity —
    a chunk is split whenever the requested step budget ends inside it.
    """

    def __init__(self, device: engine.DeviceForest, X, order: np.ndarray):
        self.device = device
        self.X = jnp.asarray(X)
        self.order = np.asarray(order, dtype=np.int32)
        self.idx = engine.init_state(device, self.X.shape[0])
        self.pos = 0
        chunks = rle_chunks(self.order)
        self._chunk_units = np.asarray([u for u, _ in chunks], dtype=np.int32)
        self._chunk_starts = np.concatenate(
            [[0], np.cumsum([n for _, n in chunks], dtype=np.int64)]
        )

        @partial(jax.jit, static_argnums=(2,))
        def _run(idx, tree_id, n):
            def body(i, _):
                return engine.tree_step(self.device, self.X, i, tree_id), None

            return jax.lax.scan(body, idx, None, length=n)[0]

        self._run = _run

    @property
    def total_steps(self) -> int:
        return int(self.order.shape[0])

    @property
    def remaining(self) -> int:
        return self.total_steps - self.pos

    def advance(self, k: int) -> int:
        """Execute up to k more steps (RLE-fused); returns steps taken."""
        k = min(int(k), self.remaining)
        taken = 0
        while taken < k:
            ci = int(np.searchsorted(self._chunk_starts, self.pos, side="right")) - 1
            seg_end = int(self._chunk_starts[ci + 1])
            step = min(k - taken, seg_end - self.pos)
            tree = jnp.int32(self._chunk_units[ci])
            self.idx = self._run(self.idx, tree, step)
            self.pos += step
            taken += step
        return taken

    def predict_proba(self) -> np.ndarray:
        return np.asarray(engine.predict_from_state(self.device, self.idx))

    def predict(self) -> np.ndarray:
        return self.predict_proba().argmax(axis=1)


@dataclasses.dataclass
class ForestProgram:
    """Adapter making a trained forest an :class:`AnytimeProgram`.

    Provide either the ordering set (``X_order``/``y_order``) — the
    quality table is computed on demand — or a precomputed ``path_probs``
    table alongside ``y_order``.
    """

    forest: ForestArrays
    y_order: np.ndarray
    X_order: Optional[np.ndarray] = None
    path_probs: Optional[np.ndarray] = None
    device: engine.DeviceForest = dataclasses.field(init=False, repr=False)

    def __post_init__(self):
        if self.X_order is None and self.path_probs is None:
            raise ValueError("ForestProgram needs X_order or path_probs")
        self.device = engine.to_device(self.forest)

    @property
    def n_units(self) -> int:
        return self.forest.n_trees

    @property
    def unit_steps(self) -> int:
        return self.forest.max_depth

    def quality_table(self) -> tuple[np.ndarray, np.ndarray]:
        if self.path_probs is None:
            self.path_probs = engine.path_probs_np(self.forest, self.X_order)
        return self.path_probs, np.asarray(self.y_order)

    def make_session(self, order: np.ndarray, inputs) -> ForestStepBackend:
        return ForestStepBackend(self.device, inputs, order)


# ---------------------------------------------------------------------------
# Deadline-aware session + runtime.
# ---------------------------------------------------------------------------


class Session:
    """Interruptible inference over any step backend.

    ``advance(k)`` runs up to k steps; ``advance_until(deadline_ms)``
    runs chunks until a wall-clock deadline; ``predict()`` is valid after
    ANY prefix — the deployment-facing realization of Sec. V, shared by
    forests and transformer ensembles.
    """

    def __init__(self, backend, chunk: int = 8, clock=time.perf_counter):
        self.backend = backend
        self.chunk = int(chunk)
        self.clock = clock

    @property
    def total_steps(self) -> int:
        return self.backend.total_steps

    @property
    def pos(self) -> int:
        return self.backend.pos

    @property
    def remaining(self) -> int:
        return self.total_steps - self.backend.pos

    def advance(self, k: int) -> int:
        if k <= 0:
            return 0
        return self.backend.advance(k)

    def advance_until(self, deadline_ms: float, chunk: Optional[int] = None) -> int:
        """Advance in chunks until ``deadline_ms`` elapses or the order is
        exhausted; returns steps taken.  The deadline is checked between
        chunks, so the overshoot is bounded by one chunk's runtime."""
        chunk = self.chunk if chunk is None else int(chunk)
        t0 = self.clock()
        budget_s = deadline_ms / 1e3
        taken = 0
        while self.remaining and (self.clock() - t0) < budget_s:
            taken += self.backend.advance(min(chunk, self.remaining))
        return taken

    def run_to_completion(self) -> int:
        return self.advance(self.remaining)

    def predict(self) -> np.ndarray:
        return self.backend.predict()

    def predict_proba(self) -> np.ndarray:
        fn = getattr(self.backend, "predict_proba", None)
        if fn is None:
            fn = self.backend.predict_logprobs
        return fn()

    def __getattr__(self, name: str):
        # Backend-specific state (e.g. the forest index array ``idx``)
        # stays reachable through the wrapper.
        return getattr(self.backend, name)


class AnytimeRuntime:
    """Single serving entry point for anytime inference.

    Wraps an :class:`AnytimeProgram` (forest or ensemble) and owns order
    generation (policy registry + content-hash cache), session creation,
    and batched order evaluation.

        rt = AnytimeRuntime(ForestProgram(forest, y_order=y, X_order=X))
        sess = rt.session(X_test, "backward_squirrel")
        sess.advance_until(deadline_ms=2.0)
        preds = sess.predict()
    """

    def __init__(self, program):
        self.program = program
        self._order_cache: dict[str, np.ndarray] = {}
        self._quality: Optional[tuple[np.ndarray, np.ndarray]] = None
        self._quality_digest: Optional[str] = None

    def quality_table(self) -> tuple[np.ndarray, np.ndarray]:
        if self._quality is None:
            self._quality = self.program.quality_table()
            # Digest once: the table is immutable after this point, and
            # per-request order()/session() calls must not re-hash a
            # potentially tens-of-MB array.
            pp, y = self._quality
            h = hashlib.sha1()
            h.update(np.ascontiguousarray(pp).tobytes())
            h.update(np.ascontiguousarray(y).tobytes())
            self._quality_digest = h.hexdigest()
        return self._quality

    def _cache_key(self, policy: OrderPolicy) -> str:
        return f"{self._quality_digest}:{policy.cache_key()}"

    def order(self, policy: PolicyLike, **overrides) -> np.ndarray:
        """Generate (or fetch from cache) the step order for ``policy``."""
        policy = _as_policy(policy, **overrides)
        pp, y = self.quality_table()
        key = self._cache_key(policy)
        hit = self._order_cache.get(key)
        if hit is None:
            hit = check_order(
                policy.generate(pp, y), self.program.n_units, self.program.unit_steps
            )
            self._order_cache[key] = hit
        return hit

    def session(
        self,
        inputs,
        policy: PolicyLike = "backward_squirrel",
        order: Optional[np.ndarray] = None,
        chunk: int = 8,
        clock=time.perf_counter,
    ) -> Session:
        if order is None:
            order = self.order(policy)
        else:
            order = check_order(order, self.program.n_units, self.program.unit_steps)
        return Session(self.program.make_session(order, inputs), chunk=chunk, clock=clock)

    def evaluate_orders(
        self, X, y, names: Optional[Sequence[PolicyLike]] = None
    ) -> dict[str, np.ndarray]:
        """Accuracy curves of many orders in ONE vmapped batched pass.

        ``names`` defaults to every registered order.  Requires the
        program to expose a :class:`~repro.core.engine.DeviceForest` as
        ``.device`` (forests); other programs fall back to serial
        per-order sessions."""
        policies = [_as_policy(n) for n in (names if names is not None else list_orders())]
        stacked = {p.name: self.order(p) for p in policies}
        device = getattr(self.program, "device", None)
        if device is not None:
            return evaluate_orders(device, X, y, stacked)
        out = {}
        for name, order in stacked.items():
            sess = self.session(X, order=order)
            curve = [float(np.mean(sess.predict() == y))]
            while sess.remaining:
                sess.advance(1)
                curve.append(float(np.mean(sess.predict() == y)))
            out[name] = np.asarray(curve)
        return out


@partial(jax.jit, static_argnums=())
def _batched_curves(device: engine.DeviceForest, X, orders_mat, y):
    return jax.vmap(lambda o: engine.run_order(device, X, o, y)[1])(orders_mat)


def evaluate_orders(
    device: engine.DeviceForest, X, y, orders_by_name: dict[str, np.ndarray]
) -> dict[str, np.ndarray]:
    """Run every order's accuracy curve in a single vmapped pass.

    All orders must share the same length (they do by construction:
    n_trees * max_depth).  Returns {name: curve [steps+1]}."""
    if not orders_by_name:
        return {}
    names = list(orders_by_name)
    mat = jnp.asarray(np.stack([orders_by_name[n] for n in names]))
    curves = _batched_curves(device, jnp.asarray(X), mat, jnp.asarray(y))
    curves = np.asarray(curves)
    return {n: curves[i] for i, n in enumerate(names)}
