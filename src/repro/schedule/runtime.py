"""Deadline-aware anytime runtime — the serving half of ``repro.schedule``.

:class:`AnytimeRuntime` wraps any :class:`~repro.core.anytime.AnytimeProgram`
(a random forest via :class:`ForestProgram`, a transformer ensemble via
:class:`repro.serving.anytime_depth.EnsembleProgram`, or anything else
decomposable into schedulable units) and owns:

* **order generation** through the :mod:`repro.schedule.policies` registry,
  memoized in a content-hash cache keyed on (quality table, policy config)
  so repeated sessions never re-run Dijkstra/Squirrel;
* **sessions** — interruptible executions with ``advance(k)``,
  ``advance_until(deadline_ms)`` and ``predict()`` after any prefix;
* **backend selection** — execution itself is pluggable
  (:mod:`repro.schedule.backends`): orders compile once into power-of-two
  bucketed :class:`~repro.schedule.backends.StepPlan` segments, then run
  on the ``jnp-ref`` oracle, the ``pallas`` MXU kernels, or ``sharded``
  across a mesh — ``AnytimeRuntime(..., backend="pallas")`` or
  per-session ``session(X, policy, backend=...)``;
* **batched evaluation** — :func:`evaluate_orders` runs the accuracy
  curves of many orders in a single vmapped pass over the step axis.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from functools import partial
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.forest.forest import ForestArrays
from repro.schedule.backends import (  # noqa: F401  (re-exported surface)
    ExecutorCore,
    ForestStepBackend,
    StepPlan,
    check_order,
    default_backend,
    get_backend,
    list_backends,
    pow2_floor,
    register_backend,
    rle_chunks,
)
from repro.schedule.policies import OrderPolicy, get_order_policy, list_orders

PolicyLike = Union[str, OrderPolicy]


def _as_policy(policy: PolicyLike, **overrides) -> OrderPolicy:
    if isinstance(policy, OrderPolicy):
        return policy
    return get_order_policy(policy, **overrides)


@dataclasses.dataclass
class ForestProgram:
    """Adapter making a trained forest an :class:`AnytimeProgram`.

    Provide either the ordering set (``X_order``/``y_order``) — the
    quality table is computed on demand — or a precomputed ``path_probs``
    table alongside ``y_order``.  Step-plans compile once per distinct
    order (content-addressed) and are shared across sessions.
    """

    forest: ForestArrays
    y_order: np.ndarray
    X_order: Optional[np.ndarray] = None
    path_probs: Optional[np.ndarray] = None
    device: engine.DeviceForest = dataclasses.field(init=False, repr=False)
    _plan_cache: dict = dataclasses.field(
        init=False, repr=False, default_factory=dict
    )

    def __post_init__(self):
        if self.X_order is None and self.path_probs is None:
            raise ValueError("ForestProgram needs X_order or path_probs")
        self.device = engine.to_device(self.forest)

    @property
    def n_units(self) -> int:
        return self.forest.n_trees

    @property
    def unit_steps(self) -> int:
        return self.forest.max_depth

    @property
    def n_features(self) -> Optional[int]:
        """Expected input-row width, when the program can know it (from
        the ordering set).  The serving layer uses this to size slot
        batches so a malformed first request cannot define the lane
        width for everyone else; None = unknown (first request decides).
        """
        if self.X_order is None:
            return None
        return int(np.asarray(self.X_order).shape[1])

    def quality_table(self) -> tuple[np.ndarray, np.ndarray]:
        if self.path_probs is None:
            self.path_probs = engine.path_probs_np(self.forest, self.X_order)
        return self.path_probs, np.asarray(self.y_order)

    def step_plan(self, order: np.ndarray) -> StepPlan:
        """Compile-once step-plan, content-addressed on the order bytes."""
        order = np.asarray(order, dtype=np.int32)
        key = hashlib.sha1(order.tobytes()).hexdigest()
        plan = self._plan_cache.get(key)
        if plan is None:
            plan = StepPlan.compile(order)
            self._plan_cache[key] = plan
        return plan

    def make_session(
        self, order: np.ndarray, inputs, backend: Optional[str] = None, **backend_opts
    ) -> ForestStepBackend:
        return ForestStepBackend(
            self.device, inputs, order,
            backend=backend, plan=self.step_plan(order), **backend_opts,
        )

    def make_slot_batch(
        self,
        order: np.ndarray,
        capacity: int,
        n_features: int,
        backend: Optional[str] = None,
        **backend_opts,
    ) -> "SessionBatch":
        """Slot-batched execution surface for the ``repro.serve``
        scheduler: ``capacity`` recyclable request slots sharing this
        program's compiled (content-addressed) step plan."""
        return SessionBatch(
            self.device, self.step_plan(np.asarray(order, dtype=np.int32)),
            capacity, n_features, backend=backend, **backend_opts,
        )

    def prior_readout(self) -> np.ndarray:
        """The 0-step ("empty") anytime readout [C]: every tree at its
        root — what a request that never got a step returns."""
        roots = engine.init_state(self.device, 1)
        return np.asarray(engine.predict_from_state(self.device, roots))[0]


# ---------------------------------------------------------------------------
# Slot-batched execution: the state surface the repro.serve scheduler
# drives.
# ---------------------------------------------------------------------------


class SessionBatch:
    """Fixed-capacity slot batch executing ONE compiled :class:`StepPlan`.

    Where a :class:`Session` serves one request, a ``SessionBatch``
    multiplexes up to ``capacity`` concurrent requests (*slots*) onto a
    single device dispatch stream.  Every slot owns an input row, an
    index-array row, and a plan cursor; :meth:`advance_segment` issues
    one fused masked dispatch in which each in-flight slot advances its
    OWN current plan segment — the vector-``units`` shape of the same
    :meth:`~repro.schedule.backends.ExecutorCore.run` entry point solo
    sessions use (on ``pallas`` this is the masked-slot kernel, with
    the boundary readout fusable into the same launch).

    Invariants the serving layer relies on:

    * all in-flight slots advance by the same power-of-two length ``L``
      per dispatch, chosen so no slot crosses its current segment
      boundary — slot state after ``pos`` steps is bit-identical to a
      solo session advanced ``pos`` steps (prefix semantics preserved
      per slot, even for slots admitted mid-flight and out of phase);
    * admission and retirement happen strictly between dispatches, i.e.
      at segment boundaries — a readout never observes a torn
      mid-segment state;
    * dispatched lengths are plan powers of two, so the ≤ 8-trace
      compile bound of solo sessions carries over.
    """

    def __init__(
        self,
        device: engine.DeviceForest,
        plan: StepPlan,
        capacity: int,
        n_features: int,
        backend: Optional[str] = None,
        dtype=np.float32,
        **backend_opts,
    ):
        backend_name = backend if backend is not None else default_backend()
        if backend_name == "sharded":
            # the slot axis shards over the mesh: round capacity up so
            # slots divide evenly (a few extra recyclable slots, never
            # fewer than asked for)
            from repro.launch import mesh as mesh_lib
            from repro.schedule.backends import resolve_device

            mesh = backend_opts.get("mesh")
            if mesh is None:
                pin = backend_opts.get("pin_device")
                if pin is not None:
                    # device-pinned pool: a degenerate one-device mesh,
                    # NOT the all-devices host mesh
                    mesh = mesh_lib.make_single_device_mesh(
                        resolve_device(pin))
                else:
                    mesh = mesh_lib.make_host_mesh(data=len(jax.devices()))
                backend_opts = {**backend_opts, "mesh": mesh}
            shards = mesh_lib.n_batch_shards(mesh)
            capacity += (-capacity) % shards
        self.plan = plan
        self.capacity = int(capacity)
        self.backend_name = backend_name
        X0 = np.zeros((self.capacity, int(n_features)), dtype=dtype)
        self.executor = get_backend(backend_name)(device, X0, plan, **backend_opts)
        self.X = self.executor.X
        self.idx = self.executor.init_state()
        self.pos = np.zeros(self.capacity, dtype=np.int64)      # plan cursor/slot
        self.active = np.zeros(self.capacity, dtype=bool)
        # per-slot step-budget cap (admission="degrade"): a slot stops
        # dispatching at min(budget, total_steps) — the readout there is
        # still an exact prefix boundary, just of a shorter prefix
        self.budget = np.full(self.capacity, plan.total_steps, dtype=np.int64)
        self.dispatched_lengths: set[int] = set()
        # admissions buffer host-side and flush as ONE fused scatter at
        # the next dispatch/readout — per-slot eager device writes would
        # cost a dispatch per admitted request.  _pending_idx holds the
        # resumed index rows of mid-flight (work-stolen) admissions;
        # absent slots start from the all-roots state.
        self._pending_rows: dict[int, np.ndarray] = {}
        self._pending_idx: dict[int, np.ndarray] = {}

    @property
    def total_steps(self) -> int:
        return self.plan.total_steps

    @property
    def n_active(self) -> int:
        return int(self.active.sum())

    def open_slots(self) -> list[int]:
        return [int(s) for s in np.flatnonzero(~self.active)]

    def stepping_slots(self) -> np.ndarray:
        """Active slots that still have plan steps left within their
        step budget."""
        return np.flatnonzero(self.active & (self.pos < self.budget))

    def admit(
        self,
        slot: int,
        x_row,
        budget: Optional[int] = None,
        idx_row=None,
        pos: int = 0,
    ) -> None:
        """Recycle ``slot`` for a new request: reset its index row to the
        all-roots state and install its input row.  Must be called
        between dispatches (always true for host callers); the device
        writes are deferred and fused with other admissions.

        ``budget`` caps how many plan steps the slot may execute
        (``admission="degrade"``): the slot stops dispatching exactly at
        ``budget`` steps — an exact prefix boundary — and is then ready
        to retire.  None = the full plan.

        ``idx_row``/``pos`` resume a MID-FLIGHT request (work stealing
        between pools): the slot starts from the given index-array row
        at plan position ``pos`` instead of the all-roots state.  The
        index row must be exactly the state a solo session holds after
        ``pos`` steps of this batch's plan — since node indices are a
        deterministic function of (input row, plan prefix), the resumed
        slot's every future boundary readout stays bit-identical to an
        unstolen run, which is what preserves the parity guarantee
        across pools sharing a content-addressed plan."""
        if self.active[slot]:
            raise ValueError(f"slot {slot} is still occupied")
        x_row = np.asarray(x_row, dtype=self.X.dtype).reshape(-1)
        if x_row.shape[0] != self.X.shape[1]:
            raise ValueError(
                f"request row has {x_row.shape[0]} features, batch expects "
                f"{self.X.shape[1]}"
            )
        total = self.plan.total_steps
        pos = int(pos)
        if pos < 0 or pos > total:
            raise ValueError(f"resume position {pos} outside [0, {total}]")
        if pos and idx_row is None:
            raise ValueError("resuming at pos > 0 requires idx_row")
        if budget is None:
            budget = total
        budget = int(budget)
        if budget < 1:
            raise ValueError(f"budget must be >= 1 step, got {budget}")
        if idx_row is not None:
            idx_row = np.asarray(idx_row).reshape(-1)
            if idx_row.shape[0] != int(self.idx.shape[1]):
                raise ValueError(
                    f"resumed index row has {idx_row.shape[0]} trees, batch "
                    f"expects {int(self.idx.shape[1])}"
                )
            self._pending_idx[slot] = idx_row
        self._pending_rows[slot] = x_row
        self.pos[slot] = pos
        self.budget[slot] = min(budget, total)
        self.active[slot] = True

    def retire(self, slot: int) -> None:
        self.active[slot] = False
        self.budget[slot] = self.plan.total_steps
        self._pending_rows.pop(slot, None)
        self._pending_idx.pop(slot, None)

    def pending_admission(self, slot: int) -> bool:
        """Whether ``slot``'s admission is still buffered host-side (its
        device state is stale until the next flush) — a pending slot can
        be re-queued by :meth:`cancel_admit` at zero device cost."""
        return slot in self._pending_rows

    def cancel_admit(self, slot: int) -> None:
        """Undo a still-buffered admission (work stealing: a queued-but-
        never-dispatched slot migrates as a plain waiting request).  Only
        valid while :meth:`pending_admission` holds."""
        if slot not in self._pending_rows:
            raise ValueError(
                f"slot {slot} has no pending admission to cancel")
        self._pending_rows.pop(slot)
        self._pending_idx.pop(slot, None)
        self.pos[slot] = 0
        self.budget[slot] = self.plan.total_steps
        self.active[slot] = False

    def _flush_admissions(self) -> None:
        if not self._pending_rows:
            return
        slots = np.asarray(sorted(self._pending_rows), dtype=np.int32)
        rows = np.stack([self._pending_rows[int(s)] for s in slots])
        # fresh admissions reset to the all-roots state; resumed (stolen)
        # admissions install their exact prefix state — ONE fused scatter
        # either way
        idx_rows = np.zeros(
            (len(slots), int(self.idx.shape[1])), dtype=self.idx.dtype)
        for i, s in enumerate(slots):
            resumed = self._pending_idx.get(int(s))
            if resumed is not None:
                idx_rows[i] = resumed
        self._pending_rows.clear()
        self._pending_idx.clear()
        self.X = self.X.at[slots].set(jnp.asarray(rows))
        self.idx = self.idx.at[slots].set(jnp.asarray(idx_rows))
        self.X, self.idx = self.executor.place_slots(self.X, self.idx)

    def advance_segment(self, readout: bool = False):
        """One fused masked dispatch through the executor's unified
        plan-segment entry point: every in-flight slot advances ``L``
        steps of its own current plan segment, where ``L`` is the
        largest power of two that crosses no slot's segment boundary
        (:func:`~repro.schedule.backends.pow2_floor` — the same
        bucketing the solo path uses, so the trace bound is shared).

        Returns ``L`` (0 when nothing can step) — or, with
        ``readout=True``, ``(L, probs)`` where ``probs`` is the new
        boundary's anytime readout fused into the SAME dispatch (one
        kernel launch on ``pallas``), or None when nothing stepped."""
        self._flush_admissions()
        step_ids = self.stepping_slots()
        if step_ids.size == 0:
            return (0, None) if readout else 0
        plan = self.plan
        segs = np.searchsorted(plan.seg_starts, self.pos[step_ids], side="right") - 1
        units = np.zeros(self.capacity, dtype=np.int32)
        units[step_ids] = plan.seg_units[segs]
        # a budget-capped slot (admission="degrade") stops exactly at its
        # budget: the dispatch length may not cross a segment boundary
        # NOR any stepping slot's budget
        bound = np.minimum(plan.seg_starts[segs + 1], self.budget[step_ids])
        rem = bound - self.pos[step_ids]
        L = pow2_floor(int(rem.min()), plan.max_segment)
        mask = np.zeros(self.capacity, dtype=bool)
        mask[step_ids] = True
        self.idx, probs = self.executor.run(
            self.idx, jnp.asarray(units), jnp.asarray(mask), L,
            X=self.X, readout=readout,
        )
        self.pos[step_ids] += L
        self.dispatched_lengths.add(L)
        return (L, probs) if readout else L

    def readout(self) -> jax.Array:
        """Device-side anytime readout [capacity, C] of the CURRENT
        boundary (asynchronous — ``np.asarray`` it to sync; the serving
        loop does so one dispatch later, double-buffered)."""
        self._flush_admissions()
        return self.executor.readout(self.idx)


# ---------------------------------------------------------------------------
# Deadline-aware session + runtime.
# ---------------------------------------------------------------------------


class Session:
    """Interruptible inference over any step backend.

    ``advance(k)`` runs up to k steps; ``advance_until(deadline_ms)``
    runs chunks until a wall-clock deadline; ``predict()`` is valid after
    ANY prefix — the deployment-facing realization of Sec. V, shared by
    forests and transformer ensembles.
    """

    def __init__(self, backend, chunk: int = 8, clock=time.perf_counter):
        self.backend = backend
        self.chunk = int(chunk)
        self.clock = clock

    @property
    def total_steps(self) -> int:
        return self.backend.total_steps

    @property
    def pos(self) -> int:
        return self.backend.pos

    @property
    def remaining(self) -> int:
        return self.total_steps - self.backend.pos

    def advance(self, k: int) -> int:
        if k <= 0:
            return 0
        return self.backend.advance(k)

    def advance_until(self, deadline_ms: float, chunk: Optional[int] = None) -> int:
        """Advance in chunks until ``deadline_ms`` elapses or the order is
        exhausted; returns steps taken.  The deadline is checked between
        chunks, so the overshoot is bounded by one chunk's runtime.
        Non-positive deadlines take no steps (and never read the clock)."""
        if deadline_ms <= 0:
            return 0
        chunk = self.chunk if chunk is None else int(chunk)
        t0 = self.clock()
        budget_s = deadline_ms / 1e3
        taken = 0
        while self.remaining and (self.clock() - t0) < budget_s:
            taken += self.backend.advance(min(chunk, self.remaining))
        return taken

    def run_to_completion(self) -> int:
        return self.advance(self.remaining)

    def predict(self) -> np.ndarray:
        return self.backend.predict()

    def predict_proba(self) -> np.ndarray:
        fn = getattr(self.backend, "predict_proba", None)
        if fn is None:
            fn = self.backend.predict_logprobs
        return fn()

    def __getattr__(self, name: str):
        # Backend-specific state (e.g. the forest index array ``idx``)
        # stays reachable through the wrapper.  Guard the ``backend``
        # attribute itself: before __init__ runs (unpickling, __new__)
        # it is absent from __dict__, and falling through to
        # getattr(self.backend, ...) would recurse forever.
        backend = self.__dict__.get("backend")
        if backend is None:
            raise AttributeError(name)
        return getattr(backend, name)


class AnytimeRuntime:
    """Single serving entry point for anytime inference.

    Wraps an :class:`AnytimeProgram` (forest or ensemble) and owns order
    generation (policy registry + content-hash cache), session creation
    with pluggable execution backends, and batched order evaluation.

        rt = AnytimeRuntime(ForestProgram(forest, y_order=y, X_order=X),
                            backend="pallas")
        sess = rt.session(X_test, "backward_squirrel")
        sess.advance_until(deadline_ms=2.0)
        preds = sess.predict()

    ``backend`` (here or per-``session``) picks the execution layer:
    ``jnp-ref`` (oracle scan), ``pallas`` (MXU kernels), ``sharded``
    (mesh batch parallelism); ``None`` auto-selects by
    ``jax.default_backend()``.
    """

    def __init__(self, program, backend: Optional[str] = None):
        if backend is not None:
            get_backend(backend)  # fail fast on typos
        self.program = program
        self.backend = backend
        self._order_cache: dict[str, np.ndarray] = {}
        self._quality: Optional[tuple[np.ndarray, np.ndarray]] = None
        self._quality_digest: Optional[str] = None

    def quality_table(self) -> tuple[np.ndarray, np.ndarray]:
        if self._quality is None:
            self._quality = self.program.quality_table()
            # Digest once: the table is immutable after this point, and
            # per-request order()/session() calls must not re-hash a
            # potentially tens-of-MB array.
            pp, y = self._quality
            h = hashlib.sha1()
            h.update(np.ascontiguousarray(pp).tobytes())
            h.update(np.ascontiguousarray(y).tobytes())
            self._quality_digest = h.hexdigest()
        return self._quality

    def _cache_key(self, policy: OrderPolicy) -> str:
        return f"{self._quality_digest}:{policy.cache_key()}"

    def order(self, policy: PolicyLike, **overrides) -> np.ndarray:
        """Generate (or fetch from cache) the step order for ``policy``."""
        policy = _as_policy(policy, **overrides)
        pp, y = self.quality_table()
        key = self._cache_key(policy)
        hit = self._order_cache.get(key)
        if hit is None:
            hit = check_order(
                policy.generate(pp, y), self.program.n_units, self.program.unit_steps
            )
            self._order_cache[key] = hit
        return hit

    def session(
        self,
        inputs,
        policy: PolicyLike = "backward_squirrel",
        order: Optional[np.ndarray] = None,
        chunk: int = 8,
        clock=time.perf_counter,
        backend: Optional[str] = None,
        **backend_opts,
    ) -> Session:
        if order is None:
            order = self.order(policy)
        else:
            order = check_order(order, self.program.n_units, self.program.unit_steps)
        backend = backend if backend is not None else self.backend
        if backend is None and not backend_opts:
            # old two-arg make_session protocol stays valid for programs
            # that don't select backends (e.g. custom user programs)
            step_backend = self.program.make_session(order, inputs)
        else:
            step_backend = self.program.make_session(
                order, inputs, backend=backend, **backend_opts
            )
        return Session(step_backend, chunk=chunk, clock=clock)

    def evaluate_orders(
        self, X, y, names: Optional[Sequence[PolicyLike]] = None
    ) -> dict[str, np.ndarray]:
        """Accuracy curves of many orders in ONE vmapped batched pass.

        ``names`` defaults to every registered order.  Requires the
        program to expose a :class:`~repro.core.engine.DeviceForest` as
        ``.device`` (forests); other programs fall back to serial
        per-order sessions."""
        policies = [_as_policy(n) for n in (names if names is not None else list_orders())]
        stacked = {p.name: self.order(p) for p in policies}
        device = getattr(self.program, "device", None)
        if device is not None:
            return evaluate_orders(device, X, y, stacked)
        out = {}
        for name, order in stacked.items():
            sess = self.session(X, order=order)
            curve = [float(np.mean(sess.predict() == y))]
            while sess.remaining:
                sess.advance(1)
                curve.append(float(np.mean(sess.predict() == y)))
            out[name] = np.asarray(curve)
        return out


@partial(jax.jit, static_argnums=())
def _batched_curves(device: engine.DeviceForest, X, orders_mat, y):
    return jax.vmap(lambda o: engine.run_order(device, X, o, y)[1])(orders_mat)


def evaluate_orders(
    device: engine.DeviceForest, X, y, orders_by_name: dict[str, np.ndarray]
) -> dict[str, np.ndarray]:
    """Run every order's accuracy curve in a single vmapped pass.

    All orders must share the same length (they do by construction:
    n_trees * max_depth).  Returns {name: curve [steps+1]}."""
    if not orders_by_name:
        return {}
    names = list(orders_by_name)
    mat = jnp.asarray(np.stack([orders_by_name[n] for n in names]))
    curves = _batched_curves(device, jnp.asarray(X), mat, jnp.asarray(y))
    curves = np.asarray(curves)
    return {n: curves[i] for i, n in enumerate(names)}
