"""Deadline-aware anytime runtime — the serving half of ``repro.schedule``.

:class:`AnytimeRuntime` wraps any :class:`~repro.core.anytime.AnytimeProgram`
(a random forest via :class:`ForestProgram`, a transformer ensemble via
:class:`repro.serving.anytime_depth.EnsembleProgram`, or anything else
decomposable into schedulable units) and owns:

* **order generation** through the :mod:`repro.schedule.policies` registry,
  memoized in a content-hash cache keyed on (quality table, policy config)
  so repeated sessions never re-run Dijkstra/Squirrel;
* **sessions** — interruptible executions with ``advance(k)``,
  ``advance_until(deadline_ms)`` and ``predict()`` after any prefix;
* **backend selection** — execution itself is pluggable
  (:mod:`repro.schedule.backends`): orders compile once into power-of-two
  bucketed :class:`~repro.schedule.backends.StepPlan` segments, then run
  on the ``jnp-ref`` oracle, the ``pallas`` MXU kernels, or ``sharded``
  across a mesh — ``AnytimeRuntime(..., backend="pallas")`` or
  per-session ``session(X, policy, backend=...)``;
* **batched evaluation** — :func:`evaluate_orders` runs the accuracy
  curves of many orders in a single vmapped pass over the step axis.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from functools import partial
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.forest.forest import ForestArrays
from repro.schedule.backends import (  # noqa: F401  (re-exported surface)
    ForestStepBackend,
    StepPlan,
    check_order,
    default_backend,
    get_backend,
    list_backends,
    register_backend,
    rle_chunks,
)
from repro.schedule.policies import OrderPolicy, get_order_policy, list_orders

PolicyLike = Union[str, OrderPolicy]


def _as_policy(policy: PolicyLike, **overrides) -> OrderPolicy:
    if isinstance(policy, OrderPolicy):
        return policy
    return get_order_policy(policy, **overrides)


@dataclasses.dataclass
class ForestProgram:
    """Adapter making a trained forest an :class:`AnytimeProgram`.

    Provide either the ordering set (``X_order``/``y_order``) — the
    quality table is computed on demand — or a precomputed ``path_probs``
    table alongside ``y_order``.  Step-plans compile once per distinct
    order (content-addressed) and are shared across sessions.
    """

    forest: ForestArrays
    y_order: np.ndarray
    X_order: Optional[np.ndarray] = None
    path_probs: Optional[np.ndarray] = None
    device: engine.DeviceForest = dataclasses.field(init=False, repr=False)
    _plan_cache: dict = dataclasses.field(
        init=False, repr=False, default_factory=dict
    )

    def __post_init__(self):
        if self.X_order is None and self.path_probs is None:
            raise ValueError("ForestProgram needs X_order or path_probs")
        self.device = engine.to_device(self.forest)

    @property
    def n_units(self) -> int:
        return self.forest.n_trees

    @property
    def unit_steps(self) -> int:
        return self.forest.max_depth

    def quality_table(self) -> tuple[np.ndarray, np.ndarray]:
        if self.path_probs is None:
            self.path_probs = engine.path_probs_np(self.forest, self.X_order)
        return self.path_probs, np.asarray(self.y_order)

    def step_plan(self, order: np.ndarray) -> StepPlan:
        """Compile-once step-plan, content-addressed on the order bytes."""
        order = np.asarray(order, dtype=np.int32)
        key = hashlib.sha1(order.tobytes()).hexdigest()
        plan = self._plan_cache.get(key)
        if plan is None:
            plan = StepPlan.compile(order)
            self._plan_cache[key] = plan
        return plan

    def make_session(
        self, order: np.ndarray, inputs, backend: Optional[str] = None, **backend_opts
    ) -> ForestStepBackend:
        return ForestStepBackend(
            self.device, inputs, order,
            backend=backend, plan=self.step_plan(order), **backend_opts,
        )


# ---------------------------------------------------------------------------
# Deadline-aware session + runtime.
# ---------------------------------------------------------------------------


class Session:
    """Interruptible inference over any step backend.

    ``advance(k)`` runs up to k steps; ``advance_until(deadline_ms)``
    runs chunks until a wall-clock deadline; ``predict()`` is valid after
    ANY prefix — the deployment-facing realization of Sec. V, shared by
    forests and transformer ensembles.
    """

    def __init__(self, backend, chunk: int = 8, clock=time.perf_counter):
        self.backend = backend
        self.chunk = int(chunk)
        self.clock = clock

    @property
    def total_steps(self) -> int:
        return self.backend.total_steps

    @property
    def pos(self) -> int:
        return self.backend.pos

    @property
    def remaining(self) -> int:
        return self.total_steps - self.backend.pos

    def advance(self, k: int) -> int:
        if k <= 0:
            return 0
        return self.backend.advance(k)

    def advance_until(self, deadline_ms: float, chunk: Optional[int] = None) -> int:
        """Advance in chunks until ``deadline_ms`` elapses or the order is
        exhausted; returns steps taken.  The deadline is checked between
        chunks, so the overshoot is bounded by one chunk's runtime.
        Non-positive deadlines take no steps (and never read the clock)."""
        if deadline_ms <= 0:
            return 0
        chunk = self.chunk if chunk is None else int(chunk)
        t0 = self.clock()
        budget_s = deadline_ms / 1e3
        taken = 0
        while self.remaining and (self.clock() - t0) < budget_s:
            taken += self.backend.advance(min(chunk, self.remaining))
        return taken

    def run_to_completion(self) -> int:
        return self.advance(self.remaining)

    def predict(self) -> np.ndarray:
        return self.backend.predict()

    def predict_proba(self) -> np.ndarray:
        fn = getattr(self.backend, "predict_proba", None)
        if fn is None:
            fn = self.backend.predict_logprobs
        return fn()

    def __getattr__(self, name: str):
        # Backend-specific state (e.g. the forest index array ``idx``)
        # stays reachable through the wrapper.  Guard the ``backend``
        # attribute itself: before __init__ runs (unpickling, __new__)
        # it is absent from __dict__, and falling through to
        # getattr(self.backend, ...) would recurse forever.
        backend = self.__dict__.get("backend")
        if backend is None:
            raise AttributeError(name)
        return getattr(backend, name)


class AnytimeRuntime:
    """Single serving entry point for anytime inference.

    Wraps an :class:`AnytimeProgram` (forest or ensemble) and owns order
    generation (policy registry + content-hash cache), session creation
    with pluggable execution backends, and batched order evaluation.

        rt = AnytimeRuntime(ForestProgram(forest, y_order=y, X_order=X),
                            backend="pallas")
        sess = rt.session(X_test, "backward_squirrel")
        sess.advance_until(deadline_ms=2.0)
        preds = sess.predict()

    ``backend`` (here or per-``session``) picks the execution layer:
    ``jnp-ref`` (oracle scan), ``pallas`` (MXU kernels), ``sharded``
    (mesh batch parallelism); ``None`` auto-selects by
    ``jax.default_backend()``.
    """

    def __init__(self, program, backend: Optional[str] = None):
        if backend is not None:
            get_backend(backend)  # fail fast on typos
        self.program = program
        self.backend = backend
        self._order_cache: dict[str, np.ndarray] = {}
        self._quality: Optional[tuple[np.ndarray, np.ndarray]] = None
        self._quality_digest: Optional[str] = None

    def quality_table(self) -> tuple[np.ndarray, np.ndarray]:
        if self._quality is None:
            self._quality = self.program.quality_table()
            # Digest once: the table is immutable after this point, and
            # per-request order()/session() calls must not re-hash a
            # potentially tens-of-MB array.
            pp, y = self._quality
            h = hashlib.sha1()
            h.update(np.ascontiguousarray(pp).tobytes())
            h.update(np.ascontiguousarray(y).tobytes())
            self._quality_digest = h.hexdigest()
        return self._quality

    def _cache_key(self, policy: OrderPolicy) -> str:
        return f"{self._quality_digest}:{policy.cache_key()}"

    def order(self, policy: PolicyLike, **overrides) -> np.ndarray:
        """Generate (or fetch from cache) the step order for ``policy``."""
        policy = _as_policy(policy, **overrides)
        pp, y = self.quality_table()
        key = self._cache_key(policy)
        hit = self._order_cache.get(key)
        if hit is None:
            hit = check_order(
                policy.generate(pp, y), self.program.n_units, self.program.unit_steps
            )
            self._order_cache[key] = hit
        return hit

    def session(
        self,
        inputs,
        policy: PolicyLike = "backward_squirrel",
        order: Optional[np.ndarray] = None,
        chunk: int = 8,
        clock=time.perf_counter,
        backend: Optional[str] = None,
        **backend_opts,
    ) -> Session:
        if order is None:
            order = self.order(policy)
        else:
            order = check_order(order, self.program.n_units, self.program.unit_steps)
        backend = backend if backend is not None else self.backend
        if backend is None and not backend_opts:
            # old two-arg make_session protocol stays valid for programs
            # that don't select backends (e.g. custom user programs)
            step_backend = self.program.make_session(order, inputs)
        else:
            step_backend = self.program.make_session(
                order, inputs, backend=backend, **backend_opts
            )
        return Session(step_backend, chunk=chunk, clock=clock)

    def evaluate_orders(
        self, X, y, names: Optional[Sequence[PolicyLike]] = None
    ) -> dict[str, np.ndarray]:
        """Accuracy curves of many orders in ONE vmapped batched pass.

        ``names`` defaults to every registered order.  Requires the
        program to expose a :class:`~repro.core.engine.DeviceForest` as
        ``.device`` (forests); other programs fall back to serial
        per-order sessions."""
        policies = [_as_policy(n) for n in (names if names is not None else list_orders())]
        stacked = {p.name: self.order(p) for p in policies}
        device = getattr(self.program, "device", None)
        if device is not None:
            return evaluate_orders(device, X, y, stacked)
        out = {}
        for name, order in stacked.items():
            sess = self.session(X, order=order)
            curve = [float(np.mean(sess.predict() == y))]
            while sess.remaining:
                sess.advance(1)
                curve.append(float(np.mean(sess.predict() == y)))
            out[name] = np.asarray(curve)
        return out


@partial(jax.jit, static_argnums=())
def _batched_curves(device: engine.DeviceForest, X, orders_mat, y):
    return jax.vmap(lambda o: engine.run_order(device, X, o, y)[1])(orders_mat)


def evaluate_orders(
    device: engine.DeviceForest, X, y, orders_by_name: dict[str, np.ndarray]
) -> dict[str, np.ndarray]:
    """Run every order's accuracy curve in a single vmapped pass.

    All orders must share the same length (they do by construction:
    n_trees * max_depth).  Returns {name: curve [steps+1]}."""
    if not orders_by_name:
        return {}
    names = list(orders_by_name)
    mat = jnp.asarray(np.stack([orders_by_name[n] for n in names]))
    curves = _batched_curves(device, jnp.asarray(X), mat, jnp.asarray(y))
    curves = np.asarray(curves)
    return {n: curves[i] for i, n in enumerate(names)}
