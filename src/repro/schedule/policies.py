"""Order-policy registry — the single place step orders come from.

Every step-order generator the paper evaluates (and any future one) is a
small :class:`OrderPolicy` dataclass registered by name via
:func:`register_order`.  Discovery is programmatic:

    >>> from repro.schedule import list_orders, get_order_policy
    >>> list_orders()[:3]
    ('optimal', 'unoptimal', 'forward_squirrel')
    >>> policy = get_order_policy("backward_squirrel")
    >>> order = policy.generate(path_probs, y)

Policies carry their own configuration (seed, state limit, prune metric,
QWYC variant) as dataclass fields, so a configured policy is a value:
hashable into the runtime's order cache, reproducible, and printable.

The registry replaces the string-dispatch if-chain that used to live in
``repro.core.anytime.generate_order`` (shim deleted after its
one-release grace period); orders the legacy dispatch produced are
byte-identical through the registry (tests/test_schedule.py keeps a
frozen copy of the old dispatch as the parity reference).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np

# NOTE: repro.core modules are imported inside generate() bodies, not at
# module level — repro.core.anytime depends on this registry, so a
# top-level import here would be circular.

__all__ = [
    "OrderPolicy",
    "register_order",
    "list_orders",
    "get_order_policy",
    "iter_policies",
    "PRUNE_METRICS",
    "OptimalOrder",
    "UnoptimalOrder",
    "ForwardSquirrelOrder",
    "BackwardSquirrelOrder",
    "RandomOrder",
    "DepthOrder",
    "BreadthOrder",
    "PruneOrder",
    "QwycOrder",
    "BanditSquirrelOrder",
]


@dataclasses.dataclass
class OrderPolicy:
    """Base class for step-order generation policies.

    Subclasses implement :meth:`generate`, which maps a quality table
    (``path_probs`` [B, U, S+1, C] on the ordering set, plus labels) to a
    step order: an int32 array of length U*S over unit ids.  ``name`` is
    filled in by the registry at construction time.
    """

    name: str = dataclasses.field(default="", repr=True, compare=False)

    def generate(self, path_probs: np.ndarray, y: np.ndarray) -> np.ndarray:
        raise NotImplementedError

    def cache_key(self) -> str:
        """Stable identity of this configured policy (for order caches).

        Only config fields participate (``compare=True``); mutable
        bookkeeping like ``last_stats`` must not shift the key between
        calls on the same instance."""
        fields = sorted(
            (f.name, getattr(self, f.name))
            for f in dataclasses.fields(self)
            if f.compare and f.name != "name"
        )
        return f"{type(self).__name__}:{self.name}:{fields!r}"

    @staticmethod
    def _shape(path_probs: np.ndarray) -> tuple[int, int]:
        """(n_units, unit_steps) from a quality table."""
        _, U, S1, _ = path_probs.shape
        return U, S1 - 1


# name -> (policy class, pre-bound config fields)
_REGISTRY: dict[str, tuple[type, dict]] = {}


def register_order(name: str, **bound):
    """Class decorator registering an :class:`OrderPolicy` under ``name``.

    ``bound`` pre-binds dataclass fields, letting one policy class serve a
    family of registered names (e.g. every ``prune_{variant}_{metric}``
    combination).  Returns the class unchanged so it can be stacked.
    """

    def deco(cls):
        if name in _REGISTRY:
            raise ValueError(f"order policy {name!r} already registered")
        field_names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(bound) - field_names
        if unknown:
            raise TypeError(
                f"{cls.__name__} has no config field(s) {sorted(unknown)}"
            )
        _REGISTRY[name] = (cls, dict(bound))
        return cls

    return deco


def list_orders() -> tuple[str, ...]:
    """Every registered order name, in registration order."""
    return tuple(_REGISTRY)


def get_order_policy(name: str, **overrides) -> OrderPolicy:
    """Instantiate the policy registered under ``name``.

    ``overrides`` set config fields the policy actually declares; fields
    the policy does not know (e.g. ``seed`` for a deterministic order)
    are silently dropped so generic callers can pass a common kwarg set.
    """
    try:
        cls, bound = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown order: {name!r} — registered: {', '.join(_REGISTRY)}"
        ) from None
    known = {f.name for f in dataclasses.fields(cls)}
    kept = {k: v for k, v in overrides.items() if k in known}
    return cls(name=name, **{**bound, **kept})


def iter_policies(**overrides) -> Iterator[OrderPolicy]:
    """Instantiate every registered policy (shared overrides applied)."""
    for name in _REGISTRY:
        yield get_order_policy(name, **overrides)


# ---------------------------------------------------------------------------
# Concrete policies, registered in the paper's canonical enumeration order
# (kept identical to the legacy ORDER_NAMES tuple).
# ---------------------------------------------------------------------------


@register_order("optimal")
@dataclasses.dataclass
class OptimalOrder(OrderPolicy):
    """Dijkstra over the (d+1)^T state DAG (Sec. IV-B)."""

    state_limit: int = 2_000_000
    maximize: bool = True
    last_stats: dict = dataclasses.field(default_factory=dict, compare=False, repr=False)

    def generate(self, path_probs, y):
        from repro.core import orders

        ev = orders.StateEvaluator(path_probs, y)
        out = orders.optimal_order(
            ev, maximize=self.maximize, state_limit=self.state_limit
        )
        self.last_stats = {"states_evaluated": len(ev._cache)}
        return out


@register_order("unoptimal", maximize=False)
@dataclasses.dataclass
class UnoptimalOrder(OptimalOrder):
    """Accuracy-MINIMIZING order — the paper's lower-bound baseline."""


@register_order("forward_squirrel")
@dataclasses.dataclass
class ForwardSquirrelOrder(OrderPolicy):
    """Greedy forward pass through the state graph (Sec. IV-C)."""

    def generate(self, path_probs, y):
        from repro.core import orders

        return orders.forward_squirrel(orders.StateEvaluator(path_probs, y))


@register_order("backward_squirrel")
@dataclasses.dataclass
class BackwardSquirrelOrder(OrderPolicy):
    """Greedy backward pass — the paper's best polynomial heuristic."""

    def generate(self, path_probs, y):
        from repro.core import orders

        return orders.backward_squirrel(orders.StateEvaluator(path_probs, y))


@register_order("random")
@dataclasses.dataclass
class RandomOrder(OrderPolicy):
    """Uniformly random (seeded) valid order — the paper's floor baseline."""

    seed: int = 0

    def generate(self, path_probs, y):
        from repro.core import orders

        U, S = self._shape(path_probs)
        return orders.random_order(U, S, seed=self.seed)


@register_order("depth")
@dataclasses.dataclass
class DepthOrder(OrderPolicy):
    """Finish each unit before starting the next (standard execution)."""

    def generate(self, path_probs, y):
        from repro.core import orders

        U, S = self._shape(path_probs)
        return orders.depth_order(U, S)


@register_order("breadth")
@dataclasses.dataclass
class BreadthOrder(OrderPolicy):
    """Advance every unit one level before going deeper anywhere."""

    def generate(self, path_probs, y):
        from repro.core import orders

        U, S = self._shape(path_probs)
        return orders.breadth_order(U, S)


@dataclasses.dataclass
class PruneOrder(OrderPolicy):
    """Depth/breadth order over a pruning-ranked tree sequence (Sec. IV-A)."""

    variant: str = "depth"
    metric: str = "IE"

    def generate(self, path_probs, y):
        from repro.core import orders, pruning

        U, S = self._shape(path_probs)
        seq = pruning.PRUNE_SEQUENCES[self.metric](path_probs, y)
        fn = orders.depth_order if self.variant == "depth" else orders.breadth_order
        return fn(U, S, seq)


@dataclasses.dataclass
class QwycOrder(OrderPolicy):
    """Depth/breadth order over the QWYC greedy tree sequence."""

    variant: str = "depth"

    def generate(self, path_probs, y):
        from repro.core import orders, qwyc

        U, S = self._shape(path_probs)
        seq, _ = qwyc.qwyc_seq(path_probs, y)
        fn = orders.depth_order if self.variant == "depth" else orders.breadth_order
        return fn(U, S, seq)


# Register the prune/qwyc families under their paper names — metric-major
# to preserve the legacy ORDER_NAMES enumeration order exactly.  The
# metric keys are spelled out (rather than read off PRUNE_SEQUENCES) to
# keep this module import-independent of repro.core; a schedule test
# asserts the two stay in sync.
PRUNE_METRICS = ("IE", "EA", "RE", "D")
for _metric in PRUNE_METRICS:
    for _variant in ("depth", "breadth"):
        register_order(f"prune_{_variant}_{_metric}", variant=_variant, metric=_metric)(
            PruneOrder
        )
for _variant in ("depth", "breadth"):
    register_order(f"qwyc_{_variant}", variant=_variant)(QwycOrder)
del _metric, _variant


@register_order("bandit_squirrel")
@dataclasses.dataclass
class BanditSquirrelOrder(OrderPolicy):
    """Epsilon-greedy reordering of Backward-Squirrel tree segments.

    The backward-squirrel order is run-length-encoded into per-tree
    segments (each tree's internal segment sequence is preserved, so the
    result is always a valid order); a bandit then replays the segments,
    at each round picking the tree with the highest *observed per-tree
    confidence gain* — the mean increase of the top class score per step
    when that tree's segments were executed so far — or, with
    probability ``epsilon``, a uniformly random tree (exploration).
    Trees not yet pulled are optimistic (tried first, in the squirrel
    order's own first-appearance rank).  Seeded and deterministic under
    a fixed ``seed``; the scoring machinery is the squirrel generators'
    own :class:`~repro.core.orders.StateEvaluator`.
    """

    epsilon: float = 0.1
    seed: int = 0

    def generate(self, path_probs, y):
        from repro.core import orders
        from repro.schedule.backends import rle_chunks

        ev = orders.StateEvaluator(path_probs, y)
        base = orders.backward_squirrel(ev)
        U, _ = self._shape(path_probs)
        segments: list[list[tuple[int, int]]] = [[] for _ in range(U)]
        first_rank = np.full(U, U, dtype=np.int64)
        for rank, (tree, n) in enumerate(rle_chunks(base)):
            if not segments[tree]:
                first_rank[tree] = rank
            segments[tree].append((tree, n))
        cursors = [0] * U

        rng = np.random.default_rng(self.seed)
        state = np.zeros(U, dtype=np.int64)
        S = ev.score_matrix(state)

        def confidence(S):
            return float(S.max(axis=1).mean())

        gain = np.full(U, np.inf)  # optimistic init: every arm pulled once
        out: list[int] = []
        remaining = sum(len(s) for s in segments)
        while remaining:
            avail = [t for t in range(U) if cursors[t] < len(segments[t])]
            if len(avail) > 1 and rng.random() < self.epsilon:
                tree = avail[int(rng.integers(len(avail)))]
            else:
                # greedy arm; np.inf ties (unpulled) break by squirrel rank
                a = np.asarray(avail)
                best = a[gain[a] == gain[a].max()]
                tree = int(best[np.argmin(first_rank[best])])
            _, n = segments[tree][cursors[tree]]
            cursors[tree] += 1
            remaining -= 1
            c0 = confidence(S)
            for _ in range(n):
                ev.apply_step(S, state, tree, forward=True)
                out.append(tree)
            observed = (confidence(S) - c0) / n
            gain[tree] = (
                observed if np.isinf(gain[tree]) else 0.5 * (gain[tree] + observed)
            )
        return np.asarray(out, dtype=np.int32)
