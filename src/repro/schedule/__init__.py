"""``repro.schedule`` — the public API for anytime inference.

The paper's contribution is a *design space* of execution step orders;
this package exposes it as one coherent surface:

* :mod:`repro.schedule.policies` — the :class:`OrderPolicy` registry
  (``register_order`` / ``get_order_policy`` / ``list_orders``): every
  order the paper evaluates, plus any you register, discoverable by name
  and configurable as a dataclass value.
* :mod:`repro.schedule.runtime` — :class:`AnytimeRuntime`: wraps any
  anytime program (forest or transformer ensemble), caches generated
  orders by content hash, serves deadline-aware :class:`Session`s with
  RLE-fused chunked execution, and evaluates many orders in one vmapped
  pass (:func:`evaluate_orders`).

Quickstart::

    from repro.schedule import AnytimeRuntime, ForestProgram, list_orders

    rt = AnytimeRuntime(ForestProgram(forest, y_order=y_o, X_order=X_o))
    sess = rt.session(X_test, "backward_squirrel")
    sess.advance_until(deadline_ms=2.0)
    preds = sess.predict()
    curves = rt.evaluate_orders(X_test, y_test, list_orders())
"""
from repro.schedule.policies import (
    OrderPolicy,
    get_order_policy,
    iter_policies,
    list_orders,
    register_order,
)
from repro.schedule.runtime import (
    AnytimeRuntime,
    ForestProgram,
    ForestStepBackend,
    Session,
    check_order,
    evaluate_orders,
    rle_chunks,
)

__all__ = [
    "OrderPolicy",
    "register_order",
    "get_order_policy",
    "list_orders",
    "iter_policies",
    "AnytimeRuntime",
    "ForestProgram",
    "ForestStepBackend",
    "Session",
    "check_order",
    "evaluate_orders",
    "rle_chunks",
]
