"""``repro.schedule`` — the public API for anytime inference.

The paper's contribution is a *design space* of execution step orders;
this package exposes it as one coherent surface:

* :mod:`repro.schedule.policies` — the :class:`OrderPolicy` registry
  (``register_order`` / ``get_order_policy`` / ``list_orders``): every
  order the paper evaluates, plus any you register, discoverable by name
  and configurable as a dataclass value.
* :mod:`repro.schedule.backends` — the pluggable execution layer
  (``register_backend`` / ``get_backend`` / ``list_backends``): orders
  compile once into power-of-two bucketed :class:`StepPlan` segments
  and run on the ``jnp-ref`` oracle scan, the ``pallas`` MXU kernels,
  or ``sharded`` across a mesh.
* :mod:`repro.schedule.runtime` — :class:`AnytimeRuntime`: wraps any
  anytime program (forest or transformer ensemble), caches generated
  orders by content hash, serves deadline-aware :class:`Session`s with
  plan-fused chunked execution on any registered backend, and evaluates
  many orders in one vmapped pass (:func:`evaluate_orders`).

Quickstart::

    from repro.schedule import AnytimeRuntime, ForestProgram, list_orders

    rt = AnytimeRuntime(ForestProgram(forest, y_order=y_o, X_order=X_o))
    sess = rt.session(X_test, "backward_squirrel", backend="pallas")
    sess.advance_until(deadline_ms=2.0)
    preds = sess.predict()
    curves = rt.evaluate_orders(X_test, y_test, list_orders())
"""
from repro.schedule.backends import (
    ExecutorCore,
    ForestExecutor,
    ForestStepBackend,
    StepPlan,
    check_order,
    default_backend,
    get_backend,
    list_backends,
    pow2_decompose,
    pow2_floor,
    register_backend,
    rle_chunks,
)
from repro.schedule.policies import (
    OrderPolicy,
    get_order_policy,
    iter_policies,
    list_orders,
    register_order,
)
from repro.schedule.runtime import (
    AnytimeRuntime,
    ForestProgram,
    Session,
    SessionBatch,
    evaluate_orders,
)

__all__ = [
    "OrderPolicy",
    "register_order",
    "get_order_policy",
    "list_orders",
    "iter_policies",
    "AnytimeRuntime",
    "ExecutorCore",
    "ForestExecutor",
    "ForestProgram",
    "ForestStepBackend",
    "Session",
    "SessionBatch",
    "StepPlan",
    "check_order",
    "default_backend",
    "evaluate_orders",
    "get_backend",
    "list_backends",
    "pow2_decompose",
    "pow2_floor",
    "register_backend",
    "rle_chunks",
]
