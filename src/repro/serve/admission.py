"""Admission-policy registry — the single place submit-time gating
comes from.

Mirrors the order/backend/impl registries: each policy is a small
:class:`AdmissionPolicy` dataclass registered by name via
:func:`register_admission`, discovered with :func:`list_admissions`,
instantiated with :func:`get_admission_policy`.  This replaces the
``admission="edf"|"reject"|"degrade"`` string-dispatch chain that used
to live inline in ``AnytimeServer._submit_slow``; the new ``certified``
mode registers through the same door instead of growing the chain.

A policy's :meth:`~AdmissionPolicy.on_submit` runs on the submit slow
path under the server lock, AFTER any ``guaranteed=True`` request has
been certified and BEFORE the request is stamped/enqueued — it may
reject (raise), stamp a degrade budget, or pass.  Two class-level traits
shape the surrounding flow: ``fast_path`` marks a policy as a no-op so
eligible submits skip the server lock entirely (the sharded-queue fast
path), and ``certify_all`` marks a policy that upgrades EVERY request to
the certified contract.
"""
from __future__ import annotations

import dataclasses
from typing import ClassVar

from repro.serve.queue import AdmissionRejected

__all__ = [
    "AdmissionPolicy",
    "register_admission",
    "list_admissions",
    "get_admission_policy",
    "EdfAdmission",
    "RejectAdmission",
    "DegradeAdmission",
    "CertifiedAdmission",
]


@dataclasses.dataclass
class AdmissionPolicy:
    """Base class for submit-time admission policies.

    Subclasses implement :meth:`on_submit`; the server calls it holding
    its global lock, so implementations may read scheduler backlog and
    stamp request fields but must not block or call back into submit.
    ``name`` is filled in by the registry at construction time.
    """

    name: str = dataclasses.field(default="", repr=True, compare=False)

    #: True = this policy never inspects or mutates a best-effort
    #: request at submit, so eligible submits may take the lock-free
    #: sharded-queue fast path.  Guaranteed requests always take the
    #: slow path (certification needs the server lock).
    fast_path: ClassVar[bool] = False
    #: True = every request submitted under this policy is upgraded to
    #: the certified contract (``guaranteed=True`` + WCET admission).
    certify_all: ClassVar[bool] = False

    def on_submit(self, server, request) -> None:
        """Gate ``request`` at submit time (holding ``server._lock``):
        raise :class:`AdmissionRejected` to shed it, stamp fields (e.g.
        ``budget_steps``) to shape it, or return to admit as-is."""
        raise NotImplementedError


# name -> (policy class, pre-bound config fields)
_REGISTRY: dict[str, tuple[type, dict]] = {}


def register_admission(name: str, **bound):
    """Class decorator registering an :class:`AdmissionPolicy` under
    ``name``.  ``bound`` pre-binds dataclass fields so one class can
    serve a family of registered names.  Returns the class unchanged.
    """

    def deco(cls):
        if name in _REGISTRY:
            raise ValueError(f"admission policy {name!r} already registered")
        field_names = {f.name for f in dataclasses.fields(cls)}
        unknown = set(bound) - field_names
        if unknown:
            raise TypeError(
                f"{cls.__name__} has no config field(s) {sorted(unknown)}"
            )
        _REGISTRY[name] = (cls, dict(bound))
        return cls

    return deco


def list_admissions() -> tuple[str, ...]:
    """Every registered admission-policy name, in registration order."""
    return tuple(_REGISTRY)


def get_admission_policy(name, **overrides) -> AdmissionPolicy:
    """Instantiate the policy registered under ``name``.

    Passes an already-built :class:`AdmissionPolicy` through unchanged,
    so server constructors accept either a name or an instance.
    """
    if isinstance(name, AdmissionPolicy):
        return name
    try:
        cls, bound = _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown admission policy: {name!r} — registered: "
            f"{', '.join(_REGISTRY)}"
        ) from None
    known = {f.name for f in dataclasses.fields(cls)}
    kept = {k: v for k, v in overrides.items() if k in known}
    return cls(name=name, **{**bound, **kept})


# ---------------------------------------------------------------------------
# Concrete policies, registered in the historical string-dispatch order.
# ---------------------------------------------------------------------------


@register_admission("edf")
@dataclasses.dataclass
class EdfAdmission(AdmissionPolicy):
    """Admit everything; the EDF queue and deadline retirement do the
    triage.  A pure no-op at submit, so best-effort submits ride the
    lock-free fast path."""

    fast_path: ClassVar[bool] = True

    def on_submit(self, server, request) -> None:
        return None


@register_admission("reject")
@dataclasses.dataclass
class RejectAdmission(AdmissionPolicy):
    """Shed load at submit: reject once the request's lane backlog
    exceeds ``capacity * admission_k`` (the PR 5 depth bound)."""

    def on_submit(self, server, request) -> None:
        backlog = server.scheduler.lane_backlog(request)
        bound = server.scheduler.capacity * server.admission_k
        if backlog >= bound:
            if server.tracer.enabled:
                server.tracer.instant(
                    "serve.admission", request_id=-1, decision="reject",
                    backlog=backlog, bound=bound, program=request.program,
                )
            raise AdmissionRejected(
                f"backlog {backlog} >= {bound:.0f} "
                f"(capacity {server.scheduler.capacity} x "
                f"admission_k {server.admission_k})"
            )


@register_admission("degrade")
@dataclasses.dataclass
class DegradeAdmission(AdmissionPolicy):
    """Admit everything, but shrink best-effort step budgets under
    pressure (predicted pressure when the server carries a calibrated
    cost model, observed backlog depth otherwise).  Guaranteed requests
    are never degraded — their certificate priced the full plan."""

    def on_submit(self, server, request) -> None:
        if request.guaranteed:
            return None
        request.budget_steps = server._degrade_budget(request)


@register_admission("certified")
@dataclasses.dataclass
class CertifiedAdmission(AdmissionPolicy):
    """Every request is guaranteed: admission prices the worst case
    from the calibrated table and admits only what provably fits its
    deadline; everything else raises ``CertificationFailed`` at submit
    with the priced bound."""

    certify_all: ClassVar[bool] = True

    def on_submit(self, server, request) -> None:
        request.guaranteed = True
        server._certify(request)
