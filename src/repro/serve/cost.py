"""`CostModel` — pricing requests against a calibrated WCET table.

The certification half of ROADMAP item 3.  ``python -m tools.obs
calibrate`` folds a traced serving run's steady-state segment histograms
(compiles split out) into a persisted per-platform worst-case table
``reports/obs/wcet_<platform>.json``; this module loads that table and
prices a request's :class:`~repro.schedule.backends.StepPlan` execution
from it, which is what certified admission consults at submit time.

Why a *per-step rate*, not per-segment sums: the scheduler's dispatch
rule fuses ``L = pow2_floor(min remaining across stepping slots)`` steps
per launch, so one request's segments fragment into data-dependent pow2
compositions (staggered admissions and degrade budgets knock slots out
of phase).  For ANY composition of ``T`` steps into dispatches of
lengths ``p_i``::

    sum_i (wcet(p_i) + harvest)  <=  T * max_p (wcet(p) + harvest) / p

so charging every step the worst *per-step* cost over the lengths the
plan can emit is sound regardless of how the fragmentation falls.  The
model additionally assumes dispatch worst cases are non-decreasing in
segment length (longer fused segments do strictly more device work), so
an uncalibrated length may be priced at the next calibrated length
above it; a length with no calibrated cell at or above it is
*unpriceable* and certification must reject.

The constant tail ``LAG_ITERATIONS`` covers the loop's structural lag:
a submitted request is admitted at the next segment boundary, its final
boundary rides the double buffer one harvest behind the dispatch, and
retirement happens at the harvest after completion — three loop
iterations at worst-case per-iteration cost.
"""
from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Optional

__all__ = ["CostModel", "CostModelError", "LAG_ITERATIONS", "WCET_DIR_ENV"]

#: loop iterations of structural lag priced into every request: buffered
#: admission (join at the next boundary) + double-buffered harvest lag +
#: retirement at the harvest after completion.
LAG_ITERATIONS = 3

#: environment override for the directory WCET tables are loaded from
#: (default: ``reports/obs`` relative to the working directory).
WCET_DIR_ENV = "REPRO_WCET_DIR"

_DEFAULT_WCET_DIR = Path("reports/obs")


class CostModelError(RuntimeError):
    """A request's worst case cannot be priced from the loaded table —
    the backend has no calibrated cells, or the plan emits a dispatch
    length with no calibrated cell at or above it.  Certified admission
    turns this into a rejection: *cannot certify* is a reject, never a
    silent admit."""


def _parse_cell_key(key: str) -> tuple[str, str, int]:
    """``"<backend>/<impl>/L<len>"`` -> (backend, impl, length)."""
    parts = key.split("/")
    if len(parts) != 3 or not parts[2].startswith("L"):
        raise CostModelError(f"malformed wcet cell key {key!r} "
                             "(want '<backend>/<impl>/L<len>')")
    try:
        length = int(parts[2][1:])
    except ValueError:
        raise CostModelError(f"malformed wcet cell key {key!r}") from None
    if length < 1:
        raise CostModelError(f"wcet cell {key!r} has non-positive length")
    return parts[0], parts[1], length


class CostModel:
    """Worst-case pricing of anytime requests from a calibrated table.

    ``table`` is the parsed ``wcet_<platform>.json`` document (see
    :mod:`tools.obs.wcet` for the persisted shape).  Per backend the
    model keeps the worst case per calibrated pow2 dispatch length —
    maximized across impls, since the tuner may pick any of them at
    dispatch time — plus the global harvest worst case (the boundary
    materialization sync, where asynchronously-dispatched device work
    surfaces as wall time).
    """

    def __init__(self, table: dict):
        if not isinstance(table, dict) or "cells" not in table:
            raise CostModelError("wcet table must be a dict with 'cells'")
        margin = float(table.get("margin", 0.0))
        if margin < 1.0:
            raise CostModelError(
                f"wcet table margin must be >= 1.0, got {margin}")
        self.platform = str(table.get("platform", "?"))  # unguarded: immutable config
        self.margin = margin                             # unguarded: immutable config
        self.table = table                               # unguarded: immutable config
        # backend -> {length: wcet_ms}, maximized across impls
        cells: dict[str, dict[int, float]] = {}
        for key, row in table["cells"].items():
            backend, _impl, length = _parse_cell_key(key)
            wcet = float(row.get("wcet_ms", 0.0))
            if wcet <= 0.0:
                raise CostModelError(f"wcet cell {key!r} has wcet_ms <= 0")
            per = cells.setdefault(backend, {})
            per[length] = max(per.get(length, 0.0), wcet)
        self._cells = cells                              # unguarded: immutable config
        harvest = table.get("harvest", {})
        self.harvest_wcet_ms = float(harvest.get("wcet_ms", 0.0))  # unguarded: immutable config
        if int(harvest.get("count", 0)) < 1 or self.harvest_wcet_ms <= 0.0:
            raise CostModelError(
                "wcet table has no calibrated harvest worst case — "
                "recalibrate from a traced serving run")

    # -- table access ------------------------------------------------------

    def backends(self) -> tuple[str, ...]:
        """Backends with at least one calibrated cell."""
        return tuple(sorted(self._cells))

    def lengths(self, backend: str) -> tuple[int, ...]:
        """Calibrated dispatch lengths for ``backend``, ascending."""
        try:
            return tuple(sorted(self._cells[backend]))
        except KeyError:
            raise CostModelError(
                f"no calibrated wcet cells for backend {backend!r} "
                f"(calibrated: {', '.join(self.backends()) or 'none'})"
            ) from None

    def segment_wcet_ms(self, backend: str, length: int) -> float:
        """Worst case of one fused dispatch of ``length`` steps: the
        calibrated cell, or — dispatch cost being non-decreasing in
        length — the smallest calibrated length at or above it."""
        per = self._cells.get(backend)
        if not per:
            raise CostModelError(
                f"no calibrated wcet cells for backend {backend!r}")
        above = [ln for ln in per if ln >= length]
        if not above:
            raise CostModelError(
                f"backend {backend!r} has no calibrated cell at or above "
                f"length {length} (calibrated: {sorted(per)}) — this "
                "dispatch length is unpriceable")
        return per[min(above)]

    # -- pricing -----------------------------------------------------------

    def step_rate_ms(self, backend: str,
                     lengths: Optional[tuple] = None) -> float:
        """Sound per-step worst-case rate over the dispatch lengths the
        plan can emit (default: every calibrated length): the max of
        ``(segment_wcet(L) + harvest_wcet) / L``.  Any fragmentation of
        ``T`` steps into pow2 dispatches costs at most ``T`` times
        this."""
        if lengths is None:
            lengths = self.lengths(backend)
        if not lengths:
            raise CostModelError("step_rate_ms needs at least one length")
        return max(
            (self.segment_wcet_ms(backend, int(L)) + self.harvest_wcet_ms)
            / int(L)
            for L in lengths
        )

    def iteration_wcet_ms(self, backend: str) -> float:
        """Worst case of one loop iteration's share for one lane on
        ``backend``: its most expensive dispatch plus a harvest."""
        per_len = self.lengths(backend)
        return max(
            self.segment_wcet_ms(backend, L) for L in per_len
        ) + self.harvest_wcet_ms

    def request_wcet_ms(self, steps: int, backend: str,
                        lengths: Optional[tuple] = None,
                        interference_ms: float = 0.0,
                        wait_ms: float = 0.0) -> float:
        """Worst-case submit-to-delivery bound of a ``steps``-step
        request: slot wait + per-step worst rate (each step may ride its
        own iteration, each iteration delayed ``interference_ms`` by
        sibling lanes busy at admission time) + the structural lag tail
        (:data:`LAG_ITERATIONS` iterations)."""
        rate = self.step_rate_ms(backend, lengths)
        it = self.iteration_wcet_ms(backend)
        return (
            wait_ms
            + int(steps) * (rate + interference_ms)
            + LAG_ITERATIONS * (it + interference_ms)
        )

    # -- persistence -------------------------------------------------------

    @classmethod
    def from_file(cls, path) -> "CostModel":
        with open(path) as fh:
            return cls(json.load(fh))

    @classmethod
    def load(cls, platform: Optional[str] = None,
             root=None) -> "CostModel":
        """Load ``wcet_<platform>.json`` from ``root`` (default:
        ``reports/obs``, overridable via :data:`WCET_DIR_ENV`).
        ``platform`` defaults to the active jax backend."""
        if platform is None:
            import jax

            platform = jax.default_backend()
        if root is None:
            root = Path(os.environ.get(WCET_DIR_ENV, _DEFAULT_WCET_DIR))
        path = Path(root) / f"wcet_{platform}.json"
        if not path.exists():
            raise CostModelError(
                f"no calibrated wcet table at {path} — run a traced "
                "serving sweep and `python -m tools.obs calibrate "
                f"--platform {platform}` first")
        return cls.from_file(path)
