"""Admission queue for deadline-bearing anytime requests.

Monotonic-clock bookkeeping: :meth:`AdmissionQueue.submit` stamps each
request with an id and an *absolute* deadline on the server's monotonic
clock (``t_deadline = now + deadline_ms/1e3``), so downstream deadline
checks are single comparisons immune to wall-clock adjustments.  The
queue itself is earliest-deadline-first: :meth:`AdmissionQueue.pop`
always yields the pending request with the nearest deadline, which is
the order the scheduler admits requests into slot batches.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Any, Optional, Union

from repro.schedule.policies import OrderPolicy

PolicyLike = Union[str, OrderPolicy]


class AdmissionRejected(RuntimeError):
    """Raised by ``AnytimeServer.submit`` under ``admission="reject"``
    when the backlog exceeds the configured depth bound.

    Load shedding at submission time: under oversubscription the EDF
    queue otherwise starves late-generation requests to 0 steps
    (delivered as prior readouts) — rejection tells the CALLER, at
    submit time, to retry elsewhere/later instead of silently burning a
    slot-less wait.  The admitted population keeps its anytime quality.
    """


@dataclasses.dataclass
class Request:
    """One deadline-bearing inference request.

    ``x`` is a single input row (``[F]``) for slot-batched programs
    (forests); for generic programs served through solo-session lanes
    (e.g. LM ensembles) it is whatever the program's ``make_session``
    accepts.  ``deadline_ms`` is relative to submission; the queue turns
    it into the absolute ``t_deadline``.
    """

    x: Any
    deadline_ms: float
    policy: PolicyLike = "backward_squirrel"
    backend: Optional[str] = None
    program: str = "default"
    #: effective step budget under ``admission="degrade"`` — stamped by
    #: the server at submit time from the instantaneous lane backlog
    #: (None = full budget).  The lane caps the slot's plan cursor at
    #: this many steps, so overload shrinks per-request work instead of
    #: rejecting or starving; fresh submissions under cleared pressure
    #: get None again (budgets restore automatically).
    budget_steps: Optional[int] = None
    # stamped by AdmissionQueue.submit (monotonic clock):
    request_id: int = -1
    t_submit: float = float("nan")
    t_deadline: float = float("nan")

    def policy_key(self) -> str:
        """Stable identity of the requested order policy (lane keying)."""
        if isinstance(self.policy, OrderPolicy):
            return self.policy.cache_key()
        return str(self.policy)


@dataclasses.dataclass
class Result:
    """What a request gets back at (or before) its deadline.

    ``proba``/``prediction`` come from the last *completed* segment
    boundary the host had seen by the deadline — bit-identical to a solo
    ``jnp-ref`` session advanced ``steps_completed`` steps, never a torn
    mid-segment state.  ``steps_completed == 0`` means the request got
    the prior (all-roots / empty) readout.  ``error`` is set (and
    ``deadline_hit`` False) when the request itself was unservable —
    e.g. an input row of the wrong width — so one malformed request
    fails ITS ticket instead of crashing the serving loop.
    """

    request_id: int
    prediction: Any
    proba: Any
    steps_completed: int
    total_steps: int
    completed: bool       # ran the entire step order before the deadline
    deadline_hit: bool    # delivered a >=1-step anytime readout (or completed)
    latency_ms: float
    error: Optional[str] = None
    #: admission="degrade" bookkeeping: ``degraded`` marks a request
    #: admitted with a shrunken step budget; ``budget_steps`` is the
    #: effective budget it ran under (== total_steps when not degraded).
    #: A degraded readout is still a clean boundary — bit-identical to a
    #: solo session advanced ``steps_completed`` steps — just from a
    #: shorter prefix of the order.
    degraded: bool = False
    budget_steps: Optional[int] = None


class AdmissionQueue:
    """EDF admission queue with monotonic-clock bookkeeping."""

    def __init__(self):
        # all queue state belongs to the owning AnytimeServer's lock: the
        # server (and the Scheduler it drives) only touches the queue from
        # locked sections, so the queue itself stays lock-free
        self._heap: list[tuple[float, int, Request]] = []  # guarded-by: AnytimeServer._lock
        self._ids = itertools.count()  # guarded-by: AnytimeServer._lock
        self.submitted = 0             # guarded-by: AnytimeServer._lock

    def submit(self, request: Request, now: float) -> Request:  # holds: AnytimeServer._lock
        """Stamp and enqueue ``request``; returns it (id/deadline filled)."""
        if request.deadline_ms < 0:
            raise ValueError(f"deadline_ms must be >= 0, got {request.deadline_ms}")
        request.request_id = next(self._ids)
        request.t_submit = now
        request.t_deadline = now + request.deadline_ms / 1e3
        self.submitted += 1
        self.push(request)
        return request

    def push(self, request: Request) -> None:  # holds: AnytimeServer._lock
        """(Re-)enqueue an already-stamped request (e.g. one that found
        no free slot this round)."""
        heapq.heappush(self._heap, (request.t_deadline, request.request_id, request))

    def pop(self) -> Optional[Request]:  # holds: AnytimeServer._lock
        """Earliest-deadline pending request, or None when empty."""
        if not self._heap:
            return None
        return heapq.heappop(self._heap)[2]

    def __len__(self) -> int:  # holds: AnytimeServer._lock
        return len(self._heap)

    def __bool__(self) -> bool:  # holds: AnytimeServer._lock
        return bool(self._heap)
