"""Sharded admission queue for deadline-bearing anytime requests.

Monotonic-clock bookkeeping: :meth:`AdmissionQueue.submit` stamps each
request with an id and an *absolute* deadline on the server's monotonic
clock (``t_deadline = now + deadline_ms/1e3``), so downstream deadline
checks are single comparisons immune to wall-clock adjustments.

The queue is earliest-deadline-first and **internally sharded**: each
request hashes (by id) onto one of ``shards`` independent EDF heaps,
each behind its own mutex.  A submit therefore touches exactly ONE shard
lock — never the server's global lock — which is what keeps the submit
hot path cheap while the driver holds the global lock for a whole
dispatch → admit → harvest iteration.  The scheduler drains arrivals
with :meth:`AdmissionQueue.take_all`, the batched cross-shard merge at
dispatch boundaries: every shard's heap is swapped out under its own
lock and the union is EDF-sorted once, outside any lock.

Shutdown discipline: :meth:`AdmissionQueue.close` marks every shard
closed under its lock, so a submit racing ``AnytimeServer.close()``
either lands before the shutdown flush (and is answered by it) or
raises — a request can never slip silently between the flush and the
closed flag.
"""
from __future__ import annotations

import contextlib
import dataclasses
import heapq
import itertools
import threading
from typing import Any, Optional, Union

from repro.schedule.policies import OrderPolicy

PolicyLike = Union[str, OrderPolicy]


class AdmissionRejected(RuntimeError):
    """Raised by ``AnytimeServer.submit`` under ``admission="reject"``
    when the backlog exceeds the configured depth bound.

    Load shedding at submission time: under oversubscription the EDF
    queue otherwise starves late-generation requests to 0 steps
    (delivered as prior readouts) — rejection tells the CALLER, at
    submit time, to retry elsewhere/later instead of silently burning a
    slot-less wait.  The admitted population keeps its anytime quality.
    """


class CertificationFailed(AdmissionRejected):
    """Raised at submit time when a ``guaranteed=True`` request (or any
    request under ``admission="certified"``) cannot be *proven* to fit
    its deadline from the calibrated worst-case table — the priced bound
    exceeds the deadline, the plan emits an unpriceable dispatch length,
    or no :class:`~repro.serve.cost.CostModel` is configured.

    Subclasses :class:`AdmissionRejected` so existing shed-handling
    callers keep working; carries the priced worst case so the caller
    can see exactly how infeasible the request was.
    """

    def __init__(self, message: str,
                 wcet_ms: Optional[float] = None,
                 deadline_ms: Optional[float] = None):
        super().__init__(message)
        #: priced worst-case completion bound (ms), when pricing got far
        #: enough to produce one; None for structural failures (no cost
        #: model, unpriceable length, no certifiable slot).
        self.wcet_ms = wcet_ms  # unguarded: written once before raise
        self.deadline_ms = deadline_ms  # unguarded: written once before raise


@dataclasses.dataclass
class Request:
    """One deadline-bearing inference request.

    ``x`` is a single input row (``[F]``) for slot-batched programs
    (forests); for generic programs served through solo-session lanes
    (e.g. LM ensembles) it is whatever the program's ``make_session``
    accepts.  ``deadline_ms`` is relative to submission; the queue turns
    it into the absolute ``t_deadline``.
    """

    x: Any
    deadline_ms: float
    policy: PolicyLike = "backward_squirrel"
    backend: Optional[str] = None
    program: str = "default"
    #: effective step budget under ``admission="degrade"`` — stamped by
    #: the server at submit time from the instantaneous lane backlog
    #: (None = full budget).  The lane caps the slot's plan cursor at
    #: this many steps, so overload shrinks per-request work instead of
    #: rejecting or starving; fresh submissions under cleared pressure
    #: get None again (budgets restore automatically).
    budget_steps: Optional[int] = None
    #: ``guaranteed=True`` requests are certified at admission against
    #: the server's calibrated :class:`~repro.serve.cost.CostModel`:
    #: either the worst-case completion provably fits the deadline (and
    #: the bound is stamped into ``wcet_ms``) or submit raises
    #: :class:`CertificationFailed`.  Guaranteed requests outrank
    #: best-effort traffic in slot admission and are never degraded.
    guaranteed: bool = False
    #: priced worst-case completion bound stamped by certified admission
    #: (None for best-effort requests).
    wcet_ms: Optional[float] = None
    # stamped by AdmissionQueue.stamp/submit (monotonic clock):
    request_id: int = -1
    t_submit: float = float("nan")
    t_deadline: float = float("nan")

    def policy_key(self) -> str:
        """Stable identity of the requested order policy (lane keying)."""
        if isinstance(self.policy, OrderPolicy):
            return self.policy.cache_key()
        return str(self.policy)


@dataclasses.dataclass
class Result:
    """What a request gets back at (or before) its deadline.

    ``proba``/``prediction`` come from the last *completed* segment
    boundary the host had seen by the deadline — bit-identical to a solo
    ``jnp-ref`` session advanced ``steps_completed`` steps, never a torn
    mid-segment state.  ``steps_completed == 0`` means the request got
    the prior (all-roots / empty) readout.  ``error`` is set (and
    ``deadline_hit`` False) when the request itself was unservable —
    e.g. an input row of the wrong width — so one malformed request
    fails ITS ticket instead of crashing the serving loop.
    """

    request_id: int
    prediction: Any
    proba: Any
    steps_completed: int
    total_steps: int
    completed: bool       # ran the entire step order before the deadline
    deadline_hit: bool    # delivered a >=1-step anytime readout (or completed)
    latency_ms: float
    error: Optional[str] = None
    #: admission="degrade" bookkeeping: ``degraded`` marks a request
    #: admitted with a shrunken step budget; ``budget_steps`` is the
    #: effective budget it ran under (== total_steps when not degraded).
    #: A degraded readout is still a clean boundary — bit-identical to a
    #: solo session advanced ``steps_completed`` steps — just from a
    #: shorter prefix of the order.
    degraded: bool = False
    budget_steps: Optional[int] = None
    #: the request was admitted under certification (``guaranteed=True``
    #: or ``admission="certified"``): ``completed`` must be True for
    #: such a result — a guaranteed delivery with ``completed=False`` is
    #: a certification miss, counted as ``guaranteed_misses`` in metrics
    #: and a hard failure in bench/CI.
    guaranteed: bool = False


class _QueueShard:
    """One EDF heap behind its own mutex — the unit of submit-side
    concurrency.  All heap/counter state lives under ``lock``; ``n`` is
    a lock-free length mirror for busy-checks and router load hints."""

    __slots__ = ("lock", "heap", "closed", "submitted", "n")

    def __init__(self):
        self.lock = threading.Lock()
        self.heap: list[tuple[float, int, Request]] = []  # guarded-by: lock
        self.closed = False    # guarded-by: lock
        self.submitted = 0     # guarded-by: lock
        # torn-free int: approximate reads steer parking/routing only —
        # every correctness-bearing read happens under `lock`
        self.n = 0             # unguarded: racy length mirror of heap

    def push(self, entry: tuple, count: bool = False) -> None:
        with self.lock:
            if self.closed:
                raise RuntimeError(
                    "submit on a closed AnytimeServer (close() was called)")
            heapq.heappush(self.heap, entry)
            if count:
                self.submitted += 1
            self.n = len(self.heap)

    def take(self) -> list[tuple]:
        """Swap the heap out under the shard lock; merge outside it."""
        with self.lock:
            taken, self.heap = self.heap, []
            self.n = 0
            return taken

    def close(self) -> None:
        with self.lock:
            self.closed = True


class AdmissionQueue:
    """Sharded EDF admission queue with monotonic-clock bookkeeping.

    ``shards=1`` (the default) preserves exact single-heap EDF pop
    semantics; serving tiers size shards to their submitter concurrency.
    ``ids`` lets a multi-pool facade share ONE id counter across its
    per-pool queues so request ids stay globally unique (shared pending
    maps and steal bookkeeping key on them).
    """

    def __init__(self, shards: int = 1, ids: Optional[itertools.count] = None):
        if shards < 1:
            raise ValueError(f"shards must be >= 1, got {shards}")
        # the shard list itself is immutable; each shard is internally
        # locked (see _QueueShard)
        self._shards = [_QueueShard() for _ in range(shards)]  # unguarded: immutable list of internally-locked shards
        # itertools.count.__next__ is atomic under the GIL — id stamping
        # needs no lock even from concurrent submitters
        self._ids = ids if ids is not None else itertools.count()  # unguarded: atomic counter

    @property
    def n_shards(self) -> int:
        return len(self._shards)

    @property
    def submitted(self) -> int:
        """Total requests stamped+enqueued through :meth:`submit`
        (lock-free sum of per-shard counters; exact when quiescent)."""
        return sum(s.submitted for s in self._shards)

    def stamp(self, request: Request, now: float) -> Request:
        """Assign ``request`` its id and absolute deadlines — lock-free
        (the id counter is GIL-atomic), so the submit fast path can
        register the ticket BEFORE the request becomes poppable."""
        if request.deadline_ms < 0:
            raise ValueError(f"deadline_ms must be >= 0, got {request.deadline_ms}")
        request.request_id = next(self._ids)
        request.t_submit = now
        request.t_deadline = now + request.deadline_ms / 1e3
        return request

    def submit(self, request: Request, now: float) -> Request:
        """Stamp and enqueue ``request``; returns it (id/deadline filled).
        Raises RuntimeError once :meth:`close` has marked the shards."""
        self.stamp(request, now)
        self.push(request, _count=True)
        return request

    def push(self, request: Request, _count: bool = False) -> None:
        """(Re-)enqueue an already-stamped request onto its id's shard —
        one shard lock, never the server's."""
        shard = self._shards[request.request_id % len(self._shards)]
        shard.push((request.t_deadline, request.request_id, request),
                   count=_count)

    def pop(self) -> Optional[Request]:
        """Globally earliest-deadline pending request, or None when
        empty.  Takes every shard lock (ascending order — deadlock-free
        vs single-shard submitters); the batched path schedulers should
        prefer is :meth:`take_all`."""
        with contextlib.ExitStack() as stack:
            for shard in self._shards:
                stack.enter_context(shard.lock)
            best = None
            for shard in self._shards:
                if shard.heap and (best is None or shard.heap[0] < best.heap[0]):
                    best = shard
            if best is None:
                return None
            entry = heapq.heappop(best.heap)
            best.n = len(best.heap)
            return entry[2]

    def take_all(self) -> list[Request]:
        """Drain EVERY shard and return the union in EDF order — the
        batched cross-shard merge the scheduler runs once per dispatch
        boundary.  Each shard's heap is swapped under its own lock; the
        sort happens outside all locks."""
        entries: list[tuple] = []
        for shard in self._shards:
            if shard.n:  # racy skip-hint; take() re-checks under the lock
                entries.extend(shard.take())
        if not entries:
            return []
        entries.sort()
        return [e[2] for e in entries]

    def close(self) -> None:
        """Mark every shard closed (under its lock): subsequent pushes
        raise.  Called by ``AnytimeServer.close()`` BEFORE the shutdown
        flush drains, so no submit can land between flush and flag."""
        for shard in self._shards:
            shard.close()

    def __len__(self) -> int:
        # lock-free sum of shard mirrors: a busy-hint, exact when no
        # submit is mid-flight
        return sum(s.n for s in self._shards)

    def __bool__(self) -> bool:
        return any(s.n for s in self._shards)
