"""Earliest-deadline-first micro-batcher over slot batches.

The scheduler coalesces requests sharing a ``(program, policy, backend)``
key into fixed-capacity *lanes*.  A forest lane drives one
:class:`~repro.schedule.runtime.SessionBatch`: all of its slots execute
the same cached :class:`~repro.schedule.backends.StepPlan` segments in
fused masked dispatches, requests admitted mid-flight join at the next
segment boundary (their slot simply starts the plan from position 0,
masked per-slot execution keeps everyone exact), and finished or expired
slots are recycled for queued requests.  Programs without a slot-batch
surface (e.g. LM ensembles) get a *session lane*: the same EDF loop and
deadline bookkeeping drive per-request solo sessions in chunk-sized
steps, which is what makes the server program-agnostic.

Boundary bookkeeping (the double buffer): each lane keeps up to three
readout snapshots —

* ``_front``  — enqueued with the dispatch that just went out (device,
  asynchronous);
* ``_back``   — the previous dispatch's snapshot, materialized on the
  host during :meth:`harvest` *while the device executes the front
  segment*;
* ``_host``   — the newest host-resident boundary, used for deliveries.

A request retired at its deadline therefore receives the newest readout
the host had fully materialized — always a segment boundary, never a
torn mid-segment state, and bit-identical to a solo ``jnp-ref`` session
advanced the same number of steps.  A request that expires before its
first harvested boundary gets the program's prior (0-step) readout.
"""
from __future__ import annotations

import heapq
import threading
from typing import NamedTuple, Optional

import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.obs import NULL_TRACER
from repro.schedule.backends import default_backend
from repro.serve.cost import LAG_ITERATIONS, CostModel, CostModelError
from repro.serve.queue import AdmissionQueue, CertificationFailed, Request


def _waiting_entry(req: Request) -> tuple:
    """Per-lane waiting-heap entry.  Guaranteed requests outrank every
    best-effort one (their admission certificate priced the wait of at
    most the guaranteed queue ahead of them — best-effort arrivals must
    not push them back), then EDF within each class."""
    return (0 if req.guaranteed else 1, req.t_deadline, req.request_id, req)


def _plan_lengths(plan) -> tuple[int, ...]:
    """Every pow2 dispatch length ``plan`` can emit.  The dispatch rule
    fuses ``pow2_floor(min remaining)`` steps, so out-of-phase slots and
    degrade budgets can fragment any segment down to 1 — the reachable
    set is every power of two up to the longest planned segment."""
    max_seg = int(max(plan.seg_lens)) if len(plan.seg_lens) else 1
    lengths = []
    length = 1
    while length <= max_seg:
        lengths.append(length)
        length *= 2
    return tuple(lengths)


def _readout_margin(row: np.ndarray) -> float:
    """top1 − top2 probability of one slot's boundary readout — the
    per-step confidence the online NMA curve tracks.  Computed from the
    ALREADY-materialized host boundary, so recording margins adds no
    kernel launches."""
    row = np.asarray(row).reshape(-1)
    if row.shape[0] < 2:
        return float(row[0]) if row.shape[0] else 0.0
    top2 = np.partition(row, -2)[-2:]
    return float(top2[1] - top2[0])


class _Boundary(NamedTuple):
    """Readout snapshot of one segment boundary."""

    probs: object        # [capacity, C] (device until harvested)
    pos: np.ndarray      # plan cursor per slot at the boundary
    owner: np.ndarray    # request_id per slot at the boundary (-1 = free)


class StealRecord(NamedTuple):
    """A request exported from one scheduler for injection into another
    (work stealing between pools).

    ``kind="waiting"`` — the request never dispatched a step; it
    migrates as a plain queued request (prior semantics unchanged).
    ``kind="inflight"`` — the request ran ``pos`` plan steps on the
    victim; ``idx_row`` is its exact index-array state at that
    (dispatch-quantized) boundary, synced to the host at export time.
    Because node indices are a deterministic function of (input row,
    plan prefix), resuming from ``(idx_row, pos)`` on any pool sharing
    the content-addressed plan yields boundary readouts bit-identical
    to an unstolen run — the migration cost is one device→host row
    sync, and the parity guarantee survives the steal.

    ``budget`` is the degrade cap the request was admitted under
    (None = the full plan), carried so a stolen degraded request still
    stops at the same shorter prefix.
    """

    request: Request
    kind: str
    idx_row: Optional[np.ndarray]
    pos: int
    budget: Optional[int]


class Delivery(NamedTuple):
    """A retired request plus the payload the server turns into a Result.

    ``proba`` is None when the request never reached a harvested
    boundary — the server substitutes the program's prior readout.
    ``budget`` is the effective step budget the request ran under
    (None = the full plan; set when admitted with a degrade cap).
    """

    request: Request
    proba: Optional[np.ndarray]
    steps: int
    completed: bool
    error: Optional[str] = None
    budget: Optional[int] = None


class ForestLane:
    """Slot-batched lane over one :class:`SessionBatch` (double-buffered)."""

    def __init__(self, batch, tracer=NULL_TRACER, label: str = "lane"):
        # lane state (the slot batch included) is owned by the server's
        # lock: every mutating entry point below carries `# holds:`
        self.batch = batch  # unguarded: reference immutable; state via holds-marked methods
        self.tracer = tracer  # unguarded: internally locked
        self.label = label    # unguarded: immutable config
        self.requests: list[Optional[Request]] = [None] * batch.capacity  # guarded-by: AnytimeServer._lock
        self._front: Optional[_Boundary] = None  # guarded-by: AnytimeServer._lock
        self._back: Optional[_Boundary] = None   # guarded-by: AnytimeServer._lock
        self._host: Optional[_Boundary] = None   # guarded-by: AnytimeServer._lock

    @property
    def capacity(self) -> int:
        return self.batch.capacity

    @property
    def n_active(self) -> int:
        return self.batch.n_active

    @property
    def busy(self) -> bool:  # holds: AnytimeServer._lock
        return (
            any(r is not None for r in self.requests)
            or self._front is not None
            or self._back is not None
        )

    def min_deadline(self) -> float:  # holds: AnytimeServer._lock
        deadlines = [r.t_deadline for r in self.requests if r is not None]
        return min(deadlines) if deadlines else float("inf")

    def _owners(self) -> np.ndarray:  # holds: AnytimeServer._lock
        return np.asarray(
            [r.request_id if r is not None else -1 for r in self.requests],
            dtype=np.int64,
        )

    def admit(self, request: Request) -> bool:  # holds: AnytimeServer._lock
        """Place ``request`` into a free slot (joining the batch at the
        next segment boundary); False when the lane is full.  A request
        carrying a degrade ``budget_steps`` gets its slot's plan cursor
        capped there — it stops at that exact prefix boundary and the
        slot recycles early."""
        slots = self.batch.open_slots()
        if not slots:
            return False
        slot = slots[0]
        self.batch.admit(slot, request.x, budget=request.budget_steps)
        self.requests[slot] = request
        tracer = self.tracer
        if tracer.enabled:
            tracer.request_slot(
                request.request_id, tracer.clock(), self.label,
                self.batch.backend_name)
            tracer.instant(
                "serve.slot_admit", track=self.label,
                request_id=request.request_id, slot=slot)
        return True

    def admit_resumed(self, rec: StealRecord) -> bool:  # holds: AnytimeServer._lock
        """Place a stolen mid-flight request into a free slot, resuming
        from its carried ``(idx_row, pos)`` boundary state; False when
        the lane is full.  Identical to :meth:`admit` except the slot
        starts at the migrated prefix instead of the all-roots state."""
        slots = self.batch.open_slots()
        if not slots:
            return False
        slot = slots[0]
        self.batch.admit(
            slot, rec.request.x, budget=rec.budget,
            idx_row=rec.idx_row, pos=rec.pos,
        )
        self.requests[slot] = rec.request
        tracer = self.tracer
        if tracer.enabled:
            tracer.request_slot(
                rec.request.request_id, tracer.clock(), self.label,
                self.batch.backend_name)
            tracer.instant(
                "serve.slot_admit", track=self.label,
                request_id=rec.request.request_id, slot=slot,
                resumed_pos=rec.pos)
        return True

    def export_slot(self, slot: int) -> StealRecord:  # holds: AnytimeServer._lock
        """Remove ``slot``'s request from this lane and return it as a
        :class:`StealRecord`.  Called strictly between dispatches (the
        caller holds the pool lock), so the slot's device state is the
        exact prefix of ``pos`` steps — a segment-boundary-aligned
        migration.  A slot whose admission is still buffered (or that
        never stepped) exports as a plain waiting request at zero device
        cost; otherwise the index row syncs to the host here (the one
        device round trip a steal pays)."""
        req = self.requests[slot]
        if req is None:
            raise ValueError(f"slot {slot} holds no request to export")
        batch = self.batch
        total = batch.total_steps
        target = int(batch.budget[slot])
        budget = target if target < total else None
        pos = int(batch.pos[slot])
        if batch.pending_admission(slot):
            batch.cancel_admit(slot)
            rec = StealRecord(req, "waiting", None, 0, budget)
        elif pos == 0:
            batch.retire(slot)
            rec = StealRecord(req, "waiting", None, 0, budget)
        else:
            idx_row = np.asarray(batch.idx[slot])
            batch.retire(slot)
            rec = StealRecord(req, "inflight", idx_row, pos, budget)
        # stale boundary snapshots (_front/_back/_host) may still carry
        # this slot: their owner arrays no longer match any live request,
        # so retire/harvest skip them — no flush needed
        self.requests[slot] = None
        return rec

    def _inflight_ids(self) -> list[int]:  # holds: AnytimeServer._lock
        return [r.request_id for r in self.requests if r is not None]

    def dispatch(self) -> int:  # holds: AnytimeServer._lock
        """Advance every in-flight slot one fused masked segment with
        the new boundary's readout FUSED into the same dispatch (one
        kernel launch on ``pallas``); rotates the double buffer.
        Returns the number of slots stepped."""
        stepped = int(self.batch.stepping_slots().size)
        tracer = self.tracer
        if tracer.enabled and stepped:
            # the executor annotates backend/impl/length/compile onto
            # this span from inside the dispatch (repro.obs.annotate)
            with tracer.span("serve.dispatch", track=self.label,
                             stepped=stepped) as sp:
                L, probs = self.batch.advance_segment(readout=True)
            tracer.account(
                self._inflight_ids(),
                "compile" if sp.args.get("compile") else "dispatch",
                sp.dur_s)
        else:
            L, probs = self.batch.advance_segment(readout=True)
        self._back = self._front
        if L:
            self._front = _Boundary(probs, self.batch.pos.copy(), self._owners())
        else:
            self._front = None
        return stepped if L else 0

    def _materialize(self) -> None:  # holds: AnytimeServer._lock
        """Pull the previous boundary to the host — the device sync."""
        back, self._back = self._back, None
        if back is not None:
            self._host = _Boundary(np.asarray(back.probs), back.pos, back.owner)

    def _record_margins(self, tracer) -> None:  # holds: AnytimeServer._lock
        """Per-slot readout margins at the just-materialized boundary
        (``Tracer(margins=True)``) — piggybacks on the harvested host
        array, zero extra kernel launches."""
        host = self._host
        if host is None:
            return
        probs = np.asarray(host.probs)
        for slot, req in enumerate(self.requests):
            if req is None or host.owner[slot] != req.request_id:
                continue
            tracer.counter(
                "serve.margin", _readout_margin(probs[slot]),
                track=self.label, request_id=req.request_id,
                steps=int(host.pos[slot]))

    def _retire(self, now: float) -> list[Delivery]:  # holds: AnytimeServer._lock
        out: list[Delivery] = []
        for slot, req in enumerate(self.requests):
            if req is None:
                continue
            host = self._host
            host_valid = host is not None and host.owner[slot] == req.request_id
            steps = int(host.pos[slot]) if host_valid else 0
            total = self.batch.total_steps
            target = int(self.batch.budget[slot])  # == total unless degraded
            done = host_valid and steps >= target
            if done or req.t_deadline <= now:
                proba = np.array(host.probs[slot]) if host_valid else None
                out.append(Delivery(
                    req, proba, steps, done and steps >= total,
                    budget=target if target < total else None,
                ))
                self.batch.retire(slot)
                self.requests[slot] = None
        return out

    def harvest(self, now: float) -> list[Delivery]:  # holds: AnytimeServer._lock
        """Materialize the previous boundary on the host (overlapping the
        device's execution of the front segment) and retire slots that
        completed the plan or whose deadline has passed."""
        tracer = self.tracer
        if not tracer.enabled:
            self._materialize()
            return self._retire(now)
        inflight = self._inflight_ids()
        with tracer.span("serve.harvest", track=self.label,
                         lane_active=len(inflight)) as sp:
            self._materialize()
            if tracer.margins:
                self._record_margins(tracer)
            out = self._retire(now)
        if inflight:
            tracer.account(inflight, "harvest", sp.dur_s)
        return out

    def flush(self) -> list[Delivery]:  # holds: AnytimeServer._lock
        """Shutdown drain: materialize the NEWEST device boundary (the
        in-flight front dispatch included — the device has already been
        asked for it) and retire every slot with that readout.  Called
        by ``AnytimeServer.stop()`` so every in-flight request is
        answered at its last segment boundary."""
        newest = self._front if self._front is not None else self._back
        if newest is not None:
            self._host = _Boundary(
                np.asarray(newest.probs), newest.pos, newest.owner)
        self._back = self._front = None
        out: list[Delivery] = []
        for slot, req in enumerate(self.requests):
            if req is None:
                continue
            host = self._host
            host_valid = host is not None and host.owner[slot] == req.request_id
            steps = int(host.pos[slot]) if host_valid else 0
            total = self.batch.total_steps
            target = int(self.batch.budget[slot])
            proba = np.array(host.probs[slot]) if host_valid else None
            out.append(Delivery(
                req, proba, steps, steps >= total,
                budget=target if target < total else None,
            ))
            self.batch.retire(slot)
            self.requests[slot] = None
        return out


class SessionLane:
    """Per-request solo sessions for programs without a slot-batch
    surface, driven by the same EDF loop and deadline bookkeeping.

    Each entry advances ``chunk`` steps per scheduler iteration and
    refreshes its boundary readout afterwards; a request retired at its
    deadline returns the readout stored *before* the advance that
    straddled the deadline — boundary semantics identical to the slot
    path, at per-session granularity.
    """

    def __init__(self, runtime, order, backend, capacity: int, chunk: int,
                 tracer=NULL_TRACER, label: str = "lane"):
        self.runtime = runtime        # unguarded: immutable config
        self.order = order            # unguarded: immutable config
        self.backend = backend        # unguarded: immutable config
        self.capacity = int(capacity)  # unguarded: immutable config
        self.chunk = int(chunk)       # unguarded: immutable config
        self.tracer = tracer          # unguarded: internally locked
        self.label = label            # unguarded: immutable config
        #: slot -> (request, session, last boundary proba, steps at boundary)
        self.entries: list[dict] = []  # guarded-by: AnytimeServer._lock

    @property
    def n_active(self) -> int:  # holds: AnytimeServer._lock
        return len(self.entries)

    @property
    def busy(self) -> bool:  # holds: AnytimeServer._lock
        return bool(self.entries)

    def min_deadline(self) -> float:  # holds: AnytimeServer._lock
        if not self.entries:
            return float("inf")
        return min(e["request"].t_deadline for e in self.entries)

    def admit(self, request: Request) -> bool:  # holds: AnytimeServer._lock
        if len(self.entries) >= self.capacity:
            return False
        kwargs = {} if self.backend is None else {"backend": self.backend}
        sess = self.runtime.session(request.x, order=self.order, **kwargs)
        total = int(sess.total_steps)
        budget = total
        if request.budget_steps is not None:
            budget = max(1, min(int(request.budget_steps), total))
        self.entries.append({
            "request": request,
            "session": sess,
            "proba": np.asarray(sess.predict_proba()),  # 0-step prior boundary
            "steps": 0,
            "budget": budget,  # degrade cap; == total when not degraded
        })
        tracer = self.tracer
        if tracer.enabled:
            tracer.request_slot(
                request.request_id, tracer.clock(), self.label,
                str(self.backend))
            tracer.instant(
                "serve.slot_admit", track=self.label,
                request_id=request.request_id, slot=len(self.entries) - 1)
        return True

    def _dispatch(self) -> int:  # holds: AnytimeServer._lock
        stepped = 0
        for e in self.entries:
            left = min(e["session"].remaining, e["budget"] - e["session"].pos)
            if left > 0:
                e["session"].advance(min(self.chunk, left))
                stepped += 1
        return stepped

    def dispatch(self) -> int:  # holds: AnytimeServer._lock
        tracer = self.tracer
        if not tracer.enabled or not self.entries:
            return self._dispatch()
        ids = [e["request"].request_id for e in self.entries]
        with tracer.span("serve.dispatch", track=self.label,
                         stepped=len(ids)) as sp:
            stepped = self._dispatch()
        tracer.account(
            ids, "compile" if sp.args.get("compile") else "dispatch",
            sp.dur_s)
        return stepped

    def _delivery(self, e: dict, completed: bool) -> Delivery:
        total = e["session"].total_steps
        budget = e["budget"] if e["budget"] < total else None
        return Delivery(
            e["request"], e["proba"], e["steps"], completed, budget=budget)

    def _harvest(self, now: float) -> list[Delivery]:  # holds: AnytimeServer._lock
        out: list[Delivery] = []
        kept: list[dict] = []
        for e in self.entries:
            req, sess = e["request"], e["session"]
            if req.t_deadline <= now:
                out.append(self._delivery(e, e["steps"] >= sess.total_steps))
                continue
            # refresh the boundary readout to the state after dispatch
            e["proba"] = np.asarray(sess.predict_proba())
            e["steps"] = int(sess.pos)
            if sess.remaining == 0 or e["steps"] >= e["budget"]:
                out.append(self._delivery(e, sess.remaining == 0))
                continue
            kept.append(e)
        self.entries = kept
        return out

    def harvest(self, now: float) -> list[Delivery]:  # holds: AnytimeServer._lock
        tracer = self.tracer
        if not tracer.enabled:
            return self._harvest(now)
        ids = [e["request"].request_id for e in self.entries]
        with tracer.span("serve.harvest", track=self.label,
                         lane_active=len(ids)) as sp:
            out = self._harvest(now)
        if ids:
            tracer.account(ids, "harvest", sp.dur_s)
        if tracer.margins:
            for e in self.entries:  # still-in-flight boundary margins
                tracer.counter(
                    "serve.margin", _readout_margin(e["proba"].reshape(-1)),
                    track=self.label,
                    request_id=e["request"].request_id, steps=e["steps"])
        return out

    def flush(self) -> list[Delivery]:  # holds: AnytimeServer._lock
        """Shutdown drain: refresh every session's boundary readout and
        retire it there (``AnytimeServer.stop()`` semantics)."""
        out: list[Delivery] = []
        for e in self.entries:
            sess = e["session"]
            e["proba"] = np.asarray(sess.predict_proba())
            e["steps"] = int(sess.pos)
            out.append(self._delivery(e, sess.remaining == 0))
        self.entries = []
        return out


class Scheduler:
    """EDF micro-batcher: admission, lane management, and the
    dispatch → admit → harvest iteration the server loop drives."""

    def __init__(
        self,
        runtimes: dict,
        metrics,
        capacity: int = 16,
        chunk: int = 8,
        backend_opts: Optional[dict] = None,
        max_idle_lanes: int = 32,
        tracer=None,
        track_prefix: str = "",
    ):
        self.runtimes = dict(runtimes)   # unguarded: immutable after init
        self.metrics = metrics           # unguarded: internally locked
        self.tracer = tracer if tracer is not None else NULL_TRACER  # unguarded: internally locked
        self.capacity = int(capacity)    # unguarded: immutable config
        self.chunk = int(chunk)          # unguarded: immutable config
        self.backend_opts = dict(backend_opts or {})  # unguarded: immutable config
        self.max_idle_lanes = int(max_idle_lanes)     # unguarded: immutable config
        # per-pool trace namespace: pool i labels its lane swimlanes
        # "p{i}:<program>:<policy>:<backend>" so a pooled tier's exported
        # trace shows one track group per pool
        self.track_prefix = str(track_prefix)         # unguarded: immutable config
        # all mutable scheduler state is owned by the server's lock; the
        # methods below carry `# holds: AnytimeServer._lock`
        self.lanes: dict[tuple, object] = {}          # guarded-by: AnytimeServer._lock
        self._lane_last_used: dict[tuple, int] = {}   # guarded-by: AnytimeServer._lock
        self._tick = 0                                # guarded-by: AnytimeServer._lock
        # per-lane EDF heaps of requests waiting for a free slot: each
        # request leaves the admission queue exactly ONCE (no per-
        # iteration pop/re-push churn proportional to the backlog)
        self._waiting: dict[tuple, list] = {}         # guarded-by: AnytimeServer._lock
        # still-queued requests per lane key, under a DEDICATED mutex so
        # the submit fast path can note_queued() without the server lock;
        # reject admission reads lane_backlog() in O(1) per submit
        # instead of scanning the queue at exactly the overload moment
        self._count_lock = threading.Lock()
        self._queued_by_lane: dict[tuple, int] = {}   # guarded-by: _count_lock
        # guaranteed requests queued but not yet in a waiting heap —
        # certify() must count them as waiters ahead, or back-to-back
        # guaranteed submits would each price a wait of zero
        self._queued_guaranteed: dict[tuple, int] = {}  # guarded-by: _count_lock
        self._prior_cache: dict[str, np.ndarray] = {}  # guarded-by: AnytimeServer._lock
        # stolen requests awaiting (re-)admission on THIS scheduler,
        # processed ahead of queue arrivals each step
        self._resume_pending: list[StealRecord] = []  # guarded-by: AnytimeServer._lock
        # (waiting, active, free) occupancy snapshot refreshed once per
        # step — the router's lock-free placement/victim-selection hint;
        # tuple replacement is atomic, correctness never depends on it
        self.load_hint = (0, 0, 0)  # unguarded: racy occupancy hint, atomic tuple swap

    # -- lane management ---------------------------------------------------

    def _runtime(self, req: Request):
        try:
            return self.runtimes[req.program]
        except KeyError:
            raise ValueError(
                f"unknown program {req.program!r}; serving: "
                f"{', '.join(self.runtimes)}"
            ) from None

    def _lane_key(self, req: Request) -> tuple:
        rt = self._runtime(req)
        backend = req.backend if req.backend is not None else rt.backend
        if backend is None and hasattr(rt.program, "make_slot_batch"):
            # canonicalize: "unset" and an explicit default must share a
            # lane, not build duplicate slot batches + jit traces
            backend = default_backend()
        return (req.program, req.policy_key(), str(backend))

    def lane_for(self, req: Request):  # holds: AnytimeServer._lock
        key = self._lane_key(req)
        lane = self.lanes.get(key)
        if lane is None:
            rt = self._runtime(req)
            order = rt.order(req.policy)
            backend = req.backend if req.backend is not None else rt.backend
            # trace display track: one swimlane per (program, policy,
            # backend) lane in the exported Chrome trace, namespaced by
            # the pool's track prefix in a multi-pool tier
            label = f"{self.track_prefix}{key[0]}:{key[1]}:{key[2]}"
            if hasattr(rt.program, "make_slot_batch"):
                # prefer the program's own input width — a malformed
                # first request must not define the lane for everyone
                n_features = getattr(rt.program, "n_features", None)
                if n_features is None:
                    n_features = int(np.asarray(req.x).reshape(-1).shape[0])
                batch = rt.program.make_slot_batch(
                    order, self.capacity, n_features,
                    backend=backend, **self.backend_opts,
                )
                lane = ForestLane(batch, tracer=self.tracer, label=label)
            else:
                lane = SessionLane(rt, order, backend, self.capacity,
                                   self.chunk, tracer=self.tracer, label=label)
            self.lanes[key] = lane
        self._lane_last_used[key] = self._tick
        return lane

    def _evict_idle_lanes(self) -> None:  # holds: AnytimeServer._lock
        """Bound device state on long-lived servers: a lane's slot batch
        (device arrays + jit traces) is worth keeping warm, but clients
        cycling through many distinct (program, policy, backend) keys
        must not grow it without limit — beyond ``max_idle_lanes``, the
        least-recently-used idle lanes are dropped (busy lanes never
        are; a re-arrival simply rebuilds)."""
        if len(self.lanes) <= self.max_idle_lanes:
            return
        idle = sorted(
            (key for key, lane in self.lanes.items()
             if not lane.busy and key not in self._waiting),
            key=lambda key: self._lane_last_used.get(key, 0),
        )
        excess = len(self.lanes) - self.max_idle_lanes
        for key in idle[:excess]:
            del self.lanes[key]
            self._lane_last_used.pop(key, None)

    # -- request-level helpers --------------------------------------------

    def total_steps(self, req: Request) -> int:
        prog = self._runtime(req).program
        return int(prog.n_units) * int(prog.unit_steps)

    def prior_proba(self, req: Request) -> np.ndarray:  # holds: AnytimeServer._lock
        """The 0-step readout a starved/zero-deadline request receives.

        Program priors are input-independent constants, cached per
        program name — mass starvation under overload must not pay one
        device round trip per starved request.  Programs without a
        ``prior_readout`` (session-lane programs) have input-shaped
        readouts and are computed per request."""
        prog = self._runtime(req).program
        if hasattr(prog, "prior_readout"):
            prior = self._prior_cache.get(req.program)
            if prior is None:
                prior = prog.prior_readout()
                self._prior_cache[req.program] = prior
            return prior
        rt = self._runtime(req)
        sess = rt.session(req.x, order=rt.order(req.policy))
        return np.asarray(sess.predict_proba())

    # -- WCET certification (admission="certified" / guaranteed=True) ------

    def certify(self, request: Request, cost_model: CostModel,  # holds: AnytimeServer._lock
                now: float, *, steps: Optional[int] = None,
                deadline_ms: Optional[float] = None) -> float:
        """Prove ``request`` fits its deadline from the calibrated
        worst-case table, or raise :class:`CertificationFailed`.

        The certificate is ``wait + E <= deadline`` where

        * ``E = steps*(rate + I) + LAG_ITERATIONS*(iter + I)`` prices the
          request's own execution: ``rate`` is the sound per-step worst
          rate over the pow2 dispatch lengths its plan can emit, ``iter``
          the lane's worst single iteration, and ``I`` the cross-lane
          interference — the summed per-iteration worst case of every
          OTHER currently-busy lane, since the loop round-robins busy
          lanes within one iteration.  Lanes opened AFTER admission are
          outside the model (certificates hold for the lane population
          at admission time; single-lane deployments — the certified
          norm — are unaffected).
        * ``wait`` is the k-th smallest slot free-time bound, k being
          the number of certified requests already waiting for this
          lane: a free slot is 0; an occupied slot frees within
          ``min(its remaining deadline, remaining steps * (rate + I))``
          plus one iteration (the retire→readmit boundary).

        ``steps``/``deadline_ms`` override the request's own full plan
        and relative deadline — the router passes the REMAINING steps
        and deadline when re-certifying a stolen request mid-flight.
        Returns the priced worst case (ms); the server stamps it into
        ``request.wcet_ms``.
        """
        budget_ms = (float(request.deadline_ms)
                     if deadline_ms is None else float(deadline_ms))

        def fail(why: str, wcet_ms: Optional[float] = None):
            raise CertificationFailed(
                f"cannot certify request (deadline {budget_ms:.3f} ms): "
                f"{why}", wcet_ms=wcet_ms, deadline_ms=budget_ms)

        try:
            key = self._lane_key(request)
            lane = self.lane_for(request)
        except Exception as e:  # noqa: BLE001 - bad request == not certifiable
            fail(str(e))
        if not isinstance(lane, ForestLane):
            fail("session-lane programs have no certifiable slot batch")
        total = self.total_steps(request)
        if steps is None:
            steps = total
        steps = max(1, min(int(steps), total))
        try:
            lengths = _plan_lengths(lane.batch.plan)
            rate = cost_model.step_rate_ms(key[2], lengths)
            iter_ms = cost_model.iteration_wcet_ms(key[2])
            # cross-lane interference: every other busy lane costs at
            # most its own worst iteration per loop iteration
            interference = 0.0
            for other_key, other in self.lanes.items():
                if other_key == key or not other.busy:
                    continue
                interference += cost_model.iteration_wcet_ms(other_key[2])
        except CostModelError as e:
            fail(str(e))
        exec_ms = (steps * (rate + interference)
                   + LAG_ITERATIONS * (iter_ms + interference))
        # certified requests already committed to this lane, ahead of us:
        # waiting-heap entries, stolen requests pending re-admission, AND
        # guaranteed submits still in the admission queue (certified
        # before us but not yet admitted into a heap)
        k = sum(1 for e in self._waiting.get(key, ()) if e[0] == 0)
        k += sum(
            1 for rec in self._resume_pending
            if rec.request.guaranteed and self._lane_key(rec.request) == key
        )
        with self._count_lock:
            k += self._queued_guaranteed.get(key, 0)
        bounds = []
        batch = lane.batch
        for slot, occupant in enumerate(lane.requests):
            if occupant is None:
                bounds.append(0.0)
                continue
            remaining = max(
                0, int(batch.budget[slot]) - int(batch.pos[slot]))
            left_ms = max(0.0, (occupant.t_deadline - now) * 1e3)
            bounds.append(
                min(left_ms, remaining * (rate + interference))
                + (iter_ms + interference))
        if k >= len(bounds):
            fail(f"{k} certified requests already waiting for "
                 f"{len(bounds)} slots")
        bounds.sort()
        wcet_ms = bounds[k] + exec_ms
        if wcet_ms > budget_ms:
            fail(f"priced worst case {wcet_ms:.3f} ms exceeds it "
                 f"(slot wait {bounds[k]:.3f} ms + execution "
                 f"{exec_ms:.3f} ms)", wcet_ms=wcet_ms)
        return wcet_ms

    def predicted_budget(self, request: Request,  # holds: AnytimeServer._lock
                         cost_model: CostModel,
                         backlog: int) -> Optional[int]:
        """Degrade-mode step budget from PREDICTED pressure: price the
        backlog ahead of this request (its queue position amortized over
        capacity slots, each backlog entry costing a full plan at the
        lane's worst per-step rate) and grant whatever steps fit in the
        deadline time that remains.  None when the lane's rate is not
        priceable — the caller falls back to the observed-depth
        formula."""
        key = self._lane_key(request)
        lane = self.lanes.get(key)
        lengths = None
        if isinstance(lane, ForestLane):
            lengths = _plan_lengths(lane.batch.plan)
        try:
            rate = cost_model.step_rate_ms(key[2], lengths)
        except CostModelError:
            return None
        if rate <= 0.0:
            return None
        total = self.total_steps(request)
        wait_ms = (backlog / max(1, self.capacity)) * total * rate
        left_ms = float(request.deadline_ms) - wait_ms
        return max(1, int(left_ms / rate)) if left_ms > 0 else 1

    # -- the serving iteration --------------------------------------------

    @property
    def busy(self) -> bool:  # holds: AnytimeServer._lock
        return bool(self._waiting) or bool(self._resume_pending) or any(
            lane.busy for lane in self.lanes.values()
        )

    @property
    def n_waiting(self) -> int:  # holds: AnytimeServer._lock
        """Requests admitted off the queue but still waiting for a free
        slot, across all lanes (stolen requests awaiting re-admission
        included)."""
        return sum(len(h) for h in self._waiting.values()) + len(
            self._resume_pending)

    def lane_backlog(self, req: Request) -> int:  # holds: AnytimeServer._lock
        """How many requests are already queued or waiting for THIS
        request's lane — what the server's reject admission policy
        compares against capacity*k.  Per-lane, not global: flooding
        one (program, policy, backend) lane must not shed load for an
        idle one.  O(1): counters, not a queue scan."""
        key = self._lane_key(req)
        with self._count_lock:
            queued = self._queued_by_lane.get(key, 0)
        return len(self._waiting.get(key, ())) + queued

    def note_queued(self, req: Request) -> None:
        """Record that ``req`` entered the admission queue (the server
        calls this right after the queue push — fast path included, so
        only the dedicated counter mutex is taken); balanced by
        :meth:`_note_dequeued` when ``_admit`` drains it."""
        key = self._lane_key(req)
        with self._count_lock:
            self._queued_by_lane[key] = self._queued_by_lane.get(key, 0) + 1
            if req.guaranteed:
                self._queued_guaranteed[key] = (
                    self._queued_guaranteed.get(key, 0) + 1)

    def _note_dequeued(self, req: Request) -> None:
        try:
            key = self._lane_key(req)
        except Exception:  # noqa: BLE001 - never let bookkeeping crash a pop
            return
        with self._count_lock:
            n = self._queued_by_lane.get(key, 0)
            if n <= 1:
                self._queued_by_lane.pop(key, None)
            else:
                self._queued_by_lane[key] = n - 1
            if req.guaranteed:
                g = self._queued_guaranteed.get(key, 0)
                if g <= 1:
                    self._queued_guaranteed.pop(key, None)
                else:
                    self._queued_guaranteed[key] = g - 1

    def _admit_resumes(self, now: float,  # holds: AnytimeServer._lock
                       deliveries: list[Delivery]) -> None:
        """(Re-)admit stolen requests ahead of queue arrivals: waiting-
        kind records rejoin the EDF waiting heaps, in-flight records go
        straight into a free slot resuming at their carried boundary.
        No free slot → the record stays pending for the next step (its
        deadline keeps it honest: expiry delivers the carried
        boundary)."""
        if not self._resume_pending:
            return
        records, self._resume_pending = self._resume_pending, []
        for rec in records:
            req = rec.request
            if req.t_deadline <= now:
                deliveries.append(self._resume_delivery(rec))
                continue
            try:
                key = self._lane_key(req)
                lane = self.lane_for(req)
            except Exception as e:  # noqa: BLE001 - isolate bad requests
                deliveries.append(Delivery(req, None, 0, False, error=str(e)))
                continue
            if rec.kind != "inflight":
                heapq.heappush(
                    self._waiting.setdefault(key, []), _waiting_entry(req))
                continue
            if not isinstance(lane, ForestLane) or not lane.admit_resumed(rec):
                self._resume_pending.append(rec)  # retry next step

    def _admit(self, queue: AdmissionQueue, now: float,  # holds: AnytimeServer._lock
               deliveries: list[Delivery]) -> None:
        """Move arrivals into per-lane EDF waiting heaps (once each),
        then fill every lane's free slots earliest-deadline-first.
        Arrivals drain through ``take_all`` — the batched cross-shard
        merge: one swap per shard, one sort, instead of a heap pop per
        request.  A request whose lane raises (unknown program,
        malformed input) fails alone — an error delivery, never a
        crashed loop or a dropped neighbor."""
        self._admit_resumes(now, deliveries)
        for req in queue.take_all():
            self._note_dequeued(req)
            if req.t_deadline <= now:
                # already expired (zero-deadline or stale): the prior
                # readout needs no lane — don't pay order generation or
                # slot-batch construction for a request that cannot run
                deliveries.append(
                    Delivery(req, None, 0, False, budget=req.budget_steps))
                continue
            try:
                key = self._lane_key(req)
                self.lane_for(req)  # create the lane up front (may raise)
            except Exception as e:  # noqa: BLE001 - isolate bad requests
                deliveries.append(Delivery(req, None, 0, False, error=str(e)))
                continue
            heapq.heappush(
                self._waiting.setdefault(key, []), _waiting_entry(req))
        for key in list(self._waiting):
            heap = self._waiting[key]
            lane = self.lanes[key]
            while heap:
                _, t_deadline, _, head = heap[0]
                if t_deadline <= now:
                    # expired while queued (or zero-deadline): prior
                    # readout, 0 steps
                    heapq.heappop(heap)
                    deliveries.append(
                        Delivery(head, None, 0, False,
                                 budget=head.budget_steps))
                    continue
                try:
                    admitted = lane.admit(head)
                except Exception as e:  # noqa: BLE001
                    heapq.heappop(heap)
                    deliveries.append(
                        Delivery(head, None, 0, False, error=str(e)))
                    continue
                if not admitted:
                    break  # lane full; EDF head waits for a recycled slot
                heapq.heappop(heap)
            if not heap:
                del self._waiting[key]

    # -- work stealing (multi-pool tier) ----------------------------------

    def export_request(self, now: float,  # holds: AnytimeServer._lock
                       guaranteed_ok: bool = True) -> Optional[StealRecord]:
        """Give up ONE request for an idle sibling pool to run.

        Preference order: the earliest-deadline non-expired WAITING
        request (migrates at zero device cost — it hasn't stepped), else
        the in-flight forest slot with the LATEST deadline (most slack
        to absorb the migration; its index row syncs to the host here).
        Session lanes never export — their per-request solo sessions
        hold backend-internal state that has no portable boundary form.
        ``guaranteed_ok=False`` excludes certified requests entirely —
        the router passes it when the thief cannot re-certify them (no
        cost model), so a guarantee never migrates onto a pool that
        cannot prove it.  Returns None when there is nothing worth
        stealing."""
        best_key = None
        best = None
        for key, heap in self._waiting.items():
            for entry in heap:
                if entry[1] <= now:
                    continue  # expired; the admit loop will deliver it
                if not guaranteed_ok and entry[3].guaranteed:
                    continue
                if best is None or entry < best:
                    best, best_key = entry, key
        if best is not None:
            heap = self._waiting[best_key]
            heap.remove(best)
            if heap:
                heapq.heapify(heap)
            else:
                del self._waiting[best_key]
            req = best[3]
            return StealRecord(req, "waiting", None, 0, req.budget_steps)
        victim = None  # (t_deadline, lane, slot)
        for lane in self.lanes.values():
            if not isinstance(lane, ForestLane):
                continue
            for slot, req in enumerate(lane.requests):
                if req is None or req.t_deadline <= now:
                    continue
                if not guaranteed_ok and req.guaranteed:
                    continue
                if int(lane.batch.pos[slot]) >= int(lane.batch.budget[slot]):
                    continue  # finished its budget; about to retire here
                if victim is None or req.t_deadline > victim[0]:
                    victim = (req.t_deadline, lane, slot)
        if victim is None:
            return None
        return victim[1].export_slot(victim[2])

    def inject(self, rec: StealRecord) -> None:  # holds: AnytimeServer._lock
        """Accept a stolen request; it (re-)admits ahead of queue
        arrivals on the next :meth:`step`."""
        self._resume_pending.append(rec)

    def _resume_delivery(self, rec: StealRecord) -> Delivery:  # holds: AnytimeServer._lock
        """Deliver a stolen request at its carried boundary: the exact-
        prefix readout of its resumed index row (``jnp-ref``'s
        ``predict_from_state`` — the parity oracle itself), or the prior
        when it never stepped."""
        req = rec.request
        if rec.kind != "inflight" or rec.idx_row is None or rec.pos == 0:
            return Delivery(req, None, 0, False, budget=rec.budget)
        try:
            prog = self._runtime(req).program
            proba = np.asarray(engine.predict_from_state(
                prog.device, jnp.asarray(rec.idx_row)[None]))[0]
        except Exception as e:  # noqa: BLE001 - isolate bad requests
            return Delivery(req, None, 0, False, error=str(e))
        total = self.total_steps(req)
        target = rec.budget if rec.budget is not None else total
        done = rec.pos >= target
        return Delivery(
            req, proba, rec.pos, done and rec.pos >= total,
            budget=rec.budget,
        )

    def _refresh_load_hint(self) -> None:  # holds: AnytimeServer._lock
        """Recompute the lock-free (waiting, active, free) occupancy
        hint once per step — what the router reads when placing and the
        steal trigger reads when picking victims."""
        waiting = self.n_waiting
        active = sum(lane.n_active for lane in self.lanes.values())
        free = sum(
            max(0, lane.capacity - lane.n_active)
            for lane in self.lanes.values()
        )
        self.load_hint = (waiting, active, free)  # unguarded: atomic tuple swap

    def step(self, queue: AdmissionQueue, now: float) -> list[Delivery]:  # holds: AnytimeServer._lock
        """One scheduling iteration.

        1. **dispatch** — every busy lane, earliest deadline first,
           enqueues its next fused masked segment (asynchronous);
        2. **admit** — queued requests join free slots at the fresh
           segment boundary, EDF order; already-expired requests are
           delivered the prior readout immediately;
        3. **harvest** — the previous boundary's readout is pulled to
           the host (overlapping device execution of the segment
           dispatched in 1) and done/expired slots retire, freeing
           capacity for the next admission round.
        """
        for lane in sorted(
            (ln for ln in self.lanes.values() if ln.busy),
            key=lambda ln: ln.min_deadline(),
        ):
            stepped = lane.dispatch()
            if stepped:
                self.metrics.record_dispatch(stepped, lane.capacity)

        self._tick += 1
        deliveries: list[Delivery] = []
        self._admit(queue, now, deliveries)
        for lane in self.lanes.values():
            deliveries.extend(lane.harvest(now))
        self._evict_idle_lanes()
        self._refresh_load_hint()
        return deliveries

    def flush(self, queue: AdmissionQueue) -> list[Delivery]:  # holds: AnytimeServer._lock
        """Shutdown drain (``AnytimeServer.stop()``): answer EVERY
        admitted request now — queued and slot-waiting requests get the
        prior (0-step) readout, stolen requests their carried boundary,
        in-flight slots their last segment boundary.  No new work is
        dispatched."""
        deliveries: list[Delivery] = []
        for req in queue.take_all():
            self._note_dequeued(req)
            deliveries.append(
                Delivery(req, None, 0, False, budget=req.budget_steps))
        for heap in self._waiting.values():
            deliveries.extend(
                Delivery(req, None, 0, False, budget=req.budget_steps)
                for _, _, _, req in heap)
        self._waiting.clear()
        records, self._resume_pending = self._resume_pending, []
        deliveries.extend(self._resume_delivery(rec) for rec in records)
        for lane in self.lanes.values():
            deliveries.extend(lane.flush())
        self._refresh_load_hint()
        return deliveries
