"""``repro.serve`` — deadline-aware async batch serving for anytime
inference.

The subsystem that turns per-session anytime machinery
(:mod:`repro.schedule`) into a *server*: many concurrent deadline-bearing
requests multiplexed onto one device runtime.

* :mod:`repro.serve.queue` — :class:`Request`/:class:`Result` and the
  EDF :class:`AdmissionQueue` with monotonic-clock bookkeeping;
* :mod:`repro.serve.scheduler` — the earliest-deadline-first
  micro-batcher: requests sharing a ``(program, policy, backend)`` key
  coalesce into fixed-capacity slot batches executing the same cached
  :class:`~repro.schedule.backends.StepPlan` segments, with per-slot
  masking for mid-flight admission and slot recycling;
* :mod:`repro.serve.server` — :class:`AnytimeServer`, the
  double-buffered driver loop (dispatch segment k+1 while harvesting
  segment k's readouts and retiring expired slots);
* :mod:`repro.serve.driver` — the background :class:`ServeDriver`
  thread that owns that loop in threaded mode, plus
  :func:`as_completed` over tickets;
* :mod:`repro.serve.metrics` — deadline-hit-rate, p50/p99
  steps-at-deadline, slot occupancy, requests/sec, degraded requests
  (bounded-reservoir percentiles — snapshots stay O(reservoir));
* :mod:`repro.serve.pool` / :mod:`repro.serve.router` — the multi-device
  tier: :class:`PooledAnytimeServer` composes one device-pinned pool per
  device behind a backlog-aware :class:`Router` with segment-boundary
  work stealing;
* :mod:`repro.serve.qos` — the frozen :class:`QoS` request spec every
  ``submit`` accepts (deadline, policy, backend, program, budget,
  ``guaranteed``);
* :mod:`repro.serve.admission` — the admission-policy registry
  (:func:`register_admission`/:func:`get_admission_policy`/
  :func:`list_admissions`; ``edf``/``reject``/``degrade``/
  ``certified``);
* :mod:`repro.serve.cost` — :class:`CostModel`, pricing a request's
  worst case from the calibrated per-platform WCET table
  (``python -m tools.obs calibrate``) for certified admission and
  predicted-pressure degrade budgets.

Quickstart (threaded — the loop runs on a background driver; callers
overlap their own work with device execution)::

    from repro.serve import AnytimeServer, QoS, as_completed

    with AnytimeServer(runtime, capacity=16) as server:
        tickets = [server.submit(x, QoS(deadline_ms=2.0)) for x in rows]
        for t in as_completed(tickets):
            print(t.result().prediction)

Cooperative (no thread — the caller pumps the loop)::

    server = AnytimeServer(runtime, capacity=16)
    tickets = [server.submit(x, QoS(deadline_ms=2.0)) for x in rows]
    server.drain()
    preds = [t.result().prediction for t in tickets]
    print(server.metrics.snapshot())
"""
from repro.serve.admission import (
    AdmissionPolicy,
    CertifiedAdmission,
    DegradeAdmission,
    EdfAdmission,
    RejectAdmission,
    get_admission_policy,
    list_admissions,
    register_admission,
)
from repro.serve.cost import LAG_ITERATIONS, CostModel, CostModelError
from repro.serve.driver import DriverDead, ServeDriver, as_completed
from repro.serve.metrics import Reservoir, ServeMetrics
from repro.serve.pool import PooledAnytimeServer
from repro.serve.qos import QoS, resolve_qos
from repro.serve.queue import (
    AdmissionQueue,
    AdmissionRejected,
    CertificationFailed,
    Request,
    Result,
)
from repro.serve.router import Router
from repro.serve.scheduler import ForestLane, Scheduler, SessionLane, StealRecord
from repro.serve.server import AnytimeServer, Ticket

__all__ = [
    "AdmissionPolicy",
    "AdmissionQueue",
    "AdmissionRejected",
    "AnytimeServer",
    "CertificationFailed",
    "CertifiedAdmission",
    "CostModel",
    "CostModelError",
    "DegradeAdmission",
    "DriverDead",
    "EdfAdmission",
    "ForestLane",
    "LAG_ITERATIONS",
    "PooledAnytimeServer",
    "QoS",
    "RejectAdmission",
    "Request",
    "Reservoir",
    "Result",
    "Router",
    "Scheduler",
    "ServeDriver",
    "ServeMetrics",
    "SessionLane",
    "StealRecord",
    "Ticket",
    "as_completed",
    "get_admission_policy",
    "list_admissions",
    "register_admission",
    "resolve_qos",
]
