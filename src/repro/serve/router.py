"""Deadline-aware request routing and work stealing between pools.

The :class:`Router` is the placement brain of a
:class:`~repro.serve.pool.PooledAnytimeServer`: every submit picks the
pool with the least backlog (queued + slot-waiting + in-flight, read
from LOCK-FREE hints — the shard length mirrors and each scheduler's
``load_hint`` tuple), so tight-deadline requests land where they wait
least.  Ties rotate round-robin to spread warmup.

Stealing runs from the CONSUMER side: an idle pool's driver, before
parking, asks the router to pull one request over from the most-loaded
sibling (``steal_into``).  The victim exports a whole request at a
segment-boundary-aligned point (:meth:`~repro.serve.scheduler.
Scheduler.export_request` — a waiting request at zero device cost, else
the in-flight slot with the most deadline slack, its index row synced
to the host), and the thief resumes it exactly like a mid-flight
admission — so the bit-parity guarantee survives the migration.  The
two pool locks are taken strictly one-at-a-time (victim's, released,
then thief's): there is no lock order between pools to get wrong.
"""
from __future__ import annotations

import itertools
from typing import Optional

from repro.serve.queue import CertificationFailed


def _backlog_score(pool) -> int:
    """Lock-free load estimate of one pool: undrained submissions (shard
    length mirrors) + slot-waiting + in-flight (scheduler load hint).
    Approximate by design — routing quality, never correctness, depends
    on it."""
    waiting, active, _free = pool.scheduler.load_hint
    return len(pool.queue) + waiting + active


class Router:
    """Backlog-aware placement + idle-pool work stealing over a fixed
    pool list.  Stateless apart from a round-robin tiebreaker; every
    decision reads lock-free hints, so routing never serializes
    submitters behind pool locks."""

    def __init__(self, pools, metrics, tracer):
        self.pools = list(pools)  # unguarded: immutable after __init__
        self.metrics = metrics    # unguarded: internally locked
        self.tracer = tracer      # unguarded: internally locked
        # itertools.count.__next__ is GIL-atomic: concurrent submitters
        # may interleave tiebreaks but never corrupt the counter
        self._rr = itertools.count()  # unguarded: atomic counter

    def place(self, request) -> int:
        """Index of the pool this request should join: least backlog,
        round-robin among ties — the EDF queues inside the chosen pool
        handle deadline ordering from there."""
        n = len(self.pools)
        if n == 1:
            return 0
        scores = [_backlog_score(p) for p in self.pools]
        lo = min(scores)
        candidates = [i for i, s in enumerate(scores) if s == lo]
        return candidates[next(self._rr) % len(candidates)]

    def order(self, request) -> list[int]:
        """Every pool index in placement-preference order (ascending
        backlog, round-robin rotation among ties).  The guaranteed
        submit path tries each in turn until one pool's admission
        certifies the request's deadline."""
        n = len(self.pools)
        if n == 1:
            return [0]
        scores = [_backlog_score(p) for p in self.pools]
        start = next(self._rr)
        return sorted(range(n), key=lambda i: (scores[i], (i - start) % n))

    def _pick_victim(self, thief) -> Optional[object]:
        """Most-loaded sibling worth stealing from, or None.  A victim
        must have work beyond what occupies it RIGHT NOW: something
        queued/waiting, or at least two in-flight requests — stealing a
        pool's only running request migrates latency without adding
        parallelism."""
        victim, victim_score = None, 0
        for pool in self.pools:
            if pool is thief:
                continue
            waiting, active, _free = pool.scheduler.load_hint
            backlog = len(pool.queue) + waiting
            if backlog == 0 and active < 2:
                continue
            score = backlog + active
            if score > victim_score:
                victim, victim_score = pool, score
        return victim

    def steal_into(self, thief) -> bool:
        """Pull one request from the most-loaded sibling into ``thief``.

        Called by an idle pool's driver (lock-free) before it parks —
        and by the cooperative facade loop for driverless pools.
        Returns True when a request migrated (the caller should re-check
        for work instead of parking)."""
        # thief must look idle by its own hints; racy — worst case we
        # steal into a pool that just got work, which is still progress
        t_waiting, t_active, _ = thief.scheduler.load_hint
        if len(thief.queue) or t_waiting or t_active:
            return False
        victim = self._pick_victim(thief)
        if victim is None:
            return False
        tracer = self.tracer
        if tracer.enabled:
            with tracer.span("serve.steal", victim=victim.name,
                             thief=thief.name) as sp:
                moved = self._migrate(victim, thief)
                sp.args["moved"] = moved
        else:
            moved = self._migrate(victim, thief)
        return moved

    def _migrate(self, victim, thief) -> bool:
        """One request, victim → thief.  Pool locks strictly
        one-at-a-time.

        Guaranteed requests only migrate onto a pool that can PROVE the
        remaining work still fits the remaining deadline: a thief with
        no cost model never receives one (``guaranteed_ok=False``
        excludes them at export), and a thief that fails to re-certify
        gives the request straight back to the victim — whose own
        certificate still holds, since losing a racing steal only ever
        DECREASES the victim's load."""
        guaranteed_ok = thief.cost_model is not None
        with victim._cond:
            rec = victim.scheduler.export_request(
                victim.clock(), guaranteed_ok=guaranteed_ok)
        if rec is None:
            return False
        if rec.request.guaranteed:
            req = rec.request
            now = thief.clock()
            total = thief.scheduler.total_steps(req)
            target = rec.budget if rec.budget is not None else total
            remaining = max(1, int(target) - int(rec.pos))
            left_ms = max(0.0, (req.t_deadline - now) * 1e3)
            with thief._cond:
                try:
                    thief.scheduler.certify(
                        req, thief.cost_model, now,
                        steps=remaining, deadline_ms=left_ms)
                except CertificationFailed:
                    certified = False
                else:
                    thief.scheduler.inject(rec)
                    certified = True
            if not certified:
                with victim._cond:
                    victim.scheduler.inject(rec)
                return False
        else:
            with thief._cond:
                thief.scheduler.inject(rec)
        self.metrics.record_steal()
        if self.tracer.enabled:
            self.tracer.instant(
                "serve.route", request_id=rec.request.request_id,
                pool=thief.name, stolen_from=victim.name, kind=rec.kind,
                resumed_pos=rec.pos)
        return True
