"""`QoS` — the one request-shaping spec ``submit`` accepts.

Before this module, per-request service parameters were a kwarg sprawl
across ``AnytimeServer.submit`` / ``PooledAnytimeServer.submit`` /
``Request`` (deadline, policy, backend, program, degrade budget — and
now ``guaranteed``).  :class:`QoS` collapses them into one frozen,
validated value: build it once, submit it with many inputs, compare it,
print it.

    >>> spec = QoS(deadline_ms=50.0, backend="pallas", guaranteed=True)
    >>> ticket = server.submit(x, spec)

The legacy kwarg surface (``submit(x, deadline_ms, policy=...,
backend=..., program=...)``) still works through a deprecation shim
(:func:`resolve_qos`) that builds the identical ``QoS`` — byte-parity
with the new path is tested, mirroring the ``generate_order`` registry
migration — and emits a :class:`DeprecationWarning`.  Mixing a ``QoS``
with legacy kwargs in one call is a :class:`TypeError`, never a silent
precedence rule.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Any, Optional, Union

from repro.serve.queue import PolicyLike, Request

__all__ = ["QoS", "resolve_qos"]


@dataclasses.dataclass(frozen=True)
class QoS:
    """Per-request quality-of-service spec.

    ``deadline_ms`` is relative to submission.  ``guaranteed=True``
    requests the certified contract: admission prices the worst case
    against the server's calibrated cost model and either proves the
    deadline or rejects at submit (``CertificationFailed``); admitted
    guaranteed requests run their FULL plan — ``budget_steps`` cannot be
    combined with it, and degrade-mode never shrinks it.
    """

    deadline_ms: float
    policy: PolicyLike = "backward_squirrel"
    backend: Optional[str] = None
    program: str = "default"
    #: explicit anytime step cap (None = full plan).  Degrade-mode
    #: admission may stamp its own cap on best-effort requests; an
    #: explicit cap here is honored as-is.
    budget_steps: Optional[int] = None
    guaranteed: bool = False

    def __post_init__(self):
        if self.deadline_ms < 0:
            raise ValueError(
                f"deadline_ms must be >= 0, got {self.deadline_ms}")
        if self.budget_steps is not None and self.budget_steps < 1:
            raise ValueError(
                f"budget_steps must be >= 1, got {self.budget_steps}")
        if self.guaranteed and self.budget_steps is not None:
            raise ValueError(
                "guaranteed requests run the full plan; budget_steps "
                "cannot be combined with guaranteed=True")

    def request(self, x: Any) -> Request:
        """Materialize one :class:`Request` carrying this spec."""
        return Request(
            x=x,
            deadline_ms=float(self.deadline_ms),
            policy=self.policy,
            backend=self.backend,
            program=self.program,
            budget_steps=self.budget_steps,
            guaranteed=self.guaranteed,
        )


_LEGACY_HINT = (
    "submit(x, deadline_ms, policy=..., backend=..., program=...) is "
    "deprecated; pass a QoS spec instead: "
    "submit(x, QoS(deadline_ms=..., policy=..., backend=..., "
    "program=..., guaranteed=...))"
)


def resolve_qos(qos: Union[QoS, float, None],
                deadline_ms: Optional[float],
                policy: Optional[PolicyLike],
                backend: Optional[str],
                program: Optional[str],
                budget_steps: Optional[int],
                guaranteed: Optional[bool],
                stacklevel: int = 3) -> QoS:
    """Shared ``submit`` shim: one ``QoS`` from either surface.

    Accepts the new surface (``qos`` is a :class:`QoS`, every legacy
    kwarg None), or the legacy one (``qos`` positionally a bare deadline
    number, or ``deadline_ms=``, plus the old kwargs) — the latter
    emits a DeprecationWarning attributed to the caller's call site.
    Mixing both surfaces raises TypeError.
    """
    if isinstance(qos, QoS):
        if (deadline_ms is not None or policy is not None
                or backend is not None or program is not None
                or budget_steps is not None or guaranteed is not None):
            raise TypeError(
                "pass either a QoS spec or the legacy kwargs, not both")
        return qos
    if qos is not None and not isinstance(qos, (int, float)):
        raise TypeError(
            f"qos must be a QoS spec (or a legacy deadline_ms number), "
            f"got {type(qos).__name__}")
    if qos is not None and deadline_ms is not None:
        raise TypeError(
            "deadline given twice (positionally and as deadline_ms=)")
    deadline = qos if qos is not None else deadline_ms
    if deadline is None:
        raise TypeError(
            "submit needs a deadline: submit(x, QoS(deadline_ms=...))")
    warnings.warn(_LEGACY_HINT, DeprecationWarning, stacklevel=stacklevel)
    return QoS(
        deadline_ms=float(deadline),
        policy=policy if policy is not None else "backward_squirrel",
        backend=backend,
        program=program if program is not None else "default",
        budget_steps=budget_steps,
        guaranteed=bool(guaranteed) if guaranteed is not None else False,
    )
