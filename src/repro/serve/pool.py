"""`PooledAnytimeServer` — the multi-device serving tier.

One facade composes N independent :class:`~repro.serve.server.
AnytimeServer` *pools*, each pinned to one device (``backend_opts
["pin_device"]`` — forest tables, inputs, and slot state committed to
that device; the ``sharded`` backend runs on a degenerate one-device
mesh so every pool executes the same code path as the single-server
tier).  Requests enter through a :class:`~repro.serve.router.Router`
that places each submit on the least-backlogged pool, and idle pools
*steal* whole requests from loaded siblings at segment-boundary-aligned
points, so one hot pool cannot strand capacity elsewhere.

Shared across pools — the properties that make N pools look like one
server:

* ONE request-id counter (ids stay globally unique, so EDF entries and
  the pending-ticket registry never collide across pools);
* ONE :class:`~repro.serve.metrics.ServeMetrics` (tier-wide hit rate /
  percentiles / steal counts);
* ONE pending-ticket map + lock, rebound onto every pool before serving
  starts — a stolen request DELIVERS on a different pool than it was
  submitted to, and its ticket must be found there;
* ONE tracer (per-pool events disambiguate via ``track_prefix`` lane
  tracks and the ``serve.steal``/``serve.route`` events);
* this facade's condition variable: tickets are constructed with the
  facade as owner, every pool notifies it after deliveries
  (:meth:`AnytimeServer._notify_owner`), so ``Ticket.result`` /
  ``as_completed`` / threaded ``drain`` block in one place.

Per pool — the properties that remove cross-device serialization:

* its own sharded admission queue, scheduler, lanes, and locks (a
  submit or dispatch on pool 0 never touches pool 1's locks);
* its own background driver thread in threaded mode, parking on its own
  wake condition and stealing work before it parks.

Both drive modes of the single server carry over: ``start()`` spawns
one driver per pool; cooperative callers pump :meth:`step`, which
round-robins the pools and rebalances idle ones.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Callable, Optional, Sequence, Union

import numpy as np

from repro.obs import NULL_TRACER
from repro.schedule.runtime import AnytimeRuntime
from repro.serve.cost import CostModel
from repro.serve.metrics import ServeMetrics
from repro.serve.qos import QoS, resolve_qos
from repro.serve.queue import AdmissionRejected, PolicyLike, Request, Result
from repro.serve.router import Router
from repro.serve.server import AnytimeServer, Ticket


class PooledAnytimeServer:
    """N per-device serving pools behind one router — one logical
    deadline-aware server whose capacity scales with device count.

    ``pools`` defaults to one per visible jax device (``devices`` picks
    an explicit subset; with fewer devices than pools, pools wrap —
    useful for oversubscription tests).  ``queue_shards`` is forwarded
    to every pool's admission queue.  ``steal=False`` disables work
    stealing (placement only) for A/B measurement.
    """

    def __init__(
        self,
        runtime: Optional[AnytimeRuntime] = None,
        *,
        programs: Optional[dict] = None,
        pools: Optional[int] = None,
        devices: Optional[Sequence] = None,
        capacity: int = 16,
        chunk: int = 8,
        clock=time.monotonic,
        backend_opts: Optional[dict] = None,
        admission: str = "edf",
        admission_k: float = 2.0,
        cost_model: Optional[CostModel] = None,
        tracer=None,
        queue_shards: int = 1,
        steal: bool = True,
    ):
        if devices is None:
            import jax

            devices = list(jax.devices())
        if not devices:
            raise ValueError("PooledAnytimeServer needs at least one device")
        n_pools = int(pools) if pools is not None else len(devices)
        if n_pools < 1:
            raise ValueError(f"pools must be >= 1, got {pools}")
        self.clock = clock                    # unguarded: immutable callable
        self.admission = admission            # unguarded: immutable config
        # one calibrated table prices every pool (they share the
        # platform); the router reads each POOL's cost_model when
        # deciding whether a guarantee may migrate there
        self.cost_model = cost_model          # unguarded: immutable config
        self.steal = bool(steal)              # unguarded: immutable config
        self.metrics = ServeMetrics()         # unguarded: internally locked
        self.tracer = tracer if tracer is not None else NULL_TRACER  # unguarded: internally locked
        # one id stream for the whole tier: request ids are globally
        # unique, so shard routing, EDF entries, and the shared pending
        # registry never collide across pools
        self._ids = itertools.count()         # unguarded: atomic counter
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._pending_lock = threading.Lock()
        self._pending: dict[int, Ticket] = {}  # guarded-by: _pending_lock
        self._closed = False                  # unguarded: write-once latch
        opts = dict(backend_opts or {})
        built = []
        for i in range(n_pools):
            pool = AnytimeServer(
                runtime,
                programs=programs,
                capacity=capacity,
                chunk=chunk,
                clock=clock,
                backend_opts={**opts, "pin_device": devices[i % len(devices)]},
                admission=admission,
                admission_k=admission_k,
                cost_model=cost_model,
                tracer=tracer,
                queue_shards=queue_shards,
                metrics=self.metrics,
                ids=self._ids,
                track_prefix=f"p{i}:",
            )
            # single-threaded setup rebinds (documented hooks on
            # AnytimeServer): tickets resolve on the facade's condition,
            # and all pools share ONE pending registry so a request can
            # deliver on a different pool than it was submitted to
            pool._ticket_owner = self
            pool._pending = self._pending
            pool._pending_lock = self._pending_lock
            built.append(pool)
        self.pools = tuple(built)             # unguarded: immutable after __init__
        # certify_all admission (e.g. "certified"): every submit takes
        # the guaranteed multi-pool placement path
        self._certify_all = built[0]._admission_policy.certify_all  # unguarded: immutable config
        self.router = Router(self.pools, self.metrics, self.tracer)  # unguarded: immutable after __init__
        if self.steal:
            for pool in self.pools:
                pool.on_idle = self._make_idle_hook(pool)

    def _make_idle_hook(self, pool) -> Callable[[], bool]:
        router = self.router
        return lambda: router.steal_into(pool)

    @property
    def n_pools(self) -> int:
        return len(self.pools)

    # -- driver lifecycle --------------------------------------------------

    @property
    def driver_running(self) -> bool:
        return any(p.driver_running for p in self.pools)

    @property
    def _driver_failed(self) -> bool:
        return any(p._driver_failed for p in self.pools)

    def _raise_if_driver_dead(self) -> None:
        for pool in self.pools:
            pool._raise_if_driver_dead()

    def start(self) -> "PooledAnytimeServer":
        """Spawn one background driver per pool (idempotent)."""
        for pool in self.pools:
            pool.start()
        return self

    def stop(self, timeout: Optional[float] = 10.0) -> list[Result]:
        """Stop every pool's driver and flush every admitted request to
        its last segment-boundary readout.  A request stolen mid-stop is
        flushed by whichever pool holds it — the shared pending registry
        resolves its ticket either way."""
        flushed: list[Result] = []
        for pool in self.pools:
            flushed.extend(pool.stop(timeout))
        with self._cond:
            self._cond.notify_all()
        return flushed

    def close(self) -> None:
        with self._lock:
            self._closed = True
        for pool in self.pools:
            pool.close()
        with self._cond:
            self._cond.notify_all()

    def __enter__(self) -> "PooledAnytimeServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- submission --------------------------------------------------------

    def submit(
        self,
        x,
        qos: Union[QoS, float, None] = None,
        deadline_ms: Optional[float] = None,
        policy: Optional[PolicyLike] = None,
        backend: Optional[str] = None,
        program: Optional[str] = None,
        budget_steps: Optional[int] = None,
        guaranteed: Optional[bool] = None,
    ) -> Ticket:
        """Mirror of :meth:`AnytimeServer.submit`: ``submit(x, QoS(...))``
        (the legacy kwarg surface works through the same deprecation
        shim)."""
        spec = resolve_qos(qos, deadline_ms, policy, backend, program,
                           budget_steps, guaranteed)
        return self.submit_request(spec.request(x))

    def submit_request(self, request: Request) -> Ticket:
        """Route to the least-backlogged pool and submit there.  The
        chosen pool's own fast/slow submit path takes over — this layer
        adds only the (lock-free) placement decision.  Guaranteed
        requests instead try pools in placement-preference order until
        one CERTIFIES the deadline; if none can, the last pool's
        :class:`~repro.serve.queue.CertificationFailed` propagates."""
        if self._closed:  # racy hint; pool/shard closed flags authoritative
            raise RuntimeError(
                "submit on a closed PooledAnytimeServer (close() was called)")
        if request.guaranteed or self._certify_all:
            i, ticket = self._submit_guaranteed(request)
        else:
            i = self.router.place(request)
            ticket = self.pools[i].submit_request(request)
        self.metrics.record_route()
        if self.tracer.enabled:
            self.tracer.instant(
                "serve.route", request_id=ticket.request_id,
                pool=self.pools[i].name,
                deadline_ms=request.deadline_ms)
        return ticket

    def _submit_guaranteed(self, request: Request) -> tuple[int, Ticket]:
        """Certified placement: each candidate pool prices the request
        against ITS slot occupancy under its own lock (ascending-backlog
        order, so the cheapest certificate is tried first); the first
        pool that certifies wins.  One pool's rejection never commits
        the request anywhere — a guarantee is either proven on the pool
        that will run it, or the submit fails."""
        last_error: Optional[AdmissionRejected] = None
        for i in self.router.order(request):
            try:
                return i, self.pools[i].submit_request(request)
            except AdmissionRejected as e:
                last_error = e
        assert last_error is not None  # n_pools >= 1
        raise last_error

    # -- the cooperative loop ----------------------------------------------

    @property
    def busy(self) -> bool:
        return any(p.busy for p in self.pools)

    def step(self) -> bool:
        """One round-robin pass: step every busy pool, then let idle
        pools steal from loaded ones.  Returns whether work remains —
        the cooperative analogue of N driver threads."""
        for pool in self.pools:
            if pool.busy:
                pool.step()
        if self.steal:
            for pool in self.pools:
                if not pool.busy:
                    self.router.steal_into(pool)
        return self.busy

    def drain(self, max_steps: Optional[int] = None) -> list[Result]:
        """Cooperative: pump :meth:`step` until every pool is idle;
        returns results delivered during the drain (across pools, in
        delivery order).  Threaded: block until the tier goes idle and
        return ``[]`` (results live on the tickets)."""
        if self.driver_running:
            with self._cond:
                seq0 = sum(p._step_seq for p in self.pools)
                self._cond.wait_for(
                    lambda: not self.busy or not self.driver_running
                    or (max_steps is not None
                        and sum(p._step_seq for p in self.pools) - seq0
                        >= max_steps))
            self._raise_if_driver_dead()
            return []
        buffer: list[Result] = []
        for pool in self.pools:
            with pool._lock:
                pool._drain_buffer = buffer
        try:
            steps = 0
            while self.busy:
                self.step()
                steps += 1
                if max_steps is not None and steps >= max_steps:
                    break
        finally:
            for pool in self.pools:
                with pool._lock:
                    pool._drain_buffer = None
        return buffer

    def serve(
        self,
        xs: Sequence,
        deadline_ms: Union[float, Sequence[float]],
        policy: PolicyLike = "backward_squirrel",
        backend: Optional[str] = None,
        program: str = "default",
    ) -> list[Result]:
        """Batch convenience mirroring :meth:`AnytimeServer.serve`."""
        if np.isscalar(deadline_ms):
            deadline_ms = [float(deadline_ms)] * len(xs)
        if len(deadline_ms) != len(xs):
            raise ValueError("deadline_ms must be scalar or match len(xs)")
        tickets = [
            self.submit(x, QoS(deadline_ms=float(d), policy=policy,
                               backend=backend, program=program))
            for x, d in zip(xs, deadline_ms)
        ]
        self.drain()
        return [t.result() for t in tickets]

    def result(self, request_id: int) -> Optional[Result]:
        """Result of a still-tracked request, or None while pending."""
        with self._pending_lock:
            ticket = self._pending.get(request_id)
        return ticket._result if ticket is not None else None


__all__ = ["PooledAnytimeServer"]
