"""Background serving driver: the thread that owns the dispatch → admit
→ harvest loop.

:class:`ServeDriver` turns :class:`~repro.serve.server.AnytimeServer`
from a cooperative loop (callers must pump ``step()``/``drain()``) into
a fire-and-forget service: ``server.start()`` spawns the driver,
``submit()`` becomes a thread-safe enqueue that wakes it, and callers
overlap their own work with device execution, collecting answers through
``concurrent.futures``-style :class:`~repro.serve.server.Ticket`
semantics (``add_done_callback``, blocking ``result(timeout=)``, and
:func:`as_completed`).

The driver holds the server's lock only for the duration of one loop
iteration, so submissions interleave with (at worst one segment of)
device execution.  When the server goes idle the thread first offers
itself for **work stealing** (``server.on_idle`` — a pooled tier's
router pulls a request over from an overloaded sibling pool), then
parks on the server's dedicated wake condition (``server._wake``) until
the next submission — no busy spin, and no contention with submitters
on the main lock.  A driver that dies on an unexpected exception
records it, wakes every blocked ``result()`` caller, and the error
propagates to them (and to the next ``submit``) instead of silently
stalling all deadlines.
"""
from __future__ import annotations

import threading
import time
from typing import Iterable, Iterator, Optional

#: how long an idle driver parks between wake-up checks.  Wake-ups are
#: notified (submit/stop), so this is only a backstop for clocks the
#: condition variable cannot see (e.g. test-controlled manual clocks).
IDLE_WAIT_S = 0.05


class DriverDead(RuntimeError):
    """The background driver thread died on an exception.

    Raised to ``Ticket.result()`` callers (and subsequent ``submit``
    attempts) with the driver's original exception as ``__cause__`` —
    a dead driver must surface loudly, not stall every in-flight
    deadline.
    """


class ServeDriver(threading.Thread):
    """Daemon thread running ``server.step()`` while there is work.

    Lifecycle is owned by the server: ``AnytimeServer.start()`` builds
    and starts one, ``AnytimeServer.stop()`` signals it, joins it, then
    flushes still-admitted requests to their last boundary readouts.
    """

    _seq = 0

    def __init__(self, server, idle_wait_s: float = IDLE_WAIT_S):
        ServeDriver._seq += 1
        super().__init__(name=f"repro-serve-driver-{ServeDriver._seq}",
                         daemon=True)
        self._server = server        # unguarded: bound once, never reassigned
        self._idle_wait_s = float(idle_wait_s)  # unguarded: immutable config
        # Event is internally synchronized
        self._stop_requested = threading.Event()  # unguarded: Event syncs itself
        # write-once from the (single) driver thread, then only read
        self.exception: Optional[BaseException] = None  # unguarded: write-once latch

    # -- control -----------------------------------------------------------

    @property
    def stopping(self) -> bool:
        return self._stop_requested.is_set()

    def request_stop(self) -> None:
        """Ask the loop to exit after its current iteration and wake it
        if parked."""
        self._stop_requested.set()
        with self._server._cond:
            self._server._cond.notify_all()
        with self._server._wake:
            self._server._wake.notify_all()

    # -- the loop ----------------------------------------------------------

    def run(self) -> None:  # pragma: no cover - exercised via threads
        server = self._server
        try:
            while not self._stop_requested.is_set():
                with server._cond:
                    busy = server.busy
                if busy:
                    server.step()
                    continue
                if self._stop_requested.is_set():
                    break
                # idle: offer this pool for work stealing before parking
                # (called WITHOUT any lock held — the hook talks to
                # sibling pools' locks)
                on_idle = server.on_idle
                if on_idle is not None and on_idle():
                    continue
                with server._wake:
                    # re-check the lock-free queued hint under _wake: a
                    # submit lands in the shard mirrors before it
                    # notifies, so the wakeup cannot be lost (timeout is
                    # a backstop for manual clocks, not a poll)
                    if not server.has_queued and not self._stop_requested.is_set():
                        server._wake.wait(self._idle_wait_s)
        except BaseException as e:  # noqa: BLE001 - must surface to callers
            self.exception = e
            with server._cond:
                server._cond.notify_all()  # wake blocked result() waits


def as_completed(tickets: Iterable, timeout: Optional[float] = None) -> Iterator:
    """Yield tickets as their results arrive (``concurrent.futures``
    style), regardless of completion order.

    Works in both serving modes: with a running driver it blocks on the
    server's condition variable; without one it drives the cooperative
    loop itself.  Raises :class:`TimeoutError` if ``timeout`` seconds
    elapse with tickets still pending, and :class:`DriverDead` if a
    driver thread died with requests outstanding.
    """
    pending = list(tickets)
    t_end = None if timeout is None else time.monotonic() + timeout
    while pending:
        still = []
        for t in pending:
            if t.done:
                yield t
            else:
                still.append(t)
        pending = still
        if not pending:
            break
        if t_end is not None and time.monotonic() >= t_end:
            raise TimeoutError(
                f"{len(pending)} ticket(s) pending after {timeout} s")
        # make progress: cooperatively step driverless servers, then
        # block on one threaded server's condition until something lands
        servers = []
        for t in pending:
            if t._server not in servers:
                servers.append(t._server)
        threaded = [s for s in servers if s.driver_running]
        for s in servers:
            if not s.driver_running:
                s._raise_if_driver_dead()
                if not s.step() and any(
                        not t.done for t in pending if t._server is s):
                    raise RuntimeError(
                        "server idle with tickets still undelivered")
        if threaded:
            srv = threaded[0]
            if len(servers) > 1:
                # other servers may deliver without notifying THIS
                # condition: bound the wait
                wait_s: Optional[float] = IDLE_WAIT_S
            elif t_end is not None:
                wait_s = max(0.0, t_end - time.monotonic())
            else:
                wait_s = None
            with srv._cond:
                # predicate checked under the lock: a delivery landing
                # between the scan above and this wait cannot be lost
                srv._cond.wait_for(
                    lambda: any(t.done for t in pending)
                    or not srv.driver_running,
                    timeout=wait_s,
                )
            srv._raise_if_driver_dead()
