"""`AnytimeServer` — the deadline-aware serving driver loop.

One server multiplexes many concurrent anytime requests onto one device
runtime.  The loop is double-buffered: each iteration *dispatches* the
next fused step-plan segment for every lane (asynchronous on device),
*admits* queued requests into freed slots at the fresh segment boundary,
then *harvests* the previous boundary's readout on the host while the
device is still executing — so deadline checks and result delivery
overlap segment execution instead of serializing with it.  Every request
is answered with the last segment-boundary readout the host had seen at
its deadline: bit-identical to a solo ``jnp-ref`` session advanced the
same number of steps, never a torn mid-segment state.

    server = AnytimeServer(runtime, capacity=16)
    tickets = [server.submit(x, deadline_ms=2.0) for x in rows]
    server.drain()
    preds = [t.result().prediction for t in tickets]

Programs are pluggable: forests serve through masked slot batches
(:class:`~repro.schedule.runtime.SessionBatch`); any other
:class:`AnytimeProgram` (e.g. the LM
:class:`~repro.serving.anytime_depth.EnsembleProgram`) is driven through
per-request session lanes by the same loop, queue, and metrics.
"""
from __future__ import annotations

import time
from typing import Optional, Sequence, Union

import numpy as np

from repro.schedule.runtime import AnytimeRuntime
from repro.serve.metrics import ServeMetrics
from repro.serve.queue import (
    AdmissionQueue,
    AdmissionRejected,
    PolicyLike,
    Request,
    Result,
)
from repro.serve.scheduler import Delivery, Scheduler


class Ticket:
    """Handle to an in-flight request; resolves to a :class:`Result`.

    Delivery writes the result directly onto the ticket (the server
    tracks only PENDING tickets), so a long-lived server's memory holds
    results exactly as long as their callers hold the tickets — whether
    collected via ``result()`` or via ``drain()``'s return value.
    """

    __slots__ = ("_server", "request", "_result")

    def __init__(self, server: "AnytimeServer", request: Request):
        self._server = server
        self.request = request
        self._result: Optional[Result] = None

    @property
    def request_id(self) -> int:
        return self.request.request_id

    @property
    def done(self) -> bool:
        return self._result is not None

    def result(self) -> Result:
        """The request's result, driving the server loop if needed."""
        while self._result is None:
            if not self._server.step() and self._result is None:
                raise RuntimeError(  # pragma: no cover - defensive
                    f"server idle but request {self.request_id} undelivered"
                )
        return self._result


class AnytimeServer:
    """Deadline-aware async batch server over anytime runtimes.

    ``runtime`` (or a ``programs`` name -> :class:`AnytimeRuntime` dict)
    names what is served; ``capacity`` is the slot count per
    ``(program, policy, backend)`` lane; ``chunk`` is the per-iteration
    step granularity of session lanes (slot lanes use plan segments);
    ``clock`` must be monotonic — injectable for deterministic tests.

    ``admission`` picks the overload policy: ``"edf"`` (default)
    accepts everything and lets the EDF queue starve whoever it must —
    a starved request is delivered its prior (0-step) readout;
    ``"reject"`` sheds load at submission instead, raising
    :class:`~repro.serve.queue.AdmissionRejected` whenever the
    submitted request's LANE already has ``capacity * admission_k``
    requests queued or waiting for a slot (per-lane: flooding one
    program/policy must not shed load for an idle one) — the admitted
    population keeps its anytime step quality and callers learn about
    the overload at submit time rather than from a degraded result.
    """

    def __init__(
        self,
        runtime: Optional[AnytimeRuntime] = None,
        *,
        programs: Optional[dict] = None,
        capacity: int = 16,
        chunk: int = 8,
        clock=time.monotonic,
        backend_opts: Optional[dict] = None,
        admission: str = "edf",
        admission_k: float = 2.0,
    ):
        runtimes = dict(programs or {})
        if runtime is not None:
            runtimes.setdefault("default", runtime)
        if not runtimes:
            raise ValueError("AnytimeServer needs a runtime or a programs dict")
        if admission not in ("edf", "reject"):
            raise ValueError(
                f"admission must be 'edf' or 'reject', got {admission!r}"
            )
        if admission_k <= 0:
            raise ValueError(f"admission_k must be > 0, got {admission_k}")
        self.admission = admission
        self.admission_k = float(admission_k)
        self.clock = clock
        self.queue = AdmissionQueue()
        self.metrics = ServeMetrics()
        self.scheduler = Scheduler(
            runtimes, self.metrics, capacity=capacity, chunk=chunk,
            backend_opts=backend_opts,
        )
        self._pending: dict[int, Ticket] = {}   # awaiting delivery
        self._drain_buffer: Optional[list[Result]] = None

    # -- submission --------------------------------------------------------

    def submit(
        self,
        x,
        deadline_ms: float,
        policy: PolicyLike = "backward_squirrel",
        backend: Optional[str] = None,
        program: str = "default",
    ) -> Ticket:
        """Enqueue one request; returns a :class:`Ticket` immediately."""
        return self.submit_request(Request(
            x=x, deadline_ms=deadline_ms, policy=policy,
            backend=backend, program=program,
        ))

    def submit_request(self, request: Request) -> Ticket:
        if request.program not in self.scheduler.runtimes:
            raise ValueError(
                f"unknown program {request.program!r}; serving: "
                f"{', '.join(self.scheduler.runtimes)}"
            )
        if self.admission == "reject":
            # per-lane: flooding one (program, policy, backend) lane
            # must not shed load for an idle one
            backlog = self.scheduler.lane_backlog(request)
            bound = self.scheduler.capacity * self.admission_k
            if backlog >= bound:
                raise AdmissionRejected(
                    f"lane backlog {backlog} >= capacity "
                    f"{self.scheduler.capacity} x admission_k "
                    f"{self.admission_k}; shed load instead of starving "
                    "admitted requests to prior readouts"
                )
        now = self.clock()
        self.queue.submit(request, now)
        self.scheduler.note_queued(request)
        self.metrics.record_submit(now)
        ticket = Ticket(self, request)
        self._pending[request.request_id] = ticket
        return ticket

    # -- the driver loop ---------------------------------------------------

    @property
    def busy(self) -> bool:
        return bool(self.queue) or self.scheduler.busy

    def step(self) -> bool:
        """One dispatch → admit → harvest iteration; returns whether any
        work remains."""
        now = self.clock()
        deliveries = self.scheduler.step(self.queue, now)
        if deliveries:
            t_done = self.clock()
            for d in deliveries:
                self._finalize(d, t_done)
        return self.busy

    def drain(self, max_steps: Optional[int] = None) -> list[Result]:
        """Run the loop until idle; returns results delivered during the
        drain, in delivery order."""
        self._drain_buffer = buffer = []
        try:
            steps = 0
            while self.busy:
                self.step()
                steps += 1
                if max_steps is not None and steps >= max_steps:
                    break
        finally:
            self._drain_buffer = None
        return buffer

    def serve(
        self,
        xs: Sequence,
        deadline_ms: Union[float, Sequence[float]],
        policy: PolicyLike = "backward_squirrel",
        backend: Optional[str] = None,
        program: str = "default",
    ) -> list[Result]:
        """Batch convenience: submit every row, drain, return results in
        submission order."""
        if np.isscalar(deadline_ms):
            deadline_ms = [float(deadline_ms)] * len(xs)
        if len(deadline_ms) != len(xs):
            raise ValueError("deadline_ms must be scalar or match len(xs)")
        tickets = [
            self.submit(x, d, policy=policy, backend=backend, program=program)
            for x, d in zip(xs, deadline_ms)
        ]
        self.drain()
        return [t.result() for t in tickets]

    def result(self, request_id: int) -> Optional[Result]:
        """Result of a still-tracked request, or None while pending."""
        ticket = self._pending.get(request_id)
        return ticket._result if ticket is not None else None

    # -- internals ---------------------------------------------------------

    def _finalize(self, d: Delivery, now: float) -> None:
        req = d.request
        proba, total = d.proba, 0
        try:
            if proba is None:
                proba = self.scheduler.prior_proba(req)
            total = self.scheduler.total_steps(req)
        except Exception as e:  # noqa: BLE001 - unservable request
            proba = None
            if d.error is None:
                d = d._replace(error=str(e))
        res = Result(
            request_id=req.request_id,
            prediction=np.argmax(proba, axis=-1) if proba is not None else None,
            proba=proba,
            steps_completed=int(d.steps),
            total_steps=total,
            completed=bool(d.completed),
            deadline_hit=bool(
                d.error is None and (d.completed or d.steps > 0 or total == 0)
            ),
            latency_ms=(now - req.t_submit) * 1e3,
            error=d.error,
        )
        ticket = self._pending.pop(req.request_id, None)
        if ticket is not None:
            ticket._result = res
        if self._drain_buffer is not None:
            self._drain_buffer.append(res)
        self.metrics.record_delivery(res, now)
