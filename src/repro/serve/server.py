"""`AnytimeServer` — the deadline-aware serving driver loop.

One server multiplexes many concurrent anytime requests onto one device
runtime.  The loop is double-buffered: each iteration *dispatches* the
next fused step-plan segment for every lane (asynchronous on device),
*admits* queued requests into freed slots at the fresh segment boundary,
then *harvests* the previous boundary's readout on the host while the
device is still executing — so deadline checks and result delivery
overlap segment execution instead of serializing with it.  Every request
is answered with the last segment-boundary readout the host had seen at
its deadline: bit-identical to a solo ``jnp-ref`` session advanced the
same number of steps, never a torn mid-segment state.

Two ways to drive the loop:

*cooperative* (the PR-3 shape) — the caller pumps it::

    server = AnytimeServer(runtime, capacity=16)
    tickets = [server.submit(x, QoS(deadline_ms=2.0)) for x in rows]
    server.drain()
    preds = [t.result().prediction for t in tickets]

*threaded* — ``start()`` (or the context manager) hands the loop to a
background :class:`~repro.serve.driver.ServeDriver` thread, ``submit``
becomes a thread-safe fire-and-forget enqueue, and tickets behave like
``concurrent.futures`` futures (``add_done_callback``, blocking
``result(timeout=)``, :func:`~repro.serve.driver.as_completed`)::

    with AnytimeServer(runtime, capacity=16) as server:
        tickets = [server.submit(x, QoS(deadline_ms=2.0)) for x in rows]
        ...caller's own work overlaps device execution here...
        preds = [t.result(timeout=5.0).prediction for t in tickets]

Programs are pluggable: forests serve through masked slot batches
(:class:`~repro.schedule.runtime.SessionBatch`); any other
:class:`AnytimeProgram` (e.g. the LM
:class:`~repro.serving.anytime_depth.EnsembleProgram`) is driven through
per-request session lanes by the same loop, queue, and metrics.
"""
from __future__ import annotations

import threading
import time
from typing import Callable, Optional, Sequence, Union

import numpy as np

from repro.obs import NULL_TRACER
from repro.schedule.runtime import AnytimeRuntime
from repro.serve.admission import get_admission_policy
from repro.serve.cost import CostModel
from repro.serve.driver import DriverDead, ServeDriver
from repro.serve.metrics import ServeMetrics
from repro.serve.qos import QoS, resolve_qos
from repro.serve.queue import (
    AdmissionQueue,
    CertificationFailed,
    PolicyLike,
    Request,
    Result,
)
from repro.serve.scheduler import Delivery, Scheduler


def _invoke_callback(fn: Callable, ticket: "Ticket") -> None:
    """Run one done-callback; a raising callback must not kill the
    serving loop (``concurrent.futures`` semantics)."""
    try:
        fn(ticket)
    except Exception:  # noqa: BLE001 - callbacks fail alone
        import traceback

        traceback.print_exc()


class Ticket:
    """Handle to an in-flight request; resolves to a :class:`Result`.

    ``concurrent.futures``-style: ``done``, blocking ``result(timeout=)``
    and ``add_done_callback(fn)`` (fired exactly once with the ticket,
    immediately if already done, from the delivering thread otherwise).
    Delivery writes the result directly onto the ticket (the server
    tracks only PENDING tickets), so a long-lived server's memory holds
    results exactly as long as their callers hold the tickets — whether
    collected via ``result()`` or via ``drain()``'s return value.
    """

    __slots__ = ("_server", "request", "_result", "_cb_lock", "_callbacks")

    def __init__(self, server, request: Request):
        self._server = server    # unguarded: bound once, never reassigned
        self.request = request   # unguarded: bound once, never reassigned
        # per-ticket lock: in a multi-pool tier delivery may come from
        # ANY pool's driver thread, so the result/callback handoff
        # cannot lean on one server's lock
        self._cb_lock = threading.Lock()
        # write-once from _finalize under _cb_lock; racy reads see
        # either None or the final value (both correct future semantics)
        self._result: Optional[Result] = None  # unguarded: write-once latch
        self._callbacks: list[Callable] = []   # guarded-by: _cb_lock

    @property
    def request_id(self) -> int:
        return self.request.request_id

    @property
    def done(self) -> bool:
        return self._result is not None

    def add_done_callback(self, fn: Callable) -> None:
        """Call ``fn(ticket)`` exactly once when the result lands —
        immediately if it already has."""
        with self._cb_lock:
            if self._result is None:
                self._callbacks.append(fn)
                return
        _invoke_callback(fn, self)

    def result(self, timeout: Optional[float] = None) -> Result:
        """The request's result.

        With a background driver running this blocks on the server's
        condition variable (no spinning, no loop-driving) until delivery,
        ``timeout`` seconds elapse (:class:`TimeoutError`), or the driver
        thread dies (:class:`~repro.serve.driver.DriverDead`, carrying
        the thread's exception as ``__cause__``).  Without a driver it
        drives the cooperative loop itself, as before.
        """
        if self._result is not None:
            return self._result
        srv = self._server
        if srv.driver_running:
            with srv._cond:
                # a clean stop() is NOT a wake condition: its shutdown
                # flush answers every admitted request and notifies —
                # waking on "driver not running" would race that flush
                # into a spurious error.  Only delivery, driver death,
                # or the timeout end this wait.
                srv._cond.wait_for(
                    lambda: self._result is not None or srv._driver_failed,
                    timeout=timeout,
                )
            if self._result is None:
                srv._raise_if_driver_dead()
                raise TimeoutError(
                    f"request {self.request_id} undelivered after "
                    f"{timeout} s"
                )
            return self._result
        # cooperative mode: drive the loop until delivered
        while self._result is None:
            srv._raise_if_driver_dead()
            if not srv.step() and self._result is None:
                raise RuntimeError(
                    f"server idle but request {self.request_id} undelivered"
                )
        return self._result


class AnytimeServer:
    """Deadline-aware async batch server over anytime runtimes.

    ``runtime`` (or a ``programs`` name -> :class:`AnytimeRuntime` dict)
    names what is served; ``capacity`` is the slot count per
    ``(program, policy, backend)`` lane; ``chunk`` is the per-iteration
    step granularity of session lanes (slot lanes use plan segments);
    ``clock`` must be monotonic — injectable for deterministic tests.

    ``admission`` names a policy from the admission registry
    (:func:`repro.serve.admission.list_admissions`; an
    :class:`~repro.serve.admission.AdmissionPolicy` instance is also
    accepted):

    * ``"edf"`` (default) accepts everything and lets the EDF queue
      starve whoever it must — a starved request is delivered its prior
      (0-step) readout;
    * ``"reject"`` sheds load at submission instead, raising
      :class:`~repro.serve.queue.AdmissionRejected` whenever the
      submitted request's LANE already has ``capacity * admission_k``
      requests queued or waiting for a slot (per-lane: flooding one
      program/policy must not shed load for an idle one) — the admitted
      population keeps its anytime step quality and callers learn about
      the overload at submit time rather than from a degraded result;
    * ``"degrade"`` accepts everything but shrinks the effective
      per-request step budget once the lane backlog passes the same
      ``capacity * admission_k`` bound — slots stop at a shorter exact
      prefix boundary and recycle early, trading steps-at-deadline
      against hit-rate smoothly instead of starving or rejecting.
      Budgets are stamped from the instantaneous backlog at submit
      (priced against the calibrated cost model when one is configured,
      observed backlog depth otherwise), so they restore to the full
      plan as soon as pressure clears.  Delivered results carry
      ``degraded``/``budget_steps``; metrics grow ``degraded_requests``
      and budget-at-deadline percentiles.
    * ``"certified"`` upgrades EVERY submit to the guaranteed contract:
      admission prices the request's worst-case completion from the
      calibrated :class:`~repro.serve.cost.CostModel` (``cost_model=``,
      see ``tools.obs calibrate``) and admits only what provably fits
      its deadline — everything else raises
      :class:`~repro.serve.queue.CertificationFailed` at submit with
      the priced bound.  Under any policy, a ``QoS(guaranteed=True)``
      submit gets the same certification individually; admitted
      guaranteed requests outrank best-effort traffic in slot admission
      and are never degraded, and a guaranteed delivery that missed its
      deadline counts as ``guaranteed_misses`` in metrics (a hard
      bench/CI failure, not a percentile).

    Threaded serving: ``start()``/``stop()``/``close()`` (or the context
    manager) run the dispatch → admit → harvest loop on a background
    :class:`~repro.serve.driver.ServeDriver`; ``submit`` is then a
    thread-safe enqueue that wakes the driver.  ``stop()`` drains
    in-flight slots to their last segment-boundary readout, so every
    admitted request is answered on shutdown.
    """

    def __init__(
        self,
        runtime: Optional[AnytimeRuntime] = None,
        *,
        programs: Optional[dict] = None,
        capacity: int = 16,
        chunk: int = 8,
        clock=time.monotonic,
        backend_opts: Optional[dict] = None,
        admission: str = "edf",
        admission_k: float = 2.0,
        cost_model: Optional[CostModel] = None,
        tracer=None,
        queue_shards: int = 1,
        metrics: Optional[ServeMetrics] = None,
        ids=None,
        track_prefix: str = "",
    ):
        runtimes = dict(programs or {})
        if runtime is not None:
            runtimes.setdefault("default", runtime)
        if not runtimes:
            raise ValueError("AnytimeServer needs a runtime or a programs dict")
        # resolve eagerly: an unknown admission name must fail at
        # construction (ValueError), not at the first overloaded submit
        policy = get_admission_policy(admission)
        if admission_k <= 0:
            raise ValueError(f"admission_k must be > 0, got {admission_k}")
        self._admission_policy = policy     # unguarded: immutable config
        self.admission = policy.name        # unguarded: immutable config
        self.admission_k = float(admission_k)  # unguarded: immutable config
        # calibrated WCET pricing for certified/guaranteed admission and
        # predicted-pressure degrade budgets (None = best-effort only:
        # guaranteed submits raise CertificationFailed)
        self.cost_model = cost_model        # unguarded: immutable config
        self.clock = clock                  # unguarded: immutable callable
        # display/trace identity; a pooled tier names its pools "p0".."pN"
        self.name = track_prefix.rstrip(":") or "server"  # unguarded: immutable config
        # queue/metrics are internally locked (sharded heap locks /
        # one metrics mutex); the scheduler's MUTABLE state is guarded
        # by this server's lock via `# holds:`-marked methods
        # (see queue.py/scheduler.py).  A PooledAnytimeServer shares
        # ONE metrics object and ONE id counter across its pools.
        self.queue = AdmissionQueue(shards=queue_shards, ids=ids)  # unguarded: internally locked
        self.metrics = metrics if metrics is not None else ServeMetrics()  # unguarded: internally locked
        self.tracer = tracer if tracer is not None else NULL_TRACER  # unguarded: internally locked
        if tracer is not None:
            # span timestamps and request deadlines must share ONE
            # timeline — the tracer adopts the server's (injectable,
            # monotonic) clock
            tracer.clock = clock
        self.scheduler = Scheduler(         # unguarded: reference immutable
            runtimes, self.metrics, capacity=capacity, chunk=chunk,
            backend_opts=backend_opts, tracer=self.tracer,
            track_prefix=track_prefix,
        )
        self._pending: dict[int, Ticket] = {}   # guarded-by: _pending_lock
        self._drain_buffer: Optional[list[Result]] = None  # guarded-by: _lock
        # loop iterations served (threaded drain bound)
        self._step_seq = 0                  # guarded-by: _lock
        # threading: the server lock guards scheduler/drain state; the
        # condition (same lock) signals deliveries.  The pending map has
        # its OWN mutex so the submit fast path can register tickets
        # without the server lock (order: _lock -> _pending_lock, never
        # reversed).  _wake is a separate condition the driver parks on
        # when idle — submitters notify it without touching _lock.
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._pending_lock = threading.Lock()
        self._wake = threading.Condition()
        # multi-pool hooks: the facade rebinds these before serving
        # starts (single-threaded setup), then they are read-only
        self._ticket_owner = self  # unguarded: bound before serving starts
        # called by an idle driver before parking; returns True when it
        # pulled work in (work stealing) and the loop should re-check
        self.on_idle: Optional[Callable[[], bool]] = None  # unguarded: bound before serving starts
        # snapshot reads everywhere; writes serialized by the callers of
        # start()/stop() (stop() must NOT hold the lock while joining the
        # driver — the driver needs it to finish its iteration)
        self._driver: Optional[ServeDriver] = None  # unguarded: see above
        # write-once error latch (idempotent re-writes of the same value)
        self._driver_error: Optional[BaseException] = None  # unguarded: latch
        # write-once latch: set True under _lock in close(); the submit
        # fast path reads it racily as a hint — the authoritative
        # closed-vs-submit race is resolved by the queue's per-shard
        # closed flags (see AdmissionQueue.close)
        self._closed = False                # unguarded: write-once latch

    # -- driver lifecycle --------------------------------------------------

    @property
    def driver_running(self) -> bool:
        """Whether a live background driver currently owns the loop."""
        driver = self._driver
        return (
            driver is not None and driver.is_alive()
            and driver.exception is None
        )

    @property
    def _driver_failed(self) -> bool:
        driver = self._driver
        return self._driver_error is not None or (
            driver is not None and driver.exception is not None
        )

    def _raise_if_driver_dead(self) -> None:
        err = self._driver_error
        if err is None and self._driver is not None:
            err = self._driver.exception
        if err is not None:
            self._driver_error = err
            raise DriverDead(
                f"serving driver thread died: {err!r}") from err

    def start(self) -> "AnytimeServer":
        """Spawn the background driver (idempotent while it is alive)."""
        with self._lock:
            if self._closed:
                raise RuntimeError("AnytimeServer is closed")
            self._raise_if_driver_dead()
            if self._driver is None or not self._driver.is_alive():
                self._driver = ServeDriver(self)
                self._driver.start()
        return self

    def stop(self, timeout: Optional[float] = 10.0) -> list[Result]:
        """Stop the driver and answer EVERY still-admitted request at
        its last completed segment-boundary readout (queued requests get
        the prior).  Returns the results delivered by this final flush.
        Safe to call without a driver (pure flush) and more than once.
        """
        driver, self._driver = self._driver, None
        if driver is not None:
            driver.request_stop()
            driver.join(timeout)
            if driver.is_alive():  # pragma: no cover - defensive
                self._driver = driver
                raise RuntimeError("serving driver failed to stop in time")
            if driver.exception is not None:
                self._driver_error = driver.exception
        callbacks: list[tuple[Callable, Ticket]] = []
        flushed: list[Result] = []
        with self._cond:
            now = self.clock()
            if self.tracer.enabled:
                with self.tracer.span("serve.flush"):
                    deliveries = self.scheduler.flush(self.queue)
            else:
                deliveries = self.scheduler.flush(self.queue)
            for d in deliveries:
                res, cbs = self._finalize(d, now)
                flushed.append(res)
                callbacks.extend(cbs)
            self._cond.notify_all()
        for fn, ticket in callbacks:
            _invoke_callback(fn, ticket)
        self._notify_owner()
        return flushed

    def close(self) -> None:
        """``stop()`` + reject all future submissions.

        The closed flag is set FIRST (under the lock), then the queue
        shards are marked closed (under their locks), so no submit can
        slip in between the shutdown flush and the flags — everything
        enqueued before close() is answered by the flush, everything
        after raises (fast-path submits race against the shard flag,
        slow-path submits against ``_closed``)."""
        with self._lock:
            self._closed = True
        self.queue.close()
        self.stop()

    def __enter__(self) -> "AnytimeServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.close()

    # -- submission --------------------------------------------------------

    def submit(
        self,
        x,
        qos: Union[QoS, float, None] = None,
        deadline_ms: Optional[float] = None,
        policy: Optional[PolicyLike] = None,
        backend: Optional[str] = None,
        program: Optional[str] = None,
        budget_steps: Optional[int] = None,
        guaranteed: Optional[bool] = None,
    ) -> Ticket:
        """Enqueue one request; returns a :class:`Ticket` immediately.
        Thread-safe; wakes the background driver if one is running.

        ``qos`` is the request spec: ``submit(x, QoS(deadline_ms=2.0,
        backend="pallas", guaranteed=True))``.  The legacy kwarg surface
        (``submit(x, deadline_ms, policy=..., backend=...,
        program=...)``) still works through a deprecation shim building
        the identical spec."""
        spec = resolve_qos(qos, deadline_ms, policy, backend, program,
                           budget_steps, guaranteed)
        return self.submit_request(spec.request(x))

    def submit_request(self, request: Request) -> Ticket:
        if request.program not in self.scheduler.runtimes:
            raise ValueError(
                f"unknown program {request.program!r}; serving: "
                f"{', '.join(self.scheduler.runtimes)}"
            )
        # FAST PATH — the common serving case (no-op admission policy,
        # best-effort, untraced): no global-lock acquisition at all.
        # Reject/degrade read lane backlog, certification prices slot
        # occupancy, and traced submits emit correlated instants, so
        # those stay on the lock-serialized slow path.
        if (self._admission_policy.fast_path and not request.guaranteed
                and not self.tracer.enabled):
            return self._submit_fast(request)
        return self._submit_slow(request)

    def _submit_fast(self, request: Request) -> Ticket:
        """Lock-split submit: stamp (GIL-atomic id counter), register
        the ticket under the small ``_pending_lock``, push onto ONE
        queue-shard lock, bump internally-locked counters, notify the
        driver's wake condition.  The server lock — which the driver
        holds for a whole dispatch→admit→harvest iteration — is never
        touched, so submitters don't stall behind device work."""
        if self._closed:  # racy hint; the shard closed flag is authoritative
            raise RuntimeError(
                "submit on a closed AnytimeServer (close() was called)")
        self._raise_if_driver_dead()
        now = self.clock()
        self.queue.stamp(request, now)
        ticket = Ticket(self._ticket_owner, request)
        # register BEFORE the request becomes poppable: the driver can
        # never harvest a delivery whose ticket is missing
        with self._pending_lock:
            self._pending[request.request_id] = ticket
        try:
            self.queue.push(request, _count=True)
        except BaseException:
            with self._pending_lock:
                self._pending.pop(request.request_id, None)
            raise
        self.scheduler.note_queued(request)
        self.metrics.record_submit(now)
        with self._wake:
            self._wake.notify_all()
        return ticket

    def _submit_slow(self, request: Request) -> Ticket:
        with self._cond:
            if self._closed:
                raise RuntimeError(
                    "submit on a closed AnytimeServer (close() was called)")
            self._raise_if_driver_dead()
            tracer = self.tracer
            admission = self._admission_policy
            # a guaranteed submit is certified whatever the admission
            # policy; certify_all policies certify inside on_submit
            # (after stamping guaranteed=True on the request)
            if request.guaranteed and not admission.certify_all:
                self._certify(request)
            admission.on_submit(self, request)
            # the backlog the admission decision actually saw — before
            # this request itself is counted
            trace_backlog = (
                self.scheduler.lane_backlog(request) if tracer.enabled else 0)
            now = self.clock()
            self.queue.submit(request, now)
            self.scheduler.note_queued(request)
            self.metrics.record_submit(now)
            if tracer.enabled:
                tracer.request_submitted(
                    request.request_id, now, request.program)
                tracer.request_admission(
                    request.request_id, self.admission, trace_backlog,
                    request.budget_steps)
                tracer.instant(
                    "serve.submit", request_id=request.request_id,
                    program=request.program, deadline_ms=request.deadline_ms)
                tracer.instant(
                    "serve.admission", request_id=request.request_id,
                    decision=self.admission, backlog=trace_backlog,
                    budget=request.budget_steps)
            ticket = Ticket(self._ticket_owner, request)
            with self._pending_lock:
                self._pending[request.request_id] = ticket
        with self._wake:
            self._wake.notify_all()   # wake a parked driver
        return ticket

    def _certify(self, request: Request) -> None:  # holds: _lock
        """Price ``request``'s worst case against the calibrated cost
        model and stamp the certificate (``request.wcet_ms``), or raise
        :class:`~repro.serve.queue.CertificationFailed` with the priced
        bound.  Either way the decision lands in metrics and (traced) a
        ``serve.admission`` instant."""
        tracer = self.tracer
        try:
            if self.cost_model is None:
                raise CertificationFailed(
                    "guaranteed submit needs a calibrated cost model — "
                    "construct the server with cost_model=CostModel.load() "
                    "(see `python -m tools.obs calibrate`)",
                    deadline_ms=request.deadline_ms)
            request.wcet_ms = self.scheduler.certify(
                request, self.cost_model, self.clock())
        except CertificationFailed as e:
            self.metrics.record_certified(False)
            if tracer.enabled:
                # no request id yet (never enters the queue)
                tracer.instant(
                    "serve.admission", request_id=-1,
                    decision="certified-reject",
                    wcet_ms=e.wcet_ms if e.wcet_ms is not None else -1.0,
                    deadline_ms=request.deadline_ms,
                    program=request.program)
            raise
        self.metrics.record_certified(True)
        if tracer.enabled:
            tracer.instant(
                "serve.admission", request_id=-1, decision="certified",
                wcet_ms=request.wcet_ms, deadline_ms=request.deadline_ms,
                program=request.program)

    def _degrade_budget(self, request: Request) -> Optional[int]:  # holds: _lock
        """Effective step budget under ``admission="degrade"``: the full
        plan while the lane backlog is under ``capacity * admission_k``.
        Past the bound, with a calibrated cost model the budget is the
        step count that PREDICTED pressure leaves room for — the priced
        backlog wait subtracted from the deadline, divided by the lane's
        worst per-step rate — and without one it shrinks by observed
        depth as ``bound / backlog``.  Both keep a floor of one unit's
        steps so every admitted request can complete at least one whole
        tree, and both read the INSTANTANEOUS backlog, so budgets
        restore automatically when pressure clears."""
        backlog = self.scheduler.lane_backlog(request)
        bound = self.scheduler.capacity * self.admission_k
        if backlog < bound:
            return None
        total = self.scheduler.total_steps(request)
        program = self.scheduler.runtimes[request.program].program
        floor_steps = max(1, int(program.unit_steps))
        if self.cost_model is not None:
            budget = self.scheduler.predicted_budget(
                request, self.cost_model, backlog)
            if budget is not None:
                return max(floor_steps, min(budget, total))
        budget = int(total * bound / (backlog + 1))
        return max(floor_steps, min(budget, total))

    # -- the driver loop ---------------------------------------------------

    @property
    def has_queued(self) -> bool:
        """Lock-free: whether any shard holds undrained submissions —
        the parked driver's re-check before waiting (a push is visible
        in the shard mirrors before its wake notify fires)."""
        return bool(self.queue)

    @property
    def busy(self) -> bool:
        return bool(self.queue) or self.scheduler.busy

    def _notify_owner(self) -> None:
        """Wake waiters on the facade's condition after deliveries —
        Ticket.result()/as_completed block on the TICKET owner's _cond,
        which for a pooled tier is the facade, not this pool."""
        owner = self._ticket_owner
        if owner is not self:
            with owner._cond:
                owner._cond.notify_all()

    def step(self) -> bool:
        """One dispatch → admit → harvest iteration; returns whether any
        work remains.  Called by the background driver when one is
        running, by the caller otherwise (both paths lock-guarded, so a
        stray cooperative ``step`` alongside a driver is safe)."""
        callbacks: list[tuple[Callable, Ticket]] = []
        with self._cond:
            now = self.clock()
            self._step_seq += 1
            if self.tracer.enabled:
                with self.tracer.span("serve.step", seq=self._step_seq):
                    deliveries = self.scheduler.step(self.queue, now)
            else:
                deliveries = self.scheduler.step(self.queue, now)
            if deliveries:
                t_done = self.clock()
                for d in deliveries:
                    callbacks.extend(self._finalize(d, t_done)[1])
            still_busy = self.busy
            # notify EVERY iteration, not just delivering ones: the
            # busy -> idle transition can happen in a later, delivery-
            # less step (a lane's in-flight boundary draining), and a
            # threaded drain() parked on "not busy" must see it
            self._cond.notify_all()
        for fn, ticket in callbacks:
            _invoke_callback(fn, ticket)
        self._notify_owner()
        return still_busy

    def drain(self, max_steps: Optional[int] = None) -> list[Result]:
        """Run the loop until idle; returns results delivered during the
        drain, in delivery order.  With a background driver running this
        instead BLOCKS until the driver has gone idle (or has served
        ``max_steps`` more loop iterations — the same bound as the
        cooperative contract) and returns ``[]`` (results live on the
        tickets)."""
        if self.driver_running:
            with self._cond:
                start = self._step_seq
                self._cond.wait_for(
                    lambda: not self.busy or not self.driver_running
                    or (max_steps is not None
                        and self._step_seq - start >= max_steps))
            self._raise_if_driver_dead()
            return []
        with self._lock:
            self._drain_buffer = buffer = []
        try:
            steps = 0
            while True:
                # busy reads queue/scheduler state owned by the lock; a
                # driver started concurrently must not race this check
                with self._lock:
                    busy = self.busy
                if not busy:
                    break
                self.step()
                steps += 1
                if max_steps is not None and steps >= max_steps:
                    break
        finally:
            with self._lock:
                self._drain_buffer = None
        return buffer

    def serve(
        self,
        xs: Sequence,
        deadline_ms: Union[float, Sequence[float]],
        policy: PolicyLike = "backward_squirrel",
        backend: Optional[str] = None,
        program: str = "default",
    ) -> list[Result]:
        """Batch convenience: submit every row, drain, return results in
        submission order.  Works in both serving modes."""
        if np.isscalar(deadline_ms):
            deadline_ms = [float(deadline_ms)] * len(xs)
        if len(deadline_ms) != len(xs):
            raise ValueError("deadline_ms must be scalar or match len(xs)")
        tickets = [
            self.submit(x, QoS(deadline_ms=float(d), policy=policy,
                               backend=backend, program=program))
            for x, d in zip(xs, deadline_ms)
        ]
        self.drain()
        return [t.result() for t in tickets]

    def result(self, request_id: int) -> Optional[Result]:
        """Result of a still-tracked request, or None while pending."""
        with self._pending_lock:
            ticket = self._pending.get(request_id)
        return ticket._result if ticket is not None else None

    # -- internals ---------------------------------------------------------

    def _finalize(  # holds: _lock
        self, d: Delivery, now: float
    ) -> tuple[Result, list[tuple[Callable, Ticket]]]:
        """Turn a delivery into a :class:`Result` on its ticket (under
        the server lock) and return the done-callbacks to invoke once
        the lock is released."""
        req = d.request
        proba, total = d.proba, 0
        try:
            if proba is None:
                proba = self.scheduler.prior_proba(req)
            total = self.scheduler.total_steps(req)
        except Exception as e:  # noqa: BLE001 - unservable request
            proba = None
            if d.error is None:
                d = d._replace(error=str(e))
        res = Result(
            request_id=req.request_id,
            prediction=np.argmax(proba, axis=-1) if proba is not None else None,
            proba=proba,
            steps_completed=int(d.steps),
            total_steps=total,
            completed=bool(d.completed),
            deadline_hit=bool(
                d.error is None and (d.completed or d.steps > 0 or total == 0)
            ),
            latency_ms=(now - req.t_submit) * 1e3,
            error=d.error,
            degraded=d.budget is not None,
            budget_steps=int(d.budget) if d.budget is not None else total,
            guaranteed=req.guaranteed,
        )
        with self._pending_lock:
            ticket = self._pending.pop(req.request_id, None)
        callbacks: list[tuple[Callable, Ticket]] = []
        if ticket is not None:
            with ticket._cb_lock:
                ticket._result = res
                callbacks = [(fn, ticket) for fn in ticket._callbacks]
                ticket._callbacks = []
        if self._drain_buffer is not None:
            self._drain_buffer.append(res)
        self.metrics.record_delivery(res, now)
        if self.tracer.enabled:
            attr = self.tracer.request_delivered(
                req.request_id, now, res.steps_completed, total,
                res.deadline_hit)
            if attr is not None:
                self.metrics.record_attribution(attr)
                self.tracer.instant(
                    "serve.deliver", request_id=req.request_id,
                    latency_ms=attr.latency_ms, steps=res.steps_completed,
                    deadline_hit=res.deadline_hit, **attr.components())
        return res, callbacks
