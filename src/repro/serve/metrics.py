"""Serving observability: the four numbers that characterize an anytime
server under load.

* **deadline-hit-rate** — fraction of delivered requests that got a
  >= 1-step anytime readout by their deadline (or completed outright);
  a miss means the request starved to 0 steps and received the prior.
* **steps-at-deadline** — p50/p99/mean of ``steps_completed`` across
  delivered requests: how deep into the step order requests get before
  their deadlines fire (the anytime-quality proxy the paper's NMA
  metric integrates).
* **slot occupancy** — mean fraction of slot capacity doing useful work
  per dispatch (batching efficiency).
* **requests/sec** — delivered requests over the first-submit →
  last-delivery wall span.
"""
from __future__ import annotations

import collections
from typing import Optional

import numpy as np


class ServeMetrics:
    """Counters the :class:`~repro.serve.server.AnytimeServer` feeds.

    ``reset()`` zeroes everything — call it after a warmup pass so
    snapshots describe the measured stream, not the jit compiles.  The
    steps-at-deadline percentile population is a bounded window
    (``window`` most recent deliveries) so a long-lived server's
    memory stays flat; scalar counters run unbounded.
    """

    def __init__(self, window: int = 100_000):
        self._window = int(window)
        self.reset()

    def reset(self) -> None:
        self.submitted = 0
        self.delivered = 0
        self.completed = 0
        self.deadline_hits = 0
        self.dispatches = 0
        self.steps_at_deadline: collections.deque[int] = collections.deque(
            maxlen=self._window)
        self._occ_num = 0.0      # sum of active-slot counts over dispatches
        self._occ_den = 0.0      # sum of capacities over dispatches
        self._t_first_submit: Optional[float] = None
        self._t_last_delivery: Optional[float] = None

    def record_submit(self, now: float) -> None:
        self.submitted += 1
        if self._t_first_submit is None:
            self._t_first_submit = now

    def record_dispatch(self, n_active: int, capacity: int) -> None:
        self.dispatches += 1
        self._occ_num += n_active
        self._occ_den += capacity

    def record_delivery(self, result, now: float) -> None:
        self.delivered += 1
        self.completed += bool(result.completed)
        self.deadline_hits += bool(result.deadline_hit)
        self.steps_at_deadline.append(int(result.steps_completed))
        self._t_last_delivery = now

    @property
    def wall_s(self) -> float:
        if self._t_first_submit is None or self._t_last_delivery is None:
            return 0.0
        return max(0.0, self._t_last_delivery - self._t_first_submit)

    def snapshot(self) -> dict:
        steps = np.asarray(list(self.steps_at_deadline), dtype=np.int64)
        wall = self.wall_s
        return {
            "submitted": self.submitted,
            "delivered": self.delivered,
            "completed": self.completed,
            "deadline_hit_rate": (
                self.deadline_hits / self.delivered if self.delivered else 0.0
            ),
            "steps_at_deadline": {
                "p50": float(np.percentile(steps, 50)) if steps.size else 0.0,
                "p99": float(np.percentile(steps, 99)) if steps.size else 0.0,
                "mean": float(steps.mean()) if steps.size else 0.0,
            },
            "slot_occupancy": self._occ_num / self._occ_den if self._occ_den else 0.0,
            "dispatches": self.dispatches,
            "wall_s": wall,
            "requests_per_sec": self.delivered / wall if wall > 0 else 0.0,
        }
