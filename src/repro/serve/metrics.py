"""Serving observability: the four numbers that characterize an anytime
server under load.

* **deadline-hit-rate** — fraction of delivered requests that got a
  >= 1-step anytime readout by their deadline (or completed outright);
  a miss means the request starved to 0 steps and received the prior.
* **steps-at-deadline** — p50/p99/mean of ``steps_completed`` across
  delivered requests: how deep into the step order requests get before
  their deadlines fire (the anytime-quality proxy the paper's NMA
  metric integrates).
* **slot occupancy** — mean fraction of slot capacity doing useful work
  per dispatch (batching efficiency).
* **requests/sec** — delivered requests over the first-submit →
  last-delivery wall span.
"""
from __future__ import annotations

import collections
import threading
from typing import Optional

import numpy as np

from repro.obs.attribution import summarize as _summarize_attribution


def _pctls(values: collections.deque) -> dict:
    """p50/p99/mean of a delivery population — well-defined at EVERY
    window size: an empty window reports zeros (not NaN), a single
    delivery reports that delivery at both percentiles (nearest-rank
    semantics, no interpolation surprises)."""
    if not values:
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0}
    arr = np.asarray(list(values), dtype=np.float64)
    return {
        "p50": float(np.percentile(arr, 50)),
        "p99": float(np.percentile(arr, 99)),
        "mean": float(arr.mean()),
    }


class ServeMetrics:
    """Counters the :class:`~repro.serve.server.AnytimeServer` feeds.

    ``reset()`` zeroes everything — call it after a warmup pass so
    snapshots describe the measured stream, not the jit compiles.  The
    steps-at-deadline percentile population is a bounded window
    (``window`` most recent deliveries) so a long-lived server's
    memory stays flat; scalar counters run unbounded.
    """

    def __init__(self, window: int = 100_000):
        self._window = int(window)  # unguarded: immutable after __init__
        # internal lock: the threaded driver records deliveries while
        # monitoring threads call snapshot() — deque iteration during a
        # concurrent append raises, so all access serializes here (the
        # server lock does NOT cover callers of snapshot())
        self._lock = threading.Lock()
        self.reset()

    def reset(self) -> None:
        with self._lock:
            self._reset_locked()

    def _reset_locked(self) -> None:  # holds: _lock
        self.submitted = 0           # guarded-by: _lock
        self.delivered = 0           # guarded-by: _lock
        self.completed = 0           # guarded-by: _lock
        self.deadline_hits = 0       # guarded-by: _lock
        self.degraded_requests = 0   # guarded-by: _lock
        self.dispatches = 0          # guarded-by: _lock
        self.steps_at_deadline: collections.deque[int] = collections.deque(
            maxlen=self._window)     # guarded-by: _lock
        # effective step budgets of delivered requests (== total_steps
        # when not degraded): the admission="degrade" frontier metric
        self.budget_at_deadline: collections.deque[int] = collections.deque(
            maxlen=self._window)     # guarded-by: _lock
        # sums of active-slot counts / capacities over dispatches
        self._occ_num = 0.0          # guarded-by: _lock
        self._occ_den = 0.0          # guarded-by: _lock
        self._t_first_submit: Optional[float] = None    # guarded-by: _lock
        self._t_last_delivery: Optional[float] = None   # guarded-by: _lock
        # deadline-budget attributions from a traced server (window-
        # bounded like the percentile populations; empty when untraced)
        self.attributions: collections.deque = collections.deque(
            maxlen=self._window)     # guarded-by: _lock

    def record_submit(self, now: float) -> None:
        with self._lock:
            self.submitted += 1
            if self._t_first_submit is None:
                self._t_first_submit = now

    def record_dispatch(self, n_active: int, capacity: int) -> None:
        with self._lock:
            self.dispatches += 1
            self._occ_num += n_active
            self._occ_den += capacity

    def _record_delivery_locked(self, result, now: float) -> None:  # holds: _lock
        self.delivered += 1
        self.completed += bool(result.completed)
        self.deadline_hits += bool(result.deadline_hit)
        self.degraded_requests += bool(getattr(result, "degraded", False))
        self.steps_at_deadline.append(int(result.steps_completed))
        budget = getattr(result, "budget_steps", None)
        self.budget_at_deadline.append(
            int(budget) if budget is not None else int(result.total_steps))
        self._t_last_delivery = now

    def record_delivery(self, result, now: float) -> None:
        with self._lock:
            self._record_delivery_locked(result, now)

    def record_attribution(self, attribution) -> None:
        """One delivered request's deadline-budget attribution
        (:class:`repro.obs.attribution.Attribution`), fed by a traced
        server alongside :meth:`record_delivery`."""
        with self._lock:
            self.attributions.append(attribution)

    def _wall_s_locked(self) -> float:  # holds: _lock
        if self._t_first_submit is None or self._t_last_delivery is None:
            return 0.0
        return max(0.0, self._t_last_delivery - self._t_first_submit)

    @property
    def wall_s(self) -> float:
        # the lock is NOT reentrant: locked paths use _wall_s_locked()
        with self._lock:
            return self._wall_s_locked()

    def snapshot(self) -> dict:
        with self._lock:
            return self._snapshot_locked()

    def _snapshot_locked(self) -> dict:  # holds: _lock
        wall = self._wall_s_locked()
        return {
            "submitted": self.submitted,
            "delivered": self.delivered,
            "completed": self.completed,
            "degraded_requests": self.degraded_requests,
            "deadline_hit_rate": (
                self.deadline_hits / self.delivered if self.delivered else 0.0
            ),
            "steps_at_deadline": _pctls(self.steps_at_deadline),
            "budget_at_deadline": _pctls(self.budget_at_deadline),
            "slot_occupancy": self._occ_num / self._occ_den if self._occ_den else 0.0,
            "dispatches": self.dispatches,
            "wall_s": wall,
            "requests_per_sec": self.delivered / wall if wall > 0 else 0.0,
            "attribution": _summarize_attribution(self.attributions),
        }
