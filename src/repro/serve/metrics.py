"""Serving observability: the four numbers that characterize an anytime
server under load.

* **deadline-hit-rate** — fraction of delivered requests that got a
  >= 1-step anytime readout by their deadline (or completed outright);
  a miss means the request starved to 0 steps and received the prior.
* **steps-at-deadline** — p50/p99/mean of ``steps_completed`` across
  delivered requests: how deep into the step order requests get before
  their deadlines fire (the anytime-quality proxy the paper's NMA
  metric integrates).
* **latency** — p50/p99/mean submit→delivery latency in ms, the y-axis
  of the throughput-vs-p99 frontier the load generator sweeps.
* **slot occupancy** — mean fraction of slot capacity doing useful work
  per dispatch (batching efficiency).
* **requests/sec** — delivered requests over the first-submit →
  last-delivery wall span.

Percentile populations are **bounded reservoirs** (Vitter's Algorithm
R): below ``reservoir`` deliveries the sample IS the population and
percentiles are exact; beyond it each delivery keeps a uniform
probability of being represented and ``snapshot()`` stays O(reservoir)
— a load generator can push millions of requests through one
``ServeMetrics`` without snapshot cost or memory growing with traffic.
"""
from __future__ import annotations

import collections
import random
import threading
from typing import Optional

import numpy as np

from repro.obs.attribution import summarize as _summarize_attribution


class Reservoir:
    """Bounded uniform sample of an unbounded delivery stream
    (Algorithm R, seeded — identical streams give identical samples).

    Not internally locked: every instance lives inside a
    :class:`ServeMetrics` and is only touched under its lock.
    """

    __slots__ = ("capacity", "count", "_values", "_rng")

    def __init__(self, capacity: int, seed: int = 0):
        if capacity < 1:
            raise ValueError(f"reservoir capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)   # guarded-by: ServeMetrics._lock
        self.count = 0                  # guarded-by: ServeMetrics._lock
        self._values: list[float] = []  # guarded-by: ServeMetrics._lock
        self._rng = random.Random(seed)  # guarded-by: ServeMetrics._lock

    def add(self, value: float) -> None:  # holds: ServeMetrics._lock
        self.count += 1
        if len(self._values) < self.capacity:
            self._values.append(value)
            return
        j = self._rng.randrange(self.count)
        if j < self.capacity:
            self._values[j] = value

    @property
    def exact(self) -> bool:  # holds: ServeMetrics._lock
        """True while every observation is still in the sample — below
        capacity the reported percentiles are exact, not estimates."""
        return self.count <= self.capacity

    def values(self) -> list[float]:  # holds: ServeMetrics._lock
        return list(self._values)

    def __len__(self) -> int:  # holds: ServeMetrics._lock
        return len(self._values)


def _pctls(values: list) -> dict:
    """p50/p99/mean of a delivery population — well-defined at EVERY
    population size: an empty population reports zeros (not NaN), a
    single delivery reports that delivery at both percentiles."""
    if not values:
        return {"p50": 0.0, "p99": 0.0, "mean": 0.0}
    arr = np.asarray(values, dtype=np.float64)
    return {
        "p50": float(np.percentile(arr, 50)),
        "p99": float(np.percentile(arr, 99)),
        "mean": float(arr.mean()),
    }


class ServeMetrics:
    """Counters the :class:`~repro.serve.server.AnytimeServer` feeds.

    ``reset()`` zeroes everything — call it after a warmup pass so
    snapshots describe the measured stream, not the jit compiles.  The
    percentile populations (steps/budget-at-deadline, latency) are
    bounded :class:`Reservoir` samples of ``reservoir`` elements each;
    scalar counters run unbounded.  ``window`` bounds only the traced
    attribution deque (those carry per-request span structure and are
    summarized, not percentiled).

    One ``ServeMetrics`` may be shared by every pool of a
    :class:`~repro.serve.pool.PooledAnytimeServer` — its internal lock
    is the only synchronization recorders need.
    """

    def __init__(self, window: int = 100_000, reservoir: int = 4096):
        self._window = int(window)  # unguarded: immutable after __init__
        self._reservoir = int(reservoir)  # unguarded: immutable after __init__
        # internal lock: the threaded driver records deliveries while
        # monitoring threads call snapshot() — all access serializes
        # here (the server lock does NOT cover callers of snapshot())
        self._lock = threading.Lock()
        self.reset()

    def reset(self) -> None:
        with self._lock:
            self._reset_locked()

    def _reset_locked(self) -> None:  # holds: _lock
        self.submitted = 0           # guarded-by: _lock
        self.delivered = 0           # guarded-by: _lock
        self.completed = 0           # guarded-by: _lock
        self.deadline_hits = 0       # guarded-by: _lock
        self.degraded_requests = 0   # guarded-by: _lock
        self.dispatches = 0          # guarded-by: _lock
        self.steps_at_deadline = Reservoir(self._reservoir, seed=1)   # guarded-by: _lock
        # effective step budgets of delivered requests (== total_steps
        # when not degraded): the admission="degrade" frontier metric
        self.budget_at_deadline = Reservoir(self._reservoir, seed=2)  # guarded-by: _lock
        # submit→delivery latency in ms — the frontier's p99 axis
        self.latency_ms = Reservoir(self._reservoir, seed=3)          # guarded-by: _lock
        # sums of active-slot counts / capacities over dispatches
        self._occ_num = 0.0          # guarded-by: _lock
        self._occ_den = 0.0          # guarded-by: _lock
        self._t_first_submit: Optional[float] = None    # guarded-by: _lock
        self._t_last_delivery: Optional[float] = None   # guarded-by: _lock
        # deadline-budget attributions from a traced server (window-
        # bounded; empty when untraced)
        self.attributions: collections.deque = collections.deque(
            maxlen=self._window)     # guarded-by: _lock
        # deadline-aware router bookkeeping (multi-pool tier only)
        self.routed = 0              # guarded-by: _lock
        self.steals = 0              # guarded-by: _lock
        # WCET-certified admission (guaranteed=True / admission=
        # "certified"): submit-time certificate decisions, delivered
        # guaranteed requests, and the number that FAILED to complete
        # their full plan — the hard-failure count bench/CI gate at zero
        self.certified_admitted = 0   # guarded-by: _lock
        self.certified_rejected = 0   # guarded-by: _lock
        self.guaranteed_delivered = 0  # guarded-by: _lock
        self.guaranteed_misses = 0    # guarded-by: _lock

    def record_submit(self, now: float) -> None:
        with self._lock:
            self.submitted += 1
            if self._t_first_submit is None:
                self._t_first_submit = now

    def record_dispatch(self, n_active: int, capacity: int) -> None:
        with self._lock:
            self.dispatches += 1
            self._occ_num += n_active
            self._occ_den += capacity

    def record_route(self) -> None:
        """One request placed onto a pool by the multi-pool router."""
        with self._lock:
            self.routed += 1

    def record_steal(self) -> None:
        """One request migrated between pools by work stealing."""
        with self._lock:
            self.steals += 1

    def record_certified(self, admitted: bool) -> None:
        """One submit-time certification decision: the worst case was
        either proven to fit the deadline (admitted) or the request was
        rejected with the priced bound."""
        with self._lock:
            if admitted:
                self.certified_admitted += 1
            else:
                self.certified_rejected += 1

    def _record_delivery_locked(self, result, now: float) -> None:  # holds: _lock
        self.delivered += 1
        self.completed += bool(result.completed)
        self.deadline_hits += bool(result.deadline_hit)
        self.degraded_requests += bool(getattr(result, "degraded", False))
        self.steps_at_deadline.add(int(result.steps_completed))
        budget = getattr(result, "budget_steps", None)
        self.budget_at_deadline.add(
            int(budget) if budget is not None else int(result.total_steps))
        latency = getattr(result, "latency_ms", None)
        if latency is not None and np.isfinite(latency):
            self.latency_ms.add(float(latency))
        if getattr(result, "guaranteed", False):
            self.guaranteed_delivered += 1
            # a guaranteed delivery that did not run its FULL plan
            # broke its certificate — the hard-failure counter
            self.guaranteed_misses += not result.completed
        self._t_last_delivery = now

    def record_delivery(self, result, now: float) -> None:
        with self._lock:
            self._record_delivery_locked(result, now)

    def record_attribution(self, attribution) -> None:
        """One delivered request's deadline-budget attribution
        (:class:`repro.obs.attribution.Attribution`), fed by a traced
        server alongside :meth:`record_delivery`."""
        with self._lock:
            self.attributions.append(attribution)

    def _wall_s_locked(self) -> float:  # holds: _lock
        if self._t_first_submit is None or self._t_last_delivery is None:
            return 0.0
        return max(0.0, self._t_last_delivery - self._t_first_submit)

    @property
    def wall_s(self) -> float:
        # the lock is NOT reentrant: locked paths use _wall_s_locked()
        with self._lock:
            return self._wall_s_locked()

    def snapshot(self) -> dict:
        with self._lock:
            return self._snapshot_locked()

    def _snapshot_locked(self) -> dict:  # holds: _lock
        wall = self._wall_s_locked()
        return {
            "submitted": self.submitted,
            "delivered": self.delivered,
            "completed": self.completed,
            "degraded_requests": self.degraded_requests,
            "deadline_hit_rate": (
                self.deadline_hits / self.delivered if self.delivered else 0.0
            ),
            "steps_at_deadline": _pctls(self.steps_at_deadline.values()),
            "budget_at_deadline": _pctls(self.budget_at_deadline.values()),
            "latency_ms": _pctls(self.latency_ms.values()),
            "percentiles_exact": (
                self.steps_at_deadline.exact and self.latency_ms.exact
            ),
            "slot_occupancy": self._occ_num / self._occ_den if self._occ_den else 0.0,
            "dispatches": self.dispatches,
            "wall_s": wall,
            "requests_per_sec": self.delivered / wall if wall > 0 else 0.0,
            "routed": self.routed,
            "steals": self.steals,
            "certified_admitted": self.certified_admitted,
            "certified_rejected": self.certified_rejected,
            "guaranteed_delivered": self.guaranteed_delivered,
            "guaranteed_misses": self.guaranteed_misses,
            "attribution": _summarize_attribution(self.attributions),
        }
