"""Serving runtime: sharded prefill + decode step builders and a simple
batched generation loop.

``make_serve_fns`` produces the jit'd entry points the multi-pod dry-run
lowers for the prefill/decode input shapes, with cache shardings chosen
per shape: batch-parallel when global_batch covers the data axes,
context-parallel (cache length sharded over "data") for long_500k-style
single-sequence decode.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import SHAPES, InputShape
from repro.launch import mesh as mesh_lib
from repro.models import model as MD
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.models.params import shardings_for


def _axis_ok(mesh: Mesh, axis: str, dim: int) -> Optional[str]:
    return axis if axis in mesh.axis_names and dim % mesh.shape[axis] == 0 else None


def cache_shardings(cfg: ModelConfig, mesh: Mesh, batch: int, seq: int,
                    context_parallel: bool):
    """NamedSharding pytree matching init_cache's structure.

    attn k/v [n, B, Sc, KH, dh]: batch over ("pod","data") normally; for
    context-parallel decode the cache length Sc is sharded over "data"
    instead.  KV heads shard over "model" when divisible & enabled.
    ssm     [n, B, H, dh, N]: heads over "model".
    conv    [n, B, W-1, Ch]:  channels over "model"."""
    cache_like = jax.eval_shape(
        lambda: T.init_cache(cfg, batch, seq, dtype=jnp.bfloat16))
    baxes = mesh_lib.batch_axes(mesh)
    bshard = baxes if batch % int(np.prod([mesh.shape[a] for a in baxes])) == 0 else None
    kv_ax = _axis_ok(mesh, "model", cfg.num_kv_heads) if cfg.shard_kv_heads else None

    def mk(path, leaf):
        names = [str(getattr(p, "key", getattr(p, "name", p))) for p in path]
        spath = "/".join(names)
        if leaf.ndim == 0:
            return NamedSharding(mesh, P())
        if "ssm" in spath and leaf.ndim == 5:       # [n,B,H,dh,N]
            h_ax = _axis_ok(mesh, "model", leaf.shape[2])
            return NamedSharding(mesh, P(None, bshard, h_ax, None, None))
        if "ssm" in spath and leaf.ndim == 4:       # conv [n,B,W-1,Ch]
            c_ax = _axis_ok(mesh, "model", leaf.shape[3])
            return NamedSharding(mesh, P(None, bshard, None, c_ax))
        if leaf.ndim == 5:                          # attn kv [n,B,Sc,KH,dh]
            if context_parallel:
                seq_ax = _axis_ok(mesh, "data", leaf.shape[2])
                return NamedSharding(mesh, P(None, None, seq_ax, kv_ax, None))
            return NamedSharding(mesh, P(None, bshard, None, kv_ax, None))
        return NamedSharding(mesh, P())

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache_like)
    return jax.tree_util.tree_unflatten(treedef, [mk(p, leaf) for p, leaf in flat])


def abstract_cache(cfg: ModelConfig, batch: int, seq: int, shardings=None):
    """ShapeDtypeStruct cache for dry-run decode lowering."""
    cache_like = jax.eval_shape(
        lambda: T.init_cache(cfg, batch, seq, dtype=jnp.bfloat16))
    if shardings is None:
        return cache_like
    return jax.tree_util.tree_map(
        lambda leaf, s: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=s),
        cache_like, shardings)


def prefill_fn(cfg: ModelConfig, cache_len: Optional[int] = None):
    def prefill(params, batch):
        logits, aux, cache = T.forward(cfg, params, batch, return_cache=True,
                                       cache_len=cache_len)
        return logits[:, -1:], cache
    return prefill


def decode_fn(cfg: ModelConfig):
    def decode(params, cache, tokens):
        return T.decode_step(cfg, params, cache, tokens)
    return decode


def make_serve_fns(cfg: ModelConfig, mesh: Mesh, shape: InputShape | str,
                   donate_cache: bool = True):
    """(jitted_prefill, jitted_decode, shardings dict) for one input shape."""
    if isinstance(shape, str):
        shape = SHAPES[shape]
    B, S = shape.global_batch, shape.seq_len
    specs = MD.build_param_specs(cfg)
    p_sh = shardings_for(specs, mesh, cfg.sharding_profile, cfg.shard_kv_heads)
    baxes = mesh_lib.batch_axes(mesh)
    ctx_par = B < int(np.prod([mesh.shape[a] for a in baxes]))
    c_sh = cache_shardings(cfg, mesh, B, S, ctx_par)
    bp = P(baxes) if not ctx_par else P()
    tok_sh = NamedSharding(mesh, bp)

    in_b = {k: NamedSharding(mesh, P(*(tuple(bp) + (None,) * (len(v.shape) - 1))))
            for k, v in MD.input_specs(cfg, shape).items()}

    jit_prefill = jax.jit(
        prefill_fn(cfg, cache_len=S),
        in_shardings=(p_sh, in_b),
        out_shardings=(NamedSharding(mesh, bp), c_sh),
    )
    jit_decode = jax.jit(
        decode_fn(cfg),
        in_shardings=(p_sh, c_sh, tok_sh),
        out_shardings=(NamedSharding(mesh, bp), c_sh),
        donate_argnums=(1,) if donate_cache else (),
    )
    return jit_prefill, jit_decode, {"params": p_sh, "cache": c_sh, "batch": in_b}


def generate(cfg: ModelConfig, params, tokens: jax.Array, max_new_tokens: int,
             *, extra_inputs: Optional[dict[str, Any]] = None,
             temperature: float = 0.0, seed: int = 0) -> jax.Array:
    """Greedy/sampled generation on the host mesh (examples, tests)."""
    B, S = tokens.shape
    batch = {"tokens": tokens}
    if extra_inputs:
        batch.update(extra_inputs)
    logits, _, cache = T.forward(cfg, params, batch, return_cache=True,
                                 cache_len=S + max_new_tokens +
                                 (cfg.num_patches if cfg.family == "vlm" else 0))
    key = jax.random.PRNGKey(seed)
    out = [tokens]
    last = logits[:, -1]
    decode = jax.jit(functools.partial(T.decode_step, cfg))
    for _ in range(max_new_tokens):
        if temperature > 0:
            key, k = jax.random.split(key)
            nxt = jax.random.categorical(k, last / temperature, axis=-1)[:, None]
        else:
            nxt = jnp.argmax(last, axis=-1)[:, None]
        out.append(nxt.astype(tokens.dtype))
        logits_d, cache = decode(params, cache, nxt.astype(jnp.int32))
        last = logits_d[:, -1]
    return jnp.concatenate(out, axis=1)
