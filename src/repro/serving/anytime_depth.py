"""Anytime-depth transformer inference — the paper's scheduling idea
generalized beyond random forests.

Mapping (DESIGN.md §Arch-applicability):

  tree            <-> one model of an ensemble (or one layer-group)
  step in a tree  <-> executing one more layer of that model
  inner-node prediction vector <-> logit-lens early-exit readout
                                   (final norm + unembed on the
                                   intermediate residual)
  ordering set S_o <-> calibration batch of next-token examples

Under the same uniform-abort-time assumption, the Optimal / Squirrel
machinery from repro.core.orders applies VERBATIM to the resulting
quality table: a *step order* decides which ensemble member advances one
layer next, and at abort the current exit readouts of all members are
summed — "jumping like a squirrel" between models instead of trees.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.schedule import get_order_policy


@dataclasses.dataclass
class EnsembleMember:
    cfg: ModelConfig
    params: dict


def quality_table(members: Sequence[EnsembleMember], batch: dict,
                  labels: np.ndarray, top_v: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Build the [B, U, L+1, V] per-state contribution table on a
    calibration batch — the transformer analogue of engine.path_probs.

    All members must share L (pad shorter members by repeating their
    final readout, i.e. extra steps are no-ops, like leaf self-loops).
    """
    tables = []
    Lmax = max(m.cfg.num_layers for m in members)
    for m in members:
        el = T.exit_logits(m.cfg, m.params, batch)            # [L+1, B, V]
        el = jax.nn.log_softmax(el.astype(jnp.float32), axis=-1)
        if m.cfg.num_layers < Lmax:                            # leaf self-loop padding
            pad = jnp.repeat(el[-1:], Lmax - m.cfg.num_layers, axis=0)
            el = jnp.concatenate([el, pad], axis=0)
        tables.append(np.asarray(jnp.transpose(el, (1, 0, 2))))  # [B, L+1, V]
    pp = np.stack(tables, axis=1)                               # [B, U, L+1, V]
    if top_v:
        # restrict to the most frequent label classes to bound the table
        keep = np.argsort(-np.bincount(labels, minlength=pp.shape[-1]))[:top_v]
        remap = {int(c): i for i, c in enumerate(keep)}
        mask = np.isin(labels, keep)
        pp = pp[mask][..., keep]
        labels = np.asarray([remap[int(lab)] for lab in labels[mask]])
    return pp, labels


def generate_depth_order(members: Sequence[EnsembleMember], calib_batch: dict,
                         labels: np.ndarray, name: str = "backward_squirrel",
                         top_v: int = 64) -> np.ndarray:
    """Step order over (member, layer) units via the policy registry.

    Any name in :func:`repro.schedule.list_orders` works — not just the
    five the old string dispatch special-cased."""
    pp, y = quality_table(members, calib_batch, labels, top_v=top_v)
    return get_order_policy(name).generate(pp, y)


@dataclasses.dataclass
class EnsembleProgram:
    """Adapter making an LM ensemble an :class:`AnytimeProgram`, so
    :class:`repro.schedule.AnytimeRuntime` can schedule and serve it with
    the exact machinery used for forests (order cache, deadline-aware
    sessions)."""

    members: Sequence[EnsembleMember]
    calib_batch: dict
    calib_labels: np.ndarray
    top_v: int = 64
    _quality: Optional[tuple] = dataclasses.field(default=None, repr=False)

    @property
    def n_units(self) -> int:
        return len(self.members)

    @property
    def unit_steps(self) -> int:
        return max(m.cfg.num_layers for m in self.members)

    def quality_table(self) -> tuple[np.ndarray, np.ndarray]:
        if self._quality is None:
            self._quality = quality_table(
                self.members, self.calib_batch, self.calib_labels, top_v=self.top_v
            )
        return self._quality

    def make_session(
        self, order: np.ndarray, inputs: dict, backend: Optional[str] = None,
        **backend_opts,
    ) -> "AnytimeEnsembleSession":
        # Layer execution is the ensemble's own jitted forward; the
        # forest kernel/mesh backends don't apply at this granularity.
        if backend not in (None, "jnp-ref"):
            raise ValueError(
                f"EnsembleProgram only supports the default 'jnp-ref' "
                f"execution backend, got {backend!r}"
            )
        if backend_opts:
            raise TypeError(
                f"EnsembleProgram sessions take no backend options, got "
                f"{sorted(backend_opts)}"
            )
        return AnytimeEnsembleSession(self.members, order, inputs)


class AnytimeEnsembleSession:
    """Interruptible ensemble inference following a generated step order.

    Each ``advance(k)`` runs k more layer-steps; ``predict()`` sums the
    current exit readouts — a valid prediction after ANY prefix, exactly
    like the forest index-array engine of Sec. V.
    """

    def __init__(self, members: Sequence[EnsembleMember], order: np.ndarray,
                 batch: dict):
        self.members = list(members)
        self.order = np.asarray(order)
        self.batch = batch
        x0 = []
        self._readout = []
        for m in self.members:
            x, positions = T._embed_inputs(m.cfg, m.params, batch)
            x0.append(x)
            self._readout.append(self._make_readout(m))
        self.hidden = x0                       # residual stream per member
        self.depth = [0] * len(self.members)
        self.positions = [
            T._embed_inputs(m.cfg, m.params, batch)[1] for m in self.members
        ]
        self.pos = 0
        # Exit readouts keyed on effective layer depth: a member whose
        # depth didn't change between predict() calls reuses its cached
        # log-softmax readout instead of re-running norm+unembed.
        self._exit_cache: list[Optional[tuple[int, jax.Array]]] = (
            [None] * len(self.members)
        )
        self.readout_computes = 0  # cache-miss counter (observability)

    @staticmethod
    def _make_readout(m: EnsembleMember):
        def ro(x):
            h = T.L.apply_norm(m.cfg, x[:, -1:], m.params.get("final_norm"))
            return T.L.final_logits(m.cfg, m.params["embed"],
                                    m.params.get("lm_head"), h)[:, 0]
        return jax.jit(ro)

    def _layer(self, u: int, layer: int):
        m = self.members[u]
        lp = jax.tree_util.tree_map(lambda a: a[layer], m.params["layers"])
        if m.cfg.family == "ssm":
            self.hidden[u] = T._mamba_block(m.cfg, lp, self.hidden[u])
        elif m.cfg.family == "moe":
            self.hidden[u], _, _ = T._moe_block(m.cfg, lp, self.hidden[u],
                                                self.positions[u])
        else:
            self.hidden[u], _ = T._dense_block(m.cfg, lp, self.hidden[u],
                                               self.positions[u],
                                               m.cfg.sliding_window)

    @property
    def total_steps(self) -> int:
        return len(self.order)

    def advance(self, k: int) -> int:
        k = min(k, self.total_steps - self.pos)
        for _ in range(k):
            u = int(self.order[self.pos])
            if self.depth[u] < self.members[u].cfg.num_layers:
                self._layer(u, self.depth[u])   # no-op past final layer
            self.depth[u] += 1
            self.pos += 1
        return k

    def _exit_logprobs(self, u: int) -> jax.Array:
        """Member u's exit readout, cached on its effective layer depth
        (``min(depth, num_layers)`` — no-op steps past the final layer
        leave the residual, and therefore the readout, unchanged)."""
        eff = min(self.depth[u], self.members[u].cfg.num_layers)
        cached = self._exit_cache[u]
        if cached is None or cached[0] != eff:
            lp = jax.nn.log_softmax(
                self._readout[u](self.hidden[u]).astype(jnp.float32), axis=-1)
            cached = (eff, lp)
            self._exit_cache[u] = cached
            self.readout_computes += 1
        return cached[1]

    def predict_logprobs(self) -> np.ndarray:
        acc = None
        for u in range(len(self.members)):
            lp = self._exit_logprobs(u)
            acc = lp if acc is None else acc + lp
        return np.asarray(acc)

    def predict(self) -> np.ndarray:
        return self.predict_logprobs().argmax(axis=-1)


def accuracy_curve(members, order, batch, labels) -> np.ndarray:
    """Next-token accuracy after every step prefix (evaluation helper)."""
    sess = AnytimeEnsembleSession(members, order, batch)
    curve = [float(np.mean(sess.predict() == labels))]
    for _ in range(sess.total_steps):
        sess.advance(1)
        curve.append(float(np.mean(sess.predict() == labels)))
    return np.asarray(curve)
