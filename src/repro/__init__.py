"""Reproduction of "Jump Like A Squirrel: Optimized Execution Step Order
for Anytime Random Forest Inference", grown toward a production-scale
JAX/Pallas anytime-inference system.

One-stop public surface — everything examples need imports from here:

    from repro import AnytimeRuntime, OrderPolicy, list_orders
"""
# Note: the device-level evaluate_orders(device, X, y, orders_by_name)
# helper stays in repro.schedule — batched evaluation at this level is
# AnytimeRuntime.evaluate_orders(X, y, names).
from repro.schedule import (
    AnytimeRuntime,
    ExecutorCore,
    ForestProgram,
    OrderPolicy,
    Session,
    get_order_policy,
    list_backends,
    list_orders,
    register_backend,
    register_order,
)
from repro.serve import (
    AdmissionRejected,
    AnytimeServer,
    Request,
    Result,
    as_completed,
)

__all__ = [
    "AdmissionRejected",
    "AnytimeRuntime",
    "AnytimeServer",
    "as_completed",
    "ExecutorCore",
    "ForestProgram",
    "OrderPolicy",
    "Request",
    "Result",
    "Session",
    "get_order_policy",
    "list_backends",
    "list_orders",
    "register_backend",
    "register_order",
]
