"""repro.obs — cross-layer tracing with deadline-budget attribution.

A span-based, thread-safe tracing subsystem for the serving stack:
every request's path (submit → admission → queue → slot → segment
dispatches → harvest → delivery) records as spans that roll up into a
per-request deadline-budget attribution (``queue_ms / dispatch_ms /
compile_ms / harvest_ms / slack_ms``), plus per-(backend, impl,
pow2-length) segment-latency histograms and optional per-step margin
traces.  Exports Chrome trace-event JSON (Perfetto-loadable); analyzed
and gated by ``python -m tools.obs``.

This package is import-light by design — no jax, no numpy — so the
kernel dispatch layer (``repro.kernels.ops``) can call
:func:`annotate`/:func:`tracing_active` without import-order or
device-init concerns.
"""
from repro.obs.attribution import Attribution, summarize
from repro.obs.export import (
    export_chrome_trace,
    segment_histograms,
    worst_case_table,
    write_chrome_trace,
)
from repro.obs.names import ATTRIBUTION_FIELDS, CATEGORIES, SPAN_NAMES
from repro.obs.tracer import (
    NULL_TRACER,
    Span,
    Tracer,
    annotate,
    current_span,
    tracing_active,
)

__all__ = [
    "Attribution",
    "summarize",
    "export_chrome_trace",
    "segment_histograms",
    "worst_case_table",
    "write_chrome_trace",
    "ATTRIBUTION_FIELDS",
    "CATEGORIES",
    "SPAN_NAMES",
    "NULL_TRACER",
    "Span",
    "Tracer",
    "annotate",
    "current_span",
    "tracing_active",
]
