"""Pinned span-name registry — the trace-consumer contract.

Every span/instant/counter the serving stack records MUST use a name
from this table (enforced statically by ``tools/analyze``'s ``obs``
checker, and at runtime by :class:`~repro.obs.tracer.Tracer` in strict
mode).  Trace consumers — the committed ``reports/obs/
serve_trace_schema.json``, ``tools/obs`` report aggregation, Perfetto
queries — key on these strings, so renaming one is a breaking change
and must update the schema and this table together.
"""
from __future__ import annotations

__all__ = ["SPAN_NAMES", "CATEGORIES", "ATTRIBUTION_FIELDS"]

#: name -> one-line meaning.  ``serve.*`` events carry the request path;
#: ``kernel.*`` events carry execution-layer detail annotated onto the
#: enclosing dispatch span.
SPAN_NAMES: dict[str, str] = {
    # instants (ph = "i")
    "serve.submit": "request entered the admission queue",
    "serve.admission": "admission decision at submit "
                       "(edf/reject/degrade, with backlog and stamped budget)",
    "serve.slot_admit": "request placed into a lane slot "
                        "(joins the batch at the next segment boundary)",
    "serve.deliver": "result finalized onto its ticket "
                     "(args carry the deadline-budget attribution)",
    "serve.route": "router placed a request on a pool "
                   "(at submit, or after a steal re-homed it)",
    # spans (ph = "X")
    "serve.step": "one dispatch -> admit -> harvest loop iteration",
    "serve.dispatch": "one lane's fused masked segment dispatch "
                      "(asynchronous device enqueue; args: backend, impl, "
                      "length, compile flag)",
    "serve.harvest": "one lane's boundary materialization (device sync) "
                     "+ slot retirement",
    "serve.flush": "shutdown flush answering every admitted request",
    "serve.steal": "idle pool pulling one request from a loaded sibling "
                   "at a segment-boundary-aligned point "
                   "(args: victim, thief, moved)",
    # counters (ph = "C")
    "serve.margin": "per-slot readout margin (top1 - top2 probability) at "
                    "a segment boundary — the online NMA trajectory",
}

#: trace-event categories (the Chrome ``cat`` field)
CATEGORIES: tuple[str, ...] = ("serve", "kernel", "quality")

#: component keys of one deadline-budget attribution record, in report
#: order.  They partition a request's end-to-end latency:
#: ``queue + dispatch + compile + harvest + slack == latency`` (within
#: clock tolerance; ``tools/obs --check`` gates it).
ATTRIBUTION_FIELDS: tuple[str, ...] = (
    "queue_ms", "dispatch_ms", "compile_ms", "harvest_ms", "slack_ms",
)
