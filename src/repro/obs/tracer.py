"""Span-based, thread-safe trace recorder for the serving stack.

One :class:`Tracer` instruments one :class:`~repro.serve.server.
AnytimeServer` request path end to end: submit → admission decision →
queue wait → slot admission → every segment dispatch → harvest →
delivery.  Three event shapes:

* **spans** — ``with tracer.span("serve.dispatch", ...) as sp`` records
  a complete ``[t0, t1]`` interval (Chrome ``ph="X"``).  Spans nest per
  thread; :func:`annotate` lets lower layers (the execution backends,
  ``repro.kernels.ops``) attach args to the innermost active span of
  the current thread without holding a tracer reference — this is how a
  dispatch span learns its kernel impl name and whether it minted a jit
  trace (a compile), without any plumbing through jit boundaries.
* **instants** — point events (``ph="i"``): submissions, admission
  decisions, deliveries.
* **counters** — time series (``ph="C"``): the per-slot readout margin
  after each segment boundary (the online NMA trajectory).

The recorder is a bounded ring buffer (``capacity`` most recent events;
``dropped`` counts evictions) so a long-lived traced server's memory
stays flat.  Thread safety: the ring and the attribution table are
guarded by one internal lock; the active-span stack is thread-local, so
concurrent driver/submitter threads never tear each other's spans.

**Disabled fast path.**  Every instrumentation site in the serving loop
is guarded by a single ``tracer.enabled`` attribute read; a disabled
tracer (or the shared :data:`NULL_TRACER` default) therefore costs one
boolean check per site — no clock reads, no allocation, no locking —
and :func:`tracing_active` lets hot leaf code (kernel dispatch) skip
its annotation entirely.  ``bench_serve.py`` gates that this overhead
stays within noise of the untraced baseline.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Optional

from repro.obs.attribution import Attribution
from repro.obs.names import SPAN_NAMES

__all__ = [
    "Span",
    "Tracer",
    "NULL_TRACER",
    "annotate",
    "current_span",
    "tracing_active",
]

# -- module-global fast-path state ------------------------------------------

#: number of *enabled* tracers alive: the one-word flag kernel-dispatch
#: annotation checks before doing ANY work.  Guarded by _ACTIVE_LOCK for
#: the (rare) enable/disable transitions; the hot read is unlocked — a
#: stale read costs at most one spurious (harmless) annotate attempt.
_ACTIVE_COUNT = 0
_ACTIVE_LOCK = threading.Lock()

_TLS = threading.local()  # .stack: list[Span] — per-thread active spans


def tracing_active() -> bool:
    """Whether any enabled tracer exists — the zero-cost guard for leaf
    instrumentation (one global read)."""
    return _ACTIVE_COUNT > 0


def _span_stack() -> list:
    stack = getattr(_TLS, "stack", None)
    if stack is None:
        stack = _TLS.stack = []
    return stack


def current_span() -> Optional["Span"]:
    """The current thread's innermost active span, or None."""
    stack = getattr(_TLS, "stack", None)
    return stack[-1] if stack else None


def annotate(**args) -> None:
    """Attach ``args`` to the current thread's innermost active span.

    The hook lower layers use to report execution detail upward — e.g.
    ``repro.kernels.ops`` reporting the tuned impl name, and the jit
    boundary reporting a compile — with no tracer reference and no-op
    cost when nothing is being traced.
    """
    stack = getattr(_TLS, "stack", None)
    if stack:
        stack[-1].args.update(args)


class Span:
    """One in-flight or completed trace interval."""

    __slots__ = ("name", "cat", "ph", "t0", "t1", "thread", "track", "args")

    def __init__(self, name: str, cat: str, ph: str, t0: float,
                 thread: int, track: Optional[str], args: dict):
        self.name = name
        self.cat = cat
        self.ph = ph          # "X" span | "i" instant | "C" counter
        self.t0 = t0
        self.t1: Optional[float] = None  # None while still open
        self.thread = thread
        self.track = track    # display track (lane key); None = thread
        self.args = args

    @property
    def dur_s(self) -> float:
        return (self.t1 - self.t0) if self.t1 is not None else 0.0

    def to_dict(self) -> dict:
        return {
            "name": self.name, "cat": self.cat, "ph": self.ph,
            "t0": self.t0, "t1": self.t1, "thread": self.thread,
            "track": self.track, "args": dict(self.args),
        }


class _SpanCtx:
    """Context manager recording one span (the ONLY way to open one —
    ``tools/analyze``'s obs checker rejects bare ``tracer.span(...)``
    calls, so begin/end can never unbalance)."""

    __slots__ = ("_tracer", "_span")

    def __init__(self, tracer: "Tracer", span: Span):
        self._tracer = tracer
        self._span = span

    def __enter__(self) -> Span:
        _span_stack().append(self._span)
        return self._span

    def __exit__(self, *exc) -> None:
        span = self._span
        stack = _span_stack()
        # pop THIS span even if an exception unwound nested ones
        while stack and stack[-1] is not span:
            stack.pop()
        if stack:
            stack.pop()
        span.t1 = self._tracer.clock()
        self._tracer._append(span)


class _NullCtx:
    """Reusable no-op span context (the disabled fast path)."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc) -> None:
        return None


_NULL_CTX = _NullCtx()


class _ReqAcc:
    """Per-request attribution accumulator (internal)."""

    __slots__ = ("t_submit", "t_admit", "program", "lane", "backend",
                 "dispatch_s", "compile_s", "harvest_s", "decision",
                 "backlog", "budget")

    def __init__(self, t_submit: float, program: str):
        self.t_submit = t_submit
        self.t_admit: Optional[float] = None
        self.program = program
        self.lane: Optional[str] = None
        self.backend: Optional[str] = None
        self.dispatch_s = 0.0
        self.compile_s = 0.0
        self.harvest_s = 0.0
        self.decision: Optional[str] = None
        self.backlog = 0
        self.budget: Optional[int] = None


class Tracer:
    """Bounded, thread-safe recorder of serving trace events plus the
    per-request deadline-budget attribution table.

    ``capacity`` bounds the event ring (oldest events evict; ``dropped``
    counts them) and the delivered-attribution window.  ``margins=True``
    additionally records the per-slot readout margin after each
    harvested segment boundary — the online confidence-vs-steps curve —
    at zero extra kernel launches (the serving loop already materializes
    boundary readouts; the margin is computed from that host array).
    ``clock`` must match the owning server's monotonic clock so span
    timestamps and request deadlines share one timeline.

    ``strict`` (default True) rejects event names missing from the
    pinned :data:`~repro.obs.names.SPAN_NAMES` registry — trace
    consumers must never silently break.
    """

    def __init__(self, capacity: int = 65536, clock=time.monotonic,
                 margins: bool = False, enabled: bool = True,
                 strict: bool = True):
        self.clock = clock
        self.margins = bool(margins)
        self.strict = bool(strict)
        self._lock = threading.Lock()
        self._events: collections.deque[Span] = collections.deque(
            maxlen=int(capacity))
        self._appended = 0
        self._requests: dict[int, _ReqAcc] = {}
        self.attributions: collections.deque[Attribution] = collections.deque(
            maxlen=int(capacity))
        self._enabled = False
        if enabled:
            self.enable()

    # -- enable/disable (the fast-path switch) ---------------------------

    @property
    def enabled(self) -> bool:
        return self._enabled

    def enable(self) -> None:
        global _ACTIVE_COUNT
        with _ACTIVE_LOCK:
            if not self._enabled:
                self._enabled = True
                _ACTIVE_COUNT += 1

    def disable(self) -> None:
        global _ACTIVE_COUNT
        with _ACTIVE_LOCK:
            if self._enabled:
                self._enabled = False
                _ACTIVE_COUNT -= 1

    # -- raw event recording --------------------------------------------

    def _check_name(self, name: str) -> None:
        if self.strict and name not in SPAN_NAMES:
            raise ValueError(
                f"unregistered trace event name {name!r}; add it to "
                "repro.obs.names.SPAN_NAMES (and the committed trace "
                "schema) first"
            )

    def _append(self, span: Span) -> None:
        with self._lock:
            self._events.append(span)
            self._appended += 1

    def span(self, name: str, cat: str = "serve",
             track: Optional[str] = None, **args):
        """Open one timed span as a context manager::

            with tracer.span("serve.dispatch", track=lane, backend=b) as sp:
                ...                     # sp.args may be annotated upward
            wall_s = sp.dur_s           # closed span stays readable

        Must be used in a ``with`` statement (statically enforced)."""
        if not self._enabled:
            return _NULL_CTX
        self._check_name(name)
        return _SpanCtx(self, Span(
            name, cat, "X", self.clock(), threading.get_ident(), track, args,
        ))

    def instant(self, name: str, cat: str = "serve",
                track: Optional[str] = None, **args) -> None:
        if not self._enabled:
            return
        self._check_name(name)
        now = self.clock()
        span = Span(name, cat, "i", now, threading.get_ident(), track, args)
        span.t1 = now
        self._append(span)

    def counter(self, name: str, value: float, cat: str = "quality",
                track: Optional[str] = None, **args) -> None:
        if not self._enabled:
            return
        self._check_name(name)
        now = self.clock()
        args = dict(args)
        args["value"] = float(value)
        span = Span(name, cat, "C", now, threading.get_ident(), track, args)
        span.t1 = now
        self._append(span)

    # -- introspection ---------------------------------------------------

    def events(self) -> list[Span]:
        """Snapshot of the ring (oldest first); safe under concurrent
        recording."""
        with self._lock:
            return list(self._events)

    @property
    def dropped(self) -> int:
        """Events evicted by the ring bound so far."""
        with self._lock:
            return self._appended - len(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._appended = 0
            self._requests.clear()
            self.attributions.clear()

    # -- per-request deadline-budget accounting --------------------------
    #
    # The serving loop calls these at the request lifecycle points; the
    # tracer turns them into one Attribution per delivered request.
    # All bookkeeping is under the tracer lock — the threaded driver
    # and concurrent submitters may interleave freely.

    def request_submitted(self, request_id: int, t_submit: float,
                          program: str) -> None:
        with self._lock:
            self._requests[request_id] = _ReqAcc(t_submit, program)

    def request_admission(self, request_id: int, decision: str,
                          backlog: int, budget: Optional[int]) -> None:
        with self._lock:
            acc = self._requests.get(request_id)
            if acc is not None:
                acc.decision = decision
                acc.backlog = int(backlog)
                acc.budget = budget

    def request_slot(self, request_id: int, t_admit: float, lane: str,
                     backend: str) -> None:
        with self._lock:
            acc = self._requests.get(request_id)
            if acc is not None and acc.t_admit is None:
                acc.t_admit = t_admit
                acc.lane = lane
                acc.backend = backend

    def account(self, request_ids, field: str, dt_s: float) -> None:
        """Add ``dt_s`` seconds of ``field`` ("dispatch" | "compile" |
        "harvest") to every listed in-flight request — how a lane's
        batched span wall time becomes per-request attribution (from
        each request's own timeline the whole span elapsed while it was
        in flight, so the full duration attributes to each)."""
        attr = field + "_s"
        with self._lock:
            for rid in request_ids:
                acc = self._requests.get(rid)
                if acc is not None:
                    setattr(acc, attr, getattr(acc, attr) + dt_s)

    def request_delivered(self, request_id: int, t_deliver: float,
                          steps: int, total_steps: int,
                          deadline_hit: bool) -> Optional[Attribution]:
        """Finalize the request's attribution record; returns it (and
        retains it in the bounded ``attributions`` window)."""
        with self._lock:
            acc = self._requests.pop(request_id, None)
            if acc is None:
                return None
            t_admit = acc.t_admit
            latency_s = max(0.0, t_deliver - acc.t_submit)
            if t_admit is None:
                # never reached a slot: the whole latency was queue wait
                queue_s, inflight_s = latency_s, 0.0
            else:
                queue_s = max(0.0, t_admit - acc.t_submit)
                inflight_s = max(0.0, t_deliver - t_admit)
            accounted = acc.dispatch_s + acc.compile_s + acc.harvest_s
            attr = Attribution(
                request_id=request_id,
                program=acc.program,
                lane=acc.lane,
                backend=acc.backend,
                decision=acc.decision,
                backlog=acc.backlog,
                budget_steps=acc.budget,
                steps=int(steps),
                total_steps=int(total_steps),
                deadline_hit=bool(deadline_hit),
                t_submit=acc.t_submit,
                t_admit=t_admit,
                t_deliver=t_deliver,
                latency_ms=latency_s * 1e3,
                queue_ms=queue_s * 1e3,
                dispatch_ms=acc.dispatch_s * 1e3,
                compile_ms=acc.compile_s * 1e3,
                harvest_ms=acc.harvest_s * 1e3,
                # the residual of the in-flight window: loop bookkeeping,
                # other lanes' dispatches, host scheduling gaps
                slack_ms=max(0.0, inflight_s - accounted) * 1e3,
            )
            self.attributions.append(attr)
            return attr


class _NullTracer(Tracer):
    """The shared always-off tracer: every untraced server holds it, so
    instrumentation sites need no None checks — just the one ``enabled``
    read.  Recording methods are hard no-ops and it can never be
    enabled (callers wanting tracing construct a real :class:`Tracer`).
    """

    def __init__(self):
        super().__init__(capacity=1, enabled=False)

    def enable(self) -> None:  # pragma: no cover - guard
        raise RuntimeError(
            "NULL_TRACER cannot be enabled; pass a Tracer() to the server")

    def span(self, name, cat="serve", track=None, **args):
        return _NULL_CTX

    def instant(self, name, cat="serve", track=None, **args) -> None:
        return None

    def counter(self, name, value, cat="quality", track=None, **args) -> None:
        return None


#: the default tracer of every server: permanently disabled, shared.
NULL_TRACER = _NullTracer()
