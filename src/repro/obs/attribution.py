"""Deadline-budget attribution: where one request's latency went.

One :class:`Attribution` record decomposes a delivered request's
end-to-end latency into the pipeline components the tentpole names::

    latency_ms == queue_ms + dispatch_ms + compile_ms
                  + harvest_ms + slack_ms        (within tolerance)

* ``queue_ms`` — submit until the request won a lane slot (EDF queue
  wait; the whole latency if it was flushed before ever running).
* ``dispatch_ms`` — wall time of its lane's segment dispatches while
  the request occupied a slot (asynchronous device enqueue + trace-time
  Python, minus reclassified compiles).
* ``compile_ms`` — the subset of dispatch wall spent minting new jit
  traces (first dispatch of a pow2 segment length).  Separated because
  it is a warmup artifact, not steady-state cost — a request unlucky
  enough to trigger compilation should show it, not hide it in
  dispatch.
* ``harvest_ms`` — boundary materialization (the device sync) and slot
  retirement for its lane.
* ``slack_ms`` — the in-flight residual: serving-loop bookkeeping,
  other lanes' turns, host scheduling gaps.  Non-negative by
  construction (all accounted intervals lie inside the in-flight
  window and run sequentially on the serving thread).

Records are produced by :meth:`repro.obs.tracer.Tracer.
request_delivered`, surfaced through ``ServeMetrics.snapshot()
["attribution"]``, exported into the Chrome trace's ``otherData``, and
checked by ``python -m tools.obs --check``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.obs.names import ATTRIBUTION_FIELDS

__all__ = ["Attribution", "summarize"]


@dataclasses.dataclass(frozen=True)
class Attribution:
    """One delivered request's latency decomposition (milliseconds)."""

    request_id: int
    program: str
    lane: Optional[str]       # None if flushed before reaching a slot
    backend: Optional[str]
    decision: Optional[str]   # admission decision (edf/reject/degrade)
    backlog: int              # lane backlog observed at admission
    budget_steps: Optional[int]
    steps: int                # steps completed at delivery
    total_steps: int
    deadline_hit: bool
    t_submit: float           # server-clock timestamps (seconds)
    t_admit: Optional[float]
    t_deliver: float
    latency_ms: float
    queue_ms: float
    dispatch_ms: float
    compile_ms: float
    harvest_ms: float
    slack_ms: float

    def components(self) -> dict[str, float]:
        """The latency decomposition, in report order."""
        return {f: getattr(self, f) for f in ATTRIBUTION_FIELDS}

    def check(self, tol_ms: float = 1.0, rel_tol: float = 0.05) -> bool:
        """Do the components sum back to the end-to-end latency?

        Tolerance is ``tol_ms`` absolute or ``rel_tol`` of the latency,
        whichever is larger — timestamps come from one monotonic clock
        but components are accumulated across span boundaries, so exact
        equality is not guaranteed at float precision.
        """
        total = sum(self.components().values())
        return abs(total - self.latency_ms) <= max(
            tol_ms, rel_tol * self.latency_ms)

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def format(self) -> str:
        """A one-request human-readable breakdown (the example demo)."""
        parts = [
            f"request {self.request_id} [{self.program}"
            + (f" @ {self.backend}" if self.backend else "")
            + "]:",
            f"  latency   {self.latency_ms:8.3f} ms"
            f"  ({self.steps}/{self.total_steps} steps,"
            f" deadline {'hit' if self.deadline_hit else 'MISS'},"
            f" decision={self.decision})",
        ]
        for field in ATTRIBUTION_FIELDS:
            v = getattr(self, field)
            share = v / self.latency_ms if self.latency_ms > 0 else 0.0
            parts.append(
                f"  {field.removesuffix('_ms'):<9} {v:8.3f} ms"
                f"  ({share:5.1%})")
        return "\n".join(parts)


def summarize(records) -> dict:
    """Aggregate attribution records for ``ServeMetrics.snapshot()``.

    Returns component means plus the mean fraction of latency each
    component explains — the fleet-level "where do deadlines go" view.
    Well-defined for zero and one record.
    """
    records = list(records)
    n = len(records)
    out: dict = {"count": n, "complete": 0}
    if n == 0:
        for field in ATTRIBUTION_FIELDS:
            out[f"mean_{field}"] = 0.0
        out["mean_latency_ms"] = 0.0
        out["sum_check_fail"] = 0
        return out
    out["complete"] = sum(1 for r in records if r.t_admit is not None)
    out["mean_latency_ms"] = sum(r.latency_ms for r in records) / n
    for field in ATTRIBUTION_FIELDS:
        out[f"mean_{field}"] = sum(getattr(r, field) for r in records) / n
    out["sum_check_fail"] = sum(1 for r in records if not r.check())
    return out
