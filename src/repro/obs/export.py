"""Chrome trace-event export + segment-latency aggregation.

:func:`export_chrome_trace` turns a :class:`~repro.obs.tracer.Tracer`'s
ring into the Chrome trace-event JSON object format —
``{"traceEvents": [...], "displayTimeUnit": "ms", "otherData": {...}}``
— loadable directly in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing``.  Events are keyed to display tracks: each lane
gets its own named track (so a lane's dispatch/harvest cadence reads as
one swimlane), and track-less events fall back to their recording
thread.  ``otherData`` carries the non-timeline payload: the
per-request attribution records and the per-(backend, impl,
pow2-length) segment-latency histograms.

:func:`segment_histograms` is the WCET calibration half (ROADMAP item
3): it aggregates every steady-state ``serve.dispatch`` span into a
latency histogram per ``backend/impl/L<length>`` cell, with jit-compile
dispatches tabulated separately (compiles are warmup, and folding their
wall time into a worst-case estimate would poison it).
:func:`worst_case_table` folds those histograms (plus the harvest-span
population) into the persisted per-platform worst-case table that
:class:`repro.serve.cost.CostModel` prices certified admission from —
the same structure ``python -m tools.obs calibrate`` writes.
"""
from __future__ import annotations

import json
from typing import Optional

from repro.obs.names import ATTRIBUTION_FIELDS, SPAN_NAMES

__all__ = [
    "export_chrome_trace",
    "segment_histograms",
    "worst_case_table",
    "write_chrome_trace",
]

_PID = 1


def _percentile(sorted_vals: list[float], q: float) -> float:
    """Nearest-rank percentile over an already-sorted non-empty list."""
    idx = min(len(sorted_vals) - 1, max(0, round(q * (len(sorted_vals) - 1))))
    return sorted_vals[idx]


def segment_histograms(events) -> dict[str, dict]:
    """Per-(backend, impl, pow2-length) dispatch-latency histograms.

    Input is a span iterable (:meth:`Tracer.events` or re-parsed
    ``traceEvents`` dicts via :mod:`tools.obs`).  Returns
    ``{"<backend>/<impl>/L<len>": {count, mean_ms, p50_ms, p95_ms,
    max_ms, compile_count, compile_mean_ms}}`` — steady-state
    statistics in the main fields, compiles counted and timed apart.
    """
    cells: dict[str, dict[str, list[float]]] = {}
    for ev in events:
        if ev.name != "serve.dispatch" or ev.ph != "X" or ev.t1 is None:
            continue
        backend = ev.args.get("backend", "?")
        impl = ev.args.get("impl", backend)
        length = ev.args.get("length", 0)
        key = f"{backend}/{impl}/L{length}"
        cell = cells.setdefault(key, {"steady": [], "compile": []})
        bucket = "compile" if ev.args.get("compile") else "steady"
        cell[bucket].append(ev.dur_s * 1e3)
    out: dict[str, dict] = {}
    for key in sorted(cells):
        steady = sorted(cells[key]["steady"])
        compile_ = cells[key]["compile"]
        row: dict = {
            "count": len(steady),
            "mean_ms": sum(steady) / len(steady) if steady else 0.0,
            "p50_ms": _percentile(steady, 0.50) if steady else 0.0,
            "p95_ms": _percentile(steady, 0.95) if steady else 0.0,
            "max_ms": max(steady) if steady else 0.0,
            "compile_count": len(compile_),
            "compile_mean_ms":
                sum(compile_) / len(compile_) if compile_ else 0.0,
        }
        out[key] = row
    return out


def worst_case_table(events, *, platform: str, margin: float = 2.0) -> dict:
    """Fold a traced run into the persisted per-platform WCET table.

    ``cells`` carries one row per calibrated ``backend/impl/L<len>``
    dispatch cell — steady-state statistics only (compile-only cells
    are dropped: a cell whose every sample jit-compiled has no steady
    worst case to certify against) — with ``wcet_ms = margin *
    max_ms``.  ``harvest`` prices the per-iteration boundary
    materialization the same way from the ``serve.harvest`` span
    population.  The structure is byte-identical to what
    ``tools.obs.wcet.fold`` recomputes from exported trace JSON, so the
    two sides cross-validate.
    """
    if margin < 1.0:
        raise ValueError(
            f"wcet margin must be >= 1 (a headroom factor), got {margin}")
    cells: dict[str, dict] = {}
    for key, row in segment_histograms(events).items():
        if row["count"] < 1:
            continue
        cells[key] = {
            "count": row["count"],
            "mean_ms": row["mean_ms"],
            "p95_ms": row["p95_ms"],
            "max_ms": row["max_ms"],
            "wcet_ms": margin * row["max_ms"],
        }
    harvests = sorted(
        ev.dur_s * 1e3 for ev in events
        if ev.name == "serve.harvest" and ev.ph == "X" and ev.t1 is not None)
    harvest = {
        "count": len(harvests),
        "mean_ms": sum(harvests) / len(harvests) if harvests else 0.0,
        "max_ms": harvests[-1] if harvests else 0.0,
        "wcet_ms": margin * harvests[-1] if harvests else 0.0,
    }
    return {
        "schema_version": 1,
        "platform": platform,
        "margin": margin,
        "cells": cells,
        "harvest": harvest,
    }


def export_chrome_trace(tracer, meta: Optional[dict] = None) -> dict:
    """Render the tracer's ring + attribution table as a Chrome
    trace-event JSON object (``dict``, ready for ``json.dump``)."""
    events = tracer.events()
    t_base = min((ev.t0 for ev in events), default=0.0)

    # display tracks: named lanes first (stable order), then raw threads
    track_tid: dict[str, int] = {}
    thread_tid: dict[int, int] = {}
    for ev in events:
        if ev.track is not None:
            track_tid.setdefault(ev.track, 0)
        else:
            thread_tid.setdefault(ev.thread, 0)
    tid = 1
    trace_events: list[dict] = [{
        "name": "process_name", "ph": "M", "pid": _PID, "tid": 0,
        "args": {"name": "repro.serve"},
    }]
    for name in sorted(track_tid):
        track_tid[name] = tid
        trace_events.append({
            "name": "thread_name", "ph": "M", "pid": _PID, "tid": tid,
            "args": {"name": name},
        })
        tid += 1
    for ident in sorted(thread_tid):
        thread_tid[ident] = tid
        trace_events.append({
            "name": "thread_name", "ph": "M", "pid": _PID, "tid": tid,
            "args": {"name": f"thread-{ident}"},
        })
        tid += 1

    for ev in events:
        rec: dict = {
            "name": ev.name,
            "cat": ev.cat,
            "ph": ev.ph,
            "ts": (ev.t0 - t_base) * 1e6,  # trace-event unit: microseconds
            "pid": _PID,
            "tid": (track_tid[ev.track] if ev.track is not None
                    else thread_tid[ev.thread]),
            "args": dict(ev.args),
        }
        if ev.ph == "X":
            rec["dur"] = ev.dur_s * 1e6
        elif ev.ph == "i":
            rec["s"] = "t"  # instant scope: thread
        trace_events.append(rec)

    other: dict = {
        "attribution_fields": list(ATTRIBUTION_FIELDS),
        "attributions": [a.to_dict() for a in list(tracer.attributions)],
        "segment_histograms": segment_histograms(events),
        "event_count": len(events),
        "dropped": tracer.dropped,
        "span_names": sorted(SPAN_NAMES),
    }
    if meta:
        other["meta"] = dict(meta)
    return {
        "traceEvents": trace_events,
        "displayTimeUnit": "ms",
        "otherData": other,
    }


def write_chrome_trace(tracer, path, meta: Optional[dict] = None) -> dict:
    """Export and write to ``path``; returns the exported object."""
    doc = export_chrome_trace(tracer, meta=meta)
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return doc
