"""Serving launcher: deadline-aware batched anytime-forest serving.

Drives :class:`repro.serve.AnytimeServer` — the EDF slot-batched
scheduler — over a freshly trained forest and a synthetic request
stream, then prints the serving metrics (requests/sec,
deadline-hit-rate, p50/p99 steps-at-deadline, slot occupancy) plus the
accuracy of the predictions actually delivered at the deadline.

    PYTHONPATH=src python -m repro.launch.serve --dataset magic \
        --n-trees 10 --depth 6 --requests 64 --deadline-ms 5 \
        --capacity 16 --policy backward_squirrel \
        --threaded --admission degrade

With ``--pools N`` (N > 1) the stream serves through the multi-device
tier instead — :class:`repro.serve.PooledAnytimeServer`: one
device-pinned pool per device (wrapping when N exceeds the device
count), a backlog-aware router, and segment-boundary work stealing;
the summary then also reports routed/stolen counts.  Pair with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` on CPU to
emulate a multi-device host.

With ``--admission certified`` (or ``--guaranteed``) admission prices
every request's worst case from the calibrated WCET table
(``python -m tools.obs calibrate``, ``--wcet`` to point elsewhere):
provably-infeasible deadlines are rejected at submit and reported as
``certified-rejected``; admitted guaranteed requests must complete
their full plan inside the deadline.

With ``--trace PATH`` the run records the full span timeline
(:mod:`repro.obs`) and writes Chrome trace-event JSON on exit — load it
at https://ui.perfetto.dev, or feed it to ``python -m tools.obs report``
for the deadline-budget attribution and segment-latency tables.
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from repro.forest import make_dataset, split_dataset, train_forest
from repro.obs import Tracer, write_chrome_trace
from repro.schedule import AnytimeRuntime, ForestProgram
from repro.serve import (
    AdmissionRejected,
    AnytimeServer,
    CertificationFailed,
    CostModel,
    CostModelError,
    PooledAnytimeServer,
    QoS,
    list_admissions,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="magic")
    ap.add_argument("--n-trees", type=int, default=10)
    ap.add_argument("--depth", type=int, default=6)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--deadline-ms", type=float, default=5.0)
    ap.add_argument("--capacity", type=int, default=16)
    ap.add_argument("--pools", type=int, default=1,
                    help="> 1 serves through the pooled multi-device tier "
                         "(per-device slot pools + router + work stealing)")
    ap.add_argument("--queue-shards", type=int, default=1,
                    help="admission-queue shards per pool (lock striping "
                         "for concurrent submitters)")
    ap.add_argument("--policy", default="backward_squirrel")
    ap.add_argument("--backend", default=None,
                    help="jnp-ref | pallas | sharded (default: auto)")
    ap.add_argument("--admission", default="edf",
                    choices=list_admissions(),
                    help="overload policy: starve (edf) / shed at submit "
                         "(reject) / shrink per-request step budgets "
                         "(degrade) / admit only provably-feasible "
                         "deadlines (certified)")
    ap.add_argument("--admission-k", type=float, default=2.0,
                    help="backlog bound = capacity * k")
    ap.add_argument("--guaranteed", action="store_true",
                    help="submit every request guaranteed=True: WCET-"
                         "certified at admission, full-plan completion "
                         "inside the deadline or rejection at submit "
                         "(needs a calibrated cost model)")
    ap.add_argument("--wcet", default=None, metavar="PATH",
                    help="WCET table for certified admission (default: "
                         "reports/obs/wcet_<platform>.json via "
                         "CostModel.load)")
    ap.add_argument("--threaded", action="store_true",
                    help="serve through the background driver thread "
                         "(fire-and-forget submits) instead of the "
                         "cooperative drain loop")
    ap.add_argument("--trace", default=None, metavar="PATH",
                    help="record the span timeline and write Chrome "
                         "trace-event JSON (Perfetto-loadable) to PATH "
                         "on exit")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    X, y = make_dataset(args.dataset, seed=args.seed)
    n_classes = int(y.max()) + 1
    (tr, ytr), (orx, yor), (te, yte) = split_dataset(X, y, seed=args.seed)
    rf = train_forest(tr, ytr, n_classes, n_trees=args.n_trees,
                      max_depth=args.depth, seed=args.seed)
    rt = AnytimeRuntime(
        ForestProgram(rf.as_arrays(), y_order=yor[:300], X_order=orx[:300]))
    tracer = Tracer(margins=True) if args.trace else None
    cost_model = None
    if args.wcet or args.guaranteed or args.admission == "certified":
        try:
            cost_model = (CostModel.from_file(args.wcet) if args.wcet
                          else CostModel.load())
        except CostModelError as e:
            print(f"cannot price certified admission: {e}", file=sys.stderr)
            sys.exit(2)
    if args.pools > 1:
        server = PooledAnytimeServer(rt, pools=args.pools,
                                     capacity=args.capacity,
                                     admission=args.admission,
                                     admission_k=args.admission_k,
                                     tracer=tracer,
                                     queue_shards=args.queue_shards,
                                     cost_model=cost_model)
    else:
        server = AnytimeServer(rt, capacity=args.capacity,
                               admission=args.admission,
                               admission_k=args.admission_k,
                               tracer=tracer,
                               queue_shards=args.queue_shards,
                               cost_model=cost_model)
    if args.threaded:
        server.start()

    # warm the slot batch's jit traces so deadlines measure serving, not
    # compilation
    warm = min(args.capacity, len(te))
    server.serve(list(te[:warm]), deadline_ms=300_000.0,
                 policy=args.policy, backend=args.backend)
    server.metrics.reset()  # report the measured stream, not the warmup

    n = min(args.requests, len(te))
    qos = QoS(deadline_ms=args.deadline_ms, policy=args.policy,
              backend=args.backend, guaranteed=args.guaranteed)
    tickets, rejected, uncertifiable = [], 0, 0
    kept_labels = []
    for i in range(n):
        try:
            tickets.append(server.submit(te[i], qos))
            kept_labels.append(yte[i])
        except CertificationFailed:
            uncertifiable += 1  # deadline provably infeasible right now
        except AdmissionRejected:
            rejected += 1   # --admission reject sheds load at submit
    server.drain()
    results = [t.result() for t in tickets]
    if args.threaded:
        server.close()
    if uncertifiable:
        print(f"certified-rejected at submit: {uncertifiable}/{n} "
              f"(priced worst case exceeded the {args.deadline_ms} ms "
              f"deadline)")
    if rejected:
        print(f"rejected at submit: {rejected}/{n} "
              f"(admission={args.admission}, backlog bound = capacity x "
              f"{args.admission_k})")
    snap = server.metrics.snapshot()
    mode = "threaded driver" if args.threaded else "cooperative loop"
    tier = f"{args.pools} pools, " if args.pools > 1 else ""
    print(f"served {len(results)} requests @ {args.deadline_ms} ms deadline "
          f"(policy={args.policy}, capacity={args.capacity}, {tier}{mode}, "
          f"admission={args.admission}"
          f"{', guaranteed' if args.guaranteed else ''})")
    if args.pools > 1:
        print(f"  routed / stolen       {snap['routed']} / {snap['steals']}")
    if not results:
        print("  (every request was rejected at submit — nothing served)")
    else:
        preds = np.asarray([int(r.prediction) for r in results])
        acc = float((preds == np.asarray(kept_labels)).mean())
        print(f"  accuracy-at-deadline  {acc:.4f}")
        print(f"  deadline-hit-rate     {snap['deadline_hit_rate']:.3f}")
        print(f"  steps-at-deadline     "
              f"p50={snap['steps_at_deadline']['p50']:.0f} "
              f"p99={snap['steps_at_deadline']['p99']:.0f} "
              f"of {results[0].total_steps}")
    if snap["guaranteed_delivered"]:
        print(f"  guaranteed            {snap['guaranteed_delivered']} "
              f"delivered, {snap['guaranteed_misses']} misses "
              f"({snap['certified_admitted']} certified, "
              f"{snap['certified_rejected']} certified-rejected)")
    if snap["degraded_requests"]:
        print(f"  degraded requests     {snap['degraded_requests']} "
              f"(budget p50 {snap['budget_at_deadline']['p50']:.0f})")
    print(f"  requests/sec          {snap['requests_per_sec']:.1f}")
    print(f"  slot occupancy        {snap['slot_occupancy']:.2f}")
    if tracer is not None:
        doc = write_chrome_trace(tracer, args.trace, meta={
            "dataset": args.dataset, "policy": args.policy,
            "deadline_ms": args.deadline_ms, "capacity": args.capacity,
            "admission": args.admission,
            "threaded": bool(args.threaded),
        })
        print(f"  trace                 {args.trace} "
              f"({len(doc['traceEvents'])} events, "
              f"{len(doc['otherData']['attributions'])} attributions, "
              f"{doc['otherData']['dropped']} dropped)")


if __name__ == "__main__":
    main()
