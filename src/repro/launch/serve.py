"""Serving launcher: batched prefill + decode on the host mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch olmo-1b --reduced \
        --batch 4 --prompt-len 32 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config
from repro.data.pipeline import frontend_stub
from repro.launch import mesh as mesh_lib
from repro.models import model as MD
from repro.serving import engine as SE


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    params = MD.init(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    toks = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)), jnp.int32)
    extra = {k: jnp.asarray(v) for k, v in
             frontend_stub(cfg, args.batch, args.seed).items()}

    t0 = time.perf_counter()
    out = SE.generate(cfg, params, toks, args.max_new,
                      extra_inputs=extra or None,
                      temperature=args.temperature, seed=args.seed)
    dt = time.perf_counter() - t0
    new_tokens = args.batch * args.max_new
    print(f"arch={cfg.name} generated {new_tokens} tokens in {dt:.2f}s "
          f"({new_tokens/dt:.1f} tok/s incl. prefill+compile)")
    print("sample:", np.asarray(out[0, args.prompt_len:]).tolist())


if __name__ == "__main__":
    main()
