import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# The two lines above MUST run before any other import (jax locks the
# device count at first init).  Everything below is ordinary code.

# Multi-pod dry-run: prove every (architecture x input shape x mesh)
# combination lowers, SPMD-partitions and compiles on the production
# meshes, then extract roofline terms from the compiled artifact.
#
# Usage:
#   python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k
#   python -m repro.launch.dryrun --arch gemma2-2b --shape train_4k --multi-pod
#   python -m repro.launch.dryrun --all --out reports/dryrun
#   python -m repro.launch.dryrun --arch ... --mesh 2,4   # CI-sized
#
# No arrays are ever allocated: parameters, optimizer state, batches and
# KV caches enter ``jit(...).lower()`` as sharded ShapeDtypeStructs.

import argparse
import dataclasses
import json
import sys
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.registry import get_config, transformer_arch_ids
from repro.configs.shapes import SHAPES
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as RL
from repro.models import model as MD
from repro.models.params import ParamSpec, shardings_for
from repro.serving import engine as SE
from repro.training import optimizer as opt_lib
from repro.training.train import train_step_fn, _batch_pspec_tree


def _abstract_tree(specs, shardings, dtype_map=None):
    def mk(s: ParamSpec, sh):
        dt = dtype_map(s) if dtype_map else s.dtype
        return jax.ShapeDtypeStruct(s.shape, dt, sharding=sh)
    return jax.tree_util.tree_map(
        mk, specs, shardings, is_leaf=lambda x: isinstance(x, ParamSpec))


def _abstract_like(tree, shardings):
    return jax.tree_util.tree_map(
        lambda leaf, s: jax.ShapeDtypeStruct(leaf.shape, leaf.dtype, sharding=s),
        tree, shardings)


def lower_combination(arch: str, shape_name: str, mesh: Mesh,
                      param_dtype=jnp.bfloat16, unroll: bool = False,
                      cfg_overrides: Optional[dict] = None):
    """Returns (lowered, chips, meta) for one (arch, shape, mesh)."""
    cfg = get_config(arch)
    if unroll:
        # XLA cost_analysis counts while-loop bodies once; the roofline
        # pass therefore compiles the depth-unrolled HLO.
        cfg = dataclasses.replace(cfg, scan_layers=False)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = SHAPES[shape_name]
    ok, why = MD.supports_shape(cfg, shape)
    if not ok:
        return None, 0, {"skipped": why}

    specs = MD.build_param_specs(cfg)
    p_sh = shardings_for(specs, mesh, cfg.sharding_profile, cfg.shard_kv_heads)
    params_abs = _abstract_tree(specs, p_sh, dtype_map=lambda s: param_dtype)
    chips = mesh.devices.size

    if shape.kind == "train":
        ocfg = opt_lib.AdamWConfig()
        # optimizer m/v in f32, sharded like params
        m_abs = _abstract_tree(specs, p_sh, dtype_map=lambda s: jnp.float32)
        opt_abs = opt_lib.AdamWState(
            step=jax.ShapeDtypeStruct((), jnp.int32, sharding=NamedSharding(mesh, P())),
            m=m_abs, v=m_abs)
        batch_specs = MD.input_specs(cfg, shape)
        b_sh = _batch_pspec_tree(cfg, mesh, batch_specs)
        batch_abs = _abstract_like(batch_specs, b_sh)
        step = train_step_fn(cfg, ocfg)
        opt_sh = opt_lib.AdamWState(step=NamedSharding(mesh, P()), m=p_sh, v=p_sh)
        jitted = jax.jit(step, in_shardings=(p_sh, opt_sh, b_sh),
                         out_shardings=(p_sh, opt_sh, None),
                         donate_argnums=(0, 1))
        with mesh_lib.mesh_context(mesh):
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
        return lowered, chips, {"kind": "train"}

    if shape.kind == "prefill":
        batch_specs = MD.input_specs(cfg, shape)
        b_sh = _batch_pspec_tree(cfg, mesh, batch_specs)
        batch_abs = _abstract_like(batch_specs, b_sh)
        import numpy as np
        baxes = mesh_lib.batch_axes(mesh)
        ctx_par = shape.global_batch < int(np.prod([mesh.shape[a] for a in baxes]))
        c_sh = SE.cache_shardings(cfg, mesh, shape.global_batch, shape.seq_len, ctx_par)
        fn = SE.prefill_fn(cfg, cache_len=shape.seq_len)
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh),
                         out_shardings=(NamedSharding(mesh, P(baxes)), c_sh))
        with mesh_lib.mesh_context(mesh):
            lowered = jitted.lower(params_abs, batch_abs)
        return lowered, chips, {"kind": "prefill"}

    if shape.kind == "decode":
        import numpy as np
        baxes = mesh_lib.batch_axes(mesh)
        n_batch_shards = int(np.prod([mesh.shape[a] for a in baxes]))
        ctx_par = shape.global_batch < n_batch_shards
        c_sh = SE.cache_shardings(cfg, mesh, shape.global_batch, shape.seq_len, ctx_par)
        cache_abs = SE.abstract_cache(cfg, shape.global_batch, shape.seq_len, c_sh)
        # pos enters as a concrete value inside abstract cache (traced) - fine
        tok_sh = NamedSharding(mesh, P(baxes) if not ctx_par else P())
        tok_abs = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32,
                                       sharding=tok_sh)
        fn = SE.decode_fn(cfg)
        jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, tok_sh),
                         out_shardings=(tok_sh, c_sh), donate_argnums=(1,))
        with mesh_lib.mesh_context(mesh):
            lowered = jitted.lower(params_abs, cache_abs, tok_abs)
        return lowered, chips, {"kind": "decode", "context_parallel": ctx_par}

    raise ValueError(shape.kind)


def run_one(arch: str, shape_name: str, mesh: Mesh, verbose: bool = True,
            unroll: bool = False,
            cfg_overrides: Optional[dict] = None) -> dict[str, Any]:
    t0 = time.perf_counter()
    result: dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "axes": list(mesh.axis_names),
        "unroll": unroll,
    }
    lowered, chips, meta = lower_combination(arch, shape_name, mesh,
                                             unroll=unroll,
                                             cfg_overrides=cfg_overrides)
    result.update(meta)
    if lowered is None:
        result["status"] = "skipped"
        if verbose:
            print(f"SKIP  {arch} x {shape_name}: {meta['skipped']}", flush=True)
        return result
    t_lower = time.perf_counter() - t0
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0 - t_lower

    mem = RL.memory_analysis_dict(compiled)
    mf = MD.model_flops(get_config(arch), shape_name)
    hlo = compiled.as_text()
    terms = RL.terms_from_compiled(compiled, chips, model_flops=mf, hlo_text=hlo)
    result.update({
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory_analysis": mem,
        "roofline": terms.as_dict(),
    })
    if verbose:
        ma = mem.get("temp_size_in_bytes", 0)
        print(f"OK    {arch} x {shape_name} [{result['mesh']}] "
              f"flops={terms.flops:.3e} coll={terms.collective_bytes:.3e}B "
              f"dom={terms.dominant} temp={ma/2**30:.2f}GiB "
              f"(lower {t_lower:.1f}s compile {t_compile:.1f}s)", flush=True)
        print(f"      memory_analysis: {mem}", flush=True)
        print(f"      cost_analysis: flops={terms.flops:.4e} "
              f"bytes={terms.bytes_accessed:.4e}", flush=True)
    return result


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mesh", default=None,
                    help="override mesh shape, e.g. 2,4 (axes data,model)")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer stacks (accurate roofline counting)")
    ap.add_argument("--out", default=None, help="directory for JSON reports")
    args = ap.parse_args()

    def build_mesh(multi_pod: bool) -> Mesh:
        if args.mesh:
            dims = tuple(int(x) for x in args.mesh.split(","))
            axes = ("pod", "data", "model")[-len(dims):]
            return mesh_lib.make_mesh(dims, axes)
        return mesh_lib.make_production_mesh(multi_pod=multi_pod)

    archs = transformer_arch_ids() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results = []
    failures = 0
    for multi_pod in meshes:
        mesh = build_mesh(multi_pod)
        for arch in archs:
            for shape in shapes:
                try:
                    results.append(run_one(arch, shape, mesh, unroll=args.unroll))
                except Exception as e:  # a failure here is a sharding bug
                    failures += 1
                    print(f"FAIL  {arch} x {shape}: {type(e).__name__}: {e}",
                          flush=True)
                    results.append({"arch": arch, "shape": shape,
                                    "status": "fail", "error": str(e)[:2000]})
    if args.out:
        os.makedirs(args.out, exist_ok=True)
        tag = "multipod" if args.multi_pod else ("both" if args.both_meshes else "singlepod")
        if args.mesh:
            tag = f"mesh{args.mesh.replace(',', 'x')}"
        if args.unroll:
            tag += "_unroll"
        name = f"{args.out}/dryrun_{tag}"
        if len(archs) == 1:
            name += f"_{archs[0]}"
        if len(shapes) == 1:
            name += f"_{shapes[0]}"
        with open(name + ".json", "w") as f:
            json.dump(results, f, indent=2)
        print(f"wrote {name}.json", flush=True)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
