"""Production mesh definitions (TPU v5e target).

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model"); the
"pod" axis carries pure data parallelism (params never shard over it),
so its collectives are exactly the gradient all-reduce crossing the
inter-pod links — the quantity the multi-pod dry-run must prove lowers.

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over the actually-available devices (tests, examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return jax.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes that shard the batch dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_pspec(mesh: Mesh) -> P:
    return P(batch_axes(mesh))


def seq_pspec(mesh: Mesh) -> P:
    """Context-parallel spec: shard a sequence/cache-length dim over the
    batch axes (used when global_batch < data axis, e.g. long_500k)."""
    return P(None, batch_axes(mesh))
