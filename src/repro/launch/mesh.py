"""Production mesh definitions (TPU v5e target).

Single pod: 16x16 = 256 chips, axes ("data", "model").
Multi-pod:  2x16x16 = 512 chips, axes ("pod", "data", "model"); the
"pod" axis carries pure data parallelism (params never shard over it),
so its collectives are exactly the gradient all-reduce crossing the
inter-pod links — the quantity the multi-pod dry-run must prove lowers.

Functions, not module constants: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _auto(n: int):
    # jax < 0.5 has neither jax.sharding.AxisType nor the axis_types
    # kwarg on make_mesh; None means "omit the kwarg".
    axis_type = getattr(jax.sharding, "AxisType", None)
    return None if axis_type is None else (axis_type.Auto,) * n


def make_mesh(shape, axes) -> Mesh:
    """jax.make_mesh with Auto axis types where the jax version has them."""
    auto = _auto(len(axes))
    if auto is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=auto)


def mesh_context(mesh: Mesh):
    """``jax.set_mesh(mesh)`` on current jax; on jax < 0.5 the Mesh
    object itself is the context manager."""
    set_mesh = getattr(jax, "set_mesh", None)
    return set_mesh(mesh) if set_mesh is not None else mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1) -> Mesh:
    """Small mesh over the actually-available devices (tests, examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = max(1, min(model, n // data))
    return make_mesh((data, model), ("data", "model"))


def make_single_device_mesh(device) -> Mesh:
    """Degenerate 1x1 ("data", "model") mesh pinned to ONE device.

    The serving tier's per-device pools use this so every pool can run
    the same ``sharded`` executor code path — ``batch_pspec`` placement,
    replicated tables — while all of its dispatches land on its own
    device.  Built directly (not via ``jax.make_mesh``, which picks
    devices itself) so the caller controls WHICH device."""
    import numpy as np

    devices = np.asarray([device], dtype=object).reshape(1, 1)
    auto = _auto(2)
    if auto is None:
        return Mesh(devices, ("data", "model"))
    return Mesh(devices, ("data", "model"), axis_types=auto)


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes that shard the batch dimension."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def batch_pspec(mesh: Mesh) -> P:
    return P(batch_axes(mesh))


def n_batch_shards(mesh: Mesh) -> int:
    """How many ways the batch axis splits on this mesh."""
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def batch_sharding(mesh: Mesh) -> NamedSharding:
    """NamedSharding placing an array's leading (batch) dim on the mesh."""
    return NamedSharding(mesh, batch_pspec(mesh))


def replicated_sharding(mesh: Mesh) -> NamedSharding:
    """Fully-replicated placement (e.g. forest node tables, params)."""
    return NamedSharding(mesh, P())


def seq_pspec(mesh: Mesh) -> P:
    """Context-parallel spec: shard a sequence/cache-length dim over the
    batch axes (used when global_batch < data axis, e.g. long_500k)."""
    return P(None, batch_axes(mesh))
