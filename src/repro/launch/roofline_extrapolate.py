import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)

# Depth-extrapolated roofline: XLA's cost_analysis counts while-loop
# (lax.scan) bodies once, and fully unrolling 26-94 layer models at 256
# emulated devices costs many CPU-minutes per combo.  Instead we compile
# the model UNROLLED at two small depths (1 and 2 layer-units at FULL
# width and FULL input shape) and extrapolate linearly:
#
#   per_unit = cost(n2_units) - cost(n1_units)
#   total    = cost(n1_units) + (full_units - n1_units) * per_unit
#
# A layer-unit is whatever repeats: a layer (dense/moe/ssm), a
# local+global pair (gemma2), a mamba-group+shared-attn (zamba2), an
# encoder+decoder layer pair (whisper).  Validated against two full
# unrolled compiles (gemma2-2b, olmo-1b train_4k) in EXPERIMENTS.md —
# agreement within ~1%.
#
#   python -m repro.launch.roofline_extrapolate --all --out reports/roofline

import argparse
import json
import sys
import time
from typing import Any, Optional

from repro.configs.registry import get_config, transformer_arch_ids
from repro.configs.shapes import SHAPES
from repro.launch import mesh as mesh_lib
from repro.launch import roofline as RL
from repro.launch.dryrun import lower_combination
from repro.models import model as MD


def depth_points(cfg) -> tuple[dict, dict, int, int, int]:
    """(overrides_small, overrides_large, n1_units, n2_units, full_units)."""
    fam = cfg.family
    if fam in ("dense", "vlm") and cfg.local_global:
        return {"num_layers": 2}, {"num_layers": 4}, 1, 2, cfg.num_layers // 2
    if fam == "hybrid":
        per = cfg.hybrid_period
        n_groups = cfg.num_layers // per
        tail = cfg.num_layers - n_groups * per
        return ({"num_layers": per + tail}, {"num_layers": 2 * per + tail},
                1, 2, n_groups)
    if fam == "encdec":
        assert cfg.num_layers == cfg.encoder_layers
        return ({"num_layers": 1, "encoder_layers": 1},
                {"num_layers": 2, "encoder_layers": 2}, 1, 2, cfg.num_layers)
    return {"num_layers": 1}, {"num_layers": 2}, 1, 2, cfg.num_layers


def _cost_point(arch: str, shape: str, mesh, overrides: dict) -> Optional[dict]:
    overrides = dict(overrides)
    overrides["scan_layers"] = False
    lowered, chips, meta = lower_combination(arch, shape, mesh,
                                             cfg_overrides=overrides)
    if lowered is None:
        return None
    compiled = lowered.compile()
    hlo = compiled.as_text()
    t = RL.terms_from_compiled(compiled, chips, hlo_text=hlo)
    return {"flops": t.flops, "bytes": t.bytes_accessed,
            "coll": dict(t.collective_by_op), "chips": chips}


def extrapolate(arch: str, shape: str, mesh, verbose=True) -> dict[str, Any]:
    cfg = get_config(arch)
    ok, why = MD.supports_shape(cfg, shape)
    rec: dict[str, Any] = {"arch": arch, "shape": shape,
                           "mesh": "x".join(str(s) for s in mesh.devices.shape)}
    if not ok:
        rec["status"] = "skipped"
        rec["skipped"] = why
        if verbose:
            print(f"SKIP  {arch} x {shape}: {why}", flush=True)
        return rec
    ov1, ov2, n1, n2, full = depth_points(cfg)
    t0 = time.perf_counter()
    p1 = _cost_point(arch, shape, mesh, ov1)
    p2 = _cost_point(arch, shape, mesh, ov2)
    dt = time.perf_counter() - t0

    def lerp(k):
        per = (p2[k] - p1[k]) / (n2 - n1)
        return p1[k] + (full - n1) * per

    coll_total = {}
    for op in RL.COLLECTIVE_OPS:
        per = (p2["coll"].get(op, 0) - p1["coll"].get(op, 0)) / (n2 - n1)
        coll_total[op] = p1["coll"].get(op, 0) + (full - n1) * per

    terms = RL.RooflineTerms(
        flops=lerp("flops"),
        bytes_accessed=lerp("bytes"),
        collective_bytes=float(sum(coll_total.values())),
        collective_by_op={k: int(v) for k, v in coll_total.items()},
        chips=p1["chips"],
        model_flops=MD.model_flops(cfg, shape),
    )
    rec.update({
        "status": "ok",
        "units": {"n1": n1, "n2": n2, "full": full},
        "points": {"n1": p1, "n2": p2},
        "roofline": terms.as_dict(),
        "wall_s": round(dt, 1),
    })
    if verbose:
        print(f"OK    {arch} x {shape} t_comp={terms.t_compute*1e3:.2f}ms "
              f"t_mem={terms.t_memory*1e3:.2f}ms "
              f"t_coll={terms.t_collective*1e3:.2f}ms dom={terms.dominant} "
              f"useful={terms.useful_flops_ratio:.2f} ({dt:.0f}s)", flush=True)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--out", default="reports/roofline")
    args = ap.parse_args()

    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split(","))
        axes = ("pod", "data", "model")[-len(dims):]
        mesh = mesh_lib.make_mesh(dims, axes)
    else:
        mesh = mesh_lib.make_production_mesh()

    archs = transformer_arch_ids() if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    results = []
    fails = 0
    for arch in archs:
        for shape in shapes:
            try:
                results.append(extrapolate(arch, shape, mesh))
            except Exception as e:
                fails += 1
                print(f"FAIL  {arch} x {shape}: {type(e).__name__}: {e}", flush=True)
                results.append({"arch": arch, "shape": shape, "status": "fail",
                                "error": str(e)[:2000]})
            # incremental write so long runs are inspectable
            os.makedirs(args.out, exist_ok=True)
            with open(f"{args.out}/roofline_extrapolated.json", "w") as f:
                json.dump(results, f, indent=2)
    print(f"wrote {args.out}/roofline_extrapolated.json", flush=True)
    return 1 if fails else 0


if __name__ == "__main__":
    sys.exit(main())
