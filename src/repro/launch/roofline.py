"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh), all in seconds:

  compute    = HLO_FLOPs        / (chips * PEAK_FLOPS)
  memory     = HLO_bytes        / (chips * HBM_BW)
  collective = collective_bytes / (chips * ICI_BW)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()``;
collective_bytes is parsed out of the partitioned HLO text (sum of
result-shape sizes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute).

Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI (the task-specified formula divides by chips*link_bw).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes per collective op kind.

    Counts each logical collective once: `-start` ops are counted,
    matching `-done` ops are skipped (same transfer), as is the
    micro-sync `all-reduce` over empty tuples."""
    out: dict[str, int] = {k: 0 for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        line = line.strip()
        if " = " not in line:
            continue
        rhs = line.split(" = ", 1)[1]
        for op in COLLECTIVE_OPS:
            # op token immediately precedes its argument list
            m = re.search(rf"\b{op}(-start)?\(", rhs)
            if m is None:
                continue
            type_part = rhs[: m.start()]
            total = sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(type_part))
            out[op] += total
            break
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops: float
    bytes_accessed: float
    collective_bytes: float
    collective_by_op: dict[str, int]
    chips: int
    model_flops: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops / (self.chips * PEAK_FLOPS)

    @property
    def t_memory(self) -> float:
        return self.bytes_accessed / (self.chips * HBM_BW)

    @property
    def t_collective(self) -> float:
        return self.collective_bytes / (self.chips * ICI_BW)

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPs — catches remat / redundant compute."""
        return self.model_flops / self.flops if self.flops else 0.0

    def as_dict(self) -> dict[str, Any]:
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_bytes": self.collective_bytes,
            "collective_by_op": self.collective_by_op,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def terms_from_compiled(compiled, chips: int, model_flops: float = 0.0,
                        hlo_text: Optional[str] = None) -> RooflineTerms:
    """cost_analysis / HLO text describe the PER-DEVICE partitioned
    program; the roofline formula wants GLOBAL quantities, so scale by
    the chip count (model_flops is already global)."""
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0)) * chips
    nbytes = float(cost.get("bytes accessed", 0.0)) * chips
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = {k: v * chips for k, v in parse_collective_bytes(text).items()}
    return RooflineTerms(
        flops=flops,
        bytes_accessed=nbytes,
        collective_bytes=float(sum(coll.values())),
        collective_by_op=coll,
        chips=chips,
        model_flops=model_flops,
    )


def memory_analysis_dict(compiled) -> dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
    except Exception:  # backend without memory analysis
        return {}
    if ma is None:
        return {}
    keys = (
        "generated_code_size_in_bytes", "argument_size_in_bytes",
        "output_size_in_bytes", "alias_size_in_bytes",
        "temp_size_in_bytes", "host_generated_code_size_in_bytes",
        "host_argument_size_in_bytes", "host_output_size_in_bytes",
        "host_alias_size_in_bytes", "host_temp_size_in_bytes",
    )
    return {k: getattr(ma, k) for k in keys if hasattr(ma, k)}
