"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --steps 100 --seq-len 128 --batch-size 8 [--data N --model M]

On a real cluster this process runs per-host under the same entrypoint
(jax.distributed.initialize picks hosts up from the environment); on
this container it runs on the host mesh.
"""
from __future__ import annotations

import argparse

import jax

from repro.configs.registry import get_config
from repro.launch import mesh as mesh_lib
from repro.training.optimizer import AdamWConfig
from repro.training.train import train_loop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--data", type=int, default=0, help="data-axis size")
    ap.add_argument("--model", type=int, default=1, help="model-axis size")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = mesh_lib.make_host_mesh(
        data=args.data or len(jax.devices()), model=args.model)
    print(f"arch={cfg.name} mesh={dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"params~{cfg.param_count()/1e6:.1f}M")
    res = train_loop(
        cfg, steps=args.steps, seq_len=args.seq_len,
        batch_size=args.batch_size, mesh=mesh,
        ocfg=AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps // 4),
                         total_steps=args.steps),
        seed=args.seed, ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every)
    print(f"final loss {res.losses[-1]:.4f} at {res.steps_per_sec:.2f} steps/s")


if __name__ == "__main__":
    main()
