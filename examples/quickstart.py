"""Quickstart: train a random forest, generate squirrel step orders via
the ``repro.schedule`` policy registry, run anytime inference through the
``AnytimeRuntime``, and print the accuracy-vs-steps trade-off.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro import AnytimeRuntime, ForestProgram, list_backends, list_orders
from repro.core.metrics import mean_accuracy, normalized_mean_accuracy
from repro.forest import make_dataset, split_dataset, train_forest


def main():
    # 1. data: the paper's three-way split (train / ordering / test)
    X, y = make_dataset("magic", seed=0)
    (Xtr, ytr), (Xor, yor), (Xte, yte) = split_dataset(X, y, seed=0)

    # 2. a standard sklearn-style random forest, but retaining the
    #    inner-node class distributions CART computes anyway
    rf = train_forest(Xtr, ytr, n_classes=2, n_trees=5, max_depth=4, seed=0)
    forest = rf.as_arrays()
    print(f"forest: {forest.n_trees} trees, depth {forest.max_depth}, "
          f"{forest.total_steps} anytime steps")
    print(f"registered order policies: {', '.join(list_orders())}")
    print(f"registered execution backends: {', '.join(list_backends())}")

    # 3. one runtime owns order generation (content-hash cached) and
    #    serving; every registered order's curve comes from a single
    #    vmapped batched pass
    rt = AnytimeRuntime(ForestProgram(forest, y_order=yor, X_order=Xor))
    names = ("optimal", "backward_squirrel", "forward_squirrel", "depth",
             "breadth", "random", "unoptimal")
    curves = rt.evaluate_orders(Xte, yte, names)
    for name in names:
        curve = curves[name]
        print(f"{name:18s} mean_acc={mean_accuracy(curve):.4f} "
              f"NMA={normalized_mean_accuracy(curve):.4f} "
              f"curve: {curve[0]:.3f} -> {curve[len(curve)//2]:.3f} "
              f"-> {curve[-1]:.3f}")

    # 4. online: interruptible session — abort after ANY number of steps.
    #    backend= picks the execution layer ("jnp-ref" oracle scan,
    #    "pallas" MXU kernels, "sharded" mesh batching); unset
    #    auto-selects by jax.default_backend().
    sess = rt.session(Xte, "backward_squirrel")
    for budget in (0, 3, 10, sess.total_steps):
        sess.advance(budget - sess.pos)
        acc = (sess.predict() == yte).mean()
        print(f"abort after {sess.pos:3d}/{sess.total_steps} steps -> "
              f"accuracy {acc:.4f}  [{sess.backend.backend_name}]")


if __name__ == "__main__":
    main()
