"""Anytime serving demo — BOTH granularities of the paper's idea behind
the ONE ``repro.schedule.AnytimeRuntime`` API:

  1. Random forests (the paper): batched tabular requests under a
     deadline; the squirrel step order decides which tree advances next;
     ``Session.advance_until(deadline_ms)`` realizes the deadline loop
     and every abort still yields a full-quality-so-far prediction.

  2. Transformers (beyond-paper): a 2-member LM ensemble served with a
     squirrel-generated layer-execution order; the SAME runtime wraps
     the ensemble via ``EnsembleProgram``.

    PYTHONPATH=src python examples/serve_anytime.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import AnytimeRuntime, ForestProgram
from repro.configs.registry import get_config
from repro.forest import make_dataset, split_dataset, train_forest
from repro.models import model as MD
from repro.serving.anytime_depth import EnsembleMember, EnsembleProgram


def forest_serving():
    print("=== anytime forest serving (paper) ===")
    X, y = make_dataset("adult", seed=0)
    (Xtr, ytr), (Xor, yor), (Xte, yte) = split_dataset(X, y, seed=0)
    rf = train_forest(Xtr, ytr, 2, n_trees=10, max_depth=8, seed=0)
    rt = AnytimeRuntime(ForestProgram(rf.as_arrays(), y_order=yor, X_order=Xor))

    for deadline_ms in (0.5, 2.0, 10.0, 1e9):
        sess = rt.session(Xte, "backward_squirrel", chunk=4)
        sess.advance_until(deadline_ms)  # abort checkpoint every 4 steps
        acc = (sess.predict() == yte).mean()
        print(f"  deadline {deadline_ms:7.1f} ms -> {sess.pos:3d}/"
              f"{sess.total_steps} steps, accuracy {acc:.4f}")

    # Execution backends are pluggable per session: "pallas" routes the
    # fused runs through the MXU kernels (compiled Mosaic on TPU;
    # interpret mode on CPU, so only a small slice here), "sharded"
    # places the batch axis on the host mesh. Both match "jnp-ref"
    # bit-for-bit — the parity suite in tests/test_backends.py.
    ref = rt.session(Xte[:64], "backward_squirrel", backend="jnp-ref")
    ref.run_to_completion()
    for backend in ("pallas", "sharded"):
        sess = rt.session(Xte[:64], "backward_squirrel", backend=backend)
        sess.run_to_completion()
        agree = (sess.predict() == ref.predict()).mean()
        print(f"  backend={backend:8s} agreement vs jnp-ref: {agree:.4f} "
              f"({len(sess.backend.dispatched_lengths)} jit traces)")


def transformer_serving():
    print("=== anytime-depth transformer serving (beyond-paper) ===")
    cfg = get_config("olmo-1b", reduced=True)
    members = []
    # briefly train two members inline so the exit readouts carry signal
    from repro.training import optimizer as opt_lib
    from repro.training.train import train_step_fn
    from repro.data.pipeline import make_batches as mb
    for i in range(2):
        params = MD.init(cfg, jax.random.PRNGKey(i))
        ocfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=30)
        step = jax.jit(train_step_fn(cfg, ocfg))
        opt = opt_lib.init_state(params)
        for k, batch in zip(range(30), mb(cfg, 64, 8, seed=i)):
            batch = {k2: jnp.asarray(v) for k2, v in batch.items()}
            params, opt, m = step(params, opt, batch)
        print(f"  member {i}: trained 30 steps, loss {float(m['loss']):.3f}")
        members.append(EnsembleMember(cfg, params))

    calib = next(mb(cfg, 64, 16, seed=100))
    batch = {"tokens": jnp.asarray(calib["tokens"])}
    labels = np.asarray(calib["labels"][:, -1])
    # the SAME runtime class serves the ensemble granularity
    rt = AnytimeRuntime(EnsembleProgram(members, batch, labels, top_v=64))
    order = rt.order("backward_squirrel")
    print(f"  squirrel layer order over (member,layer) units: {order.tolist()}")

    test = next(mb(cfg, 64, 16, seed=200))
    tb = {"tokens": jnp.asarray(test["tokens"])}
    tl = np.asarray(test["labels"][:, -1])
    sess = rt.session(tb, order=order)
    curve = [float(np.mean(sess.predict() == tl))]
    while sess.remaining:
        sess.advance(1)
        curve.append(float(np.mean(sess.predict() == tl)))
    for k in range(0, len(curve), max(1, len(curve) // 6)):
        print(f"  after {k:2d} layer-steps: next-token acc {curve[k]:.3f}")
    print(f"  final ({len(curve)-1} steps): {curve[-1]:.3f}")


if __name__ == "__main__":
    forest_serving()
    transformer_serving()
