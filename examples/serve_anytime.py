"""Anytime serving demo — BOTH granularities of the paper's idea behind
the ONE ``repro.serve.AnytimeServer`` loop:

  1. Random forests (the paper): many concurrent deadline-bearing
     requests multiplexed onto one device runtime by the EDF
     slot-batched scheduler; every request gets the last completed
     segment-boundary readout at its deadline — bit-identical to a solo
     session advanced the same number of steps.

  2. Threaded serving: the same server as a fire-and-forget service —
     a background driver owns the loop, the caller submits from its own
     thread and collects tickets as they complete, and overload is
     absorbed by degrade admission (budgets shrink instead of requests
     being rejected or starved).

  3. Transformers (beyond-paper): a 2-member LM ensemble served by the
     SAME server through a session lane — the subsystem is
     program-agnostic.

    PYTHONPATH=src python examples/serve_anytime.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import AnytimeRuntime, AnytimeServer, ForestProgram, as_completed
from repro.serve import QoS
from repro.configs.registry import get_config
from repro.forest import make_dataset, split_dataset, train_forest
from repro.models import model as MD
from repro.obs import Tracer
from repro.serving.anytime_depth import EnsembleMember, EnsembleProgram


def forest_serving():
    print("=== anytime forest serving (paper) ===")
    X, y = make_dataset("adult", seed=0)
    (Xtr, ytr), (Xor, yor), (Xte, yte) = split_dataset(X, y, seed=0)
    rf = train_forest(Xtr, ytr, 2, n_trees=10, max_depth=8, seed=0)
    rt = AnytimeRuntime(ForestProgram(rf.as_arrays(), y_order=yor, X_order=Xor))
    server = AnytimeServer(rt, capacity=16)

    # warm the slot batch's jit traces, then serve one capacity-sized
    # generation per deadline tier — every delivery is the anytime
    # readout of the last completed segment boundary, so tighter
    # deadlines land earlier on the squirrel order's accuracy curve
    n = 16
    server.serve(list(Xte[:n]), deadline_ms=300_000.0)
    for deadline_ms in (5.0, 25.0, 100.0, 1e9):
        results = server.serve(list(Xte[:n]), deadline_ms=deadline_ms)
        preds = np.asarray([int(r.prediction) for r in results])
        steps = np.asarray([r.steps_completed for r in results])
        acc = float((preds == yte[:n]).mean())
        print(f"  deadline {deadline_ms:9.1f} ms -> steps p50 "
              f"{int(np.percentile(steps, 50)):3d}/{results[0].total_steps}, "
              f"accuracy {acc:.4f}")

    # oversubscribed burst: 4x capacity shares the slots, EDF recycling
    server.metrics.reset()  # snapshot the burst alone, not the tiers above
    burst = server.serve(list(Xte[:64]), deadline_ms=30_000.0)
    snap = server.metrics.snapshot()
    print(f"  burst of {len(burst)} on {server.scheduler.capacity} slots: "
          f"hit-rate {snap['deadline_hit_rate']:.2f}, occupancy "
          f"{snap['slot_occupancy']:.2f}, {snap['requests_per_sec']:.1f} req/s")

    # requests pick execution backends per lane; all three match the
    # jnp-ref oracle (tests/test_serve.py asserts bit-parity)
    ref = server.serve(list(Xte[:8]), deadline_ms=1e9, backend="jnp-ref")
    for backend in ("pallas", "sharded"):
        res = server.serve(list(Xte[:8]), deadline_ms=1e9, backend=backend)
        agree = np.mean([int(a.prediction) == int(b.prediction)
                         for a, b in zip(res, ref)])
        print(f"  backend={backend:8s} agreement vs jnp-ref: {agree:.4f}")


def threaded_serving():
    print("=== threaded fire-and-forget serving (PR 5) ===")
    X, y = make_dataset("magic", seed=0)
    (Xtr, ytr), (Xor, yor), (Xte, yte) = split_dataset(X, y, seed=0)
    rf = train_forest(Xtr, ytr, 2, n_trees=8, max_depth=6, seed=0)
    rt = AnytimeRuntime(ForestProgram(rf.as_arrays(), y_order=yor, X_order=Xor))

    # the context manager starts the background driver; submit() is a
    # thread-safe enqueue and this thread's own work (here: feature
    # prep for the NEXT batch) overlaps device execution.  The tracer
    # records the full span timeline + per-request deadline-budget
    # attribution (queue/dispatch/compile/harvest/slack)
    tracer = Tracer(margins=True)
    with AnytimeServer(rt, capacity=8, admission="degrade",
                       admission_k=1.0, tracer=tracer) as server:
        tickets = [server.submit(x, QoS(deadline_ms=60_000.0)) for x in Xte[:32]]
        tickets[0].add_done_callback(
            lambda t: print(f"  first completion callback: request "
                            f"{t.request_id} after "
                            f"{t.result().steps_completed} steps"))
        prepped = np.asarray(Xte[32:64])      # caller-side work, overlapped
        done_order = [t.request_id for t in as_completed(tickets)]
        print(f"  {len(done_order)} tickets resolved while this thread "
              f"prepped {prepped.shape[0]} more rows")
        snap = server.metrics.snapshot()
        print(f"  hit-rate {snap['deadline_hit_rate']:.2f}, degraded "
              f"{snap['degraded_requests']} (budgets shrink past "
              f"capacity x k backlog; budget p50 "
              f"{snap['budget_at_deadline']['p50']:.0f} of "
              f"{rt.program.n_units * rt.program.unit_steps} steps)")
    # leaving the block stop()s the driver: in-flight slots drained to
    # their last boundary readout, every admitted ticket answered
    print(f"  after close: all done = {all(t.done for t in tickets)}")
    # where did one request's latency actually go?  Every delivered
    # ticket has an attribution record; components sum to the
    # end-to-end latency (jit compiles are split out of dispatch, so a
    # request that paid for a trace mint shows it)
    attr = next(a for a in tracer.attributions
                if a.request_id == tickets[0].request_id)
    print("  one-request deadline-budget attribution:")
    for line in attr.format().splitlines():
        print(f"    {line}")
    print(f"  ({len(list(tracer.attributions))} attribution records, "
          f"{len(tracer.events())} spans recorded — export with "
          f"repro.obs.write_chrome_trace for Perfetto)")


def transformer_serving():
    print("=== anytime-depth transformer serving (beyond-paper) ===")
    cfg = get_config("olmo-1b", reduced=True)
    members = []
    # briefly train two members inline so the exit readouts carry signal
    from repro.training import optimizer as opt_lib
    from repro.training.train import train_step_fn
    from repro.data.pipeline import make_batches as mb
    for i in range(2):
        params = MD.init(cfg, jax.random.PRNGKey(i))
        ocfg = opt_lib.AdamWConfig(lr=1e-3, warmup_steps=5, total_steps=30)
        step = jax.jit(train_step_fn(cfg, ocfg))
        opt = opt_lib.init_state(params)
        for k, batch in zip(range(30), mb(cfg, 64, 8, seed=i)):
            batch = {k2: jnp.asarray(v) for k2, v in batch.items()}
            params, opt, m = step(params, opt, batch)
        print(f"  member {i}: trained 30 steps, loss {float(m['loss']):.3f}")
        members.append(EnsembleMember(cfg, params))

    calib = next(mb(cfg, 64, 16, seed=100))
    batch = {"tokens": jnp.asarray(calib["tokens"])}
    labels = np.asarray(calib["labels"][:, -1])
    # the SAME server class serves the ensemble granularity: the program
    # has no slot-batch surface, so requests flow through a session lane
    rt = AnytimeRuntime(EnsembleProgram(members, batch, labels, top_v=64))
    server = AnytimeServer(rt, capacity=2, chunk=1)
    order = rt.order("backward_squirrel")
    print(f"  squirrel layer order over (member,layer) units: {order.tolist()}")

    test = next(mb(cfg, 64, 16, seed=200))
    tb = {"tokens": jnp.asarray(test["tokens"])}
    tl = np.asarray(test["labels"][:, -1])
    for deadline_ms in (3_000.0, 1e9):
        ticket = server.submit(tb, QoS(deadline_ms=deadline_ms))
        server.drain()
        r = ticket.result()
        acc = float(np.mean(r.prediction == tl))
        print(f"  deadline {deadline_ms:9.1f} ms -> "
              f"{r.steps_completed:2d}/{r.total_steps} layer-steps, "
              f"next-token acc {acc:.3f} "
              f"({'completed' if r.completed else 'aborted at deadline'})")


if __name__ == "__main__":
    forest_serving()
    threaded_serving()
    transformer_serving()
