"""End-to-end training driver: train a transformer on the synthetic LM
stream with the sharded train step (any assigned arch, reduced or full).

    # ~15M-param model, a few hundred steps on CPU:
    PYTHONPATH=src python examples/train_lm.py --arch olmo-1b --reduced \
        --steps 200 --seq-len 128 --batch-size 8

    # the 100M-class run used for EXPERIMENTS.md (slower):
    PYTHONPATH=src python examples/train_lm.py --arch olmo-1b --preset 100m \
        --steps 300 --seq-len 256 --batch-size 4
"""
import argparse
import dataclasses

import jax

from repro.configs.registry import get_config
from repro.training.optimizer import AdamWConfig
from repro.training.train import train_loop

PRESETS = {
    # ~100M-class: 10 layers x d_model 896 (demo vocab 8k so the unigram/
    # Markov structure is learnable within a few hundred CPU steps — a 50k
    # vocab needs far more tokens/step than a CPU demo can push)
    "100m": dict(num_layers=10, d_model=896, num_heads=14, num_kv_heads=14,
                 head_dim=64, d_ff=3584, vocab_size=8192),
    # ~25M for quicker demos
    "25m": dict(num_layers=6, d_model=512, num_heads=8, num_kv_heads=8,
                head_dim=64, d_ff=2048, vocab_size=8192),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--preset", choices=list(PRESETS), default=None)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced or args.preset is None)
    if args.preset:
        cfg = dataclasses.replace(cfg, **PRESETS[args.preset])
    n_params = cfg.param_count()
    print(f"arch={cfg.name} params~{n_params/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    res = train_loop(
        cfg,
        steps=args.steps,
        seq_len=args.seq_len,
        batch_size=args.batch_size,
        ocfg=AdamWConfig(lr=args.lr, warmup_steps=min(50, args.steps // 4),
                         total_steps=args.steps),
        ckpt_dir=args.ckpt_dir,
        ckpt_every=100 if args.ckpt_dir else 0,
    )
    import numpy as np
    first = float(np.mean(res.losses[:10]))
    last = float(np.mean(res.losses[-10:]))
    print(f"done: loss {first:.4f} -> {last:.4f} "
          f"({res.steps_per_sec:.2f} steps/s)")
    assert last < first, "training must reduce loss"


if __name__ == "__main__":
    main()
