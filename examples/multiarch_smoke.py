"""Run one forward + one train step + one decode step for EVERY assigned
architecture (reduced variants) — the ``--arch`` selector demo.

    PYTHONPATH=src python examples/multiarch_smoke.py [--arch qwen3-14b]
"""
import argparse

import jax
import jax.numpy as jnp

from repro.configs.registry import get_config, transformer_arch_ids
from repro.configs.shapes import InputShape
from repro.models import model as MD
from repro.models import transformer as T
from repro.training import optimizer as opt_lib
from repro.training.train import train_step_fn


def run_arch(arch: str):
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = MD.init(cfg, key)
    batch = MD.make_batch(cfg, InputShape("smoke", 32, 2, "train"), key)

    step = jax.jit(train_step_fn(cfg, opt_lib.AdamWConfig(total_steps=10)))
    opt = opt_lib.init_state(params)
    params2, opt, metrics = step(params, opt, batch)

    pre = MD.make_batch(cfg, InputShape("p", 16, 2, "prefill"), key)
    _, _, cache = T.forward(cfg, params, pre, return_cache=True, cache_len=20)
    dl, _ = T.decode_step(cfg, params, cache, jnp.zeros((2, 1), jnp.int32))

    print(f"{arch:22s} [{cfg.family:6s}] loss={float(metrics['loss']):7.4f} "
          f"decode_logits={tuple(dl.shape)} "
          f"params={MD.param_count(MD.build_param_specs(cfg)):,}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    args = ap.parse_args()
    for arch in ([args.arch] if args.arch else transformer_arch_ids()):
        run_arch(arch)


if __name__ == "__main__":
    main()
