"""Assemble the §Dry-run and §Roofline tables of EXPERIMENTS.md from the
JSON reports.

    PYTHONPATH=src python -m benchmarks.experiments_report > reports/tables.md
"""
from __future__ import annotations

import glob
import json

from repro.configs.registry import get_config
from repro.launch.roofline import HBM_BW
from repro.models.model import model_flops, traffic_floor_bytes


def fmt_t(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.1f}µs"


def fmt_b(x):
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.2f}{unit}"
    return f"{x:.0f}B"


def dryrun_table(recs):
    lines = [
        "| arch | shape | mesh | kind | status | compile | per-chip temp (CPU BA) | per-chip args |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        mesh = r.get("mesh", "?")
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | - | "
                         f"SKIP ({r.get('skipped','')[:48]}) | - | - | - |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | {mesh} | - | FAIL | - | - | - |")
            continue
        ma = r.get("memory_analysis", {})
        lines.append(
            f"| {r['arch']} | {r['shape']} | {mesh} | {r.get('kind','')} | ok | "
            f"{r.get('compile_s','-')}s | {fmt_b(ma.get('temp_size_in_bytes', 0))} | "
            f"{fmt_b(ma.get('argument_size_in_bytes', 0))} |")
    return "\n".join(lines)


def roofline_table(recs):
    lines = [
        "| arch | shape | t_compute | t_mem (XLA bound) | t_mem (floor) | t_collective | dominant | MODEL/HLO | bottleneck note |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        if r["status"] == "skipped":
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - | "
                         f"SKIP: {r.get('skipped','')[:40]} |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - | FAIL |")
            continue
        t = r["roofline"]
        cfg = get_config(r["arch"])
        chips = t["chips"]
        mf = model_flops(cfg, r["shape"])  # recompute with exact counts
        floor = traffic_floor_bytes(cfg, r["shape"]) / (chips * HBM_BW)
        useful = mf / t["flops"] if t["flops"] else 0.0
        # dominant using the floor-vs-bound window
        terms = {"compute": t["t_compute_s"], "memory": t["t_memory_s"],
                 "collective": t["t_collective_s"]}
        dom = max(terms, key=terms.get)
        note = {
            "compute": "matmul-bound: raise MXU utilization / cut remat",
            "memory": "traffic-bound: fuse elementwise chains, bf16 intermediates",
            "collective": "comm-bound: reshard or overlap collectives",
        }[dom]
        lines.append(
            f"| {r['arch']} | {r['shape']} | {fmt_t(t['t_compute_s'])} | "
            f"{fmt_t(t['t_memory_s'])} | {fmt_t(floor)} | "
            f"{fmt_t(t['t_collective_s'])} | **{dom}** | {useful:.2f} | {note} |")
    return "\n".join(lines)


def main():
    both = []
    for p in (glob.glob("reports/dryrun_final/dryrun_both*.json")
              or glob.glob("reports/dryrun/dryrun_both*.json")):
        both.extend(json.load(open(p)))
    # de-dup: later entries win; drop stale FAILs once an ok exists
    seen = {}
    for r in both:
        key = (r["arch"], r["shape"], r.get("mesh"))
        seen[key] = r
    ok_pairs = {(r["arch"], r["shape"]) for r in seen.values() if r["status"] == "ok"}
    both = [r for r in seen.values()
            if not (r["status"] == "fail" and (r["arch"], r["shape"]) in ok_pairs)]
    print("## §Dry-run (scanned production configs, 16x16 and 2x16x16)\n")
    print(dryrun_table(both))
    print()
    try:
        roof = json.load(open("reports/roofline/roofline_extrapolated.json"))
        print("## §Roofline (single-pod 16x16, depth-extrapolated exact counts)\n")
        print(roofline_table(roof))
    except FileNotFoundError:
        print("(roofline_extrapolated.json not yet available)")


if __name__ == "__main__":
    main()
