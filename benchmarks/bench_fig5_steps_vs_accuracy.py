"""Paper Fig. 5: accuracy vs steps curves (letter; paper: 7 trees x
depth 7; our default 6x6 keeps the Optimal Order tractable on 2 CPUs —
7^6 = 117k Dijkstra states vs the paper-size 8^7 = 2.1M).

Claims under test: all orders share start/end accuracy; squirrel/optimal
rise fastest; unoptimal rises slowest.

All curves come from ONE vmapped batched pass over the step axis
(``AnytimeRuntime.evaluate_orders``) instead of a serial per-order loop.
"""
from __future__ import annotations


from benchmarks.common import build_pipeline, runtime_for
from repro.core.metrics import mean_accuracy, normalized_mean_accuracy

ORDERS = ("optimal", "backward_squirrel", "forward_squirrel",
          "prune_depth_IE", "breadth", "random", "unoptimal")


def run(dataset: str = "letter", n_trees: int = 6, depth: int = 6,
        include_optimal: bool = True, verbose: bool = True):
    fa, pp, yor, te, yte = build_pipeline(dataset, n_trees, depth)
    names = [n for n in ORDERS
             if include_optimal or n not in ("optimal", "unoptimal")]
    rt = runtime_for(fa, pp, yor)
    curves = rt.evaluate_orders(te, yte, names)  # single vmapped pass
    if verbose:
        for name in names:
            c = curves[name]
            print(f"fig5,{name},mean={mean_accuracy(c):.4f},"
                  f"nma={normalized_mean_accuracy(c):.4f},"
                  f"start={c[0]:.4f},end={c[-1]:.4f}")
    return {"curves": {k: v.tolist() for k, v in curves.items()}}


if __name__ == "__main__":
    run()
