"""Trace-driven open-loop traffic harness for the pooled serving tier.

Generates arrival-stamped request streams — Poisson or bursty MMPP
(2-state Markov-modulated Poisson) inter-arrivals, mixed deadline
distributions, mixed order policies — and drives a
:class:`~repro.serve.pool.PooledAnytimeServer` with them in one of two
execution modes:

* **sim** (default; the frontier's mode) — *virtual time*.  The pooled
  server runs cooperatively under a manual clock; each pool owns its own
  virtual timeline, advanced by a **calibrated** per-iteration step cost
  measured from a real, warmed run of the same lanes on this machine.
  Pools progress in parallel in virtual time — exactly the concurrency
  a multi-device deployment gets from N real devices — so the
  sustained-throughput-vs-p99-latency frontier and the pool-scaling
  gate are measurable on a single-core CI container, where N OS threads
  time-slicing one core could never show a wall-clock speedup.  All
  deadline/latency accounting is against the virtual clock; the actual
  device math still runs for real (results stay bit-exact, steals still
  migrate real slot state).
* **real** — wall-clock, threaded drivers, ``time.sleep`` pacing.  An
  open-loop stream submits at its scheduled arrival times no matter how
  far completions lag (the load a server cannot push back on); a
  closed-loop stream caps in-flight requests at a fixed concurrency.
  Used by the ``serve-scale`` CI smoke (under
  ``--xla_force_host_platform_device_count=8``) to exercise the real
  thread/driver/steal machinery end to end.

The **frontier** sweep offers each pool-count a ladder of arrival rates
(multiples of the calibrated single-pool service rate) and reports, per
point: offered rate, sustained delivery throughput, the anytime
deadline-hit rate (>= 1 segment by deadline — EDF keeps this near 1.0
deep into overload; quality degrades instead of requests missing), the
**good rate** (full plan served inside the deadline — the saturation
signal), p50/p99 virtual latency, and steal counts.  The *knee* of a
configuration is the highest offered rate whose good rate stays >=
``hit_floor`` (0.99); ``pool_scaling = knee(4 pools) / knee(1 pool)``
is the gated number: >= ``min_pool_scaling`` (3.0) or the build fails.

    PYTHONPATH=src python -m benchmarks.loadgen --smoke
    PYTHONPATH=src python -m benchmarks.loadgen --mode real --pools 4
"""
from __future__ import annotations

import argparse
import math
import random
import time

import numpy as np

from benchmarks.common import build_pipeline, runtime_for
from repro.serve import (
    AdmissionRejected,
    CertificationFailed,
    PooledAnytimeServer,
    QoS,
    Request,
)

#: default deadline mix, in units of one request's calibrated solo
#: service time: (weight, lo, hi) — a loose majority plus a tight tail,
#: sampled uniformly inside each band.  The bands sit a small factor
#: above the service time, so queue wait beyond a few service times
#: turns into missed completions — that is what makes the knee visible;
#: deadlines many times the service time would hide saturation behind
#: the EDF queue's elasticity for any finite stream.
DEADLINE_MIX = ((0.7, 2.0, 4.0), (0.3, 1.5, 2.5))
#: default policy mix (weight, order-policy name)
POLICY_MIX = ((1.0, "backward_squirrel"),)
#: offered-rate ladder, in multiples of the calibrated base rate —
#: dense around the single-pool knee, extended past 4x for the pooled
#: configurations
RATE_MULTIPLIERS = (0.5, 0.75, 1.0, 1.25, 1.5, 2.0, 3.0, 4.0, 5.0, 6.0)


class ManualClock:
    """Monotonic clock under harness control (seconds)."""

    def __init__(self, t: float = 1000.0):
        self.t = t

    def __call__(self) -> float:
        return self.t


# ---------------------------------------------------------------------------
# Arrival processes and request-stream synthesis
# ---------------------------------------------------------------------------


def poisson_arrivals(rate_rps: float, n: int, rng: random.Random) -> list[float]:
    """Cumulative arrival offsets (s) of a Poisson stream at ``rate_rps``."""
    t, out = 0.0, []
    for _ in range(n):
        t += rng.expovariate(rate_rps)
        out.append(t)
    return out


def mmpp_arrivals(rate_rps: float, n: int, rng: random.Random,
                  burst_factor: float = 4.0, switch_hz: float = 2.0,
                  ) -> list[float]:
    """2-state MMPP at mean ``rate_rps``: a *burst* state at
    ``burst_factor`` x the quiet state's rate, state residencies
    exponential with mean ``1/switch_hz`` seconds.  Same average load as
    the Poisson stream, very different short-term queue pressure."""
    # mean rate = (lo + hi) / 2 with equal mean residencies
    lo = 2.0 * rate_rps / (1.0 + burst_factor)
    hi = lo * burst_factor
    t, out = 0.0, []
    state_hi = rng.random() < 0.5
    while len(out) < n:
        # competing exponentials: whichever fires first — the next
        # arrival at the current state's rate, or a state switch —
        # advances time; memorylessness makes the discard-and-redraw of
        # the loser exact
        dt_arr = rng.expovariate(hi if state_hi else lo)
        dt_switch = rng.expovariate(switch_hz)
        if dt_switch < dt_arr:
            t += dt_switch
            state_hi = not state_hi
        else:
            t += dt_arr
            out.append(t)
    return out


def sample_mix(mix, n: int, rng: random.Random) -> list:
    """n draws from a ((weight, *payload), ...) mixture."""
    weights = [m[0] for m in mix]
    total = sum(weights)
    out = []
    for _ in range(n):
        u, acc = rng.random() * total, 0.0
        for m in mix:
            acc += m[0]
            if u <= acc:
                out.append(m[1:])
                break
    return out


def make_schedule(rows, *, rate_rps: float, n: int, svc_ms: float,
                  deadline_mix=DEADLINE_MIX, policy_mix=POLICY_MIX,
                  arrival: str = "poisson", backend=None, seed: int = 0,
                  ) -> list[tuple[float, Request]]:
    """An arrival-stamped request stream: ``[(t_offset_s, Request)]``.

    Deadlines are sampled from ``deadline_mix`` in units of ``svc_ms``
    (one request's calibrated solo service time), policies from
    ``policy_mix``."""
    rng = random.Random(seed)
    if arrival == "poisson":
        times = poisson_arrivals(rate_rps, n, rng)
    elif arrival == "mmpp":
        times = mmpp_arrivals(rate_rps, n, rng)
    else:
        raise ValueError(f"arrival must be 'poisson' or 'mmpp', got {arrival!r}")
    deadlines = [rng.uniform(lo, hi) * svc_ms
                 for (lo, hi) in sample_mix(deadline_mix, n, rng)]
    policies = [p for (p,) in sample_mix(policy_mix, n, rng)]
    return [
        (times[i], Request(x=rows[i % len(rows)], deadline_ms=deadlines[i],
                           policy=policies[i], backend=backend))
        for i in range(n)
    ]


# ---------------------------------------------------------------------------
# Virtual-time simulation
# ---------------------------------------------------------------------------


def _warm(srv: PooledAnytimeServer, rows, policy_mix, backend) -> None:
    """Compile every pool's lane traces before any timed point: submit
    directly to each pool (bypassing the router) so ALL pools warm."""
    for pool in srv.pools:
        for mix_entry in policy_mix:
            policy = mix_entry[1]
            for i in range(min(srv.pools[0].scheduler.capacity, len(rows))):
                pool.submit_request(Request(
                    x=rows[i], deadline_ms=300_000.0, policy=policy,
                    backend=backend))
    while srv.busy:
        srv.step()
    srv.metrics.reset()


def _warm_admission_counts(srv: PooledAnytimeServer, rows, policy,
                           backend) -> None:
    """Warm the eager admission-flush shapes: the first k-row slot
    admission compiles its own scatter/broadcast ops per distinct k.
    Submits k single-step requests per count so every flush width a
    paced stream can produce is compiled before anything is timed —
    wall-clock blips of ~100 ms mid-storm would break real-mode
    certificates that the steady-state cost model proved feasible."""
    for pool in srv.pools:
        for k in range(1, pool.scheduler.capacity + 1):
            for j in range(k):
                pool.submit_request(Request(
                    x=rows[j % len(rows)], deadline_ms=300_000.0,
                    policy=policy, backend=backend, budget_steps=1))
            while srv.busy:
                srv.step()
    srv.metrics.reset()


def drive_sim(srv: PooledAnytimeServer, clock: ManualClock, schedule,
              step_cost_s: float, submit=None) -> list:
    """Event-driven virtual-time drive of one arrival schedule.

    Each pool owns a virtual timeline: a ``pool.step()`` — one real
    dispatch->admit->harvest iteration — costs ``step_cost_s`` of that
    pool's virtual time only, so N busy pools advance N iterations per
    ``step_cost_s`` of virtual wall time, the parallelism N devices
    would give.  Arrivals interleave at their stamped offsets; work
    stealing runs whenever a pool goes idle, charged one step cost on
    the thief's timeline (the migration sync).  Returns the tickets.

    ``submit`` overrides the per-arrival submit call (default:
    ``srv.submit_request``) — a callback may catch
    :class:`~repro.serve.AdmissionRejected` and return ``None``, in
    which case no ticket is recorded for that arrival.
    """
    do_submit = submit if submit is not None else srv.submit_request
    t0 = clock.t
    next_t = {p: t0 for p in srv.pools}
    tickets = []
    i, n = 0, len(schedule)
    guard = 0
    limit = 1000 * (n + 10)
    while True:
        guard += 1
        if guard > limit:
            raise RuntimeError("virtual-time drive failed to converge")
        t_arr = t0 + schedule[i][0] if i < n else math.inf
        t_pool, pool = math.inf, None
        for p in srv.pools:
            if p.busy:
                tp = max(next_t[p], clock.t)
                if tp < t_pool:
                    t_pool, pool = tp, p
        if pool is None and i >= n:
            break
        if t_arr <= t_pool:
            clock.t = max(clock.t, t_arr)
            ticket = do_submit(schedule[i][1])
            if ticket is not None:
                tickets.append(ticket)
            i += 1
            continue
        clock.t = t_pool
        pool.step()
        next_t[pool] = clock.t + step_cost_s
        if srv.steal:
            for p in srv.pools:
                if not p.busy and srv.router.steal_into(p):
                    next_t[p] = max(next_t[p], clock.t) + step_cost_s
    return tickets


def _point_stats(tickets, snap, *, rate_rps: float, span_s: float) -> dict:
    results = [t.result() for t in tickets]
    lat = np.asarray([r.latency_ms for r in results])
    return {
        "offered_rps": rate_rps,
        "requests": len(results),
        "throughput_rps": len(results) / span_s if span_s > 0 else 0.0,
        # the anytime hit bar (>= 1 segment by deadline): EDF keeps this
        # near 1.0 deep into overload — quality degrades instead
        "hit_rate": float(np.mean([r.deadline_hit for r in results])),
        # the frontier's saturation signal: full plan served inside the
        # deadline.  Collapses once offered load passes pool capacity.
        "good_rate": float(np.mean([r.completed for r in results])),
        "latency_p50_ms": float(np.percentile(lat, 50)),
        "latency_p99_ms": float(np.percentile(lat, 99)),
        "steals": snap["steals"],
        "routed": snap["routed"],
    }


def run_sim_point(srv: PooledAnytimeServer, clock: ManualClock, rows, *,
                  rate_rps: float, n_requests: int, svc_ms: float,
                  step_cost_s: float, deadline_mix=DEADLINE_MIX,
                  policy_mix=POLICY_MIX, arrival: str = "poisson",
                  backend=None, seed: int = 0) -> dict:
    """One frontier point: drive one schedule through a (pre-warmed)
    pooled server in virtual time."""
    schedule = make_schedule(
        rows, rate_rps=rate_rps, n=n_requests, svc_ms=svc_ms,
        deadline_mix=deadline_mix, policy_mix=policy_mix, arrival=arrival,
        backend=backend, seed=seed)
    srv.metrics.reset()
    t_start = clock.t
    tickets = drive_sim(srv, clock, schedule, step_cost_s)
    span_s = max(clock.t - t_start, 1e-9)
    point = _point_stats(tickets, srv.metrics.snapshot(),
                         rate_rps=rate_rps, span_s=span_s)
    point["arrival"] = arrival
    return point


# ---------------------------------------------------------------------------
# Calibration: the measured cost model the simulation runs on
# ---------------------------------------------------------------------------


def calibrate(rt, rows, *, capacity: int, backend=None,
              policy: str = "backward_squirrel") -> dict:
    """Measure, on a real warmed single server, (a) the wall cost of one
    serving-loop iteration and (b) the end-to-end service time of one
    full batch — the constants the virtual-time frontier runs on."""
    from repro.serve import AnytimeServer

    server = AnytimeServer(rt, capacity=capacity)
    server.serve(list(rows[:capacity]), deadline_ms=300_000.0,
                 policy=policy, backend=backend)  # compile traces
    server.metrics.reset()
    t0 = time.perf_counter()
    results = server.serve(list(rows[:capacity]), deadline_ms=300_000.0,
                           policy=policy, backend=backend)
    wall_s = time.perf_counter() - t0
    steps = server._step_seq
    assert all(r.completed for r in results)
    step_cost_s = wall_s / max(steps, 1)
    # iterations one request occupies a slot for (full batch admitted at
    # once: every request rides every iteration)
    segs_per_request = steps
    svc_ms = segs_per_request * step_cost_s * 1e3
    return {
        "capacity": capacity,
        "wall_s": wall_s,
        "loop_iterations": steps,
        "step_cost_s": step_cost_s,
        "segments_per_request": segs_per_request,
        "svc_ms": svc_ms,
        # one pool's sustainable rate: capacity requests per batch time
        "base_rate_rps": capacity / wall_s,
    }


def calibrate_cost_model(rt, rows, *, capacity: int = 8, backend="jnp-ref",
                         policy: str = "backward_squirrel",
                         margin: float = 3.0, platform=None,
                         repeats: int = 2):
    """Calibrate a fresh :class:`~repro.serve.CostModel` on THIS machine.

    Runs a budget sweep on a real single server — full-batch serves
    plus single requests at every pow2 step budget, so the dispatcher
    visits every pow2 segment length certification may price.  The
    first sweep runs UNTRACED as warmup: it absorbs the jit compiles
    AND the eager admission-op compiles (the first k-row slot-batch
    admission flush compiles its own scatter shapes — wall time that is
    warmup, not recurring cost, and must not leak into a steady cell's
    max).  The ``repeats`` traced sweeps after it sample pure steady
    state; the trace folds into a WCET table
    (:func:`repro.obs.worst_case_table`) priced by
    :class:`~repro.serve.CostModel`.  The storm and bench gates
    calibrate fresh rather than loading the committed table: a
    certificate priced from another machine's maxima proves nothing
    about this one.

    Returns ``(cost_model, total_steps)`` — the priced model and the
    full plan length, so callers can price a full-plan request.
    """
    import jax

    from repro.obs import Tracer, worst_case_table
    from repro.serve import AnytimeServer, CostModel

    tracer = Tracer(enabled=False)
    server = AnytimeServer(rt, capacity=capacity, tracer=tracer)
    batch = list(rows[:capacity])

    def sweep() -> int:
        results = server.serve(batch, deadline_ms=300_000.0,
                               policy=policy, backend=backend)
        n_steps = results[0].total_steps
        b = 1
        while b < n_steps:
            ticket = server.submit(rows[0], QoS(
                deadline_ms=300_000.0, policy=policy, backend=backend,
                budget_steps=b))
            server.drain()
            ticket.result()
            b *= 2
        return n_steps

    total = sweep()  # warmup: jit traces + eager admission shapes
    tracer.enable()
    for _ in range(max(1, repeats)):
        total = sweep()
    tracer.disable()
    table = worst_case_table(
        tracer.events(),
        platform=platform or jax.default_backend(), margin=margin)
    return CostModel(table), total


# ---------------------------------------------------------------------------
# The frontier sweep (sim mode) and the real-mode smoke
# ---------------------------------------------------------------------------


def sweep_frontier(rt, rows, *, pools_list=(1, 4), capacity: int = 8,
                   n_requests: int = 96, rate_multipliers=RATE_MULTIPLIERS,
                   deadline_mix=DEADLINE_MIX, policy_mix=POLICY_MIX,
                   backend="jnp-ref", queue_shards: int = 2,
                   hit_floor: float = 0.99, seed: int = 0,
                   verbose: bool = True) -> dict:
    """Sustained-throughput-vs-p99-latency frontier across pool counts.

    One warmed pooled server per pool count serves every rate point
    (virtual time; the manual clock only moves forward).  Returns the
    per-point ladder, each configuration's knee, and the gated
    ``pool_scaling`` ratio."""
    cal = calibrate(rt, rows, capacity=capacity, backend=backend,
                    policy=policy_mix[0][1])
    base = cal["base_rate_rps"]
    out = {"mode": "sim", "calibration": cal, "hit_floor": hit_floor,
           "deadline_mix": [list(m) for m in deadline_mix],
           "policy_mix": [list(m) for m in policy_mix],
           "n_requests": n_requests, "capacity": capacity,
           "points": [], "knee_rps": {}, "knee_multiplier": {}}
    for pools in pools_list:
        clock = ManualClock()
        srv = PooledAnytimeServer(
            rt, pools=pools, capacity=capacity, clock=clock,
            queue_shards=queue_shards)
        _warm(srv, rows, policy_mix, backend)
        knee, knee_mult = 0.0, 0.0
        for mult in rate_multipliers:
            rate = mult * base
            point = run_sim_point(
                srv, clock, rows, rate_rps=rate, n_requests=n_requests,
                svc_ms=cal["svc_ms"], step_cost_s=cal["step_cost_s"],
                deadline_mix=deadline_mix, policy_mix=policy_mix,
                backend=backend, seed=seed + int(mult * 100))
            point["pools"] = pools
            point["rate_multiplier"] = mult
            out["points"].append(point)
            if point["good_rate"] >= hit_floor and rate > knee:
                knee, knee_mult = rate, mult
            if verbose:
                print(f"loadgen,pools,{pools},mult,{mult:.2f},"
                      f"offered_rps,{rate:.1f},good_rate,"
                      f"{point['good_rate']:.3f},hit_rate,"
                      f"{point['hit_rate']:.3f},p99_ms,"
                      f"{point['latency_p99_ms']:.2f},steals,"
                      f"{point['steals']}", flush=True)
        out["knee_rps"][str(pools)] = knee
        out["knee_multiplier"][str(pools)] = knee_mult
    lo, hi = str(min(pools_list)), str(max(pools_list))
    lo_knee = out["knee_rps"][lo]
    out["pool_scaling"] = (out["knee_rps"][hi] / lo_knee) if lo_knee else 0.0
    # one bursty sanity point at the large config's knee: same mean rate,
    # MMPP short-term pressure (reported, not gated)
    clock = ManualClock()
    srv = PooledAnytimeServer(
        rt, pools=max(pools_list), capacity=capacity, clock=clock,
        queue_shards=queue_shards)
    _warm(srv, rows, policy_mix, backend)
    burst_rate = max(out["knee_rps"][hi], base)
    burst = run_sim_point(
        srv, clock, rows, rate_rps=burst_rate, n_requests=n_requests,
        svc_ms=cal["svc_ms"], step_cost_s=cal["step_cost_s"],
        deadline_mix=deadline_mix, policy_mix=policy_mix, arrival="mmpp",
        backend=backend, seed=seed)
    burst["pools"] = max(pools_list)
    out["burst_point"] = burst
    if verbose:
        print(f"loadgen,mmpp,pools,{burst['pools']},offered_rps,"
              f"{burst_rate:.1f},good_rate,{burst['good_rate']:.3f},"
              f"p99_ms,{burst['latency_p99_ms']:.2f}", flush=True)
        print(f"loadgen,knee_rps,{out['knee_rps']},pool_scaling,"
              f"{out['pool_scaling']:.2f}", flush=True)
    return out


def run_real(rt, rows, *, pools: int, capacity: int = 8,
             n_requests: int = 32, rate_rps: float = 50.0,
             deadline_ms: float = 250.0, loop: str = "open",
             concurrency: int = 16, backend="jnp-ref",
             queue_shards: int = 2, seed: int = 0) -> dict:
    """Wall-clock smoke: threaded pooled serving under a paced stream.

    ``loop="open"`` submits at the schedule's arrival offsets no matter
    how completions lag; ``loop="closed"`` caps in-flight requests at
    ``concurrency``.  Exercises the real driver/steal machinery (the
    ``serve-scale`` CI job runs this under 8 emulated devices)."""
    rng = random.Random(seed)
    srv = PooledAnytimeServer(rt, pools=pools, capacity=capacity,
                              queue_shards=queue_shards)
    with srv:
        _warm(srv, rows, ((1.0, "backward_squirrel"),), backend)
        qos = QoS(deadline_ms=deadline_ms, backend=backend)
        t0 = time.perf_counter()
        tickets = []
        if loop == "open":
            times = poisson_arrivals(rate_rps, n_requests, rng)
            for i, t_arr in enumerate(times):
                lag = t0 + t_arr - time.perf_counter()
                if lag > 0:
                    time.sleep(lag)
                tickets.append(srv.submit(rows[i % len(rows)], qos))
            results = [t.result(timeout=120.0) for t in tickets]
        elif loop == "closed":
            results, inflight, i = [], [], 0
            while i < n_requests or inflight:
                while i < n_requests and len(inflight) < concurrency:
                    inflight.append(srv.submit(rows[i % len(rows)], qos))
                    i += 1
                results.append(inflight.pop(0).result(timeout=120.0))
        else:
            raise ValueError(f"loop must be 'open' or 'closed', got {loop!r}")
        wall_s = time.perf_counter() - t0
        snap = srv.metrics.snapshot()
    lat = np.asarray([r.latency_ms for r in results])
    return {
        "mode": "real", "loop": loop, "pools": pools,
        "requests": len(results), "wall_s": wall_s,
        "throughput_rps": len(results) / wall_s,
        "hit_rate": float(np.mean([r.deadline_hit for r in results])),
        "latency_p50_ms": float(np.percentile(lat, 50)),
        "latency_p99_ms": float(np.percentile(lat, 99)),
        "steals": snap["steals"],
        "routed": snap["routed"],
        "errors": sum(1 for r in results if r.error is not None),
    }


# ---------------------------------------------------------------------------
# The adversarial deadline storm: guaranteed + best-effort mixed traffic
# ---------------------------------------------------------------------------


def make_storm_schedule(rows, *, rate_rps: float, n: int, svc_ms: float,
                        guaranteed_wcet_ms: float,
                        guaranteed_frac: float = 0.25,
                        guaranteed_slack: float = 4.0,
                        best_effort_band=(0.4, 1.5),
                        policy: str = "backward_squirrel", backend=None,
                        arrival: str = "mmpp", seed: int = 0,
                        ) -> list[tuple[float, Request]]:
    """An adversarial mixed stream: a ``guaranteed_frac`` minority of
    ``guaranteed=True`` requests with deadlines ``guaranteed_slack`` x
    the priced idle-pool worst case, interleaved with best-effort
    traffic whose deadlines sit BELOW one solo service time
    (``best_effort_band`` x ``svc_ms``) — tight enough that under the
    bursty arrival process the best-effort lanes must degrade while the
    certified minority still has to land every deadline."""
    rng = random.Random(seed)
    if arrival == "mmpp":
        times = mmpp_arrivals(rate_rps, n, rng)
    else:
        times = poisson_arrivals(rate_rps, n, rng)
    out = []
    for i in range(n):
        if rng.random() < guaranteed_frac:
            req = Request(
                x=rows[i % len(rows)],
                deadline_ms=guaranteed_slack * guaranteed_wcet_ms,
                policy=policy, backend=backend, guaranteed=True)
        else:
            req = Request(
                x=rows[i % len(rows)],
                deadline_ms=rng.uniform(*best_effort_band) * svc_ms,
                policy=policy, backend=backend)
        out.append((times[i], req))
    return out


def run_storm(rt, rows, *, mode: str = "sim", pools: int = 2,
              capacity: int = 8, n_requests: int = 64,
              rate_multiplier=None, guaranteed_frac: float = 0.25,
              margin: float = 3.0, backend="jnp-ref",
              policy: str = "backward_squirrel", queue_shards: int = 2,
              gate: bool = True, seed: int = 0, verbose: bool = True,
              ) -> dict:
    """Deadline storm: certified guaranteed traffic through an
    overloaded degrade-mode pooled server.

    Calibrates a fresh cost model on this machine, then offers
    ``rate_multiplier`` x one pool's sustainable rate of mixed traffic
    (``--mode sim`` drives virtual time, ``--mode real`` paces wall
    clock through the threaded drivers).  The gate is the PR's hard
    guarantee: **every admitted guaranteed request completes its full
    plan inside its deadline — zero misses** — while the best-effort
    majority visibly degrades (shrunken step budgets) and
    non-admissible guaranteed requests are rejected at submit, never
    silently missed."""
    cal = calibrate(rt, rows, capacity=capacity, backend=backend,
                    policy=policy)
    cost_model, total_steps = calibrate_cost_model(
        rt, rows, capacity=capacity, backend=backend, policy=policy,
        margin=margin)
    wcet_full = cost_model.request_wcet_ms(total_steps, backend=backend)
    # default offered load: 3x the AGGREGATE capacity, whatever the pool
    # count — the storm must actually overload the tier deep enough that
    # per-pool backlog crosses the degrade threshold even while EDF
    # retires expired best-effort requests out of the queue
    if rate_multiplier is None:
        rate_multiplier = 3.0 * pools
    rate = rate_multiplier * cal["base_rate_rps"]
    # real mode breathes wall-clock jitter the virtual drive never sees:
    # give the certified minority proportionally more slack
    slack = 4.0 if mode == "sim" else 6.0
    schedule = make_storm_schedule(
        rows, rate_rps=rate, n=n_requests, svc_ms=cal["svc_ms"],
        guaranteed_wcet_ms=wcet_full, guaranteed_frac=guaranteed_frac,
        guaranteed_slack=slack, policy=policy, backend=backend, seed=seed)
    rejections = {"certified": 0, "overload": 0}

    clock = ManualClock() if mode == "sim" else None
    srv = PooledAnytimeServer(
        rt, pools=pools, capacity=capacity, admission="degrade",
        admission_k=1.0, queue_shards=queue_shards,
        cost_model=cost_model, **({"clock": clock} if clock else {}))

    def submit(req):
        try:
            return srv.submit_request(req)
        except CertificationFailed:
            rejections["certified"] += 1
        except AdmissionRejected:
            rejections["overload"] += 1
        return None

    if mode == "sim":
        _warm(srv, rows, ((1.0, policy),), backend)
        _warm_admission_counts(srv, rows, policy, backend)
        t_start = clock.t
        tickets = drive_sim(srv, clock, schedule, cal["step_cost_s"],
                            submit=submit)
        span_s = max(clock.t - t_start, 1e-9)
        results = [t.result() for t in tickets]
        snap = srv.metrics.snapshot()
    elif mode == "real":
        with srv:
            _warm(srv, rows, ((1.0, policy),), backend)
            _warm_admission_counts(srv, rows, policy, backend)
            t0 = time.perf_counter()
            tickets = []
            for t_arr, req in schedule:
                lag = t0 + t_arr - time.perf_counter()
                if lag > 0:
                    time.sleep(lag)
                ticket = submit(req)
                if ticket is not None:
                    tickets.append(ticket)
            results = [t.result(timeout=120.0) for t in tickets]
            span_s = max(time.perf_counter() - t0, 1e-9)
            snap = srv.metrics.snapshot()
    else:
        raise ValueError(f"mode must be 'sim' or 'real', got {mode!r}")

    guaranteed = [(t, t.result()) for t in tickets if t.request.guaranteed]
    misses = [
        (t, r) for t, r in guaranteed
        if not r.completed or r.latency_ms > t.request.deadline_ms]
    best_effort = [r for r in results if not r.guaranteed]
    out = {
        "mode": mode, "pools": pools, "offered_rps": rate,
        "requests": n_requests, "delivered": len(results),
        "span_s": span_s,
        "guaranteed_admitted": len(guaranteed),
        "guaranteed_misses": len(misses),
        "metrics_guaranteed_misses": snap["guaranteed_misses"],
        "certified_rejected": rejections["certified"],
        "overload_rejected": rejections["overload"],
        "degraded_requests": snap["degraded_requests"],
        "best_effort_delivered": len(best_effort),
        "best_effort_good_rate": (
            float(np.mean([r.completed for r in best_effort]))
            if best_effort else 0.0),
        "priced_full_wcet_ms": wcet_full,
    }
    if verbose:
        print(f"loadgen,storm,{mode},pools,{pools},"
              f"guaranteed,{out['guaranteed_admitted']},misses,"
              f"{out['guaranteed_misses']},certified_rejected,"
              f"{out['certified_rejected']},degraded,"
              f"{out['degraded_requests']},be_good_rate,"
              f"{out['best_effort_good_rate']:.3f}", flush=True)
    if gate:
        assert out["guaranteed_admitted"] > 0, (
            "storm admitted no guaranteed requests — the certified lane "
            "was never exercised (deadline slack too tight for the "
            "priced worst case?)")
        assert not misses and snap["guaranteed_misses"] == 0, (
            f"{len(misses)} certified guaranteed request(s) missed their "
            f"deadline (metrics counted {snap['guaranteed_misses']}) — "
            "a certificate was issued and then broken: "
            + "; ".join(
                f"req {t.request.request_id}: completed={r.completed}, "
                f"latency {r.latency_ms:.3f} ms vs deadline "
                f"{t.request.deadline_ms:.3f} ms"
                for t, r in misses[:5]))
        assert out["degraded_requests"] > 0, (
            "storm never degraded best-effort traffic — the offered "
            "rate is not actually adversarial for this capacity")
    return out


def run(dataset: str = "magic", n_trees: int = 6, depth: int = 5,
        capacity: int = 8, n_requests: int = 96, pools_list=(1, 4),
        backend: str = "jnp-ref", seed: int = 0,
        min_pool_scaling: float = 3.0, hit_floor: float = 0.99,
        gate: bool = True, verbose: bool = True) -> dict:
    """Frontier sweep + gate: >= ``min_pool_scaling`` x knee scaling from
    the smallest to the largest pool count at equal (>= ``hit_floor``)
    hit rate, or the build fails."""
    fa, pp, yor, te, yte = build_pipeline(
        dataset, n_trees, depth, seed=seed, n_order=200, n_test=128)
    rt = runtime_for(fa, pp, yor)
    out = sweep_frontier(
        rt, te, pools_list=pools_list, capacity=capacity,
        n_requests=n_requests, backend=backend, hit_floor=hit_floor,
        seed=seed, verbose=verbose)
    if gate:
        lo, hi = str(min(pools_list)), str(max(pools_list))
        assert out["knee_rps"][lo] > 0, (
            f"single-pool config never reached good-rate >= {hit_floor} — "
            "the rate ladder starts above its capacity (re-calibrate)")
        assert out["pool_scaling"] >= min_pool_scaling, (
            f"{hi}-pool knee only {out['pool_scaling']:.2f}x the {lo}-pool "
            f"knee at >= {hit_floor:.0%} good rate "
            f"(gate: >= {min_pool_scaling}x)")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--mode", default="sim", choices=("sim", "real"))
    ap.add_argument("--scenario", default="frontier",
                    choices=("frontier", "storm"),
                    help="frontier: throughput-vs-p99 sweep + pool-"
                         "scaling gate; storm: adversarial guaranteed + "
                         "best-effort mix + zero-certified-miss gate")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CI-sized)")
    ap.add_argument("--dataset", default="magic")
    ap.add_argument("--pools", type=int, default=4,
                    help="real mode / storm: pool count")
    ap.add_argument("--loop", default="open", choices=("open", "closed"),
                    help="real mode: open- vs closed-loop pacing")
    ap.add_argument("--rate", type=float, default=50.0,
                    help="real mode: offered requests/sec")
    ap.add_argument("--requests", type=int, default=None)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.scenario == "storm":
        fa, pp, yor, te, yte = build_pipeline(
            args.dataset, 6, 5, seed=args.seed, n_order=200, n_test=128)
        rt = runtime_for(fa, pp, yor)
        # 48 even for smoke: the guaranteed minority is a Bernoulli draw
        # per request, and smaller populations can leave the certified
        # lane (and the degrade threshold) unexercised
        n = args.requests or (48 if args.smoke else 96)
        pools = min(args.pools, 2) if args.smoke else args.pools
        # real smoke shrinks the slot count too: the degrade threshold
        # scales with capacity, and real threads drain the smoke-sized
        # stream fast enough that 8-wide pools never build a backlog
        cap = 4 if (args.smoke and args.mode == "real") else 8
        out = run_storm(rt, te, mode=args.mode, pools=pools, capacity=cap,
                        n_requests=n, seed=args.seed)
        print(f"loadgen,storm,gate,ok,guaranteed,"
              f"{out['guaranteed_admitted']},misses,0,"
              f"certified_rejected,{out['certified_rejected']}")
        return
    if args.mode == "sim":
        n = args.requests or (64 if args.smoke else 96)
        out = run(dataset=args.dataset, n_requests=n, seed=args.seed)
        print(f"loadgen,gate,ok,pool_scaling,{out['pool_scaling']:.2f}")
    else:
        fa, pp, yor, te, yte = build_pipeline(
            args.dataset, 6, 5, seed=args.seed, n_order=200, n_test=128)
        rt = runtime_for(fa, pp, yor)
        n = args.requests or (24 if args.smoke else 64)
        out = run_real(rt, te, pools=args.pools, n_requests=n,
                       rate_rps=args.rate, loop=args.loop, seed=args.seed)
        assert out["errors"] == 0, f"{out['errors']} request(s) errored"
        print(f"loadgen,real,{args.loop},pools,{out['pools']},"
              f"throughput_rps,{out['throughput_rps']:.1f},hit_rate,"
              f"{out['hit_rate']:.3f},p99_ms,{out['latency_p99_ms']:.2f},"
              f"steals,{out['steals']}")


if __name__ == "__main__":
    main()
